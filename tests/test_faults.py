"""Fault-injection harness: the chaos must itself be deterministic.

Every decision in ``core.faults`` is a pure function of
``(plan.seed, scope ids)`` — these tests pin that contract (same seed →
same chaos forever; different seed → different chaos), plus the shape of
each injected fault: drops are permanent across attempts, flakiness
re-rolls per attempt, duplicates double chunks, corruption flips exactly
one bit, and file corruption damages checkpoints the way crashes do.
"""
import numpy as np
import pytest

from repro.core import faults
from repro.core.faults import FaultPlan, ShardFailure


def test_plan_validates_probabilities():
    with pytest.raises(ValueError, match="drop"):
        FaultPlan(drop=1.5)
    with pytest.raises(ValueError, match="flaky"):
        FaultPlan(flaky=-0.1)
    with pytest.raises(ValueError, match="delay_seconds"):
        FaultPlan(delay_seconds=-1.0)


def test_decisions_are_deterministic_and_seed_keyed():
    a = FaultPlan(seed=7, drop=0.4, flaky=0.4, delay=0.4, duplicate=0.4,
                  corrupt=0.4)
    b = FaultPlan(seed=7, drop=0.4, flaky=0.4, delay=0.4, duplicate=0.4,
                  corrupt=0.4)
    c = FaultPlan(seed=8, drop=0.4, flaky=0.4, delay=0.4, duplicate=0.4,
                  corrupt=0.4)
    va = [(a.is_dropped(s), a.is_flaky(s, 0), a.delay_for(s) > 0,
           a.chunk_events(s, 0)) for s in range(64)]
    vb = [(b.is_dropped(s), b.is_flaky(s, 0), b.delay_for(s) > 0,
           b.chunk_events(s, 0)) for s in range(64)]
    vc = [(c.is_dropped(s), c.is_flaky(s, 0), c.delay_for(s) > 0,
           c.chunk_events(s, 0)) for s in range(64)]
    assert va == vb           # replayable
    assert va != vc           # actually keyed by the seed
    # each fault type fires with roughly its configured probability
    assert 0 < sum(v[0] for v in va) < 64


def test_drop_is_permanent_flaky_is_transient():
    plan = FaultPlan(seed=3, drop_shards=(5,), flaky=0.5)
    # permanent: every attempt sees the same death
    assert all(plan.is_dropped(5) for _ in range(10))
    # transient: the (shard, attempt) keying must re-roll — some shard
    # fails on attempt 0 and passes on a later attempt
    rescued = any(plan.is_flaky(s, 0) and not plan.is_flaky(s, 1)
                  for s in range(64))
    assert rescued


def test_chaos_chunks_drop_raises_before_any_yield():
    plan = FaultPlan(seed=0, drop_shards=(2,))
    delivered = []
    with pytest.raises(ShardFailure, match="shard 2"):
        for c in faults.chaos_chunks(plan, 2, [np.ones((4, 2))]):
            delivered.append(c)
    assert delivered == []    # all-or-nothing: nothing escaped


def test_chaos_chunks_duplicate_and_passthrough():
    chunks = [np.full((3, 2), i, np.float32) for i in range(4)]
    dup = list(faults.chaos_chunks(
        FaultPlan(seed=0, duplicate=1.0), 0, chunks))
    assert len(dup) == 8      # every chunk delivered twice
    clean = list(faults.chaos_chunks(FaultPlan(seed=0), 0, chunks))
    assert len(clean) == 4
    for got, want in zip(clean, chunks):
        np.testing.assert_array_equal(got, want)


def test_corruption_flips_exactly_one_bit():
    x = np.arange(64, dtype=np.float32)
    y = faults.flip_bit(x, np.random.default_rng(0))
    xor = x.view(np.uint8) ^ y.view(np.uint8)
    assert int(np.unpackbits(xor).sum()) == 1
    # chaos_chunks with corrupt=1.0 applies it per chunk, deterministically
    c1 = list(faults.chaos_chunks(FaultPlan(seed=1, corrupt=1.0), 0, [x]))
    c2 = list(faults.chaos_chunks(FaultPlan(seed=1, corrupt=1.0), 0, [x]))
    np.testing.assert_array_equal(c1[0], c2[0])
    assert not np.array_equal(c1[0], x)


def test_corrupt_state_changes_digest():
    from repro.core import stream
    import jax

    st = stream.init(jax.random.key(0), rows=2, log2_cols=6, pool=8)
    before = stream.state_digest(st)
    bad = faults.corrupt_state(st, seed=0, shard=1)
    assert stream.state_digest(bad) != before
    # the original was not mutated in place
    assert stream.state_digest(st) == before


def test_corrupt_file_flip_and_truncate(tmp_path):
    p = tmp_path / "blob.bin"
    payload = bytes(range(256)) * 8
    p.write_bytes(payload)
    faults.corrupt_file(p, seed=0, mode="flip")
    after = p.read_bytes()
    assert len(after) == len(payload)
    diff = [i for i, (x, y) in enumerate(zip(payload, after)) if x != y]
    assert len(diff) == 1
    p.write_bytes(payload)
    faults.corrupt_file(p, seed=0, mode="truncate", truncate_frac=0.25)
    assert p.stat().st_size == len(payload) // 4
    with pytest.raises(ValueError, match="mode"):
        faults.corrupt_file(p, mode="shred")
