"""Quantizer: pack/unpack roundtrip (property), grid fitting, collision model."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import quantize, u64


@given(dims=st.integers(1, 12), bins=st.integers(2, 32),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(dims, bins, seed):
    bits = max(1, math.ceil(math.log2(bins)))
    if dims * bits > 64:
        return
    rng = np.random.default_rng(seed)
    grid = quantize.GridSpec(dims=dims, bins=bins,
                             lo=np.zeros(dims, np.float32),
                             hi=np.ones(dims, np.float32))
    coords = jnp.asarray(rng.integers(0, bins, size=(64, dims)), jnp.uint32)
    key = quantize.pack(grid, coords)
    back = quantize.unpack(grid, key)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(coords))


def test_pack_rejects_too_many_bits():
    with pytest.raises(ValueError):
        quantize.GridSpec(dims=20, bins=32, lo=np.zeros(20, np.float32),
                          hi=np.ones(20, np.float32))


def test_quantize_bounds_and_clip():
    grid = quantize.GridSpec(dims=2, bins=8,
                             lo=np.zeros(2, np.float32),
                             hi=np.ones(2, np.float32))
    pts = jnp.asarray([[-5.0, 0.5], [0.999, 2.0], [0.0, 0.0]])
    q = quantize.quantize(grid, pts)
    assert q.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(q), [[0, 4], [7, 7], [0, 0]])


def test_fit_grid_covers_data():
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.normal(size=(1000, 4)).astype(np.float32))
    grid = quantize.fit_grid(pts, bins=16)
    q = np.asarray(quantize.quantize(grid, pts))
    assert q.min() >= 0 and q.max() <= 15
    # interior points (not exactly on the boundary after padding)
    assert (np.asarray(grid.lo) < np.asarray(pts).min(0)).all()
    assert (np.asarray(grid.hi) > np.asarray(pts).max(0)).all()


def test_cell_center_inverse():
    grid = quantize.GridSpec(dims=3, bins=10,
                             lo=np.zeros(3, np.float32),
                             hi=np.ones(3, np.float32) * 10)
    coords = jnp.asarray([[0, 5, 9]], jnp.uint32)
    c = np.asarray(quantize.cell_center(grid, coords))[0]
    np.testing.assert_allclose(c, [0.5, 5.5, 9.5], rtol=1e-5)
    # quantizing the center gives back the coords
    q = np.asarray(quantize.quantize(grid, jnp.asarray(c)[None]))
    np.testing.assert_array_equal(q[0], [0, 5, 9])


def test_collision_rate_paper_numbers():
    """Paper §III-2: K=1e4, D=10, M=8 -> C≈1057; M=16 -> C≈0.00144."""
    _, c8 = quantize.collision_rate(8.0**10, 10**4, 10)
    _, c16 = quantize.collision_rate(16.0**10, 10**4, 10)
    assert abs(c8 - 1057) / 1057 < 0.05
    assert abs(c16 - 0.00144) / 0.00144 < 0.05


def test_points_to_keys_distinct_cells_distinct_keys():
    grid = quantize.GridSpec(dims=2, bins=4,
                             lo=np.zeros(2, np.float32),
                             hi=np.ones(2, np.float32))
    pts = jnp.asarray([[0.1, 0.1], [0.9, 0.9], [0.1, 0.12]])
    k = quantize.points_to_keys(grid, pts)
    keys = u64.to_py(k)
    assert keys[0] != keys[1]
    assert keys[0] == keys[2]
