"""Geo-distributed sketching: multi-device shard_map tests.

These run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the flag must be set before jax initializes, and the main test process
must keep seeing 1 device — per the project's dry-run discipline).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.core import geo, pipeline, quantize, sketch, heavy_hitters

    assert len(jax.devices()) == 8

    # clustered data, sharded over 2 "pods" x 4 "data" workers
    rng = np.random.default_rng(0)
    n = 64_000
    centers = np.asarray([[0.2]*4, [0.8]*4, [0.2, 0.8, 0.2, 0.8]])
    pts = [rng.uniform(0, 1, size=(n // 4, 4))]
    for c in centers:
        pts.append(c + 0.02 * rng.normal(size=(n // 4, 4)))
    pts = np.clip(np.concatenate(pts), 0, 1).astype(np.float32)
    rng.shuffle(pts)
    pts = jnp.asarray(pts)

    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    grid = quantize.fit_grid(pts, bins=16)

    # --- distributed extraction (hierarchical: data then pod) ---
    res = geo.geo_extract(mesh, grid, pts, rows=8, log2_cols=12,
                          top_k=64, data_axes=("data", "pod"), seed=0)
    assert int(res.total_count) == n

    # --- single-device reference: same grid, same seed => same hashes ---
    key_hi, key_lo = quantize.points_to_keys(grid, pts)
    sk = sketch.init(jax.random.key(0), 8, 12)
    sk = sketch.update_sorted(sk, key_hi, key_lo)
    hh_ref = heavy_hitters.extract(sk, key_hi, key_lo, k=64)

    # merged sketch table must equal the single-shot table EXACTLY
    # (linearity: sum of shard sketches == sketch of concatenation)
    np.testing.assert_allclose(np.asarray(res.merged.table),
                               np.asarray(sk.table), atol=1e-3)

    # the recovered HH key sets must agree
    def keyset(hh):
        m = np.asarray(hh.mask)
        hi = np.asarray(hh.key_hi, np.uint64)[m]
        lo = np.asarray(hh.key_lo, np.uint64)[m]
        return set(((hi << np.uint64(32)) | lo).tolist())

    ks_dist, ks_ref = keyset(res.hh), keyset(hh_ref)
    overlap = len(ks_dist & ks_ref) / max(len(ks_ref), 1)
    assert overlap > 0.95, f"HH sets diverge: {overlap}"

    # --- streaming ingest (lax.scan over batches) on the same 8 devices ---
    # each device reads its own slice of pts in 4 chunks; the merged sketch
    # must equal the one-shot table EXACTLY (integer counts in f32), and
    # the recovered HH set must match the one-shot distributed result.
    per = n // 8
    chunk = per // 4

    def shard_fn(idx, b):
        start = idx * per + b * chunk
        ids = start + jnp.arange(chunk)
        return pts[ids], None

    res_s = geo.geo_extract_from_shards(
        mesh, grid, shard_fn, rows=8, log2_cols=12, top_k=64,
        data_axes=("data", "pod"), seed=0, num_batches=4)
    assert int(res_s.total_count) == n
    np.testing.assert_array_equal(np.asarray(res_s.merged.table),
                                  np.asarray(res.merged.table))

    # every unambiguously-heavy cell (est >= 20: cluster cells, far above
    # the count~1 background tie zone) must be recovered identically
    def heavyset(hh, thresh=20.0):
        m = np.asarray(hh.mask) & (np.asarray(hh.count) >= thresh)
        hi = np.asarray(hh.key_hi, np.uint64)[m]
        lo = np.asarray(hh.key_lo, np.uint64)[m]
        return set(((hi << np.uint64(32)) | lo).tolist())

    hs_dist, hs_stream = heavyset(res.hh), heavyset(res_s.hh)
    assert len(hs_dist) > 10
    assert hs_stream == hs_dist, "streaming lost heavy cells"
    print("GEO-OK")
""")


@pytest.mark.slow
def test_geo_extract_multidevice_matches_single_device(tmp_path):
    script = tmp_path / "geo_test.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    assert "GEO-OK" in out.stdout
