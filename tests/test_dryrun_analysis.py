"""HLO analysis + roofline: trip-count multiplication, dot flops, collective
accounting, sharding-spec construction, and a real (small-mesh) lower+compile
of one smoke arch in a subprocess."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo

_FAKE_HLO = """\
HloModule test

%body.1 (param: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %param = (s32[], f32[128,128]) parameter(0)
  %gte.0 = s32[] get-tuple-element(%param), index=0
  %gte.1 = f32[128,128]{1,0} get-tuple-element(%param), index=1
  %dot.1 = f32[128,128]{1,0} dot(%gte.1, %gte.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar.1 = f32[128,128]{1,0} all-reduce(%dot.1), replica_groups={{0,1,2,3}}, to_apply=%sum.1
  %c1 = s32[] constant(1)
  %add.1 = s32[] add(%gte.0, %c1)
  ROOT %tuple.1 = (s32[], f32[128,128]) tuple(%add.1, %ar.1)
}

%cond.1 (param.1: (s32[], f32[128,128])) -> pred[] {
  %param.1 = (s32[], f32[128,128]) parameter(0)
  %gte.2 = s32[] get-tuple-element(%param.1), index=0
  %c10 = s32[] constant(10)
  ROOT %lt = pred[] compare(%gte.2, %c10), direction=LT
}

%sum.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[128,128]) -> (s32[], f32[128,128]) {
  %p0 = f32[128,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[128,128]) tuple(%c0, %p0)
  %ag.1 = f32[128,512]{1,0} all-gather(%p0), replica_groups={{0,256}}, dimensions={1}
  ROOT %while.1 = (s32[], f32[128,128]) while(%t0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
}
"""


def test_trip_count_multiplication():
    res = analyze_hlo(_FAKE_HLO)
    # dot: 2*128^3 per iteration x 10 trips
    assert res["flops"] == pytest.approx(10 * 2 * 128 ** 3)
    # all-reduce inside loop: 128*128*4 bytes x 10; all-gather outside: x1
    ar = 10 * 128 * 128 * 4
    ag = 128 * 512 * 4
    assert res["per_kind"]["all-reduce"] == pytest.approx(ar)
    assert res["per_kind"]["all-gather"] == pytest.approx(ag)
    assert res["collective_bytes"] == pytest.approx(ar + ag)
    # the all-gather's groups span the pod boundary (0 and 256)
    assert res["collective_dcn_bytes"] == pytest.approx(ag)


def test_param_pspecs_cover_all_leaves():
    import jax
    from repro.configs import get_config
    from repro.launch.sharding import param_pspecs, ShardingPolicy
    from repro.train.steps import param_specs
    for arch in ("tinyllama-1.1b", "jamba-v0.1-52b", "arctic-480b",
                 "seamless-m4t-large-v2", "mamba2-130m"):
        cfg = get_config(arch, smoke=True)
        shapes = param_specs(cfg, tp=1)
        specs = param_pspecs(shapes, ShardingPolicy())
        for (pth, shape), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(shapes)[0],
                jax.tree_util.tree_flatten_with_path(specs)[0]):
            assert len(spec) <= len(shape.shape), (arch, pth, spec)


_SMALL_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.launch import sharding as shlib
    from repro.train.steps import (TrainStepConfig, make_train_step,
                                   make_batch_specs, train_state_specs)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("jamba-v0.1-52b", smoke=True)
    pol = shlib.ShardingPolicy(act_mode="seq_tp")
    shlib.set_activation_sharding(mesh, ("pod", "data"), "model",
                                  act_mode="seq_tp")
    tcfg = TrainStepConfig(q_chunk=16)
    state_shape = train_state_specs(cfg, tcfg, tp=2)
    batch_shape = make_batch_specs(cfg, global_batch=8, seq_len=32)
    state_sh = shlib.to_shardings(mesh,
                                  shlib.train_state_pspecs(state_shape, pol))
    batch_sh = shlib.to_shardings(mesh,
                                  shlib.batch_pspecs(batch_shape, mesh))
    step = make_train_step(cfg, tcfg, grad_shardings=state_sh["params"])
    lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                      out_shardings=(state_sh, None)).lower(
        state_shape, batch_shape)
    compiled = lowered.compile()
    assert compiled.cost_analysis() is not None
    hlo = compiled.as_text()
    assert "all-reduce" in hlo or "all-gather" in hlo  # it IS distributed
    print("MINI-DRYRUN-OK")
""")


@pytest.mark.slow
def test_mini_multipod_dryrun_compiles(tmp_path):
    """A 2x2x2 'multi-pod' mesh lower+compile of the hybrid smoke arch —
    the same code path as the 512-chip production dry-run."""
    script = tmp_path / "mini_dryrun.py"
    script.write_text(_SMALL_MESH_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    assert "MINI-DRYRUN-OK" in out.stdout


def test_production_dryrun_results_green():
    """The committed dry-run artifacts must cover all 40 cells x 2 meshes
    with no errors (the actual deliverable-(e) evidence)."""
    d = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run artifacts not present")
    recs = []
    for name in os.listdir(d):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                recs.append(json.load(f))
    assert len(recs) == 80
    assert sum(r["status"] == "ok" for r in recs) == 64
    assert sum(r["status"] == "skipped" for r in recs) == 16
    assert not any(r["status"] == "error" for r in recs)
