"""data/loader: shard-plan determinism, no-double-processing, resume.

The streaming pipeline (pipeline.run_streaming via chunks_from_loader)
leans on three loader invariants that were previously untested:

  1. the plan is a pure function of (num_shards, num_hosts, epoch) —
     every host computes the identical assignment with no coordination;
  2. between a host's primary pass (__iter__) and its straggler pickup
     (steal), no shard is ever processed twice;
  3. resuming from a recorded `completed` set replays exactly the
     remaining shards, in plan order.
"""
import numpy as np
import pytest

from repro.data.loader import ShardPlan, ShardedLoader


def _mk(shard, b):
    return {"shard": shard, "batch": b}


# ------------------------------------------------------------------- plan
@pytest.mark.parametrize("num_shards,num_hosts", [(64, 4), (48, 3), (16, 16)])
@pytest.mark.parametrize("epoch", [0, 1, 7])
def test_plan_deterministic_partition(num_shards, num_hosts, epoch):
    """Every host, recomputing the plan independently, sees the same
    disjoint cover of all shards."""
    seen = []
    for h in range(num_hosts):
        a = ShardPlan(num_shards, num_hosts, epoch).shards_for(h)
        b = ShardPlan(num_shards, num_hosts, epoch).shards_for(h)
        assert a == b                          # fresh objects, same answer
        seen.extend(a)
    assert sorted(seen) == list(range(num_shards))


def test_plan_epoch_rotation_moves_shards():
    plan0 = ShardPlan(64, 4, epoch=0)
    plan1 = ShardPlan(64, 4, epoch=1)
    assert plan0.shards_for(0) != plan1.shards_for(0)
    # rotation must still partition
    seen = sorted(s for h in range(4) for s in plan1.shards_for(h))
    assert seen == list(range(64))


def test_steal_order_covers_exactly_the_others():
    plan = ShardPlan(32, 4, epoch=2)
    for h in range(4):
        mine = set(plan.shards_for(h))
        stolen = plan.steal_order(h)
        assert len(stolen) == len(set(stolen))       # no duplicates
        assert set(stolen) == set(range(32)) - mine  # everyone else's


# ----------------------------------------------- iterate + steal, no double
def test_no_shard_processed_twice_between_iter_and_steal():
    plan = ShardPlan(24, 3)
    loader = ShardedLoader(plan, host=0, make_batch=_mk,
                           batches_per_shard=2)
    primary = [s for s, _ in loader]
    # host 1 finished two shards before dying; host 2 finished none
    done_elsewhere = plan.shards_for(1)[:2]
    stolen = [s for s, _ in loader.steal(done_elsewhere)]
    processed = primary + stolen
    # each shard appears exactly batches_per_shard times, and the
    # externally-completed shards never appear at all
    counts = {s: processed.count(s) for s in set(processed)}
    assert all(c == 2 for c in counts.values())
    assert set(done_elsewhere).isdisjoint(counts)
    assert sorted(set(processed) | set(done_elsewhere)) == list(range(24))


def test_steal_after_full_completion_is_empty():
    plan = ShardPlan(12, 2)
    fast = ShardedLoader(plan, host=0, make_batch=_mk)
    list(fast)
    assert list(fast.steal(plan.shards_for(1))) == []


# ------------------------------------------------------------------ resume
def test_resume_from_completed_replays_remainder():
    plan = ShardPlan(20, 2, epoch=3)
    full_order = [s for s, _ in ShardedLoader(plan, 0, _mk)]
    crashed_after = 3
    completed = full_order[:crashed_after]
    resumed = ShardedLoader(plan, 0, _mk, completed=completed)
    rest = [s for s, _ in resumed]
    assert rest == full_order[crashed_after:]    # plan order, no repeats
    assert resumed.completed == set(full_order)


def test_resume_yields_all_batches_of_incomplete_shards():
    """A shard is only `completed` once ALL its batches ran — resuming an
    incomplete shard replays it from batch 0 (batch idempotence is the
    make_batch contract)."""
    plan = ShardPlan(6, 1)
    loader = ShardedLoader(plan, 0, _mk, batches_per_shard=3)
    batches = [(s, b["batch"]) for s, b in loader]
    assert len(batches) == 18
    for s in plan.shards_for(0):
        assert [b for sh, b in batches if sh == s] == [0, 1, 2]


def test_batches_feed_streaming_pipeline_in_plan_order():
    """chunks_from_loader: fresh loader per pass, identical order."""
    from repro.core.pipeline import chunks_from_loader
    plan = ShardPlan(8, 1, epoch=1)

    def make(shard, b):
        return np.full((4, 2), shard, np.float32)

    factory = chunks_from_loader(plan, 0, make)
    pass1 = [int(c[0, 0]) for c in factory()]
    pass2 = [int(c[0, 0]) for c in factory()]
    assert pass1 == pass2 == plan.shards_for(0)


def test_chunks_from_loader_steals_exactly_once():
    """Straggler mitigation end to end: two hosts share one completion
    board (``on_shard_done`` publishes, ``globally_completed`` re-reads it
    at steal time).  Host 1 stalls mid-pass; host 0 finishes its primary
    slice and steals the leftovers — between the two of them EVERY shard
    is processed exactly once, with every batch, and nothing host 1
    already published is re-ingested."""
    from repro.core.pipeline import chunks_from_loader
    plan = ShardPlan(16, 2, epoch=4)
    board = set()

    def make(shard, b):
        return np.full((2, 2), shard, np.float32)

    def factory_for(host):
        return chunks_from_loader(plan, host, make, batches_per_shard=2,
                                  steal=True,
                                  globally_completed=lambda: set(board),
                                  on_shard_done=board.add)

    fast, slow = iter(factory_for(0)()), iter(factory_for(1)())
    got = {0: [], 1: []}
    # interleave; host 1 dies after 5 chunks (mid-shard: odd count with
    # batches_per_shard=2, so its in-flight shard is NOT on the board)
    for i in range(5):
        got[0].append(int(next(fast)[0, 0]))
        got[1].append(int(next(slow)[0, 0]))
    for c in fast:                       # host 0 drains primary + steals
        got[0].append(int(c[0, 0]))

    c0 = {s: got[0].count(s) for s in set(got[0])}
    c1 = {s: got[1].count(s) for s in set(got[1])}
    in_flight = {got[1][-1]}             # host 1 died mid-shard (5 chunks)
    # host 0 saw every one of its shards exactly once, with both batches
    assert all(c == 2 for c in c0.values())
    # host 1's finished shards are complete; only its in-flight one is cut
    assert all(c == 2 for s, c in c1.items() if s not in in_flight)
    assert c1[got[1][-1]] == 1
    # no shard was ingested by both hosts, except host 1's in-flight one
    # (it never reached the board, so host 0 must re-ingest it — batch
    # idempotence, same contract as crash-resume)
    assert (set(c0) & set(c1)) <= in_flight
    # between them every shard ran
    assert set(c0) | set(c1) == set(range(16))
    # host 0 really did steal: it processed shards outside its slice
    assert set(c0) - set(plan.shards_for(0))
