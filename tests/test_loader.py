"""data/loader: shard-plan determinism, no-double-processing, resume.

The streaming pipeline (pipeline.run_streaming via chunks_from_loader)
leans on three loader invariants that were previously untested:

  1. the plan is a pure function of (num_shards, num_hosts, epoch) —
     every host computes the identical assignment with no coordination;
  2. between a host's primary pass (__iter__) and its straggler pickup
     (steal), no shard is ever processed twice;
  3. resuming from a recorded `completed` set replays exactly the
     remaining shards, in plan order.
"""
import numpy as np
import pytest

from repro.data.loader import ShardPlan, ShardedLoader


def _mk(shard, b):
    return {"shard": shard, "batch": b}


# ------------------------------------------------------------------- plan
@pytest.mark.parametrize("num_shards,num_hosts", [(64, 4), (48, 3), (16, 16)])
@pytest.mark.parametrize("epoch", [0, 1, 7])
def test_plan_deterministic_partition(num_shards, num_hosts, epoch):
    """Every host, recomputing the plan independently, sees the same
    disjoint cover of all shards."""
    seen = []
    for h in range(num_hosts):
        a = ShardPlan(num_shards, num_hosts, epoch).shards_for(h)
        b = ShardPlan(num_shards, num_hosts, epoch).shards_for(h)
        assert a == b                          # fresh objects, same answer
        seen.extend(a)
    assert sorted(seen) == list(range(num_shards))


def test_plan_epoch_rotation_moves_shards():
    plan0 = ShardPlan(64, 4, epoch=0)
    plan1 = ShardPlan(64, 4, epoch=1)
    assert plan0.shards_for(0) != plan1.shards_for(0)
    # rotation must still partition
    seen = sorted(s for h in range(4) for s in plan1.shards_for(h))
    assert seen == list(range(64))


def test_steal_order_covers_exactly_the_others():
    plan = ShardPlan(32, 4, epoch=2)
    for h in range(4):
        mine = set(plan.shards_for(h))
        stolen = plan.steal_order(h)
        assert len(stolen) == len(set(stolen))       # no duplicates
        assert set(stolen) == set(range(32)) - mine  # everyone else's


# ----------------------------------------------- iterate + steal, no double
def test_no_shard_processed_twice_between_iter_and_steal():
    plan = ShardPlan(24, 3)
    loader = ShardedLoader(plan, host=0, make_batch=_mk,
                           batches_per_shard=2)
    primary = [s for s, _ in loader]
    # host 1 finished two shards before dying; host 2 finished none
    done_elsewhere = plan.shards_for(1)[:2]
    stolen = [s for s, _ in loader.steal(done_elsewhere)]
    processed = primary + stolen
    # each shard appears exactly batches_per_shard times, and the
    # externally-completed shards never appear at all
    counts = {s: processed.count(s) for s in set(processed)}
    assert all(c == 2 for c in counts.values())
    assert set(done_elsewhere).isdisjoint(counts)
    assert sorted(set(processed) | set(done_elsewhere)) == list(range(24))


def test_steal_after_full_completion_is_empty():
    plan = ShardPlan(12, 2)
    fast = ShardedLoader(plan, host=0, make_batch=_mk)
    list(fast)
    assert list(fast.steal(plan.shards_for(1))) == []


# ------------------------------------------------------------------ resume
def test_resume_from_completed_replays_remainder():
    plan = ShardPlan(20, 2, epoch=3)
    full_order = [s for s, _ in ShardedLoader(plan, 0, _mk)]
    crashed_after = 3
    completed = full_order[:crashed_after]
    resumed = ShardedLoader(plan, 0, _mk, completed=completed)
    rest = [s for s, _ in resumed]
    assert rest == full_order[crashed_after:]    # plan order, no repeats
    assert resumed.completed == set(full_order)


def test_resume_yields_all_batches_of_incomplete_shards():
    """A shard is only `completed` once ALL its batches ran — resuming an
    incomplete shard replays it from batch 0 (batch idempotence is the
    make_batch contract)."""
    plan = ShardPlan(6, 1)
    loader = ShardedLoader(plan, 0, _mk, batches_per_shard=3)
    batches = [(s, b["batch"]) for s, b in loader]
    assert len(batches) == 18
    for s in plan.shards_for(0):
        assert [b for sh, b in batches if sh == s] == [0, 1, 2]


def test_batches_feed_streaming_pipeline_in_plan_order():
    """chunks_from_loader: fresh loader per pass, identical order."""
    from repro.core.pipeline import chunks_from_loader
    plan = ShardPlan(8, 1, epoch=1)

    def make(shard, b):
        return np.full((4, 2), shard, np.float32)

    factory = chunks_from_loader(plan, 0, make)
    pass1 = [int(c[0, 0]) for c in factory()]
    pass2 = [int(c[0, 0]) for c in factory()]
    assert pass1 == pass2 == plan.shards_for(0)
