"""Embed backends: dense / tiled / pallas gradient equivalence, UMAP
sparse-vs-dense symmetrization, and the no-(N,N)-buffer regression.

The acceptance bar for the memory-bounded engine: all three tSNE
backends produce gradients within 1e-4 relative tolerance on an N=512
fixture, and the tiled path's jaxpr contains no (N, N) intermediate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tsne, umap


def _fixture(n=512, d=8, seed=0, weighted=False):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-3, 3, size=(4, d))
    x = np.concatenate([
        c + 0.3 * rng.normal(size=(n // 4, d)) for c in centers])
    x = jnp.asarray(x.astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    w = jnp.asarray(rng.uniform(1, 100, size=n).astype(np.float32)) \
        if weighted else None
    return x, y, w


# ------------------------------------------------------------ tSNE gradients
@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("exag", [1.0, 12.0])
@pytest.mark.parametrize("backend", ["tiled", "pallas"])
def test_grad_matches_dense(backend, exag, weighted):
    x, y, w = _fixture(weighted=weighted)
    stats = tsne.calibrate_stats(x, 30.0, weights=w)
    g_dense, kl_dense = tsne.embedding_grad(x, y, stats, exag,
                                            backend="dense")
    g, kl = tsne.embedding_grad(x, y, stats, exag, backend=backend,
                                block=128)
    scale = float(jnp.max(jnp.abs(g_dense)))
    assert scale > 0
    assert float(jnp.max(jnp.abs(g - g_dense))) <= 1e-4 * scale
    assert float(jnp.abs(kl - kl_dense)) <= 1e-3 * max(1.0, abs(float(kl_dense)))


def test_grad_block_not_dividing_n():
    """Padding path: N=500 with block 128 must agree with dense too."""
    x, y, _ = _fixture(n=500)
    stats = tsne.calibrate_stats(x, 20.0, block=128)
    g_dense, _ = tsne.embedding_grad(x, y, stats, 1.0, backend="dense")
    for backend in ("tiled", "pallas"):
        g, _ = tsne.embedding_grad(x, y, stats, 1.0, backend=backend,
                                   block=128)
        scale = float(jnp.max(jnp.abs(g_dense)))
        assert float(jnp.max(jnp.abs(g - g_dense))) <= 1e-4 * scale


def test_calibrate_stats_block_invariant():
    """Row-blocked calibration must not depend on the block size."""
    x, _, w = _fixture(n=300, weighted=True)
    a = tsne.calibrate_stats(x, 25.0, weights=w, block=300)
    b = tsne.calibrate_stats(x, 25.0, weights=w, block=64)
    np.testing.assert_allclose(np.asarray(a.beta), np.asarray(b.beta),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a.zp), np.asarray(b.zp), rtol=1e-5)


def test_calibrate_p_wrapper_matches_legacy_properties():
    """calibrate_p is now a wrapper over blocked stats — same invariants."""
    x, _, _ = _fixture(n=256, d=4)
    p = tsne.calibrate_p(x, 15.0)
    p = np.asarray(p)
    assert np.isclose(p.sum(), 1.0, atol=1e-4)
    np.testing.assert_allclose(p, p.T, rtol=1e-5)          # symmetric
    assert (p >= 1e-12 - 1e-18).all()


def test_run_tsne_backend_dispatch_and_finite():
    x, _, w = _fixture(n=200, d=4, weighted=True)
    cfg = tsne.TsneConfig(n_iter=10, perplexity=10.0, block=64)
    for backend in ("dense", "tiled", "pallas"):
        y, kls = tsne.run_tsne(jax.random.key(0), x, cfg, weights=w,
                               backend=backend)
        assert np.isfinite(np.asarray(y)).all(), backend
        assert np.isfinite(np.asarray(kls)).all(), backend
    with pytest.raises(ValueError):
        tsne.run_tsne(jax.random.key(0), x, cfg, backend="nope")


# ------------------------------------------------------- UMAP symmetrization
@pytest.mark.parametrize("weighted", [False, True])
def test_umap_sparse_symmetrization_matches_dense(weighted):
    x, _, w = _fixture(n=400, d=5, weighted=weighted)
    idx, dist = umap.knn_graph(x, 10)
    e_d, m_d = umap.fuzzy_simplicial_set(idx, dist, weights=w,
                                         symmetrize="dense")
    e_s, m_s = umap.fuzzy_simplicial_set(idx, dist, weights=w,
                                         symmetrize="sparse")
    np.testing.assert_array_equal(np.asarray(e_d), np.asarray(e_s))
    np.testing.assert_allclose(np.asarray(m_d), np.asarray(m_s),
                               rtol=1e-6, atol=1e-7)


def test_umap_knn_chunked_matches_dense():
    x, _, _ = _fixture(n=500, d=6)
    idx, dist = umap.knn_graph(x, 12)
    idx_c, dist_c = umap.knn_graph(x, 12, block=128)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_c))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(dist_c),
                               rtol=1e-6, atol=1e-6)


# -------------------------------------------------------- no-(N,N) regression
def _has_square_buffer(fn, n, *args):
    from benchmarks.common import iter_jaxpr_avals
    jaxpr = jax.make_jaxpr(fn)(*args)
    for aval in iter_jaxpr_avals(jaxpr.jaxpr):
        shape = getattr(aval, "shape", ())
        if len(shape) >= 2 and shape[-1] >= n and shape[-2] >= n:
            return True
    return False


def test_tiled_tsne_never_allocates_n_by_n():
    n = 4096
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))

    def calib(x_):
        return tsne.calibrate_stats(x_, 30.0, block=512)

    assert not _has_square_buffer(calib, n, x)

    stats = jax.eval_shape(calib, x)
    stats = tsne.PointStats(*[jnp.zeros(s.shape, s.dtype) for s in stats])

    def tiled(y_):
        return tsne.embedding_grad(x, y_, stats, 1.0, backend="tiled",
                                   block=512)[0]

    def dense(y_):
        return tsne.embedding_grad(x, y_, stats, 1.0, backend="dense")[0]

    assert not _has_square_buffer(tiled, n, y)
    # positive control: the detector must fire on the dense path
    assert _has_square_buffer(dense, n, y)


def test_full_tiled_run_tsne_never_allocates_n_by_n():
    """run_tsne(backend='tiled') end-to-end, N=4096: no (N, N) anywhere."""
    n = 4096
    x = jnp.zeros((n, 4), jnp.float32)
    cfg = tsne.TsneConfig(n_iter=3, block=512, backend="tiled")

    def full(x_):
        return tsne.run_tsne(jax.random.key(0), x_, cfg)[0]

    assert not _has_square_buffer(full, n, x)


def test_umap_pipeline_never_allocates_n_by_n():
    n = 4096
    x = jnp.zeros((n, 4), jnp.float32)

    def graph(x_):
        idx, dist = umap.knn_graph(x_, 15, block=512)
        return umap.fuzzy_simplicial_set(idx, dist)

    assert not _has_square_buffer(graph, n, x)