"""Checkpointing: roundtrip, atomicity, corruption fallback, elastic resume,
async manager, retention."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 8), jnp.float32),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jax.random.normal(k, (3,), jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    assert latest_step(str(tmp_path)) == 5
    like = jax.tree.map(jnp.zeros_like, t)
    back = restore_checkpoint(str(tmp_path), 5, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_corrupt_checkpoint_skipped(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 2, t)
    # corrupt step 2: truncate the arrays file
    with open(tmp_path / "step_00000002" / "arrays.npz", "w") as f:
        f.write("garbage")
    assert latest_step(str(tmp_path)) == 1


def test_partial_write_invisible(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a crash mid-write: tmp dir exists, no final rename
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(str(tmp_path)) == 1


def test_elastic_resume_different_shardings(tmp_path):
    """Checkpoint written unsharded restores onto explicit shardings."""
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    dev = jax.devices()[0]
    sh = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), t)
    back = restore_checkpoint(str(tmp_path), 3, jax.tree.map(
        jnp.zeros_like, t), shardings=sh)
    np.testing.assert_array_equal(np.asarray(t["a"]), np.asarray(back["a"]))


def test_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        mgr.save(s, _tree(s))
    mgr.wait()
    mgr.close()
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_00000030", "step_00000040"]
    assert latest_step(str(tmp_path)) == 40
