"""End-to-end SnS on synthetic clustered data: the paper's full Fig. 1 flow.

Ground-truth Gaussian mixture (which the paper lacked!) → quantize → sketch
→ HH → replicas → UMAP/tSNE → cluster purity via the contingency table the
paper builds in §IV-1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pipeline
from repro.core.tsne import TsneConfig
from repro.core.umap import UmapConfig


def _mixture(n, seed=0, dims=4, n_clusters=3, background_frac=0.3):
    """Dense Gaussian clusters over a uniform background (paper's regime:
    high density contrast)."""
    rng = np.random.default_rng(seed)
    n_bg = int(n * background_frac)
    n_cl = n - n_bg
    centers = rng.uniform(0.15, 0.85, size=(n_clusters, dims))
    per = n_cl // n_clusters
    pts = [rng.uniform(0, 1, size=(n_bg, dims))]
    labels = [np.full((n_bg,), -1)]
    for i, c in enumerate(centers):
        m = per if i < n_clusters - 1 else n_cl - per * (n_clusters - 1)
        pts.append(c + 0.02 * rng.normal(size=(m, dims)))
        labels.append(np.full((m,), i))
    pts = np.clip(np.concatenate(pts), 0, 1).astype(np.float32)
    labels = np.concatenate(labels)
    perm = rng.permutation(n)
    return jnp.asarray(pts[perm]), labels[perm], centers


@pytest.mark.slow
def test_sns_end_to_end_umap():
    pts, labels, centers = _mixture(40_000, seed=0)
    cfg = pipeline.SnsConfig(bins=16, rows=8, log2_cols=12, top_k=256,
                             max_replicas=4, embedder="umap")
    res = pipeline.run(cfg, pts,
                       umap_cfg=UmapConfig(n_neighbors=10, n_epochs=100))
    assert not np.isnan(np.asarray(res.embedding)).any()
    # HHs must be dominated by cluster cells: the densest cells of a
    # clustered + uniform mixture are inside the clusters
    hh_cells = np.asarray(res.hh.count)[np.asarray(res.hh.mask)]
    assert hh_cells.size > 10
    # coverage: clusters hold 70% of mass in ~tiny volume -> top cells
    # should capture a large fraction
    assert res.coverage > 0.4


@pytest.mark.slow
def test_sns_end_to_end_tsne():
    pts, labels, centers = _mixture(20_000, seed=1)
    cfg = pipeline.SnsConfig(bins=12, rows=8, log2_cols=12, top_k=128,
                             max_replicas=4, embedder="tsne")
    res = pipeline.run(cfg, pts,
                       tsne_cfg=TsneConfig(n_iter=150, perplexity=15.0))
    assert not np.isnan(np.asarray(res.embedding)).any()


def test_hh_recovers_cluster_cells():
    """Top HH cells must sit on the true cluster centers."""
    pts, labels, centers = _mixture(50_000, seed=2, n_clusters=3,
                                    background_frac=0.2)
    cfg = pipeline.SnsConfig(bins=16, rows=8, log2_cols=14, top_k=64)
    grid, hh = pipeline.sketch_stage(cfg, pts)
    from repro.core import quantize
    coords = quantize.unpack(grid, (hh.key_hi, hh.key_lo))
    hh_centers = np.asarray(quantize.cell_center(grid, coords))
    live = np.asarray(hh.mask)
    # each true center must be within one cell of some heavy hitter
    cell = np.asarray(grid.cell_size)
    for c in centers:
        d = np.abs(hh_centers[live] - c).max(axis=1)
        assert (d < 1.5 * cell.max()).any(), f"no HH near center {c}"


def test_assign_points_to_hh():
    pts, labels, _ = _mixture(20_000, seed=3)
    cfg = pipeline.SnsConfig(bins=12, rows=8, log2_cols=12, top_k=128)
    grid, hh = pipeline.sketch_stage(cfg, pts)
    assign = pipeline.assign_points_to_hh(grid, hh, np.asarray(pts))
    in_hh = assign >= 0
    # a decent fraction of all points lives in HH cells
    assert in_hh.mean() > 0.3
    # cluster points should be assigned far more often than background
    assert in_hh[labels >= 0].mean() > 2.0 * max(in_hh[labels < 0].mean(), 0.01)


def test_assign_points_to_hh_matches_dict_lookup():
    """The searchsorted fast path must agree with the per-point dict oracle."""
    from repro.core import quantize
    pts, _, _ = _mixture(10_000, seed=5)
    cfg = pipeline.SnsConfig(bins=12, rows=8, log2_cols=12, top_k=128)
    grid, hh = pipeline.sketch_stage(cfg, pts)
    got = pipeline.assign_points_to_hh(grid, hh, np.asarray(pts), chunk=3000)
    # oracle: the old host-side dict implementation
    lut = {}
    for i, (h, l, m) in enumerate(zip(np.asarray(hh.key_hi),
                                      np.asarray(hh.key_lo),
                                      np.asarray(hh.mask))):
        if m:
            lut[(int(h) << 32) | int(l)] = i
    khi, klo = quantize.points_to_keys(grid, pts)
    keys = (np.asarray(khi, np.uint64) << np.uint64(32)) | \
        np.asarray(klo, np.uint64)
    want = np.asarray([lut.get(int(k), -1) for k in keys])
    np.testing.assert_array_equal(got, want)


def test_coverage_one_when_every_cell_heavy():
    """A stream whose every occupied cell is a heavy hitter -> coverage 1."""
    rng = np.random.default_rng(7)
    # 6 well-separated cell centers, many points each: 6 distinct keys
    centers = np.stack(np.meshgrid([0.1, 0.5, 0.9], [0.25, 0.75]),
                       -1).reshape(-1, 2)
    pts = np.repeat(centers, 500, axis=0).astype(np.float32)
    pts += 0.001 * rng.normal(size=pts.shape).astype(np.float32)
    perm = rng.permutation(len(pts))
    cfg = pipeline.SnsConfig(bins=8, rows=8, log2_cols=12, top_k=16,
                             max_replicas=2, embedder="umap")
    from repro.core.umap import UmapConfig
    res = pipeline.run(cfg, jnp.asarray(pts[perm]),
                       umap_cfg=UmapConfig(n_neighbors=5, n_epochs=10))
    assert res.coverage == pytest.approx(1.0, rel=1e-6)


def test_assign_points_to_hh_chunked_equivalence():
    """The jitted chunked path == the one-shot pass (chunk >= n), across
    chunk sizes that do and do not divide the batch."""
    pts, _, _ = _mixture(5_000, seed=11)
    cfg = pipeline.SnsConfig(bins=12, rows=8, log2_cols=12, top_k=128)
    grid, hh = pipeline.sketch_stage(cfg, pts)
    oneshot = pipeline.assign_points_to_hh(grid, hh, np.asarray(pts),
                                           chunk=5_000)
    assert (oneshot >= 0).any()
    for chunk in (512, 733, 4_999, 50_000):
        got = pipeline.assign_points_to_hh(grid, hh, np.asarray(pts),
                                           chunk=chunk)
        np.testing.assert_array_equal(got, oneshot)


def test_sns_config_fails_loud_at_construction():
    """Bad knobs raise at SnsConfig() time with every violation listed —
    not as a shape error three stages into a trace."""
    for bad, frag in ((dict(bins=1), "bins"),
                      (dict(rows=0), "rows"),
                      (dict(log2_cols=0), "log2_cols"),
                      (dict(log2_cols=40), "log2_cols"),
                      (dict(top_k=0), "top_k"),
                      (dict(candidate_pool=-1), "candidate_pool"),
                      (dict(ingest_chunk=0), "ingest_chunk"),
                      (dict(embedder="pca"), "embedder"),
                      (dict(embed_backend="cuda"), "embed_backend"),
                      (dict(max_replicas=0), "max_replicas"),
                      (dict(jitter_frac=2.0), "jitter_frac"),
                      (dict(embed_grid=1), "embed_grid"),
                      (dict(embed_grid_max=8, embed_grid=128),
                       "embed_grid_max")):
        with pytest.raises(ValueError, match=frag):
            pipeline.SnsConfig(**bad)
    # several violations at once: all reported in one message
    with pytest.raises(ValueError) as ei:
        pipeline.SnsConfig(bins=0, rows=0, top_k=0)
    msg = str(ei.value)
    assert "bins" in msg and "rows" in msg and "top_k" in msg
