"""Replica generation: schemes, mass conservation, jitter containment."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize, replicas
from repro.core.heavy_hitters import HeavyHitters


def _hh(counts):
    k = len(counts)
    keys = np.arange(k, dtype=np.uint64) * np.uint64(7919)
    order = np.argsort(counts)[::-1]
    counts = np.asarray(counts, np.float32)[order]
    keys = keys[order]
    return HeavyHitters(
        key_hi=jnp.asarray((keys >> np.uint64(32)).astype(np.uint32)),
        key_lo=jnp.asarray((keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
        count=jnp.asarray(counts),
        mask=jnp.ones((k,), bool))


def test_replica_counts_uniform():
    hh = _hh([100, 50, 10, 1])
    n = np.asarray(replicas.replica_counts(hh, "uniform", 4))
    np.testing.assert_array_equal(n, [4, 4, 4, 4])


def test_replica_counts_count_scheme():
    # paper: 1 + floor(log2(f / f_min))
    hh = _hh([16.0, 8.0, 4.0, 1.0])
    n = np.asarray(replicas.replica_counts(hh, "count", 8))
    np.testing.assert_array_equal(n, [5, 4, 3, 1])


def test_replica_counts_rank_scheme():
    # paper: 1 + floor(log2(r_max / r)), ranks 1..4
    hh = _hh([16.0, 8.0, 4.0, 1.0])
    n = np.asarray(replicas.replica_counts(hh, "rank", 8))
    np.testing.assert_array_equal(n, [3, 2, 1, 1])


def test_representatives_mass_and_jitter():
    grid = quantize.GridSpec(dims=3, bins=8,
                             lo=np.zeros(3, np.float32),
                             hi=np.ones(3, np.float32) * 8)
    # build HHs from real cells so unpack works
    coords = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.uint32)
    hi, lo = quantize.pack(grid, coords)
    hh = HeavyHitters(key_hi=hi, key_lo=lo,
                      count=jnp.asarray([100.0, 10.0]),
                      mask=jnp.ones((2,), bool))
    rep = replicas.make_representatives(jax.random.key(0), grid, hh,
                                        scheme="count", max_replicas=8)
    pts, w, ids = replicas.compact(rep)
    # total mass preserved per HH
    np.testing.assert_allclose(w[ids == 0].sum(), 100.0, rtol=1e-5)
    np.testing.assert_allclose(w[ids == 1].sum(), 10.0, rtol=1e-5)
    # jitter stays within ±jitter_frac of cell size around the center
    centers = np.asarray(quantize.cell_center(grid, coords))
    for i in range(2):
        delta = np.abs(pts[ids == i] - centers[i])
        assert (delta <= 0.25 * 1.0 + 1e-5).all()


def test_jitter_is_keyed_by_cell_not_rank():
    """Regression for the warm-start contract: a cell's representative
    points must be a pure function of (cell key, slot, seed).  Reordering
    the HH rows (as drift does when it reshuffles the count ranking) must
    NOT re-roll anyone's jitter — a position-indexed draw would move every
    matched rep's input point between refreshes and wreck the warm init."""
    grid = quantize.GridSpec(dims=3, bins=8,
                             lo=np.zeros(3, np.float32),
                             hi=np.ones(3, np.float32) * 8)
    coords = jnp.asarray([[1, 2, 3], [4, 5, 6], [7, 0, 2]], jnp.uint32)
    hi, lo = quantize.pack(grid, coords)
    perm = np.array([2, 0, 1])
    a = HeavyHitters(key_hi=hi, key_lo=lo,
                     count=jnp.asarray([30.0, 20.0, 10.0]),
                     mask=jnp.ones((3,), bool))
    b = HeavyHitters(key_hi=hi[perm], key_lo=lo[perm],
                     count=jnp.asarray([90.0, 50.0, 40.0]),
                     mask=jnp.ones((3,), bool))
    key = jax.random.key(7)
    ra = replicas.make_representatives(key, grid, a, scheme="uniform",
                                       max_replicas=4)
    rb = replicas.make_representatives(key, grid, b, scheme="uniform",
                                       max_replicas=4)
    pa = np.asarray(ra.points).reshape(3, 4, 3)
    pb = np.asarray(rb.points).reshape(3, 4, 3)
    np.testing.assert_array_equal(pa, pb[np.argsort(perm)])
    # and a different seed still re-rolls everything
    rc = replicas.make_representatives(jax.random.key(8), grid, a,
                                       scheme="uniform", max_replicas=4)
    assert not np.array_equal(np.asarray(rc.points), pa.reshape(12, 3))


def test_masked_hh_get_no_replicas():
    grid = quantize.GridSpec(dims=2, bins=4,
                             lo=np.zeros(2, np.float32),
                             hi=np.ones(2, np.float32))
    coords = jnp.asarray([[1, 1], [2, 2]], jnp.uint32)
    hi, lo = quantize.pack(grid, coords)
    hh = HeavyHitters(key_hi=hi, key_lo=lo,
                      count=jnp.asarray([50.0, 0.0]),
                      mask=jnp.asarray([True, False]))
    rep = replicas.make_representatives(jax.random.key(0), grid, hh,
                                        scheme="uniform", max_replicas=4)
    _, _, ids = replicas.compact(rep)
    assert set(ids.tolist()) == {0}
