"""Online SnsService: serving contract tests.

The three levers get one pin each: (1) update() folds chunks into the
live state without re-reading history and reports drift; (2) refresh()
warm-starts from the cached embedding in a fraction of the cold
iteration budget, matching returning representatives by (cell, slot);
(3) transform() places out-of-sample queries barycentric-exactly (an
identity query lands on its representative) and its jaxpr never
allocates a (Q, N_reps) dense buffer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import iter_jaxpr_avals
from repro.core import pipeline, quantize, stream
from repro.core.service import (ServiceConfig, SnsService,
                                _transform_chunks)
from repro.core.tsne import TsneConfig
from repro.core.umap import UmapConfig
from repro.data.synthetic import MixtureSpec, gaussian_mixture

SPEC = MixtureSpec(dims=3, n_clusters=4, cluster_std=0.05,
                   background_frac=0.0)
CFG = pipeline.SnsConfig(bins=6, rows=8, log2_cols=10, top_k=32,
                         candidate_pool=96, ingest_chunk=512,
                         embedder="tsne", embed_backend="dense",
                         max_replicas=4, seed=0)
TC = TsneConfig(dims=2, n_iter=120, exaggeration_iters=30,
                momentum_switch=30, perplexity=10.0)
SCFG = ServiceConfig(transform_chunk=128, transform_k=4)


@pytest.fixture(scope="module")
def data():
    pts, _ = gaussian_mixture(4000, SPEC, seed=1)
    drift, _ = gaussian_mixture(600, SPEC, seed=2)
    return np.asarray(pts, np.float32), np.asarray(drift, np.float32)


@pytest.fixture(scope="module")
def scenario(data):
    """One full serving episode: ingest → cold refresh → drift →
    warm refresh.  Tests below assert on the captured results so the
    (mutated) service state is deterministic for all of them."""
    pts, drift = data
    grid = quantize.fit_grid(np.concatenate([pts, drift]), CFG.bins)
    svc = SnsService(CFG, grid, tsne_cfg=TC, service_cfg=SCFG)
    stats0 = svc.update([pts[:2000], pts[2000:]])
    cold = svc.refresh(mode="cold")
    stats1 = svc.update(drift)
    warm = svc.refresh()
    return svc, cold, warm, stats0, stats1


def test_update_reports_absorption_and_drift(scenario):
    svc, _, _, stats0, stats1 = scenario
    assert stats0["points"] == 4000.0
    assert stats0["points_per_sec"] > 0
    assert stats0["pending_fraction"] == 1.0   # nothing served yet
    assert stats0["needs_refresh"]
    # post-refresh drift: 600 of 4600 total ≈ 0.13 > default 0.1 gate
    assert 0.12 < stats1["pending_fraction"] < 0.14
    assert stats1["needs_refresh"]
    # the warm refresh consumed the pending mass
    assert svc.pending_fraction() == 0.0


def test_warm_refresh_matches_and_cuts_iterations(scenario):
    _, cold, warm, _, _ = scenario
    assert not cold.warm and warm.warm
    # same-distribution drift: most cells return
    assert warm.n_matched > warm.n_new
    assert 5 * warm.n_iters <= cold.n_iters
    assert int(warm.kl_trace.shape[0]) == warm.n_iters
    assert np.isfinite(np.asarray(warm.kl_trace)).all()
    assert not np.isnan(np.asarray(warm.embedding)).any()


def test_warm_refresh_without_cache_raises(data):
    pts, _ = data
    grid = quantize.fit_grid(pts, CFG.bins)
    svc = SnsService(CFG, grid, tsne_cfg=TC, service_cfg=SCFG)
    with pytest.raises(ValueError, match="no previous"):
        svc.refresh(mode="warm")
    with pytest.raises(ValueError, match="refresh"):
        svc.transform(pts[:4])


def test_transform_identity_query(scenario):
    """A query identical to a representative must land (within fp
    cancellation tolerance) on that representative's embedded coords."""
    svc = scenario[0]
    rep_x = np.asarray(svc._cache.rep_x)
    rep_y = np.asarray(svc._cache.rep_y)
    scale = np.abs(rep_y).max()
    for i in (0, len(rep_x) // 2, len(rep_x) - 1):
        y = svc.transform(rep_x[i])
        assert np.linalg.norm(y - rep_y[i]) < 1e-3 * scale
    # batched: every rep queried at once, chunked path (Q > chunk)
    yb = svc.transform(np.tile(rep_x, (2, 1)))
    want = np.tile(rep_y, (2, 1))
    assert np.abs(yb - want).max() < 1e-2 * scale


def test_transform_batch_is_finite_and_shaped(scenario):
    svc = scenario[0]
    q, _ = gaussian_mixture(1000, SPEC, seed=3)
    y = svc.transform(np.asarray(q, np.float32))
    assert y.shape == (1000, 2)
    assert np.isfinite(y).all()
    # placements stay inside the served embedding's bounding box (convex
    # combinations of rep coordinates cannot escape it)
    rep_y = np.asarray(svc._cache.rep_y)
    assert (y.min(0) >= rep_y.min(0) - 1e-4).all()
    assert (y.max(0) <= rep_y.max(0) + 1e-4).all()


def test_transform_jaxpr_has_no_q_by_nreps_buffer(scenario):
    """The batched path is pinned to peak O(chunk · N_reps): no traced
    intermediate may carry BOTH the full query count and the rep count."""
    svc = scenario[0]
    n_reps = int(svc._cache.rep_x.shape[0])
    Q, chunk = 1024, 128
    q = jnp.zeros((Q, svc._cache.rep_x.shape[1]), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda qq: _transform_chunks(qq, svc._cache.rep_x,
                                     svc._cache.rep_y, 4, chunk, 1e-12))(q)
    for aval in iter_jaxpr_avals(jaxpr.jaxpr):
        shape = getattr(aval, "shape", ())
        assert not (Q in shape and n_reps in shape), shape


def test_save_load_roundtrip(scenario, tmp_path):
    svc = scenario[0]
    path = tmp_path / "svc_ck"
    svc.save(path)
    svc2 = SnsService.load(path, CFG, svc.grid, tsne_cfg=TC,
                           service_cfg=SCFG)
    assert float(svc2.state.count) == float(svc.state.count)
    np.testing.assert_array_equal(np.asarray(svc2._cache.rep_y),
                                  np.asarray(svc._cache.rep_y))
    q, _ = gaussian_mixture(64, SPEC, seed=4)
    np.testing.assert_allclose(svc2.transform(np.asarray(q, np.float32)),
                               svc.transform(np.asarray(q, np.float32)),
                               rtol=1e-6)
    # the resurrected fold keeps absorbing
    more, _ = gaussian_mixture(256, SPEC, seed=5)
    st = svc2.update(np.asarray(more, np.float32))
    assert st["points"] == 256.0


def test_umap_service_end_to_end(data):
    pts, drift = data
    cfg = pipeline.SnsConfig(bins=6, rows=8, log2_cols=10, top_k=32,
                             candidate_pool=96, ingest_chunk=512,
                             embedder="umap", max_replicas=4, seed=0)
    uc = UmapConfig(dims=2, n_neighbors=6, n_epochs=60)
    grid = quantize.fit_grid(np.concatenate([pts, drift]), cfg.bins)
    svc = SnsService(cfg, grid, umap_cfg=uc, service_cfg=SCFG)
    svc.update(pts)
    cold = svc.refresh()
    assert cold.kl_trace is None        # UMAP has no KL trace
    svc.update(drift)
    warm = svc.refresh()
    assert warm.warm and warm.n_matched > 0
    assert 5 * warm.n_iters <= cold.n_iters
    y = svc.transform(pts[:100])
    assert y.shape == (100, 2) and np.isfinite(y).all()


# ------------------------------------------------------- failure semantics
def test_service_config_fails_loud():
    for bad in (dict(refresh_drift=1.5), dict(error_ratio=-0.1),
                dict(warm_factor=0), dict(transform_k=0),
                dict(transform_eps=0.0)):
        with pytest.raises(ValueError, match="invalid ServiceConfig"):
            ServiceConfig(**bad)


def test_not_ready_guards(data):
    """transform()/save() before the first refresh raise
    ServiceNotReadyError (a ValueError, so legacy except clauses hold)."""
    from repro.core.service import ServiceNotReadyError

    pts, _ = data
    svc = SnsService(CFG, quantize.fit_grid(pts, CFG.bins),
                     tsne_cfg=TC, service_cfg=SCFG)
    assert issubclass(ServiceNotReadyError, ValueError)
    with pytest.raises(ServiceNotReadyError, match="refresh"):
        svc.transform(pts[:4])
    with pytest.raises(ServiceNotReadyError, match="refresh"):
        svc.save("/tmp/never-written")
    h = svc.health()
    assert not h["serving"] and h["refreshes"] == 0


def test_health_report_after_clean_episode(scenario):
    svc = scenario[0]
    h = svc.health()
    assert h["serving"] and h["n_reps"] > 0
    assert h["coverage"] == 1.0 and h["lost_shards"] == ()
    assert h["refreshes"] >= 2
    assert h["hh_error_bound"] >= 0.0
    assert h["last_refresh"]["ok"] and h["last_refresh"]["seconds"] > 0


def test_failed_refresh_rolls_back(scenario, monkeypatch):
    """A refresh that dies mid-embed must leave the previous snapshot
    serving (transactional swap) and show up in health()."""
    svc = scenario[0]
    before = np.asarray(svc._cache.rep_y).copy()
    fails_before = svc.health()["refresh_failures"]

    def boom(*a, **k):
        raise RuntimeError("embed exploded")

    monkeypatch.setattr(pipeline, "embed_points", boom)
    with pytest.raises(RuntimeError, match="embed exploded"):
        svc.refresh()
    monkeypatch.undo()
    h = svc.health()
    assert h["serving"]
    assert h["refresh_failures"] == fails_before + 1
    assert h["last_refresh"]["ok"] is False
    assert "embed exploded" in h["last_refresh"]["error"]
    # the served snapshot is byte-identical to the pre-failure one
    np.testing.assert_array_equal(np.asarray(svc._cache.rep_y), before)


def test_health_exposes_per_shard_latency_histograms(data):
    """update_shards() feeds per-shard attempt counts and per-attempt
    wall-clock buckets into health() — including failed attempts from
    flaky shards."""
    from repro.core import resilience
    from repro.core.faults import FaultPlan

    pts, _ = data
    grid = quantize.fit_grid(pts, CFG.bins)
    svc = SnsService(CFG, grid, tsne_cfg=TC, service_cfg=SCFG)
    shards = {s: [pts[s * 500:(s + 1) * 500]] for s in range(4)}
    svc.update_shards(
        shards, faults=FaultPlan(seed=1, flaky=0.5),
        policy=resilience.RetryPolicy(max_attempts=4, base_delay=0.001))
    h = svc.health()
    lat = h["shard_latency"]
    assert set(lat) == set(range(4))
    for s, rec in lat.items():
        assert rec["attempts"] >= 1
        assert set(rec["buckets"]) == set(resilience.LATENCY_BUCKET_LABELS)
        # every recorded attempt landed in exactly one bucket
        assert sum(rec["buckets"].values()) == rec["attempts"]
        assert rec["failures"] == 0        # retries rescued every shard
    retries = h["update_retries"]
    assert retries >= 1                    # flaky=0.5 over 4x4 attempts
    assert sum(r["attempts"] for r in lat.values()) == 4 + retries
    # a second pass accumulates rather than resets
    svc.update_shards(shards)
    lat2 = svc.health()["shard_latency"]
    assert all(lat2[s]["attempts"] > lat[s]["attempts"] or
               lat2[s]["attempts"] == lat[s]["attempts"] + 1
               for s in lat2)
