"""UMAP: ab curve fit, kNN exactness, fuzzy set properties, blobs separate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import umap


def _blobs(n_per, centers, scale=0.05, seed=0):
    rng = np.random.default_rng(seed)
    cs = np.asarray(centers, np.float32)
    pts = np.concatenate([
        c + scale * rng.normal(size=(n_per, cs.shape[1])).astype(np.float32)
        for c in cs])
    labels = np.repeat(np.arange(len(cs)), n_per)
    return jnp.asarray(pts), labels


def test_fit_ab_default_close_to_umap_learn():
    # umap-learn's values for spread=1.0, min_dist=0.1: a≈1.577, b≈0.895
    a, b = umap.fit_ab(1.0, 0.1)
    assert abs(a - 1.577) < 0.15
    assert abs(b - 0.895) < 0.05


def test_knn_graph_exact():
    x = jnp.asarray([[0.0, 0], [1, 0], [2, 0], [10, 0]])
    idx, dist = umap.knn_graph(x, 2)
    idx = np.asarray(idx)
    assert set(idx[0].tolist()) == {1, 2}
    assert set(idx[3].tolist()) == {2, 1}
    np.testing.assert_allclose(np.asarray(dist)[0], [1.0, 2.0], atol=1e-5)


def test_fuzzy_set_properties():
    x, _ = _blobs(30, [[0, 0], [5, 5]], seed=1)
    idx, dist = umap.knn_graph(x, 5)
    edges, memb = umap.fuzzy_simplicial_set(idx, dist)
    memb = np.asarray(memb)
    assert edges.shape == (60 * 5, 2)
    assert (memb >= 0).all() and (memb <= 1.0 + 1e-5).all()
    # nearest neighbour always has membership ~1 before symmetrization;
    # after t-conorm it can only grow — every node must have >=1 strong edge
    strong = {}
    e = np.asarray(edges)
    for (s, d), m in zip(e, memb):
        strong[s] = max(strong.get(s, 0.0), m)
    assert min(strong.values()) > 0.9


@pytest.mark.slow
def test_umap_blobs_separate():
    x, labels = _blobs(40, [[0, 0, 0], [5, 5, 5], [-5, 5, 0]], seed=2)
    cfg = umap.UmapConfig(n_neighbors=10, n_epochs=150)
    y = np.asarray(umap.run_umap(jax.random.key(0), x, cfg))
    assert not np.isnan(y).any()
    intra, inter = [], []
    for a in range(3):
        ya = y[labels == a]
        intra.append(np.linalg.norm(ya - ya.mean(0), axis=1).mean())
        for b in range(a + 1, 3):
            inter.append(np.linalg.norm(ya.mean(0) - y[labels == b].mean(0)))
    assert min(inter) > 1.5 * max(intra)


def test_weighted_umap_runs():
    x, _ = _blobs(30, [[0, 0], [4, 0]], seed=3)
    w = jnp.concatenate([jnp.full((30,), 100.0), jnp.ones((30,))])
    cfg = umap.UmapConfig(n_neighbors=8, n_epochs=50)
    y = np.asarray(umap.run_umap(jax.random.key(1), x, cfg, weights=w))
    assert not np.isnan(y).any()


def test_negative_sampling_excludes_edge_endpoints():
    """Regression: a uniform negative draw can hit the edge's own dst,
    repelling the pair the attractive step just pulled together.  With
    N = 2 EVERY draw is an endpoint, so the fixed optimizer must act as
    pure attraction and collapse the pair; the buggy one repels dst on
    ~half the draws and keeps the points apart."""
    edges = jnp.asarray([[0, 1], [1, 0]], jnp.int32)
    memb = jnp.ones((2,), jnp.float32)
    # start the pair nearly coincident: pure attraction keeps it collapsed
    # (final gap ~1e-5), while endpoint-repulsion kicks it apart to O(10)
    init = jnp.asarray([[0.0, 0.0], [0.01, 0.0]], jnp.float32)
    cfg = umap.UmapConfig(n_epochs=100, neg_rate=5, learning_rate=1.0)
    y = np.asarray(umap.optimize_embedding(jax.random.key(0), edges, memb,
                                           2, cfg, init=init))
    assert np.isfinite(y).all()
    assert np.linalg.norm(y[0] - y[1]) < 0.1


def test_run_umap_init_propagates_to_epoch_zero():
    """Warm-start hook on the full run_umap path: n_epochs=0 returns the
    init bit-exactly; a bad shape fails loudly."""
    x, _ = _blobs(20, [[0, 0], [4, 0]], seed=9)
    y0 = 0.1 * np.asarray(
        jax.random.normal(jax.random.key(3), (40, 2)), np.float32)
    cfg = umap.UmapConfig(n_neighbors=6, n_epochs=0)
    y = umap.run_umap(jax.random.key(1), x, cfg, init=jnp.asarray(y0))
    np.testing.assert_array_equal(np.asarray(y), y0)
    with pytest.raises(ValueError, match="shape"):
        umap.run_umap(jax.random.key(1), x, cfg,
                      init=jnp.zeros((3, 2), jnp.float32))
