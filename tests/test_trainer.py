"""Trainer: fault injection -> restart -> bit-exact continuation; data
loader determinism + straggler stealing; activation sketcher telemetry."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import ShardPlan, ShardedLoader, zipf_token_stream
from repro.train.steps import TrainStepConfig
from repro.train.trainer import Trainer, TrainerConfig


CFG = get_config("tinyllama-1.1b", smoke=True)
TCFG = TrainStepConfig(q_chunk=16, peak_lr=1e-3, warmup_steps=2,
                       total_steps=50)


def _batch_fn(step):
    return zipf_token_stream(jax.random.key(1000 + step), 2, 32,
                             CFG.vocab_size)


class _Boom(RuntimeError):
    pass


def test_fault_injection_and_bitexact_resume(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    rc = TrainerConfig(total_steps=12, ckpt_every=4, ckpt_dir=ckpt,
                       log_every=4)

    # run A: die at step 10 (after the step-8 checkpoint)
    def bomb(step):
        if step == 10:
            raise _Boom()

    with pytest.raises(_Boom):
        Trainer(CFG, TCFG, rc, _batch_fn, fault_hook=bomb).run()

    # run B: restart — must resume from step 8 and finish
    tr = Trainer(CFG, TCFG, rc, _batch_fn)
    assert tr.start_step == 8
    out = tr.run()
    assert out["final_step"] == 12

    # run C (oracle): train 0..12 uninterrupted in a fresh dir
    rc2 = TrainerConfig(total_steps=12, ckpt_every=12,
                        ckpt_dir=str(tmp_path / "oracle"), log_every=4)
    out2 = Trainer(CFG, TCFG, rc2, _batch_fn).run()

    # bit-exact: same final loss metrics
    a = [m for m in out["metrics"] if m["step"] == 12][0]
    b = [m for m in out2["metrics"] if m["step"] == 12][0]
    assert a["loss"] == b["loss"], (a, b)


def test_trainer_with_activation_monitor(tmp_path):
    rc = TrainerConfig(total_steps=6, ckpt_every=6,
                       ckpt_dir=str(tmp_path / "c"), log_every=2,
                       monitor_activations=True)
    out = Trainer(CFG, TCFG, rc, _batch_fn).run()
    rep = out["activation_report"]
    assert rep["hh_count"] > 0
    assert rep["tokens_seen"] > 0


def test_shard_plan_deterministic_and_complete():
    plan = ShardPlan(num_shards=64, num_hosts=4, epoch=3)
    all_shards = []
    for h in range(4):
        s = plan.shards_for(h)
        assert s == plan.shards_for(h)          # deterministic
        all_shards.extend(s)
    assert sorted(all_shards) == list(range(64))  # partition, no overlap


def test_loader_straggler_stealing():
    plan = ShardPlan(num_shards=16, num_hosts=2)
    seen = []

    def mk(shard, b):
        return {"shard": shard}

    fast = ShardedLoader(plan, host=0, make_batch=mk)
    for shard, _ in fast:
        seen.append(shard)
    # host 1 "died" after finishing 2 shards
    done_by_h1 = plan.shards_for(1)[:2]
    for shard, _ in fast.steal(globally_completed=done_by_h1):
        seen.append(shard)
    assert sorted(seen + done_by_h1) == list(range(16))
