"""Fused single-sort ingest: equivalence, invariants, trace regressions.

The PR-3 throughput refactor replaced the two-sort chunk fold
(``sketch.update_sorted`` + ``candidates.merge_topk`` re-sorting pool ∪
raw-chunk) with one ``candidates.sorted_runs`` per chunk feeding both the
sketch scatter (``sketch.update_runs``) and a sort-free sorted-merge
reservoir update (``candidates.merge_runs``, key-sorted carried
invariant).  The fused path is a re-association of the same exact-integer
adds, so it must be *bit-identical* to the legacy path:

* sketch tables equal exactly;
* reservoir live (key → count) sets equal exactly (storage order differs
  by design: merge_topk count-descending vs merge_runs key-ascending);
* heavy hitters extracted from either reservoir equal exactly.

Plus the trace regressions the perf claim rests on: exactly ONE sort
primitive per chunk step (legacy had two), and the superbatched scan's
trace is O(1) in the number of stacked chunks.  And the two PR-3
follow-ups: resumable ingest (save/load round-trip, bit-identical resume)
and the eviction-watermark space-saving diagnostic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import candidates, pipeline, quantize, sketch as sketch_mod
from repro.core import stream
from repro.core.candidates import Candidates

GRID = quantize.GridSpec(dims=3, bins=8, lo=(0.0,) * 3, hi=(1.0,) * 3)
# 6 dims x 6 bits = 36 > 32 bits: keys spill into the hi limb, so the
# general two-limb sort path runs (GRID packs 9 bits -> single-limb path)
GRID_WIDE = quantize.GridSpec(dims=6, bins=64, lo=(0.0,) * 6, hi=(1.0,) * 6)


def legacy_ingest_step(state, grid, points, mask=None):
    """The PR-2 two-sort chunk fold, reconstructed as the reference:
    update_sorted re-sorts the chunk keys, merge_topk re-sorts pool ∪ raw
    chunk.  (stream.ingest_step used to be exactly this.)"""
    pool = state.cands.capacity
    n = points.shape[0]
    key_hi, key_lo = quantize.points_to_keys(grid, points)
    sk = sketch_mod.update_sorted(state.sketch, key_hi, key_lo, mask=mask)
    chunk_cands = Candidates(
        key_hi=key_hi, key_lo=key_lo,
        count=jnp.ones((n,), jnp.float32),
        mask=jnp.ones((n,), bool) if mask is None else mask)
    cands = state.cands.merge_topk(chunk_cands, pool)
    inc = jnp.full((), n, jnp.float32) if mask is None \
        else jnp.sum(mask.astype(jnp.float32))
    return stream.IngestState(sketch=sk, cands=cands,
                              count=state.count + inc,
                              evict_max=state.evict_max)


def _cand_dict(c):
    """Live (packed key) -> count, order-insensitive."""
    m = np.asarray(c.mask)
    hi = np.asarray(c.key_hi, np.uint64)[m]
    lo = np.asarray(c.key_lo, np.uint64)[m]
    cnt = np.asarray(c.count)[m]
    return dict(zip(((hi << np.uint64(32)) | lo).tolist(), cnt.tolist()))


def _assert_key_sorted(c):
    """The merge_runs carried invariant: live keys strictly ascending,
    padding (mask False) only after every live entry."""
    m = np.asarray(c.mask)
    live_idx = np.flatnonzero(m)
    assert live_idx.size == 0 or live_idx[-1] == live_idx.size - 1, \
        "padding interleaved with live entries"
    packed = (np.asarray(c.key_hi, np.uint64)[m] << np.uint64(32)) | \
        np.asarray(c.key_lo, np.uint64)[m]
    assert np.all(np.diff(packed.astype(np.int64)) > 0), \
        "live keys not strictly ascending"


def _key_stream(rng, n, universe):
    """uint32 keys drawn from `universe` distinct values (dup-heavy when
    universe << n, all-distinct when universe is None)."""
    if universe is None:
        lo = rng.permutation(n).astype(np.uint32)
    else:
        lo = rng.integers(0, universe, size=n).astype(np.uint32)
    hi = (lo % 3).astype(np.uint32)     # exercise the hi limb too
    return jnp.asarray(hi), jnp.asarray(lo)


# ----------------------------------------------------- runs-level identity
@pytest.mark.parametrize("universe,masked_tail", [
    (12, 0), (12, 57), (None, 0), (None, 31), (1, 0)])
def test_update_runs_matches_update_sorted(universe, masked_tail):
    rng = np.random.default_rng(3)
    n = 200
    hi, lo = _key_stream(rng, n, universe)
    mask = jnp.arange(n) < (n - masked_tail)
    sk0 = sketch_mod.init(jax.random.key(0), 4, 8)
    ref = sketch_mod.update_sorted(sk0, hi, lo, mask=mask)
    runs = candidates.sorted_runs(hi, lo, mask=mask)
    fused = sketch_mod.update_runs(sk0, runs)
    np.testing.assert_array_equal(np.asarray(ref.table),
                                  np.asarray(fused.table))


@pytest.mark.parametrize("universe,masked_tail", [
    (10, 0), (10, 40), (300, 0), (None, 0), (None, 25)])
def test_merge_runs_matches_merge_topk(universe, masked_tail):
    """Fold 4 chunks through both reservoir merges: identical live sets
    with bit-identical counts at every step; merge_runs stays key-sorted."""
    rng = np.random.default_rng(7)
    n, pool = 100, 16
    ref = candidates.empty(pool)
    fused = candidates.empty(pool)
    for _ in range(4):
        hi, lo = _key_stream(rng, n, universe)
        mask = jnp.arange(n) < (n - masked_tail)
        chunk = Candidates(key_hi=hi, key_lo=lo,
                           count=jnp.ones((n,), jnp.float32), mask=mask)
        ref = candidates.merge_topk(ref, chunk, pool)
        runs = candidates.sorted_runs(hi, lo, mask=mask)
        fused, _ = candidates.merge_runs(fused, runs, pool)
        _assert_key_sorted(fused)
        assert _cand_dict(ref) == _cand_dict(fused)


@given(universe=st.one_of(st.none(), st.integers(1, 400)),
       masked_tail=st.integers(0, 99), seed=st.integers(0, 2 ** 16))
@settings(max_examples=25, deadline=None)
def test_merge_runs_matches_merge_topk_property(universe, masked_tail, seed):
    rng = np.random.default_rng(seed)
    n, pool = 100, 12
    hi, lo = _key_stream(rng, n, universe)
    mask = jnp.arange(n) < (n - masked_tail)
    start = candidates.local_topk(*_key_stream(rng, 50, 8), pool)
    # merge_runs requires the key-sorted invariant — re-sort the seed pool
    srt = np.argsort(
        (np.asarray(start.key_hi, np.uint64) << np.uint64(32))
        | np.asarray(start.key_lo, np.uint64), kind="stable")
    start_sorted = Candidates(*(jnp.asarray(np.asarray(f)[srt])
                                for f in start))
    chunk = Candidates(key_hi=hi, key_lo=lo,
                       count=jnp.ones((n,), jnp.float32), mask=mask)
    ref = candidates.merge_topk(start, chunk, pool)
    fused, evicted = candidates.merge_runs(
        start_sorted, candidates.sorted_runs(hi, lo, mask=mask), pool)
    _assert_key_sorted(fused)
    assert _cand_dict(ref) == _cand_dict(fused)
    assert float(evicted) >= 0.0


# ----------------------------------------------------- step-level identity
@pytest.mark.parametrize("universe,masked_tail,grid", [
    (6, 0, GRID), (6, 100, GRID), (2000, 0, GRID), (None, 0, GRID),
    (None, 64, GRID), (None, 0, GRID_WIDE), (6, 100, GRID_WIDE)])
def test_fused_step_matches_legacy_two_sort_step(universe, masked_tail,
                                                 grid):
    """Full fold over 5 chunks: sketch tables bit-identical, reservoir
    live sets bit-identical, extracted heavy hitters bit-identical —
    on both the single-limb (≤ 32-bit grid) and two-limb key sort paths."""
    from repro.core import heavy_hitters as hh_mod
    rng = np.random.default_rng(11)
    n, pool, k = 256, 64, 32
    st_fused = stream.init(jax.random.key(0), 4, 10, pool)
    st_legacy = stream.init(jax.random.key(0), 4, 10, pool)
    for _ in range(5):
        pts = jnp.asarray(rng.uniform(0, 1, size=(n, grid.dims)),
                          jnp.float32)
        if universe is not None:      # collapse points onto few cells
            pts = jnp.round(pts * (universe % 7 + 2)) / (universe % 7 + 2)
        mask = jnp.arange(n) < (n - masked_tail)
        st_fused = stream.ingest_step(st_fused, grid, pts, mask=mask)
        st_legacy = legacy_ingest_step(st_legacy, grid, pts, mask=mask)
    np.testing.assert_array_equal(np.asarray(st_fused.sketch.table),
                                  np.asarray(st_legacy.sketch.table))
    assert _cand_dict(st_fused.cands) == _cand_dict(st_legacy.cands)
    assert float(st_fused.count) == float(st_legacy.count)
    hh_f = hh_mod.from_candidates(st_fused.sketch, st_fused.cands, k)
    hh_l = hh_mod.from_candidates(st_legacy.sketch, st_legacy.cands, k)
    for a, b in zip(hh_f, hh_l):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------- jaxpr regressions
def _jaxpr_of_step():
    state = stream.init(jax.random.key(0), 4, 8, 16)

    def step(st, pts, mask):
        return stream.ingest_step(st, GRID, pts, mask=mask)

    return jax.make_jaxpr(step)(state, jnp.zeros((512, 3)),
                                jnp.ones((512,), bool))


def test_exactly_one_sort_per_chunk_step():
    """THE perf claim: the fused step issues exactly one sort primitive
    (legacy two-sort step: two).  top_k / cumsum / binary search gathers
    are not sorts."""
    from benchmarks.common import count_primitive
    assert count_primitive(_jaxpr_of_step().jaxpr, "sort") == 1

    state = stream.init(jax.random.key(0), 4, 8, 16)

    def legacy(st, pts, mask):
        return legacy_ingest_step(st, GRID, pts, mask=mask)

    legacy_jaxpr = jax.make_jaxpr(legacy)(
        state, jnp.zeros((512, 3)), jnp.ones((512,), bool))
    assert count_primitive(legacy_jaxpr.jaxpr, "sort") == 2


def test_superbatch_trace_o1_and_single_sort():
    """The (B, chunk, D) superbatch scan body is traced once: total
    equation count is independent of B, and the whole superbatch jaxpr
    still contains exactly one sort."""
    from benchmarks.common import count_eqns, count_primitive

    def jaxpr_for(b):
        state = stream.init(jax.random.key(0), 4, 8, 16)
        return jax.make_jaxpr(
            lambda s, p, m: stream.ingest_superbatch(s, p, m, grid=GRID))(
                state, jnp.zeros((b, 256, 3)), jnp.ones((b, 256), bool))

    j2, j16 = jaxpr_for(2), jaxpr_for(16)
    assert count_eqns(j2.jaxpr) == count_eqns(j16.jaxpr)
    assert count_primitive(j16.jaxpr, "sort") == 1


def test_superbatch_matches_per_chunk_ingest():
    """ingest_all(superbatch=B) ≡ ingest_all(superbatch=1) bit-exactly,
    including a ragged tail that pads the last superbatch with fully
    masked chunks."""
    rng = np.random.default_rng(5)
    pts = rng.uniform(0, 1, size=(3333, 3)).astype(np.float32)

    def run(superbatch):
        state = stream.init(jax.random.key(1), 4, 10, 64)
        return stream.ingest_all(state, GRID, [pts], 512,
                                 superbatch=superbatch)

    a, b = run(1), run(4)
    np.testing.assert_array_equal(np.asarray(a.sketch.table),
                                  np.asarray(b.sketch.table))
    assert _cand_dict(a.cands) == _cand_dict(b.cands)
    assert float(a.count) == float(b.count) == 3333.0
    assert float(a.evict_max) == float(b.evict_max)


# --------------------------------------------------------- resumable ingest
def test_save_load_resume_bit_identical(tmp_path):
    """Checkpoint mid-stream, reload, finish: heavy hitters bit-identical
    to the uninterrupted fold — including through reservoir evictions
    (pool 64 << 512 occupied cells).  The checkpoint lands on a rechunk
    block boundary (chunk lengths are multiples of 512), so the resumed
    fold sees the exact same block sequence as the straight one."""
    from repro.core import heavy_hitters as hh_mod
    rng = np.random.default_rng(9)
    chunks = [rng.uniform(0, 1, size=(1024, 3)).astype(np.float32)
              for _ in range(6)]

    straight = stream.init(jax.random.key(2), 4, 10, 64)
    straight = stream.ingest_all(straight, GRID, chunks, 512, superbatch=2)

    first = stream.init(jax.random.key(2), 4, 10, 64)
    first = stream.ingest_all(first, GRID, chunks[:3], 512, superbatch=2)
    # suffix-less on purpose: np.savez appends '.npz', load must follow
    path = tmp_path / "ingest_ckpt"
    stream.save_state(first, path)
    resumed = stream.load_state(path)
    resumed = stream.ingest_all(resumed, GRID, chunks[3:], 512, superbatch=2)

    for a, b in zip(jax.tree_util.tree_leaves(straight),
                    jax.tree_util.tree_leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    hh_s = hh_mod.from_candidates(straight.sketch, straight.cands, 32)
    hh_r = hh_mod.from_candidates(resumed.sketch, resumed.cands, 32)
    for a, b in zip(hh_s, hh_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------- eviction watermark
def test_evict_watermark_zero_while_exact():
    """Distinct keys ≤ pool: no eviction ever, watermark stays 0 — the
    reservoir is provably exact."""
    rng = np.random.default_rng(13)
    pts = (rng.integers(0, 3, size=(2000, 3)) / 4.0).astype(np.float32)
    state = stream.init(jax.random.key(0), 4, 10, 64)  # 27 cells << 64
    state = stream.ingest_all(state, GRID, [pts], 256, superbatch=2)
    assert float(stream.space_saving_bound(state)) == 0.0


def test_evict_watermark_rises_on_overflow():
    """More distinct keys than the pool: evictions must happen and the
    watermark records the largest evicted exact count (≤ the heaviest
    key's true count, > 0)."""
    rng = np.random.default_rng(17)
    pts = rng.uniform(0, 1, size=(4000, 3)).astype(np.float32)
    state = stream.init(jax.random.key(0), 4, 10, 8)   # pool 8 << 512 cells
    state = stream.ingest_all(state, GRID, [pts], 256, superbatch=2)
    bound = float(stream.space_saving_bound(state))
    assert bound > 0.0
    assert bound <= float(jnp.max(state.cands.count))


def test_oneshot_and_mesh_surface_watermark():
    """The candidate-stage watermark is measured on every extraction
    path: one-shot local truncation, mesh one-shot (pmax), and the mesh
    streaming reservoir — 0 exactly when the candidate set is complete."""
    from repro.core import geo
    rng = np.random.default_rng(23)
    pts = jnp.asarray(rng.uniform(0, 1, size=(4000, 3)), jnp.float32)
    grid = quantize.fit_grid(pts, 8)    # ~512 occupied cells

    # one-shot run(): tiny pool truncates, big pool does not
    tight = pipeline.SnsConfig(bins=8, rows=4, log2_cols=10, top_k=8,
                               candidate_pool=16, max_replicas=1)
    roomy = pipeline.SnsConfig(bins=8, rows=4, log2_cols=10, top_k=600,
                               candidate_pool=600, max_replicas=1)
    from repro.core.umap import UmapConfig
    ucfg = UmapConfig(n_neighbors=3, n_epochs=2)
    assert pipeline.run(tight, pts, umap_cfg=ucfg).hh_error_bound > 0.0
    assert pipeline.run(roomy, pts, umap_cfg=ucfg).hh_error_bound == 0.0

    # mesh paths (1-device mesh): one-shot pmax + streaming reservoir
    mesh = jax.make_mesh((1,), ("data",))
    res = geo.geo_extract(mesh, grid, pts, rows=4, log2_cols=10,
                          top_k=8, candidate_pool=16)
    assert float(res.evict_max) > 0.0

    def shard_fn(idx, b):
        return pts[b * 500 + jnp.arange(500)], None

    res_s = geo.geo_extract_from_shards(
        mesh, grid, shard_fn, rows=4, log2_cols=10, top_k=8,
        candidate_pool=16, num_batches=8)
    assert float(res_s.evict_max) > 0.0
    res_roomy = geo.geo_extract_from_shards(
        mesh, grid, shard_fn, rows=4, log2_cols=10, top_k=600,
        candidate_pool=600, num_batches=8)
    assert float(res_roomy.evict_max) == 0.0


def test_run_streaming_surfaces_error_bound():
    rng = np.random.default_rng(19)
    pts = rng.uniform(0, 1, size=(3000, 3)).astype(np.float32)
    from repro.core.umap import UmapConfig
    cfg = pipeline.SnsConfig(bins=4, rows=8, log2_cols=10, top_k=32,
                             candidate_pool=96, ingest_chunk=512,
                             ingest_superbatch=2, max_replicas=2)
    res = pipeline.run_streaming(cfg, [pts],
                                 umap_cfg=UmapConfig(n_neighbors=5,
                                                     n_epochs=5))
    # bins=4, D=3 → ≤ 64 occupied cells < pool 96: reservoir exact
    assert res.hh_error_bound == 0.0
