"""Sparse tSNE backend: dense-agreement, FFT-repulsion accuracy, and the
sub-quadratic jaxpr contract.

The equivalence ladder mirrors the backend's two approximations:

* attraction — on a COMPLETE kNN graph (k = N−1) the sparse COO P equals
  the dense symmetrized P exactly, so any gradient difference is due to
  the grid repulsion alone;
* repulsion — the cloud-in-cell + FFT field is compared against the
  brute-force O(N²) sum at a fine grid;
* end to end — run_tsne(backend="sparse") must embed clustered blobs with
  the same cluster separation as the dense backend, weighted included,
  and land at a comparable dense-P KL;
* cost — the per-iteration jaxpr carries no (N, N) buffer and no
  dot_general at all (the O(N²·D) kNN build is setup, not iteration).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import count_primitive, iter_jaxpr_avals
from repro.core import neighbors, tsne


def _blobs(n=400, d=8, n_clusters=4, seed=0, weighted=False, spread=4.0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-spread, spread, size=(n_clusters, d))
    x = np.concatenate([
        c + 0.25 * rng.normal(size=(n // n_clusters, d)) for c in centers])
    labels = np.repeat(np.arange(n_clusters), n // n_clusters)
    w = jnp.asarray(rng.uniform(1, 100, size=n).astype(np.float32)) \
        if weighted else None
    return jnp.asarray(x.astype(np.float32)), labels, w


def _coo_to_dense(sp: tsne.SparseP, n: int) -> np.ndarray:
    m = np.zeros((n, n), np.float64)
    np.add.at(m, (np.asarray(sp.src), np.asarray(sp.dst)), np.asarray(sp.val))
    return m


# ------------------------------------------------------------- P construction
@pytest.mark.parametrize("weighted", [False, True])
def test_sparse_p_is_normalized_symmetric_coo(weighted):
    x, _, w = _blobs(n=300, weighted=weighted, seed=2)
    sp = tsne.build_sparse_p(x, 15.0, k=10, weights=w)
    val = np.asarray(sp.val)
    assert np.isclose(val.sum(), 1.0, atol=1e-5)
    assert (val >= 0).all()
    m = _coo_to_dense(sp, 300)
    np.testing.assert_allclose(m, m.T, atol=1e-9)            # symmetrized
    assert (np.diag(m) == 0).all()                           # no self edges
    # bounds really delimit the per-row slices of the sorted edge list
    bounds = np.asarray(sp.bounds)
    src = np.asarray(sp.src)
    assert bounds[0] == 0 and bounds[-1] == src.shape[0]
    for i in (0, 150, 299):
        assert (src[bounds[i]:bounds[i + 1]] == i).all()


@pytest.mark.parametrize("weighted", [False, True])
def test_sparse_p_complete_graph_equals_dense_p(weighted):
    """k = N−1 removes the kNN truncation: COO P == dense P exactly."""
    x, _, w = _blobs(n=256, weighted=weighted, seed=3)
    idx, dist = neighbors.knn_graph(x, 255)
    sp = tsne.sparse_p_from_knn(idx, dist, 30.0, weights=w)
    p_dense = np.array(tsne.p_from_stats(
        x, tsne.calibrate_stats(x, 30.0, weights=w)))
    p_sparse = _coo_to_dense(sp, 256)
    np.fill_diagonal(p_dense, 0.0)          # dense path clamps diag to 1e-12
    assert np.abs(p_sparse - p_dense).max() <= 1e-6 * p_dense.max()


def test_calibrate_stats_knn_matches_dense_at_full_k():
    x, _, _ = _blobs(n=200, seed=4)
    idx, dist = neighbors.knn_graph(x, 199)
    a = tsne.calibrate_stats_knn(dist, 20.0)
    b = tsne.calibrate_stats(x, 20.0)
    np.testing.assert_allclose(np.asarray(a.beta), np.asarray(b.beta),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(a.zp), np.asarray(b.zp), rtol=1e-4)


# ------------------------------------------------------------- FFT repulsion
def test_fft_repulsion_matches_bruteforce():
    rng = np.random.default_rng(5)
    n = 400
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32) * 3.0)
    rep, z = tsne.fft_repulsion(y, grid_size=256)
    d2 = np.asarray(tsne.pairwise_sq_dists(y), np.float64)
    num = 1.0 / (1.0 + d2)
    np.fill_diagonal(num, 0.0)
    num2 = num * num
    yn = np.asarray(y, np.float64)
    rep_exact = num2.sum(1)[:, None] * yn - num2 @ yn
    assert abs(float(z) - num.sum()) <= 2e-3 * num.sum()
    scale = np.abs(rep_exact).max()
    assert np.abs(np.asarray(rep) - rep_exact).max() <= 5e-3 * scale


def test_fft_repulsion_converges_with_grid():
    """Halving h must shrink the field error (sanity on the interpolation)."""
    rng = np.random.default_rng(6)
    n = 300
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32) * 2.0)
    d2 = np.asarray(tsne.pairwise_sq_dists(y), np.float64)
    num2 = (1.0 / (1.0 + d2)) ** 2
    np.fill_diagonal(num2, 0.0)
    yn = np.asarray(y, np.float64)
    rep_exact = num2.sum(1)[:, None] * yn - num2 @ yn
    errs = []
    for g in (32, 64, 128):
        rep, _ = tsne.fft_repulsion(y, grid_size=g)
        errs.append(np.abs(np.asarray(rep) - rep_exact).max())
    assert errs[2] < errs[1] < errs[0]


# ---------------------------------------------------------------- full grads
@pytest.mark.parametrize("exag", [1.0, 12.0])
def test_sparse_grad_matches_dense_on_complete_graph(exag):
    x, _, w = _blobs(n=256, weighted=True, seed=7)
    y = jnp.asarray(np.random.default_rng(8).normal(size=(256, 2))
                    .astype(np.float32))
    idx, dist = neighbors.knn_graph(x, 255)
    sp = tsne.sparse_p_from_knn(idx, dist, 30.0, weights=w)
    stats = tsne.calibrate_stats(x, 30.0, weights=w)
    g_dense, kl_dense = tsne.embedding_grad(x, y, stats, exag,
                                            backend="dense")
    g, kl = tsne.sparse_grad(y, sp, exag, grid_size=256)
    scale = float(jnp.max(jnp.abs(g_dense)))
    assert scale > 0
    assert float(jnp.max(jnp.abs(g - g_dense))) <= 2e-3 * scale
    assert float(jnp.abs(kl - kl_dense)) <= 1e-2 * max(1.0, abs(float(kl_dense)))


def test_embedding_grad_rejects_sparse_backend():
    x, _, _ = _blobs(n=64)
    stats = tsne.calibrate_stats(x, 10.0)
    with pytest.raises(ValueError, match="sparse"):
        tsne.embedding_grad(x, jnp.zeros((64, 2)), stats, backend="sparse")
    with pytest.raises(ValueError, match="dims"):
        tsne.run_tsne(jax.random.key(0), x,
                      tsne.TsneConfig(dims=3, backend="sparse"))


# -------------------------------------------------------------- end to end
def _centroid_accuracy(y: np.ndarray, labels: np.ndarray) -> float:
    cents = np.stack([y[labels == c].mean(0) for c in np.unique(labels)])
    d = ((y[:, None, :] - cents[None]) ** 2).sum(-1)
    return float((d.argmin(1) == labels).mean())


@pytest.mark.parametrize("weighted", [False, True])
def test_run_tsne_sparse_embeds_blobs_like_dense(weighted):
    x, labels, w = _blobs(n=400, seed=9, weighted=weighted)
    cfg = tsne.TsneConfig(n_iter=250, perplexity=20.0, block=128,
                          grid_size=128)
    key = jax.random.key(0)
    y_dense, _ = tsne.run_tsne(key, x, cfg, weights=w, backend="dense")
    y_sparse, kls = tsne.run_tsne(key, x, cfg, weights=w, backend="sparse")
    y_sparse = np.asarray(y_sparse)
    assert np.isfinite(y_sparse).all()
    assert np.isfinite(np.asarray(kls)).all()
    acc_d = _centroid_accuracy(np.asarray(y_dense), labels)
    acc_s = _centroid_accuracy(y_sparse, labels)
    assert acc_s >= min(0.95, acc_d - 0.02)
    # both land at a comparable dense-P KL (the sparse run is judged by
    # the exact objective, not its own truncated one)
    p = tsne.p_from_stats(x, tsne.calibrate_stats(x, 20.0, weights=w))
    kl_d = float(tsne.kl_divergence(p, jnp.asarray(y_dense)))
    kl_s = float(tsne.kl_divergence(p, jnp.asarray(y_sparse)))
    assert kl_s <= kl_d + 0.75


# ------------------------------------------------------------- adaptive grid
def test_adaptive_grid_doubles_only_and_caps():
    """_grid_for_span: doubling boundaries from the starting G, monotone,
    capped at grid_max."""
    cfg = tsne.TsneConfig(grid_size=32, grid_interval=0.5, grid_max=256)
    assert tsne._grid_for_span(1.0, 32, cfg) == 32      # span fits
    assert tsne._grid_for_span(20.0, 32, cfg) == 64     # one doubling
    assert tsne._grid_for_span(50.0, 32, cfg) == 128
    assert tsne._grid_for_span(1e6, 32, cfg) == 256     # capped
    assert tsne._grid_for_span(1.0, 128, cfg) == 128    # never shrinks


def test_run_tsne_adaptive_grid_matches_fixed_grid_quality():
    """Starting from a coarse G with a fixed cell-spacing target, the
    staged adaptive optimizer must land at the same blob quality as the
    fixed-G run — within the fixed-G test's own tolerances."""
    x, labels, w = _blobs(n=400, seed=9, weighted=True)
    key = jax.random.key(0)
    fixed = tsne.TsneConfig(n_iter=250, perplexity=20.0, block=128,
                            grid_size=128)
    adaptive = tsne.TsneConfig(n_iter=250, perplexity=20.0, block=128,
                               grid_size=32, grid_interval=0.5,
                               grid_max=256, adaptive_interval=50)
    y_fixed, _ = tsne.run_tsne(key, x, fixed, weights=w, backend="sparse")
    y_adapt, kls = tsne.run_tsne(key, x, adaptive, weights=w,
                                 backend="sparse")
    y_adapt = np.asarray(y_adapt)
    assert np.isfinite(y_adapt).all()
    assert np.isfinite(np.asarray(kls)).all()
    acc_f = _centroid_accuracy(np.asarray(y_fixed), labels)
    acc_a = _centroid_accuracy(y_adapt, labels)
    assert acc_a >= min(0.95, acc_f - 0.02)
    p = tsne.p_from_stats(x, tsne.calibrate_stats(x, 20.0, weights=w))
    kl_f = float(tsne.kl_divergence(p, jnp.asarray(y_fixed)))
    kl_a = float(tsne.kl_divergence(p, jnp.asarray(y_adapt)))
    assert kl_a <= kl_f + 0.75


# --------------------------------------------------------------- cost model
def test_sparse_iteration_jaxpr_subquadratic():
    """The per-iteration step: no (N, N)-sized buffer, no dot at all."""
    from benchmarks.bench_embed_throughput import synthetic_sparse_p
    n, k = 4096, 16
    sp = synthetic_sparse_p(n, k, np.random.default_rng(10))
    y = jnp.zeros((n, 2), jnp.float32)

    def step(y_):
        return tsne.sparse_grad(y_, sp, 1.0, grid_size=128)[0]

    jaxpr = jax.make_jaxpr(step)(y)
    biggest = max(
        int(np.prod(a.shape, dtype=np.int64))
        for a in iter_jaxpr_avals(jaxpr.jaxpr) if hasattr(a, "shape"))
    assert biggest < n * n // 8, f"buffer of {biggest} elems ~ O(N²)"
    # "no dot at all" is a property of the XLA cumsum segment-reduce; the
    # fused Pallas kernel (pinned via SNS_KERNEL_MODE=interpret/compiled)
    # uses a block-sized one-hot matmul by design, still subquadratic.
    from repro.kernels import registry
    seg = registry.resolve("segment_reduce", shape=(n,), dtype=jnp.float32)
    if seg.mode == "xla":
        assert count_primitive(jaxpr.jaxpr, "dot_general") == 0


def test_full_sparse_run_tsne_never_allocates_n_by_n():
    """run_tsne(backend='sparse') end to end (kNN setup included):
    (block, N) streaming buffers are fine, (N, N) is not."""
    n = 4096
    x = jnp.zeros((n, 4), jnp.float32)
    cfg = tsne.TsneConfig(n_iter=2, block=512, backend="sparse", knn=16,
                          grid_size=64)

    def full(x_):
        return tsne.run_tsne(jax.random.key(0), x_, cfg)[0]

    jaxpr = jax.make_jaxpr(full)(x)
    for aval in iter_jaxpr_avals(jaxpr.jaxpr):
        shape = getattr(aval, "shape", ())
        assert not (len(shape) >= 2 and shape[-1] >= n and shape[-2] >= n), \
            f"(N, N) buffer {shape} in the sparse path"
