"""Attention invariants: chunking equivalence, GQA vs repeated-KV oracle,
rope properties, cache-mask semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import layers as L


def _qkv(b, s, h, kvh, hd, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunking_invariance(chunk):
    """Output must be identical for any q_chunk size."""
    q, k, v = _qkv(2, 32, 4, 2, 8)
    pos = jnp.arange(32)
    full = L.attention(q, k, v, pos, None, causal=True, q_chunk=32)
    chunked = L.attention(q, k, v, pos, None, causal=True, q_chunk=chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


def test_gqa_matches_repeated_kv():
    """GQA with kvh<h must equal MHA with explicitly repeated K/V."""
    q, k, v = _qkv(1, 16, 8, 2, 8, seed=1)
    pos = jnp.arange(16)
    gqa = L.attention(q, k, v, pos, None, causal=True, q_chunk=16)
    k_rep = jnp.repeat(k, 4, axis=2)
    v_rep = jnp.repeat(v, 4, axis=2)
    mha = L.attention(q, k_rep, v_rep, pos, None, causal=True, q_chunk=16)
    np.testing.assert_allclose(np.asarray(gqa), np.asarray(mha),
                               rtol=1e-5, atol=1e-5)


def test_causality():
    """Changing future K/V must not change past outputs."""
    q, k, v = _qkv(1, 16, 2, 2, 8, seed=2)
    pos = jnp.arange(16)
    out1 = L.attention(q, k, v, pos, None, causal=True, q_chunk=16)
    k2 = k.at[:, 10:].set(99.0)
    v2 = v.at[:, 10:].set(-99.0)
    out2 = L.attention(q, k2, v2, pos, None, causal=True, q_chunk=16)
    np.testing.assert_allclose(np.asarray(out1[:, :10]),
                               np.asarray(out2[:, :10]), rtol=1e-5,
                               atol=1e-5)
    assert not np.allclose(np.asarray(out1[:, 10:]), np.asarray(out2[:, 10:]))


def test_kv_valid_len_masks_cache_tail():
    """Decode semantics: slots beyond kv_valid_len are invisible."""
    q, k, v = _qkv(1, 1, 2, 2, 8, seed=3)
    cache_k = jnp.concatenate([k] * 8, axis=1)          # (1, 8, 2, 8)
    cache_v = jnp.concatenate([v] * 8, axis=1)
    poisoned_k = cache_k.at[:, 5:].set(77.0)
    poisoned_v = cache_v.at[:, 5:].set(-77.0)
    pos = jnp.asarray([4])
    a = L.attention(q, cache_k, cache_v, pos, jnp.asarray(5), causal=True)
    b = L.attention(q, poisoned_k, poisoned_v, pos, jnp.asarray(5),
                    causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


@given(hd=st.sampled_from([8, 16, 64]), theta=st.sampled_from([1e4, 5e5]))
@settings(max_examples=10, deadline=None)
def test_rope_properties(hd, theta):
    """RoPE preserves norms and is relative: <R(p)q, R(p+d)k> depends only
    on d (shift invariance of the rotary inner product)."""
    key = jax.random.key(0)
    q = jax.random.normal(key, (1, 1, 1, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, hd))
    # norm preservation
    rq = L.apply_rope(q, jnp.asarray([3]), theta)
    np.testing.assert_allclose(float(jnp.linalg.norm(rq)),
                               float(jnp.linalg.norm(q)), rtol=1e-5)
    # relative property
    def dot_at(p1, p2):
        a = L.apply_rope(q, jnp.asarray([p1]), theta)
        b = L.apply_rope(k, jnp.asarray([p2]), theta)
        return float(jnp.sum(a * b))
    assert dot_at(0, 5) == pytest.approx(dot_at(7, 12), rel=1e-4, abs=1e-4)


def test_rms_norm_scale_and_dtype():
    x = jax.random.normal(jax.random.key(0), (2, 3, 16), jnp.bfloat16)
    out = L.rms_norm(x, jnp.ones((16,), jnp.bfloat16))
    assert out.dtype == jnp.bfloat16
    rms = np.sqrt(np.mean(np.asarray(out, np.float32) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, atol=0.1)
