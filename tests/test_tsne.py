"""tSNE: calibration hits target perplexity; KL decreases; blobs separate."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tsne


def _blobs(n_per, centers, scale=0.05, seed=0, dim=None):
    rng = np.random.default_rng(seed)
    cs = np.asarray(centers, np.float32)
    dim = dim or cs.shape[1]
    pts = np.concatenate([
        c + scale * rng.normal(size=(n_per, dim)).astype(np.float32)
        for c in cs])
    labels = np.repeat(np.arange(len(cs)), n_per)
    return jnp.asarray(pts), labels


def test_pairwise_sq_dists():
    x = jnp.asarray([[0.0, 0.0], [3.0, 4.0]])
    d = np.asarray(tsne.pairwise_sq_dists(x))
    np.testing.assert_allclose(d, [[0, 25], [25, 0]], atol=1e-5)


def test_calibration_hits_perplexity():
    x, _ = _blobs(60, [[0, 0], [5, 5], [-5, 5]], seed=1)
    perp = 20.0
    p = tsne.calibrate_p(x, perp)
    n = x.shape[0]
    assert np.isclose(float(jnp.sum(p)), 1.0, atol=1e-4)
    # recompute per-row entropy of the conditional: use the joint as proxy —
    # rows of the symmetrized P should have effective support ~perplexity
    p_np = np.asarray(p)
    row = p_np / p_np.sum(1, keepdims=True)
    with np.errstate(divide="ignore", invalid="ignore"):
        h = -np.nansum(np.where(row > 0, row * np.log(row), 0), axis=1)
    eff = np.exp(h)
    # symmetrization shifts it somewhat; just require the right ballpark
    assert 0.5 * perp < eff.mean() < 3.0 * perp


def test_kl_decreases_and_blobs_separate():
    x, labels = _blobs(50, [[0, 0, 0], [4, 4, 4], [-4, 4, 0]], seed=2)
    cfg = tsne.TsneConfig(n_iter=250, perplexity=15.0)
    y, kls = tsne.run_tsne(jax.random.key(0), x, cfg)
    y = np.asarray(y)
    assert not np.isnan(y).any()
    kls = np.asarray(kls)
    # KL after exaggeration ends must keep decreasing on average
    assert kls[-1] < kls[cfg.exaggeration_iters + 10]
    # cluster separation: mean intra-cluster dist << mean inter-cluster dist
    intra, inter = [], []
    for a in range(3):
        ya = y[labels == a]
        intra.append(np.linalg.norm(ya - ya.mean(0), axis=1).mean())
        for b_ in range(a + 1, 3):
            inter.append(np.linalg.norm(ya.mean(0) - y[labels == b_].mean(0)))
    assert min(inter) > 2.0 * max(intra)


def test_weighted_tsne_runs():
    x, _ = _blobs(40, [[0, 0], [6, 0]], seed=3)
    w = jnp.concatenate([jnp.full((40,), 10.0), jnp.ones((40,))])
    cfg = tsne.TsneConfig(n_iter=100, perplexity=10.0)
    y, kls = tsne.run_tsne(jax.random.key(1), x, cfg, weights=w)
    assert not np.isnan(np.asarray(y)).any()
    assert np.isfinite(np.asarray(kls)).all()


def test_init_propagates_to_iteration_zero():
    """The warm-start hook: with n_iter=0 the returned embedding IS the
    init (bit-exact — nothing may perturb iteration 0), and with
    iterations two different inits must yield different trajectories
    (the init reaches the optimizer, not just the return path)."""
    x, _ = _blobs(20, [[0, 0], [4, 4]], seed=4)
    y0 = 0.05 * np.asarray(
        jax.random.normal(jax.random.key(7), (40, 2)), np.float32)
    cfg = tsne.TsneConfig(n_iter=0, perplexity=10.0,
                          exaggeration_iters=0, momentum_switch=0)
    y, _ = tsne.run_tsne(jax.random.key(0), x, cfg, init=jnp.asarray(y0))
    np.testing.assert_array_equal(np.asarray(y), y0)
    cfg1 = tsne.TsneConfig(n_iter=1, perplexity=10.0,
                           exaggeration_iters=0, momentum_switch=0)
    yw, _ = tsne.run_tsne(jax.random.key(0), x, cfg1, init=jnp.asarray(y0))
    y2, _ = tsne.run_tsne(jax.random.key(0), x, cfg1,
                          init=jnp.asarray(2.0 * y0))
    assert np.abs(np.asarray(yw) - np.asarray(y2)).max() > 1e-6


def test_init_propagates_sparse_backend():
    x, _ = _blobs(30, [[0, 0, 0], [4, 4, 4]], seed=5)
    y0 = 0.05 * np.asarray(
        jax.random.normal(jax.random.key(8), (60, 2)), np.float32)
    cfg = tsne.TsneConfig(n_iter=0, perplexity=8.0, backend="sparse",
                          exaggeration_iters=0, momentum_switch=0)
    y, _ = tsne.run_tsne(jax.random.key(0), x, cfg, init=jnp.asarray(y0))
    np.testing.assert_array_equal(np.asarray(y), y0)


def test_init_validation_rejects_bad_shape_and_dtype():
    import pytest
    x, _ = _blobs(10, [[0, 0]], seed=6)
    cfg = tsne.TsneConfig(n_iter=1, perplexity=5.0)
    with pytest.raises(ValueError, match="shape"):
        tsne.run_tsne(jax.random.key(0), x, cfg,
                      init=jnp.zeros((3, 2), jnp.float32))
    with pytest.raises(ValueError, match="float"):
        tsne.run_tsne(jax.random.key(0), x, cfg,
                      init=jnp.zeros((10, 2), jnp.int32))
