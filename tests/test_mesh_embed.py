"""Mesh-parallel embed stage: sharded layout, equivalence, jaxpr contract.

Two tiers:

* host-side tests (any device count) — ``coo.shard_edge_layout`` property
  tests against ``np.add.at``, ``core.mesh`` sizing helpers, dispatch
  guards, and the pipeline wiring of ``SnsConfig.embed_mesh``;
* 8-device tests (skipped unless the process sees >= 8 devices) — the
  fp-equivalence and collective-contract pins for the sharded kNN build,
  sparse tSNE iteration, and UMAP epoch loop.  CI runs this file as a
  separate step under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
  (the flag must be set before jax initializes; the main test process
  keeps seeing 1 device per the project's dry-run discipline), and the
  slow subprocess wrapper at the bottom gives the default suite the same
  coverage.

The equivalence contract is deliberately split by horizon: per-step
quantities (gradients, epoch deltas) agree to tight fp tolerance, and
short optimizer prefixes agree to loose tolerance — but BOTH embedders'
dynamics are chaotic (momentum+gains sign switches, near-singular UMAP
repulsion), so summation-order noise from the block-local reductions is
amplified exponentially and end-state equality over hundreds of steps is
not a well-posed contract.  Long-horizon agreement is asserted at the
quality level instead (final KL within a few percent).
"""
import dataclasses
import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from benchmarks.common import count_primitive
from repro.core import coo, pipeline, tsne, umap
from repro.core import mesh as mesh_mod

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


# ---------------------------------------------------- host-side: row blocks
def test_row_block_sizing():
    assert mesh_mod.row_block(16, 4) == (4, 16)
    assert mesh_mod.row_block(17, 4) == (5, 20)      # non-dividing: padded
    assert mesh_mod.row_block(3, 8) == (1, 8)        # more shards than rows
    rows_per, n_pad = mesh_mod.row_block(203, 8)
    assert n_pad >= 203 and n_pad == rows_per * 8


def test_resolve_mesh_normalizes_specs():
    assert mesh_mod.resolve_mesh(None) is None
    m = mesh_mod.resolve_mesh(1)
    assert isinstance(m, mesh_mod.Mesh)
    assert mesh_mod.mesh_axis(m) == mesh_mod.EMBED_AXIS
    assert mesh_mod.axis_size(m, mesh_mod.EMBED_AXIS) == 1
    assert mesh_mod.resolve_mesh(m) is m             # Mesh passes through
    with pytest.raises(TypeError):
        mesh_mod.resolve_mesh("eight")
    with pytest.raises(ValueError):
        mesh_mod.make_embed_mesh(jax.device_count() + 1)


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 80),
       e=st.integers(1, 400), s=st.integers(1, 9))
@settings(max_examples=30, deadline=None)
def test_shard_edge_layout_reduces_like_np_add_at(seed, n, e, s):
    """Property: over arbitrary src-sorted COO multisets (duplicate edges,
    rows with no edges, empty blocks, block counts that don't divide N),
    the per-block local src reduction stitched back together == np.add.at
    on src, and the psum of per-block full-length dst partials ==
    np.add.at on dst."""
    rng = np.random.default_rng(seed)
    src = np.sort(rng.integers(0, n, e)).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    vals = rng.normal(size=(e, 2)).astype(np.float32)
    lay = coo.shard_edge_layout(src, dst, n, s)
    v = coo.shard_payload(lay, jnp.asarray(vals))     # (S, Ep, 2)

    rows_per, n_pad = lay.rows_per_shard, lay.n_padded
    assert lay.n_shards == s and n_pad == rows_per * s >= n
    # payload on padded slots is exactly zero
    assert float(jnp.abs(jnp.where(lay.edge_mask[..., None], 0.0, v)
                         ).max()) == 0.0
    # every live slot maps back to its global edge (draw-alignment hook)
    ids = np.asarray(lay.edge_ids)
    mask = np.asarray(lay.edge_mask)
    np.testing.assert_array_equal(np.asarray(lay.src)[mask], src[ids[mask]])
    np.testing.assert_array_equal(np.sort(ids[mask]), np.arange(e))

    by_src = np.concatenate([
        np.asarray(coo.segment_reduce(v[b], lay.src_bounds[b]))
        for b in range(s)])                           # (n_pad, 2)
    dst_parts = [coo.segment_reduce(jnp.asarray(v[b])[lay.dst_order[b]],
                                    lay.dst_bounds[b]) for b in range(s)]
    by_dst = np.asarray(sum(dst_parts))               # the psum, host-side

    ref_src = np.zeros((n_pad, 2), np.float64)
    ref_dst = np.zeros((n_pad, 2), np.float64)
    np.add.at(ref_src, src, vals.astype(np.float64))
    np.add.at(ref_dst, dst, vals.astype(np.float64))
    scale = max(1.0, np.abs(ref_src).max(), np.abs(ref_dst).max())
    assert np.abs(by_src - ref_src).max() <= 1e-4 * scale
    assert np.abs(by_dst - ref_dst).max() <= 1e-4 * scale


def test_shard_edge_layout_rejects_unsorted_src():
    with pytest.raises(ValueError, match="sorted"):
        coo.shard_edge_layout(np.array([3, 1]), np.array([0, 0]), 4, 2)


def test_run_tsne_mesh_requires_sparse_backend():
    x = jnp.zeros((8, 3))
    cfg = tsne.TsneConfig(backend="dense", n_iter=1)
    with pytest.raises(ValueError, match="sparse"):
        tsne.run_tsne(jax.random.key(0), x, cfg, mesh=1)


# ---------------------------------------------------- host-side: wiring
def test_embed_stage_wires_embed_mesh_into_both_embedders(monkeypatch):
    """SnsConfig.embed_mesh (an int spec) must reach run_umap/run_tsne as
    a resolved 1-D Mesh."""
    seen = {}

    def fake_run_umap(key, x, cfg, weights=None, mesh=None):
        seen["umap"] = mesh
        return jnp.zeros((x.shape[0], cfg.dims))

    def fake_run_tsne(key, x, cfg, weights=None, backend=None, mesh=None):
        seen["tsne"] = mesh
        return jnp.zeros((x.shape[0], cfg.dims)), jnp.zeros((cfg.n_iter,))

    monkeypatch.setattr(pipeline.umap_mod, "run_umap", fake_run_umap)
    monkeypatch.setattr(pipeline.tsne_mod, "run_tsne", fake_run_tsne)
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.uniform(0, 1, size=(256, 3)).astype(np.float32))
    for embedder in ("umap", "tsne"):
        cfg = pipeline.SnsConfig(bins=8, rows=4, log2_cols=10, top_k=32,
                                 embedder=embedder, embed_mesh=1,
                                 embed_backend="sparse")
        grid, hh = pipeline.sketch_stage(cfg, pts)
        pipeline.embed_stage(cfg, grid, hh)
    for k in ("umap", "tsne"):
        assert isinstance(seen[k], mesh_mod.Mesh), k
        assert mesh_mod.mesh_axis(seen[k]) == mesh_mod.EMBED_AXIS


# ------------------------------------------------------- 8-device fixtures
def _blob_data(n=203, dims=5, seed=0):
    """Two-cluster weighted data at a deliberately non-dividing N."""
    rng = np.random.default_rng(seed)
    x = np.concatenate([rng.normal(0, 1, (n // 2, dims)),
                        rng.normal(6, 1, (n - n // 2, dims))])
    w = rng.integers(1, 50, n).astype(np.float32)
    return jnp.asarray(x.astype(np.float32)), jnp.asarray(w)


@pytest.fixture(scope="module")
def mesh8():
    return mesh_mod.make_embed_mesh(8)


# ------------------------------------------------ host-side: kNN edge cases
def test_knn_graph_block_not_dividing_n_matches_dense():
    """Blocked exact path at a block that does NOT divide N (203 = 5·37
    + 18): padded tail rows must not leak into anyone's neighbor list."""
    from repro.core import neighbors
    x, _ = _blob_data()
    i1, d1 = neighbors.knn_graph(x, 10)
    i2, d2 = neighbors.knn_graph(x, 10, block=37)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)


@pytest.mark.parametrize("method", ["exact", "ann"])
def test_knn_graph_clamps_k_to_n_minus_1(method):
    """k ≥ N−1 clamps to N−1 on every path (a point has at most N−1
    neighbors), and with k = N−1 both engines return the full sorted
    neighbor set — so they must agree exactly."""
    from repro.core import neighbors
    x, _ = _blob_data(n=9)
    idx, dist = neighbors.knn_graph(x, 50, method=method)
    assert idx.shape == (9, 8) and dist.shape == (9, 8)
    own = np.arange(9)[:, None]
    assert not (np.asarray(idx) == own).any()        # never lists itself
    ei, _ = neighbors.knn_graph(x, 8)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ei))


# ----------------------------------------------------- 8-device: kNN + grad
@needs8
def test_knn_graph_mesh_matches_single_device(mesh8):
    from repro.core import neighbors
    x, _ = _blob_data()
    i1, d1 = neighbors.knn_graph(x, 10, block=64)
    i2, d2 = neighbors.knn_graph(x, 10, block=64, mesh=mesh8)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)


@needs8
def test_ann_knn_graph_mesh_matches_single_device(mesh8):
    """The approximate engine under shard_map at a non-power-of-two N is
    BIT-exact vs single-device: replicated probe merges, per-global-row
    RNG draws, and a psum'd change count make the sharded NN-descent take
    the identical trajectory (a layout/draw misalignment would diverge in
    round 1)."""
    from repro.core import neighbors
    x, _ = _blob_data()
    i1, d1 = neighbors.knn_graph(x, 10, method="ann")
    i2, d2 = neighbors.knn_graph(x, 10, method="ann", mesh=mesh8)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


@needs8
def test_sharded_tsne_gradient_matches_sparse_grad(mesh8):
    """Per-iteration quantities agree tightly: the sharded gradient, KL,
    and Z are the same math reassociated over blocks."""
    x, w = _blob_data()
    n = x.shape[0]
    axis = mesh_mod.mesh_axis(mesh8)
    P = mesh_mod.P
    sp = tsne.build_sparse_p(x, 10.0, k=10, weights=w)
    ssp = tsne.shard_sparse_p(sp, n, 8)
    rows_per, n_pad = mesh_mod.row_block(n, 8)
    rng = np.random.default_rng(1)
    y = jnp.asarray(rng.normal(0, 1e-2, (n, 2)).astype(np.float32))
    yp = jnp.pad(y, [(0, n_pad - n), (0, 0)])
    g_ref, kl_ref = tsne.sparse_grad(y, sp, 12.0, grid_size=32)

    lay_specs = jax.tree_util.tree_map(lambda _: P(axis), ssp)

    @mesh_mod.shard_map_compat(mesh=mesh8, in_specs=(P(axis), lay_specs, P()),
                               out_specs=(P(axis), P()))
    def spmd(y_blk, ssp_, y_full):
        lay = jax.tree_util.tree_map(lambda a: a[0], ssp_.layout)
        return tsne.sparse_grad_shard(y_blk, lay, ssp_.val[0], y_full,
                                      12.0, 32, axis, n)

    g_mesh, kl_mesh = spmd(yp, ssp, yp)
    scale = max(1.0, float(jnp.abs(g_ref).max()))
    assert float(jnp.abs(g_ref - g_mesh[:n]).max()) <= 1e-4 * scale
    # padded rows must receive exactly zero gradient
    assert float(jnp.abs(g_mesh[n:]).max()) == 0.0
    assert abs(float(kl_ref) - float(kl_mesh)) <= 1e-3


@needs8
@pytest.mark.parametrize("grid_interval", [0.0, 0.5])
def test_run_tsne_mesh_matches_single_device_prefix(mesh8, grid_interval):
    """Short optimizer prefix (both the fixed-G and the adaptive staged
    drivers): same key, same config → same trajectory to fp tolerance at
    a non-dividing N."""
    x, w = _blob_data()
    cfg = tsne.TsneConfig(backend="sparse", n_iter=8, grid_size=32, knn=10,
                          grid_interval=grid_interval, grid_max=64,
                          adaptive_interval=4,
                          exaggeration_iters=5, momentum_switch=5)
    key = jax.random.key(3)
    y1, k1 = tsne.run_tsne(key, x, cfg, weights=w)
    y2, k2 = tsne.run_tsne(key, x, cfg, weights=w, mesh=mesh8)
    assert y2.shape == y1.shape
    scale = max(1.0, float(jnp.abs(y1).max()))
    assert float(jnp.abs(y1 - y2).max()) <= 2e-2 * scale
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2), atol=1e-2)


@needs8
def test_run_tsne_mesh_long_run_stays_stable_and_descends(mesh8):
    """Long horizon: trajectories decohere (chaotic dynamics amplify
    block-reduction fp noise through the momentum+gains optimizer), and
    on a 203-point landscape the two runs legitimately settle in
    different basins — so the contract here is STABILITY, not closeness:
    the sharded run must stay finite for 150 iterations and descend into
    the same quality regime as the single-device run.  (The tight
    equivalence contracts live in the per-gradient and short-prefix
    tests above.)"""
    x, w = _blob_data()
    # learning_rate tamed for this tiny heavily-weighted blob (the
    # default 200 diverges on BOTH paths), and quality read as the best
    # post-exaggeration KL: the late gains build-up overshoots the
    # funnel floor, so the final iterate is noise, the floor is not
    cfg = tsne.TsneConfig(backend="sparse", n_iter=150, grid_size=32,
                          knn=10, exaggeration_iters=40, momentum_switch=40,
                          learning_rate=20.0)
    key = jax.random.key(5)
    _, k1 = tsne.run_tsne(key, x, cfg, weights=w)
    _, k2 = tsne.run_tsne(key, x, cfg, weights=w, mesh=mesh8)
    k1, k2 = np.asarray(k1), np.asarray(k2)
    assert np.isfinite(k1).all() and np.isfinite(k2).all()
    q1, q2 = float(k1[45:].min()), float(k2[45:].min())
    # both optimizers descended well below the post-exaggeration start...
    assert q1 < 0.7 * float(k1[45]) and q2 < 0.7 * float(k2[45])
    # ...into the same quality regime (different basins differ by tens of
    # percent on this toy landscape; a broken collective would be orders)
    assert max(q1, q2) <= 2.5 * min(q1, q2), (q1, q2)


# ------------------------------------------------------- 8-device: UMAP
@needs8
def test_run_umap_mesh_matches_single_device_prefix(mesh8):
    """Short optimizer prefix, draw-for-draw: any negative-sample
    misalignment would produce O(1) differences after a single epoch, so
    the tight epoch-1 tolerance doubles as the RNG alignment test."""
    x, w = _blob_data()
    for epochs, tol in ((1, 1e-4), (3, 2e-2)):
        cfg = umap.UmapConfig(n_epochs=epochs, n_neighbors=10, block=64)
        u1 = umap.run_umap(jax.random.key(7), x, cfg, weights=w)
        u2 = umap.run_umap(jax.random.key(7), x, cfg, weights=w, mesh=mesh8)
        assert u2.shape == u1.shape
        scale = max(1.0, float(jnp.abs(u1).max()))
        assert float(jnp.abs(u1 - u2).max()) <= tol * scale, epochs


@needs8
def test_umap_mesh_epoch_delta_matches_reference(mesh8):
    """The sharded per-epoch delta == the single-device epoch_delta for
    the same key at every state along a short trajectory."""
    x, w = _blob_data(n=117)
    n = x.shape[0]
    axis = mesh_mod.mesh_axis(mesh8)
    P = mesh_mod.P
    cfg = umap.UmapConfig(n_neighbors=8, block=64)
    a, b = umap.fit_ab(cfg.spread, cfg.min_dist)
    idx, dist = umap.knn_graph(x, cfg.n_neighbors, block=cfg.block)
    edges, memb = umap.fuzzy_simplicial_set(idx, dist, weights=w)
    layout, order = coo.edge_layout(edges[:, 0], edges[:, 1], n)
    memb_n = (memb / jnp.maximum(jnp.max(memb), 1e-12))[order]
    slay = coo.shard_edge_layout(np.asarray(layout.src),
                                 np.asarray(layout.dst), n, 8)
    memb_s = coo.shard_payload(slay, memb_n)
    e_total = int(layout.src.shape[0])
    rows_per, n_pad = mesh_mod.row_block(n, 8)
    lay_specs = jax.tree_util.tree_map(lambda _: P(axis), slay)

    @mesh_mod.shard_map_compat(
        mesh=mesh8, in_specs=(P(axis), lay_specs, P(axis), P()),
        out_specs=P(axis))
    def spmd(y_blk, slay_, memb_s_, kneg):
        lay = jax.tree_util.tree_map(lambda v: v[0], slay_)
        y_full = jax.lax.all_gather(y_blk, axis, axis=0, tiled=True)
        return umap.epoch_delta_shard(y_blk, y_full, lay, memb_s_[0], kneg,
                                      a, b, cfg.neg_rate, n, e_total, axis)

    rng = np.random.default_rng(2)
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    kloop = jax.random.key(11)
    for i in range(4):
        kloop, kneg = jax.random.split(kloop)
        ref = umap.epoch_delta(y, layout, memb_n, kneg, a, b, cfg.neg_rate)
        yp = jnp.pad(y, [(0, n_pad - n), (0, 0)])
        got = spmd(yp, slay, memb_s, kneg)
        scale = max(1.0, float(jnp.abs(ref).max()))
        assert float(jnp.abs(ref - got[:n]).max()) <= 1e-4 * scale, i
        assert float(jnp.abs(got[n:]).max()) == 0.0    # padded rows inert
        y = y + 0.7 * ref


# ------------------------------------------------- 8-device: jaxpr contract
@needs8
def test_sharded_tsne_stage_jaxpr_pins_collectives_and_scatters(mesh8):
    """The sharded iteration adds ZERO scatter primitives over the
    single-device stage (the only scatter-adds are the same four CIC
    corner splats, now per device) and speaks exactly the documented
    collective set: one all_gather (block positions) + five psums (grid,
    Z, two KL partials, centering mean)."""
    x, w = _blob_data()
    n = x.shape[0]
    cfg = tsne.TsneConfig(backend="sparse", n_iter=4, grid_size=32, knn=10)
    sp = tsne.build_sparse_p(x, cfg.perplexity, k=10, weights=w)
    ssp = tsne.shard_sparse_p(sp, n, 8)
    rows_per, n_pad = mesh_mod.row_block(n, 8)
    kls = jnp.zeros((cfg.n_iter,))
    it0 = jnp.asarray(0, jnp.int32)

    def state(rows):
        z = jnp.zeros((rows, 2))
        return tsne.TsneState(z, z, jnp.ones((rows, 2)))

    sharded = jax.make_jaxpr(functools.partial(
        tsne._sparse_stage_mesh, cfg=cfg, count=4, grid_size=32,
        interpret=True, mesh=mesh8, n=n))(state(n_pad), kls, ssp, it0)
    single = jax.make_jaxpr(functools.partial(
        tsne._sparse_stage, cfg=cfg, count=4, grid_size=32,
        interpret=True))(state(n), kls, sp, it0)

    for prim in ("scatter-add", "scatter", "scatter-mul", "scatter-max"):
        assert count_primitive(sharded.jaxpr, prim) == \
            count_primitive(single.jaxpr, prim), \
            f"sharding changed {prim} count"
    assert count_primitive(sharded.jaxpr, "scatter-add") == 4  # CIC corners
    assert count_primitive(sharded.jaxpr, "all_gather") == 1
    assert count_primitive(sharded.jaxpr, "psum") == 5
    for prim in ("ppermute", "all_to_all", "reduce_scatter"):
        assert count_primitive(sharded.jaxpr, prim) == 0


@needs8
def test_sharded_umap_optimizer_jaxpr_scatter_free_and_pinned(mesh8):
    """The whole sharded UMAP optimizer (setup + epoch fori_loop): zero
    scatter primitives of any flavour, and exactly one all_gather (block
    positions) + one psum (dst-side partials) per epoch body."""
    x, w = _blob_data()
    n = x.shape[0]
    cfg = umap.UmapConfig(n_epochs=3, n_neighbors=10, block=64)
    idx, dist = umap.knn_graph(x, cfg.n_neighbors, block=cfg.block)
    edges, memb = umap.fuzzy_simplicial_set(idx, dist, weights=w)
    layout, order = coo.edge_layout(edges[:, 0], edges[:, 1], n)
    memb_n = (memb / jnp.maximum(jnp.max(memb), 1e-12))[order]
    slay = coo.shard_edge_layout(np.asarray(layout.src),
                                 np.asarray(layout.dst), n, 8)
    memb_s = coo.shard_payload(slay, memb_n)
    jaxpr = jax.make_jaxpr(functools.partial(
        umap._optimize_embedding_mesh, cfg=cfg, n=n,
        e_total=int(layout.src.shape[0]), mesh=mesh8))(
            jax.random.key(0), slay, memb_s, None)
    for prim in ("scatter-add", "scatter", "scatter-mul", "scatter-max"):
        assert count_primitive(jaxpr.jaxpr, prim) == 0, prim
    assert count_primitive(jaxpr.jaxpr, "all_gather") == 1
    assert count_primitive(jaxpr.jaxpr, "psum") == 1
    for prim in ("ppermute", "all_to_all", "reduce_scatter"):
        assert count_primitive(jaxpr.jaxpr, prim) == 0


@needs8
def test_cancer_1m_config_constructs_sharded_stage(mesh8):
    """CANCER_1M smoke: derive the TsneConfig exactly as embed_stage does
    and CONSTRUCT (trace, not run) the sharded adaptive stage at the
    paper's grid/knn settings — the full-scale run is a benchmark, but
    the trace must already be valid here."""
    from repro.configs.sns_paper import CANCER_1M
    tc = tsne.TsneConfig(dims=CANCER_1M.embed_dims)
    tc = dataclasses.replace(
        tc, backend=CANCER_1M.embed_backend, block=CANCER_1M.embed_block,
        knn=CANCER_1M.embed_knn, grid_size=CANCER_1M.embed_grid,
        grid_interval=CANCER_1M.embed_grid_interval,
        grid_max=CANCER_1M.embed_grid_max, cic=CANCER_1M.embed_cic)
    assert tc.backend == "sparse" and tc.grid_interval > 0
    # modest row count; the static structure (G, staged adaptive driver,
    # collective set) is what the trace checks
    n = 512
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
    sp = tsne.build_sparse_p(x, tc.perplexity, k=tc.knn or None,
                             block=tc.block)
    ssp = tsne.shard_sparse_p(sp, n, 8)
    rows_per, n_pad = mesh_mod.row_block(n, 8)
    z = jnp.zeros((n_pad, 2))
    state = tsne.TsneState(z, z, jnp.ones((n_pad, 2)))
    jaxpr = jax.make_jaxpr(functools.partial(
        tsne._sparse_stage_mesh, cfg=tc, count=tc.adaptive_interval,
        grid_size=tc.grid_size, interpret=True, mesh=mesh8, n=n))(
            state, jnp.zeros((tc.n_iter,)), ssp, jnp.asarray(0, jnp.int32))
    assert count_primitive(jaxpr.jaxpr, "all_gather") == 1
    assert count_primitive(jaxpr.jaxpr, "psum") == 5


# ------------------------------------------------- 8-device: full pipeline
@needs8
def test_pipeline_embed_mesh_end_to_end_matches_single_device(mesh8):
    """SnsConfig.embed_mesh end to end (sketch → HH → reps → sharded
    UMAP): same result as the single-device pipeline to fp tolerance."""
    rng = np.random.default_rng(4)
    pts = jnp.asarray(rng.uniform(0, 1, size=(4096, 3)).astype(np.float32))
    base = dict(bins=8, rows=4, log2_cols=10, top_k=64, embedder="umap")
    ucfg = umap.UmapConfig(n_epochs=2, n_neighbors=8)
    cfg1 = pipeline.SnsConfig(**base)
    cfg2 = pipeline.SnsConfig(**base, embed_mesh=mesh8)
    r1 = pipeline.run(cfg1, pts, umap_cfg=ucfg)
    r2 = pipeline.run(cfg2, pts, umap_cfg=ucfg)
    assert r1.embedding.shape == r2.embedding.shape
    scale = max(1.0, float(jnp.abs(r1.embedding).max()))
    assert float(jnp.abs(r1.embedding - r2.embedding).max()) <= 1e-3 * scale


# ---------------------------------------------------- subprocess tier bridge
@pytest.mark.slow
def test_mesh_suite_under_virtual_8_devices():
    """Run this file's 8-device tests in a subprocess that actually sees 8
    virtual CPU devices (the default suite's process must keep seeing 1 —
    dry-run discipline), so `pytest -m slow` covers the mesh contract
    without the CI-only XLA_FLAGS step."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         "-m", "not slow", os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=3000, cwd=root)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
