"""Resilience layer: retries, straggler cutoff, partial aggregation.

The load-bearing claims, each pinned here:

* bounded retry with deterministic backoff rescues transient faults and
  fails loud (``RetryError``) on permanent ones;
* digest verification catches in-transit corruption and retries it;
* an all-healthy collection is BIT-IDENTICAL to ingesting the same data
  through independent shards and merging — resilience costs nothing when
  nothing fails;
* partial aggregation after loss equals the fold of exactly the
  surviving sub-stream (CountSketch linearity), with coverage and the
  widened error bound quantifying the damage;
* the widened heavy-hitter bound is MONOTONE: losing more shards never
  shrinks it (given true expected per-shard counts);
* ``min_coverage`` / zero survivors fail loud with ``CoverageError``.
"""
import time

import jax
import numpy as np
import pytest

from repro.core import faults, geo, quantize, resilience
from repro.core import heavy_hitters as hh_mod
from repro.core import stream
from repro.core.faults import FaultPlan
from repro.core.resilience import (CoverageError, IntegrityError,
                                   RetryError, RetryPolicy)

ROWS, LOG2_COLS, POOL, TOP_K = 4, 10, 256, 32
N_SHARDS, PER_SHARD, DIMS = 6, 300, 3

FAST = RetryPolicy(max_attempts=3, base_delay=0.001, max_delay=0.01)


def _shard_data():
    rng = np.random.RandomState(0)
    return {s: [(rng.randn(PER_SHARD, DIMS) * 0.05
                 + (s % 3)).astype(np.float32)]
            for s in range(N_SHARDS)}


@pytest.fixture(scope="module")
def grid():
    return quantize.fit_grid(
        np.concatenate([c for v in _shard_data().values() for c in v]), 8)


def _extract(grid, data, **kw):
    return geo.resilient_extract(
        grid, data, rows=ROWS, log2_cols=LOG2_COLS, top_k=TOP_K,
        candidate_pool=POOL, seed=0, chunk_size=128,
        policy=kw.pop("policy", FAST), **kw)


def _live_hh(hh):
    m = np.asarray(hh.mask).astype(bool)
    keys = (np.asarray(hh.key_hi, np.uint64)[m] << np.uint64(32)) \
        | np.asarray(hh.key_lo, np.uint64)[m]
    order = np.argsort(keys)
    return keys[order], np.asarray(hh.count)[m][order]


# --------------------------------------------------------------- retry unit
def test_retry_policy_validates():
    for bad in (dict(max_attempts=0), dict(multiplier=0.5),
                dict(jitter=2.0), dict(attempt_timeout=0.0),
                dict(base_delay=-1.0)):
        with pytest.raises(ValueError):
            RetryPolicy(**bad)


def test_backoff_deterministic_bounded():
    p = RetryPolicy(base_delay=0.1, multiplier=2.0, max_delay=0.5,
                    jitter=0.5)
    for attempt in range(6):
        d1 = p.backoff(attempt, seed=3)
        d2 = p.backoff(attempt, seed=3)
        assert d1 == d2                      # deterministic
        raw = min(0.1 * 2.0 ** attempt, 0.5)
        assert raw * 0.5 <= d1 <= raw * 1.5  # jitter stays in ±50%
    assert p.backoff(0, seed=1) != p.backoff(0, seed=2)


def test_call_with_retry_rescues_transient():
    calls = [0]

    def flaky():
        calls[0] += 1
        if calls[0] < 3:
            raise RuntimeError("transient")
        return "ok"

    out, attempts = resilience.call_with_retry(flaky, FAST)
    assert out == "ok" and attempts == 3


def test_call_with_retry_exhausts_loudly():
    def dead():
        raise RuntimeError("permanent")

    with pytest.raises(RetryError) as ei:
        resilience.call_with_retry(dead, FAST)
    assert isinstance(ei.value.__cause__, RuntimeError)


def test_check_failure_counts_as_attempt():
    """A delivery that fails its integrity check retries like any fault."""
    calls = [0]

    def job():
        calls[0] += 1
        return calls[0]

    def check(v):
        if v < 2:
            raise IntegrityError("bad digest")

    out, attempts = resilience.call_with_retry(job, FAST, check=check)
    assert out == 2 and attempts == 2


# ------------------------------------------------------------ the collector
def test_all_healthy_collection_is_lossless(grid):
    """No faults → coverage 1, no retries burned, and the extracted HHs
    are bit-identical to a second run (pure function of the data)."""
    r1 = _extract(grid, _shard_data())
    r2 = _extract(grid, _shard_data())
    assert r1.coverage == 1.0 and r1.lost == () and r1.retries == 0
    assert r1.observed_count == N_SHARDS * PER_SHARD
    k1, c1 = _live_hh(r1.hh)
    k2, c2 = _live_hh(r2.hh)
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(c1, c2)


def test_partial_merge_equals_fold_of_survivors(grid):
    """Subset consistency (CountSketch linearity): drop shard 2 and the
    extraction equals running on the surviving shards alone."""
    data = _shard_data()
    lossy = _extract(grid, data, faults=FaultPlan(seed=0, drop_shards=(2,)))
    survivors = {s: v for s, v in data.items() if s != 2}
    clean = _extract(grid, survivors)
    assert lossy.lost == (2,)
    assert lossy.observed_count == clean.observed_count
    kl, cl = _live_hh(lossy.hh)
    kc, cc = _live_hh(clean.hh)
    np.testing.assert_array_equal(kl, kc)
    np.testing.assert_array_equal(cl, cc)


def test_flaky_shards_are_rescued_by_retry(grid):
    """Transient failures burn retries but lose nothing."""
    res = _extract(grid, _shard_data(), faults=FaultPlan(seed=1, flaky=0.5))
    assert res.coverage == 1.0 and res.lost == ()
    assert res.retries > 0


def test_straggler_cutoff(grid):
    """A shard sleeping past the deadline is abandoned, not awaited."""
    data = _shard_data()
    plan = FaultPlan(seed=0, drop_shards=(), delay=0.0)
    slow = {s: v for s, v in data.items()}

    def sleepy(chunks=data[4]):
        time.sleep(6.0)   # modest: the abandoned thread is joined at
        return list(chunks)  # interpreter exit (non-daemon executors)

    slow[4] = sleepy
    t0 = time.monotonic()
    res = _extract(grid, slow, faults=plan, deadline=1.5,
                   policy=RetryPolicy(max_attempts=1))
    assert time.monotonic() - t0 < 5.0       # did not wait out the sleep
    assert 4 in res.lost
    st = {s.shard: s for s in res.statuses}[4]
    assert st.error == "deadline" and not st.ok
    assert res.coverage < 1.0


def test_min_coverage_fails_loud(grid):
    with pytest.raises(CoverageError, match="coverage"):
        _extract(grid, _shard_data(),
                 faults=FaultPlan(seed=0, drop_shards=(0, 1, 2)),
                 min_coverage=0.9)


def test_zero_survivors_fails_loud(grid):
    with pytest.raises(CoverageError, match="no shard"):
        _extract(grid, _shard_data(),
                 faults=FaultPlan(seed=0,
                                  drop_shards=tuple(range(N_SHARDS))))


def test_digest_verification_catches_corruption(grid):
    """corrupt=1.0 flips a bit in every delivered state AFTER its digest
    was computed; verify=True must reject every delivery → zero shards
    survive their retry budgets."""
    with pytest.raises(CoverageError):
        _extract(grid, _shard_data(),
                 faults=FaultPlan(seed=0, corrupt=1.0),
                 policy=RetryPolicy(max_attempts=2, base_delay=0.001))


# ------------------------------------------------- degradation properties
def test_error_bound_monotone_under_widening_loss(grid):
    """Dropping MORE shards never shrinks the widened bound (with true
    per-shard expected counts): bound = max survivor watermark + lost
    mass, and a newly lost shard adds expected_t >= its own watermark."""
    data = _shard_data()
    expected = {s: float(PER_SHARD) for s in range(N_SHARDS)}
    for chain_seed in range(3):
        order = np.random.RandomState(chain_seed).permutation(N_SHARDS)
        prev = -np.inf
        for k in range(N_SHARDS):            # nested masks, one more each
            mask = tuple(int(s) for s in order[:k])
            res = _extract(grid, data, expected_counts=expected,
                           faults=FaultPlan(seed=0, drop_shards=mask))
            assert res.hh_error_bound >= prev, \
                f"bound shrank at mask {mask} (chain seed {chain_seed})"
            assert res.coverage == pytest.approx(1.0 - k / N_SHARDS)
            prev = res.hh_error_bound


def test_lost_mass_estimated_without_expected_counts(grid):
    """No expected_counts → lost mass estimated as the mean observed
    shard mass (here exact: equal shards)."""
    res = _extract(grid, _shard_data(),
                   faults=FaultPlan(seed=0, drop_shards=(1,)))
    assert res.coverage == pytest.approx((N_SHARDS - 1) / N_SHARDS)
    assert res.hh_error_bound >= PER_SHARD   # the estimated lost mass


# ----------------------------------------- non-retryable exception classes
def test_value_error_fails_immediately():
    """Deterministic failures must not burn the attempt budget: a
    ValueError re-raises from the FIRST attempt, untouched."""
    calls = []

    def fn():
        calls.append(1)
        raise ValueError("bad config")

    with pytest.raises(ValueError, match="bad config"):
        resilience.call_with_retry(fn, FAST)
    assert len(calls) == 1


def test_checkpoint_corrupt_fails_immediately():
    calls = []

    def fn():
        calls.append(1)
        raise stream.CheckpointCorruptError("bit rot")

    with pytest.raises(stream.CheckpointCorruptError):
        resilience.call_with_retry(fn, FAST)
    assert len(calls) == 1


def test_integrity_error_still_retries():
    """The digest-mismatch path must STAY retryable — corruption in
    transit is transient by definition."""
    calls = []

    def fn():
        calls.append(1)
        if len(calls) < 2:
            raise IntegrityError("digest mismatch")
        return "ok"

    out, attempts = resilience.call_with_retry(fn, FAST)
    assert out == "ok" and attempts == 2


def test_custom_exception_classes_override_default():
    """An empty deny tuple restores retry-everything; a custom allowlist
    excludes everything else."""
    calls = []

    def fn():
        calls.append(1)
        raise ValueError("flaky-but-declared-retryable")

    relaxed = RetryPolicy(max_attempts=2, base_delay=0.001,
                          non_retryable_exceptions=())
    with pytest.raises(RetryError):
        resilience.call_with_retry(fn, relaxed)
    assert len(calls) == 2

    strict = RetryPolicy(max_attempts=3, base_delay=0.001,
                         retryable_exceptions=(IntegrityError,),
                         non_retryable_exceptions=())
    calls.clear()

    def boom():
        calls.append(1)
        raise RuntimeError("not on the allowlist")

    with pytest.raises(RuntimeError, match="allowlist"):
        resilience.call_with_retry(boom, strict)
    assert len(calls) == 1


def test_policy_rejects_non_exception_tuples():
    with pytest.raises(ValueError, match="retryable_exceptions"):
        RetryPolicy(retryable_exceptions=("ValueError",))


def test_collector_degrades_on_non_retryable_shard(grid):
    """A shard whose job raises ValueError is recorded as lost with a
    non-retryable verdict after ONE attempt; the healthy shards still
    partial-aggregate."""
    data = _shard_data()
    jobs = geo.shard_ingest_jobs(grid, data, seed=0, rows=ROWS,
                                 log2_cols=LOG2_COLS, pool=POOL,
                                 chunk_size=PER_SHARD)
    poisoned = dict(jobs)

    def bad():
        raise ValueError("deterministic poison")

    poisoned[0] = bad
    res = resilience.collect_shards(poisoned, policy=FAST, verify=True)
    st = res.statuses[0]
    assert not st.ok and st.attempts == 1
    assert "non-retryable" in st.error and "ValueError" in st.error
    assert res.lost == (0,)
    assert res.n_ok == N_SHARDS - 1


# ------------------------------------------------- attempt latency capture
def test_attempt_seconds_recorded_per_attempt():
    calls = []

    def fn():
        calls.append(1)
        time.sleep(0.002)
        if len(calls) < 3:
            raise IntegrityError("again")
        return "ok"

    laps = []
    out, attempts = resilience.call_with_retry(
        fn, FAST, on_attempt=lambda a, s, e: laps.append((a, s, e)))
    assert attempts == 3 and len(laps) == 3
    assert [a for a, _, _ in laps] == [0, 1, 2]
    assert all(s >= 0.002 for _, s, _ in laps)
    assert laps[-1][2] is None and laps[0][2] is not None


def test_shard_status_carries_attempt_seconds(grid):
    data = _shard_data()
    plan = FaultPlan(seed=3, flaky=0.4)
    res = _extract(grid, data, faults=plan,
                   policy=RetryPolicy(max_attempts=4, base_delay=0.001))
    for st in res.statuses:
        if st.ok:
            assert len(st.attempt_seconds) == st.attempts
            assert all(s >= 0 for s in st.attempt_seconds)


def test_latency_histogram_buckets():
    h = resilience.latency_histogram([0.0005, 0.005, 0.5, 50.0, 0.002])
    assert len(h) == len(resilience.LATENCY_BUCKET_LABELS)
    assert h == [1, 2, 0, 1, 0, 1]
    assert sum(h) == 5
