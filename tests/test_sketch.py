"""Count Sketch: estimation accuracy, linearity (merge), update-path
equivalence, top-k recovery, ℓ₂ estimate.  Property tests via hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import sketch, u64


def _zipf_stream(n_items, n_distinct, seed=0, alpha=1.5):
    """Zipfian key stream (fat tail, like the paper's clustered data)."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, n_distinct + 1) ** alpha
    p /= p.sum()
    ids = rng.choice(n_distinct, size=n_items, p=p).astype(np.uint64)
    keys = ids * np.uint64(0x9E3779B97F4A7C15) + np.uint64(12345)  # spread
    hi = jnp.asarray((keys >> np.uint64(32)).astype(np.uint32))
    lo = jnp.asarray((keys & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    return hi, lo, ids, keys


def _exact_counts(ids, n_distinct):
    return np.bincount(ids.astype(np.int64), minlength=n_distinct)


def test_estimate_accuracy_heavy_items():
    hi, lo, ids, keys = _zipf_stream(50_000, 2_000, seed=1)
    sk = sketch.init(jax.random.key(0), rows=8, log2_cols=12)
    sk = sketch.update(sk, hi, lo)
    exact = _exact_counts(ids, 2_000)
    # query the 20 heaviest distinct keys
    top = np.argsort(exact)[::-1][:20]
    qk = top.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15) + np.uint64(12345)
    qhi = jnp.asarray((qk >> np.uint64(32)).astype(np.uint32))
    qlo = jnp.asarray((qk & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    est = np.asarray(sketch.estimate(sk, qhi, qlo))
    rel = np.abs(est - exact[top]) / exact[top]
    assert rel.max() < 0.05, f"relative error too high: {rel}"


def test_merge_linearity():
    """merge(sketch(A), sketch(B)) == sketch(A ++ B) exactly."""
    hi, lo, _, _ = _zipf_stream(10_000, 500, seed=2)
    sk0 = sketch.init(jax.random.key(1), rows=4, log2_cols=10)
    a = sketch.update(sk0, hi[:5000], lo[:5000])
    b = sketch.update(sk0, hi[5000:], lo[5000:])
    ab = sketch.merge(a, b)
    full = sketch.update(sk0, hi, lo)
    np.testing.assert_array_equal(np.asarray(ab.table), np.asarray(full.table))


def test_update_sorted_equivalent():
    hi, lo, _, _ = _zipf_stream(4_096, 300, seed=3)
    sk0 = sketch.init(jax.random.key(2), rows=4, log2_cols=10)
    a = sketch.update(sk0, hi, lo)
    b = sketch.update_sorted(sk0, hi, lo)
    np.testing.assert_allclose(np.asarray(a.table), np.asarray(b.table),
                               atol=1e-4)


def test_update_mask_and_values():
    hi, lo, _, _ = _zipf_stream(128, 50, seed=4)
    sk0 = sketch.init(jax.random.key(3), rows=4, log2_cols=8)
    v = jnp.arange(128, dtype=jnp.float32)
    m = jnp.arange(128) < 64
    a = sketch.update(sk0, hi, lo, values=v, mask=m)
    b = sketch.update(sk0, hi[:64], lo[:64], values=v[:64])
    np.testing.assert_allclose(np.asarray(a.table), np.asarray(b.table),
                               atol=1e-4)


@given(rows=st.integers(2, 8), log2_cols=st.integers(6, 12),
       seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_property_merge_commutes(rows, log2_cols, seed):
    hi, lo, _, _ = _zipf_stream(1_000, 100, seed=seed)
    sk0 = sketch.init(jax.random.key(seed), rows=rows, log2_cols=log2_cols)
    a = sketch.update(sk0, hi[:500], lo[:500])
    b = sketch.update(sk0, hi[500:], lo[500:])
    np.testing.assert_array_equal(
        np.asarray(sketch.merge(a, b).table),
        np.asarray(sketch.merge(b, a).table))


def test_l2_estimate():
    hi, lo, ids, _ = _zipf_stream(20_000, 1_000, seed=5)
    sk = sketch.init(jax.random.key(4), rows=16, log2_cols=12)
    sk = sketch.update(sk, hi, lo)
    exact_l2 = float(np.sqrt((_exact_counts(ids, 1_000) ** 2).sum()))
    est = float(sketch.l2_estimate(sk))
    assert abs(est - exact_l2) / exact_l2 < 0.15


def test_tensor_sketch_roundtrip_topk():
    """Gradient-compression primitive: heavy coordinates recoverable."""
    n = 4096
    rng = np.random.default_rng(6)
    g = rng.normal(scale=0.01, size=n).astype(np.float32)
    heavy_idx = rng.choice(n, 16, replace=False)
    g[heavy_idx] += np.sign(rng.normal(size=16)) * 5.0
    sk = sketch.init(jax.random.key(5), rows=8, log2_cols=10)
    sk = sketch.tensor_sketch_update(sk, jnp.asarray(g))
    est = np.asarray(sketch.tensor_sketch_estimate(sk, n))
    got = set(np.argsort(np.abs(est))[::-1][:16])
    assert len(got & set(heavy_idx)) >= 14   # recover nearly all heavy coords


def test_topk_from_candidates_dedupes():
    hi, lo, ids, keys = _zipf_stream(20_000, 500, seed=7)
    sk = sketch.init(jax.random.key(6), rows=8, log2_cols=12)
    sk = sketch.update(sk, hi, lo)
    exact = _exact_counts(ids, 500)
    top_true = set(np.argsort(exact)[::-1][:10])
    # candidates: top-30 true keys, each duplicated 3x
    cand_ids = np.repeat(np.argsort(exact)[::-1][:30], 3)
    ck = cand_ids.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15) + np.uint64(12345)
    chi = jnp.asarray((ck >> np.uint64(32)).astype(np.uint32))
    clo = jnp.asarray((ck & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    thi, tlo, test_ = sketch.topk_from_candidates(sk, chi, clo, 10)
    got_keys = set(u64.to_py((thi, tlo)).tolist())
    true_keys = {int(i) * 0x9E3779B97F4A7C15 + 12345 & 0xFFFFFFFFFFFFFFFF
                 for i in top_true}
    # no duplicates in output
    assert len(got_keys) == 10
    assert len(got_keys & true_keys) >= 9
