"""Property tests: u64 limb arithmetic must match numpy uint64 exactly."""
import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import u64

u64s = st.integers(min_value=0, max_value=2**64 - 1)
u32s = st.integers(min_value=0, max_value=2**32 - 1)


def _np64(x):
    return np.uint64(x & 0xFFFFFFFFFFFFFFFF)


@given(u64s, u64s)
@settings(max_examples=50, deadline=None)
def test_add(a, b):
    got = u64.to_py(u64.add(u64.from_py(a), u64.from_py(b)))
    assert got == _np64(a + b)


@given(u64s, u32s)
@settings(max_examples=50, deadline=None)
def test_add_u32(a, x):
    got = u64.to_py(u64.add_u32(u64.from_py(a), jnp.uint32(x)))
    assert got == _np64(a + x)


@given(u32s, u32s)
@settings(max_examples=50, deadline=None)
def test_umul32_full(x, y):
    got = u64.to_py(u64.umul32_full(jnp.uint32(x), jnp.uint32(y)))
    assert got == _np64(x * y)


@given(u64s, u32s)
@settings(max_examples=50, deadline=None)
def test_mul_u32(a, x):
    got = u64.to_py(u64.mul_u32(u64.from_py(a), jnp.uint32(x)))
    assert got == _np64(a * x)


@given(u64s, st.integers(min_value=0, max_value=63))
@settings(max_examples=50, deadline=None)
def test_shr_shl(a, s):
    assert u64.to_py(u64.shr(u64.from_py(a), s)) == _np64(a >> s)
    assert u64.to_py(u64.shl(u64.from_py(a), s)) == _np64(a << s)


@given(u64s, u64s)
@settings(max_examples=50, deadline=None)
def test_xor_eq_less(a, b):
    assert u64.to_py(u64.xor(u64.from_py(a), u64.from_py(b))) == _np64(a ^ b)
    assert bool(u64.eq(u64.from_py(a), u64.from_py(b))) == (a == b)
    assert bool(u64.less(u64.from_py(a), u64.from_py(b))) == (a < b)


def test_vectorized_shapes():
    a = u64.from_py(12345, shape=(4, 3))
    b = u64.from_py(2**63 + 17, shape=(4, 3))
    hi, lo = u64.add(a, b)
    assert hi.shape == (4, 3) and lo.shape == (4, 3)
    assert (u64.to_py((hi, lo)) == _np64(12345 + 2**63 + 17)).all()
