"""Hash family: determinism, range, empirical uniformity + independence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing


def _keys(n, seed=0):
    rng = np.random.default_rng(seed)
    k = rng.integers(0, 2**64, size=n, dtype=np.uint64)
    return (jnp.asarray((k >> np.uint64(32)).astype(np.uint32)),
            jnp.asarray((k & np.uint64(0xFFFFFFFF)).astype(np.uint32)))


def test_bucket_hash_range_and_determinism():
    params = hashing.make_params(jax.random.key(0), rows=4)
    hi, lo = _keys(1000)
    b1 = hashing.bucket_hash(params, hi, lo, log2_buckets=10)
    b2 = hashing.bucket_hash(params, hi, lo, log2_buckets=10)
    assert b1.shape == (4, 1000)
    assert (b1 == b2).all()
    assert int(b1.max()) < 1024 and int(b1.min()) >= 0


def test_bucket_hash_uniformity():
    """Chi-square-ish check: bucket occupancy close to uniform."""
    params = hashing.make_params(jax.random.key(1), rows=1)
    hi, lo = _keys(200_000, seed=1)
    b = np.asarray(hashing.bucket_hash(params, hi, lo, log2_buckets=8))[0]
    counts = np.bincount(b, minlength=256)
    expected = 200_000 / 256
    # Poisson std ≈ sqrt(expected) ≈ 28; allow 6 sigma
    assert np.abs(counts - expected).max() < 6 * np.sqrt(expected)


def test_sign_hash_balance_and_values():
    params = hashing.make_params(jax.random.key(2), rows=2)
    hi, lo = _keys(100_000, seed=2)
    s = np.asarray(hashing.sign_hash(params, hi, lo))
    assert set(np.unique(s)) <= {-1, 1}
    assert abs(s.mean()) < 0.02        # balanced


def test_rows_independent():
    params = hashing.make_params(jax.random.key(3), rows=2)
    hi, lo = _keys(50_000, seed=3)
    s = np.asarray(hashing.sign_hash(params, hi, lo)).astype(np.float64)
    corr = (s[0] * s[1]).mean()
    assert abs(corr) < 0.02


def test_pairwise_independence_empirical():
    """E[h(i)h(j)] ~ 0 for i != j (the AMS unbiasedness requirement)."""
    params = hashing.make_params(jax.random.key(4), rows=1)
    hi, lo = _keys(4096, seed=4)
    s = np.asarray(hashing.sign_hash(params, hi, lo))[0].astype(np.float64)
    outer = np.outer(s, s)
    off = outer[~np.eye(len(s), dtype=bool)]
    assert abs(off.mean()) < 0.02


def test_fold_u64_to_u32_deterministic():
    hi, lo = _keys(100)
    f1 = hashing.fold_u64_to_u32(hi, lo)
    f2 = hashing.fold_u64_to_u32(hi, lo)
    assert (f1 == f2).all()
