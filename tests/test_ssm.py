"""SSD correctness: chunked matmul form vs naive sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


def _naive_ssd(xh, dt, a_log, b, c, init_state=None):
    """Reference: step-by-step recurrence h_t = dA h + dt B x; y = C h."""
    bsz, s, h, p = xh.shape
    n = b.shape[-1]
    a = -np.exp(np.asarray(a_log, np.float64))
    state = np.zeros((bsz, h, p, n), np.float64) if init_state is None \
        else np.asarray(init_state, np.float64)
    xh = np.asarray(xh, np.float64)
    dt = np.asarray(dt, np.float64)
    b = np.asarray(b, np.float64)
    c = np.asarray(c, np.float64)
    ys = np.zeros_like(xh)
    for t in range(s):
        da = np.exp(dt[:, t, :] * a[None, :])              # (B, H)
        state = state * da[:, :, None, None] + \
            np.einsum("bhp,bn,bh->bhpn", xh[:, t], b[:, t], dt[:, t])
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, c[:, t])
    return ys, state


@pytest.mark.parametrize("bsz,s,h,p,n,chunk", [
    (2, 16, 3, 4, 8, 4),
    (1, 32, 2, 8, 16, 8),
    (2, 24, 4, 4, 4, 24),    # single chunk
])
def test_ssd_scan_matches_naive(bsz, s, h, p, n, chunk):
    rng = np.random.default_rng(0)
    xh = jnp.asarray(rng.normal(size=(bsz, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(bsz, s, h)).astype(np.float32))
    a_log = jnp.asarray(rng.uniform(-1, 1, size=(h,)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(bsz, s, n)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(bsz, s, n)).astype(np.float32))
    y, final = ssm.ssd_scan(xh, dt, a_log, b, c, chunk)
    y_ref, final_ref = _naive_ssd(xh, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), final_ref,
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_with_init_state_continues():
    """Processing [first half] then [second half w/ carried state] must equal
    one full pass — the streaming/prefill-chunking invariant."""
    rng = np.random.default_rng(1)
    bsz, s, h, p, n, chunk = 1, 32, 2, 4, 8, 8
    xh = jnp.asarray(rng.normal(size=(bsz, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(bsz, s, h)).astype(np.float32))
    a_log = jnp.asarray(rng.uniform(-1, 1, size=(h,)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(bsz, s, n)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(bsz, s, n)).astype(np.float32))
    y_full, st_full = ssm.ssd_scan(xh, dt, a_log, b, c, chunk)
    y1, st1 = ssm.ssd_scan(xh[:, :16], dt[:, :16], a_log, b[:, :16],
                           c[:, :16], chunk)
    y2, st2 = ssm.ssd_scan(xh[:, 16:], dt[:, 16:], a_log, b[:, 16:],
                           c[:, 16:], chunk, init_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-4, atol=1e-4)


def test_padded_heads_zero_contribution():
    """TP-padded SSD heads must not change the layer output."""
    key = jax.random.key(0)
    d_model, d_inner, n, conv_w = 32, 64, 8, 4
    real_heads, headdim = 4, 16
    x = jax.random.normal(jax.random.key(1), (2, 8, d_model), jnp.float32)
    p_exact = ssm.init_ssm(key, d_model, d_inner, n, real_heads, real_heads,
                           conv_w, jnp.float32)
    p_padded = ssm.init_ssm(key, d_model, d_inner, n, 8, real_heads,
                            conv_w, jnp.float32)
    y1, _ = ssm.ssm_forward(p_exact, x, heads=real_heads, n_state=n, chunk=8)
    y2, _ = ssm.ssm_forward(p_padded, x, heads=8, n_state=n, chunk=8)
    # padded lanes are zeroed at init => identical function up to the RNG
    # draws; compare only the *structure*: padded output must be finite and
    # the zero-lane property must hold
    assert np.isfinite(np.asarray(y2)).all()
    w_x = np.asarray(p_padded.w_x)
    assert (w_x[:, real_heads * headdim:] == 0).all()
    w_dt = np.asarray(p_padded.w_dt)
    assert (w_dt[:, real_heads:] == 0).all()
