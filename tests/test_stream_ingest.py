"""Streaming ingest engine: one-shot ↔ streaming equivalence contract.

Acceptance bar for the bounded sketch stage: on the same data (with a
candidate pool covering the distinct occupied cells) the streaming path
produces BIT-IDENTICAL heavy hitters (keys, counts, mask) to the one-shot
path — for the single-host pipeline and the mesh (`geo_extract_from_shards`)
path alike, over chunk sizes that do and do not divide N.  Plus the memory
regressions: the scanned mesh ingest allocates no buffer proportional to
num_batches·chunk, and one jitted ingest step is O(chunk + pool + sketch).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import candidates, geo, pipeline, quantize, stream
from repro.data.synthetic import MixtureSpec, gaussian_mixture

N = 4000
SPEC = MixtureSpec(dims=3, n_clusters=4, cluster_std=0.05,
                   background_frac=0.0)
# bins=4, D=3 -> at most 64 occupied cells << pool: the reservoir never
# evicts, so streaming must be EXACTLY the one-shot sketch stage.
CFG = pipeline.SnsConfig(bins=4, rows=8, log2_cols=10, top_k=32,
                         candidate_pool=96, ingest_chunk=512)


@pytest.fixture(scope="module")
def points():
    pts, _ = gaussian_mixture(N, SPEC, seed=1)
    return pts


@pytest.fixture(scope="module")
def oneshot(points):
    return pipeline.sketch_stage(CFG, jnp.asarray(points))


def _chunks(points, size):
    def factory():
        for s in range(0, len(points), size):
            yield points[s:s + size]
    return factory


def _assert_hh_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.key_hi), np.asarray(b.key_hi))
    np.testing.assert_array_equal(np.asarray(a.key_lo), np.asarray(b.key_lo))
    np.testing.assert_array_equal(np.asarray(a.count), np.asarray(b.count))
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))


# --------------------------------------------------- single-host equivalence
@pytest.mark.parametrize("chunk", [500, 4000, 333, 77])  # divides N & not
def test_streaming_matches_oneshot_single_host(points, oneshot, chunk):
    grid1, hh1 = oneshot
    grid2, hh2, total = pipeline.sketch_stage_streaming(
        CFG, _chunks(points, chunk))
    assert grid1 == grid2          # chunked min/max == full-array min/max
    assert total == float(N)
    _assert_hh_identical(hh1, hh2)


@given(chunk=st.integers(50, 700))
@settings(max_examples=8, deadline=None)
def test_streaming_matches_oneshot_property(chunk):
    pts, _ = gaussian_mixture(N, SPEC, seed=1)
    grid1, hh1 = pipeline.sketch_stage(CFG, jnp.asarray(pts))
    _, hh2, _ = pipeline.sketch_stage_streaming(CFG, _chunks(pts, chunk))
    _assert_hh_identical(hh1, hh2)


def test_sketch_stage_accepts_chunk_iterator(points, oneshot):
    """sketch_stage itself dispatches iterables to the streaming engine."""
    grid1, hh1 = oneshot
    grid2, hh2 = pipeline.sketch_stage(CFG, _chunks(points, 640))
    assert grid1 == grid2
    _assert_hh_identical(hh1, hh2)


def test_streaming_needs_reiterable_without_grid(points):
    gen = iter([points])           # one-shot iterator, no grid
    with pytest.raises(ValueError, match="re-iterable|one-shot"):
        pipeline.sketch_stage_streaming(CFG, gen)
    # with the grid supplied, a one-shot iterator is fine
    grid = quantize.fit_grid(jnp.asarray(points), CFG.bins)
    _, hh, total = pipeline.sketch_stage_streaming(CFG, iter([points]),
                                                   grid=grid)
    assert total == float(N)


# --------------------------------------------------------- mesh equivalence
@pytest.mark.parametrize("chunk,nb", [(500, 8), (640, 7)])  # 640·7 > N: mask
def test_streaming_matches_oneshot_mesh(points, chunk, nb):
    pts = jnp.asarray(points)
    mesh = jax.make_mesh((1,), ("data",))
    grid = quantize.fit_grid(pts, CFG.bins)
    res1 = geo.geo_extract(mesh, grid, pts, rows=CFG.rows,
                           log2_cols=CFG.log2_cols, top_k=CFG.top_k,
                           candidate_pool=CFG.candidate_pool, seed=CFG.seed)

    def shard_fn(idx, b):
        ids = b * chunk + jnp.arange(chunk)
        mask = ids < N
        return pts[jnp.minimum(ids, N - 1)], mask

    res2 = geo.geo_extract_from_shards(
        mesh, grid, shard_fn, rows=CFG.rows, log2_cols=CFG.log2_cols,
        top_k=CFG.top_k, candidate_pool=CFG.candidate_pool, seed=CFG.seed,
        num_batches=nb)
    # sketch linearity: scanned chunk updates == one update of everything
    np.testing.assert_array_equal(np.asarray(res1.merged.table),
                                  np.asarray(res2.merged.table))
    _assert_hh_identical(res1.hh, res2.hh)
    assert float(res2.total_count) == float(res1.total_count) == N


# ------------------------------------------------------- memory regressions
def _avals(jaxpr):
    from benchmarks.common import iter_jaxpr_avals
    return [a for a in iter_jaxpr_avals(jaxpr) if hasattr(a, "shape")]


def test_scanned_ingest_no_stream_buffer():
    """The scanned mesh ingest must not allocate any buffer proportional to
    num_batches·chunk (the old Python-unrolled loop concatenated all keys).
    Biggest legal buffer: the sketch table R·C."""
    chunk, nb = 256, 64
    mesh = jax.make_mesh((1,), ("data",))
    grid = quantize.GridSpec(dims=3, bins=4, lo=(0.0,) * 3, hi=(1.0,) * 3)

    def gen_fn(idx, b):
        k = jax.random.fold_in(jax.random.fold_in(jax.random.key(0), idx), b)
        return jax.random.uniform(k, (chunk, 3)), None

    def full():
        return geo.geo_extract_from_shards(
            mesh, grid, gen_fn, rows=4, log2_cols=8, top_k=8,
            candidate_pool=16, num_batches=nb)

    jaxpr = jax.make_jaxpr(full)()
    biggest = max(int(np.prod(a.shape, dtype=np.int64))
                  for a in _avals(jaxpr.jaxpr))
    assert biggest < nb * chunk, \
        f"O(stream) buffer in scanned ingest: {biggest} elems"
    assert biggest <= 4 * 256    # nothing beyond the sketch table

    # positive control: the one-shot mesh path DOES hold all N keys
    pts = jnp.zeros((nb * chunk, 3), jnp.float32)

    def oneshot():
        return geo.geo_extract(mesh, grid, pts, rows=4, log2_cols=8,
                               top_k=8, candidate_pool=16)

    jaxpr1 = jax.make_jaxpr(oneshot)()
    biggest1 = max(int(np.prod(a.shape, dtype=np.int64))
                   for a in _avals(jaxpr1.jaxpr))
    assert biggest1 >= nb * chunk


def test_ingest_step_peak_independent_of_stream():
    """One jitted ingest step is O(chunk + pool + R·C) — no N anywhere."""
    grid = quantize.GridSpec(dims=3, bins=4, lo=(0.0,) * 3, hi=(1.0,) * 3)
    state = stream.init(jax.random.key(0), 4, 8, 16)

    def step(st, pts, mask):
        return stream.ingest_step(st, grid, pts, mask=mask)

    jaxpr = jax.make_jaxpr(step)(state, jnp.zeros((512, 3)),
                                 jnp.ones((512,), bool))
    peak = max(int(np.prod(a.shape, dtype=np.int64)) * a.dtype.itemsize
               for a in _avals(jaxpr.jaxpr))
    # 4 rows x 512 items of int32 hashes is the biggest legal intermediate
    assert peak <= 4 * 512 * 4


# ----------------------------------------------------------- reservoir unit
def test_merge_topk_exact_when_under_capacity():
    rng = np.random.default_rng(0)
    ids = rng.integers(0, 12, size=200).astype(np.uint32)
    hi, lo = jnp.zeros(200, jnp.uint32), jnp.asarray(ids)
    whole = candidates.local_topk(hi, lo, 16)
    a = candidates.local_topk(hi[:77], lo[:77], 16)
    b = candidates.local_topk(hi[77:], lo[77:], 16)
    merged = a.merge_topk(b, 16)

    def as_dict(c):
        m = np.asarray(c.mask)
        return dict(zip(np.asarray(c.key_lo)[m].tolist(),
                        np.asarray(c.count)[m].tolist()))

    assert as_dict(whole) == as_dict(merged)


def test_merge_topk_identity():
    c = candidates.local_topk(jnp.zeros(8, jnp.uint32),
                              jnp.arange(8, dtype=jnp.uint32), 8)
    merged = c.merge_topk(candidates.empty(8), 8)
    np.testing.assert_array_equal(np.asarray(merged.count),
                                  np.asarray(c.count))
    assert int(merged.mask.sum()) == int(c.mask.sum())


def test_rechunk_order_and_mask():
    chunks = [np.full((3, 2), i, np.float32) for i in range(5)]  # 15 rows
    out = list(stream.rechunk(chunks, 4))
    assert len(out) == 4
    cat = np.concatenate([p[m] for p, m in out])
    np.testing.assert_array_equal(cat, np.concatenate(chunks))
    assert all(p.shape == (4, 2) for p, _ in out)
    assert int(out[-1][1].sum()) == 3          # ragged tail masked


def test_fit_grid_streaming_matches_fit_grid(points):
    g1 = quantize.fit_grid(jnp.asarray(points), 16)
    g2 = quantize.fit_grid_streaming(_chunks(points, 700), 16)
    assert g1 == g2                            # bit-identical corners
    with pytest.raises(ValueError, match="empty"):
        quantize.fit_grid_streaming([], 16)


def test_ingest_count_masks_padding(points):
    grid = quantize.fit_grid(jnp.asarray(points), CFG.bins)
    state = stream.init(jax.random.key(0), 4, 8, 16)
    state = stream.ingest_all(state, grid, _chunks(points, 999)(), 512)
    assert float(state.count) == float(N)      # pad rows not counted


# ------------------------------------------------------------- end to end
def test_run_streaming_end_to_end(points, oneshot):
    from repro.core.umap import UmapConfig
    cfg = pipeline.SnsConfig(bins=4, rows=8, log2_cols=10, top_k=32,
                             candidate_pool=96, ingest_chunk=512,
                             max_replicas=2, embedder="umap")
    res = pipeline.run_streaming(cfg, _chunks(points, 600),
                                 umap_cfg=UmapConfig(n_neighbors=5,
                                                     n_epochs=10))
    _assert_hh_identical(oneshot[1], res.hh)
    assert np.isfinite(np.asarray(res.embedding)).all()
    # coverage from the ingest count, not a resident array
    want = float(jnp.sum(res.hh.count)) / N
    assert res.coverage == pytest.approx(want, rel=1e-6)


def test_run_streaming_argument_validation(points):
    with pytest.raises(ValueError, match="chunk source"):
        pipeline.run_streaming(CFG)
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="shard_fn"):
        pipeline.run_streaming(CFG, mesh=mesh)
    with pytest.raises(ValueError, match="grid"):
        pipeline.run_streaming(CFG, mesh=mesh, shard_fn=lambda i, b: None)
    with pytest.raises(ValueError, match="single-host only"):
        pipeline.sketch_stage(CFG, _chunks(points, 500), mesh=mesh)


# ------------------------------------------------- checkpoint-resumed ingest
def test_checkpoint_resume_bit_identical(points, tmp_path):
    """save_state/load_state mid-stream, then continuing, must reproduce
    the unbroken run EXACTLY: same sketch table, same reservoir, same
    heavy hitters, same count — the resumability contract the online
    service's persistence rides on."""
    from repro.core import heavy_hitters as hh_mod
    grid = quantize.fit_grid(jnp.asarray(points), CFG.bins)
    parts = np.array_split(np.asarray(points), 3)

    def ingest(state, part):
        return stream.ingest_all(state, grid, [part], CFG.ingest_chunk)

    unbroken = stream.init(jax.random.key(CFG.seed), CFG.rows,
                           CFG.log2_cols, CFG.candidate_pool)
    for p in parts:
        unbroken = ingest(unbroken, p)

    broken = stream.init(jax.random.key(CFG.seed), CFG.rows,
                         CFG.log2_cols, CFG.candidate_pool)
    for i, p in enumerate(parts):
        broken = ingest(broken, p)
        ck = tmp_path / f"ck{i}"
        stream.save_state(broken, ck)
        broken = stream.load_state(ck)

    assert float(broken.count) == float(unbroken.count) == float(N)
    np.testing.assert_array_equal(np.asarray(broken.sketch.table),
                                  np.asarray(unbroken.sketch.table))
    hh_b = hh_mod.from_candidates(broken.sketch, broken.cands, CFG.top_k)
    hh_u = hh_mod.from_candidates(unbroken.sketch, unbroken.cands,
                                  CFG.top_k)
    _assert_hh_identical(hh_b, hh_u)


def test_checkpoint_resume_error_bound_monotone(points, tmp_path):
    """With a pool too small for the occupied cells the reservoir evicts;
    the space-saving watermark must be monotone non-decreasing across
    every save/load boundary (a reset watermark would understate the HH
    error after resume)."""
    cfg = pipeline.SnsConfig(bins=8, rows=8, log2_cols=10, top_k=8,
                             candidate_pool=16, ingest_chunk=512)
    grid = quantize.fit_grid(jnp.asarray(points), cfg.bins)
    state = stream.init(jax.random.key(cfg.seed), cfg.rows,
                        cfg.log2_cols, cfg.candidate_pool)
    bounds = []
    for i, part in enumerate(np.array_split(np.asarray(points), 5)):
        state = stream.ingest_all(state, grid, [part], cfg.ingest_chunk)
        bounds.append(float(stream.space_saving_bound(state)))
        ck = tmp_path / f"mb{i}"
        stream.save_state(state, ck)
        state = stream.load_state(ck)
        # the reloaded watermark is the saved one, bit-exact
        assert float(stream.space_saving_bound(state)) == bounds[-1]
    assert bounds == sorted(bounds)
    assert bounds[-1] > 0.0        # evictions actually happened


def test_save_state_extras_roundtrip(points, tmp_path):
    """The extra= side-channel (the service's cache persistence) must
    round-trip arrays exactly and stay invisible to plain load_state."""
    state = stream.init(jax.random.key(0), CFG.rows, CFG.log2_cols,
                        CFG.candidate_pool)
    extra = {"rep_y": np.arange(12, dtype=np.float32).reshape(6, 2),
             "pending": np.float64(123.0)}
    ck = tmp_path / "extras"
    stream.save_state(state, ck, extra=extra)
    plain = stream.load_state(ck)
    assert float(plain.count) == 0.0
    _, extras = stream.load_state(ck, with_extra=True)
    assert set(extras) == {"rep_y", "pending"}
    np.testing.assert_array_equal(extras["rep_y"], extra["rep_y"])
    assert float(extras["pending"]) == 123.0
    with pytest.raises(ValueError, match="non-empty"):
        stream.save_state(state, ck, extra={"": np.zeros(1)})
