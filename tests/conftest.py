"""Shared pytest config.

NOTE: do NOT set XLA_FLAGS / device-count here — smoke tests and benches
must see the single real CPU device.  Multi-device tests spawn subprocesses
that set the flag before importing jax (see test_geo.py, test_dryrun.py).
"""
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (subprocess compiles, dry-runs)")
