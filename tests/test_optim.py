"""Optimizers: AdamW/Adafactor convergence on a quadratic; Count-Sketch
gradient compression with error feedback converges and recovers heavy
coordinates; schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         AdafactorConfig, adafactor_init, adafactor_update,
                         SketchCompressConfig, sketch_compress_init,
                         compress_and_reduce, cosine_schedule, linear_warmup)


def _quadratic_problem(seed=0, n=256):
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))

    def loss(p):
        return 0.5 * jnp.sum((p["w"] - target) ** 2)
    params = {"w": jnp.zeros((n,), jnp.float32)}
    return loss, params, target


def test_adamw_converges():
    loss, params, target = _quadratic_problem()
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw_init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-2


def test_adamw_weight_decay_shrinks():
    params = {"w": jnp.full((8,), 10.0)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=0.0)
    state = adamw_init(params)
    g = {"w": jnp.zeros((8,))}
    params2, _, _ = adamw_update(g, state, params, cfg)
    assert float(params2["w"][0]) < 10.0


def test_adafactor_converges_matrix():
    rng = np.random.default_rng(1)
    target = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))

    def loss(p):
        return 0.5 * jnp.mean((p["w"] - target) ** 2)
    params = {"w": jnp.zeros((256, 256), jnp.float32)}
    cfg = AdafactorConfig(lr=0.3)
    state = adafactor_init(params, cfg)
    # factored stats: vr is (256,), vc is (256,) — not the full matrix
    assert state.vr["w"].shape == (256,)
    l0 = float(loss(params))
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = adafactor_update(g, state, params, cfg)
    assert float(loss(params)) < 0.1 * l0


def test_sketch_compression_recovers_heavy_and_converges():
    """Sparse-signal quadratic: sketch-compressed SGD must still converge,
    and per-round transmitted density stays ~top_k/n."""
    loss, params, target = _quadratic_problem(n=512)
    ccfg = SketchCompressConfig(rows=8, log2_cols=10, top_k=128,
                                momentum=0.0)
    cstate = sketch_compress_init(params, ccfg)
    lr = 0.5
    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        upd, cstate, density = compress_and_reduce(g, cstate, ccfg)
        params = jax.tree.map(lambda p, u: p - lr * u, params, upd)
        assert float(density) <= 128 / 512 + 1e-3
    assert float(loss(params)) < 0.01 * l0


def test_sketch_compression_error_feedback_accumulates():
    """Coordinates not transmitted this round are kept in the error buffer,
    not lost (the EF invariant: err + transmitted == mom + prev_err + est)."""
    n = 128
    params = {"w": jnp.zeros((n,), jnp.float32)}
    ccfg = SketchCompressConfig(rows=8, log2_cols=10, top_k=4, momentum=0.0)
    cstate = sketch_compress_init(params, ccfg)
    g = {"w": jnp.asarray(np.linspace(1.0, 2.0, n).astype(np.float32))}
    upd, cstate2, _ = compress_and_reduce(g, cstate, ccfg)
    sent = np.asarray(upd["w"])
    err = np.asarray(cstate2.error["w"])
    # the sum of (sent + err) must approximate the sketch ESTIMATE of g
    # (within CS estimation error), and exactly 4 coords were sent
    assert (np.abs(sent) > 0).sum() == 4
    np.testing.assert_allclose(sent + err, np.asarray(g["w"]),
                               atol=0.35)   # CS estimate noise bound


def test_schedules():
    assert float(linear_warmup(0, 10, 1.0)) < 0.2
    assert float(linear_warmup(9, 10, 1.0)) == 1.0
    s = [float(cosine_schedule(t, 10, 100, 1.0)) for t in (0, 10, 55, 99)]
    assert s[0] < s[1] and s[1] > s[2] > s[3]
