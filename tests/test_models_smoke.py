"""Per-arch smoke tests: reduced config, one train step + prefill/decode on
CPU; assert output shapes, finite losses, no NaNs, loss decreases over a
few steps for one representative arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as model_mod
from repro.train.steps import (TrainStepConfig, init_train_state,
                               make_train_step, make_prefill_step,
                               make_decode_step)

SEQ = 32
BATCH = 2
TCFG = TrainStepConfig(q_chunk=16, remat=True, optimizer="adamw")


def _batch(cfg, key, batch=BATCH, seq=SEQ):
    ks = jax.random.split(key, 3)
    text_len = seq - (cfg.num_prefix if cfg.frontend == "vision" else 0)
    b = {
        "tokens": jax.random.randint(ks[0], (batch, text_len), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (batch, text_len), 0,
                                     cfg.vocab_size),
        "loss_mask": jnp.ones((batch, text_len), jnp.float32),
    }
    if cfg.frontend == "vision":
        b["patch_embeds"] = 0.02 * jax.random.normal(
            ks[2], (batch, cfg.num_prefix, cfg.d_model), cfg.pdtype)
    if cfg.encoder_layers:
        b["src_embeds"] = 0.02 * jax.random.normal(
            ks[2], (batch, seq, cfg.d_model), cfg.pdtype)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.key(0)
    state = init_train_state(key, cfg, TCFG)
    step = jax.jit(make_train_step(cfg, TCFG))
    batch = _batch(cfg, jax.random.key(1))
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss {loss}"
    # ln(vocab) ballpark for random init
    assert 0.5 * np.log(cfg.vocab_size) < loss < 3.0 * np.log(cfg.vocab_size)
    for path, leaf in jax.tree_util.tree_leaves_with_path(state["params"]):
        assert not np.isnan(np.asarray(leaf, np.float32)).any(), \
            f"{arch}: NaN in {path}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = model_mod.init_params(jax.random.key(0), cfg)
    cache_len = SEQ + 8
    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    decode = jax.jit(make_decode_step(cfg))
    batch = _batch(cfg, jax.random.key(1))
    logits, state = prefill(params, batch)
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: prefill NaN"
    expect_pos = SEQ if cfg.frontend != "vision" else SEQ
    assert int(state["pos"]) == expect_pos
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, state = decode(params, tok, state)
        assert logits.shape == (BATCH, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch}: decode NaN"
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    assert int(state["pos"]) == expect_pos + 3


def test_loss_decreases_dense():
    """A few steps on fixed data must reduce the loss (learning sanity)."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    tcfg = TrainStepConfig(q_chunk=16, peak_lr=1e-2, warmup_steps=1,
                           total_steps=100)
    state = init_train_state(jax.random.key(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, jax.random.key(1))
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_decode_matches_prefill_logits():
    """Teacher-forced decode must reproduce prefill's next-token logits
    (cache correctness, incl. rope offsets)."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = model_mod.init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (1, 8), 0, cfg.vocab_size)
    cache_len = 16
    # full prefill over 8 tokens
    prefill = make_prefill_step(cfg, cache_len)
    logits_full, _ = prefill(params, {"tokens": tokens})
    # prefill over 7 then decode token 8
    logits_7, st = prefill(params, {"tokens": tokens[:, :7]})
    decode = make_decode_step(cfg)
    logits_step, _ = decode(params, tokens[:, 7:8], st)
    np.testing.assert_allclose(np.asarray(logits_step),
                               np.asarray(logits_full),
                               rtol=2e-2, atol=2e-2)


def test_ssm_decode_matches_forward():
    """Mamba2: token-by-token decode equals chunked SSD forward."""
    cfg = get_config("mamba2-130m", smoke=True)
    params = model_mod.init_params(jax.random.key(0), cfg)
    s = 8
    tokens = jax.random.randint(jax.random.key(1), (1, s), 0, cfg.vocab_size)
    prefill = make_prefill_step(cfg, s)
    logits_full, _ = prefill(params, {"tokens": tokens})
    # decode token-by-token from scratch
    state = model_mod.init_decode_state(cfg, 1, s)
    decode = make_decode_step(cfg)
    for i in range(s):
        logits, state = decode(params, tokens[:, i:i + 1], state)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_full),
                               rtol=3e-2, atol=3e-2)
