"""Scatter-free UMAP epoch engine: equivalence, jaxpr contract, wiring.

The epoch rewrite swaps the two `.at[].add` scatters per epoch for the
shared sorted-COO cumsum reduction (repro.core.coo) — the contract is:

* equivalence — against the PR-4 scatter epoch, FROZEN verbatim in
  benchmarks/bench_embed_throughput.py, the full optimizer trajectory
  matches to fp tolerance for the same key (the fuzzy-set edge list is
  src-sorted, so the stable setup sort preserves edge order and the
  per-edge negative-sample stream lines up draw for draw);
* cost — the epoch-loop jaxpr carries ZERO scatter primitives and no
  (N, N)- or (E, N)-sized buffer (the biggest temp is the (E, R, dims)
  negative-sample block);
* shared core — repro.core.coo reduces arbitrary src/dst multisets
  correctly (property-tested against np.add.at);
* wiring — SnsConfig.embed_block reaches UmapConfig.block through
  pipeline.embed_stage (regression: the knob that bounds kNN memory).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from benchmarks.bench_embed_throughput import (synthetic_umap_edges,
                                               umap_scatter_epoch_delta)
from benchmarks.common import count_primitive, iter_jaxpr_avals
from repro.core import coo, pipeline, umap


# ------------------------------------------------------------- shared core
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 60),
       e=st.integers(1, 300))
@settings(max_examples=25, deadline=None)
def test_coo_segment_reduce_matches_scatter(seed, n, e):
    """edge_layout + segment_reduce == np.add.at on both endpoints, for
    arbitrary (unsorted, duplicate-heavy) edge multisets."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    vals = rng.normal(size=(e, 2)).astype(np.float32)
    layout, order = coo.edge_layout(jnp.asarray(src), jnp.asarray(dst), n)
    v = jnp.asarray(vals)[order]
    by_src = np.asarray(coo.segment_reduce(v, layout.src_bounds))
    by_dst = np.asarray(coo.segment_reduce(v[layout.dst_order],
                                           layout.dst_bounds))
    ref_src = np.zeros((n, 2), np.float64)
    ref_dst = np.zeros((n, 2), np.float64)
    np.add.at(ref_src, src, vals.astype(np.float64))
    np.add.at(ref_dst, dst, vals.astype(np.float64))
    scale = max(1.0, np.abs(ref_src).max(), np.abs(ref_dst).max())
    assert np.abs(by_src - ref_src).max() <= 1e-4 * scale
    assert np.abs(by_dst - ref_dst).max() <= 1e-4 * scale


def test_edge_layout_stable_on_sorted_input():
    """A src-sorted edge list must keep its order (identity permutation) —
    this is what aligns the per-edge RNG stream with the frozen baseline."""
    n, k = 40, 4
    rng = np.random.default_rng(3)
    edges, _ = synthetic_umap_edges(n, k, rng)
    layout, order = coo.edge_layout(edges[:, 0], edges[:, 1], n)
    np.testing.assert_array_equal(np.asarray(order), np.arange(n * k))
    np.testing.assert_array_equal(np.asarray(layout.src),
                                  np.asarray(edges[:, 0]))
    np.testing.assert_array_equal(np.asarray(layout.dst),
                                  np.asarray(edges[:, 1]))


# ------------------------------------------------------- epoch equivalence
@pytest.mark.parametrize("seed,n,k", [(0, 64, 4), (1, 128, 7), (2, 31, 3)])
def test_scatter_free_epoch_matches_frozen_scatter_along_trajectory(seed, n,
                                                                    k):
    """At EVERY state the optimizer visits, the scatter-free epoch delta
    equals the frozen PR-4 scatter delta for the same negative-sample key
    (identical draws — the src-sorted edge list keeps edge order, so only
    the reduction's summation order differs).  Compared per epoch rather
    than at the trajectory's end: the SGD dynamics amplify fp noise
    through the near-singular 1/(0.001+d²) repulsion, so end-state
    agreement is not a well-posed contract, per-step agreement is."""
    rng = np.random.default_rng(seed)
    edges, memb = synthetic_umap_edges(n, k, rng)
    cfg = umap.UmapConfig(n_epochs=12, neg_rate=5, learning_rate=1.0)
    a, b = umap.fit_ab(cfg.spread, cfg.min_dist)
    memb_n = memb / jnp.maximum(jnp.max(memb), 1e-12)
    layout, order = coo.edge_layout(edges[:, 0], edges[:, 1], n)
    memb_s = memb_n[order]
    src, dst = edges[:, 0], edges[:, 1]
    y = jnp.asarray(rng.normal(size=(n, cfg.dims)).astype(np.float32))
    kloop = jax.random.key(seed)
    for i in range(cfg.n_epochs):
        kloop, kneg = jax.random.split(kloop)
        scat = umap_scatter_epoch_delta(y, kneg, src, dst, memb_n, a, b,
                                        cfg.neg_rate)
        free = umap.epoch_delta(y, layout, memb_s, kneg, a, b, cfg.neg_rate)
        err = float(jnp.max(jnp.abs(free - scat)))
        scale = max(1.0, float(jnp.max(jnp.abs(scat))))
        assert err <= 1e-4 * scale, f"epoch {i}: delta err {err}"
        alpha = cfg.learning_rate * (1.0 - i / cfg.n_epochs)
        y = y + alpha * scat


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(8, 150),
       k=st.integers(1, 8), neg_rate=st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_single_epoch_delta_matches_scatter_delta(seed, n, k, neg_rate):
    """Property: one epoch delta, same kneg — scatter-free == scatter to
    fp tolerance for arbitrary edge geometry and negative-sample rate."""
    k = min(k, n - 1)
    rng = np.random.default_rng(seed)
    edges, memb = synthetic_umap_edges(n, k, rng)
    a, b = umap.fit_ab(1.0, 0.1)
    memb_n = memb / jnp.maximum(jnp.max(memb), 1e-12)
    layout, order = coo.edge_layout(edges[:, 0], edges[:, 1], n)
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    kneg = jax.random.key(seed)
    free = np.asarray(umap.epoch_delta(y, layout, memb_n[order], kneg,
                                       a, b, neg_rate))
    scat = np.asarray(umap_scatter_epoch_delta(y, kneg, edges[:, 0],
                                               edges[:, 1], memb_n, a, b,
                                               neg_rate))
    assert np.abs(free - scat).max() <= 1e-4 * max(1.0, np.abs(scat).max())


# --------------------------------------------------------------- cost model
def test_umap_epoch_jaxpr_scatter_free_and_subquadratic():
    """The jitted optimizer (setup + epoch fori_loop): ZERO scatter
    primitives of any flavour, and no (N, N)/(E, N) buffer — the biggest
    temp is the (E, neg_rate, dims) negative-sample block."""
    n, k = 1024, 8
    rng = np.random.default_rng(10)
    edges, memb = synthetic_umap_edges(n, k, rng)
    cfg = umap.UmapConfig(n_epochs=5)

    def full(edges_, memb_):
        return umap.optimize_embedding(jax.random.key(0), edges_, memb_,
                                       n, cfg)

    jaxpr = jax.make_jaxpr(full)(edges, memb)
    for prim in ("scatter-add", "scatter", "scatter-mul", "scatter-max"):
        assert count_primitive(jaxpr.jaxpr, prim) == 0, \
            f"{prim} in the scatter-free epoch engine"
    e = n * k
    biggest = max(
        int(np.prod(a.shape, dtype=np.int64))
        for a in iter_jaxpr_avals(jaxpr.jaxpr) if hasattr(a, "shape"))
    assert biggest <= e * cfg.neg_rate * cfg.dims, \
        f"buffer of {biggest} elems beyond the negative-sample block"
    assert biggest < n * n // 8, f"buffer of {biggest} elems ~ O(N²)"
    assert biggest < e * n // 8, f"buffer of {biggest} elems ~ O(E·N)"


# ------------------------------------------------------------------- wiring
def test_embed_stage_wires_embed_block_into_umap_cfg(monkeypatch):
    """SnsConfig.embed_block must reach UmapConfig.block (it bounds the
    kNN row-block — the knob that keeps the graph build O(block·N))."""
    seen = {}

    def fake_run_umap(key, x, cfg, weights=None, mesh=None):
        seen["cfg"] = cfg
        return jnp.zeros((x.shape[0], cfg.dims))

    monkeypatch.setattr(pipeline.umap_mod, "run_umap", fake_run_umap)
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.uniform(0, 1, size=(512, 3)).astype(np.float32))
    cfg = pipeline.SnsConfig(bins=8, rows=4, log2_cols=10, top_k=32,
                             embedder="umap", embed_block=123)
    grid, hh = pipeline.sketch_stage(cfg, pts)
    pipeline.embed_stage(cfg, grid, hh)
    assert seen["cfg"].block == 123


def test_embed_stage_wires_adaptive_grid_into_tsne_cfg(monkeypatch):
    """The new adaptive-grid / CIC knobs must reach TsneConfig too."""
    seen = {}

    def fake_run_tsne(key, x, cfg, weights=None, backend=None, mesh=None):
        seen["cfg"] = cfg
        return jnp.zeros((x.shape[0], cfg.dims)), jnp.zeros((cfg.n_iter,))

    monkeypatch.setattr(pipeline.tsne_mod, "run_tsne", fake_run_tsne)
    rng = np.random.default_rng(1)
    pts = jnp.asarray(rng.uniform(0, 1, size=(512, 3)).astype(np.float32))
    cfg = pipeline.SnsConfig(bins=8, rows=4, log2_cols=10, top_k=32,
                             embedder="tsne", embed_backend="sparse",
                             embed_grid=64, embed_grid_interval=0.25,
                             embed_grid_max=512, embed_cic="pallas")
    grid, hh = pipeline.sketch_stage(cfg, pts)
    pipeline.embed_stage(cfg, grid, hh)
    tc = seen["cfg"]
    assert (tc.grid_size, tc.grid_interval, tc.grid_max, tc.cic) == \
        (64, 0.25, 512, "pallas")


def test_run_umap_end_to_end_stays_scatter_free_on_blobs():
    """Sanity: the rewritten engine still embeds structure (fast check —
    the full quality contract lives in test_umap.py's slow blob test)."""
    rng = np.random.default_rng(5)
    x = np.concatenate([
        rng.normal(size=(40, 4)).astype(np.float32) * 0.05,
        rng.normal(size=(40, 4)).astype(np.float32) * 0.05 + 3.0])
    cfg = umap.UmapConfig(n_neighbors=8, n_epochs=80)
    y = np.asarray(umap.run_umap(jax.random.key(0), jnp.asarray(x), cfg))
    assert np.isfinite(y).all()
    gap = np.linalg.norm(y[:40].mean(0) - y[40:].mean(0))
    intra = max(np.linalg.norm(y[:40] - y[:40].mean(0), axis=1).mean(),
                np.linalg.norm(y[40:] - y[40:].mean(0), axis=1).mean())
    assert gap > 1.5 * intra
