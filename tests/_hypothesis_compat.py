"""Import hypothesis if available; otherwise degrade property tests to skips.

Usage in test modules:

    from _hypothesis_compat import given, settings, st

When hypothesis is installed this re-exports the real API unchanged.  When
it is absent (minimal containers), ``@given(...)`` marks the test as
skipped with a clear reason instead of crashing the whole module at
collection, and ``st.<anything>(...)`` returns inert stand-in strategy
objects so module-level strategy definitions still evaluate.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _StubStrategy:
        """Inert strategy: chainable, callable, composable — never drawn."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _StubStrategies:
        def __getattr__(self, name):
            return _StubStrategy()

    st = _StubStrategies()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed — property test skipped")(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
