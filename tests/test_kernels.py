"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing, quantize, sketch as sketch_mod
from repro.kernels import ops, ref


def _points(n, d, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, 1, size=(n, d)).astype(np.float32))


def _grid(d, bins=16):
    return quantize.GridSpec(dims=d, bins=bins,
                             lo=np.zeros(d, np.float32),
                             hi=np.ones(d, np.float32))


# ---------------------------------------------------------------- hash_points
@pytest.mark.parametrize("n,d,rows,l2c,block", [
    (256, 4, 4, 10, 128),
    (1000, 8, 8, 14, 256),     # non-multiple of block -> padding path
    (512, 2, 16, 18, 512),
    (64, 12, 2, 6, 64),
])
def test_hash_points_matches_ref(n, d, rows, l2c, block):
    params = hashing.make_params(jax.random.key(0), rows)
    grid = _grid(d)
    pts = _points(n, d)
    kb, ks = ops.hash_points(params, grid, pts, l2c, block_items=block)
    rb, rs = ref.hash_points(params, grid, pts, l2c)
    np.testing.assert_array_equal(np.asarray(kb), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))


# -------------------------------------------------------------- sketch_update
@pytest.mark.parametrize("n,rows,l2c,block,weighted", [
    (512, 4, 10, 256, False),
    (700, 8, 12, 256, True),    # padding path + weighted
    (256, 16, 8, 128, False),
    (128, 2, 16, 128, True),    # C at the kernel-path limit
])
def test_sketch_update_fused_matches_update(n, rows, l2c, block, weighted):
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 2**64, size=n, dtype=np.uint64)
    hi = jnp.asarray((keys >> np.uint64(32)).astype(np.uint32))
    lo = jnp.asarray((keys & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    v = jnp.asarray(rng.normal(size=n).astype(np.float32)) if weighted else None
    sk0 = sketch_mod.init(jax.random.key(2), rows, l2c)
    a = ops.sketch_update_fused(sk0, hi, lo, values=v, block_items=block)
    b = sketch_mod.update(sk0, hi, lo, values=v)
    np.testing.assert_allclose(np.asarray(a.table), np.asarray(b.table),
                               atol=1e-4)


def test_sketch_update_fused_rejects_huge_table():
    sk = sketch_mod.init(jax.random.key(0), 4, 18)
    with pytest.raises(ValueError):
        ops.sketch_update_fused(sk, jnp.zeros(4, jnp.uint32),
                                jnp.zeros(4, jnp.uint32))


# ------------------------------------------------------------ sketch_estimate
@pytest.mark.parametrize("n_stream,q,rows,l2c,bq,bc", [
    (5000, 256, 4, 10, 128, 256),
    (5000, 300, 8, 12, 128, 512),   # query padding path
    (2000, 64, 16, 10, 64, 128),
])
def test_sketch_estimate_mxu_matches_estimate(n_stream, q, rows, l2c, bq, bc):
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 2**32, size=n_stream, dtype=np.uint64)  # collisions
    hi = jnp.asarray((keys >> np.uint64(32)).astype(np.uint32))
    lo = jnp.asarray((keys & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    sk = sketch_mod.init(jax.random.key(4), rows, l2c)
    sk = sketch_mod.update(sk, hi, lo)
    qk = keys[rng.choice(n_stream, q, replace=False)]
    qhi = jnp.asarray((qk >> np.uint64(32)).astype(np.uint32))
    qlo = jnp.asarray((qk & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    got = ops.sketch_estimate_mxu(sk, qhi, qlo, block_q=bq, block_c=bc)
    want = sketch_mod.estimate(sk, qhi, qlo)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


# ----------------------------------------------------------------- tsne fused
@pytest.mark.parametrize("n,dh,block,exag", [
    (256, 4, 128, 1.0),
    (300, 8, 128, 4.0),        # padding path + exaggeration
    (128, 2, 64, 12.0),
])
def test_tsne_forces_fused_matches_ref(n, dh, block, exag):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(n, dh)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    beta = jnp.asarray(rng.uniform(0.5, 2.0, size=n).astype(np.float32))
    zp = ref.tsne_zp(x, beta)
    z = ref.tsne_z(y)
    want = ref.tsne_forces(x, y, beta, zp, z, exaggeration=exag)
    got = ops.tsne_step_fused(x, y, beta, zp, exaggeration=exag, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_tsne_z_kernel_matches_ref():
    rng = np.random.default_rng(6)
    y = jnp.asarray(rng.normal(size=(384, 2)).astype(np.float32))
    from repro.kernels import tsne_forces as tf
    got = tf.tsne_z(y, block=128)
    np.testing.assert_allclose(float(got), float(ref.tsne_z(y)), rtol=1e-5)


# ------------------------------------------------------------------- cic tile
@pytest.mark.parametrize("n,g,block", [
    (512, 32, 256),
    (700, 64, 256),            # non-multiple of block -> padding path
    (128, 16, 128),
])
def test_cic_splat_gather_match_xla_loop(n, g, block):
    """One-hot matmul splat/gather vs the XLA 4-corner scatter/gather."""
    from repro.core import tsne as tsne_mod
    rng = np.random.default_rng(7)
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32) * 2.0)
    i0, f, _ = tsne_mod._cic_weights(y, g)
    vals = jnp.asarray(rng.uniform(0.5, 2.0, size=(n, 3)).astype(np.float32))
    got = ops.cic_splat(i0, f, vals, g, block_items=block, interpret=True)
    w = tsne_mod._corner_weights(f)
    want = jnp.zeros((3, g, g), jnp.float32)
    for ci, (dx, dy) in enumerate(tsne_mod._CORNERS):
        want = want.at[:, i0[:, 0] + dx, i0[:, 1] + dy].add(
            vals.T * w[ci][None, :])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    # gather the splatted fields back at the same points
    got_g = ops.cic_gather(got, i0, f, block_items=block, interpret=True)
    acc = []
    for c in range(3):
        a = 0.0
        for ci, (dx, dy) in enumerate(tsne_mod._CORNERS):
            a += want[c, i0[:, 0] + dx, i0[:, 1] + dy] * w[ci]
        acc.append(a)
    want_g = jnp.stack(acc, axis=1)
    np.testing.assert_allclose(np.asarray(got_g), np.asarray(want_g),
                               rtol=1e-5, atol=1e-4)


def test_fft_repulsion_pallas_cic_matches_xla():
    """The full repulsion pass agrees across CIC dispatch paths."""
    from repro.core import tsne as tsne_mod
    rng = np.random.default_rng(8)
    y = jnp.asarray(rng.normal(size=(400, 2)).astype(np.float32) * 3.0)
    rx, zx = tsne_mod.fft_repulsion(y, 64, cic="xla")
    rp, zp = tsne_mod.fft_repulsion(y, 64, cic="pallas", interpret=True)
    scale = float(jnp.max(jnp.abs(rx)))
    assert float(jnp.max(jnp.abs(rx - rp))) <= 1e-4 * max(scale, 1.0)
    assert abs(float(zx) - float(zp)) <= 1e-4 * float(zx)
