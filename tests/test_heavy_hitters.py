"""Heavy-hitter extraction: local exact top-k, global recovery, oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import candidates, heavy_hitters, sketch, u64


def _stream(n, n_distinct, seed=0, alpha=1.6):
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, n_distinct + 1) ** alpha
    p /= p.sum()
    ids = rng.choice(n_distinct, size=n, p=p)
    keys = ids.astype(np.uint64) * np.uint64(0x2545F4914F6CDD1D) + np.uint64(7)
    hi = jnp.asarray((keys >> np.uint64(32)).astype(np.uint32))
    lo = jnp.asarray((keys & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    return hi, lo, ids


def test_local_topk_exact():
    hi, lo, ids = _stream(8192, 200, seed=0)
    exact = np.bincount(ids, minlength=200)
    c = candidates.local_topk(hi, lo, k=16)
    got = u64.to_py((c.key_hi, c.key_lo))
    true_order = np.argsort(exact)[::-1]
    true_keys = (true_order[:16].astype(np.uint64)
                 * np.uint64(0x2545F4914F6CDD1D) + np.uint64(7))
    # counts must match exactly for the keys returned
    top_counts = np.sort(np.asarray(c.count))[::-1]
    np.testing.assert_array_equal(top_counts,
                                  np.sort(exact[true_order[:16]])[::-1])
    assert set(got.tolist()) == set(true_keys.tolist())
    assert bool(c.mask.all())


def test_local_topk_fewer_distinct_than_k():
    hi, lo, ids = _stream(256, 5, seed=1)
    c = candidates.local_topk(hi, lo, k=16)
    assert int(c.mask.sum()) == 5
    assert float(c.count.sum()) == 256.0   # all mass accounted for


def test_local_topk_k_exceeds_items():
    """Regression: k > n used to crash in lax.top_k (geo.geo_extract passes
    pool=2*top_k unguarded, so a shard smaller than the pool blew up).
    Now the selection clamps to n and pads the output to k."""
    hi, lo, ids = _stream(8, 4, seed=9)
    c = candidates.local_topk(hi, lo, k=32)
    assert c.key_hi.shape == (32,)
    assert int(c.mask.sum()) == len(set(ids.tolist()))
    assert float(c.count.sum()) == 8.0
    # padding is inert: invalid key, zero count
    pad = ~np.asarray(c.mask)
    assert (np.asarray(c.key_hi)[pad] == 0xFFFFFFFF).all()
    assert (np.asarray(c.count)[pad] == 0).all()


def test_geo_extract_shard_smaller_than_pool():
    """End-to-end regression for the same crash: a tiny stream through
    geo.geo_extract with the default pool = 2*top_k > n."""
    import jax
    from repro.core import geo, quantize

    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.uniform(0, 1, (48, 3)).astype(np.float32))
    mesh = jax.make_mesh((1,), ("data",))
    grid = quantize.fit_grid(pts, 4)
    res = geo.geo_extract(mesh, grid, pts, rows=4, log2_cols=8,
                          top_k=64)          # pool=128 > 48 items
    assert int(res.total_count) == 48
    assert int(np.asarray(res.hh.mask).sum()) <= 48


def test_extract_single_shard():
    hi, lo, ids = _stream(50_000, 1_000, seed=2)
    sk = sketch.init(jax.random.key(0), rows=8, log2_cols=12)
    sk = sketch.update(sk, hi, lo)
    hh = heavy_hitters.extract(sk, hi, lo, k=20, candidate_pool=64)
    exact = np.bincount(ids, minlength=1_000)
    true_top = np.argsort(exact)[::-1][:20]
    true_keys = set((true_top.astype(np.uint64)
                     * np.uint64(0x2545F4914F6CDD1D) + np.uint64(7)).tolist())
    got = set(u64.to_py((hh.key_hi, hh.key_lo))[np.asarray(hh.mask)].tolist())
    assert len(got & true_keys) >= 18
    # counts sorted descending
    cnt = np.asarray(hh.count)
    assert (np.diff(cnt) <= 1e-6).all()


def test_exact_counts_oracle():
    hi, lo, ids = _stream(1000, 50, seed=3)
    exact = np.bincount(ids, minlength=50)
    q = np.arange(50)
    qk = q.astype(np.uint64) * np.uint64(0x2545F4914F6CDD1D) + np.uint64(7)
    qhi = jnp.asarray((qk >> np.uint64(32)).astype(np.uint32))
    qlo = jnp.asarray((qk & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    got = np.asarray(heavy_hitters.exact_counts(hi, lo, qhi, qlo))
    np.testing.assert_array_equal(got, exact)


def test_candidates_concat():
    hi, lo, _ = _stream(512, 20, seed=4)
    a = candidates.local_topk(hi[:256], lo[:256], k=8)
    b = candidates.local_topk(hi[256:], lo[256:], k=8)
    c = candidates.concat(a, b)
    assert c.key_hi.shape == (16,)
    assert float(c.count.sum()) == float(a.count.sum()) + float(b.count.sum())


def test_candidate_pool_recall_bound():
    """Candidate-pool sizing (§Perf Cell C): with i.i.d. shards, the union
    of per-shard top-p lists covers ≈ the global top-p keys, NOT
    shards×p distinct keys — a key of global rank r sits near local rank
    r on EVERY shard.  So per-shard pool must be ≥ ~1.5·top_k for full
    recall; pool < top_k provably loses the tail.  This test pins both
    sides of that bound (it caught an unsafe pool claim during §Perf)."""
    import jax
    from repro.core import candidates as cand_mod
    from repro.core import sketch as sketch_mod

    n_shards, per_shard, k = 8, 20_000, 64
    sk0 = sketch_mod.init(jax.random.key(0), rows=8, log2_cols=12)
    merged = sk0
    pools = {"unsafe": 24, "safe": int(1.5 * k) + 8}
    cands = {name: [] for name in pools}
    full_ids = []
    for w in range(n_shards):
        hi, lo, ids = _stream(per_shard, 2_000, seed=100 + w)
        full_ids.append(ids)
        sk_w = sketch_mod.update_sorted(sk0, hi, lo)
        merged = sketch_mod.merge(merged, sk_w) if w else sk_w
        for name, p in pools.items():
            cands[name].append(cand_mod.local_topk(hi, lo, k=p))
    exact = np.bincount(np.concatenate(full_ids), minlength=2_000)
    true_top = set(np.argsort(exact)[::-1][:k].tolist())
    true_keys = {int(i) * 0x2545F4914F6CDD1D + 7 & 0xFFFFFFFFFFFFFFFF
                 for i in true_top}

    def recover(cands_list):
        c = candidates.concat(*cands_list)
        hh = heavy_hitters.from_candidates(merged, c, k)
        got = u64.to_py((hh.key_hi, hh.key_lo))[np.asarray(hh.mask)]
        return sum(int(g) in true_keys for g in got)

    rec_unsafe = recover(cands["unsafe"])
    rec_safe = recover(cands["safe"])
    assert rec_safe >= 0.92 * k          # pool ≥ 1.5k ⇒ full recall
    assert rec_unsafe < 0.7 * k          # pool < k  ⇒ provable tail loss
