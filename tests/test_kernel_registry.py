"""Kernel dispatch registry: resolution order, mode precedence, per-op
mode equivalence (padding tails, oversized tiles, dtype promotion), the
zero-Pallas jaxpr pin for forced-XLA paths, and the fused segment-reduce
bit-for-bit contract against the cumsum path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ann as ann_mod
from repro.core import coo, pipeline
from repro.core import tsne as tsne_mod
from repro.core import umap as umap_mod
from repro.kernels import knn_tile, ops, registry
from repro.kernels import segment_reduce as segred

ON_CPU = jax.default_backend() not in registry.ACCELERATOR_BACKENDS

# every mode this backend can actually execute (compiled needs Mosaic)
RUNNABLE = ("interpret", "xla") if ON_CPU \
    else ("compiled", "interpret", "xla")


@pytest.fixture(autouse=True)
def _neutral_mode_env(monkeypatch):
    """These tests probe the precedence chain itself, so the ambient
    CI-matrix pin (SNS_KERNEL_MODE) must not leak in; tests that need
    the env var set it explicitly via monkeypatch."""
    monkeypatch.delenv(registry.ENV_VAR, raising=False)


@pytest.fixture
def fake_op():
    """Install a throwaway op; clean the registry afterwards."""
    name = "_test_probe_op"

    def install(mode, fn=None, **kw):
        return registry.register(name, mode, **kw)(fn or (lambda: mode))

    yield name, install
    registry._REGISTRY.pop(name, None)
    registry.set_mode_override(None, name)
    registry.set_mode_override(None, "*")


# ------------------------------------------------------------ resolution
class TestResolutionOrder:
    def test_auto_walks_compiled_interpret_xla(self, fake_op):
        name, install = fake_op
        install("compiled")
        install("interpret")
        install("xla")
        # on CPU compiled's default accel_only gate declines -> interpret
        got = registry.resolve(name, backend="cpu")
        assert got.mode == "interpret"
        # on an accelerator compiled wins
        got = registry.resolve(name, backend="tpu")
        assert got.mode == "compiled"

    def test_prefer_declines_without_blocking_forced(self, fake_op):
        name, install = fake_op
        install("interpret", prefer=registry.accel_only)
        install("xla")
        # auto on CPU: interpret's prefer declines -> xla
        assert registry.resolve(name, backend="cpu").mode == "xla"
        # but FORCING interpret still works (supported=always)
        assert registry.resolve(name, mode="interpret",
                                backend="cpu").mode == "interpret"

    def test_forced_unsupported_raises_not_downgrades(self, fake_op):
        name, install = fake_op
        install("compiled")
        install("xla")
        with pytest.raises(registry.KernelUnavailableError):
            registry.resolve(name, mode="compiled", backend="cpu")

    def test_forced_unregistered_mode_raises(self, fake_op):
        name, install = fake_op
        install("xla")
        with pytest.raises(registry.KernelUnavailableError):
            registry.resolve(name, mode="interpret", backend="cpu")

    def test_unknown_op_raises(self):
        with pytest.raises(KeyError):
            registry.resolve("_no_such_op_")

    def test_no_impl_accepts_backend_raises(self, fake_op):
        name, install = fake_op
        install("compiled")          # accel_only, nothing else registered
        with pytest.raises(registry.KernelUnavailableError):
            registry.resolve(name, backend="cpu")


class TestModePrecedence:
    def test_explicit_beats_override_and_env(self, fake_op, monkeypatch):
        name, install = fake_op
        install("interpret")
        install("xla")
        monkeypatch.setenv(registry.ENV_VAR, "interpret")
        registry.set_mode_override("interpret", name)
        assert registry.resolve(name, mode="xla",
                                backend="cpu").mode == "xla"

    def test_override_beats_env(self, fake_op, monkeypatch):
        name, install = fake_op
        install("interpret")
        install("xla")
        monkeypatch.setenv(registry.ENV_VAR, "interpret")
        registry.set_mode_override("xla", name)
        assert registry.resolve(name, backend="cpu").mode == "xla"

    def test_env_beats_auto(self, fake_op, monkeypatch):
        name, install = fake_op
        install("interpret")
        install("xla")
        monkeypatch.setenv(registry.ENV_VAR, "xla")
        assert registry.resolve(name, backend="cpu").mode == "xla"

    def test_global_override_applies_to_all_ops(self, fake_op):
        name, install = fake_op
        install("interpret")
        install("xla")
        registry.set_mode_override("xla", "*")
        try:
            assert registry.resolve(name, backend="cpu").mode == "xla"
        finally:
            registry.set_mode_override(None, "*")

    def test_invalid_mode_strings_raise(self, monkeypatch):
        with pytest.raises(ValueError):
            registry.resolve_mode("mosaic")
        monkeypatch.setenv(registry.ENV_VAR, "bogus")
        with pytest.raises(ValueError):
            registry.resolve_mode(None)

    def test_coerce_mode_mapping(self):
        assert registry.coerce_mode(True, None) == "interpret"
        assert registry.coerce_mode(False, None) == "compiled"
        assert registry.coerce_mode(True, "xla") == "xla"     # mode wins
        assert registry.coerce_mode(None, None) is None

    def test_legacy_interpret_loses_to_process_pin(self, fake_op,
                                                   monkeypatch):
        """The legacy interpret bool is a backend-derived DEFAULT, so
        the CI-matrix env pin overrides it; explicit mode= still wins."""
        name, _ = fake_op
        monkeypatch.setenv(registry.ENV_VAR, "xla")
        assert registry.legacy_mode(name, True, None) == "xla"
        assert registry.legacy_mode(name, True, "interpret") == "interpret"
        monkeypatch.delenv(registry.ENV_VAR)
        assert registry.legacy_mode(name, True, None) == "interpret"
        assert registry.legacy_mode(name, None, None) is None


def test_all_call_sites_registered():
    """The tentpole contract: every Pallas call-site op is in the
    registry with an XLA reference to test against."""
    expected = {"cic_splat", "cic_gather", "knn_dist_tiles", "tsne_step",
                "segment_reduce"}
    assert expected <= set(registry.list_ops())
    for op in expected:
        assert "xla" in registry.modes_of(op), op
        assert "compiled" in registry.modes_of(op), op


# ------------------------------------------------- per-op mode equivalence
# non-divisible sizes exercise the padding tails of every wrapper
@pytest.mark.parametrize("n", [37, 1000])
@pytest.mark.parametrize("mode", RUNNABLE)
def test_cic_modes_equivalent(n, mode):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    g = 16
    pts = jax.random.uniform(k1, (n, 2), jnp.float32, 0.0, g - 1.001)
    i0 = jnp.floor(pts).astype(jnp.int32)
    f = pts - jnp.floor(pts)
    vals = jax.random.normal(k2, (n, 3), jnp.float32)
    fields = jax.random.normal(k3, (3, g, g), jnp.float32)
    ref_s = ops.cic_splat(i0, f, vals, g, mode="xla")
    ref_g = ops.cic_gather(fields, i0, f, mode="xla")
    got_s = ops.cic_splat(i0, f, vals, g, mode=mode)
    got_g = ops.cic_gather(fields, i0, f, mode=mode)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(ref_s),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_g), np.asarray(ref_g),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,block", [
    (100, 32),     # padding tail
    (50, 128),     # block >= n: one oversized tile covers everything
])
@pytest.mark.parametrize("mode", RUNNABLE)
def test_tsne_step_modes_equivalent(n, block, mode):
    k1, k2 = jax.random.split(jax.random.key(1))
    x = jax.random.normal(k1, (n, 4), jnp.float32)
    y = jax.random.normal(k2, (n, 2), jnp.float32)
    beta = jnp.ones((n,), jnp.float32)
    zp = jnp.full((n,), float(n), jnp.float32)
    ref_f, ref_kl = ops.tsne_step_fused(x, y, beta, zp, block=block,
                                        mode="xla", return_kl=True)
    got_f, got_kl = ops.tsne_step_fused(x, y, beta, zp, block=block,
                                        mode=mode, return_kl=True)
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(ref_f),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(got_kl), float(ref_kl),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", RUNNABLE)
def test_knn_dist_tiles_modes_equivalent(mode):
    k1, k2, k3 = jax.random.split(jax.random.key(2), 3)
    t, b, d = 3, 16, 4
    qx = jax.random.normal(k1, (t, b, d), jnp.float32)
    qid = jnp.arange(t * b, dtype=jnp.int32).reshape(t, b)
    cx = jax.random.normal(k2, (t, 3 * b, d), jnp.float32)
    cid = jax.random.randint(k3, (t, 3 * b), -1, t * b, dtype=jnp.int32)
    ref = knn_tile.distance_tiles(qx, qid, cx, cid, mode="xla")
    got = knn_tile.distance_tiles(qx, qid, cx, cid, mode=mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("rows,fan,rpb", [
    (33, 5, 8),     # non-divisible row-block tail
    (4, 7, 128),    # rows_per_block >= n: single oversized block
    (1, 64, 8),     # everything in one row
])
@pytest.mark.parametrize("mode", RUNNABLE)
def test_segment_reduce_modes_equivalent(rows, fan, rpb, mode):
    rng = np.random.default_rng(3)
    # ragged bounds: random fan-out around `fan`, including empty rows
    sizes = rng.integers(0, 2 * fan + 1, size=rows)
    bounds = jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]),
                         jnp.int32)
    e = int(bounds[-1])
    vals = jnp.asarray(rng.normal(size=(e, 2)).astype(np.float32))
    ref = coo.segment_reduce(vals, bounds, mode="xla")
    if mode == "xla":
        got = coo.segment_reduce(vals, bounds, mode="xla")
    else:
        impl = registry.get("segment_reduce", mode)
        got = impl.fn(vals, bounds, rows_per_block=rpb, edge_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------- segment reduce: bit-for-bit
@pytest.mark.parametrize("rows,fan", [(1, 16), (16, 0), (64, 3), (33, 9)])
def test_segment_reduce_bitwise_on_exact_payloads(rows, fan):
    """With integer-valued fp32 payloads (< 2^24) every addition is
    exact, so the fused kernel and the cumsum-difference path must agree
    BIT FOR BIT on every shape — empty rows, single row, ragged tails."""
    rng = np.random.default_rng(4)
    sizes = rng.integers(0, 2 * fan + 1, size=rows) if fan else \
        np.zeros(rows, np.int64)   # fan=0: all rows empty
    bounds = jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]),
                         jnp.int32)
    e = int(bounds[-1])
    vals = jnp.asarray(
        rng.integers(-1000, 1000, size=(e, 2)).astype(np.float32))
    ref = coo.segment_reduce(vals, bounds)          # cumsum path
    got = segred.segment_reduce_pallas(vals, bounds, rows_per_block=8,
                                       edge_chunk=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_segment_reduce_1d_payload_and_empty():
    vals = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
    bounds = jnp.asarray([0, 2, 2, 4], jnp.int32)
    got = segred.segment_reduce_pallas(vals, bounds, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), [3.0, 0.0, 7.0])
    empty = segred.segment_reduce_pallas(
        jnp.zeros((0,), jnp.float32), jnp.zeros((1,), jnp.int32),
        interpret=True)
    assert empty.shape == (0,)


# ------------------------------------------------------- dtype promotion
@pytest.mark.parametrize("dtype", [jnp.float16, jnp.bfloat16])
def test_segment_reduce_kernel_accumulates_fp32(dtype):
    """fp16/bf16 payloads accumulate in fp32 inside the kernel: a row of
    [256, 1, 1, ..., 1] sums to 256+k exactly in fp32, while native
    low-precision accumulation would round every +1 away."""
    k = 8
    vals = jnp.asarray([256.0] + [1.0] * k, jnp.float32).astype(dtype)
    bounds = jnp.asarray([0, k + 1], jnp.int32)
    out = segred.segment_reduce_pallas(vals, bounds, interpret=True)
    assert out.dtype == dtype
    assert float(out[0].astype(jnp.float32)) == 256.0 + k


@pytest.mark.parametrize("dtype", [jnp.float16, jnp.bfloat16])
def test_tsne_step_promotes_to_fp32(dtype):
    n = 40
    k1, k2 = jax.random.split(jax.random.key(5))
    x = jax.random.normal(k1, (n, 4), jnp.float32)
    y = jax.random.normal(k2, (n, 2), jnp.float32)
    beta = jnp.ones((n,), jnp.float32)
    zp = jnp.full((n,), float(n), jnp.float32)
    ref = ops.tsne_step_fused(x, y, beta, zp, mode="interpret")
    got = ops.tsne_step_fused(x.astype(dtype), y.astype(dtype), beta, zp,
                              mode="interpret")
    assert got.dtype == jnp.float32          # fp32 accumulation out
    # low-precision INPUT costs precision, fp32 ACCUMULATION caps it
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=0.15, atol=0.05)


@pytest.mark.parametrize("dtype", [jnp.float16, jnp.bfloat16])
def test_cic_splat_promotes_to_fp32(dtype):
    n, g = 100, 8
    k1, k2 = jax.random.split(jax.random.key(6))
    pts = jax.random.uniform(k1, (n, 2), jnp.float32, 0.0, g - 1.001)
    i0 = jnp.floor(pts).astype(jnp.int32)
    f = pts - jnp.floor(pts)
    vals = jax.random.normal(k2, (n, 2), jnp.float32)
    ref = ops.cic_splat(i0, f, vals, g, mode="interpret")
    got = ops.cic_splat(i0, f.astype(dtype), vals, g, mode="interpret")
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=0.02, atol=0.02)


# ------------------------------------------------------------- jaxpr pins
def _count_primitive(jaxpr, name):
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for p in eqn.params.values():
            vals = p if isinstance(p, (list, tuple)) else [p]
            for v in vals:
                if hasattr(v, "jaxpr"):
                    n += _count_primitive(v.jaxpr, name)
                elif hasattr(v, "eqns"):
                    n += _count_primitive(v, name)
    return n


def _pallas_calls(fn, *args):
    return _count_primitive(jax.make_jaxpr(fn)(*args).jaxpr, "pallas_call")


def test_xla_mode_traces_contain_zero_pallas_calls():
    """Forcing kernel_mode="xla" must produce pure-XLA programs — the CI
    matrix leg depends on it actually avoiding the Pallas machinery."""
    n, g = 64, 8
    k1, k2, k3 = jax.random.split(jax.random.key(7), 3)
    pts = jax.random.uniform(k1, (n, 2), jnp.float32, 0.0, g - 1.001)
    i0 = jnp.floor(pts).astype(jnp.int32)
    f = pts - jnp.floor(pts)
    vals = jax.random.normal(k2, (n, 2), jnp.float32)
    fields = jax.random.normal(k3, (2, g, g), jnp.float32)
    y = jax.random.normal(k2, (n, 2), jnp.float32)
    ones = jnp.ones((n,), jnp.float32)
    qx = jax.random.normal(k1, (2, 8, 4), jnp.float32)
    qid = jnp.arange(16, dtype=jnp.int32).reshape(2, 8)
    cx = jax.random.normal(k2, (2, 24, 4), jnp.float32)
    cid = jnp.arange(48, dtype=jnp.int32).reshape(2, 24) % 16
    sv = jax.random.normal(k3, (40, 2), jnp.float32)
    sb = jnp.asarray([0, 10, 25, 40], jnp.int32)

    cases = {
        "cic_splat": lambda: ops.cic_splat(i0, f, vals, g, mode="xla"),
        "cic_gather": lambda: ops.cic_gather(fields, i0, f, mode="xla"),
        "tsne_step": lambda: ops.tsne_step_fused(pts, y, ones,
                                                 ones * n, mode="xla"),
        "knn_dist_tiles": lambda: knn_tile.distance_tiles(
            qx, qid, cx, cid, mode="xla"),
        "segment_reduce": lambda: coo.segment_reduce(sv, sb, mode="xla"),
    }
    for op, fn in cases.items():
        assert _pallas_calls(fn) == 0, \
            f"{op}: mode='xla' trace still contains pallas_call"
    # sanity: the pin would catch a regression — interpret DOES trace one
    assert _pallas_calls(
        lambda: ops.cic_splat(i0, f, vals, g, mode="interpret")) >= 1


# ------------------------------------------------------- config plumbing
def test_sns_config_validates_kernel_mode():
    with pytest.raises(ValueError, match="kernel_mode"):
        pipeline.SnsConfig(kernel_mode="mosaic")


def test_resolve_embed_cfg_threads_kernel_mode():
    cfg = pipeline.SnsConfig(embedder="tsne", embed_backend="sparse",
                             kernel_mode="xla")
    ecfg = pipeline.resolve_embed_cfg(cfg)
    assert ecfg.kernel_mode == "xla"
    assert ecfg.ann is not None and ecfg.ann.kernel_mode == "xla"
    ucfg = pipeline.resolve_embed_cfg(
        dataclasses.replace(cfg, embedder="umap"))
    assert ucfg.kernel_mode == "xla"
    # auto leaves the ANN config alone (None = defer to tile/interpret)
    auto = pipeline.resolve_embed_cfg(
        dataclasses.replace(cfg, kernel_mode="auto"))
    assert auto.kernel_mode == "auto" and auto.ann is None


def test_run_tsne_rejects_bad_kernel_mode():
    cfg = tsne_mod.TsneConfig(n_iter=1, kernel_mode="bogus")
    x = jnp.zeros((8, 3), jnp.float32)
    with pytest.raises(ValueError, match="kernel_mode"):
        tsne_mod.run_tsne(jax.random.key(0), x, cfg)


@pytest.mark.parametrize("mode", ["interpret", "xla"])
def test_sparse_tsne_runs_under_forced_mode(mode):
    """End-to-end: the sparse tSNE loop (cic + tsne kernels + segment
    reduce) runs under each CPU-runnable forced tier and produces the
    same embedding as auto (which resolves to one of these)."""
    x = jnp.asarray(np.random.default_rng(8).normal(
        size=(64, 4)).astype(np.float32))
    base = tsne_mod.TsneConfig(backend="sparse", n_iter=3, knn=4,
                               grid_size=16, perplexity=4.0)
    cfg = dataclasses.replace(base, kernel_mode=mode)
    emb, _ = tsne_mod.run_tsne(jax.random.key(0), x, cfg)
    ref, _ = tsne_mod.run_tsne(jax.random.key(0), x, base)
    assert emb.shape == ref.shape
    np.testing.assert_allclose(np.asarray(emb), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("mode", ["interpret", "xla"])
def test_umap_runs_under_forced_mode(mode):
    x = jnp.asarray(np.random.default_rng(9).normal(
        size=(48, 4)).astype(np.float32))
    base = umap_mod.UmapConfig(n_epochs=2, n_neighbors=4)
    cfg = dataclasses.replace(base, kernel_mode=mode)
    emb = umap_mod.run_umap(jax.random.key(0), x, cfg)
    ref = umap_mod.run_umap(jax.random.key(0), x, base)
    np.testing.assert_allclose(np.asarray(emb), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_ann_kernel_mode_forces_distance_tier():
    x = jnp.asarray(np.random.default_rng(10).normal(
        size=(128, 4)).astype(np.float32))
    base = ann_mod.AnnConfig(tile="xla")
    ref_i, _ = ann_mod.ann_knn_graph(x, 4, dataclasses.replace(
        base, kernel_mode="interpret"))
    ref_x, _ = ann_mod.ann_knn_graph(x, 4, dataclasses.replace(
        base, kernel_mode="xla"))
    np.testing.assert_array_equal(np.asarray(ref_i), np.asarray(ref_x))


# --------------------------------------------------------- tile params
def test_tile_params_table_and_cache(tmp_path):
    p = registry.tile_params("cic_splat", backend="cpu")
    assert p["block_items"] == 1024
    assert registry.tile_params("tsne_step", backend="tpu")["block"] == 512
    cache = tmp_path / "tune.json"
    registry.record_autotune("cic_splat", {"block_items": 2048},
                             backend="cpu", bucket="65536x2",
                             path=str(cache))
    got = registry.tile_params("cic_splat", backend="cpu",
                               shape=(60000, 2), cache_path=str(cache))
    assert got["block_items"] == 2048          # exact-bucket hit
    other = registry.tile_params("cic_splat", backend="cpu",
                                 shape=(100, 2), cache_path=str(cache))
    assert other["block_items"] == 1024        # different bucket -> table


def test_shape_bucket():
    assert registry.shape_bucket((1000, 2)) == "1024x2"
    assert registry.shape_bucket(()) == "scalar"
    assert registry.shape_bucket((1,)) == "1"


def test_autotune_op_skips_raising_candidates(tmp_path):
    cache = tmp_path / "tune.json"

    def measure(params):
        if params["k"] == 1:
            raise RuntimeError("VMEM")
        return params["k"] * 0.5

    best = registry.autotune_op("cic_splat", [{"k": 1}, {"k": 2}, {"k": 4}],
                                measure, backend="cpu",
                                cache_path=str(cache))
    assert best == {"k": 2}
    with pytest.raises(registry.KernelUnavailableError):
        registry.autotune_op(
            "cic_splat", [{"k": 1}],
            lambda p: (_ for _ in ()).throw(RuntimeError("x")),
            backend="cpu", cache_path=str(cache))
