"""End-to-end chaos suite: the full pipeline under injected failures.

Seed-parameterized via ``CHAOS_SEED`` (CI loops it over several values:
every fault decision is a pure function of the seed, so a failure on
seed N reproduces with ``CHAOS_SEED=N pytest tests/test_chaos.py``).
Each test is one scenario from the failure menu the deployment model
actually faces:

* a dead shard + a straggler → the run completes degraded
  (``ingest_coverage < 1``, widened bound, finite embedding);
* at-least-once / corrupted chunk delivery → the fold survives;
* loader-path shard failure → all-or-nothing skip, steal-rescuable;
* torn or bit-rotted checkpoints → detected, previous generation served;
* a chaotic service episode → keeps serving through it all.
"""
import os

import numpy as np
import pytest

from repro.core import faults, pipeline, quantize, resilience, stream
from repro.core.faults import FaultPlan
from repro.core.resilience import RetryPolicy
from repro.core.service import SnsService
from repro.core.tsne import TsneConfig
from repro.data.loader import ShardPlan

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))

N_SHARDS, PER_SHARD, DIMS = 8, 250, 3
CFG = pipeline.SnsConfig(bins=6, rows=4, log2_cols=10, top_k=24,
                         candidate_pool=128, ingest_chunk=256,
                         embedder="tsne", embed_backend="dense", seed=0)
TC = TsneConfig(dims=2, n_iter=40, exaggeration_iters=10,
                momentum_switch=10, perplexity=8.0)
FAST = RetryPolicy(max_attempts=3, base_delay=0.001, max_delay=0.01)


def _shard(s: int) -> np.ndarray:
    rng = np.random.RandomState(1000 + s)
    return (rng.randn(PER_SHARD, DIMS) * 0.05 + (s % 4)).astype(np.float32)


@pytest.fixture(scope="module")
def grid():
    g = quantize.fit_grid(
        np.concatenate([_shard(s) for s in range(N_SHARDS)]), CFG.bins)
    # warm the jitted ingest path once: the deadline-cutoff tests below
    # measure delivery latency, not first-call compile time (a cold cache
    # under 8 concurrent jobs can blow any reasonable deadline)
    import jax
    st = stream.init(jax.random.key(0), CFG.rows, CFG.log2_cols,
                     CFG.candidate_pool)
    stream.ingest_all(st, g, iter([_shard(0)]), CFG.ingest_chunk)
    return g


def test_run_survives_dead_shard_and_straggler(grid):
    """One permanently dead shard plus one slow one, cut off at the
    deadline: the pipeline still produces a finite embedding and reports
    the damage honestly."""
    dead = CHAOS_SEED % N_SHARDS
    slow = (CHAOS_SEED + 3) % N_SHARDS
    plan = FaultPlan(seed=CHAOS_SEED, drop_shards=(dead,))
    data = {s: [_shard(s)] for s in range(N_SHARDS)}

    def straggler(s=slow):
        import time
        time.sleep(6.0)   # modest: the abandoned thread is joined at
        return [_shard(s)]  # interpreter exit (non-daemon executors)

    data[slow] = straggler
    res = pipeline.run_resilient(
        CFG, data, grid, faults=plan, policy=RetryPolicy(max_attempts=1),
        deadline=2.0, expected_counts={s: PER_SHARD
                                       for s in range(N_SHARDS)},
        tsne_cfg=TC)
    assert set(res.lost_shards) == {dead, slow}
    assert res.ingest_coverage == pytest.approx(1 - 2 / N_SHARDS)
    # two shards' worth of mass is unaccounted for — the bound says so
    assert res.hh_error_bound >= 2 * PER_SHARD
    assert np.isfinite(np.asarray(res.embedding)).all()


def test_duplicate_and_corrupt_chunks_do_not_kill_ingest(grid):
    """At-least-once delivery and in-transit bit flips on raw DATA chunks
    bias counts but never crash the fold (sketch linearity: duplicates
    add; a flipped coordinate is just a different point)."""
    plan = FaultPlan(seed=CHAOS_SEED, duplicate=0.5, corrupt=0.3)
    data = {s: [_shard(s)] for s in range(N_SHARDS)}
    res = pipeline.run_resilient(CFG, data, grid, faults=plan,
                                 policy=FAST, tsne_cfg=TC)
    assert res.lost_shards == ()
    assert res.ingest_coverage == 1.0
    assert np.isfinite(np.asarray(res.embedding)).all()


def test_loader_path_degrades_all_or_nothing(grid):
    """ShardedLoader + chaos_make_batch: a failing shard is skipped whole
    (no half-delivered batches), recorded, and the ingest proceeds on
    the survivors."""
    dead = CHAOS_SEED % N_SHARDS
    plan = FaultPlan(seed=CHAOS_SEED, drop_shards=(dead,))
    skipped = []

    def on_err(shard, exc):
        skipped.append(shard)
        return True

    factory = pipeline.chunks_from_loader(
        ShardPlan(num_shards=N_SHARDS, num_hosts=1), 0,
        lambda s, b: _shard(s), faults=plan, on_shard_error=on_err)
    delivered = sum(c.shape[0] for c in factory())
    assert skipped == [dead]
    assert delivered == (N_SHARDS - 1) * PER_SHARD


def test_checkpoint_bitrot_detected_and_recovered(tmp_path, grid):
    """Silent corruption: the flipped checkpoint fails its checksum;
    with a backup generation the previous state is served instead."""
    import jax
    path = str(tmp_path / "fold")
    st = stream.init(jax.random.key(0), CFG.rows, CFG.log2_cols, 64)
    st = stream.ingest_all(st, grid, iter([_shard(0)]), 128)
    count_gen1 = float(st.count)
    stream.save_state(st, path)
    st2 = stream.ingest_all(st, grid, iter([_shard(1)]), 128)
    stream.save_state(st2, path, keep_backup=True)   # rotates gen1 → .bak
    faults.corrupt_file(stream._npz_path(path), seed=CHAOS_SEED,
                        mode="flip")
    with pytest.raises(stream.CheckpointCorruptError):
        stream.load_state(path)
    rec = stream.load_state(path, fallback=True)     # the .bak generation
    assert float(rec.count) == count_gen1


def test_truncated_checkpoint_regression(tmp_path, grid):
    """A torn write (crash mid-flush, pre-atomic-rename era) must never
    parse as a valid state."""
    import jax
    path = str(tmp_path / "fold")
    st = stream.init(jax.random.key(0), CFG.rows, CFG.log2_cols, 64)
    st = stream.ingest_all(st, grid, iter([_shard(0)]), 128)
    stream.save_state(st, path)
    faults.corrupt_file(stream._npz_path(path), seed=CHAOS_SEED,
                        mode="truncate")
    with pytest.raises(stream.CheckpointCorruptError):
        stream.load_state(path)
    # and a stale temp file from a crashed writer never shadows the real
    # checkpoint: save again, confirm the load sees the fresh state
    with open(stream._npz_path(path) + ".tmp.999", "wb") as f:
        f.write(b"garbage")
    stream.save_state(st, path)
    assert float(stream.load_state(path).count) == float(st.count)


def test_service_keeps_serving_through_chaos(tmp_path, grid):
    """One service episode on the full failure menu: flaky updates are
    retried, a dead shard degrades coverage, refresh commits, transform
    serves, and a corrupted checkpoint falls back to the previous
    generation."""
    dead = CHAOS_SEED % N_SHARDS
    plan = FaultPlan(seed=CHAOS_SEED, drop_shards=(dead,), flaky=0.2)
    svc = SnsService(CFG, grid, tsne_cfg=TC)
    rep = svc.update_shards(
        {s: [_shard(s)] for s in range(N_SHARDS)}, faults=plan,
        policy=RetryPolicy(max_attempts=6, base_delay=0.001),
        expected_counts={s: PER_SHARD for s in range(N_SHARDS)})
    assert rep["lost"] == [dead]
    svc.refresh()
    h = svc.health()
    assert h["serving"] and h["coverage"] == pytest.approx(
        1 - 1 / N_SHARDS)
    assert h["lost_shards"] == (dead,)
    y = svc.transform(_shard(0)[:16])
    assert np.isfinite(y).all()
    path = str(tmp_path / "svc")
    svc.save(path)
    svc.update(_shard(1))
    svc.save(path)
    faults.corrupt_file(stream._npz_path(path), seed=CHAOS_SEED,
                        mode="truncate")
    rec = SnsService.load(path, CFG, grid, tsne_cfg=TC)
    # the .bak generation: pre-second-update state, counters intact
    assert float(rec.state.count) < float(svc.state.count)
    assert rec._lost_shards == (dead,)
    assert np.isfinite(rec.transform(_shard(0)[:4])).all()
