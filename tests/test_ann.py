"""Approximate kNN engine (core.ann): recall, exactness at tiny N,
dispatch wiring, the Pallas distance tile, and the jaxpr contracts
(no quadratic buffer, single fused refinement loop).

The recall contract is the one the pipeline relies on when
``knn_graph(method="auto")`` crosses ``AnnConfig.auto_threshold``:
ann recall ≥ 0.9 against the exact graph on representative blob
geometry.  CI additionally gates recall at bench scale via
``benchmarks/bench_knn_recall.py --smoke``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from benchmarks.common import iter_jaxpr_avals
from repro.core import ann, neighbors
from repro.kernels import knn_tile


def _points(n, dims, seed, clusters=8):
    """Blobby geometry (what heavy-hitter representatives look like)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 4, (clusters, dims))
    x = centers[rng.integers(0, clusters, n)] + rng.normal(0, 0.3, (n, dims))
    return jnp.asarray(x.astype(np.float32))


def _recall(ann_idx, exact_idx):
    n, _ = exact_idx.shape
    rows = np.arange(n, dtype=np.int64)[:, None]
    return float(np.isin(np.asarray(ann_idx).astype(np.int64) + rows * n,
                         np.asarray(exact_idx).astype(np.int64) + rows * n
                         ).mean())


# ------------------------------------------------------------------ recall
@given(n=st.sampled_from((512, 777, 1024)), k=st.sampled_from((8, 15, 32)),
       seed=st.integers(0, 50))
@settings(max_examples=6, deadline=None)
def test_ann_recall_at_least_090(n, k, seed):
    """Property: ann recall ≥ 0.9 vs exact over blob draws — sizes
    include a non-power-of-two (padding path) and k spanning the UMAP
    and tSNE regimes."""
    x = _points(n, 6, seed)
    ei, _ = neighbors.knn_graph(x, k)
    ai, _ = neighbors.knn_graph(x, k, method="ann")
    assert _recall(ai, ei) >= 0.9


@pytest.mark.parametrize("n,k,seed", [(512, 8, 0), (777, 15, 1),
                                      (1024, 32, 2)])
def test_ann_recall_fixed_cases(n, k, seed):
    """Non-hypothesis fallback for minimal containers: the same recall
    contract at three fixed (n, k, seed) points (including the padding
    path at a non-power-of-two n)."""
    x = _points(n, 6, seed)
    ei, _ = neighbors.knn_graph(x, k)
    ai, _ = neighbors.knn_graph(x, k, method="ann")
    assert _recall(ai, ei) >= 0.9


def test_ann_matches_exact_at_tiny_n():
    """When one bucket window covers the whole set, stage 1 is already
    exact and NN-descent is a fixpoint: identical indices, identical
    (sqrt-consistent) distances."""
    x = _points(100, 4, 3)
    ei, ed = neighbors.knn_graph(x, 7)
    ai, ad = neighbors.knn_graph(x, 7, method="ann")
    np.testing.assert_array_equal(np.asarray(ai), np.asarray(ei))
    # distances agree to fp (the tile kernel's qq+cc−2qc form vs the
    # exact path's association differ in the last couple of ulps)
    np.testing.assert_allclose(np.asarray(ad), np.asarray(ed), atol=1e-4)


# ---------------------------------------------------------------- dispatch
def test_knn_graph_method_dispatch():
    x = _points(64, 4, 0)
    ei, ed = neighbors.knn_graph(x, 5)
    for method in ("exact", "auto"):     # auto stays exact below threshold
        mi, md = neighbors.knn_graph(x, 5, method=method)
        np.testing.assert_array_equal(np.asarray(mi), np.asarray(ei))
        np.testing.assert_array_equal(np.asarray(md), np.asarray(ed))
    with pytest.raises(ValueError, match="method"):
        neighbors.knn_graph(x, 5, method="bogus")


def test_ann_knn_graph_clamps_k():
    x = _points(9, 3, 1)
    idx, dist = ann.ann_knn_graph(x, 50)
    assert idx.shape == (9, 8) and dist.shape == (9, 8)
    ei, _ = neighbors.knn_graph(x, 50)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ei))


# ------------------------------------------------------------ dedupe merge
def test_dedupe_topk_drops_dups_and_invalid_keeps_first():
    idx = jnp.array([[3, 1, 3, -1, 2]], jnp.int32)
    d2 = jnp.array([[0.5, 0.2, 0.1, 0.0, 0.9]], jnp.float32)
    mi, md = ann._dedupe_topk(idx, d2, 3)
    # id 3 keeps its FIRST occurrence (0.5) — the keep-first contract the
    # change-count convergence metric depends on; -1 is dropped entirely
    np.testing.assert_array_equal(np.asarray(mi), [[1, 3, 2]])
    np.testing.assert_allclose(np.asarray(md), [[0.2, 0.5, 0.9]])
    # fixpoint: re-merging a deduped row with itself is the identity
    mi2, md2 = ann._dedupe_topk(jnp.concatenate([mi, mi], axis=1),
                                jnp.concatenate([md, md], axis=1), 3)
    np.testing.assert_array_equal(np.asarray(mi2), np.asarray(mi))
    np.testing.assert_array_equal(np.asarray(md2), np.asarray(md))


def test_dedupe_topk_pads_short_rows_with_inf():
    idx = jnp.array([[4, 4, -1, -1]], jnp.int32)
    d2 = jnp.array([[1.0, 2.0, 0.0, 0.0]], jnp.float32)
    mi, md = ann._dedupe_topk(idx, d2, 3)
    assert int(mi[0, 0]) == 4 and float(md[0, 0]) == 1.0
    assert np.isinf(np.asarray(md)[0, 1:]).all()


# ------------------------------------------------------- Pallas tile kernel
def test_distance_tiles_pallas_matches_xla_including_padding():
    """The Pallas tile == the XLA reference on tiles containing padded
    query rows (qid −1), padded candidates (cid −1), and self-pairs —
    masked slots are +inf on both paths, finite slots agree."""
    rng = np.random.default_rng(7)
    t, b, c, d = 3, 8, 12, 5
    qx = jnp.asarray(rng.normal(size=(t, b, d)).astype(np.float32))
    cx = jnp.asarray(rng.normal(size=(t, c, d)).astype(np.float32))
    qid = rng.integers(0, 40, (t, b)).astype(np.int32)
    cid = rng.integers(0, 40, (t, c)).astype(np.int32)
    qid[0, -3:] = -1                       # padded query rows
    cid[:, -4:] = -1                       # padded candidates
    cid[1, 0] = qid[1, 0]                  # a guaranteed self-pair
    qid, cid = jnp.asarray(qid), jnp.asarray(cid)
    ref = np.asarray(knn_tile.distance_tiles(qx, qid, cx, cid, tile="xla"))
    got = np.asarray(knn_tile.distance_tiles(qx, qid, cx, cid,
                                             tile="pallas", interpret=True))
    np.testing.assert_array_equal(np.isinf(ref), np.isinf(got))
    fin = np.isfinite(ref)
    np.testing.assert_allclose(got[fin], ref[fin], atol=1e-4)
    assert np.isinf(ref[1, 0][np.asarray(cid)[1] == int(qid[1, 0])]).all()

    with pytest.raises(ValueError, match="tile backend"):
        knn_tile.distance_tiles(qx, qid, cx, cid, tile="cuda")


# --------------------------------------------------------- jaxpr contracts
def test_ann_build_jaxpr_has_no_quadratic_buffer():
    """The point of the engine: no (N, N)-scale intermediate anywhere in
    the build (probe layout, tile scan, NN-descent).  Pinned two ways:
    the largest buffer at N = 4096 is far below N² elements (it is the
    O(block·C·D) candidate-coordinate gather of the descent round, so
    the pin uses a sub-N block as the real > auto_threshold runs do),
    and it grows LINEARLY when N doubles — a quadratic buffer would
    grow 4×."""
    k, cfg = 15, ann.AnnConfig(block=512)

    def biggest(n):
        x = jnp.zeros((n, 8), jnp.float32)
        jaxpr = jax.make_jaxpr(lambda x_: ann._ann_build(x_, k, cfg))(x)
        return max(int(np.prod(a.shape, dtype=np.int64))
                   for a in iter_jaxpr_avals(jaxpr.jaxpr)
                   if hasattr(a, "shape"))

    b1, b2 = biggest(4096), biggest(8192)
    assert b1 < 4096 * 4096 // 2, f"quadratic-scale buffer: {b1} elems"
    assert b2 <= 2.5 * b1, (b1, b2)


def test_nn_descent_is_one_fused_loop():
    """The refinement is a SINGLE jitted fori_loop — exactly one
    top-level loop primitive (static trip count lowers to scan), not an
    unrolled or per-iteration-dispatched python loop."""
    n, k = 512, 8
    cfg = ann.AnnConfig(iters=5)
    x = jnp.zeros((n, 4), jnp.float32)
    idx0 = jnp.zeros((n, k), jnp.int32)
    d20 = jnp.zeros((n, k), jnp.float32)
    rid = jnp.arange(n, dtype=jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda x_, i_, d_, r_, key_: ann._nn_descent(
            x_, i_, d_, r_, key_, k, n, cfg, n, n, n))(
                x, idx0, d20, rid, jax.random.PRNGKey(0))
    loops = sum(1 for eqn in jaxpr.jaxpr.eqns
                if eqn.primitive.name in ("scan", "while"))
    assert loops == 1, [e.primitive.name for e in jaxpr.jaxpr.eqns]


# ------------------------------------------------------------- query mode
def _brute_query(q, x, k):
    d2 = np.sum((np.asarray(q)[:, None, :] - np.asarray(x)[None]) ** 2, -1)
    idx = np.argsort(d2, axis=1, kind="stable")[:, :k]
    return idx, np.sqrt(np.take_along_axis(d2, idx, axis=1))


def test_knn_query_exact_matches_brute_force_no_self_exclusion():
    """Asymmetric queries keep their corpus twin: a query identical to a
    corpus point must return that point at distance ~0 (the transform()
    identity contract), unlike the self-excluding graph build."""
    x = _points(300, 5, 11)
    q = jnp.concatenate([x[:16], _points(40, 5, 12)])     # 16 identities
    idx, dist = neighbors.knn_query(q, x, 6)
    bi, bd = _brute_query(q, x, 6)
    # fp32 |a|²+|b|²−2ab cancellation leaves ~1e-2 noise at blob scale
    np.testing.assert_allclose(np.asarray(dist), bd, atol=1e-2)
    # identity queries: nearest neighbor is the twin at ~zero distance
    np.testing.assert_array_equal(np.asarray(idx)[:16, 0], np.arange(16))
    assert np.asarray(dist)[:16, 0].max() < 1e-2
    # clamps k to N (not N-1: queries are not corpus members)
    fi, _ = neighbors.knn_query(q[:4], x[:5], 50)
    assert fi.shape == (4, 5)


def test_ann_knn_query_recall_and_identity():
    """ANN query path: recall ≥ 0.9 vs brute force on blob geometry, the
    corpus-graph expansion only helps, and identity queries survive (the
    −1 query ids never collide with corpus candidate ids)."""
    x = _points(900, 6, 21)
    q = jnp.concatenate([x[:32], _points(200, 6, 22)])
    bi, _ = _brute_query(q, x, 10)
    ai, ad = ann.ann_knn_query(q, x, 10)
    m = q.shape[0]
    rows = np.arange(m, dtype=np.int64)[:, None]
    base = float(np.isin(np.asarray(ai) + rows * x.shape[0],
                         bi + rows * x.shape[0]).mean())
    assert base >= 0.9, base
    gi, _ = ann.ann_knn_graph(x, 10)
    ei, ed = ann.ann_knn_query(q, x, 10, corpus_graph=gi)
    expanded = float(np.isin(np.asarray(ei) + rows * x.shape[0],
                             bi + rows * x.shape[0]).mean())
    assert expanded >= base - 1e-9, (base, expanded)
    np.testing.assert_array_equal(np.asarray(ei)[:32, 0], np.arange(32))
    assert np.asarray(ed)[:32, 0].max() < 1e-2


# ------------------------------------- reverse_edge_values packed-key bound
@pytest.mark.parametrize("n", [2 ** 16, 2 ** 16 + 1])
def test_reverse_edge_values_across_packed_key_boundary(n):
    """Regression for the uint32 packed-key bound: N = 2¹⁶ is the last
    size where keys i·n + j fit uint32 (max key = 2³² − 1 exactly);
    2¹⁶ + 1 must take the gather fallback.  A ring graph makes every
    reverse value analytic, so both branches are checked for VALUES, not
    just for not crashing."""
    assert (n <= neighbors.PACKED_KEY_N_MAX) == (n == 2 ** 16)
    i = np.arange(n, dtype=np.int64)
    knn_idx = np.stack([(i + 1) % n, (i - 1) % n], 1).astype(np.int32)
    vals_nk = (2.0 * i[:, None] + np.array([0.0, 1.0])).astype(np.float32)
    rows = np.repeat(i, 2).astype(np.int32)
    cols = knn_idx.reshape(-1)
    got = np.asarray(neighbors.reverse_edge_values(
        jnp.asarray(knn_idx), jnp.asarray(vals_nk), jnp.asarray(rows),
        jnp.asarray(cols), jnp.asarray(vals_nk.reshape(-1)), n))
    # reverse of (i → i+1) is slot 1 of row i+1; of (i → i−1), slot 0 of i−1
    expected = np.stack([2.0 * ((i + 1) % n) + 1.0,
                         2.0 * ((i - 1) % n)], 1).reshape(-1)
    np.testing.assert_array_equal(got, expected.astype(np.float32))
