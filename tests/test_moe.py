"""MoE dispatch correctness: sort-based capacity dispatch vs a dense
per-token oracle; property tests over expert counts / top-k / capacity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import moe


def _dense_oracle(p, x, top_k):
    """No-capacity reference: every token goes to its top-k experts."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p.router
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.sum(gates, -1, keepdims=True)
    out = jnp.zeros_like(xf, dtype=jnp.float32)
    for k in range(top_k):
        for e in range(p.router.shape[1]):
            sel = (ids[:, k] == e)
            h = jax.nn.silu(xf @ p.w_gate[e]) * (xf @ p.w_up[e])
            y = h @ p.w_down[e]
            out = out + jnp.where(sel[:, None],
                                  gates[:, k:k + 1] * y.astype(jnp.float32),
                                  0.0)
    return out.reshape(b, s, d)


@pytest.mark.parametrize("e,k,cap", [(4, 2, 8.0), (8, 1, 8.0), (8, 4, 8.0)])
def test_moe_matches_dense_oracle_when_capacity_ample(e, k, cap):
    """With capacity >> need, no token drops and the sort-based dispatch
    must equal the dense computation exactly."""
    key = jax.random.key(0)
    d, ff = 16, 32
    p = moe.init_moe(key, d, e, ff, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, d), jnp.float32)
    got, aux = moe.moe_apply(p, x, top_k=k, capacity_factor=cap)
    want = _dense_oracle(p, x, k)
    assert float(aux.dropped_frac) == 0.0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_moe_capacity_drops_bounded():
    """At capacity_factor 1.0 some assignments may drop, never more than
    the theoretical bound, and outputs stay finite."""
    key = jax.random.key(2)
    d, ff, e, k = 16, 32, 8, 2
    p = moe.init_moe(key, d, e, ff, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (4, 16, d), jnp.float32)
    got, aux = moe.moe_apply(p, x, top_k=k, capacity_factor=1.0)
    assert 0.0 <= float(aux.dropped_frac) < 0.5
    assert np.isfinite(np.asarray(got)).all()


@given(e=st.sampled_from([2, 4, 8]), k=st.integers(1, 2),
       seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_moe_property_mass_conservation(e, k, seed):
    """Sum of per-slot gates over kept assignments == sum of token gates
    that were not dropped; output zero for fully-dropped tokens."""
    key = jax.random.key(seed)
    d, ff = 8, 16
    p = moe.init_moe(key, d, e, ff, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, d), jnp.float32)
    got, aux = moe.moe_apply(p, x, top_k=k, capacity_factor=8.0)
    assert np.isfinite(np.asarray(got)).all()
    assert float(aux.load_balance_loss) >= 0.99  # >= 1 at uniform routing
    assert float(aux.z_loss) >= 0.0


def test_router_zloss_penalizes_large_logits():
    key = jax.random.key(4)
    d, ff, e = 8, 16, 4
    p = moe.init_moe(key, d, e, ff, jnp.float32)
    x_small = 0.01 * jax.random.normal(jax.random.key(5), (1, 8, d))
    x_big = 100.0 * jax.random.normal(jax.random.key(5), (1, 8, d))
    _, aux_s = moe.moe_apply(p, x_small, top_k=2)
    _, aux_b = moe.moe_apply(p, x_big, top_k=2)
    assert float(aux_b.z_loss) > float(aux_s.z_loss)
