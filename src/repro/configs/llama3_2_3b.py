"""llama3.2-3b [dense] — small llama3.
28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256
[hf:meta-llama/Llama-3.2-1B; unverified]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    arch_id="llama3.2-3b", family="dense",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=128_256, head_dim=128,
    rope_theta=500_000.0)

SMOKE = ModelConfig(
    arch_id="llama3.2-3b-smoke", family="dense",
    num_layers=2, d_model=48, num_heads=3, num_kv_heads=1,
    d_ff=128, vocab_size=256, head_dim=16, rope_theta=500_000.0)
