"""arctic-480b [moe] — 128 experts top-2 + parallel dense residual MLP.
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base; hf]

Every layer: attention, then (dense MLP ff=4864) ∥ (MoE 128e top-2,
expert ff=4864) in parallel from the same normed input (dense_residual).
56 heads padded to 64 for the 16-way model axis.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    arch_id="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32_000, head_dim=128,
    num_experts=128, moe_top_k=2, expert_ff=4864,
    moe_every=1, dense_residual=True)

SMOKE = ModelConfig(
    arch_id="arctic-480b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=96, vocab_size=256, head_dim=16,
    num_experts=8, moe_top_k=2, expert_ff=96,
    moe_every=1, dense_residual=True)
