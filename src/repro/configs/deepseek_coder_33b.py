"""deepseek-coder-33b [dense] — llama-arch.
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256  [arXiv:2401.14196; hf]

56 query heads are not divisible by the 16-way model axis: padded to 64
at param-build time (zeroed, exact outputs; see DESIGN.md §5).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    arch_id="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=19_200, vocab_size=32_256, head_dim=128)

SMOKE = ModelConfig(
    arch_id="deepseek-coder-33b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=7, num_kv_heads=1,  # odd heads kept
    d_ff=192, vocab_size=256, head_dim=16)
