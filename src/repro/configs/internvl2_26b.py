"""internvl2-26b [vlm] — InternViT + InternLM2 backbone.
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553  [arXiv:2404.16821; hf]

Backbone-only per the brief: the InternViT frontend is a stub —
``input_specs()`` supplies precomputed patch embeddings (B, 256, d_model)
prepended to the token embeddings.  Decode is text-only with a KV cache.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    arch_id="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92_553, head_dim=128,
    frontend="vision", num_prefix=256)

SMOKE = ModelConfig(
    arch_id="internvl2-26b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    frontend="vision", num_prefix=8)
