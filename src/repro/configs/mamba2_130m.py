"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
24L d_model=768 d_ff=0 vocab=50280, ssm_state=128  [arXiv:2405.21060; unverified]

No attention, no MLP: each layer is a single Mamba2 block.  SSD heads:
d_inner=1536, headdim=64 -> 24 heads (padded to 32 on a 16-way model axis).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    arch_id="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50_280, head_dim=0,
    ssm_state=128, ssm_headdim=64, ssm_expand=2,
    tie_embeddings=True)

SMOKE = ModelConfig(
    arch_id="mamba2-130m-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=256, head_dim=0,
    ssm_state=16, ssm_headdim=16, ssm_expand=2,
    tie_embeddings=True)
