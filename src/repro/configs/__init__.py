"""Architecture registry: ``--arch <id>`` → (FULL, SMOKE) ModelConfigs."""
from __future__ import annotations

from typing import Dict, Tuple

from repro.models.config import ModelConfig

from repro.configs import (arctic_480b, deepseek_coder_33b, internvl2_26b,
                           jamba_v0_1_52b, llama3_2_3b, mamba2_130m,
                           qwen1_5_110b, qwen3_moe_235b_a22b,
                           seamless_m4t_large_v2, tinyllama_1_1b)

_MODULES = {
    "internvl2-26b": internvl2_26b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "deepseek-coder-33b": deepseek_coder_33b,
    "llama3.2-3b": llama3_2_3b,
    "qwen1.5-110b": qwen1_5_110b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "mamba2-130m": mamba2_130m,
    "arctic-480b": arctic_480b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = _MODULES[arch_id]
    return mod.SMOKE if smoke else mod.FULL


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
