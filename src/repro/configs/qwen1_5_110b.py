"""qwen1.5-110b [dense] — QKV bias.
80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064
[hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    arch_id="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=49_152, vocab_size=152_064, head_dim=128,
    qkv_bias=True)

SMOKE = ModelConfig(
    arch_id="qwen1.5-110b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256, head_dim=16, qkv_bias=True)
