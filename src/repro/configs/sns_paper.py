"""The paper's own experiment configurations (§IV).

* cancer: 52M pixels → 26M after noise cut, 8-dim PCA colors, 25 bins/axis,
  16×2·10⁵ sketch, top 20,000 heavy hitters → UMAP 2-D.
* sdss:   30M stars, 10 color-difference features (paper uses subsets of
  the (u-g, …, i-z) differences; the published run binned 22/axis and took
  2,609–20,000 HHs) → UMAP 4-D.

Column counts are rounded to powers of two (2¹⁸ = 262144 ≈ 2·10⁵) so the
bucket hash is a shift — see core/sketch.init.
"""
from repro.core.pipeline import SnsConfig

CANCER = SnsConfig(
    bins=25, rows=16, log2_cols=18, top_k=20_000,
    replica_scheme="count", max_replicas=8, jitter_frac=0.25,
    embedder="umap", embed_dims=2)

SDSS = SnsConfig(
    bins=22, rows=16, log2_cols=18, top_k=2_609,
    replica_scheme="count", max_replicas=8, jitter_frac=0.25,
    embedder="umap", embed_dims=4)

# Error-vs-rank evaluation (paper §III-2): 22 bins, top-20k query set
CANCER_ERROR_EVAL = SnsConfig(
    bins=22, rows=16, log2_cols=18, top_k=20_000,
    embedder="umap", embed_dims=2)

# Beyond the paper: the tiled/pallas embed backends never materialize an
# (N, N) buffer (10^5 reps fit in O(block·N) memory), and the sparse
# backend also drops the per-iteration WORK to O(N·k + G²·log G) — kNN
# attraction + FFT grid repulsion — so 10^5-10^6 representative tSNE runs
# finish in minutes on CPU (benchmarks/bench_embed_throughput.py).
CANCER_100K = SnsConfig(
    bins=32, rows=16, log2_cols=20, top_k=100_000,
    replica_scheme="count", max_replicas=4, jitter_frac=0.25,
    embedder="tsne", embed_dims=2,
    embed_backend="sparse", embed_block=512, embed_knn=90, embed_grid=128)

SDSS_100K = SnsConfig(
    bins=28, rows=16, log2_cols=20, top_k=100_000,
    replica_scheme="count", max_replicas=4, jitter_frac=0.25,
    embedder="umap", embed_dims=4,
    embed_backend="tiled", embed_block=2048)

# The million-representative regime the sketch/ingest engine already
# sustains (PR 2-3): only the sparse backend makes the embed side keep up.
CANCER_1M = SnsConfig(
    bins=48, rows=16, log2_cols=22, top_k=1_000_000,
    replica_scheme="count", max_replicas=1, jitter_frac=0.25,
    embedder="tsne", embed_dims=2,
    # embed_knn=0 → 3·perplexity (the calibration needs k comfortably
    # above the perplexity so the entropy target is reachable).
    # Adaptive grid: start at G=256 and double with the embedding span
    # (cell spacing ≤ 0.5 embedding units, G capped at 1024) — a million
    # representatives spread far wider than the blob regimes a fixed G
    # was tuned on, and a re-spaced fixed grid would coarsen with span.
    embed_backend="sparse", embed_block=1024, embed_knn=0, embed_grid=256,
    embed_grid_interval=0.5, embed_grid_max=1024,
    # a million reps is firmly past the exact-kNN wall: the approximate
    # engine (core.ann, recall ≥ 0.9) replaces the O(N²·D) build —
    # "ann" states it explicitly ("auto" would pick it here anyway)
    embed_knn_method="ann")
