"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, no dense MLP.
94L d_model=4096 64H (GQA kv=4) expert_ff=1536 vocab=151936
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    arch_id="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=0,                      # all layers MoE, no dense MLP
    vocab_size=151_936, head_dim=128,
    num_experts=128, moe_top_k=8, expert_ff=1536,
    moe_every=1)

SMOKE = ModelConfig(
    arch_id="qwen3-moe-235b-a22b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=0, vocab_size=256, head_dim=16,
    num_experts=8, moe_top_k=4, expert_ff=96,
    moe_every=1)
