"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.
24L d_model=1024 16H (kv=16 => MHA) d_ff=8192 vocab=256206
[arXiv:2308.11596; hf]

Backbone-only: the speech frontend is a stub — ``input_specs()`` supplies
precomputed frame embeddings (B, S, d_model) as encoder input.  Decode =
text decoder with self-KV cache + cached encoder cross-K/V.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    arch_id="seamless-m4t-large-v2", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256_206, head_dim=64,
    encoder_layers=24, frontend="audio")

SMOKE = ModelConfig(
    arch_id="seamless-m4t-large-v2-smoke", family="encdec",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    encoder_layers=2, frontend="audio")
