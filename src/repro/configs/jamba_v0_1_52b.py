"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE.
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf]

Layer pattern (period 8): attention at i % 8 == 4, Mamba2 elsewhere;
MoE replaces the MLP every other layer (odd indices).  SSD heads:
d_inner=8192, headdim=64 -> 128 heads (16-divisible, no padding).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    arch_id="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14_336, vocab_size=65_536, head_dim=128,
    num_experts=16, moe_top_k=2, expert_ff=14_336,
    moe_every=2, moe_offset=1,
    ssm_state=128, ssm_headdim=64, ssm_expand=2,
    attn_every=8, attn_offset=4)

SMOKE = ModelConfig(
    arch_id="jamba-v0.1-52b-smoke", family="hybrid",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    num_experts=4, moe_top_k=2, expert_ff=128,
    moe_every=2, moe_offset=1,
    ssm_state=16, ssm_headdim=16, ssm_expand=2,
    attn_every=8, attn_offset=4)
