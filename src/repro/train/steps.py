"""train_step / serve_step factories + ShapeDtypeStruct input specs.

These are the functions ``launch/dryrun.py`` lowers for every
(architecture × shape × mesh) cell and the Trainer runs for real:

* ``make_train_step``  — forward + loss + grad + AdamW/Adafactor update.
* ``make_prefill_step`` — prompt → filled caches + first-token logits.
* ``make_decode_step``  — one token against the cache (+ SSM states).

``make_batch_specs``/``make_decode_specs`` build the matching
ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no allocation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_mod
from repro.models.config import ModelConfig
from repro.optim import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                         AdafactorConfig, AdafactorState, adafactor_init,
                         adafactor_update, cosine_schedule)


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    optimizer: str = "adamw"          # adamw | adafactor
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    q_chunk: int = 1024
    remat: bool = True
    remat_policy: str = "nothing"     # nothing | dots (§Perf hillclimb)


def init_train_state(key: jax.Array, cfg: ModelConfig,
                     tcfg: TrainStepConfig, tp: int = 1
                     ) -> Dict[str, Any]:
    params = model_mod.init_params(key, cfg, tp=tp)
    if tcfg.optimizer == "adamw":
        opt = adamw_init(params)
    else:
        opt = adafactor_init(params)
    return {"params": params, "opt": opt,
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, tcfg: TrainStepConfig = TrainStepConfig(),
                    grad_shardings: Any = None) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``grad_shardings``: optional NamedSharding pytree matching params.
    Constraining grads to the param sharding turns the data-parallel
    gradient sync into a reduce-scatter (ZeRO) instead of the full
    all-reduce GSPMD otherwise emits — §Perf iteration 2.
    """
    ocfg = AdamWConfig(lr=tcfg.peak_lr) if tcfg.optimizer == "adamw" \
        else AdafactorConfig(lr=tcfg.peak_lr)

    def loss_fn(params, batch):
        return model_mod.forward_train(cfg, params, batch,
                                       q_chunk=tcfg.q_chunk,
                                       remat=tcfg.remat,
                                       remat_policy=tcfg.remat_policy)

    def train_step(state, batch):
        (total, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"], batch)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        lr = cosine_schedule(state["step"], tcfg.warmup_steps,
                             tcfg.total_steps, tcfg.peak_lr)
        if tcfg.optimizer == "adamw":
            new_p, new_opt, gnorm = adamw_update(
                grads, state["opt"], state["params"], ocfg, lr=lr)
        else:
            new_p, new_opt = adafactor_update(
                grads, state["opt"], state["params"], ocfg, lr=lr)
            gnorm = jnp.zeros(())
        metrics = dict(metrics, grad_norm=gnorm, lr=lr, total_loss=total)
        return ({"params": new_p, "opt": new_opt, "step": state["step"] + 1},
                metrics)

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int, tp: int = 1
                      ) -> Callable:
    """``prefill(params, batch) -> (logits (B, V), decode_state)``."""

    def prefill(params, batch):
        bsz = batch["tokens"].shape[0]
        state = model_mod.init_decode_state(cfg, bsz, cache_len, tp=tp)
        prefix = batch.get("patch_embeds") if cfg.frontend == "vision" \
            else None
        if cfg.encoder_layers:
            enc_out = model_mod.encode(cfg, params, batch["src_embeds"],
                                       remat=False)
            state = model_mod.fill_cross_caches(cfg, params, state, enc_out)
        logits, state = model_mod.forward_step(cfg, params, batch["tokens"],
                                               state, prefix_embeds=prefix)
        return logits, state

    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    """``decode(params, token (B,1), state) -> (logits, state)``.
    One new token against the existing KV/SSM caches."""

    def decode(params, token, state):
        return model_mod.forward_step(cfg, params, token, state)

    return decode


# ======================================================== ShapeDtypeStructs
def make_batch_specs(cfg: ModelConfig, global_batch: int, seq_len: int
                     ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training/prefill batch stand-ins for one shape cell."""
    text_len = seq_len - (cfg.num_prefix if cfg.frontend == "vision" else 0)
    specs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, text_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, text_len), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((global_batch, text_len),
                                          jnp.float32),
    }
    if cfg.frontend == "vision":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.num_prefix, cfg.d_model), cfg.pdtype)
    if cfg.encoder_layers:
        specs["src_embeds"] = jax.ShapeDtypeStruct(
            (global_batch, seq_len, cfg.d_model), cfg.pdtype)
    return specs


def make_decode_specs(cfg: ModelConfig, global_batch: int, cache_len: int,
                      tp: int = 1) -> Tuple[jax.ShapeDtypeStruct, Any]:
    """(token spec, decode-state spec pytree) for one decode cell."""
    token = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
    state = jax.eval_shape(
        lambda: model_mod.init_decode_state(cfg, global_batch, cache_len,
                                            tp=tp))
    return token, state


def param_specs(cfg: ModelConfig, tp: int = 1) -> Any:
    """Abstract parameter pytree (no allocation) for lowering."""
    return jax.eval_shape(
        lambda: model_mod.init_params(jax.random.key(0), cfg, tp=tp))


def train_state_specs(cfg: ModelConfig, tcfg: TrainStepConfig, tp: int = 1
                      ) -> Any:
    return jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), cfg, tcfg, tp=tp))
