"""Trainer: checkpointed training loop with fault injection hooks.

Production posture on a laptop: the loop is deliberately structured the
way a 1000-node runner would be —

* state lives in one donated pytree; the step is a single jit;
* checkpoints every ``ckpt_every`` steps through the async
  CheckpointManager (atomic rename, retention, corruption-safe restart);
* ``fault_hook(step)`` can raise mid-run (tests kill the trainer at an
  arbitrary step and assert bit-exact continuation from the last
  checkpoint);
* data comes from a ShardedLoader (deterministic over-decomposed shards,
  straggler stealing);
* optional SnS activation monitor (the paper's pipeline as telemetry).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, \
    restore_checkpoint
from repro.models.config import ModelConfig
from repro.train.callbacks import ActivationSketcher
from repro.train.steps import (TrainStepConfig, init_train_state,
                               make_train_step)


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    monitor_activations: bool = False


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainStepConfig,
                 run_cfg: TrainerConfig,
                 batch_fn: Callable[[int], Dict[str, Any]],
                 fault_hook: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.run_cfg = run_cfg
        self.batch_fn = batch_fn
        self.fault_hook = fault_hook
        self.step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=0)
        self.ckpt = CheckpointManager(run_cfg.ckpt_dir, keep=run_cfg.keep)
        self.metrics_log: List[Dict[str, float]] = []
        self.sketcher = ActivationSketcher() \
            if run_cfg.monitor_activations else None

        start = latest_step(run_cfg.ckpt_dir)
        template = jax.eval_shape(
            lambda: init_train_state(jax.random.key(run_cfg.seed), cfg, tcfg))
        if start is not None:
            self.state = restore_checkpoint(run_cfg.ckpt_dir, start,
                                            template)
            self.start_step = start
        else:
            self.state = init_train_state(jax.random.key(run_cfg.seed),
                                          cfg, tcfg)
            self.start_step = 0

    def run(self) -> Dict[str, Any]:
        rc = self.run_cfg
        t0 = time.time()
        step = self.start_step
        try:
            while step < rc.total_steps:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                batch = self.batch_fn(step)
                self.state, metrics = self.step_fn(self.state, batch)
                step += 1
                if self.sketcher is not None and step % rc.log_every == 0:
                    # monitor input embeddings as a cheap residual proxy
                    emb = self.state["params"]["embed"][batch["tokens"][:1]]
                    self.sketcher.observe(emb)
                if step % rc.log_every == 0 or step == rc.total_steps:
                    row = {k: float(v) for k, v in metrics.items()}
                    row["step"] = step
                    self.metrics_log.append(row)
                if step % rc.ckpt_every == 0 or step == rc.total_steps:
                    self.ckpt.save(step, self.state)
        finally:
            self.ckpt.wait()
            self.ckpt.close()
        out = {"final_step": step, "wall_s": time.time() - t0,
               "metrics": self.metrics_log}
        if self.sketcher is not None:
            out["activation_report"] = {
                k: v for k, v in self.sketcher.report().items()
                if k not in ("hh", "grid")}
        return out
