"""Training callbacks — including the paper's pipeline as a *live monitor*.

``ActivationSketcher`` runs Sketch-and-Scale over the model's hidden
states during training: each step, a batch of residual-stream vectors is
random-projected to D ≤ 8 dims, quantized on a fixed grid, and streamed
into a per-process Count Sketch.  At report time the heavy hitters (the
densest cells of representation space, aggregated over EVERY token the
model has seen since the last report) come out, optionally UMAP-embedded.
Because the sketch is linear, multi-host runs psum-merge their sketches —
full-corpus representation maps with O(R·C) memory and traffic, exactly
the paper's pipeline with "geo-distributed edge nodes" = training workers.

For MoE archs the same machinery over router logits detects expert-space
density collapse: HH mass concentrating into few cells = routing collapse.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heavy_hitters as hh_mod
from repro.core import quantize, sketch as sketch_mod
from repro.core.quantize import GridSpec


@dataclasses.dataclass
class ActivationSketcher:
    proj_dims: int = 8
    bins: int = 16
    rows: int = 8
    log2_cols: int = 14
    top_k: int = 256
    seed: int = 0
    box: float = 4.0            # grid half-width in projected units

    def __post_init__(self):
        self._sk = sketch_mod.init(jax.random.key(self.seed),
                                   self.rows, self.log2_cols)
        self._proj = None
        self._grid = GridSpec(
            dims=self.proj_dims, bins=self.bins,
            lo=tuple([-self.box] * self.proj_dims),
            hi=tuple([self.box] * self.proj_dims))
        self._keys: List[np.ndarray] = []
        self.tokens_seen = 0

        @jax.jit
        def _update(sk, proj, acts):
            flat = acts.reshape(-1, acts.shape[-1]).astype(jnp.float32)
            # normalize scale so the fixed grid stays meaningful
            flat = flat / (jnp.linalg.norm(flat, axis=1, keepdims=True)
                           / np.sqrt(flat.shape[1]) + 1e-6)
            z = flat @ proj                               # (N, proj_dims)
            khi, klo = quantize.points_to_keys(self._grid, z)
            return sketch_mod.update_sorted(sk, khi, klo), khi, klo
        self._update = _update

    def observe(self, acts: jnp.ndarray) -> None:
        """acts: (..., d_model) hidden states from the current step."""
        d = acts.shape[-1]
        if self._proj is None:
            self._proj = jax.random.normal(
                jax.random.key(self.seed + 1), (d, self.proj_dims),
                jnp.float32) / np.sqrt(d)
        self._sk, khi, klo = self._update(self._sk, self._proj, acts)
        # keep a bounded reservoir of keys as HH identity candidates
        n = khi.shape[0]
        take = min(n, 4096)
        self._keys.append(np.stack([np.asarray(khi[:take]),
                                    np.asarray(klo[:take])], 1))
        if len(self._keys) > 64:
            self._keys = self._keys[-64:]
        self.tokens_seen += int(np.prod(acts.shape[:-1]))

    def report(self) -> Dict[str, Any]:
        """Extract heavy hitters of representation space."""
        if not self._keys:
            return {"hh_count": 0}
        keys = np.concatenate(self._keys)
        hh = hh_mod.extract(self._sk, jnp.asarray(keys[:, 0]),
                            jnp.asarray(keys[:, 1]), k=self.top_k)
        live = np.asarray(hh.mask)
        counts = np.asarray(hh.count)[live]
        total = float(counts.sum())
        return {
            "hh_count": int(live.sum()),
            "hh_mass": total,
            "hh_top1_frac": float(counts[0] / total) if total else 0.0,
            "hh": hh,
            "grid": self._grid,
            "tokens_seen": self.tokens_seen,
        }

    def merged(self, other: "ActivationSketcher") -> sketch_mod.CountSketch:
        """Cross-worker merge (linearity): local sketches simply add."""
        return sketch_mod.merge(self._sk, other._sk)


@dataclasses.dataclass
class RouterCollapseMonitor:
    """HH concentration over router logits — routing-collapse alarm."""
    sketcher: Optional[ActivationSketcher] = None
    alarm_top1_frac: float = 0.5

    def __post_init__(self):
        if self.sketcher is None:
            self.sketcher = ActivationSketcher(proj_dims=4, bins=12,
                                               top_k=64, seed=17)

    def observe(self, router_logits: jnp.ndarray) -> None:
        self.sketcher.observe(router_logits)

    def check(self) -> Dict[str, Any]:
        rep = self.sketcher.report()
        rep["collapsed"] = rep.get("hh_top1_frac", 0.0) > self.alarm_top1_frac
        return rep
