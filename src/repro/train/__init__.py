from repro.train.steps import (TrainStepConfig, make_train_step,
                               make_prefill_step, make_decode_step,
                               make_batch_specs, make_decode_specs)
