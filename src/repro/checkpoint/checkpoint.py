"""Fault-tolerant checkpointing: sharded npz + manifest, atomic rename,
async writer, elastic resume.

Layout:  <dir>/step_<N>/
             manifest.json     — leaf paths, shapes, dtypes, shard info,
                                 sharding PartitionSpecs (as strings)
             arrays.npz        — one entry per flattened leaf path
         <dir>/step_<N>.tmp/   — in-flight write (atomic rename commits)

Restart discovers the newest *complete* step (manifest present and every
array readable); corrupt/partial steps are skipped — the fault-injection
test kills a writer mid-flight and asserts recovery from the previous
step.

Elastic resume: arrays are saved logically (full value, gathered), so a
checkpoint written on an 8-device mesh restores onto 4 or 16 devices —
``restore_checkpoint`` re-device_puts against whatever shardings the new
run supplies.  (On a real multi-host pod each host writes its own shard
file; the manifest format already carries shard metadata for that
extension.)
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    """Resolve dtype names incl. ml_dtypes (bfloat16, float8_*)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "name"):
                parts.append(str(k.name))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra_meta: Optional[Dict[str, Any]] = None) -> str:
    """Write one checkpoint atomically.  Returns the committed path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "format": 1, "leaves": [],
                "meta": extra_meta or {}}
    for path, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        # npz cannot round-trip ml_dtypes (bfloat16, f8): store raw bytes,
        # dtype+shape live in the manifest
        arrays[path] = np.frombuffer(arr.tobytes(), np.uint8)
        manifest["leaves"].append({
            "path": path, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    # manifest LAST: its presence marks the step as complete
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _is_complete(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            names = set(z.files)
        return all(l["path"] in names for l in manifest["leaves"])
    except Exception:                                        # noqa: BLE001
        return False


def latest_step(directory: str) -> Optional[int]:
    """Newest complete checkpoint step, skipping corrupt/partial ones."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    for s in sorted(steps, reverse=True):
        if _is_complete(os.path.join(directory, f"step_{s:08d}")):
            return s
    return None


def restore_checkpoint(directory: str, step: int, like: Any,
                       shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (values replaced).

    ``shardings``: optional pytree of NamedShardings for elastic resume
    onto a different mesh/device count — arrays are device_put per leaf.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    meta = {l["path"]: l for l in manifest["leaves"]}
    with np.load(os.path.join(path, "arrays.npz")) as z:
        data = {k: z[k] for k in z.files}
    leaves = _flatten_with_paths(like)
    sh_leaves = None
    if shardings is not None:
        sh_leaves = [s for _, s in _flatten_with_paths(shardings)]
    new = []
    for i, (p, leaf) in enumerate(leaves):
        if p not in data:
            raise KeyError(f"checkpoint missing leaf {p}")
        m = meta[p]
        arr = np.frombuffer(data[p].tobytes(), _np_dtype(m["dtype"])) \
            .reshape(m["shape"])
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        if sh_leaves is not None:
            new.append(jax.device_put(arr, sh_leaves[i]))
        else:
            new.append(jax.device_put(arr))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, new)


class CheckpointManager:
    """Async checkpointing with bounded queue + retention policy."""

    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._thread: Optional[threading.Thread] = None
        self._errors: List[str] = []
        os.makedirs(directory, exist_ok=True)
        if async_write:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, meta = item
            try:
                save_checkpoint(self.directory, step, tree, meta)
                self._gc()
            except Exception as e:                           # noqa: BLE001
                self._errors.append(f"step {step}: {e}")
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(s for s in (
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(
                self.directory, f"step_{s:08d}"), ignore_errors=True)

    def save(self, step: int, tree: Any,
             meta: Optional[Dict[str, Any]] = None) -> None:
        # device_get BEFORE queuing so the training step can be donated
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        if self.async_write:
            self._q.put((step, host_tree, meta))
        else:
            save_checkpoint(self.directory, step, host_tree, meta)
            self._gc()

    def wait(self) -> None:
        if self.async_write:
            self._q.join()
        if self._errors:
            raise RuntimeError("; ".join(self._errors))

    def close(self) -> None:
        if self.async_write and self._thread is not None:
            self._q.join()
            self._q.put(None)
            self._thread.join(timeout=30)
            self._thread = None
