"""AdamW with decoupled weight decay and global-norm clipping.

States are f32 regardless of param dtype (bf16 params get f32 master
copies folded into the update via the f32 m/v and cast on write)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 cfg: AdamWConfig, lr: Optional[jnp.ndarray] = None
                 ) -> Tuple[Any, AdamWState, jnp.ndarray]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    lr = cfg.lr if lr is None else lr
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm
