"""Adafactor (Shazeer & Stern 2018): factored second moments.

For ≥2-D params the v statistics are stored as row/col vectors instead of
a full matrix — the optimizer state for a 100B model drops from 800 GB to
~param size, which is what makes the ≥100B assigned archs trainable on
the briefed 16 GB/chip budget (DESIGN.md §6)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8          # t^-decay second-moment decay schedule
    eps: float = 1e-30
    clip_threshold: float = 1.0
    min_dim_size_to_factor: int = 128
    weight_decay: float = 0.0


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any      # row stats (or full v for small/1-D params)
    vc: Any      # col stats (or None sentinel zeros)
    factored: Any   # static bool pytree mirrored as arrays


def _should_factor(shape, min_size) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_size and shape[-2] >= min_size


def adafactor_init(params: Any, cfg: AdafactorConfig = AdafactorConfig()
                   ) -> AdafactorState:
    def vr_init(p):
        if _should_factor(p.shape, cfg.min_dim_size_to_factor):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc_init(p):
        if _should_factor(p.shape, cfg.min_dim_size_to_factor):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(vr_init, params),
        vc=jax.tree.map(vc_init, params),
        factored=jax.tree.map(
            lambda p: _should_factor(p.shape, cfg.min_dim_size_to_factor),
            params))


def adafactor_update(grads: Any, state: AdafactorState, params: Any,
                     cfg: AdafactorConfig,
                     lr: Optional[jnp.ndarray] = None
                     ) -> Tuple[Any, AdafactorState]:
    lr = cfg.lr if lr is None else lr
    step = state.step + 1
    beta2 = 1.0 - step.astype(jnp.float32) ** (-cfg.decay)

    def upd(p, g, vr, vc, factored):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + cfg.eps
        if factored:
            vr2 = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc2 = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            row_mean = jnp.mean(vr2, axis=-1, keepdims=True)
            u = gf / (jnp.sqrt(vr2 / row_mean)[..., None]
                      * jnp.sqrt(vc2)[..., None, :])
        else:
            vr2 = beta2 * vr + (1 - beta2) * g2
            vc2 = vc
            u = gf / jnp.sqrt(vr2)
        # update clipping (RMS)
        rms = jnp.sqrt(jnp.mean(u * u))
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        new_p = p.astype(jnp.float32) - lr * u \
            - lr * cfg.weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), vr2, vc2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_vr = treedef.flatten_up_to(state.vr)
    flat_vc = treedef.flatten_up_to(state.vc)
    flat_f = treedef.flatten_up_to(state.factored)
    out = [upd(p, g, vr, vc, f) for p, g, vr, vc, f
           in zip(flat_p, flat_g, flat_vr, flat_vc, flat_f)]
    return (treedef.unflatten([o[0] for o in out]),
            AdafactorState(step=step,
                           vr=treedef.unflatten([o[1] for o in out]),
                           vc=treedef.unflatten([o[2] for o in out]),
                           factored=state.factored))
