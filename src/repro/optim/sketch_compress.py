"""Count-Sketch gradient compression with error feedback (SketchSGD /
FetchSGD — the paper's refs [9] and [20], built on the paper's own data
structure).

Instead of all-reducing the full gradient (2·|params| bytes over the
wire), each data shard sketches its *local* gradient into an (R, C) Count
Sketch and the **sketches** are all-reduced — valid because the sketch is
linear: Σ_w sketch(g_w) = sketch(Σ_w g_w).  The merged sketch recovers
the top-k heaviest coordinates (momentum-accumulated, error-feedback
corrected), which are the only coordinates applied.

Wire bytes per step drop from 2·N to 4·R·C + (k index/value exchange):
for a 1.1B-param model with R=8, C=2²⁰, that is 260× less cross-pod
traffic — the same linearity that lets the paper merge geo-distributed
sketches makes the DCN collective cheap (EXPERIMENTS.md §Perf).

SPMD usage (inside shard_map over the data axes):

    sk = local_sketch(grads, state)           # per-shard
    sk = sketch.psum_merge(sk, ("data","pod"))  # hierarchical merge
    updates, state = decompress(sk, state)    # identical on every shard

Error feedback keeps un-transmitted mass: e ← (e + g) − transmitted, the
standard fix for biased compression (Karimireddy et al. 2019).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sketch_mod
from repro.core.sketch import CountSketch


@dataclasses.dataclass(frozen=True)
class SketchCompressConfig:
    rows: int = 8
    log2_cols: int = 18
    top_k: int = 10_000          # coordinates applied per step
    momentum: float = 0.9
    seed: int = 0


class SketchCompressState(NamedTuple):
    error: Any                   # pytree like params — error feedback
    momentum: Any                # pytree like params — server momentum
    sizes: Any                   # static leaf sizes (aux, not traced)


def _flatten(tree: Any) -> Tuple[jnp.ndarray, Any, list]:
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    return flat, treedef, sizes


def _unflatten(flat: jnp.ndarray, like: Any) -> Any:
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape))
        out.append(flat[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return treedef.unflatten(out)


def sketch_compress_init(params: Any, cfg: SketchCompressConfig
                         ) -> SketchCompressState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return SketchCompressState(
        error=jax.tree.map(zeros, params),
        momentum=jax.tree.map(zeros, params),
        sizes=jax.tree.map(lambda p: int(np.prod(p.shape)), params))


def make_sketch(cfg: SketchCompressConfig) -> CountSketch:
    """Shared hash functions — every worker must build the identical sketch
    (the paper's 'same hashing functions at every site' contract)."""
    return sketch_mod.init(jax.random.key(cfg.seed), cfg.rows, cfg.log2_cols)


def local_sketch(grads: Any, state: SketchCompressState,
                 cfg: SketchCompressConfig) -> CountSketch:
    """Per-shard: sketch (momentum + error-feedback corrected) gradient."""
    flat, _, _ = _flatten(grads)
    sk = make_sketch(cfg)
    return sketch_mod.tensor_sketch_update(sk, flat)


def decompress(merged: CountSketch, grads_like: Any,
               state: SketchCompressState, cfg: SketchCompressConfig
               ) -> Tuple[Any, SketchCompressState, jnp.ndarray]:
    """Recover top-k coordinates from the merged sketch, apply momentum +
    error feedback in the *virtual* full-gradient space.

    FetchSGD order: momentum and error feedback both live sketch-side in
    the original paper; we keep them coordinate-side (equivalent for
    linear ops, simpler to shard) — momentum on the estimated gradient,
    error = previous error + estimate − transmitted.
    """
    flat_err, _, _ = _flatten(state.error)
    n = flat_err.shape[0]
    est = sketch_mod.tensor_sketch_estimate(merged, n)      # (N,) f32
    flat_mom, _, _ = _flatten(state.momentum)
    mom = cfg.momentum * flat_mom + est
    corrected = mom + flat_err
    # top-k magnitude selection (k-th LARGEST |coordinate| is the cut)
    k = min(cfg.top_k, n)
    thresh = jax.lax.top_k(jnp.abs(corrected), k)[0][-1]
    keep = jnp.abs(corrected) >= jnp.maximum(thresh, 1e-30)
    transmitted = jnp.where(keep, corrected, 0.0)
    new_err = corrected - transmitted
    # momentum resets on transmitted coordinates (FetchSGD §3.2)
    new_mom = jnp.where(keep, 0.0, mom)
    new_state = SketchCompressState(
        error=_unflatten(new_err, state.error),
        momentum=_unflatten(new_mom, state.momentum),
        sizes=state.sizes)
    density = jnp.sum(keep.astype(jnp.float32)) / n
    return _unflatten(transmitted, grads_like), new_state, density


def compress_and_reduce(grads: Any, state: SketchCompressState,
                        cfg: SketchCompressConfig, axis_names=None
                        ) -> Tuple[Any, SketchCompressState, jnp.ndarray]:
    """One full compression round.  ``axis_names``: mesh axes to merge over
    (None = single process, merge is identity)."""
    sk = local_sketch(grads, state, cfg)
    if axis_names:
        for ax in (axis_names if isinstance(axis_names, (tuple, list))
                   else (axis_names,)):
            sk = sketch_mod.psum_merge(sk, ax)
    return decompress(sk, grads, state, cfg)
