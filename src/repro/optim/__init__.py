from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update
from repro.optim.adafactor import (AdafactorConfig, AdafactorState,
                                   adafactor_init, adafactor_update)
from repro.optim.schedule import cosine_schedule, linear_warmup
from repro.optim.sketch_compress import (SketchCompressConfig,
                                         SketchCompressState,
                                         sketch_compress_init,
                                         compress_and_reduce)
