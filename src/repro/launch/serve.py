"""Production serving launcher: continuous batched decode.

    python -m repro.launch.serve --arch jamba-v0.1-52b --smoke \
        --batch 8 --prompt-len 64 --gen 32 [--mesh 2,2]

Prefill + decode loop with KV/SSM caches — the same serve_step the
decode_32k / long_500k dry-run cells lower at pod scale.  (LM stack
only: the Sketch-and-Scale serving API is ``core.service.SnsService``,
demoed by examples/sns_service.py.)
"""
import os
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--host-devices", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import time
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import model as model_mod
    from repro.train.steps import make_prefill_step, make_decode_step

    cfg = get_config(args.arch, smoke=args.smoke)
    params = model_mod.init_params(jax.random.key(0), cfg)
    cache_len = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    decode = jax.jit(make_decode_step(cfg))

    key = jax.random.key(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.num_prefix, cfg.d_model), cfg.pdtype)
    if cfg.encoder_layers:
        batch["src_embeds"] = 0.02 * jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), cfg.pdtype)

    t0 = time.perf_counter()
    logits, state = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"[prefill] {args.batch}x{args.prompt_len} "
          f"{(time.perf_counter() - t0) * 1e3:.0f} ms")

    def sample(logits, k):
        if args.temperature <= 0:
            return jnp.argmax(logits, -1)
        return jax.random.categorical(k, logits / args.temperature, -1)

    tok = sample(logits, key)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    n_out = 1
    for i in range(args.gen - 1):
        logits, state = decode(params, tok, state)
        tok = sample(logits, jax.random.fold_in(key, i))[:, None] \
            .astype(jnp.int32)
        n_out += 1
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"[decode] {n_out - 1} steps, "
          f"{dt * 1e3 / max(n_out - 1, 1):.1f} ms/token, "
          f"{args.batch * (n_out - 1) / dt:.0f} tok/s aggregate")


if __name__ == "__main__":
    main()
