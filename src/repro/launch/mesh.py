"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run must
set XLA_FLAGS before any jax initialization.

Axis semantics:
  "pod"   — across TPU pods / data centers (DCN links, ~25 GB/s/host).
            Only sketch merges and gradient reductions cross it.
  "data"  — data parallel + FSDP shard axis inside a pod (ICI).
  "model" — tensor/expert parallel axis (ICI).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: Tuple[int, ...], axes: Sequence[str]):
    """Arbitrary small mesh for tests/examples on host devices."""
    return jax.make_mesh(shape, tuple(axes))


def dp_axes(mesh) -> Tuple[str, ...]:
    """Data-parallel axes of a mesh = every axis that is not 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def tp_size(mesh) -> int:
    return int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
