"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: the
compiled artifact yields memory_analysis (fits?), cost_analysis
(FLOPs/bytes for §Roofline), and the optimized HLO (collective bytes).

Usage:
    python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results/]
    python -m repro.launch.dryrun --all --both-meshes   # the full matrix

Results are one JSON per cell (resumable: existing files are skipped).
"""
# The VERY FIRST lines — before ANY other import, jax locks the device
# count on first init:
import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import sharding as shlib
from repro.launch.mesh import dp_axes, dp_size, make_production_mesh, tp_size
from repro.models.config import SHAPES, shape_applicable
from repro.train.steps import (TrainStepConfig, make_train_step,
                               make_prefill_step, make_decode_step,
                               make_batch_specs, make_decode_specs,
                               param_specs, train_state_specs)

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str, pod_boundary: int = 256
                              ) -> Dict[str, Any]:
    """Sum result-shape bytes of every collective op in the optimized HLO.

    Uses the op RESULT type (for all-gather/all-to-all the result is the
    full gathered tensor = wire bytes; for all-reduce/reduce-scatter ~the
    reduced payload).  Cross-pod ops are detected from replica_groups
    containing device ids on both sides of ``pod_boundary``.
    """
    per_kind: Dict[str, int] = {}
    dcn_bytes = 0
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:          # async pairs: count the start only
            continue
        _, type_str, kind = m.groups()
        b = _shape_bytes(type_str)
        per_kind[kind] = per_kind.get(kind, 0) + b
        count += 1
        gm = re.search(r"replica_groups=\{?\{([^}]*)\}", line)
        if gm:
            try:
                ids = [int(x) for x in gm.group(1).split(",") if x.strip()]
                if ids and (min(ids) < pod_boundary <= max(ids)):
                    dcn_bytes += b
            except ValueError:
                pass
    return {"per_kind": per_kind, "total": sum(per_kind.values()),
            "dcn": dcn_bytes, "num_ops": count}


def _mem_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        return {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(ma, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
        }
    except Exception as e:                                   # noqa: BLE001
        return {"error": str(e)}


def _cost_dict(compiled) -> Dict[str, Any]:
    try:
        ca = compiled.cost_analysis()
        if ca is None:
            return {}
        keep = {}
        for k, v in ca.items():
            if k in ("flops", "bytes accessed", "optimal_seconds") or \
                    k.startswith("bytes accessed"):
                keep[k] = float(v)
        return keep
    except Exception as e:                                   # noqa: BLE001
        return {"error": str(e)}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             policy: Optional[shlib.ShardingPolicy] = None,
             save_hlo: Optional[str] = None,
             remat_policy: str = "nothing",
             capacity_factor: Optional[float] = None) -> Dict[str, Any]:
    """Lower + compile one cell; return the record for §Dry-run."""
    pol = policy or shlib.ShardingPolicy()
    cfg = get_config(arch)
    if capacity_factor is not None:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, capacity_factor=capacity_factor)
    shp = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape_name)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mesh": "(2,16,16)" if multi_pod else "(16,16)",
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = tp_size(mesh)
    dp = dp_axes(mesh)
    shlib.set_activation_sharding(mesh, dp, pol.tp_axis,
                                  act_mode=pol.act_mode,
                                  moe_constraint=pol.moe_constraint)
    rec["policy"] = {"act_mode": pol.act_mode, "fsdp": pol.fsdp,
                     "moe_constraint": pol.moe_constraint,
                     "remat_policy": remat_policy}
    t0 = time.time()
    try:
        if shp.kind == "train":
            tcfg = TrainStepConfig(remat_policy=remat_policy)
            state_shape = train_state_specs(cfg, tcfg, tp=tp)
            batch_shape = make_batch_specs(cfg, shp.global_batch, shp.seq_len)
            state_sh = shlib.to_shardings(
                mesh, shlib.train_state_pspecs(state_shape, pol))
            batch_sh = shlib.to_shardings(
                mesh, shlib.batch_pspecs(batch_shape, mesh))
            step = make_train_step(cfg, tcfg,
                                   grad_shardings=state_sh["params"])
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None), donate_argnums=(0,))
            lowered = jitted.lower(state_shape, batch_shape)
        elif shp.kind == "prefill":
            p_shape = param_specs(cfg, tp=tp)
            batch_shape = make_batch_specs(cfg, shp.global_batch, shp.seq_len)
            p_sh = shlib.to_shardings(mesh, shlib.param_pspecs(p_shape, pol))
            batch_sh = shlib.to_shardings(
                mesh, shlib.batch_pspecs(batch_shape, mesh))
            _, dstate_shape = make_decode_specs(cfg, shp.global_batch,
                                                shp.seq_len, tp=tp)
            dstate_sh = shlib.to_shardings(
                mesh, shlib.decode_state_pspecs(dstate_shape, mesh,
                                                shp.global_batch, pol))
            fn = make_prefill_step(cfg, shp.seq_len, tp=tp)
            jitted = jax.jit(fn, in_shardings=(p_sh, batch_sh),
                             out_shardings=(None, dstate_sh))
            lowered = jitted.lower(p_shape, batch_shape)
        else:  # decode
            p_shape = param_specs(cfg, tp=tp)
            p_sh = shlib.to_shardings(mesh, shlib.param_pspecs(p_shape, pol))
            token_shape, dstate_shape = make_decode_specs(
                cfg, shp.global_batch, shp.seq_len, tp=tp)
            dstate_sh = shlib.to_shardings(
                mesh, shlib.decode_state_pspecs(dstate_shape, mesh,
                                                shp.global_batch, pol))
            token_sh = NamedSharding(
                mesh, P(dp if shp.global_batch >= dp_size(mesh) else None,
                        None))
            fn = make_decode_step(cfg)
            jitted = jax.jit(fn, in_shardings=(p_sh, token_sh, dstate_sh),
                             out_shardings=(None, dstate_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(p_shape, token_shape, dstate_shape)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        hlo = compiled.as_text()
        from repro.launch.hlo_analysis import analyze_hlo
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": _mem_dict(compiled),
            "cost": _cost_dict(compiled),
            "collectives": collective_bytes_from_hlo(hlo),
            "hlo_tripaware": analyze_hlo(hlo),
            "hlo_lines": hlo.count("\n"),
            "param_count": cfg.param_count(),
            "active_param_count": cfg.active_param_count(),
            "global_batch": shp.global_batch,
            "seq_len": shp.seq_len,
            "kind": shp.kind,
            "devices": int(np.prod(list(mesh.shape.values()))),
        })
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
    except Exception as e:                                   # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    finally:
        shlib.set_activation_sharding(None, None, None)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--act-mode", default="embed_tp",
                    choices=("embed_tp", "seq_tp", "dp_only"))
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--moe-constraint", action="store_true")
    ap.add_argument("--remat-policy", default="nothing",
                    choices=("nothing", "dots"))
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    policy = shlib.ShardingPolicy(
        fsdp=not args.no_fsdp, act_mode=args.act_mode,
        moe_constraint=args.moe_constraint)

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                if args.both_meshes:
                    cells.append((a, s, False))
                    cells.append((a, s, True))
                else:
                    cells.append((a, s, args.multi_pod))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required without --all")
        cells = [(args.arch, args.shape, args.multi_pod)]

    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'2pod' if mp else '1pod'}"
        if args.tag:
            tag += f"__{args.tag}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[skip existing] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        rec = run_cell(arch, shape, mp, policy=policy,
                       save_hlo=args.save_hlo,
                       remat_policy=args.remat_policy,
                       capacity_factor=args.capacity_factor)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            fl = rec["cost"].get("flops", 0)
            extra = (f" flops={fl:.3e} coll={rec['collectives']['total']:.3e}B"
                     f" compile={rec['compile_s']}s")
        elif status == "error":
            extra = " " + rec["error"][:200]
        print(f"[{status}] {tag}{extra}", flush=True)


if __name__ == "__main__":
    main()
