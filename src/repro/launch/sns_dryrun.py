"""Dry-run + roofline for the paper's OWN pipeline at pod scale.

Lowers ``geo_extract``'s SPMD program (quantize → pack → per-device
Count Sketch update + local top-L → hierarchical psum merge → all-gather
candidates → global top-K) on the production mesh, with a configurable
per-device batch: 512 devices × 2²⁰ points/step ≈ 5.4·10⁸ points per
step — the paper's "billions across data centers" regime is a few such
steps.

    python -m repro.launch.sns_dryrun [--multi-pod] [--rows 16]
        [--log2-cols 18] [--top-k 20000] [--pool 0] [--per-device 1048576]
        [--out results/sns_perf/baseline.json]
"""
import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import candidates as cand_mod
from repro.core import geo
from repro.core import heavy_hitters as hh_mod
from repro.core import quantize, sketch as sketch_mod
from repro.core.quantize import GridSpec
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rows", type=int, default=16)
    ap.add_argument("--log2-cols", type=int, default=18)
    ap.add_argument("--top-k", type=int, default=20_000)
    ap.add_argument("--pool", type=int, default=0,
                    help="candidate pool per shard (0 -> 2*top_k)")
    ap.add_argument("--per-device", type=int, default=1 << 20)
    ap.add_argument("--dims", type=int, default=8)
    ap.add_argument("--bins", type=int, default=25)
    ap.add_argument("--update", choices=("sorted", "scatter"),
                    default="sorted")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    data_axes = tuple(a for a in mesh.axis_names)   # all axes carry data
    n_dev = int(np.prod(list(mesh.shape.values())))
    n_total = n_dev * args.per_device
    pool = args.pool or 2 * args.top_k

    grid = GridSpec(dims=args.dims, bins=args.bins,
                    lo=tuple([0.0] * args.dims), hi=tuple([1.0] * args.dims))
    sk0 = sketch_mod.init(jax.random.key(0), args.rows, args.log2_cols)
    upd = sketch_mod.update_sorted if args.update == "sorted" \
        else sketch_mod.update

    @geo.shard_map_compat(mesh=mesh, in_specs=(P(), P(data_axes)),
                          out_specs=(P(), P()))
    def spmd(sk, pts):
        key_hi, key_lo = quantize.points_to_keys(grid, pts)
        sk_local = upd(sk, key_hi, key_lo)
        cands = cand_mod.local_topk(key_hi, key_lo, pool)
        hh, merged = hh_mod.distributed_extract(
            sk_local, cands, args.top_k, merge_axes=data_axes)
        return hh, merged

    pts_spec = jax.ShapeDtypeStruct((n_total, args.dims), jnp.float32)
    sk_spec = jax.eval_shape(lambda: sk0)
    pts_sh = NamedSharding(mesh, P(data_axes))
    sk_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), sk_spec)

    t0 = time.time()
    lowered = jax.jit(spmd, in_shardings=(sk_sh, pts_sh)).lower(
        sk_spec, pts_spec)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    ana = analyze_hlo(hlo)
    rec = {
        "config": vars(args), "devices": n_dev, "points_per_step": n_total,
        "mesh": "(2,16,16)" if args.multi_pod else "(16,16)",
        "compile_s": round(time.time() - t0, 1),
        "hlo_tripaware": ana,
        "cost": {k: float(v) for k, v in
                 (compiled.cost_analysis() or {}).items()
                 if k in ("flops", "bytes accessed")},
    }
    # roofline terms (per device)
    tc = ana["flops"] / 197e12
    tm = ana["bytes"] / 819e9
    ici = ana["collective_bytes"] - ana["collective_dcn_bytes"]
    tcl = ici / 50e9 + ana["collective_dcn_bytes"] / 25e9
    rec["roofline"] = {
        "compute_ms": round(tc * 1e3, 3), "memory_ms": round(tm * 1e3, 3),
        "collective_ms": round(tcl * 1e3, 3),
        "bottleneck": max([("compute", tc), ("memory", tm),
                           ("collective", tcl)], key=lambda x: x[1])[0],
        "points_per_sec_at_bound": n_total / max(tc, tm, tcl),
    }
    out = json.dumps(rec, indent=1)
    print(out)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            f.write(out)


if __name__ == "__main__":
    main()
