"""Production training launcher.

    python -m repro.launch.train --arch tinyllama-1.1b --smoke \
        --steps 100 --batch 8 --seq 128 [--mesh 2,2,2] [--act-mode seq_tp]

On this container only smoke configs are trainable for real; the full
configs train through the identical code path on a pod (the mesh flag
accepts any shape whose product equals the device count).  Checkpoints,
fault-tolerant restart, activation monitoring and the sharded data
pipeline are all on by default — this is the entry point a cluster job
would exec per host.
"""
import os
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=("adamw", "adafactor"))
    ap.add_argument("--mesh", default="",
                    help="comma shape, e.g. 2,2,2 -> (pod,data,model); "
                         "empty = single device")
    ap.add_argument("--act-mode", default="seq_tp",
                    choices=("embed_tp", "seq_tp", "dp_only"))
    ap.add_argument("--host-devices", type=int, default=0,
                    help="fake host devices (testing the mesh path on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--monitor", action="store_true",
                    help="SnS activation monitor")
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import jax
    from repro.configs import get_config
    from repro.data import zipf_token_stream
    from repro.launch import sharding as shlib
    from repro.train.steps import TrainStepConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        names = ("pod", "data", "model")[-len(shape):]
        mesh = jax.make_mesh(shape, names)
        dp = tuple(a for a in names if a != "model")
        shlib.set_activation_sharding(mesh, dp, "model",
                                      act_mode=args.act_mode)
        print(f"[mesh] {dict(mesh.shape)} act_mode={args.act_mode}")

    tcfg = TrainStepConfig(optimizer=args.optimizer, peak_lr=args.lr,
                           warmup_steps=max(args.steps // 10, 1),
                           total_steps=args.steps,
                           q_chunk=min(1024, args.seq))
    rc = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, log_every=10,
                       monitor_activations=args.monitor)

    def batch_fn(step):
        return zipf_token_stream(jax.random.key(step), args.batch,
                                 args.seq, cfg.vocab_size)

    tr = Trainer(cfg, tcfg, rc, batch_fn)
    if tr.start_step:
        print(f"[resume] from step {tr.start_step}")
    out = tr.run()
    for m in out["metrics"]:
        print(f"  step {int(m['step']):5d} loss {m['loss']:.4f} "
              f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f}")
    print(f"[done] {out['final_step']} steps in {out['wall_s']:.1f}s; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
