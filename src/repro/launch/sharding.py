"""Sharding rules: parameter / optimizer / batch / decode-state
PartitionSpecs for the production mesh.

Baseline policy (hillclimbed variants in EXPERIMENTS.md §Perf):

* weights: TP over "model" on heads / d_ff / experts / d_inner / vocab,
  FSDP (ZeRO-3) over "data" on the other big dim — gathered per-layer
  inside the scan by GSPMD;
* activations at layer boundaries: (batch → dp axes, seq → None,
  embed → "model") — Megatron-SP style, keeps the 80-layer residual
  stream at 1/16 size per device;
* KV caches (decode): batch → dp, seq → "model" (flash-decoding style:
  GSPMD turns softmax/context over the sharded seq dim into the
  max/sum/weighted-V all-reduce combine);  batch-1 long-context shards
  seq over every axis.

Param rules are matched by tree *path* (leaf names are stable across all
families), so one table covers every assigned arch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = True              # shard weights over "data" (ZeRO-3)
    fsdp_axis: str = "data"
    tp_axis: str = "model"
    seq_shard_decode: bool = True  # KV-cache seq over "model"
    # activation layout at layer boundaries (hillclimbed — §Perf):
    #   "embed_tp": (dp, None, "model")  Megatron-SP style   [baseline]
    #   "seq_tp":   (dp, "model", None)  sequence-parallel blocks
    #   "dp_only":  (dp, None, None)     replicated over model
    act_mode: str = "embed_tp"
    moe_constraint: bool = False   # pin (E,C,D) dispatch to ("model",dp,None)

    @property
    def act_embed_tp(self) -> bool:
        return self.act_mode == "embed_tp"


def _leaf_spec(path: str, ndim: int, pol: ShardingPolicy) -> P:
    """PartitionSpec for one parameter leaf.  ``path`` is '/'-joined."""
    fs = pol.fsdp_axis if pol.fsdp else None
    tp = pol.tp_axis
    name = path.split("/")[-1]
    stacked = path.startswith("blocks") or "blocks" in path
    pre = (None,) if stacked else ()

    def spec(*dims):
        return P(*(pre + dims))

    if name in ("embed", "lm_head"):
        return P(tp, fs)
    if name == "patch_proj":
        return P(None, tp)
    if name in ("final_norm", "enc_final_norm"):
        return P(None)
    # ---- attention (AttnParams fields) ----
    if name == "wq":
        return spec(fs, tp, None)
    if name in ("wk", "wv"):
        return spec(fs, None, None)       # KV heads may be < TP; replicate
    if name == "wo":
        return spec(tp, None, fs)
    if name == "bq":
        return spec(tp, None)
    if name in ("bk", "bv"):
        return spec(None, None)
    # ---- mlp ----
    if name in ("w_gate", "w_up") and ndim == len(pre) + 2:
        return spec(fs, tp)
    if name == "w_down" and ndim == len(pre) + 2:
        return spec(tp, fs)
    # ---- moe (expert-stacked 3D) ----
    if name == "router":
        return spec(fs, None)
    if name in ("w_gate", "w_up"):        # (E, D, F)
        return spec(tp, fs, None)
    if name == "w_down":                  # (E, F, D)
        return spec(tp, None, fs)
    # ---- ssm ----
    if name in ("w_z", "w_x"):
        return spec(fs, tp)
    if name in ("w_b", "w_c", "w_dt"):
        return spec(fs, None)
    if name == "conv_x":
        return spec(None, tp)
    if name in ("conv_b", "conv_c"):
        return spec(None, None)
    if name == "conv_bias_x":
        return spec(tp)
    if name in ("conv_bias_b", "conv_bias_c"):
        return spec(None)
    if name in ("a_log", "d_skip", "dt_bias"):
        return spec(tp)
    if name == "w_out":
        return spec(tp, fs)
    if name == "norm_scale":
        return spec(tp)
    if name.startswith("norm"):
        return spec(None)
    # fallback: replicate
    return P(*([None] * ndim))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspecs(params_shape: Any, pol: ShardingPolicy = ShardingPolicy()
                 ) -> Any:
    """PartitionSpec pytree matching an (abstract) param pytree."""
    def one(path, leaf):
        return _leaf_spec(_path_str(path), len(leaf.shape), pol)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_pspecs(opt_shape: Any, params_shape: Any,
               pol: ShardingPolicy = ShardingPolicy()) -> Any:
    """Optimizer state mirrors param sharding (m/v same shape as params);
    scalar step replicated; Adafactor row/col stats replicated (small)."""
    pspecs = param_pspecs(params_shape, pol)
    # structural: AdamW m/v mirror the param tree -> reuse param specs;
    # other optimizers' stats are O(sqrt(param)) and stay replicated.
    from repro.optim import AdamWState
    if isinstance(opt_shape, AdamWState):
        return AdamWState(step=P(), m=pspecs, v=pspecs)
    # adafactor / other: replicate stats (they are O(sqrt(param)) size)
    return jax.tree.map(lambda l: P(*([None] * len(l.shape))), opt_shape)


def train_state_pspecs(state_shape: Any,
                       pol: ShardingPolicy = ShardingPolicy()) -> Any:
    return {"params": param_pspecs(state_shape["params"], pol),
            "opt": opt_pspecs(state_shape["opt"], state_shape["params"], pol),
            "step": P()}


def batch_pspecs(batch_shape: Any, mesh: Mesh) -> Any:
    """Batch dim over every non-model axis; everything else replicated."""
    from repro.launch.mesh import dp_axes
    dp = dp_axes(mesh)

    def one(leaf):
        dims = (dp,) + (None,) * (len(leaf.shape) - 1)
        return P(*dims)
    return jax.tree.map(one, batch_shape)


def decode_state_pspecs(state_shape: Any, mesh: Mesh, global_batch: int,
                        pol: ShardingPolicy = ShardingPolicy()) -> Any:
    """KV caches (nsb, B, T, KVH, hd); ssm states (nsb, B, H, P, N);
    conv lookbacks (nsb, B, W-1, Ch); pos scalar."""
    from repro.launch.mesh import dp_axes, dp_size
    dp = dp_axes(mesh)
    batch_shardable = global_batch >= dp_size(mesh) and global_batch > 1
    bdim = dp if batch_shardable else None
    # seq axis of caches: "model" when batch is sharded; every axis when
    # batch-1 long-context (the only way to fit 512k slots)
    seq_axes = pol.tp_axis if batch_shardable else tuple(dp) + (pol.tp_axis,)

    def one(path, leaf):
        ps = _path_str(path)
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        if ps.endswith(("/k", "/v")):
            return P(None, bdim, seq_axes if pol.seq_shard_decode else None,
                     None, None)
        if ps.endswith("/ssm"):
            return P(None, bdim, pol.tp_axis, None, None)
        if ps.endswith("/conv_x"):
            return P(None, bdim, None, pol.tp_axis)
        if ps.endswith("/conv_bc"):
            return P(None, bdim, None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, state_shape)


def to_shardings(mesh: Mesh, pspec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------ activation context
_ACT_CTX: dict = {"mesh": None, "dp": None, "tp": None,
                  "act_mode": "embed_tp", "moe_constraint": False}


def set_activation_sharding(mesh: Optional[Mesh], dp: Optional[Tuple[str, ...]],
                            tp: Optional[str], act_mode: str = "embed_tp",
                            moe_constraint: bool = False) -> None:
    """Enable with_sharding_constraint hooks inside the model code.
    Call with (None, None, None) to disable (single-device tests)."""
    _ACT_CTX["mesh"] = mesh
    _ACT_CTX["dp"] = dp
    _ACT_CTX["tp"] = tp
    _ACT_CTX["act_mode"] = act_mode
    _ACT_CTX["moe_constraint"] = moe_constraint


def shard_act_btd(x: jnp.ndarray) -> jnp.ndarray:
    """Constraint for (B, S, D) residual-stream activations."""
    mesh = _ACT_CTX["mesh"]
    if mesh is None:
        return x
    mode = _ACT_CTX["act_mode"]
    if mode == "seq_tp":
        spec = P(_ACT_CTX["dp"], _ACT_CTX["tp"], None)
    elif mode == "dp_only":
        spec = P(_ACT_CTX["dp"], None, None)
    else:
        spec = P(_ACT_CTX["dp"], None, _ACT_CTX["tp"])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_act_logits_input(x: jnp.ndarray) -> jnp.ndarray:
    """Pre-LM-head constraint: gather seq so the vocab matmul shards on V
    (prevents XLA choosing a vocab all-gather under seq_tp)."""
    mesh = _ACT_CTX["mesh"]
    if mesh is None or _ACT_CTX["act_mode"] != "seq_tp":
        return x
    spec = P(_ACT_CTX["dp"], None, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_moe_dispatch(xe: jnp.ndarray) -> jnp.ndarray:
    """Constraint for the (E, C, D) expert dispatch buffer."""
    mesh = _ACT_CTX["mesh"]
    if mesh is None or not _ACT_CTX["moe_constraint"]:
        return xe
    spec = P(_ACT_CTX["tp"], _ACT_CTX["dp"], None)
    return jax.lax.with_sharding_constraint(xe, NamedSharding(mesh, spec))
