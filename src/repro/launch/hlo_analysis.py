"""Trip-count-aware HLO cost accounting.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program under-reports FLOPs/bytes/collectives by the trip
count (verified: a 10-step scanned matmul reports 1 step's flops).  This
module re-derives the three roofline inputs from the optimized HLO text
*with* loop multipliers:

* dot FLOPs: 2 · numel(result) · prod(contracting dims), from each
  ``dot`` op + a per-computation symbol table for operand shapes,
  multiplied by the product of enclosing ``known_trip_count``s;
* HBM bytes: Σ (operand + result bytes) over ops that touch memory
  (post-fusion, an op's operands/results are its actual HBM traffic;
  fusion-internal temporaries stay in registers/VMEM);
* collective bytes: per-kind result-shape bytes; DCN-crossing ops
  detected from replica_groups spanning the pod boundary.

Non-dot FLOPs (elementwise, transcendental) are not counted — transformer
steps are ≥95% dot FLOPs; the omission is conservative for the compute
term and documented in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# type may be a tuple containing /*index=N*/ comments (with '=');
# non-greedy up to the first 'opkind(' token.
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
                    r"(.+?)\s+([\w\-]+)\(")
# header params may contain nested tuple parens; key on the leading name
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[":{]+n[":]+(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,{} ]*)\}\}")

_NO_MEM_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id"}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class _Op:
    __slots__ = ("name", "type_str", "kind", "line")

    def __init__(self, name, type_str, kind, line):
        self.name, self.type_str, self.kind, self.line = \
            name, type_str, kind, line


def _parse_computations(hlo: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and line.rstrip().endswith("{") and " -> " in line \
                    and not line.startswith(" "):
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            comps[cur].append(_Op(m.group(1), m.group(2), m.group(3), line))
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry          # type: ignore
    return comps


def _dot_flops(op: _Op, symtab: Dict[str, str]) -> float:
    mres = 1
    for _, dims in _dims(op.type_str):
        for d in dims:
            mres *= d
    # contracting dims from the lhs operand's shape
    args = op.line.split(op.kind + "(", 1)[1]
    lhs_name = args.split(",")[0].strip().lstrip("%")
    lhs_type = symtab.get(lhs_name, "")
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if cm and lhs_type:
        ldims = _dims(lhs_type)
        if ldims:
            shape = ldims[0][1]
            for ci in cm.group(1).split(","):
                if ci.strip():
                    idx = int(ci)
                    if idx < len(shape):
                        contract *= shape[idx]
    return 2.0 * mres * contract


def _op_bytes(op: _Op, symtab: Dict[str, str]) -> int:
    if op.kind in _NO_MEM_OPS:
        return 0
    total = _type_bytes(op.type_str)
    args = op.line.split(op.kind + "(", 1)[1]
    # operand list ends at the first ")," or ")" at depth 0
    depth, end = 0, len(args)
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    for ref in args[:end].split(","):
        ref = ref.strip().lstrip("%")
        if ref in symtab:
            total += _type_bytes(symtab[ref])
    return total


def _crosses_boundary(line: str, boundary: int) -> bool:
    gm = _GROUPS_RE.search(line)
    if not gm:
        return False
    for grp in gm.group(1).split("},{"):
        ids = [int(x) for x in re.findall(r"\d+", grp)]
        if ids and min(ids) < boundary <= max(ids):
            return True
    return False


def analyze_hlo(hlo: str, pod_boundary: int = 256) -> Dict[str, Any]:
    """Full trip-count-aware accounting for one SPMD module (per device)."""
    comps = _parse_computations(hlo)
    entry_name = comps.pop("__entry_name__")
    comps.pop("__entry__")

    memo: Dict[str, Dict[str, float]] = {}

    def cost(cname: str, stack=()) -> Dict[str, float]:
        if cname in memo:
            return memo[cname]
        if cname not in comps or cname in stack:
            return {"flops": 0.0, "bytes": 0.0, "coll": 0.0, "coll_dcn": 0.0,
                    "coll_ops": 0.0,
                    **{f"coll_{k}": 0.0 for k in COLLECTIVES}}
        symtab = {op.name: op.type_str for op in comps[cname]}
        acc = {"flops": 0.0, "bytes": 0.0, "coll": 0.0, "coll_dcn": 0.0,
               "coll_ops": 0.0, **{f"coll_{k}": 0.0 for k in COLLECTIVES}}
        for op in comps[cname]:
            if op.kind == "dot":
                acc["flops"] += _dot_flops(op, symtab)
            acc["bytes"] += _op_bytes(op, symtab)
            base_kind = op.kind.replace("-start", "")
            if base_kind in COLLECTIVES and not op.kind.endswith("-done"):
                b = _type_bytes(op.type_str)
                acc["coll"] += b
                acc[f"coll_{base_kind}"] += b
                acc["coll_ops"] += 1
                if _crosses_boundary(op.line, pod_boundary):
                    acc["coll_dcn"] += b
            # --- children ---
            mult = 1.0
            if op.kind == "while":
                tm = _TRIP_RE.search(op.line)
                mult = float(tm.group(1)) if tm else 1.0
            children = _CALLED_RE.findall(op.line)
            children += _COND_RE.findall(op.line)
            bm = _BRANCH_RE.search(op.line)
            if bm:
                children += [c.strip().lstrip("%")
                             for c in bm.group(1).split(",")]
            for ch in children:
                sub = cost(ch, stack + (cname,))
                for k in acc:
                    acc[k] += mult * sub[k]
        memo[cname] = acc
        return acc

    total = cost(entry_name) if entry_name else {
        "flops": 0.0, "bytes": 0.0, "coll": 0.0, "coll_dcn": 0.0,
        "coll_ops": 0.0, **{f"coll_{k}": 0.0 for k in COLLECTIVES}}
    return {
        "flops": total["flops"],
        "bytes": total["bytes"],
        "collective_bytes": total["coll"],
        "collective_dcn_bytes": total["coll_dcn"],
        "collective_ops": total["coll_ops"],
        "per_kind": {k: total[f"coll_{k}"] for k in COLLECTIVES
                     if total[f"coll_{k}"] > 0},
    }
