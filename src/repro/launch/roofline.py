"""Roofline analysis from dry-run artifacts (§Roofline of EXPERIMENTS.md).

Per (arch × shape × mesh) cell, three terms in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bandwidth
    collective = ICI_bytes / ICI_bw  +  DCN_bytes / DCN_bw

The SPMD HLO module is the per-device program, so cost_analysis FLOPs /
bytes are already per-device.  MODEL_FLOPS uses the 6·N·D convention
(2·N·B for single-token decode), giving the useful-compute ratio that
catches remat/padding/dispatch waste.

Hardware constants (TPU v5e, per the brief):
    197 TFLOP/s bf16 · 819 GB/s HBM · ~50 GB/s/link ICI · ~25 GB/s DCN.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 25e9


def analytic_hbm_bytes(rec: Dict[str, Any]) -> float:
    """Per-device HBM traffic model for one step (TPU fusion assumed).

    The HLO-text byte count is a gross over-estimate on this container:
    the CPU backend materializes every intermediate that the TPU backend
    would fuse into registers/VMEM.  This analytic model counts only the
    traffic a fused TPU execution must pay:

    train:   weights 3× (fwd + bwd-dgrad + bwd-wgrad passes over the
             gathered per-layer tiles) + optimizer state (read m,v,p_f32 +
             write back = 7 f32 passes over the local shard) + remat
             boundary activations (write + 2 reads) + logits row.
    prefill: weights 1× + KV cache write + boundary activations 1×.
    decode:  weights(active) 1× + full KV/SSM cache read + tiny writes.
    """
    from repro.configs import get_config
    from repro.models.config import SHAPES
    cfg = get_config(rec["arch"])
    shp = SHAPES[rec["shape"]]
    dev = rec["devices"]
    tp = 16
    dp = dev // tp
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    bsz_local = max(shp.global_batch // dp, 1)
    d = cfg.d_model

    if shp.kind == "train":
        w = 3 * (2 * n_params) / dev            # bf16 weights ×3 passes
        opt = 7 * (4 * n_params) / dev          # f32 m,v,master r/w
        act = 3 * cfg.num_layers * bsz_local * shp.seq_len * (2 * d) / tp
        logits = 3 * bsz_local * shp.seq_len * 2 * cfg.vocab_size / tp
        return w + opt + act + logits
    if shp.kind == "prefill":
        w = (2 * n_params) / dev
        kv_w = (2 * cfg.num_layers * bsz_local * shp.seq_len
                * cfg.num_kv_heads * cfg.head_dim * 2) / tp
        act = cfg.num_layers * bsz_local * shp.seq_len * (2 * d) / tp
        return w + kv_w + act
    # decode: weights once + cache read
    w = (2 * n_active) / dev
    if cfg.family in ("ssm", "hybrid"):
        n_attn = sum(cfg.is_attn_layer(i) for i in range(cfg.num_layers))
        n_ssm = cfg.num_layers - n_attn
        cache = (n_attn * shp.global_batch * shp.seq_len
                 * cfg.num_kv_heads * cfg.head_dim * 2
                 + n_ssm * shp.global_batch * cfg.ssm_heads
                 * cfg.ssm_headdim * cfg.ssm_state * 4) / dev
    else:
        layers = cfg.num_layers + cfg.encoder_layers
        cache = (layers * shp.global_batch * shp.seq_len
                 * cfg.num_kv_heads * cfg.head_dim * 2) / dev
        if cfg.encoder_layers:
            cache *= 2                         # self + cross caches
    return w + cache


def roofline_terms(rec: Dict[str, Any]) -> Dict[str, Any]:
    """Compute the three terms + bottleneck for one dry-run record.

    FLOPs and collective bytes: trip-count-aware HLO accounting
    (hlo_analysis.py).  Memory: analytic fused-TPU traffic model (the raw
    HLO bytes, reported as ``hbm_hlo_upper_gb``, over-count CPU-backend
    materialization ~100-1000×).
    """
    if rec.get("status") != "ok":
        return {"status": rec.get("status", "missing"),
                "reason": rec.get("reason", rec.get("error", ""))[:200]}
    ta = rec.get("hlo_tripaware", {})
    flops = ta.get("flops", 0.0)
    coll_total = ta.get("collective_bytes", 0.0)
    dcn = ta.get("collective_dcn_bytes", 0.0)
    ici = coll_total - dcn
    bytes_acc = analytic_hbm_bytes(rec)

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = ici / ICI_BW + dcn / DCN_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)

    # useful-model-FLOPs ratio
    n_act = rec["active_param_count"]
    dev = rec["devices"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        model_flops = 6 * n_act * tokens
    elif rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        model_flops = 2 * n_act * tokens
    else:  # decode: one token per sequence
        model_flops = 2 * n_act * rec["global_batch"]
    hlo_total = flops * dev
    ratio = model_flops / hlo_total if hlo_total else 0.0
    # roofline fraction: useful compute time / achievable step time
    t_star = max(t_compute, t_memory, t_coll)
    frac = (model_flops / dev / PEAK_FLOPS) / t_star if t_star else 0.0
    return {
        "status": "ok",
        **{k: float(f"{v:.6g}") for k, v in terms.items()},
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops_per_dev": flops,
        "useful_ratio": round(ratio, 4),
        "roofline_frac": round(frac, 4),
        "ici_bytes": ici, "dcn_bytes": dcn,
        "hbm_hlo_upper_gb": round(ta.get("bytes", 0.0) / 2**30, 1),
        "mem_per_dev_gb": round(
            ((rec["memory"].get("argument_bytes") or 0)
             + (rec["memory"].get("temp_bytes") or 0)
             + (rec["memory"].get("output_bytes") or 0)
             - (rec["memory"].get("alias_bytes") or 0)) / 2**30, 2),
    }


def build_table(result_dir: str) -> List[Dict[str, Any]]:
    rows = []
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = {"arch": rec["arch"], "shape": rec["shape"],
               "mesh": rec["mesh"]}
        row.update(roofline_terms(rec))
        rows.append(row)
    return rows


def to_markdown(rows: List[Dict[str, Any]]) -> str:
    hdr = ("| arch | shape | mesh | compute (ms) | memory (ms) | "
           "collective (ms) | bottleneck | useful ratio | roofline frac | "
           "mem/dev (GB) |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"{r.get('status')} ({r.get('reason', '')[:60]}) | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {1e3 * r['compute_s']:.2f} | {1e3 * r['memory_s']:.2f} "
            f"| {1e3 * r['collective_s']:.2f} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.3f} | {r['roofline_frac']:.3f} "
            f"| {r['mem_per_dev_gb']} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = build_table(args.results)
    print(to_markdown(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
