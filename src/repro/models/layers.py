"""Transformer building blocks: RMSNorm, RoPE, GQA attention (chunked,
cache-aware), SwiGLU MLP.  Pure JAX; bf16 compute with f32 softmax/norm.

Attention is *query-chunked*: logits for one (B, H, q_chunk, T) tile at a
time via ``lax.scan``, so the (S, S) score matrix is never materialized —
peak activation memory is O(S·q_chunk) per layer instead of O(S²).  GQA
keeps K/V at ``num_kv_heads`` and broadcasts inside the einsum (XLA fuses
the repeat; no materialized copy).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5
             ) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x (B, S, H, hd), positions (B, S) or (S,) -> rotated x."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                    # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def _scores_softmax_ctx(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        mask: jnp.ndarray) -> jnp.ndarray:
    """q (B,Sq,KVH,G,hd), k/v (B,T,KVH,hd), mask (B|1, Sq, T) -> ctx like q."""
    scale = float(1.0 / np.sqrt(q.shape[-1]))
    logits = jnp.einsum("bqkgd,btkd->bkgqt", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bkgqt,btkd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return ctx.astype(q.dtype)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              q_positions: jnp.ndarray, kv_valid_len: Optional[jnp.ndarray],
              *, causal: bool, q_chunk: int = 1024) -> jnp.ndarray:
    """Chunked GQA attention.

    q (B, Sq, H, hd); k, v (B, T, KVH, hd); q_positions (Sq,) absolute
    positions of the queries (for causal masking against cache slots);
    kv_valid_len: scalar count of valid cache slots (None = all T).
    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    q5 = q.reshape(b, sq, kvh, g, hd)
    kv_pos = jnp.arange(t)

    def mask_for(qpos):
        m = jnp.ones((qpos.shape[0], t), bool)
        if causal:
            m &= qpos[:, None] >= kv_pos[None, :]
        if kv_valid_len is not None:
            m &= kv_pos[None, :] < kv_valid_len
        return m[None]                              # (1, Sq_chunk, T)

    if sq <= q_chunk:
        return _scores_softmax_ctx(q5, k, v, mask_for(q_positions)
                                   ).reshape(b, sq, h, hd)

    assert sq % q_chunk == 0, (sq, q_chunk)
    nc = sq // q_chunk
    qc = q5.reshape(b, nc, q_chunk, kvh, g, hd)
    pc = q_positions.reshape(nc, q_chunk)

    def step(_, inputs):
        qi, pi = inputs
        ctx = _scores_softmax_ctx(qi, k, v, mask_for(pi))
        return None, ctx

    _, ctx = jax.lax.scan(step, None, (jnp.moveaxis(qc, 1, 0), pc))
    ctx = jnp.moveaxis(ctx, 0, 1).reshape(b, sq, kvh, g, hd)
    return ctx.reshape(b, sq, h, hd)


class AttnParams(NamedTuple):
    wq: jnp.ndarray          # (D, H, hd)
    wk: jnp.ndarray          # (D, KVH, hd)
    wv: jnp.ndarray          # (D, KVH, hd)
    wo: jnp.ndarray          # (H, hd, D)
    bq: Optional[jnp.ndarray] = None    # (H, hd) — qwen1.5 qkv bias
    bk: Optional[jnp.ndarray] = None
    bv: Optional[jnp.ndarray] = None


def init_attn(key: jax.Array, d_model: int, heads: int, kv_heads: int,
              head_dim: int, real_heads: int, *, bias: bool, dtype
              ) -> AttnParams:
    """``heads`` may exceed ``real_heads`` (TP padding): padded head slices
    are zero so they contribute nothing through wo."""
    ks = jax.random.split(key, 4)
    scale_in = float(1.0 / np.sqrt(d_model))
    scale_out = float(1.0 / np.sqrt(real_heads * head_dim))
    wq = jax.random.normal(ks[0], (d_model, heads, head_dim), dtype) * scale_in
    wo = jax.random.normal(ks[3], (heads, head_dim, d_model), dtype) * scale_out
    if heads != real_heads:
        padmask = (jnp.arange(heads) < real_heads).astype(dtype)
        wq = wq * padmask[None, :, None]
        wo = wo * padmask[:, None, None]
    wk = jax.random.normal(ks[1], (d_model, kv_heads, head_dim), dtype) * scale_in
    wv = jax.random.normal(ks[2], (d_model, kv_heads, head_dim), dtype) * scale_in
    if bias:
        return AttnParams(wq, wk, wv, wo,
                          bq=jnp.zeros((heads, head_dim), dtype),
                          bk=jnp.zeros((kv_heads, head_dim), dtype),
                          bv=jnp.zeros((kv_heads, head_dim), dtype))
    return AttnParams(wq, wk, wv, wo)


def qkv_proj(p: AttnParams, x: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    k = jnp.einsum("bsd,dhk->bshk", x, p.wk)
    v = jnp.einsum("bsd,dhk->bshk", x, p.wv)
    if p.bq is not None:
        q = q + p.bq
        k = k + p.bk
        v = v + p.bv
    return q, k, v


def out_proj(p: AttnParams, ctx: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bshk,hkd->bsd", ctx, p.wo)


class MlpParams(NamedTuple):
    w_gate: jnp.ndarray      # (D, F)
    w_up: jnp.ndarray        # (D, F)
    w_down: jnp.ndarray      # (F, D)


def init_mlp(key: jax.Array, d_model: int, d_ff: int, dtype) -> MlpParams:
    ks = jax.random.split(key, 3)
    si, so = float(1.0 / np.sqrt(d_model)), float(1.0 / np.sqrt(d_ff))
    return MlpParams(
        w_gate=jax.random.normal(ks[0], (d_model, d_ff), dtype) * si,
        w_up=jax.random.normal(ks[1], (d_model, d_ff), dtype) * si,
        w_down=jax.random.normal(ks[2], (d_ff, d_model), dtype) * so)


def mlp(p: MlpParams, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p.w_gate) * (x @ p.w_up)
    return h @ p.w_down


def update_cache(cache: jnp.ndarray, new: jnp.ndarray, pos: jnp.ndarray
                 ) -> jnp.ndarray:
    """Write (B, Snew, KVH, hd) into cache (B, T, KVH, hd) at time `pos`."""
    return jax.lax.dynamic_update_slice(
        cache, new.astype(cache.dtype), (0, pos, 0, 0))
