"""Mixture-of-Experts: token-choice top-k router with capacity, sort-based
dispatch (static shapes, TPU-native).

GShard's one-hot dispatch einsum materializes an (N, E, C) tensor — at
65k tokens × 128 experts that is tens of GB.  We instead use the
sort-based formulation: flatten the N·K (token, expert, gate) assignments,
sort by expert id (TPU bitonic sort), compute each assignment's position
within its expert's run, drop those ≥ capacity, and scatter token ids
into an (E·C,) slot table.  Expert FFN runs as one batched einsum over
(E, C, D); results scatter-add back weighted by the gates.

Aux losses follow Switch/ST-MoE: load-balance loss (mean fraction ×
mean router prob per expert) and router z-loss.

Sharding: expert dim E maps to the "model" mesh axis (EP); the gather
into (E, C, D) and the scatter back are where GSPMD inserts the
all-to-all-equivalent collectives.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class MoeParams(NamedTuple):
    router: jnp.ndarray      # (D, E)
    w_gate: jnp.ndarray      # (E, D, F)
    w_up: jnp.ndarray        # (E, D, F)
    w_down: jnp.ndarray      # (E, F, D)


def init_moe(key: jax.Array, d_model: int, num_experts: int, expert_ff: int,
             dtype) -> MoeParams:
    ks = jax.random.split(key, 4)
    si = float(1.0 / np.sqrt(d_model))
    so = float(1.0 / np.sqrt(expert_ff))
    return MoeParams(
        router=jax.random.normal(ks[0], (d_model, num_experts),
                                 jnp.float32) * si,
        w_gate=jax.random.normal(ks[1], (num_experts, d_model, expert_ff),
                                 dtype) * si,
        w_up=jax.random.normal(ks[2], (num_experts, d_model, expert_ff),
                               dtype) * si,
        w_down=jax.random.normal(ks[3], (num_experts, expert_ff, d_model),
                                 dtype) * so)


def capacity(num_tokens: int, num_experts: int, top_k: int,
             factor: float) -> int:
    c = int(np.ceil(num_tokens * top_k * factor / num_experts))
    return max(8, ((c + 7) // 8) * 8)       # pad to VPU sublane multiple


class MoeAux(NamedTuple):
    load_balance_loss: jnp.ndarray
    z_loss: jnp.ndarray
    dropped_frac: jnp.ndarray   # fraction of assignments over capacity


def moe_apply(p: MoeParams, x: jnp.ndarray, *, top_k: int,
              capacity_factor: float = 1.25) -> Tuple[jnp.ndarray, MoeAux]:
    """x (B, S, D) -> (B, S, D), aux losses.  Static shapes throughout."""
    b, s, d = x.shape
    n = b * s
    e = p.router.shape[1]
    c = capacity(n, e, top_k, capacity_factor)
    xf = x.reshape(n, d)

    logits = (xf.astype(jnp.float32) @ p.router)          # (N, E) f32 router
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)   # (N, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # ---- sort-based dispatch --------------------------------------------
    flat_expert = expert_ids.reshape(-1)                  # (N*K,)
    flat_token = jnp.repeat(jnp.arange(n, dtype=jnp.int32), top_k)
    flat_gate = gate_vals.reshape(-1)
    # stable sort by expert keeps router order for fair capacity dropping
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # position within each expert's run
    same = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            (se[1:] == se[:-1]).astype(jnp.int32)])
    idx = jnp.arange(n * top_k, dtype=jnp.int32)
    run_start = jnp.where(same == 0, idx, 0)
    run_start = jax.lax.associative_scan(jnp.maximum, run_start)
    pos_in_expert = idx - run_start
    keep = pos_in_expert < c
    slot = se.astype(jnp.int32) * c + pos_in_expert       # (N*K,) in [0, E*C)
    slot = jnp.where(keep, slot, e * c)                   # overflow slot

    # slot -> token gather table ((E*C)+1 with trash slot)
    slot_token = jnp.zeros((e * c + 1,), jnp.int32).at[slot].set(st,
                                                                 mode="drop")
    slot_filled = jnp.zeros((e * c + 1,), bool).at[slot].set(keep,
                                                             mode="drop")
    gather_idx = slot_token[:e * c]
    filled = slot_filled[:e * c]

    xe = jnp.where(filled[:, None], xf[gather_idx], 0.0)  # (E*C, D)
    xe = xe.reshape(e, c, d)
    from repro.launch.sharding import shard_moe_dispatch
    xe = shard_moe_dispatch(xe)                           # EP constraint

    # ---- expert FFN (batched over E) ------------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p.w_gate)) \
        * jnp.einsum("ecd,edf->ecf", xe, p.w_up)
    ye = jnp.einsum("ecf,efd->ecd", h, p.w_down)          # (E, C, D)

    # ---- combine: scatter-add back, gate-weighted ------------------------
    # per-slot gate (scattered alongside the token ids)
    slot_gate = jnp.zeros((e * c + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sg, 0.0), mode="drop")[:e * c]
    ye_flat = ye.reshape(e * c, d)
    gated = ye_flat * slot_gate[:, None].astype(ye_flat.dtype)
    out = jnp.zeros((n, d), ye_flat.dtype).at[gather_idx].add(
        jnp.where(filled[:, None], gated, 0.0), mode="drop")

    # ---- aux losses -------------------------------------------------------
    # load-balance (Switch eq. 4): E * sum_e f_e * P_e
    assign_onehot = jax.nn.one_hot(expert_ids[:, 0], e)   # top-1 fraction
    f = jnp.mean(assign_onehot, axis=0)
    pmean = jnp.mean(probs, axis=0)
    lb = e * jnp.sum(f * pmean)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - jnp.sum(keep) / (n * top_k)
    aux = MoeAux(load_balance_loss=lb, z_loss=z, dropped_frac=dropped)
    return out.reshape(b, s, d).astype(x.dtype), aux
