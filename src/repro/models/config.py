"""Unified model configuration covering all assigned architecture families.

One dataclass parameterizes dense GQA transformers, MoE (token-choice
top-k, optional parallel dense residual), Mamba2/SSD, Jamba-style hybrids,
encoder-decoder, and modality-stub (vlm/audio) variants.  Every assigned
arch in ``repro.configs`` is an instance of this dataclass.

TP head padding: with a fixed 16-way "model" mesh axis, head counts that
are not multiples of 16 (deepseek 56H, llama3.2 24H, arctic 56H, mamba2's
24 SSD heads) are padded up at *parameter-build* time (``tp_pad``).
Padded heads have zero weights in and out, so outputs are exact; the
wasted FLOPs show up honestly in the roofline's MODEL_FLOPS/HLO_FLOPS
ratio (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 128
    qkv_bias: bool = False       # qwen1.5
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    moe_top_k: int = 0
    expert_ff: int = 0           # per-expert hidden dim
    moe_every: int = 1           # layer i is MoE iff i % moe_every == moe_offset
    moe_offset: int = 0
    dense_residual: bool = False  # arctic: parallel dense MLP beside the MoE
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss_weight: float = 1e-2

    # --- SSM / hybrid ---
    ssm_state: int = 0           # N; 0 -> no ssm layers
    ssm_headdim: int = 64        # P
    ssm_expand: int = 2          # d_inner = expand * d_model
    ssm_conv_width: int = 4
    ssm_chunk: int = 256         # SSD chunk length
    attn_every: int = 0          # hybrid: layer i is attention iff
    attn_offset: int = 0         #   i % attn_every == attn_offset (jamba: 8, 4)

    # --- encoder-decoder ---
    encoder_layers: int = 0      # 0 -> decoder-only

    # --- modality frontend stub ---
    frontend: str = "none"       # none | vision | audio
    num_prefix: int = 256        # vlm: patch embeddings per image

    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # -------------------------------------------------------------- derived
    @property
    def gqa_groups(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def padded_heads(self, tp: int) -> int:
        """Query heads padded to a multiple of the model-axis size."""
        return _round_up(self.num_heads, tp)

    def padded_ssm_heads(self, tp: int) -> int:
        return _round_up(self.ssm_heads, tp)

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_every == 0:
            return True
        return i % self.attn_every == self.attn_offset

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0:
            return False
        return i % self.moe_every == self.moe_offset

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind: {attn|ssm} x {dense|moe} product."""
        kinds = []
        for i in range(self.num_layers):
            mix = "attn" if self.is_attn_layer(i) else "ssm"
            ff = "moe" if self.is_moe_layer(i) else "mlp"
            kinds.append(f"{mix}+{ff}")
        return tuple(kinds)

    def superblock_period(self) -> int:
        """Smallest period of the layer-kind pattern (scan unrolling unit).

        Homogeneous stacks -> 1 (pure scan); jamba -> 8 (scan over
        superblocks of 8 unrolled sub-layers)."""
        kinds = self.layer_kinds()
        for p in range(1, len(kinds) + 1):
            if len(kinds) % p == 0 and all(
                    kinds[i] == kinds[i % p] for i in range(len(kinds))):
                return p
        return len(kinds)

    # ------------------------------------------------------------ counting
    def param_count(self) -> int:
        """Total parameters (unpadded), for 6·N·D roofline accounting."""
        d, v = self.d_model, self.vocab_size
        n = v * d                                    # embedding
        if not self.tie_embeddings:
            n += v * d                               # lm head
        attn = (d * self.num_heads * self.head_dim   # q
                + 2 * d * self.num_kv_heads * self.head_dim   # kv
                + self.num_heads * self.head_dim * d  # o
                + (self.num_heads + 2 * self.num_kv_heads) * self.head_dim
                * (1 if self.qkv_bias else 0))
        mlp = 3 * d * self.d_ff                       # swiglu
        moe = (self.num_experts * 3 * d * self.expert_ff
               + d * self.num_experts) if self.num_experts else 0
        h = self.ssm_heads
        ssm = (d * (2 * self.d_inner + 2 * self.ssm_state + h)  # in_proj
               + self.ssm_conv_width * (self.d_inner + 2 * self.ssm_state)
               + 3 * h                                # A, D, dt_bias
               + self.d_inner * d)                    # out_proj
        layers = 0
        for i in range(self.num_layers):
            layers += 2 * d                           # norms
            layers += attn if self.is_attn_layer(i) else ssm
            if self.is_moe_layer(i):
                layers += moe + (mlp if self.dense_residual else 0)
            else:
                layers += mlp
        enc = 0
        if self.encoder_layers:
            enc_attn = attn
            enc = self.encoder_layers * (2 * d + enc_attn + mlp)
            # decoder cross-attention blocks
            layers += self.num_layers * (d + attn)
        return n + layers + enc + d                   # final norm

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.num_experts:
            return self.param_count()
        full_moe = self.num_experts * 3 * self.d_model * self.expert_ff
        active_moe = self.moe_top_k * 3 * self.d_model * self.expert_ff
        n_moe_layers = sum(self.is_moe_layer(i)
                           for i in range(self.num_layers))
        return self.param_count() - n_moe_layers * (full_moe - active_moe)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """Is this (arch, shape) cell runnable?  long_500k needs sub-quadratic
    attention (SSM / hybrid); pure full-attention archs skip it."""
    if shape == "long_500k":
        if cfg.family in ("ssm", "hybrid"):
            return True, ""
        return False, ("full-attention arch: decoding against a 512k dense "
                       "KV cache is the quadratic-memory regime long_500k "
                       "excludes (DESIGN.md §5)")
    return True, ""
