"""Mamba2 / SSD (state-space duality) layer — chunked matmul form.

Implements the SSD algorithm of Dao & Gu 2024 (arXiv:2405.21060): the
selective state-space recurrence

    h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t,      y_t = C_t h_t + D x_t

evaluated in *chunks*: within a chunk the recurrence unrolls into a
masked (C·Bᵀ ∘ decay) attention-like matmul (MXU-friendly); across chunks
a small (H, P, N) state carries via ``lax.scan``.  Scalar-identity A per
head (the Mamba2 restriction) makes all decays rank-1.

Decode is the O(1)-per-token recurrent form: one state update per step —
this is why the `long_500k` shape runs for SSM/hybrid archs only.

Projection weights are stored per-component (z, x, B, C, dt) rather than
as one fused in_proj so each can carry its own PartitionSpec: the d_inner
lanes (z/x) shard over the "model" axis, the small B/C/dt lanes stay
replicated — a fused layout would split mid-component (DESIGN.md §6).
Heads H = d_inner / headdim, TP-padded (padded lanes zeroed, outputs
exact).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class SsmParams(NamedTuple):
    w_z: jnp.ndarray         # (D, d_in_pad) gate branch
    w_x: jnp.ndarray         # (D, d_in_pad) ssm input branch
    w_b: jnp.ndarray         # (D, N)
    w_c: jnp.ndarray         # (D, N)
    w_dt: jnp.ndarray        # (D, H)
    conv_x: jnp.ndarray      # (W, d_in_pad) depthwise causal conv
    conv_b: jnp.ndarray      # (W, N)
    conv_c: jnp.ndarray      # (W, N)
    conv_bias_x: jnp.ndarray  # (d_in_pad,)
    conv_bias_b: jnp.ndarray  # (N,)
    conv_bias_c: jnp.ndarray  # (N,)
    a_log: jnp.ndarray       # (H,)
    d_skip: jnp.ndarray      # (H,)
    dt_bias: jnp.ndarray     # (H,)
    w_out: jnp.ndarray       # (d_in_pad, D)
    norm_scale: jnp.ndarray  # (d_in_pad,) gated RMSNorm before out_proj


class SsmState(NamedTuple):
    """Decode-time recurrent state."""
    ssm: jnp.ndarray         # (B, H, P, N) f32
    conv_x: jnp.ndarray      # (B, W-1, d_in_pad) conv lookback
    conv_bc: jnp.ndarray     # (B, W-1, 2*N)


def init_ssm(key: jax.Array, d_model: int, d_inner: int, n_state: int,
             heads: int, real_heads: int, conv_width: int, dtype
             ) -> SsmParams:
    """``heads`` may be TP-padded above ``real_heads`` (zeroed lanes)."""
    ks = jax.random.split(key, 8)
    headdim = d_inner // real_heads
    d_in_pad = heads * headdim
    si = float(1.0 / np.sqrt(d_model))
    w_z = jax.random.normal(ks[0], (d_model, d_in_pad), dtype) * si
    w_x = jax.random.normal(ks[1], (d_model, d_in_pad), dtype) * si
    w_dt = jax.random.normal(ks[2], (d_model, heads), dtype) * si
    if heads != real_heads:
        lane = (jnp.arange(d_in_pad) < real_heads * headdim).astype(dtype)
        w_z = w_z * lane[None, :]
        w_x = w_x * lane[None, :]
        hmask = (jnp.arange(heads) < real_heads).astype(dtype)
        w_dt = w_dt * hmask[None, :]
    a0 = jnp.log(jnp.clip(
        1.0 + jnp.arange(heads, dtype=jnp.float32), 1.0, 16.0))
    return SsmParams(
        w_z=w_z, w_x=w_x,
        w_b=jax.random.normal(ks[3], (d_model, n_state), dtype) * si,
        w_c=jax.random.normal(ks[4], (d_model, n_state), dtype) * si,
        w_dt=w_dt,
        conv_x=jax.random.normal(ks[5], (conv_width, d_in_pad), dtype) * 0.1,
        conv_b=jax.random.normal(ks[6], (conv_width, n_state), dtype) * 0.1,
        conv_c=jax.random.normal(ks[7], (conv_width, n_state), dtype) * 0.1,
        conv_bias_x=jnp.zeros((d_in_pad,), dtype),
        conv_bias_b=jnp.zeros((n_state,), dtype),
        conv_bias_c=jnp.zeros((n_state,), dtype),
        a_log=a0,                               # A = -exp(a_log) < 0
        d_skip=jnp.ones((heads,), jnp.float32),
        dt_bias=jnp.zeros((heads,), jnp.float32),
        w_out=jax.random.normal(ks[2], (d_in_pad, d_model), dtype)
        * float(1.0 / np.sqrt(d_inner)),
        norm_scale=jnp.ones((d_in_pad,), dtype))


def _segsum(log_a: jnp.ndarray) -> jnp.ndarray:
    """(..., Q) per-step log decays -> (..., Q, Q) lower-tri cumulative sums:
    out[t, s] = sum_{r=s+1..t} log_a_r  (the decay from step s to t)."""
    q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]        # (…, t, s)
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(xh: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, chunk: int,
             init_state: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.

    xh (B, S, H, P), dt (B, S, H) positive, b/c (B, S, N), a_log (H,).
    Returns (y (B, S, H, P), final_state (B, H, P, N)).  All f32 inside.
    """
    bsz, s, h, p = xh.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xf = xh.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    bf = b.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cf = c.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    a = -jnp.exp(a_log.astype(jnp.float32))           # (H,) negative
    log_decay = dtf * a[None, None, None, :]          # (B, nc, Q, H)
    xdt = xf * dtf[..., None]                         # Δ·x

    # intra-chunk (diagonal blocks): y[t] += Σ_s≤t C_t·B_s exp(Σ_{s<r≤t}) x_s
    seg = _segsum(jnp.moveaxis(log_decay, -1, -2))    # (B, nc, H, Q, Q)
    decay_mat = jnp.exp(seg)
    cb = jnp.einsum("bgtn,bgsn->bgts", cf, bf)        # (B, nc, Q, Q)
    y_diag = jnp.einsum("bgts,bghts,bgshp->bgthp",
                        cb, decay_mat, xdt)

    # chunk-final states: S_g = Σ_s exp(Σ_{s<r≤Q}) B_s ⊗ (Δx)_s
    cum = jnp.cumsum(log_decay, axis=2)               # (B, nc, Q, H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)   # (B, nc, Q, H)
    states = jnp.einsum("bgsn,bgsh,bgshp->bghpn", bf, decay_to_end, xdt)

    # inter-chunk recurrence over the nc chunk states
    chunk_decay = jnp.exp(cum[:, :, -1, :])           # (B, nc, H)
    s0 = jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)

    def step(carry, inp):
        st, dec = inp                                  # (B,H,P,N), (B,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry                              # emit state BEFORE chunk

    final, prior = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prior = jnp.moveaxis(prior, 0, 1)                  # (B, nc, H, P, N)

    # off-diagonal: y[t] += C_t exp(Σ_{0<r≤t}) S_prior
    in_decay = jnp.exp(cum)                            # (B, nc, Q, H)
    y_off = jnp.einsum("bgtn,bgth,bghpn->bgthp", cf, in_decay, prior)
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final


def _dw_conv(x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray,
             lookback: Optional[jnp.ndarray]
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d + silu.  x (B, S, Ch), w (W, Ch)."""
    width = w.shape[0]
    if lookback is None:
        lookback = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([lookback, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    new_lb = xp[:, -(width - 1):, :] if width > 1 else lookback
    return jax.nn.silu(out + bias[None, None, :]), new_lb


def ssm_forward(p: SsmParams, x: jnp.ndarray, *, heads: int, n_state: int,
                chunk: int, state: Optional[SsmState] = None
                ) -> Tuple[jnp.ndarray, SsmState]:
    """Full Mamba2 block (train/prefill).  x (B, S, D)."""
    z = x @ p.w_z                                     # (B, S, d_in_pad)
    xr = x @ p.w_x
    br = x @ p.w_b
    cr = x @ p.w_c
    dt_raw = x @ p.w_dt                               # (B, S, H)
    lb_x = None if state is None else state.conv_x
    lb_bc = None if state is None else state.conv_bc
    xh, new_lb_x = _dw_conv(xr, p.conv_x, p.conv_bias_x, lb_x)
    bc = jnp.concatenate([br, cr], axis=-1)
    w_bc = jnp.concatenate([p.conv_b, p.conv_c], axis=-1)
    bias_bc = jnp.concatenate([p.conv_bias_b, p.conv_bias_c])
    bc_out, new_lb_bc = _dw_conv(bc, w_bc, bias_bc, lb_bc)
    b = bc_out[..., :n_state]
    c = bc_out[..., n_state:]
    d_in_pad = z.shape[-1]
    headdim = d_in_pad // heads
    xh = xh.reshape(*xh.shape[:-1], heads, headdim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p.dt_bias[None, None, :])
    y, final = ssd_scan(xh, dt, p.a_log, b, c, chunk,
                        None if state is None else state.ssm)
    y = y + xh.astype(jnp.float32) * p.d_skip[None, None, :, None]
    y = y.reshape(*y.shape[:-2], d_in_pad).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    from repro.models.layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p.norm_scale)
    out = y @ p.w_out
    return out, SsmState(ssm=final, conv_x=new_lb_x, conv_bc=new_lb_bc)


def ssm_decode_step(p: SsmParams, x: jnp.ndarray, state: SsmState,
                    *, heads: int, n_state: int
                    ) -> Tuple[jnp.ndarray, SsmState]:
    """O(1) single-token recurrence.  x (B, 1, D)."""
    z = x @ p.w_z
    xr = x @ p.w_x
    bc = jnp.concatenate([x @ p.w_b, x @ p.w_c], axis=-1)
    dt_raw = x @ p.w_dt
    width = p.conv_x.shape[0]

    def one_step_conv(xin, lb, w, bias):
        xp = jnp.concatenate([lb, xin], axis=1)       # (B, W, Ch)
        out = sum(xp[:, i:i + 1, :] * w[i][None, None, :]
                  for i in range(width))
        return jax.nn.silu(out + bias[None, None, :]), xp[:, 1:, :]

    xh, new_lb_x = one_step_conv(xr, state.conv_x, p.conv_x, p.conv_bias_x)
    w_bc = jnp.concatenate([p.conv_b, p.conv_c], axis=-1)
    bias_bc = jnp.concatenate([p.conv_bias_b, p.conv_bias_c])
    bc_out, new_lb_bc = one_step_conv(bc, state.conv_bc, w_bc, bias_bc)
    b = bc_out[:, 0, :n_state]
    c = bc_out[:, 0, n_state:]
    d_in_pad = z.shape[-1]
    headdim = d_in_pad // heads
    xh = xh.reshape(xh.shape[0], heads, headdim).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0, :].astype(jnp.float32)
                         + p.dt_bias[None, :])        # (B, H)
    a = -jnp.exp(p.a_log.astype(jnp.float32))
    decay = jnp.exp(dt * a[None, :])                  # (B, H)
    bf = b.astype(jnp.float32)                        # (B, N)
    cf = c.astype(jnp.float32)
    new_state = state.ssm * decay[:, :, None, None] + \
        jnp.einsum("bhp,bn,bh->bhpn", xh, bf, dt)
    y = jnp.einsum("bhpn,bn->bhp", new_state, cf)
    y = y + xh * p.d_skip[None, :, None]
    y = y.reshape(y.shape[0], 1, d_in_pad).astype(x.dtype)
    from repro.models.layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p.norm_scale)
    return y @ p.w_out, SsmState(ssm=new_state, conv_x=new_lb_x,
                                 conv_bc=new_lb_bc)
