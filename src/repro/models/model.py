"""Unified model: parameters, train forward, prefill, and decode for every
assigned architecture family.

Layer stacks are organized as **superblocks**: the smallest repeating
pattern of layer kinds (1 for homogeneous stacks, 8 for jamba's
[mamba,mamba,mamba,mamba,attn,mamba,mamba,mamba] × [mlp/moe] interleave).
Parameters are stacked over superblocks and the stack is traversed with
``lax.scan`` — HLO size stays O(period), not O(layers), which keeps
compile times sane at 94 layers and lets remat checkpoint exactly one
superblock.

Decode state is a pytree of per-sub-layer stacked caches (KV for
attention subs, (ssm, conv) for SSD subs, cross-KV for encoder-decoder).

All dense compute is bf16 with f32 softmax/norm/router; loss in f32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig

Params = Dict[str, Any]
Caches = Dict[str, Any]


# ============================================================ param building
def _sub_kind(cfg: ModelConfig, j: int) -> str:
    mix = "attn" if cfg.is_attn_layer(j) else "ssm"
    if cfg.num_experts and cfg.is_moe_layer(j):
        ff = "moe+mlp" if cfg.dense_residual else "moe"
    elif cfg.d_ff > 0:
        ff = "mlp"
    else:
        ff = "none"
    return f"{mix}|{ff}"


def _init_sub(key: jax.Array, cfg: ModelConfig, kind: str, tp: int) -> Params:
    mix, ff = kind.split("|")
    d, dt = cfg.d_model, cfg.pdtype
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": jnp.ones((d,), dt)}
    if mix == "attn":
        p["attn"] = L.init_attn(
            ks[0], d, cfg.padded_heads(tp), cfg.num_kv_heads, cfg.head_dim,
            cfg.num_heads, bias=cfg.qkv_bias, dtype=dt)
    else:
        p["ssm"] = ssm_mod.init_ssm(
            ks[0], d, cfg.d_inner, cfg.ssm_state, cfg.padded_ssm_heads(tp),
            cfg.ssm_heads, cfg.ssm_conv_width, dt)
    if ff != "none":
        p["norm2"] = jnp.ones((d,), dt)
    if ff in ("moe", "moe+mlp"):
        p["moe"] = moe_mod.init_moe(ks[1], d, cfg.num_experts,
                                    cfg.expert_ff, dt)
    if ff in ("mlp", "moe+mlp"):
        p["mlp"] = L.init_mlp(ks[2], d, cfg.d_ff, dt)
    return p


def _init_cross_sub(key: jax.Array, cfg: ModelConfig, tp: int) -> Params:
    """Cross-attention insert for encoder-decoder decoder layers."""
    return {"norm": jnp.ones((cfg.d_model,), cfg.pdtype),
            "attn": L.init_attn(key, cfg.d_model, cfg.padded_heads(tp),
                                cfg.num_kv_heads, cfg.head_dim,
                                cfg.num_heads, bias=False, dtype=cfg.pdtype)}


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def padded_vocab(cfg: ModelConfig, tp: int) -> int:
    """Vocab rounded up to the model-axis size (sharding divisibility);
    padded logits are masked to -inf in every head computation."""
    return ((cfg.vocab_size + tp - 1) // tp) * tp


def init_params(key: jax.Array, cfg: ModelConfig, tp: int = 1) -> Params:
    """Full parameter pytree.  ``tp``: model-axis size for head/vocab
    padding."""
    keys = jax.random.split(key, 8)
    d, dt = cfg.d_model, cfg.pdtype
    v = padded_vocab(cfg, tp)
    params: Params = {
        "embed": jax.random.normal(keys[0], (v, d), dt) * 0.02,
        "final_norm": jnp.ones((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[1], (v, d), dt) * 0.02

    period = cfg.superblock_period()
    nsb = cfg.num_layers // period
    sub_kinds = [_sub_kind(cfg, j) for j in range(period)]
    blocks = {}
    for j, kind in enumerate(sub_kinds):
        kj = jax.random.fold_in(keys[2], j)
        subs = [_init_sub(jax.random.fold_in(kj, i), cfg, kind, tp)
                for i in range(nsb)]
        blocks[f"sub{j}"] = _stack(subs)
        if cfg.encoder_layers:   # decoder layers get cross-attention
            kc = jax.random.fold_in(keys[3], j)
            blocks[f"cross{j}"] = _stack(
                [_init_cross_sub(jax.random.fold_in(kc, i), cfg, tp)
                 for i in range(nsb)])
    params["blocks"] = blocks

    if cfg.encoder_layers:
        enc = [_init_sub(jax.random.fold_in(keys[4], i), cfg, "attn|mlp", tp)
               for i in range(cfg.encoder_layers)]
        params["enc_blocks"] = {"sub0": _stack(enc)}
        params["enc_final_norm"] = jnp.ones((d,), dt)
    if cfg.frontend == "vision":
        # stub projection for precomputed patch embeddings
        params["patch_proj"] = jax.random.normal(keys[5], (d, d), dt) \
            * float(1.0 / np.sqrt(d))
    return params


# ========================================================== block application
def _apply_ff(cfg: ModelConfig, kind: str, p: Params, x: jnp.ndarray,
              aux: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    _, ff = kind.split("|")
    if ff == "none":
        return x
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    delta = 0.0
    if ff in ("moe", "moe+mlp"):
        mo, moe_aux = moe_mod.moe_apply(p["moe"], h, top_k=cfg.moe_top_k,
                                        capacity_factor=cfg.capacity_factor)
        aux["lb_loss"] = aux.get("lb_loss", 0.0) + moe_aux.load_balance_loss
        aux["z_loss"] = aux.get("z_loss", 0.0) + moe_aux.z_loss
        delta = delta + mo
    if ff in ("mlp", "moe+mlp"):
        delta = delta + L.mlp(p["mlp"], h)
    return x + delta


def _ssm_heads_of(p: Params) -> int:
    """Padded SSD head count, read from the param shapes."""
    return p["ssm"].a_log.shape[-1]


def _apply_sub_train2(cfg: ModelConfig, kind: str, p: Params,
                      x: jnp.ndarray, positions: jnp.ndarray,
                      aux: Dict[str, jnp.ndarray], q_chunk: int
                      ) -> jnp.ndarray:
    mix, _ = kind.split("|")
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if mix == "attn":
        q, k, v = L.qkv_proj(p["attn"], h)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        ctx = L.attention(q, k, v, positions, None, causal=True,
                          q_chunk=q_chunk)
        x = x + L.out_proj(p["attn"], ctx)
    else:
        out, _ = ssm_mod.ssm_forward(
            p["ssm"], h, heads=_ssm_heads_of(p), n_state=cfg.ssm_state,
            chunk=min(cfg.ssm_chunk, x.shape[1]))
        x = x + out
    return _apply_ff(cfg, kind, p, x, aux)


def _apply_cross(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                 enc_k: jnp.ndarray, enc_v: jnp.ndarray) -> jnp.ndarray:
    """Cross-attention against precomputed encoder K/V."""
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["attn"].wq)
    ctx = L.attention(q, enc_k, enc_v,
                      jnp.zeros((x.shape[1],), jnp.int32), None,
                      causal=False, q_chunk=1024)
    return x + L.out_proj(p["attn"], ctx)


def _cross_kv(p: Params, enc_out: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["attn"].wk)
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["attn"].wv)
    return k, v


# ================================================================== forward
def _remat_policy(name: str):
    if name == "dots":
        # save matmul outputs (they are small per-device shards post-TP);
        # avoids backward re-gathers of weights/activations — §Perf
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _scan_blocks_train(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                       positions: jnp.ndarray, q_chunk: int,
                       enc_out: Optional[jnp.ndarray] = None,
                       remat: bool = True, remat_policy: str = "nothing"
                       ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    period = cfg.superblock_period()
    sub_kinds = [_sub_kind(cfg, j) for j in range(period)]
    blocks = params["blocks"]

    def superblock(x, slc):
        from repro.launch.sharding import shard_act_btd
        aux = {"lb_loss": jnp.zeros((), jnp.float32),
               "z_loss": jnp.zeros((), jnp.float32)}
        for j, kind in enumerate(sub_kinds):
            x = shard_act_btd(x)      # boundary constraint (no-op w/o mesh)
            x = _apply_sub_train2(cfg, kind, slc[f"sub{j}"], x, positions,
                                  aux, q_chunk)
            if enc_out is not None:
                x = _apply_cross(cfg, slc[f"cross{j}"], x,
                                 *_cross_kv(slc[f"cross{j}"], enc_out))
        return shard_act_btd(x), (aux["lb_loss"], aux["z_loss"])

    if remat:
        superblock = jax.checkpoint(
            superblock, policy=_remat_policy(remat_policy))

    def step(x, slc):
        return superblock(x, slc)

    x, (lb, zl) = jax.lax.scan(step, x, blocks)
    return x, {"lb_loss": jnp.sum(lb), "z_loss": jnp.sum(zl)}


def encode(cfg: ModelConfig, params: Params, src_embeds: jnp.ndarray,
           remat: bool = True) -> jnp.ndarray:
    """Encoder stack (bidirectional attention) over stub frame embeddings."""
    x = src_embeds.astype(cfg.cdtype)
    positions = jnp.arange(x.shape[1])

    def block(x, slc):
        aux: Dict[str, jnp.ndarray] = {}
        h = L.rms_norm(x, slc["norm1"], cfg.norm_eps)
        q, k, v = L.qkv_proj(slc["attn"], h)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        ctx = L.attention(q, k, v, positions, None, causal=False,
                          q_chunk=4096)
        x = x + L.out_proj(slc["attn"], ctx)
        x = _apply_ff(cfg, "attn|mlp", slc, x, aux)
        return x, None

    if remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(block, x, params["enc_blocks"]["sub0"])
    return L.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def forward_train(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
                  q_chunk: int = 1024, remat: bool = True,
                  remat_policy: str = "nothing"
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Token (+ modality-stub) inputs -> mean masked cross-entropy loss."""
    from repro.launch.sharding import shard_act_btd
    tokens = batch["tokens"]
    x = shard_act_btd(params["embed"][tokens].astype(cfg.cdtype))  # (B,S,D)
    offset = 0
    if cfg.frontend == "vision":
        pe = batch["patch_embeds"].astype(cfg.cdtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
        offset = pe.shape[1]
    positions = jnp.arange(x.shape[1])

    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(cfg, params, batch["src_embeds"], remat=remat)

    x, aux = _scan_blocks_train(cfg, params, x, positions, q_chunk,
                                enc_out=enc_out, remat=remat,
                                remat_policy=remat_policy)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if offset:
        x = x[:, offset:, :]
    from repro.launch.sharding import shard_act_logits_input
    x = shard_act_logits_input(x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head)            # bf16
    logits = logits.astype(jnp.float32)
    if head.shape[0] != cfg.vocab_size:                    # mask vocab pad
        pad_mask = jnp.arange(head.shape[0]) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)

    labels = batch["labels"]
    mask = batch["loss_mask"].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + cfg.aux_loss_weight * aux.get("lb_loss", 0.0) \
        + cfg.router_z_loss * aux.get("z_loss", 0.0)
    metrics = {"loss": loss, "lb_loss": aux.get("lb_loss", 0.0),
               "z_loss": aux.get("z_loss", 0.0)}
    return total, metrics


# ================================================================= decoding
def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int,
                      tp: int = 1, dtype=None) -> Caches:
    """Allocate per-sub-layer caches, stacked over superblocks."""
    dt = dtype or cfg.pdtype
    period = cfg.superblock_period()
    nsb = cfg.num_layers // period
    state: Caches = {"pos": jnp.zeros((), jnp.int32)}
    for j in range(period):
        kind = _sub_kind(cfg, j)
        mix, _ = kind.split("|")
        if mix == "attn":
            shape = (nsb, batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
            state[f"sub{j}"] = {"k": jnp.zeros(shape, dt),
                                "v": jnp.zeros(shape, dt)}
        else:
            h = cfg.padded_ssm_heads(tp)
            hd = cfg.d_inner // cfg.ssm_heads
            state[f"sub{j}"] = {
                "ssm": jnp.zeros((nsb, batch, h, hd, cfg.ssm_state),
                                 jnp.float32),
                "conv_x": jnp.zeros(
                    (nsb, batch, cfg.ssm_conv_width - 1, h * hd), dt),
                "conv_bc": jnp.zeros(
                    (nsb, batch, cfg.ssm_conv_width - 1, 2 * cfg.ssm_state),
                    dt)}
        if cfg.encoder_layers:
            shape = (nsb, batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
            state[f"cross{j}"] = {"k": jnp.zeros(shape, dt),
                                  "v": jnp.zeros(shape, dt)}
    return state


def _apply_sub_step(cfg: ModelConfig, kind: str, p: Params, x: jnp.ndarray,
                    cache: Caches, pos: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, Caches]:
    """One sub-layer on (B, S_new, D) with cache read+write (S_new=1 decode,
    or the full prompt during prefill)."""
    mix, _ = kind.split("|")
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    s_new = x.shape[1]
    if mix == "attn":
        q, k, v = L.qkv_proj(p["attn"], h)
        positions = pos + jnp.arange(s_new)
        q = L.apply_rope(q, positions[None, :], cfg.rope_theta)
        k = L.apply_rope(k, positions[None, :], cfg.rope_theta)
        k_cache = L.update_cache(cache["k"], k, pos)
        v_cache = L.update_cache(cache["v"], v, pos)
        ctx = L.attention(q, k_cache, v_cache, positions, pos + s_new,
                          causal=True, q_chunk=1024)
        x = x + L.out_proj(p["attn"], ctx)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        st = ssm_mod.SsmState(ssm=cache["ssm"], conv_x=cache["conv_x"],
                              conv_bc=cache["conv_bc"])
        if s_new == 1:
            out, st = ssm_mod.ssm_decode_step(
                p["ssm"], h, st, heads=_ssm_heads_of(p),
                n_state=cfg.ssm_state)
        else:
            out, st = ssm_mod.ssm_forward(
                p["ssm"], h, heads=_ssm_heads_of(p), n_state=cfg.ssm_state,
                chunk=min(cfg.ssm_chunk, s_new), state=st)
        x = x + out
        new_cache = {"ssm": st.ssm, "conv_x": st.conv_x,
                     "conv_bc": st.conv_bc}
    aux: Dict[str, jnp.ndarray] = {}
    return _apply_ff(cfg, kind, p, x, aux), new_cache


def forward_step(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                 state: Caches,
                 prefix_embeds: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, Caches]:
    """Cache-carrying forward (prefill: tokens (B, S); decode: (B, 1)).
    Returns (logits for the final position (B, V), new state)."""
    period = cfg.superblock_period()
    sub_kinds = [_sub_kind(cfg, j) for j in range(period)]
    pos = state["pos"]
    x = params["embed"][tokens].astype(cfg.cdtype)
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(cfg.cdtype)
        if cfg.frontend == "vision":
            pe = pe @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)

    block_caches = {k: v for k, v in state.items() if k != "pos"}

    def superblock(x, slc_and_cache):
        slc, cache = slc_and_cache
        new_cache = {}
        for j, kind in enumerate(sub_kinds):
            x, nc = _apply_sub_step(cfg, kind, slc[f"sub{j}"], x,
                                    cache[f"sub{j}"], pos)
            new_cache[f"sub{j}"] = nc
            if cfg.encoder_layers:   # cross K/V prefilled by fill_cross_caches
                ck = cache[f"cross{j}"]
                x = _apply_cross(cfg, slc[f"cross{j}"], x, ck["k"], ck["v"])
                new_cache[f"cross{j}"] = ck
        return x, new_cache

    def step(x, inp):
        return superblock(x, inp)

    x, new_caches = jax.lax.scan(step, x, (params["blocks"], block_caches))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,vd->bv", x[:, -1, :], head).astype(jnp.float32)
    if head.shape[0] != cfg.vocab_size:                    # mask vocab pad
        pad_mask = jnp.arange(head.shape[0]) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    new_state: Caches = dict(new_caches)
    new_state["pos"] = pos + x.shape[1]
    return logits, new_state


def fill_cross_caches(cfg: ModelConfig, params: Params, state: Caches,
                      enc_out: jnp.ndarray) -> Caches:
    """Precompute encoder K/V for every decoder layer (encdec prefill)."""
    period = cfg.superblock_period()
    new_state = dict(state)
    for j in range(period):
        cp = params["blocks"][f"cross{j}"]
        k, v = jax.vmap(lambda p: _cross_kv(p, enc_out),
                        in_axes=0)(cp)     # stacked over superblocks
        slen = k.shape[2]
        ck = dict(new_state[f"cross{j}"])
        ck["k"] = jax.lax.dynamic_update_slice(
            ck["k"], k.astype(ck["k"].dtype), (0, 0, 0, 0, 0))
        ck["v"] = jax.lax.dynamic_update_slice(
            ck["v"], v.astype(ck["v"].dtype), (0, 0, 0, 0, 0))
        new_state[f"cross{j}"] = ck
    return new_state
