"""Pallas TPU kernels: cloud-in-cell splat/gather for the FFT repulsion grid.

The sparse tSNE backend's repulsion pass moves all N points through a G×G
particle-mesh grid every iteration (``tsne.fft_repulsion``): splat the
masses (1, y_x, y_y) bilinearly onto the grid, FFT-convolve, gather the
fields back bilinearly.  The XLA path expresses the splat as four
scatter-adds of N updates each — fine on CPU at moderate N, but scatter
is the one primitive in the sparse iteration that does not vectorize.

These kernels recast BOTH directions as dense one-hot matmuls, which is
what the MXU actually wants:

* for a tile of B points, build the separable bilinear weight matrices
  wx, wy (B, G) — each row holds (1−f) at the point's cell and f at
  cell+1, so the outer product wx[p]ᵀ·wy[p] is exactly the 4-corner CIC
  stencil;
* splat:   grid[c]  = Σ_p m_c[p]·wx[p]ᵀ·wy[p]  →  (wxᵀ∘m_c) @ wy,
  accumulated across point tiles (the grid output block is revisited by
  every step of the 1-D point grid);
* gather:  out[p,c] = wx[p] @ field[c] @ wy[p]ᵀ  →  rowsum((wx@field[c])∘wy).

Cost per tile and channel is one (G, B)×(B, G) (splat) or (B, G)×(G, G)
(gather) matmul — O(G²) MACs per point, MORE flops than the 4-corner
stencil's O(1) updates, but they are dense MXU flops instead of XLA's
serial scatter-update walk; the trade only pays where scatter stalls the
pipeline (keep G moderate — at the adaptive cap G = 1024 the one-hot
matrices dwarf the stencil work even on the MXU).  On CPU the kernels run
in interpret mode (tests pin fp agreement against the XLA path); dispatch
is via ``TsneConfig.cic = "pallas"`` through ``tsne.fft_repulsion``.

Padding contract (handled by ``ops.cic_splat``/``ops.cic_gather``): point
tiles are padded to ``block_items``; padded rows carry zero masses (splat
adds nothing) and their gathered rows are sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import registry


def _onehot_weights(i0: jnp.ndarray, f: jnp.ndarray, g: int):
    """Separable CIC weight matrices wx, wy (B, G) for one point tile."""
    b = i0.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (b, g), 1)
    wx = jnp.where(iota == i0[:, 0:1], 1.0 - f[:, 0:1], 0.0) \
        + jnp.where(iota == i0[:, 0:1] + 1, f[:, 0:1], 0.0)
    wy = jnp.where(iota == i0[:, 1:2], 1.0 - f[:, 1:2], 0.0) \
        + jnp.where(iota == i0[:, 1:2] + 1, f[:, 1:2], 0.0)
    return wx, wy


def _splat_kernel(i0_ref, f_ref, vals_ref, out_ref, *, g: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    wx, wy = _onehot_weights(i0_ref[...], f_ref[...], g)
    vals = vals_ref[...]                                     # (B, C)
    for c in range(out_ref.shape[0]):
        out_ref[c] += jnp.dot(wx.T * vals[:, c][None, :], wy,
                              preferred_element_type=jnp.float32)


def _gather_kernel(fields_ref, i0_ref, f_ref, out_ref, *, g: int):
    wx, wy = _onehot_weights(i0_ref[...], f_ref[...], g)
    for c in range(fields_ref.shape[0]):
        tmp = jnp.dot(wx, fields_ref[c],
                      preferred_element_type=jnp.float32)    # (B, G)
        out_ref[:, c] = jnp.sum(tmp * wy, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("grid_size", "block_items", "interpret"))
def cic_splat(i0: jnp.ndarray, f: jnp.ndarray, vals: jnp.ndarray,
              grid_size: int, *, block_items: int = 1024,
              interpret: bool = True) -> jnp.ndarray:
    """Splat per-point channel masses onto the grid: (C, G, G).

    i0 (N, 2) int32 cell indices in [0, G−2], f (N, 2) fractional
    offsets, vals (N, C) channel masses (zero rows = padding no-ops).
    N must be a multiple of ``block_items`` (ops.py pads).
    """
    n, c = vals.shape
    assert n % block_items == 0
    return pl.pallas_call(
        functools.partial(_splat_kernel, g=grid_size),
        grid=(n // block_items,),
        in_specs=[
            pl.BlockSpec((block_items, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_items, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_items, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((c, grid_size, grid_size),
                               lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, grid_size, grid_size),
                                       jnp.float32),
        interpret=interpret,
    )(i0, f.astype(jnp.float32), vals.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_items", "interpret"))
def cic_gather(fields: jnp.ndarray, i0: jnp.ndarray, f: jnp.ndarray, *,
               block_items: int = 1024, interpret: bool = True
               ) -> jnp.ndarray:
    """Bilinear per-point gather of C grid fields: (N, C).

    fields (C, G, G) float32, i0/f as in :func:`cic_splat`.  N must be a
    multiple of ``block_items`` (ops.py pads; padded rows are junk to be
    sliced off by the caller).
    """
    c, g, _ = fields.shape
    n = i0.shape[0]
    assert n % block_items == 0
    return pl.pallas_call(
        functools.partial(_gather_kernel, g=g),
        grid=(n // block_items,),
        in_specs=[
            pl.BlockSpec((c, g, g), lambda i: (0, 0, 0)),
            pl.BlockSpec((block_items, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_items, 2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_items, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.float32),
        interpret=interpret,
    )(fields.astype(jnp.float32), i0, f.astype(jnp.float32))


# -- XLA references + registry wiring ---------------------------------------

def cic_splat_xla(i0: jnp.ndarray, f: jnp.ndarray, vals: jnp.ndarray,
                  grid_size: int, **_tile) -> jnp.ndarray:
    """Pure-XLA splat: four scatter-adds, one per CIC corner.  Same
    padding contract as the kernel (zero-mass rows splat nothing)."""
    f = f.astype(jnp.float32)
    v = vals.astype(jnp.float32)
    out = jnp.zeros((vals.shape[1], grid_size, grid_size), jnp.float32)
    for dx in (0, 1):
        for dy in (0, 1):
            w = ((f[:, 0] if dx else 1.0 - f[:, 0])
                 * (f[:, 1] if dy else 1.0 - f[:, 1]))      # (N,)
            out = out.at[:, i0[:, 0] + dx, i0[:, 1] + dy].add(
                w[None, :] * v.T)
    return out


def cic_gather_xla(fields: jnp.ndarray, i0: jnp.ndarray, f: jnp.ndarray,
                   **_tile) -> jnp.ndarray:
    """Pure-XLA gather: four corner gathers, bilinearly weighted."""
    f = f.astype(jnp.float32)
    fld = fields.astype(jnp.float32)
    acc = jnp.zeros((i0.shape[0], fields.shape[0]), jnp.float32)
    for dx in (0, 1):
        for dy in (0, 1):
            w = ((f[:, 0] if dx else 1.0 - f[:, 0])
                 * (f[:, 1] if dy else 1.0 - f[:, 1]))      # (N,)
            acc = acc + w[:, None] * fld[:, i0[:, 0] + dx, i0[:, 1] + dy].T
    return acc


def _splat_mode(interpret: bool):
    def fn(i0, f, vals, grid_size, *, block_items: int = 1024):
        return cic_splat(i0, f, vals, grid_size, block_items=block_items,
                         interpret=interpret)
    return fn


def _gather_mode(interpret: bool):
    def fn(fields, i0, f, *, block_items: int = 1024):
        return cic_gather(fields, i0, f, block_items=block_items,
                          interpret=interpret)
    return fn


registry.register("cic_splat", "compiled")(_splat_mode(False))
registry.register("cic_splat", "interpret")(_splat_mode(True))
registry.register("cic_splat", "xla")(cic_splat_xla)
registry.register("cic_gather", "compiled")(_gather_mode(False))
registry.register("cic_gather", "interpret")(_gather_mode(True))
registry.register("cic_gather", "xla")(cic_gather_xla)
