"""Pallas TPU kernels: flash-style fused tSNE gradient.

Exact tSNE materializes three (N, N) matrices per iteration (P, Q, and
(P−Q)·num).  At the paper's N = 2·10⁴ representatives that is 4.8 GB of
HBM traffic per iteration; beyond N ≈ 10⁵ it stops fitting entirely.  This
kernel *never materializes any N×N matrix*: like flash attention, it
streams (Bi × Bj) tiles, recomputing both the high-dim affinity P (from
the calibrated per-point precisions beta and row normalizers zp) and the
low-dim kernel Q on the fly, accumulating forces tile-by-tile in VMEM.

Two passes per iteration (Z is a global reduction that must precede the
force weighting — same structure as flash attention's softmax statistics):

    pass 1 (``tsne_z``):       Z = Σ_{i≠j} 1/(1+|y_i−y_j|²)
    pass 2 (``tsne_forces``):  F_i = 4 Σ_j (exag·P_ij − num_ij/Z)·num_ij·(y_i−y_j)

Both are (N/B)² tile grids; all matmuls (x_i·x_jᵀ, pq·y_j) hit the MXU.
HBM traffic drops from O(N²) to O(N²·D/B) — with B = 512, D ≤ 10, that is
a ≥ 50× reduction, turning the embedder from memory-bound to compute-bound
(see EXPERIMENTS.md §Perf for the roofline arithmetic).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sq_dists(a, b):
    """(Bi, D), (Bj, D) -> (Bi, Bj) squared distances, MXU-shaped."""
    a2 = jnp.sum(a * a, axis=1)
    b2 = jnp.sum(b * b, axis=1)
    cross = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    return jnp.maximum(a2[:, None] - 2.0 * cross + b2[None, :], 0.0)


def _pair_mask(bi, bj, block, n_valid):
    """(Bi, Bj) True where the pair is valid (off-diagonal, not padding)."""
    gi = bi * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    gj = bj * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    return (gi != gj) & (gi < n_valid) & (gj < n_valid)


def _z_kernel(y_i_ref, y_j_ref, z_ref, *, block: int, n_valid: int):
    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    d2 = _sq_dists(y_i_ref[...], y_j_ref[...])
    num = 1.0 / (1.0 + d2)
    mask = _pair_mask(pl.program_id(0), pl.program_id(1), block, n_valid)
    z_ref[0, 0] += jnp.sum(jnp.where(mask, num, 0.0))


def _force_kernel(x_i_ref, x_j_ref, y_i_ref, y_j_ref, beta_i_ref,
                  beta_j_ref, zp_i_ref, zp_j_ref, z_ref, out_ref,
                  *, block: int, n_valid: int, exaggeration: float):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mask = _pair_mask(pl.program_id(0), pl.program_id(1), block, n_valid)
    # high-dim affinity, recomputed on the fly (never stored)
    d2x = _sq_dists(x_i_ref[...], x_j_ref[...])
    pc_ij = jnp.exp(-beta_i_ref[...] * d2x) / zp_i_ref[...]        # (Bi,Bj)
    pc_ji = jnp.exp(-beta_j_ref[...].T * d2x) / zp_j_ref[...].T
    p = jnp.where(mask, (pc_ij + pc_ji) / (2.0 * n_valid), 0.0)
    # low-dim kernel
    y_i = y_i_ref[...]
    y_j = y_j_ref[...]
    num = 1.0 / (1.0 + _sq_dists(y_i, y_j))
    num = jnp.where(mask, num, 0.0)
    q = num / z_ref[0, 0]
    pq = (exaggeration * p - q) * num
    out_ref[...] += 4.0 * (
        jnp.sum(pq, axis=1, keepdims=True) * y_i
        - jnp.dot(pq, y_j, preferred_element_type=jnp.float32))


@functools.partial(jax.jit, static_argnames=("block", "n_valid", "interpret"))
def tsne_z(y: jnp.ndarray, *, block: int = 256, n_valid: int = None,
           interpret: bool = True) -> jnp.ndarray:
    """Repulsive normalizer Z.  y is (N, dims), N a multiple of block."""
    n = y.shape[0]
    n_valid = n if n_valid is None else n_valid
    assert n % block == 0
    nb = n // block
    z = pl.pallas_call(
        functools.partial(_z_kernel, block=block, n_valid=n_valid),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, y.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((block, y.shape[1]), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(y, y)
    return z[0, 0]


@functools.partial(jax.jit, static_argnames=(
    "block", "n_valid", "exaggeration", "interpret"))
def tsne_forces(x: jnp.ndarray, y: jnp.ndarray, beta: jnp.ndarray,
                zp: jnp.ndarray, z: jnp.ndarray, *, block: int = 256,
                n_valid: int = None, exaggeration: float = 1.0,
                interpret: bool = True) -> jnp.ndarray:
    """Fused tSNE gradient.  x (N, Dh), y (N, dims), beta/zp (N,), z scalar.

    N must be a multiple of ``block`` (ops.py pads; padded rows produce
    zero force and are masked out of every pair).
    """
    n = x.shape[0]
    n_valid = n if n_valid is None else n_valid
    assert n % block == 0
    nb = n // block
    beta2 = beta[:, None]
    zp2 = zp[:, None]
    zmat = jnp.reshape(z, (1, 1)).astype(jnp.float32)

    return pl.pallas_call(
        functools.partial(_force_kernel, block=block, n_valid=n_valid,
                          exaggeration=float(exaggeration)),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, x.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((block, x.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((block, y.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((block, y.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, y.shape[1]), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, y.shape[1]), jnp.float32),
        interpret=interpret,
    )(x, x, y, y, beta2, beta2, zp2, zp2, zmat)
