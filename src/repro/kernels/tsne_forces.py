"""Pallas TPU kernels: flash-style fused tSNE gradient.

Exact tSNE materializes three (N, N) matrices per iteration (P, Q, and
(P−Q)·num).  At the paper's N = 2·10⁴ representatives that is 4.8 GB of
HBM traffic per iteration; beyond N ≈ 10⁵ it stops fitting entirely.  This
kernel *never materializes any N×N matrix*: like flash attention, it
streams (Bi × Bj) tiles, recomputing both the high-dim affinity P (from
the calibrated per-point statistics beta / shift / zp / w — see
``repro.core.tsne.PointStats``) and the low-dim kernel Q on the fly,
accumulating forces tile-by-tile in VMEM.

Two passes per iteration (Z is a global reduction that must precede the
force weighting — same structure as flash attention's softmax statistics):

    pass 1 (``tsne_z``):       Z = Σ_{i≠j} 1/(1+|y_i−y_j|²)
    pass 2 (``tsne_forces``):  F_i = 4 Σ_j (exag·P_ij − num_ij/Z)·num_ij·(y_i−y_j)

with the weighted symmetrization  P_ij = ½ (w_i·pc(j|i) + w_j·pc(i|j)),
pc(j|i) = exp(−beta_i·d²x_ij − shift_i)/zp_i.  Uniform w_i = 1/N recovers
the classic (pc + pcᵀ)/2N.  ``shift`` is the flash-style log-domain row
shift that keeps the recomputed exponentials in range.  Exaggeration and
Z arrive as traced scalars so the kernel can live inside a ``fori_loop``
without retracing per phase.  Pass 2 also accumulates the two KL partial
sums Σ pe·log pe and Σ pe·log num so the optimizer gets the loss for free.

Both passes are (N/B)² tile grids; all matmuls (x_i·x_jᵀ, pq·y_j) hit the
MXU.  HBM traffic drops from O(N²) to O(N²·D/B) — with B = 512, D ≤ 10,
that is a ≥ 50× reduction, turning the embedder from memory-bound to
compute-bound (see EXPERIMENTS.md §Perf for the roofline arithmetic).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import registry


def _sq_dists(a, b):
    """(Bi, D), (Bj, D) -> (Bi, Bj) squared distances, MXU-shaped."""
    a2 = jnp.sum(a * a, axis=1)
    b2 = jnp.sum(b * b, axis=1)
    cross = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    return jnp.maximum(a2[:, None] - 2.0 * cross + b2[None, :], 0.0)


def _pair_mask(bi, bj, block, n_valid):
    """(Bi, Bj) True where the pair is valid (off-diagonal, not padding)."""
    gi = bi * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    gj = bj * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    return (gi != gj) & (gi < n_valid) & (gj < n_valid)


def _z_kernel(y_i_ref, y_j_ref, z_ref, *, block: int, n_valid: int):
    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    d2 = _sq_dists(y_i_ref[...], y_j_ref[...])
    num = 1.0 / (1.0 + d2)
    mask = _pair_mask(pl.program_id(0), pl.program_id(1), block, n_valid)
    z_ref[0, 0] += jnp.sum(jnp.where(mask, num, 0.0))


def _force_kernel(x_i_ref, x_j_ref, y_i_ref, y_j_ref, s_i_ref, s_j_ref,
                  scal_ref, out_ref, kl_ref, *, block: int, n_valid: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _init_kl():
        kl_ref[...] = jnp.zeros_like(kl_ref)

    mask = _pair_mask(pl.program_id(0), pl.program_id(1), block, n_valid)
    s_i = s_i_ref[...]                       # (Bi, 4): beta, shift, zp, w
    s_j = s_j_ref[...]                       # (Bj, 4)
    # high-dim affinity, recomputed on the fly (never stored)
    d2x = _sq_dists(x_i_ref[...], x_j_ref[...])
    pc_ij = jnp.exp(-s_i[:, 0:1] * d2x - s_i[:, 1:2]) / s_i[:, 2:3]
    pc_ji = jnp.exp(-s_j[:, 0][None, :] * d2x - s_j[:, 1][None, :]) \
        / s_j[:, 2][None, :]
    p = jnp.where(
        mask, 0.5 * (s_i[:, 3:4] * pc_ij + s_j[:, 3][None, :] * pc_ji), 0.0)
    # low-dim kernel
    y_i = y_i_ref[...]
    y_j = y_j_ref[...]
    num = 1.0 / (1.0 + _sq_dists(y_i, y_j))
    num = jnp.where(mask, num, 0.0)
    q = num / scal_ref[0, 0]
    pe = scal_ref[0, 1] * p                  # exaggerated P
    pq = (pe - q) * num
    out_ref[...] += 4.0 * (
        jnp.sum(pq, axis=1, keepdims=True) * y_i
        - jnp.dot(pq, y_j, preferred_element_type=jnp.float32))
    kl_ref[0, 0] += jnp.sum(
        jnp.where(pe > 0, pe * jnp.log(jnp.maximum(pe, 1e-37)), 0.0))
    kl_ref[0, 1] += jnp.sum(
        jnp.where(pe > 0, pe * jnp.log(jnp.maximum(num, 1e-37)), 0.0))


@functools.partial(jax.jit, static_argnames=("block", "n_valid", "interpret"))
def tsne_z(y: jnp.ndarray, *, block: int = 256, n_valid: int = None,
           interpret: bool = True) -> jnp.ndarray:
    """Repulsive normalizer Z.  y is (N, dims), N a multiple of block."""
    n = y.shape[0]
    n_valid = n if n_valid is None else n_valid
    assert n % block == 0
    nb = n // block
    z = pl.pallas_call(
        functools.partial(_z_kernel, block=block, n_valid=n_valid),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, y.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((block, y.shape[1]), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(y, y)
    return z[0, 0]


@functools.partial(jax.jit, static_argnames=("block", "n_valid", "interpret"))
def tsne_forces(x: jnp.ndarray, y: jnp.ndarray, stats: jnp.ndarray,
                z: jnp.ndarray, exaggeration: jnp.ndarray, *,
                block: int = 256, n_valid: int = None,
                interpret: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused tSNE gradient + KL partials.

    x (N, Dh), y (N, dims), stats (N, 4) = [beta, shift, zp, w] columns,
    z / exaggeration traced scalars.  N must be a multiple of ``block``
    (ops.py pads; padded rows carry w = 0, produce zero force, and are
    masked out of every pair).

    Returns (forces (N, dims), kl_parts (1, 2)) with
    kl_parts = [Σ pe·log pe, Σ pe·log num] over valid pairs, pe = exag·P.
    """
    n = x.shape[0]
    n_valid = n if n_valid is None else n_valid
    assert n % block == 0
    nb = n // block
    scal = jnp.stack([z.astype(jnp.float32),
                      jnp.asarray(exaggeration, jnp.float32)]).reshape(1, 2)

    return pl.pallas_call(
        functools.partial(_force_kernel, block=block, n_valid=n_valid),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, x.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((block, x.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((block, y.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((block, y.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((block, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((block, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block, y.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n, y.shape[1]), jnp.float32),
            jax.ShapeDtypeStruct((1, 2), jnp.float32),
        ),
        interpret=interpret,
    )(x, x, y, y, stats, stats, scal)


# -- XLA reference + registry wiring ----------------------------------------
# The registered op "tsne_step" is the full two-pass iteration on the
# PADDED arrays: fn(x, y, stats, exaggeration, *, block, n_valid) ->
# (forces (N, dims), kl_parts (1, 2), z).  ops.tsne_step_fused handles
# padding/unpadding and routes here through the registry.

def tsne_step_xla(x: jnp.ndarray, y: jnp.ndarray, stats: jnp.ndarray,
                  exaggeration, *, block: int = 256,
                  n_valid: int = None) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                jnp.ndarray]:
    """Dense pure-jnp reference with the kernel's exact masking and KL
    partial-sum semantics (``block`` is accepted and ignored)."""
    n = x.shape[0]
    n_valid = n if n_valid is None else n_valid
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    beta, shift, zp, w = (stats[:, 0].astype(jnp.float32),
                          stats[:, 1].astype(jnp.float32),
                          stats[:, 2].astype(jnp.float32),
                          stats[:, 3].astype(jnp.float32))
    idx = jnp.arange(n)
    mask = ((idx[:, None] != idx[None, :])
            & (idx[:, None] < n_valid) & (idx[None, :] < n_valid))
    d2x = _sq_dists(x, x)
    pc = jnp.exp(-beta[:, None] * d2x - shift[:, None]) / zp[:, None]
    p = jnp.where(mask, 0.5 * (w[:, None] * pc + w[None, :] * pc.T), 0.0)
    num = jnp.where(mask, 1.0 / (1.0 + _sq_dists(y, y)), 0.0)
    z = jnp.sum(num)
    exag = jnp.asarray(exaggeration, jnp.float32)
    pe = exag * p
    pq = (pe - num / z) * num
    forces = 4.0 * (jnp.sum(pq, axis=1, keepdims=True) * y
                    - jnp.dot(pq, y, preferred_element_type=jnp.float32))
    kl_parts = jnp.stack([
        jnp.sum(jnp.where(pe > 0, pe * jnp.log(jnp.maximum(pe, 1e-37)), 0.0)),
        jnp.sum(jnp.where(pe > 0, pe * jnp.log(jnp.maximum(num, 1e-37)),
                          0.0))]).reshape(1, 2)
    return forces, kl_parts, z


def _step_mode(interpret: bool):
    def fn(x, y, stats, exaggeration, *, block: int = 256, n_valid=None):
        z = tsne_z(y, block=block, n_valid=n_valid, interpret=interpret)
        f, kl_parts = tsne_forces(
            x, y, stats, z, jnp.asarray(exaggeration, jnp.float32),
            block=block, n_valid=n_valid, interpret=interpret)
        return f, kl_parts, z
    return fn


registry.register("tsne_step", "compiled")(_step_mode(False))
registry.register("tsne_step", "interpret")(_step_mode(True))
registry.register("tsne_step", "xla")(tsne_step_xla)
