"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; kernel tests sweep shapes and
dtypes and assert allclose against these.  They are also the CPU fallback
paths used when Pallas is unavailable.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import hashing, quantize, sketch as sketch_mod, u64
from repro.core.hashing import MulShiftParams
from repro.core.quantize import GridSpec


def hash_points(params: MulShiftParams, grid: GridSpec,
                points: jnp.ndarray, log2_cols: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """points (N, D) -> (buckets (R, N) uint32, signs (R, N) int32)."""
    key_hi, key_lo = quantize.points_to_keys(grid, points)
    buckets = hashing.bucket_hash(params, key_hi, key_lo, log2_cols)
    signs = hashing.sign_hash(params, key_hi, key_lo)
    return buckets, signs


def sketch_update(table: jnp.ndarray, buckets: jnp.ndarray,
                  signs: jnp.ndarray,
                  values: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """table (R, C) += scatter of signs*values at buckets.  The oracle for
    the fused accumulate kernel (hashes precomputed)."""
    r, c = table.shape
    n = buckets.shape[1]
    v = jnp.ones((n,), table.dtype) if values is None \
        else values.astype(table.dtype)
    upd = signs.astype(table.dtype) * v[None, :]
    flat_idx = (jnp.arange(r, dtype=jnp.int32)[:, None] * c
                + buckets.astype(jnp.int32))
    flat = table.reshape(-1).at[flat_idx.reshape(-1)].add(upd.reshape(-1))
    return flat.reshape(r, c)


def sketch_estimate(table: jnp.ndarray, buckets: jnp.ndarray,
                    signs: jnp.ndarray) -> jnp.ndarray:
    """Per-row signed gather: est (R, Q) = sign * table[r, bucket]."""
    gathered = jnp.take_along_axis(table, buckets.astype(jnp.int32), axis=1)
    return gathered.astype(jnp.float32) * signs.astype(jnp.float32)


def estimate_median(table: jnp.ndarray, buckets: jnp.ndarray,
                    signs: jnp.ndarray) -> jnp.ndarray:
    """Full estimate: median over rows of the signed gather -> (Q,)."""
    return jnp.median(sketch_estimate(table, buckets, signs), axis=0)


def tsne_z(y: jnp.ndarray) -> jnp.ndarray:
    """Repulsive normalizer Z = sum_{i != j} 1/(1+|y_i-y_j|^2)."""
    n = y.shape[0]
    d = jnp.sum(y * y, 1)[:, None] - 2 * (y @ y.T) + jnp.sum(y * y, 1)[None]
    num = 1.0 / (1.0 + jnp.maximum(d, 0.0))
    num = num.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    return jnp.sum(num)


def tsne_forces(x: jnp.ndarray, y: jnp.ndarray, beta: jnp.ndarray,
                zp: jnp.ndarray, z: jnp.ndarray,
                exaggeration: float = 1.0,
                shift: Optional[jnp.ndarray] = None,
                weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Fused-tSNE oracle: gradient with P recomputed on the fly from the
    high-dim points.

    p_cond(j|i) = exp(-beta_i d2x_ij - shift_i) / zp_i  (zp excludes the
    diagonal; shift defaults to 0, the unshifted-zp convention),
    P = (w_i p_cond + w_j p_cond^T) / 2 (uniform w = 1/N -> classic
    (pc + pc^T)/2N),  q = num/z,  grad_i = 4 sum_j (exag*P-q)
    * num * (y_i - y_j).
    """
    n = x.shape[0]
    m = jnp.zeros((n,)) if shift is None else shift
    w = jnp.full((n,), 1.0 / n) if weights is None \
        else weights / jnp.sum(weights)
    d2x = jnp.sum(x * x, 1)[:, None] - 2 * (x @ x.T) + jnp.sum(x * x, 1)[None]
    d2x = jnp.maximum(d2x, 0.0)
    pc = jnp.exp(-beta[:, None] * d2x - m[:, None]) / zp[:, None]
    pc = pc.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    wpc = w[:, None] * pc
    p = 0.5 * (wpc + wpc.T)
    d2y = jnp.sum(y * y, 1)[:, None] - 2 * (y @ y.T) + jnp.sum(y * y, 1)[None]
    num = 1.0 / (1.0 + jnp.maximum(d2y, 0.0))
    num = num.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    q = num / z
    pq = (exaggeration * p - q) * num
    return 4.0 * (jnp.sum(pq, 1, keepdims=True) * y - pq @ y)


def tsne_zp(x: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """Row normalizers zp_i = sum_{j != i} exp(-beta_i d2x_ij) (helper for
    building tsne_forces inputs from calibrated betas)."""
    n = x.shape[0]
    d2x = jnp.sum(x * x, 1)[:, None] - 2 * (x @ x.T) + jnp.sum(x * x, 1)[None]
    d2x = jnp.maximum(d2x, 0.0)
    e = jnp.exp(-beta[:, None] * d2x)
    e = e.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    return jnp.sum(e, axis=1)
