"""Pallas TPU kernel: fused hash + ±1 accumulate into a VMEM-resident table.

The paper's GPU implementation scatter-adds into the sketch with CUDA
atomics.  TPUs have no atomics — instead we exploit the *sequential* TPU
grid: the (R, C) table is an output block whose index_map pins it to the
same VMEM tile for every grid step ("output revisiting"), so accumulation
across item blocks is race-free by construction.

Per grid step: a (block_items,) slab of pre-packed 64-bit keys is hashed
for all R rows *vectorized* (VPU), then accumulated with an unrolled
scalar loop (R dynamic stores per item).  The scalar stores serialize on
real hardware, so this kernel is the **low-latency small-batch path**
(items ≲ 10⁵ per call: decode-time activation sketching, per-microbatch
gradient sketches).  The bulk path for 10⁸⁺ items/call is the fused runs
pipeline — ``candidates.sorted_runs`` (one XLA sort + segment-sum per
chunk) feeding ``sketch.update_runs`` (one deduped scatter) and the
reservoir merge alike; ``sketch.update_sorted`` wraps the same pair for
callers holding raw keys.  Sorting turns random access into sequential
streaming — see DESIGN.md §3.

VMEM budget: table (R=16, C=2¹⁵) f32 = 2 MiB + block of keys — fits v5e's
16 MiB VMEM with room for double-buffered inputs; ops.py enforces
C ≤ 2¹⁶ for the kernel path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hashing
from repro.core.hashing import MulShiftParams


def _kernel(key_hi_ref, key_lo_ref, values_ref, params_ref, table_ref,
            *, rows: int, log2_cols: int, block_items: int):
    # zero the table on the first visit
    @pl.when(pl.program_id(0) == 0)
    def _init():
        table_ref[...] = jnp.zeros_like(table_ref)

    params = MulShiftParams(*(params_ref[i, :] for i in range(6)))
    khi = key_hi_ref[0, :]
    klo = key_lo_ref[0, :]
    buckets = hashing.bucket_hash(params, khi, klo, log2_cols)  # (R, B)
    signs = hashing.sign_hash(params, khi, klo)                 # (R, B)
    vals = values_ref[0, :]                                     # (B,)
    upd = signs.astype(table_ref.dtype) * vals[None, :].astype(table_ref.dtype)

    def body(i, _):
        for r in range(rows):                    # static unroll over rows
            c = buckets[r, i].astype(jnp.int32)
            table_ref[r, pl.dslice(c, 1)] += upd[r, i]
        return 0

    jax.lax.fori_loop(0, block_items, body, 0)


@functools.partial(jax.jit, static_argnames=(
    "rows", "log2_cols", "block_items", "interpret"))
def sketch_update_table(params: MulShiftParams, key_hi: jnp.ndarray,
                        key_lo: jnp.ndarray, values: jnp.ndarray,
                        *, rows: int, log2_cols: int,
                        block_items: int = 1024,
                        interpret: bool = True) -> jnp.ndarray:
    """Build a fresh (R, C) f32 table from (N,) keys + values in one fused
    pass.  N must be a multiple of block_items (ops.py pads with value=0)."""
    n = key_hi.shape[0]
    assert n % block_items == 0, (n, block_items)
    nb = n // block_items
    cols = 1 << log2_cols
    pmat = jnp.stack(list(params), axis=0)            # (6, R)

    return pl.pallas_call(
        functools.partial(_kernel, rows=rows, log2_cols=log2_cols,
                          block_items=block_items),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block_items), lambda i: (0, i)),
            pl.BlockSpec((1, block_items), lambda i: (0, i)),
            pl.BlockSpec((1, block_items), lambda i: (0, i)),
            pl.BlockSpec((6, rows), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, cols), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(key_hi[None, :], key_lo[None, :], values[None, :], pmat)
