"""Pallas TPU kernel: fused quantize → pack → hash for a block of points.

The VPU-bound front half of the sketch pipeline.  One grid step loads a
(block_items, D) tile of points into VMEM, quantizes against the grid,
packs the bin coordinates into 64-bit keys (uint32 limb pairs) and
evaluates all R bucket/sign hashes — ~8 uint32 multiplies per point-row,
fully vectorized, zero HBM round-trips for the intermediates.

Feeds either the sort-based production aggregation (`ops.hash_points` →
`sketch.update_sorted`) or the fused accumulate kernel
(`kernels.sketch_update`).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import hashing, u64
from repro.core.hashing import MulShiftParams
from repro.core.quantize import GridSpec


def _kernel(points_ref, lo_ref, inv_ref, params_ref,
            buckets_ref, signs_ref, *, grid_spec: GridSpec, log2_cols: int):
    pts = points_ref[...]                         # (B, D) f32
    lo = lo_ref[...]                              # (1, D)
    inv = inv_ref[...]                            # (1, D)
    # quantize
    idx = jnp.floor((pts - lo) * inv)
    idx = jnp.clip(idx, 0.0, float(grid_spec.bins - 1)).astype(jnp.uint32)
    # pack bit-fields into u64 limb pairs
    bits = grid_spec.bits_per_dim
    key = (jnp.zeros((pts.shape[0],), jnp.uint32),
           jnp.zeros((pts.shape[0],), jnp.uint32))
    for d in range(grid_spec.dims):
        key = u64.shl(key, bits)
        key = u64.add_u32(key, idx[:, d])
    # hash all R rows
    params = MulShiftParams(*(params_ref[i, :] for i in range(6)))
    buckets_ref[...] = hashing.bucket_hash(params, key[0], key[1], log2_cols)
    signs_ref[...] = hashing.sign_hash(params, key[0], key[1])


@functools.partial(jax.jit, static_argnames=(
    "grid_spec", "log2_cols", "block_items", "interpret"))
def hash_points(params: MulShiftParams, grid_spec: GridSpec,
                points: jnp.ndarray, log2_cols: int,
                block_items: int = 1024, interpret: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """points (N, D) → (buckets (R, N) uint32, signs (R, N) int32).

    N must be a multiple of ``block_items`` (ops.py pads).
    """
    n, d = points.shape
    r = params.rows
    assert n % block_items == 0, (n, block_items)
    nb = n // block_items
    lo = jnp.asarray(grid_spec.lo_arr, jnp.float32)[None, :]
    inv = jnp.asarray(grid_spec.bins / (grid_spec.hi_arr - grid_spec.lo_arr),
                      jnp.float32)[None, :]
    pmat = jnp.stack(list(params), axis=0)        # (6, R) uint32

    return pl.pallas_call(
        functools.partial(_kernel, grid_spec=grid_spec, log2_cols=log2_cols),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_items, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((6, r), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((r, block_items), lambda i: (0, i)),
            pl.BlockSpec((r, block_items), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, n), jnp.uint32),
            jax.ShapeDtypeStruct((r, n), jnp.int32),
        ],
        interpret=interpret,
    )(points, lo, inv, pmat)
