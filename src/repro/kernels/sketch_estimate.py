"""Pallas TPU kernel: sketch estimate as MXU one-hot gathers.

``estimate`` needs table[r, h1_r(q)] for Q queries × R rows.  Arbitrary
gather is slow on TPU; instead each (Q_tile × C_tile) one-hot indicator is
contracted against the table tile on the MXU:

    est[r, q] = Σ_c  1[h1_r(q) = c] · table[r, c]        (then · sign)

Grid is (q_tiles, c_tiles); the output tile revisits across the C
dimension, and exactly one C tile contributes per (r, q), so the signed
contribution accumulates to the gathered value.  Work is R·Q·C MAC — for
the paper's query load (Q = 2·10⁴ candidates, R = 16, C = 2¹⁸) that is
8.4·10¹⁰ MAC ≈ 0.9 ms at v5e's MXU rate, versus a scalar gather that
would issue R·Q = 3.2·10⁵ serialized VMEM reads.

The row-wise median (R is 16; tiny) runs as a normal XLA op outside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(table_ref, buckets_ref, signs_ref, out_ref,
            *, rows: int, block_c: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c_off = pl.program_id(1) * block_c
    col_ids = c_off + jax.lax.broadcasted_iota(
        jnp.int32, (buckets_ref.shape[1], block_c), 1)      # (Qt, Ct)
    for r in range(rows):                                   # static unroll
        b = buckets_ref[r, :].astype(jnp.int32)             # (Qt,)
        onehot = (b[:, None] == col_ids).astype(jnp.float32)
        gathered = jnp.dot(onehot, table_ref[r, :].astype(jnp.float32),
                           preferred_element_type=jnp.float32)   # (Qt,)
        out_ref[r, :] += signs_ref[r, :].astype(jnp.float32) * gathered


@functools.partial(jax.jit, static_argnames=(
    "block_q", "block_c", "interpret"))
def sketch_estimate_table(table: jnp.ndarray, buckets: jnp.ndarray,
                          signs: jnp.ndarray, *, block_q: int = 256,
                          block_c: int = 512, interpret: bool = True
                          ) -> jnp.ndarray:
    """(R, C) table + (R, Q) buckets/signs → (R, Q) signed estimates.

    Q must be a multiple of block_q, C of block_c (ops.py pads queries)."""
    r, c = table.shape
    q = buckets.shape[1]
    assert q % block_q == 0 and c % block_c == 0, (q, block_q, c, block_c)

    return pl.pallas_call(
        functools.partial(_kernel, rows=r, block_c=block_c),
        grid=(q // block_q, c // block_c),
        in_specs=[
            pl.BlockSpec((r, block_c), lambda qi, ci: (0, ci)),
            pl.BlockSpec((r, block_q), lambda qi, ci: (0, qi)),
            pl.BlockSpec((r, block_q), lambda qi, ci: (0, qi)),
        ],
        out_specs=pl.BlockSpec((r, block_q), lambda qi, ci: (0, qi)),
        out_shape=jax.ShapeDtypeStruct((r, q), jnp.float32),
        interpret=interpret,
    )(table, buckets, signs)
