"""Backend-aware kernel dispatch registry.

Every Pallas call site in the repo (CIC splat/gather, the kNN distance
scan, the fused tSNE force tile, the sorted-COO segment reduce) routes
through this module instead of hard-coding ``interpret = backend != "tpu"``
at each call.  Each op registers up to three implementations:

    "compiled"   — pl.pallas_call with interpret=False (Mosaic / Triton);
                   only *supported* on accelerator backends.
    "interpret"  — the same kernel body executed by the Pallas
                   interpreter; runs anywhere, bit-compatible with
                   compiled modulo fp reassociation.
    "xla"        — a pure-jnp reference with identical semantics; the
                   ground truth every other mode is tested against.

Resolution order under ``mode="auto"`` is compiled → interpret → xla:
the first implementation whose ``prefer`` predicate accepts the current
``(backend, shape, dtype)`` wins.  ``prefer`` is the *auto-ordering*
preference (e.g. the segment-reduce interpret kernel declines CPU so the
cumsum-difference XLA path stays the CPU default), while ``supported``
is the hard capability gate (compiled kernels cannot run on CPU at all,
so forcing ``mode="compiled"`` there fails loudly rather than silently
falling back — a CI box must never *think* it exercised Mosaic).

Mode precedence, highest first:

    1. an explicit ``mode=`` argument at the call site (tests pin these);
    2. a per-op override installed with :func:`set_mode_override`;
    3. the process-wide ``SNS_KERNEL_MODE`` env var (the CI kernel-matrix
       step pins ``interpret`` / ``xla`` this way, per whole process, so
       jit caches are never invalidated mid-run);
    4. ``"auto"``.

Call sites thread the resolved mode as a jit-static string, so two modes
never share a compilation cache entry.  ``SnsConfig.kernel_mode`` feeds
(1) through the config plumbing in ``core.pipeline``.

The module also owns the per-backend tile-size table (VMEM-conscious
defaults for compiled grids) and an optional empirical autotune cache:
winners are persisted to JSON keyed by ``(backend, op, shape-bucket)``
so a one-off ``bench_kernels --autotune`` pass on real hardware keeps
paying off across processes.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax

MODES = ("compiled", "interpret", "xla")
ENV_VAR = "SNS_KERNEL_MODE"
CACHE_ENV_VAR = "SNS_KERNEL_CACHE"

#: Backends on which a non-interpret pallas_call can actually compile.
ACCELERATOR_BACKENDS = ("tpu", "gpu", "cuda", "rocm")

Predicate = Callable[[Optional[str], Tuple[int, ...], Any], bool]


class KernelUnavailableError(RuntimeError):
    """No registered implementation satisfies the requested mode/backend."""


def always(backend: Optional[str], shape: Tuple[int, ...],
           dtype: Any) -> bool:
    """Predicate: runs anywhere."""
    return True


def accel_only(backend: Optional[str], shape: Tuple[int, ...],
               dtype: Any) -> bool:
    """Predicate: accelerator backends only (no CPU Mosaic/Triton)."""
    return backend in ACCELERATOR_BACKENDS


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One registered implementation of one op."""
    op: str
    mode: str            # "compiled" | "interpret" | "xla"
    fn: Callable
    supported: Predicate  # hard capability gate (checked even when forced)
    prefer: Predicate     # auto-ordering preference (checked in "auto" only)

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)


_REGISTRY: Dict[str, Dict[str, KernelImpl]] = {}
_MODE_OVERRIDES: Dict[str, str] = {}   # op (or "*") -> mode
_LOCK = threading.Lock()
_BUILTINS_LOADED = False


def register(op: str, mode: str, *, supported: Predicate = None,
             prefer: Predicate = None) -> Callable[[Callable], Callable]:
    """Decorator: register ``fn`` as the ``mode`` implementation of ``op``.

    ``supported`` defaults to :func:`always` for interpret/xla and
    :func:`accel_only` for compiled; ``prefer`` defaults to ``supported``.
    Re-registering an (op, mode) pair overwrites (last wins) so tests can
    install probes.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")

    def deco(fn: Callable) -> Callable:
        sup = supported if supported is not None else (
            accel_only if mode == "compiled" else always)
        pref = prefer if prefer is not None else sup
        with _LOCK:
            _REGISTRY.setdefault(op, {})[mode] = KernelImpl(
                op=op, mode=mode, fn=fn, supported=sup, prefer=pref)
        return fn
    return deco


def _ensure_builtins() -> None:
    """Import the kernel modules whose import side-effect is registration.

    Lazy so that ``import repro.kernels.registry`` stays cheap and free of
    import cycles (the kernel modules import this module at top level).
    """
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    from repro.kernels import cic, knn_tile, segment_reduce, tsne_forces  # noqa: F401


def list_ops() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def modes_of(op: str) -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(m for m in MODES if m in _REGISTRY.get(op, {}))


def get(op: str, mode: str) -> Optional[KernelImpl]:
    _ensure_builtins()
    return _REGISTRY.get(op, {}).get(mode)


def set_mode_override(mode: Optional[str], op: str = "*") -> None:
    """Install (or with ``mode=None`` clear) a per-op or global override.

    NOTE: overrides are consulted at *trace* time.  Flipping one mid-
    process does not invalidate already-compiled jit caches whose call
    sites resolved under the old override; prefer explicit ``mode=``
    arguments (fresh static-arg cache key) or the process-level env var.
    """
    if mode is not None and mode not in MODES + ("auto",):
        raise ValueError(f"mode must be one of {MODES + ('auto',)} or None")
    with _LOCK:
        if mode is None:
            _MODE_OVERRIDES.pop(op, None)
        else:
            _MODE_OVERRIDES[op] = mode


def resolve_mode(mode: Optional[str] = None, op: str = "*") -> str:
    """Collapse the precedence chain to a concrete mode (or "auto")."""
    if mode is not None and mode != "auto":
        if mode not in MODES:
            raise ValueError(f"unknown kernel mode {mode!r}; "
                             f"expected one of {MODES + ('auto',)}")
        return mode
    for key in (op, "*"):
        if key in _MODE_OVERRIDES:
            return _MODE_OVERRIDES[key]
    env = os.environ.get(ENV_VAR, "")
    if env:
        if env not in MODES + ("auto",):
            raise ValueError(f"{ENV_VAR}={env!r} is not one of "
                             f"{MODES + ('auto',)}")
        return env
    return "auto"


def coerce_mode(interpret: Optional[bool] = None,
                mode: Optional[str] = None) -> Optional[str]:
    """Back-compat shim: map a legacy ``interpret`` flag to a mode string.

    An explicit ``mode`` wins; an explicit boolean ``interpret`` maps to
    interpret/compiled; both-None defers to :func:`resolve_mode`.
    """
    if mode is not None:
        return mode
    if interpret is True:
        return "interpret"
    if interpret is False:
        return "compiled"
    return None


def legacy_mode(op: str, interpret: Optional[bool] = None,
                mode: Optional[str] = None) -> Optional[str]:
    """Mode for a call site that still carries a legacy ``interpret``
    flag.  An explicit ``mode=`` is user forcing and wins outright; the
    boolean is only a backend-derived *default*, so a process-level pin
    (per-op override / ``SNS_KERNEL_MODE``) beats it — that is what lets
    the CI kernel-matrix step pin a whole run to interpret/xla without
    touching every internal call site.  Both-None defers entirely."""
    if mode is not None:
        return mode
    pinned = resolve_mode(None, op)
    if pinned != "auto":
        return pinned
    return coerce_mode(interpret, None)


def resolve(op: str, *, mode: Optional[str] = None,
            backend: Optional[str] = None, shape: Tuple[int, ...] = (),
            dtype: Any = None) -> KernelImpl:
    """Pick the implementation for ``op``.  Fails loudly, never silently
    downgrades a forced mode."""
    _ensure_builtins()
    if op not in _REGISTRY:
        raise KeyError(f"unknown kernel op {op!r}; "
                       f"registered: {list_ops()}")
    if backend is None:
        backend = jax.default_backend()
    m = resolve_mode(mode, op)
    impls = _REGISTRY[op]
    if m != "auto":
        impl = impls.get(m)
        if impl is None:
            raise KernelUnavailableError(
                f"op {op!r} has no {m!r} implementation "
                f"(registered: {modes_of(op)})")
        if not impl.supported(backend, tuple(shape), dtype):
            raise KernelUnavailableError(
                f"op {op!r} mode {m!r} is not supported on backend "
                f"{backend!r} for shape {tuple(shape)} dtype {dtype}")
        return impl
    for cand in MODES:  # compiled -> interpret -> xla
        impl = impls.get(cand)
        if impl is None:
            continue
        if impl.prefer(backend, tuple(shape), dtype) \
                and impl.supported(backend, tuple(shape), dtype):
            return impl
    raise KernelUnavailableError(
        f"op {op!r}: no implementation accepts backend {backend!r} "
        f"(registered: {modes_of(op)})")


# ---------------------------------------------------------------------------
# Per-backend tile-size table + autotune cache
# ---------------------------------------------------------------------------

# VMEM/SMEM-conscious compiled-grid defaults.  "*" is the fallback row
# (CPU interpret mode is insensitive to these; the values keep the
# interpret grids identical to today's defaults so jit caches and tests
# are stable).  TPU rows keep the largest live block under ~2 MiB of
# VMEM at the adaptive grid cap G = 1024 (cic one-hots are (B, G) f32).
_TILE_TABLE: Dict[str, Dict[str, Dict[str, int]]] = {
    "cic_splat": {"tpu": {"block_items": 512},
                  "gpu": {"block_items": 1024},
                  "*": {"block_items": 1024}},
    "cic_gather": {"tpu": {"block_items": 512},
                   "gpu": {"block_items": 1024},
                   "*": {"block_items": 1024}},
    "knn_dist_tiles": {"*": {}},     # blocks are data-shape-determined
    "tsne_step": {"tpu": {"block": 512},
                  "gpu": {"block": 256},
                  "*": {"block": 256}},
    "segment_reduce": {"tpu": {"rows_per_block": 256, "edge_chunk": 512},
                       "gpu": {"rows_per_block": 128, "edge_chunk": 512},
                       "*": {"rows_per_block": 128, "edge_chunk": 256}},
}


def shape_bucket(shape: Tuple[int, ...]) -> str:
    """Next-pow2 bucket per dim: (1000, 2) -> "1024x2" (autotune keys)."""
    parts = []
    for s in shape:
        s = int(s)
        parts.append(str(s if s <= 1 else 1 << (s - 1).bit_length()))
    return "x".join(parts) if parts else "scalar"


def _cache_path(path: Optional[str] = None) -> str:
    if path:
        return path
    env = os.environ.get(CACHE_ENV_VAR)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache",
                        "sns_kernel_autotune.json")


def load_autotune_cache(path: Optional[str] = None) -> Dict[str, Dict]:
    p = _cache_path(path)
    try:
        with open(p, "r", encoding="utf-8") as fh:
            data = json.load(fh)
    except (OSError, ValueError):
        return {}
    return data if isinstance(data, dict) else {}


def record_autotune(op: str, params: Dict[str, int], *,
                    backend: Optional[str] = None, bucket: str = "",
                    path: Optional[str] = None) -> str:
    """Persist an autotune winner; returns the cache key written."""
    backend = backend or jax.default_backend()
    key = f"{backend}/{op}/{bucket or '*'}"
    p = _cache_path(path)
    cache = load_autotune_cache(p)
    cache[key] = dict(params)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    tmp = p + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(cache, fh, indent=2, sort_keys=True)
    os.replace(tmp, p)
    return key


def tile_params(op: str, *, backend: Optional[str] = None,
                shape: Tuple[int, ...] = None,
                cache_path: Optional[str] = None) -> Dict[str, int]:
    """Tile sizes for ``op``: autotuned winner if cached, else the table.

    Lookup order: exact ``backend/op/bucket`` autotune entry, then the
    backend's wildcard-bucket entry, then the static table row for the
    backend, then the table's "*" row.
    """
    backend = backend or jax.default_backend()
    table = _TILE_TABLE.get(op, {})
    base = dict(table.get("*", {}))
    base.update(table.get(backend, {}))
    cache = load_autotune_cache(cache_path)
    for key in (f"{backend}/{op}/*",
                f"{backend}/{op}/{shape_bucket(tuple(shape))}"
                if shape is not None else None):
        if key and key in cache and isinstance(cache[key], dict):
            base.update({k: int(v) for k, v in cache[key].items()})
    return base


def autotune_op(op: str, candidates, measure, *,
                backend: Optional[str] = None, bucket: str = "",
                cache_path: Optional[str] = None) -> Dict[str, int]:
    """Empirical autotune: time ``measure(params)`` (seconds) for each
    candidate dict, persist the winner, return it.  Candidates that raise
    (e.g. a block size that exceeds VMEM) are skipped; all failing is an
    error."""
    backend = backend or jax.default_backend()
    best, best_t = None, float("inf")
    for params in candidates:
        try:
            t = float(measure(dict(params)))
        except Exception:                                    # noqa: BLE001
            continue
        if t < best_t:
            best, best_t = dict(params), t
    if best is None:
        raise KernelUnavailableError(
            f"autotune for op {op!r} on {backend!r}: every candidate failed")
    record_autotune(op, best, backend=backend, bucket=bucket,
                    path=cache_path)
    return best
