"""Pallas kernel: fused segment reduce for the sorted-COO attraction pass.

``coo.segment_reduce`` computes per-row sums of row-sorted edge payloads
as a cumulative-sum difference — a deliberate workaround for XLA *CPU*
scatter, which walks updates serially (~100× slower at E ~ 10⁷).  On an
accelerator the cumsum trick is itself the bottleneck: it materializes an
(E+1, D) prefix array and two gathers of HBM traffic for what is really
one streaming pass over the edges.

This kernel does the reduction in ONE pass with tiled, row-bounds-aware
partial sums.  The grid runs over blocks of R output rows; each step
reads its R+1 row bounds, walks the covered edge span [bounds[r0],
bounds[r0+R]) in fixed-size chunks of C edges, and folds each chunk into
the (R, D) accumulator as a one-hot membership matmul:

    onehot[r, c] = 1  iff  bounds[r0+r] <= edge_c < bounds[r0+r+1]
    acc         += onehot @ chunk          (fp32 MXU accumulation)

Each edge chunk is read once by the single row block that owns it (plus
at most once more when a chunk straddles a block boundary), so HBM
traffic is O(E·D) — no prefix array, no gathers, no scatter.  fp32
accumulation is pinned regardless of the payload dtype.

Numerics: a direct per-row sum and the cumsum-difference reassociate
floating-point addition differently, so bitwise equality with
``coo.segment_reduce`` holds exactly when the additions are exact (e.g.
integer-valued fp32 payloads below 2²⁴ — the kernel tests pin bit-for-bit
there) and to ~1e-6 relative otherwise.  The cumsum path remains the CPU
default; this kernel registers as the accelerator-preferred path (its
``prefer`` predicate declines CPU in auto mode, while forced
``mode="interpret"`` still runs it anywhere for CI coverage).

VMEM note: v1 keeps the whole (E, D) payload resident per grid step
(full-array BlockSpec).  That bounds compiled use to edge lists that fit
VMEM (~10⁶ × 2 fp32 at 16 MiB); streaming the spans via explicit HBM DMA
is the follow-up once real hardware is in the loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import registry


def _seg_kernel(bounds_ref, vals_ref, out_ref, *, rows: int, chunk: int):
    i = pl.program_id(0)
    r0 = i * rows
    b = bounds_ref[pl.ds(r0, rows + 1), 0]                   # (R+1,)
    lo = b[0]
    hi = b[rows]
    n_chunks = (hi - lo + chunk - 1) // chunk

    def body(j, acc):
        start = lo + j * chunk
        ch = vals_ref[pl.ds(start, chunk), :]                # (C, D)
        eidx = start + jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
        inside = (eidx >= b[:rows][:, None]) & (eidx < b[1:][:, None])
        onehot = jnp.where(inside & (eidx < hi), 1.0, 0.0)   # (R, C)
        return acc + jnp.dot(onehot, ch.astype(jnp.float32),
                             preferred_element_type=jnp.float32)

    acc = jnp.zeros((rows, out_ref.shape[1]), jnp.float32)
    out_ref[...] = jax.lax.fori_loop(0, n_chunks, body, acc)


@functools.partial(
    jax.jit, static_argnames=("rows_per_block", "edge_chunk", "interpret"))
def _segment_reduce_padded(vals: jnp.ndarray, bounds2d: jnp.ndarray, *,
                           rows_per_block: int, edge_chunk: int,
                           interpret: bool) -> jnp.ndarray:
    """vals (Ep, D) f32 (guard-padded), bounds2d (Np+1, 1) int32 with Np a
    multiple of rows_per_block -> (Np, D) f32 row sums."""
    ep, d = vals.shape
    np1 = bounds2d.shape[0]
    n_pad = np1 - 1
    assert n_pad % rows_per_block == 0
    return pl.pallas_call(
        functools.partial(_seg_kernel, rows=rows_per_block,
                          chunk=edge_chunk),
        grid=(n_pad // rows_per_block,),
        in_specs=[
            pl.BlockSpec((np1, 1), lambda i: (0, 0)),
            pl.BlockSpec((ep, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        interpret=interpret,
    )(bounds2d, vals)


def segment_reduce_pallas(vals: jnp.ndarray, bounds: jnp.ndarray, *,
                          rows_per_block: int = 128, edge_chunk: int = 256,
                          interpret: bool = True) -> jnp.ndarray:
    """Row sums of row-sorted edge payloads via the fused kernel.

    vals (E,) or (E, D); bounds (N+1,) int32 ascending with bounds[0] = 0
    and bounds[N] = E (``coo.row_bounds`` output).  Matches
    ``coo.segment_reduce`` semantics, fp32 accumulation, result cast back
    to the payload dtype.
    """
    squeeze = vals.ndim == 1
    v = vals[:, None] if squeeze else vals
    e, d = v.shape
    n = bounds.shape[0] - 1
    if n == 0:
        out = jnp.zeros((0, d), vals.dtype)
        return out[:, 0] if squeeze else out
    rows_per_block = min(rows_per_block, max(n, 1))
    n_pad = -(-n // rows_per_block) * rows_per_block
    # padded rows are empty segments: repeat the terminal bound
    bpad = jnp.concatenate(
        [bounds.astype(jnp.int32),
         jnp.full((n_pad - n,), bounds[-1], jnp.int32)])[:, None]
    # guard chunk of zero payload so the last dynamic slice never clamps
    # into live edges (dynamic_slice clamps OOB starts backwards)
    vpad = jnp.pad(v.astype(jnp.float32),
                   [(0, (-e) % edge_chunk + edge_chunk), (0, 0)])
    out = _segment_reduce_padded(
        vpad, bpad, rows_per_block=rows_per_block, edge_chunk=edge_chunk,
        interpret=interpret)[:n].astype(vals.dtype)
    return out[:, 0] if squeeze else out


def segment_reduce_xla(vals: jnp.ndarray, bounds: jnp.ndarray
                       ) -> jnp.ndarray:
    """Reference: the cumsum-difference trick (mirrors
    ``coo.segment_reduce``'s arithmetic exactly — same reassociation,
    same bits)."""
    zero = jnp.zeros((1,) + vals.shape[1:], vals.dtype)
    csum = jnp.concatenate([zero, jnp.cumsum(vals, axis=0)], axis=0)
    return csum[bounds[1:]] - csum[bounds[:-1]]


# -- registry wiring --------------------------------------------------------

def _run(interpret: bool):
    def fn(vals, bounds, *, rows_per_block: int = 128,
           edge_chunk: int = 256):
        return segment_reduce_pallas(
            vals, bounds, rows_per_block=rows_per_block,
            edge_chunk=edge_chunk, interpret=interpret)
    return fn


registry.register("segment_reduce", "compiled")(_run(False))
# prefer declines CPU so the cumsum path stays the CPU default in auto
# mode; forcing mode="interpret" still runs the kernel anywhere.
registry.register("segment_reduce", "interpret",
                  prefer=registry.accel_only)(_run(True))


@registry.register("segment_reduce", "xla")
def _xla(vals, bounds, **_tile):
    return segment_reduce_xla(vals, bounds)
