"""Pallas TPU kernel: tiled squared-distance scan for the approximate kNN
candidate stage.

The ann candidate generator (``core.ann``) turns the bucketing pass into a
regular computation: after sorting points by grid-cell key, each sorted
tile of B query rows scores the same shared window of C = 3B candidate
rows (its own tile plus a one-tile halo on each side).  That is exactly an
MXU-shaped block — one (B, D) × (D, C) matmul per tile plus rank-1
row/column norm corrections:

    d²(q, c) = |q|² + |c|² − 2·q@cᵀ

The kernel computes one (B, C) block of squared distances per grid step
and masks invalid candidates to +inf in-register:

* ``cid < 0``   — window padding (halo beyond the sorted range, or tile
  padding past N);
* ``cid == qid`` — self-pairs.

``top_k`` selection stays outside in XLA (per-row k-selection is not MXU
work).  On CPU the kernel runs in interpret mode; ``distance_tiles``
dispatches between it and the pure-XLA reference (``tile="xla"``), and
tests pin fp agreement between the two, padding paths included.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import registry


def _dist_kernel(qx_ref, qid_ref, cx_ref, cid_ref, out_ref):
    q = qx_ref[0]                                            # (B, D)
    c = cx_ref[0]                                            # (C, D)
    qid = qid_ref[0]                                         # (B,)
    cid = cid_ref[0]                                         # (C,)
    qq = jnp.sum(q * q, axis=1)
    cc = jnp.sum(c * c, axis=1)
    cross = jnp.dot(q, c.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(qq[:, None] + cc[None, :] - 2.0 * cross, 0.0)
    invalid = (cid[None, :] < 0) | (cid[None, :] == qid[:, None])
    out_ref[0] = jnp.where(invalid, jnp.inf, d2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _distance_tiles_pallas(qx: jnp.ndarray, qid: jnp.ndarray,
                           cx: jnp.ndarray, cid: jnp.ndarray,
                           interpret: bool = True) -> jnp.ndarray:
    t, b, d = qx.shape
    c = cx.shape[1]
    return pl.pallas_call(
        _dist_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b), lambda i: (i, 0)),
            pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, b, c), jnp.float32),
        interpret=interpret,
    )(qx.astype(jnp.float32), qid, cx.astype(jnp.float32), cid)


@jax.jit
def _distance_tiles_xla(qx: jnp.ndarray, qid: jnp.ndarray,
                        cx: jnp.ndarray, cid: jnp.ndarray) -> jnp.ndarray:
    qx = qx.astype(jnp.float32)
    cx = cx.astype(jnp.float32)
    qq = jnp.sum(qx * qx, axis=2)                            # (T, B)
    cc = jnp.sum(cx * cx, axis=2)                            # (T, C)
    cross = jnp.einsum("tbd,tcd->tbc", qx, cx,
                       preferred_element_type=jnp.float32)
    d2 = jnp.maximum(qq[:, :, None] + cc[:, None, :] - 2.0 * cross, 0.0)
    invalid = (cid[:, None, :] < 0) | (cid[:, None, :] == qid[:, :, None])
    return jnp.where(invalid, jnp.inf, d2)


def distance_tiles(qx: jnp.ndarray, qid: jnp.ndarray, cx: jnp.ndarray,
                   cid: jnp.ndarray, *, tile: str = "xla",
                   interpret: bool = True,
                   mode: str = None) -> jnp.ndarray:
    """Masked squared-distance blocks for T query tiles.

    qx (T, B, D) query rows, qid (T, B) int32 global ids, cx (T, C, D)
    candidate windows, cid (T, C) int32 candidate ids (−1 = padding).
    Returns (T, B, C) float32 squared distances with padding and
    self-pairs forced to +inf.

    Dispatch goes through ``kernels.registry`` (op ``knn_dist_tiles``).
    ``mode`` forces a registry mode directly; with ``mode=None`` a
    process-level pin (``SNS_KERNEL_MODE`` / override) wins, else the
    legacy ``tile``/``interpret`` pair selects the path as before.
    """
    if tile not in ("pallas", "xla"):
        raise ValueError(f"unknown distance tile backend: {tile!r}")
    if mode is None:
        pinned = registry.resolve_mode(None, "knn_dist_tiles")
        if pinned != "auto":
            mode = pinned
        elif tile == "pallas":
            mode = "interpret" if interpret else "compiled"
        else:
            mode = "xla"
    impl = registry.resolve("knn_dist_tiles", mode=mode, shape=qx.shape,
                            dtype=qx.dtype)
    return impl.fn(qx, qid, cx, cid)


registry.register("knn_dist_tiles", "compiled")(
    lambda qx, qid, cx, cid: _distance_tiles_pallas(
        qx, qid, cx, cid, interpret=False))
registry.register("knn_dist_tiles", "interpret")(
    lambda qx, qid, cx, cid: _distance_tiles_pallas(
        qx, qid, cx, cid, interpret=True))
registry.register("knn_dist_tiles", "xla")(_distance_tiles_xla)
