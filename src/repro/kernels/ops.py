"""Jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, dispatch kernel vs. pure-jnp reference
(`use_kernel=False` or unavailable platform → ref), and adapt to the
CountSketch pytree API so callers can swap paths with one flag.

On this CPU container the kernels run in interpret mode (Python-level
execution of the kernel body); on TPU the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as quantize_mod
from repro.core.hashing import MulShiftParams
from repro.core.quantize import GridSpec
from repro.core.sketch import CountSketch
from repro.kernels import cic as _cic  # noqa: F401 (registers cic ops)
from repro.kernels import hash_points as _hp
from repro.kernels import ref as _ref
from repro.kernels import registry
from repro.kernels import sketch_estimate as _se
from repro.kernels import sketch_update as _su
from repro.kernels import tsne_forces as _tf  # noqa: F401 (registers tsne_step)


def _pad_to(x: jnp.ndarray, multiple: int, axis: int = 0,
            value=0) -> Tuple[jnp.ndarray, int]:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


def hash_points(params: MulShiftParams, grid: GridSpec, points: jnp.ndarray,
                log2_cols: int, *, block_items: int = 1024,
                use_kernel: bool = True, interpret: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused quantize+pack+hash.  Returns (buckets (R, N), signs (R, N))."""
    if not use_kernel:
        return _ref.hash_points(params, grid, points, log2_cols)
    padded, n = _pad_to(points, block_items, axis=0)
    b, s = _hp.hash_points(params, grid, padded, log2_cols,
                           block_items=block_items, interpret=interpret)
    return b[:, :n], s[:, :n]


def sketch_update_fused(sk: CountSketch, key_hi: jnp.ndarray,
                        key_lo: jnp.ndarray,
                        values: Optional[jnp.ndarray] = None,
                        *, block_items: int = 1024,
                        interpret: bool = True) -> CountSketch:
    """Fused hash+accumulate path (low-latency, C ≤ 2¹⁶ — see kernel doc).
    Semantics identical to ``sketch.update``."""
    if sk.cols > (1 << 16):
        raise ValueError(
            f"kernel path supports C <= 2^16 (VMEM-resident table); "
            f"got C={sk.cols}.  Use sketch.update_sorted for bulk streams.")
    n = key_hi.shape[0]
    v = jnp.ones((n,), jnp.float32) if values is None \
        else values.astype(jnp.float32)
    khi, _ = _pad_to(key_hi, block_items)
    klo, _ = _pad_to(key_lo, block_items)
    vpad, _ = _pad_to(v, block_items)          # pad value 0 → no-op updates
    delta = _su.sketch_update_table(
        sk.params, khi, klo, vpad, rows=sk.rows, log2_cols=sk.log2_cols,
        block_items=block_items, interpret=interpret)
    return sk._replace(table=sk.table + delta.astype(sk.table.dtype))


def sketch_estimate_mxu(sk: CountSketch, key_hi: jnp.ndarray,
                        key_lo: jnp.ndarray, *, block_q: int = 256,
                        block_c: int = 512, interpret: bool = True
                        ) -> jnp.ndarray:
    """MXU estimate path: median over rows of one-hot-gathered counts."""
    from repro.core import hashing, sketch as sketch_mod
    n = key_hi.shape[0]
    buckets = hashing.bucket_hash(sk.params, key_hi, key_lo, sk.log2_cols)
    signs = hashing.sign_hash(sk.params, key_hi, key_lo)
    bpad, _ = _pad_to(buckets, block_q, axis=1)
    spad, _ = _pad_to(signs, block_q, axis=1)
    est = _se.sketch_estimate_table(
        sk.table.astype(jnp.float32), bpad, spad,
        block_q=block_q, block_c=block_c, interpret=interpret)
    return jnp.median(est[:, :n], axis=0)


def cic_splat(i0: jnp.ndarray, f: jnp.ndarray, vals: jnp.ndarray,
              grid_size: int, *, block_items: Optional[int] = None,
              interpret: Optional[bool] = None,
              mode: Optional[str] = None) -> jnp.ndarray:
    """Cloud-in-cell splat of (N, C) channel masses → (C, G, G) grid.

    Pads the point list to ``block_items`` (padded rows carry zero mass,
    so they splat nothing).  Dispatch goes through ``kernels.registry``
    (op ``cic_splat``): ``mode`` forces a registry mode; the legacy
    ``interpret`` flag is a backend-derived default that a process-level
    pin (override / ``SNS_KERNEL_MODE``) beats; both-None resolves
    compiled → interpret → xla for the current backend.  ``block_items``
    None consults the per-backend tile table (autotune-cache aware).
    """
    impl = registry.resolve("cic_splat",
                            mode=registry.legacy_mode("cic_splat",
                                                      interpret, mode),
                            shape=vals.shape, dtype=vals.dtype)
    if block_items is None:
        block_items = registry.tile_params(
            "cic_splat", shape=vals.shape)["block_items"]
    i0p, _ = _pad_to(i0, block_items)
    fp, _ = _pad_to(f, block_items)
    vp, _ = _pad_to(vals, block_items)        # pad mass 0 → no-op splat
    return impl.fn(i0p, fp, vp, grid_size, block_items=block_items)


def cic_gather(fields: jnp.ndarray, i0: jnp.ndarray, f: jnp.ndarray, *,
               block_items: Optional[int] = None,
               interpret: Optional[bool] = None,
               mode: Optional[str] = None) -> jnp.ndarray:
    """Bilinear gather of C grid fields at N points → (N, C).

    Pads the point list to ``block_items`` and slices the junk rows off.
    Dispatch as in :func:`cic_splat` (op ``cic_gather``).
    """
    impl = registry.resolve("cic_gather",
                            mode=registry.legacy_mode("cic_gather",
                                                      interpret, mode),
                            shape=fields.shape, dtype=fields.dtype)
    if block_items is None:
        block_items = registry.tile_params(
            "cic_gather", shape=fields.shape)["block_items"]
    i0p, n = _pad_to(i0, block_items)
    fp, _ = _pad_to(f, block_items)
    out = impl.fn(fields, i0p, fp, block_items=block_items)
    return out[:n]


def tsne_step_fused(x: jnp.ndarray, y: jnp.ndarray, beta: jnp.ndarray,
                    zp: jnp.ndarray, *, shift: Optional[jnp.ndarray] = None,
                    weights: Optional[jnp.ndarray] = None,
                    exaggeration=1.0, block: Optional[int] = None,
                    interpret: Optional[bool] = None,
                    mode: Optional[str] = None,
                    return_kl: bool = False):
    """One fused tSNE gradient: pass-1 Z reduction + pass-2 force tiles.

    ``shift`` is the per-row log-domain shift paired with ``zp`` (None =
    unshifted zp, the legacy convention); ``weights`` the normalized point
    masses (None = uniform 1/N, the classic symmetrization).  Exaggeration
    may be a traced scalar.  Dispatch goes through ``kernels.registry``
    (op ``tsne_step``; ``mode``/``interpret`` as in :func:`cic_splat`,
    ``block`` None consults the tile table).  Inputs are promoted to fp32
    before the kernel regardless of dtype (fp16/bf16 in → fp32 accum).
    With ``return_kl`` also returns the KL of exag·P against current Q.
    """
    n = x.shape[0]
    impl = registry.resolve("tsne_step",
                            mode=registry.legacy_mode("tsne_step",
                                                      interpret, mode),
                            shape=x.shape, dtype=x.dtype)
    if block is None:
        block = registry.tile_params("tsne_step", shape=x.shape)["block"]
    m = jnp.zeros((n,), jnp.float32) if shift is None else shift
    w = jnp.full((n,), 1.0 / n, jnp.float32) if weights is None \
        else weights / jnp.sum(weights)
    stats = jnp.stack([beta.astype(jnp.float32), m.astype(jnp.float32),
                       zp.astype(jnp.float32), w.astype(jnp.float32)], axis=1)
    xpad, _ = _pad_to(x.astype(jnp.float32), block)
    ypad, _ = _pad_to(y.astype(jnp.float32), block)
    spad = jnp.pad(stats, [(0, (-n) % block), (0, 0)])
    # padded rows: zp=1 avoids 0-div, w=0 removes them from P
    if (-n) % block:
        spad = spad.at[n:, 2].set(1.0)
    exag = jnp.asarray(exaggeration, jnp.float32)
    f, kl_parts, z = impl.fn(xpad, ypad, spad, exag, block=block, n_valid=n)
    if not return_kl:
        return f[:n]
    kl = kl_parts[0, 0] - kl_parts[0, 1] + exag * jnp.log(z)
    return f[:n], kl
