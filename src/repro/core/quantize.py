"""Hypercube quantizer: points -> grid cells -> packed 64-bit keys.

The paper (§III-1) encloses the data in a D-dimensional hypercube with M
linear bins per axis and concatenates the quantized coordinates into a
feature vector fed to Count Sketch.  We pack with *bit fields* rather than
base-M positional encoding so that unpacking is shift/mask (no 64-bit
division, which TPUs lack): each coordinate gets ceil(log2(M)) bits.

Constraint: D * ceil(log2(M)) <= 64.  The paper's regime (D < 20, M ~ 8-32)
always satisfies this; config validation enforces it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import u64


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """A fitted quantization grid.  Hashable (corner coords stored as
    tuples) so it can ride as a static jit argument into Pallas wrappers."""
    dims: int
    bins: int                      # M, linear bins per axis
    lo: Tuple[float, ...]          # (D,) lower corner
    hi: Tuple[float, ...]          # (D,) upper corner
    bits_per_dim: int = 0

    def __post_init__(self):
        object.__setattr__(self, "lo",
                           tuple(float(v) for v in np.asarray(self.lo).ravel()))
        object.__setattr__(self, "hi",
                           tuple(float(v) for v in np.asarray(self.hi).ravel()))
        bits = max(1, math.ceil(math.log2(self.bins)))
        object.__setattr__(self, "bits_per_dim", bits)
        if self.dims * bits > 64:
            raise ValueError(
                f"cannot pack D={self.dims} dims x {bits} bits into 64-bit keys; "
                f"reduce bins (M={self.bins}) or dims (paper regime is D<20)")

    @property
    def lo_arr(self) -> np.ndarray:
        return np.asarray(self.lo, np.float32)

    @property
    def hi_arr(self) -> np.ndarray:
        return np.asarray(self.hi, np.float32)

    @property
    def cell_size(self) -> np.ndarray:
        return (self.hi_arr - self.lo_arr) / self.bins

    @property
    def volume(self) -> float:
        """Total number of cells V = M^D (paper §III-2)."""
        return float(self.bins) ** self.dims


def fit_grid(points: jnp.ndarray, bins: int,
             lo: Optional[np.ndarray] = None,
             hi: Optional[np.ndarray] = None,
             pad: float = 1e-3) -> GridSpec:
    """Fit the enclosing hypercube.  `lo`/`hi` may be supplied (geo-distributed
    sites must agree on the grid; see core/geo.py) — then no data pass is made."""
    d = int(points.shape[-1])
    if lo is None:
        lo = np.asarray(jnp.min(points.reshape(-1, d), axis=0), np.float32)
    if hi is None:
        hi = np.asarray(jnp.max(points.reshape(-1, d), axis=0), np.float32)
    lo = np.asarray(lo, np.float32)
    hi = np.asarray(hi, np.float32)
    span = np.maximum(hi - lo, 1e-12)
    return GridSpec(dims=d, bins=int(bins), lo=lo - pad * span, hi=hi + pad * span)


def fit_grid_streaming(chunks, bins: int, pad: float = 1e-3) -> GridSpec:
    """Fit the enclosing hypercube from a chunk stream — the first pass of
    the two-pass streaming pipeline.  Chunked running min/max, so no stage
    ever holds the full array; min/max are associative, which makes the
    result bit-identical to :func:`fit_grid` on the concatenated points.

    ``chunks``: an iterable of (n_i, D) arrays, or a callable returning one
    (the re-iterable form used by ``pipeline.run_streaming``).
    """
    if callable(chunks):
        chunks = chunks()
    lo = hi = None
    d = None
    for c in chunks:
        c = np.asarray(c, np.float32)
        if c.ndim != 2:
            c = c.reshape(-1, c.shape[-1])
        if d is None:
            d = c.shape[1]
        if c.shape[0] == 0:        # empty shard batch — min has no identity
            continue
        clo, chi = c.min(axis=0), c.max(axis=0)
        lo = clo if lo is None else np.minimum(lo, clo)
        hi = chi if hi is None else np.maximum(hi, chi)
    if lo is None:
        raise ValueError("fit_grid_streaming: empty chunk stream")
    span = np.maximum(hi - lo, 1e-12)
    return GridSpec(dims=d, bins=int(bins), lo=lo - pad * span,
                    hi=hi + pad * span)


def quantize(grid: GridSpec, points: jnp.ndarray) -> jnp.ndarray:
    """(..., D) float points -> (..., D) uint32 bin coordinates in [0, M)."""
    lo = jnp.asarray(grid.lo_arr)
    inv = jnp.asarray(grid.bins / (grid.hi_arr - grid.lo_arr), jnp.float32)
    idx = jnp.floor((points - lo) * inv)
    idx = jnp.clip(idx, 0, grid.bins - 1)
    return idx.astype(jnp.uint32)


def pack(grid: GridSpec, coords: jnp.ndarray) -> u64.U64:
    """(..., D) uint32 coords -> packed 64-bit keys (hi, lo) of shape (...)."""
    bits = grid.bits_per_dim
    hi = jnp.zeros(coords.shape[:-1], jnp.uint32)
    lo = jnp.zeros(coords.shape[:-1], jnp.uint32)
    key = (hi, lo)
    for i in range(grid.dims):
        key = u64.shl(key, bits)
        key = u64.add_u32(key, coords[..., i])
    return key


def unpack(grid: GridSpec, key: u64.U64) -> jnp.ndarray:
    """Packed keys (...) -> (..., D) uint32 coords (inverse of `pack`)."""
    bits = grid.bits_per_dim
    mask = np.uint32((1 << bits) - 1)
    outs = []
    k = key
    for _ in range(grid.dims):
        outs.append(u64.bitand_u32(k, mask))
        k = u64.shr(k, bits)
    return jnp.stack(outs[::-1], axis=-1)


def cell_center(grid: GridSpec, coords: jnp.ndarray) -> jnp.ndarray:
    """(..., D) uint32 coords -> float32 cell centers in data space."""
    cs = jnp.asarray(grid.cell_size)
    return jnp.asarray(grid.lo_arr) + (coords.astype(jnp.float32) + 0.5) * cs


def points_to_keys(grid: GridSpec, points: jnp.ndarray) -> u64.U64:
    return pack(grid, quantize(grid, points))


def collision_rate(volume: float, num_hh: int, dims: int) -> Tuple[float, float]:
    """Paper §III-2 Poisson contact-neighbourhood collision model.

    K heavy hitters on a grid of V cells; each cell's contact neighbourhood
    is the 3^D hypercube around it, so the HH density per neighbourhood is
    rho = K * 3^D / V.  A *random collision* is a neighbourhood containing
    two or more HHs:  P(coll) = P(N>=2) = 1 - e^-rho - rho*e^-rho, and the
    expected number of collided HHs is C = K * P(coll).  This reproduces the
    paper's numbers: K=1e4, D=10, M=8 -> C~1057; M=16 -> C~0.00144.
    """
    lam = num_hh / volume
    w = 3.0 ** dims
    rho = w * lam
    p_ge2 = 1.0 - math.exp(-rho) - rho * math.exp(-rho)
    return rho, num_hh * p_ge2


def collision_rate_text(volume: float, num_hh: int, dims: int
                        ) -> Tuple[float, float]:
    """The formula as WRITTEN in the paper's text: C = K·P(>0) with
    P(>0) = 1 - e^-rho.  Note: the paper's published numbers (1057,
    0.00144) do NOT follow this formula — they follow :func:`collision_rate`
    (P(N>=2)).  Monte-Carlo placement (benchmarks/bench_collision_model)
    supports the *text* formula for per-HH collision counting; we keep
    both and document the discrepancy in EXPERIMENTS.md.
    """
    lam = num_hh / volume
    w = 3.0 ** dims
    rho = w * lam
    return rho, num_hh * (1.0 - math.exp(-rho))
