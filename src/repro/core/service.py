"""Online SnS service: the pipeline as a long-lived serving system.

ROADMAP item 3's gap between a reproduction and a production service:
the paper's premise is data that never stops arriving at edge nodes, yet
``pipeline.run`` recomputes everything from a cold start.  The sketch is
linear and the reservoir resumable (PR 2/3), so an :class:`SnsService`
keeps one live :class:`~repro.core.stream.IngestState` and serves three
operations, each a distinct perf lever:

* :meth:`SnsService.update` — fold new chunks into the live fold via the
  fused ``ingest_superbatch`` path.  No re-read of history: absorbing a
  chunk costs the same whether the service has seen 10⁴ or 10⁹ points.
  Heavy hitters are NOT re-extracted here; :meth:`SnsService.needs_refresh`
  watches drift (fraction of mass ingested since the last refresh) and
  the space-saving error watermark against the smallest served HH count.

* :meth:`SnsService.refresh` — re-extract HH → representatives → embed.
  Returning representatives are matched to the previous embedding by
  (quantized cell key, replica slot) and seeded at their old coordinates;
  new cells are placed by inverse-distance-weighted kNN interpolation
  over the matched ones; the optimizer then runs from that init (the
  ``init=`` hooks on ``run_tsne``/``run_umap``) with early exaggeration
  skipped and ~10× fewer iterations than cold start.

* :meth:`SnsService.transform` — batched out-of-sample embedding of raw
  query points with NO optimizer: asymmetric kNN of queries against the
  frozen representative set (:func:`repro.core.neighbors.knn_query`),
  then barycentric placement under inverse-square-distance attraction
  weights — one jitted ``lax.map`` over fixed-size chunks, so peak memory
  is O(chunk · N_reps), never (Q, N_reps), and high query traffic serves
  at batched-millisecond latency.

The grid is fixed at construction (the paper's shared-hypercube
contract): cell keys — the identity that the warm-start matching relies
on — are only comparable across refreshes under one grid.

Failure semantics (what retries, what degrades, what fails loud):

* :meth:`SnsService.update_shards` ingests per-shard sources through the
  resilience collector: transient shard failures RETRY under a
  ``RetryPolicy``, stragglers are cut off at a deadline, permanent
  losses DEGRADE into partial aggregation (the service keeps serving;
  ``health()`` reports ``coverage < 1`` and the widened error bound),
  and coverage under ``min_coverage`` FAILS LOUD without touching the
  live fold.
* :meth:`SnsService.refresh` is TRANSACTIONAL: the new snapshot is built
  entirely off to the side and swapped in atomically; any exception
  mid-refresh leaves the previous snapshot serving (``transform()``
  never observes a half-built state) and is recorded in ``health()``
  before re-raising.
* :meth:`SnsService.save` writes atomically (temp + rename) with a
  checksum and rotates the previous generation to a ``.bak``;
  :meth:`SnsService.load` verifies the checksum and falls back to that
  previous good generation if the newest checkpoint is torn or bit-rotted.
* Calling :meth:`transform` / :meth:`save` before the first refresh
  raises :class:`ServiceNotReadyError` (a ``ValueError``) — never an
  attribute or shape error from deep inside a trace.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import geo
from repro.core import heavy_hitters as hh_mod
from repro.core import neighbors, pipeline, replicas
from repro.core import resilience
from repro.core import stream as stream_mod
from repro.core.pipeline import SnsConfig
from repro.core.quantize import GridSpec


class ServiceNotReadyError(ValueError):
    """transform()/save() called before the first successful refresh()."""


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Serving-side knobs (the pipeline knobs stay on ``SnsConfig``)."""
    # refresh policy: refresh when the mass ingested since the last
    # refresh exceeds this fraction of the total stream...
    refresh_drift: float = 0.1
    # ...or when the space-saving eviction watermark (the largest count
    # the candidate stage may have withheld) reaches this fraction of the
    # smallest HH count currently being served — past that, the served
    # top-K set itself is in doubt
    error_ratio: float = 0.5
    # warm refresh iteration budget; 0 → cold budget // warm_factor
    warm_iters: int = 0
    warm_factor: int = 10
    # transform(): kNN fan-out, chunk rows per jitted map step, and the
    # attraction weight floor w = 1/(d² + eps) — eps small enough that an
    # identity query (d = 0) collapses onto its representative
    transform_k: int = 8
    transform_chunk: int = 4096
    transform_eps: float = 1e-12

    def __post_init__(self):
        bad = []
        if not 0.0 <= self.refresh_drift <= 1.0:
            bad.append(f"refresh_drift={self.refresh_drift} (need [0, 1])")
        if self.error_ratio < 0:
            bad.append(f"error_ratio={self.error_ratio} (need >= 0)")
        if self.warm_iters < 0:
            bad.append(f"warm_iters={self.warm_iters} (need >= 0)")
        if self.warm_factor < 1:
            bad.append(f"warm_factor={self.warm_factor} (need >= 1)")
        if self.transform_k < 1:
            bad.append(f"transform_k={self.transform_k} (need >= 1)")
        if self.transform_chunk < 1:
            bad.append(f"transform_chunk={self.transform_chunk} "
                       "(need >= 1)")
        if not self.transform_eps > 0:
            bad.append(f"transform_eps={self.transform_eps} (need > 0)")
        if bad:
            raise ValueError("invalid ServiceConfig: " + "; ".join(bad))


@dataclasses.dataclass
class EmbedCache:
    """The frozen serving snapshot produced by the last refresh()."""
    rep_cell: np.ndarray      # (live,) uint64 packed quantized cell key
    rep_slot: np.ndarray      # (live,) int32 replica slot within the cell
    rep_x: jnp.ndarray        # (live, D) representative data coords
    rep_y: jnp.ndarray        # (live, dims) embedded coords
    rep_w: np.ndarray         # (live,) weights (HH counts)
    rep_ids: np.ndarray       # (live,) HH index of each rep
    min_hh_count: float       # smallest served HH count (error_ratio gate)


@dataclasses.dataclass
class RefreshResult:
    embedding: jnp.ndarray    # (live, dims)
    weights: np.ndarray       # (live,)
    hh_ids: np.ndarray        # (live,)
    warm: bool                # did this refresh run from a warm init?
    n_matched: int            # reps seeded at their previous coordinates
    n_new: int                # reps placed by kNN interpolation
    n_iters: int              # optimizer iterations this refresh ran
    kl_trace: Optional[jnp.ndarray]  # tSNE per-iteration KL (None: UMAP)


@functools.partial(jax.jit, static_argnames=("k", "chunk", "eps"))
def _transform_chunks(q: jnp.ndarray, rep_x: jnp.ndarray,
                      rep_y: jnp.ndarray, k: int, chunk: int, eps: float
                      ) -> jnp.ndarray:
    """Barycentric out-of-sample placement, one chunk at a time.

    ``lax.map`` over (nb, chunk, D) keeps the distance buffer at
    (chunk, N_reps) — the jaxpr never allocates (Q, N_reps)
    (tests/test_service.py pins this on the traced avals)."""
    def one(qc):
        idx, dist = neighbors.knn_query(qc, rep_x, k)
        w = 1.0 / (dist * dist + eps)
        w = w / jnp.sum(w, axis=1, keepdims=True)
        return jnp.einsum("qk,qkd->qd", w, rep_y[idx])

    nb = q.shape[0] // chunk
    out = jax.lax.map(one, q.reshape(nb, chunk, -1))
    return out.reshape(-1, rep_y.shape[1])


def _packed_cells(hh: hh_mod.HeavyHitters, ids: np.ndarray) -> np.ndarray:
    """uint64 packed cell key of each live rep (by its HH index)."""
    hi = np.asarray(hh.key_hi, np.uint64)[ids]
    lo = np.asarray(hh.key_lo, np.uint64)[ids]
    return (hi << np.uint64(32)) | lo


class SnsService:
    """Long-lived SnS pipeline: incremental ingest, warm re-embed,
    batched out-of-sample transform.  See the module docstring for the
    serving model; ``examples/sns_service.py`` walks the full loop."""

    def __init__(self, cfg: SnsConfig, grid: GridSpec, *,
                 tsne_cfg=None, umap_cfg=None,
                 service_cfg: Optional[ServiceConfig] = None):
        self.cfg = cfg
        self.grid = grid
        self.scfg = service_cfg or ServiceConfig()
        self._ecfg = pipeline.resolve_embed_cfg(cfg, tsne_cfg=tsne_cfg,
                                                umap_cfg=umap_cfg)
        pool = cfg.candidate_pool or 2 * cfg.top_k
        self.state = stream_mod.init(jax.random.key(cfg.seed), cfg.rows,
                                     cfg.log2_cols, pool)
        self._cache: Optional[EmbedCache] = None
        self._pending = 0.0   # mass ingested since the last refresh
        # resilience / health bookkeeping (see health())
        self._lost_mass = 0.0          # estimated mass of dropped shards
        self._lost_shards: tuple = ()  # shard ids lost across updates
        self._update_retries = 0       # retry attempts spent in updates
        # per-shard attempt-latency forensics accumulated across
        # update_shards() calls: shard -> {attempts, failures, buckets}
        # (buckets = counts per resilience.LATENCY_BUCKET_LABELS).
        # Operational telemetry only — deliberately NOT checkpointed.
        self._shard_latency: Dict[int, Dict[str, object]] = {}
        self._refreshes = 0
        self._refresh_failures = 0
        self._last_refresh: Optional[Dict[str, object]] = None

    # ------------------------------------------------------------ ingest
    def update(self, chunks) -> Dict[str, float]:
        """Fold new data into the live ingest state (no history re-read).

        ``chunks``: a single (n, D) array, an iterable of them, or a
        zero-arg callable factory.  Returns absorption stats — points
        folded, wall seconds (device-synced), points/sec — plus the
        current drift picture (``pending_fraction``, ``needs_refresh``).
        """
        if pipeline._is_points_array(chunks):
            chunks = [chunks]
        before = float(self.state.count)     # syncs any in-flight fold
        t0 = time.perf_counter()
        self.state = stream_mod.ingest_all(
            self.state, self.grid, pipeline._chunk_stream(chunks),
            self.cfg.ingest_chunk, superbatch=self.cfg.ingest_superbatch)
        absorbed = float(self.state.count) - before   # blocks on the fold
        dt = time.perf_counter() - t0
        self._pending += absorbed
        return {"points": absorbed, "seconds": dt,
                "points_per_sec": absorbed / dt if dt > 0 else 0.0,
                "pending_fraction": self.pending_fraction(),
                "needs_refresh": self.needs_refresh()}

    def update_shards(self, shard_chunks, *,
                      policy: Optional[resilience.RetryPolicy] = None,
                      deadline: Optional[float] = None,
                      min_coverage: float = 0.0,
                      expected_counts=None,
                      faults=None) -> Dict[str, float]:
        """Fold per-shard sources into the live state resiliently.

        ``shard_chunks``: dict ``{shard_id: chunks-or-factory}`` (or a
        sequence, enumerated).  Each shard is ingested independently
        (retried per ``policy``, cut off at ``deadline`` seconds), the
        surviving partial sketches are merged — CountSketch linearity
        makes the merge exactly the fold of the surviving sub-stream —
        and the merged state is folded into the live one.  Lost shards
        widen the served error bound (``health()``) instead of killing
        the service; coverage below ``min_coverage`` raises
        :class:`~repro.core.resilience.CoverageError` WITHOUT touching
        the live fold.
        """
        if not isinstance(shard_chunks, dict):
            shard_chunks = dict(enumerate(shard_chunks))
        pool = int(self.state.cands.capacity)
        jobs = geo.shard_ingest_jobs(
            self.grid, shard_chunks, seed=self.cfg.seed,
            rows=self.cfg.rows, log2_cols=self.cfg.log2_cols, pool=pool,
            chunk_size=self.cfg.ingest_chunk,
            superbatch=self.cfg.ingest_superbatch, faults=faults)
        t0 = time.perf_counter()
        agg = resilience.collect_shards(
            jobs, policy=policy, deadline=deadline,
            min_coverage=min_coverage, expected_counts=expected_counts,
            verify=True)
        # only now touch the live fold (CoverageError above leaves it be)
        self.state = stream_mod.merge_states(self.state, agg.state)
        absorbed = float(agg.observed_count)
        dt = time.perf_counter() - t0
        self._pending += absorbed
        self._lost_mass += float(agg.lost_mass)
        self._lost_shards = tuple(sorted(set(self._lost_shards)
                                         | set(agg.lost)))
        self._update_retries += agg.retries
        self._fold_shard_latency(agg.statuses)
        return {"points": absorbed, "seconds": dt,
                "points_per_sec": absorbed / dt if dt > 0 else 0.0,
                "coverage": agg.coverage, "lost": list(agg.lost),
                "retries": agg.retries,
                "pending_fraction": self.pending_fraction(),
                "needs_refresh": self.needs_refresh()}

    def _fold_shard_latency(self, statuses) -> None:
        """Accumulate per-shard attempt counts + latency buckets from one
        collector pass into the running histograms (health() exposes
        them).  Buckets are log-spaced per
        ``resilience.LATENCY_BUCKET_LABELS``."""
        nb = len(resilience.LATENCY_BUCKET_LABELS)
        for st in statuses:
            rec = self._shard_latency.setdefault(
                int(st.shard), {"attempts": 0, "failures": 0,
                                "buckets": [0] * nb})
            rec["attempts"] += int(st.attempts)
            rec["failures"] += 0 if st.ok else 1
            hist = resilience.latency_histogram(st.attempt_seconds)
            rec["buckets"] = [a + b for a, b in zip(rec["buckets"], hist)]

    def pending_fraction(self) -> float:
        """Fraction of all ingested mass not yet reflected in the served
        embedding (1.0 before the first refresh)."""
        total = float(self.state.count)
        return self._pending / total if total > 0 else 0.0

    def needs_refresh(self) -> bool:
        """Drift / error-bound refresh policy (see ServiceConfig)."""
        if self._cache is None:
            return True
        if self.pending_fraction() >= self.scfg.refresh_drift:
            return True
        return (self.error_bound()
                >= self.scfg.error_ratio * self._cache.min_hh_count)

    def error_bound(self) -> float:
        """Served per-cell count error bound: the space-saving eviction
        watermark widened by the mass of any shards lost in
        :meth:`update_shards` (resilience.widened_bound)."""
        return resilience.widened_bound(
            float(stream_mod.space_saving_bound(self.state)),
            self._lost_mass)

    def coverage(self) -> float:
        """Fraction of the offered stream actually folded (1.0 when no
        shard has ever been lost)."""
        seen = float(self.state.count)
        offered = seen + self._lost_mass
        return seen / offered if offered > 0 else 1.0

    # ----------------------------------------------------------- refresh
    def refresh(self, mode: str = "auto") -> RefreshResult:
        """Re-extract heavy hitters and re-embed, warm-starting from the
        previous embedding when possible.

        ``mode``: ``"auto"`` (warm iff a previous embedding exists and
        any representative matches), ``"cold"`` (force a from-scratch
        embed), ``"warm"`` (fail loudly if there is nothing to warm from).
        """
        if mode not in ("auto", "cold", "warm"):
            raise ValueError(f"unknown refresh mode: {mode!r}")
        if mode == "warm" and self._cache is None:
            raise ValueError("warm refresh requested but no previous "
                             "embedding exists; run refresh() first")
        t0 = time.perf_counter()
        try:
            cache, result = self._build_snapshot(mode)
        except Exception as e:
            # transactional: the half-built snapshot is dropped on the
            # floor — self._cache still serves the previous embedding
            self._refresh_failures += 1
            self._last_refresh = {
                "ok": False, "mode": mode, "error": repr(e),
                "seconds": time.perf_counter() - t0}
            raise
        # commit: swap the snapshot in atomically (plain attribute
        # assignment — transform() sees either the old or the new cache)
        self._cache = cache
        self._pending = 0.0
        self._refreshes += 1
        self._last_refresh = {
            "ok": True, "mode": mode, "warm": result.warm,
            "n_matched": result.n_matched, "n_new": result.n_new,
            "n_iters": result.n_iters,
            "seconds": time.perf_counter() - t0}
        return result

    def _build_snapshot(self, mode: str):
        """Build the next serving snapshot entirely off to the side.
        Returns (EmbedCache, RefreshResult); never mutates self."""
        cfg = self.cfg
        hh = hh_mod.from_candidates(self.state.sketch, self.state.cands,
                                    cfg.top_k)
        # same key discipline as pipeline.embed_stage: reps and optimizer
        # draws are bit-reproducible for a given (seed, HH set)
        krep, kembed = jax.random.split(jax.random.key(cfg.seed + 1))
        reps = replicas.make_representatives(
            krep, self.grid, hh, scheme=cfg.replica_scheme,
            max_replicas=cfg.max_replicas, jitter_frac=cfg.jitter_frac)
        pts, w, ids = replicas.compact(reps)
        cells = _packed_cells(hh, ids)
        slots = (np.flatnonzero(np.asarray(reps.mask))
                 % cfg.max_replicas).astype(np.int32)

        init, n_matched, n_new = None, 0, 0
        if mode != "cold" and self._cache is not None:
            init, n_matched, n_new = self._warm_init(pts, cells, slots)
        warm = init is not None
        ecfg, n_iters = self._refresh_ecfg(warm)

        x, wj = jnp.asarray(pts), jnp.asarray(w)
        emb, trace = pipeline.embed_points(cfg, kembed, x, wj, ecfg,
                                           init=init)
        live_counts = np.asarray(hh.count)[np.asarray(hh.mask).astype(bool)]
        cache = EmbedCache(
            rep_cell=cells, rep_slot=slots, rep_x=x, rep_y=emb,
            rep_w=w, rep_ids=ids,
            min_hh_count=float(live_counts.min()) if live_counts.size
            else 0.0)
        result = RefreshResult(embedding=emb, weights=w, hh_ids=ids,
                               warm=warm, n_matched=n_matched,
                               n_new=n_new, n_iters=n_iters,
                               kl_trace=trace)
        return cache, result

    def _warm_init(self, pts, cells, slots):
        """Seed coordinates for the new rep set from the cached embedding:
        returning (cell, slot) identities keep their old position, new
        ones interpolate over their kNN among the matched (inverse square
        distance weights).  Returns (init | None, n_matched, n_new)."""
        cache = self._cache
        prev = {(int(c), int(s)): j for j, (c, s)
                in enumerate(zip(cache.rep_cell, cache.rep_slot))}
        at = np.array([prev.get((int(c), int(s)), -1)
                       for c, s in zip(cells, slots)], np.int64)
        matched = at >= 0
        n_matched = int(matched.sum())
        if n_matched == 0:
            return None, 0, 0
        old_y = np.asarray(cache.rep_y)
        dims = old_y.shape[1]
        y0 = np.zeros((pts.shape[0], dims), np.float32)
        y0[matched] = old_y[at[matched]]
        fresh = ~matched
        n_new = int(fresh.sum())
        if n_new:
            k = min(self.scfg.transform_k, n_matched)
            idx, dist = neighbors.knn_query(
                jnp.asarray(pts[fresh]), jnp.asarray(pts[matched]), k)
            dist = np.asarray(dist)
            wk = 1.0 / (dist * dist + self.scfg.transform_eps)
            wk /= wk.sum(axis=1, keepdims=True)
            y0[fresh] = np.einsum("qk,qkd->qd", wk,
                                  y0[matched][np.asarray(idx)])
        return jnp.asarray(y0), n_matched, n_new

    def _refresh_ecfg(self, warm: bool):
        """Embedder config + iteration count for this refresh.  Warm runs
        skip early exaggeration (the init is already globally arranged —
        exaggeration would tear it apart) and cut iterations ~10×."""
        ecfg = self._ecfg
        if self.cfg.embedder == "tsne":
            cold = ecfg.n_iter
            if not warm:
                return ecfg, cold
            iters = self.scfg.warm_iters or \
                max(1, cold // self.scfg.warm_factor)
            return dataclasses.replace(
                ecfg, n_iter=iters, exaggeration_iters=0,
                momentum_switch=0), iters
        cold = ecfg.n_epochs
        if not warm:
            return ecfg, cold
        iters = self.scfg.warm_iters or \
            max(1, cold // self.scfg.warm_factor)
        return dataclasses.replace(ecfg, n_epochs=iters), iters

    # ------------------------------------------------------------ health
    def health(self) -> Dict[str, object]:
        """One-call serving/ingest health report.

        ``serving`` is True once a refresh has committed a snapshot;
        ``coverage`` / ``lost_shards`` / ``hh_error_bound`` reflect any
        degradation absorbed by :meth:`update_shards`; ``last_refresh``
        records the most recent refresh outcome (including failures the
        transactional swap rolled back)."""
        c = self._cache
        return {
            "serving": c is not None,
            "n_reps": int(c.rep_y.shape[0]) if c is not None else 0,
            "points": float(self.state.count),
            "pending_fraction": self.pending_fraction(),
            "needs_refresh": self.needs_refresh(),
            "hh_error_bound": self.error_bound(),
            "coverage": self.coverage(),
            "lost_shards": self._lost_shards,
            "update_retries": self._update_retries,
            # per-shard latency forensics: attempt counts + log-spaced
            # per-attempt wall-clock buckets (resilience.
            # LATENCY_BUCKET_LABELS), accumulated over update_shards()
            "shard_latency": {
                s: {"attempts": rec["attempts"],
                    "failures": rec["failures"],
                    "buckets": dict(zip(resilience.LATENCY_BUCKET_LABELS,
                                        rec["buckets"]))}
                for s, rec in sorted(self._shard_latency.items())},
            "refreshes": self._refreshes,
            "refresh_failures": self._refresh_failures,
            "last_refresh": self._last_refresh,
        }

    # --------------------------------------------------------- transform
    def transform(self, queries) -> np.ndarray:
        """Embed raw query points against the frozen served embedding —
        no optimizer.  (Q, D) → (Q, dims); one jitted chunked pass, peak
        memory O(transform_chunk · N_reps)."""
        if self._cache is None:
            raise ServiceNotReadyError(
                "transform() needs a served embedding; call "
                "refresh() first")
        q = np.asarray(queries, np.float32)
        squeeze = q.ndim == 1
        if squeeze:
            q = q[None, :]
        n = q.shape[0]
        if n == 0:
            return np.zeros((0, self._cache.rep_y.shape[1]), np.float32)
        chunk = max(1, min(self.scfg.transform_chunk, n))
        k = min(self.scfg.transform_k, int(self._cache.rep_x.shape[0]))
        pad = (-n) % chunk
        if pad:
            q = np.concatenate(
                [q, np.zeros((pad, q.shape[1]), np.float32)])
        y = _transform_chunks(jnp.asarray(q), self._cache.rep_x,
                              self._cache.rep_y, k, chunk,
                              self.scfg.transform_eps)
        out = np.asarray(y[:n])
        return out[0] if squeeze else out

    # ------------------------------------------------------- persistence
    def save(self, path) -> None:
        """Checkpoint the live fold AND the serving snapshot to one
        ``.npz`` (via ``stream.save_state`` extras).  The write is atomic
        and checksummed, and the previous checkpoint generation rotates
        to ``<path>.npz.bak`` — :meth:`load` falls back to it if this
        write is later found torn or corrupted."""
        if self._cache is None:
            raise ServiceNotReadyError(
                "save() checkpoints the serving snapshot; call refresh() "
                "first (to checkpoint a fold alone, use stream.save_state "
                "on .state)")
        extra = {"pending": np.float64(self._pending),
                 "lost_mass": np.float64(self._lost_mass),
                 "lost_shards": np.asarray(self._lost_shards, np.int64),
                 "update_retries": np.int64(self._update_retries)}
        c = self._cache
        extra.update(
            rep_cell=c.rep_cell, rep_slot=c.rep_slot,
            rep_x=np.asarray(c.rep_x), rep_y=np.asarray(c.rep_y),
            rep_w=c.rep_w, rep_ids=c.rep_ids,
            min_hh_count=np.float64(c.min_hh_count))
        stream_mod.save_state(self.state, path, extra=extra,
                              keep_backup=True)

    @classmethod
    def load(cls, path, cfg: SnsConfig, grid: GridSpec, *,
             tsne_cfg=None, umap_cfg=None,
             service_cfg: Optional[ServiceConfig] = None) -> "SnsService":
        """Resurrect a service from :meth:`save` — the fold continues and
        the served embedding (if one was cached) serves immediately.
        Checksums are verified; if the newest checkpoint is corrupt the
        ``.bak`` generation (rotated by :meth:`save`) is loaded instead
        (``stream.load_state(fallback=True)``)."""
        svc = cls(cfg, grid, tsne_cfg=tsne_cfg, umap_cfg=umap_cfg,
                  service_cfg=service_cfg)
        state, extras = stream_mod.load_state(path, with_extra=True,
                                              fallback=True)
        svc.state = state
        svc._pending = float(extras.get("pending", 0.0))
        svc._lost_mass = float(extras.get("lost_mass", 0.0))
        svc._lost_shards = tuple(
            int(s) for s in extras.get("lost_shards", ()))
        svc._update_retries = int(extras.get("update_retries", 0))
        if "rep_y" in extras:
            svc._cache = EmbedCache(
                rep_cell=extras["rep_cell"].astype(np.uint64),
                rep_slot=extras["rep_slot"].astype(np.int32),
                rep_x=jnp.asarray(extras["rep_x"]),
                rep_y=jnp.asarray(extras["rep_y"]),
                rep_w=extras["rep_w"],
                rep_ids=extras["rep_ids"],
                min_hh_count=float(extras["min_hh_count"]))
        return svc
