"""Vanilla (exact) tSNE in pure JAX — the paper's downstream embedder.

Faithful to van der Maaten & Hinton 2008 + the reference implementation:

* per-point perplexity calibration by binary search over sigma (fixed 50
  iterations, vectorized over points),
* symmetrized joint P, early exaggeration, momentum + per-parameter gains,
* exact O(N²) gradient  4·Σ_j (p_ij − q_ij)(y_i − y_j)/(1 + |y_i − y_j|²).

Weighted extension (SnS): each input point carries a weight w_i (the HH
count).  P is built from the weighted conditional probabilities, so a
representative standing for 10⁶ raw points pulls proportionally harder —
this is the "replication" of paper §II-1 done in closed form (replicas
are still supported; weights are the numerically-clean equivalent).

The O(N²) pairwise kernels are the compute hot-spot; they are expressed
as matmul-shaped ops (squared-distance via Gram matrix) so XLA maps them
to the MXU.  ``repro.kernels.pairwise`` provides the Pallas-fused variant.

Sized for the paper's regime: N = 10⁴–2·10⁴ representatives. N=20k → 3.2 GB
for the (N,N) float32 P/Q — fits one TPU core's HBM comfortably.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TsneConfig:
    dims: int = 2
    perplexity: float = 30.0
    n_iter: int = 500
    early_exaggeration: float = 12.0
    exaggeration_iters: int = 125
    learning_rate: float = 200.0
    momentum_start: float = 0.5
    momentum_final: float = 0.8
    momentum_switch: int = 125
    min_gain: float = 0.01
    sigma_search_iters: int = 50


def pairwise_sq_dists(x: jnp.ndarray, y: Optional[jnp.ndarray] = None
                      ) -> jnp.ndarray:
    """Squared Euclidean distances via the Gram-matrix identity (MXU-shaped)."""
    y = x if y is None else y
    xx = jnp.sum(x * x, axis=1)
    yy = jnp.sum(y * y, axis=1)
    d = xx[:, None] - 2.0 * (x @ y.T) + yy[None, :]
    return jnp.maximum(d, 0.0)


def _cond_probs_and_entropy(neg_d: jnp.ndarray, beta: jnp.ndarray
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-wise conditional P and Shannon entropy for precision beta.

    neg_d: (N, N) negative squared distances with -inf on the diagonal.
    """
    logits = neg_d * beta[:, None]
    logits = logits - jnp.max(logits, axis=1, keepdims=True)
    p = jnp.exp(logits)
    p_sum = jnp.sum(p, axis=1, keepdims=True)
    p = p / p_sum
    # H = -sum p log p, computed stably from logits
    logp = logits - jnp.log(p_sum)
    h = -jnp.sum(jnp.where(p > 0, p * logp, 0.0), axis=1)
    return p, h


def calibrate_p(x: jnp.ndarray, perplexity: float,
                weights: Optional[jnp.ndarray] = None,
                search_iters: int = 50) -> jnp.ndarray:
    """Joint symmetrized P with per-point sigma matched to the perplexity.

    Binary search on beta = 1/(2 sigma²) per row, vectorized; fixed
    iteration count keeps it jit-compatible.
    """
    n = x.shape[0]
    d = pairwise_sq_dists(x)
    neg_d = -d.at[jnp.arange(n), jnp.arange(n)].set(jnp.inf)
    target_h = jnp.log(perplexity)

    def body(_, state):
        beta, beta_lo, beta_hi = state
        _, h = _cond_probs_and_entropy(neg_d, beta)
        too_entropic = h > target_h        # entropy too high -> raise beta
        beta_lo = jnp.where(too_entropic, beta, beta_lo)
        beta_hi = jnp.where(too_entropic, beta_hi, beta)
        beta_next = jnp.where(
            jnp.isinf(beta_hi), beta * 2.0, 0.5 * (beta_lo + beta_hi))
        return beta_next, beta_lo, beta_hi

    beta0 = jnp.ones((n,))
    lo0 = jnp.zeros((n,))
    hi0 = jnp.full((n,), jnp.inf)
    beta, _, _ = jax.lax.fori_loop(0, search_iters, body, (beta0, lo0, hi0))
    p_cond, _ = _cond_probs_and_entropy(neg_d, beta)

    if weights is not None:
        w = weights / jnp.sum(weights)
        # weighted symmetrization: P_ij ∝ w_i P(j|i) + w_j P(i|j)
        p = w[:, None] * p_cond + (w[:, None] * p_cond).T
    else:
        p = (p_cond + p_cond.T) / (2.0 * n)
    p = p / jnp.sum(p)
    return jnp.maximum(p, 1e-12)


def kl_divergence(p: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    n = y.shape[0]
    num = 1.0 / (1.0 + pairwise_sq_dists(y))
    num = num.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    q = jnp.maximum(num / jnp.sum(num), 1e-12)
    return jnp.sum(p * (jnp.log(p) - jnp.log(q)))


def _grad_and_kl(p: jnp.ndarray, y: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact tSNE gradient (matmul form) + current KL."""
    n = y.shape[0]
    num = 1.0 / (1.0 + pairwise_sq_dists(y))                 # (N, N)
    num = num.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    z = jnp.sum(num)
    q = jnp.maximum(num / z, 1e-12)
    pq = (p - q) * num                                       # (N, N)
    # grad_i = 4 [ (sum_j pq_ij) y_i - sum_j pq_ij y_j ]
    grad = 4.0 * (jnp.sum(pq, axis=1, keepdims=True) * y - pq @ y)
    kl = jnp.sum(p * (jnp.log(p) - jnp.log(q)))
    return grad, kl


class TsneState(NamedTuple):
    y: jnp.ndarray
    velocity: jnp.ndarray
    gains: jnp.ndarray


@functools.partial(jax.jit, static_argnames=("cfg",))
def run_tsne(key: jax.Array, x: jnp.ndarray, cfg: TsneConfig,
             weights: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full tSNE: returns (embedding (N, dims), KL trace (n_iter,))."""
    n = x.shape[0]
    p = calibrate_p(x, cfg.perplexity, weights=weights,
                    search_iters=cfg.sigma_search_iters)
    y0 = 1e-4 * jax.random.normal(key, (n, cfg.dims))
    state = TsneState(y=y0, velocity=jnp.zeros_like(y0),
                      gains=jnp.ones_like(y0))

    def step(i, carry):
        state, kls = carry
        exag = jnp.where(i < cfg.exaggeration_iters,
                         cfg.early_exaggeration, 1.0)
        mom = jnp.where(i < cfg.momentum_switch,
                        cfg.momentum_start, cfg.momentum_final)
        grad, kl = _grad_and_kl(p * exag, state.y)
        same_sign = jnp.sign(grad) == jnp.sign(state.velocity)
        gains = jnp.where(same_sign, state.gains * 0.8, state.gains + 0.2)
        gains = jnp.maximum(gains, cfg.min_gain)
        vel = mom * state.velocity - cfg.learning_rate * gains * grad
        y = state.y + vel
        y = y - jnp.mean(y, axis=0, keepdims=True)
        return TsneState(y, vel, gains), kls.at[i].set(kl)

    state, kls = jax.lax.fori_loop(
        0, cfg.n_iter, step, (state, jnp.zeros((cfg.n_iter,))))
    return state.y, kls
