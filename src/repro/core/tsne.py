"""tSNE in pure JAX — the paper's downstream embedder, three backends.

Faithful to van der Maaten & Hinton 2008 + the reference implementation:

* per-point perplexity calibration by binary search over sigma (fixed 50
  iterations, vectorized over points, streamed in row blocks),
* symmetrized joint P, early exaggeration, momentum + per-parameter gains,
* exact gradient  4·Σ_j (p_ij − q_ij)(y_i − y_j)/(1 + |y_i − y_j|²).

Weighted extension (SnS): each input point carries a weight w_i (the HH
count).  P is built from the weighted conditional probabilities, so a
representative standing for 10⁶ raw points pulls proportionally harder —
this is the "replication" of paper §II-1 done in closed form (replicas
are still supported; weights are the numerically-clean equivalent).

Calibration never materializes an (N, N) matrix: it streams row blocks
(``lax.map`` over chunks) and returns per-point sufficient statistics
``PointStats`` — precision beta, a log-domain row shift, the shifted row
normalizer zp, and the normalized point mass w.  Every backend rebuilds
P_ij = ½(w_i·pc(j|i) + w_j·pc(i|j)) from these four numbers per point,
flash-attention style.

Gradient backends (``TsneConfig.backend`` / ``run_tsne(backend=...)``):

* ``"dense"``  — the classic matmul-shaped O(N²)-memory path.  Fastest at
  the paper's N ≤ 2·10⁴ where the (N, N) buffers fit.
* ``"tiled"``  — pure-XLA block streaming: both calibration and the
  per-iteration gradient touch only (block, N) buffers, so N = 10⁵+
  representatives fit on any host.  Works on CPU/GPU/TPU unchanged.
* ``"pallas"`` — the fused two-pass Pallas kernel
  (``repro.kernels.ops.tsne_step_fused``): Z reduction then force tiles,
  recomputing P and Q on the fly in VMEM.  Interpret mode is selected
  automatically off-TPU.

All three agree to fp tolerance (tests/test_embed_backends.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BACKENDS = ("dense", "tiled", "pallas")


@dataclasses.dataclass(frozen=True)
class TsneConfig:
    dims: int = 2
    perplexity: float = 30.0
    n_iter: int = 500
    early_exaggeration: float = 12.0
    exaggeration_iters: int = 125
    learning_rate: float = 200.0
    momentum_start: float = 0.5
    momentum_final: float = 0.8
    momentum_switch: int = 125
    min_gain: float = 0.01
    sigma_search_iters: int = 50
    backend: str = "dense"         # "dense" | "tiled" | "pallas"
    block: int = 512               # row-block for calibration / tiled / pallas


class PointStats(NamedTuple):
    """Per-point sufficient statistics for rebuilding P on the fly.

    pc(j|i) = exp(−beta_i·d²(x_i, x_j) − shift_i) / zp_i   (0 on the diag),
    P_ij    = ½ (w_i·pc(j|i) + w_j·pc(i|j)),   Σ_ij P_ij = 1.

    ``shift`` is the per-row max logit (flash-style log-domain shift) so zp
    never under/overflows regardless of the calibrated precision.
    """
    beta: jnp.ndarray    # (N,) precision 1/(2 sigma²)
    shift: jnp.ndarray   # (N,) row max of −beta_i·d², subtracted pre-exp
    zp: jnp.ndarray      # (N,) shifted row normalizer Σ_{j≠i} exp(logit−shift)
    w: jnp.ndarray       # (N,) normalized point mass, Σ w = 1


def pairwise_sq_dists(x: jnp.ndarray, y: Optional[jnp.ndarray] = None
                      ) -> jnp.ndarray:
    """Squared Euclidean distances via the Gram-matrix identity (MXU-shaped)."""
    y = x if y is None else y
    xx = jnp.sum(x * x, axis=1)
    yy = jnp.sum(y * y, axis=1)
    d = xx[:, None] - 2.0 * (x @ y.T) + yy[None, :]
    return jnp.maximum(d, 0.0)


def _pad_rows(x: jnp.ndarray, block: int, value=0) -> jnp.ndarray:
    pad = (-x.shape[0]) % block
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=value)


def _rows_probs_entropy(neg_d: jnp.ndarray, beta: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-wise conditional P and Shannon entropy for precision beta.

    neg_d: (B, N) negative squared distances, −inf at invalid pairs.
    """
    logits = neg_d * beta[:, None]
    logits = logits - jnp.max(logits, axis=1, keepdims=True)
    p = jnp.exp(logits)
    p_sum = jnp.sum(p, axis=1, keepdims=True)
    p = p / p_sum
    logp = logits - jnp.log(p_sum)
    h = -jnp.sum(jnp.where(p > 0, p * logp, 0.0), axis=1)
    return p, h


def calibrate_stats(x: jnp.ndarray, perplexity: float,
                    weights: Optional[jnp.ndarray] = None,
                    search_iters: int = 50, block: int = 512) -> PointStats:
    """Perplexity calibration in row blocks — peak memory O(block · N).

    Binary search on beta = 1/(2 sigma²) per row (fixed iteration count,
    jit-compatible), streamed over row chunks with ``lax.map`` so no
    (N, N) buffer ever exists.
    """
    n = x.shape[0]
    block = min(block, n) if n > 0 else block
    xp = _pad_rows(x, block)
    nb = xp.shape[0] // block
    row_ids = jnp.arange(xp.shape[0])
    col_ids = jnp.arange(n)
    target_h = jnp.log(perplexity)

    def chunk_stats(args):
        xc, idc = args                              # (B, D), (B,)
        d2 = pairwise_sq_dists(xc, x)               # (B, N) — the only big temp
        valid = idc[:, None] != col_ids[None, :]
        neg_d = jnp.where(valid, -d2, -jnp.inf)

        def body(_, state):
            beta, lo, hi = state
            _, h = _rows_probs_entropy(neg_d, beta)
            too_entropic = h > target_h             # entropy high -> raise beta
            lo = jnp.where(too_entropic, beta, lo)
            hi = jnp.where(too_entropic, hi, beta)
            nxt = jnp.where(jnp.isinf(hi), beta * 2.0, 0.5 * (lo + hi))
            return nxt, lo, hi

        init = (jnp.ones((block,)), jnp.zeros((block,)),
                jnp.full((block,), jnp.inf))
        beta, _, _ = jax.lax.fori_loop(0, search_iters, body, init)
        logits = jnp.where(valid, -d2 * beta[:, None], -jnp.inf)
        shift = jnp.max(logits, axis=1)
        zp = jnp.sum(jnp.exp(logits - shift[:, None]), axis=1)
        return beta, shift, zp

    beta, shift, zp = jax.lax.map(
        chunk_stats, (xp.reshape(nb, block, -1), row_ids.reshape(nb, block)))
    beta = beta.reshape(-1)[:n]
    shift = shift.reshape(-1)[:n]
    zp = zp.reshape(-1)[:n]
    if weights is not None:
        w = weights / jnp.sum(weights)
    else:
        w = jnp.full((n,), 1.0 / n)
    return PointStats(beta=beta, shift=shift, zp=zp, w=w)


def p_from_stats(x: jnp.ndarray, stats: PointStats) -> jnp.ndarray:
    """Dense joint P from per-point stats (the O(N²) reconstruction)."""
    n = x.shape[0]
    d2 = pairwise_sq_dists(x)
    pc = jnp.exp(-stats.beta[:, None] * d2 - stats.shift[:, None]) \
        / stats.zp[:, None]
    pc = pc.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    wpc = stats.w[:, None] * pc
    p = 0.5 * (wpc + wpc.T)
    p = p / jnp.sum(p)
    return jnp.maximum(p, 1e-12)


def calibrate_p(x: jnp.ndarray, perplexity: float,
                weights: Optional[jnp.ndarray] = None,
                search_iters: int = 50, block: int = 512) -> jnp.ndarray:
    """Joint symmetrized P with per-point sigma matched to the perplexity.

    Convenience wrapper (dense result) over the blocked ``calibrate_stats``.
    """
    stats = calibrate_stats(x, perplexity, weights=weights,
                            search_iters=search_iters, block=block)
    return p_from_stats(x, stats)


def kl_divergence(p: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    n = y.shape[0]
    num = 1.0 / (1.0 + pairwise_sq_dists(y))
    num = num.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    q = jnp.maximum(num / jnp.sum(num), 1e-12)
    return jnp.sum(p * (jnp.log(p) - jnp.log(q)))


def _grad_and_kl(p: jnp.ndarray, y: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact tSNE gradient (matmul form) + current KL — dense backend."""
    n = y.shape[0]
    num = 1.0 / (1.0 + pairwise_sq_dists(y))                 # (N, N)
    num = num.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    z = jnp.sum(num)
    q = jnp.maximum(num / z, 1e-12)
    pq = (p - q) * num                                       # (N, N)
    # grad_i = 4 [ (sum_j pq_ij) y_i - sum_j pq_ij y_j ]
    grad = 4.0 * (jnp.sum(pq, axis=1, keepdims=True) * y - pq @ y)
    kl = jnp.sum(p * (jnp.log(p) - jnp.log(q)))
    return grad, kl


def _tiled_grad_kl(x: jnp.ndarray, y: jnp.ndarray, stats: PointStats,
                   exaggeration: jnp.ndarray, n_valid: int, block: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-streamed gradient + KL: peak memory O(block · N).

    All inputs padded to a multiple of ``block`` (padded rows carry w = 0
    and are masked out of every pair).  Two passes, like the Pallas
    kernel: Z is a global reduction that must precede the force weighting.
    """
    npad, dims = y.shape
    nb = npad // block
    ids = jnp.arange(npad)
    col_live = ids[None, :] < n_valid

    def pair_mask(idc):
        return (idc[:, None] != ids[None, :]) & \
            (idc[:, None] < n_valid) & col_live

    def z_chunk(args):
        yc, idc = args
        num = 1.0 / (1.0 + pairwise_sq_dists(yc, y))
        return jnp.sum(jnp.where(pair_mask(idc), num, 0.0))

    chunks_y = y.reshape(nb, block, dims)
    chunks_id = ids.reshape(nb, block)
    z = jnp.sum(jax.lax.map(z_chunk, (chunks_y, chunks_id)))

    beta, shift, zp, w = stats

    def force_chunk(args):
        xc, yc, bc, mc, zc, wc, idc = args
        mask = pair_mask(idc)
        d2x = pairwise_sq_dists(xc, x)
        pc_ij = jnp.exp(-bc[:, None] * d2x - mc[:, None]) / zc[:, None]
        pc_ji = jnp.exp(-beta[None, :] * d2x - shift[None, :]) / zp[None, :]
        p = jnp.where(mask, 0.5 * (wc[:, None] * pc_ij + w[None, :] * pc_ji),
                      0.0)
        num = 1.0 / (1.0 + pairwise_sq_dists(yc, y))
        num = jnp.where(mask, num, 0.0)
        q = num / z
        pe = exaggeration * p
        pq = (pe - q) * num
        f = 4.0 * (jnp.sum(pq, axis=1, keepdims=True) * yc - pq @ y)
        # KL partials: Σ pe log pe and Σ pe log num (q = num/Z folds in later)
        a = jnp.sum(jnp.where(pe > 0, pe * jnp.log(jnp.maximum(pe, 1e-37)),
                              0.0))
        b = jnp.sum(jnp.where(pe > 0, pe * jnp.log(jnp.maximum(num, 1e-37)),
                              0.0))
        return f, a, b

    xs = (x.reshape(nb, block, -1), chunks_y,
          beta.reshape(nb, block), shift.reshape(nb, block),
          zp.reshape(nb, block), w.reshape(nb, block), chunks_id)
    f, a, b = jax.lax.map(force_chunk, xs)
    kl = jnp.sum(a) - jnp.sum(b) + exaggeration * jnp.log(z)
    return f.reshape(npad, dims), kl


def embedding_grad(x: jnp.ndarray, y: jnp.ndarray, stats: PointStats,
                   exaggeration=1.0, *, backend: str = "tiled",
                   block: int = 512, interpret: Optional[bool] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One tSNE gradient evaluation on any backend — test/bench surface.

    Returns (grad (N, dims), KL of the exaggerated P against current Q).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of {BACKENDS}")
    exaggeration = jnp.asarray(exaggeration, jnp.float32)
    if backend == "dense":
        return _grad_and_kl(p_from_stats(x, stats) * exaggeration, y)
    if backend == "pallas":
        from repro.kernels import ops
        return ops.tsne_step_fused(
            x, y, stats.beta, stats.zp, shift=stats.shift, weights=stats.w,
            exaggeration=exaggeration, block=min(block, x.shape[0]),
            interpret=interpret, return_kl=True)
    n = x.shape[0]
    block = min(block, n)
    pad = functools.partial(_pad_rows, block=block)
    spad = PointStats(beta=pad(stats.beta), shift=pad(stats.shift),
                      zp=pad(stats.zp, value=1), w=pad(stats.w))
    f, kl = _tiled_grad_kl(pad(x), pad(y), spad, exaggeration,
                           n_valid=n, block=block)
    return f[:n], kl


class TsneState(NamedTuple):
    y: jnp.ndarray
    velocity: jnp.ndarray
    gains: jnp.ndarray


@functools.partial(jax.jit, static_argnames=("cfg", "backend", "interpret"))
def _run_tsne(key: jax.Array, x: jnp.ndarray, weights, *, cfg: TsneConfig,
              backend: str, interpret: bool
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n = x.shape[0]
    stats = calibrate_stats(x, cfg.perplexity, weights=weights,
                            search_iters=cfg.sigma_search_iters,
                            block=cfg.block)
    if backend == "dense":
        p = p_from_stats(x, stats)

        def grad_fn(y, exag):
            return _grad_and_kl(p * exag, y)
    else:
        def grad_fn(y, exag):
            return embedding_grad(x, y, stats, exag, backend=backend,
                                  block=cfg.block, interpret=interpret)

    y0 = 1e-4 * jax.random.normal(key, (n, cfg.dims))
    state = TsneState(y=y0, velocity=jnp.zeros_like(y0),
                      gains=jnp.ones_like(y0))

    def step(i, carry):
        state, kls = carry
        exag = jnp.where(i < cfg.exaggeration_iters,
                         cfg.early_exaggeration, 1.0)
        mom = jnp.where(i < cfg.momentum_switch,
                        cfg.momentum_start, cfg.momentum_final)
        grad, kl = grad_fn(state.y, exag)
        same_sign = jnp.sign(grad) == jnp.sign(state.velocity)
        gains = jnp.where(same_sign, state.gains * 0.8, state.gains + 0.2)
        gains = jnp.maximum(gains, cfg.min_gain)
        vel = mom * state.velocity - cfg.learning_rate * gains * grad
        y = state.y + vel
        y = y - jnp.mean(y, axis=0, keepdims=True)
        return TsneState(y, vel, gains), kls.at[i].set(kl)

    state, kls = jax.lax.fori_loop(
        0, cfg.n_iter, step, (state, jnp.zeros((cfg.n_iter,))))
    return state.y, kls


def run_tsne(key: jax.Array, x: jnp.ndarray, cfg: TsneConfig,
             weights: Optional[jnp.ndarray] = None,
             backend: Optional[str] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full tSNE: returns (embedding (N, dims), KL trace (n_iter,)).

    ``backend`` overrides ``cfg.backend``; Pallas interpret mode is
    auto-selected off-TPU.
    """
    backend = backend or cfg.backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of {BACKENDS}")
    interpret = jax.default_backend() != "tpu"
    return _run_tsne(key, x, weights, cfg=cfg, backend=backend,
                     interpret=interpret)
