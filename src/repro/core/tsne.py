"""tSNE in pure JAX — the paper's downstream embedder, three backends.

Faithful to van der Maaten & Hinton 2008 + the reference implementation:

* per-point perplexity calibration by binary search over sigma (fixed 50
  iterations, vectorized over points, streamed in row blocks),
* symmetrized joint P, early exaggeration, momentum + per-parameter gains,
* exact gradient  4·Σ_j (p_ij − q_ij)(y_i − y_j)/(1 + |y_i − y_j|²).

Weighted extension (SnS): each input point carries a weight w_i (the HH
count).  P is built from the weighted conditional probabilities, so a
representative standing for 10⁶ raw points pulls proportionally harder —
this is the "replication" of paper §II-1 done in closed form (replicas
are still supported; weights are the numerically-clean equivalent).

Calibration never materializes an (N, N) matrix: it streams row blocks
(``lax.map`` over chunks) and returns per-point sufficient statistics
``PointStats`` — precision beta, a log-domain row shift, the shifted row
normalizer zp, and the normalized point mass w.  Every backend rebuilds
P_ij = ½(w_i·pc(j|i) + w_j·pc(i|j)) from these four numbers per point,
flash-attention style.

Gradient backends (``TsneConfig.backend`` / ``run_tsne(backend=...)``):

========== ============== ================= =====================================
backend    per-iter time  per-iter memory   when to use
========== ============== ================= =====================================
``dense``  O(N²)          O(N²)             N ≤ 2·10⁴ (paper regime); exact
``tiled``  O(N²)          O(block·N)        N ≤ ~10⁵: exact, bounded memory
``pallas`` O(N²)          O(block²) VMEM    TPU: exact, fused two-pass kernel
``sparse`` O(N·k+G²logG)  O(N·k + G²)       N = 10⁵–10⁶: kNN attraction + FFT
                                            grid repulsion (BH/FIt-SNE style)
========== ============== ================= =====================================

``dense``/``tiled``/``pallas`` compute the exact gradient and agree to fp
tolerance (tests/test_embed_backends.py).  ``sparse`` is the sub-quadratic
approximation: attraction restricted to the symmetrized kNN graph
(perplexity calibrated against kNN distances only — van der Maaten 2014),
repulsion via cloud-in-cell splatting onto a G×G grid in embedding space,
one FFT convolution with the (1+r²)⁻¹/(1+r²)⁻² kernels, and a bilinear
gather back — the Z normalizer falls out of the same grid pass
(FIt-SNE, Linderman et al. 2019).  On a complete kNN graph (k = N−1) its
attraction term equals the dense one exactly; repulsion converges to the
exact field as G grows (tests/test_sparse_tsne.py).

Two further sparse-backend knobs (this PR's follow-ups to the above):

* adaptive grid — ``grid_interval > 0`` fixes the target CELL SPACING
  instead of the grid size: the optimizer runs in jitted stages and G
  doubles (grid_size → grid_max) whenever the embedding span outgrows
  the spacing, FIt-SNE-style, retracing only at doubling boundaries;
* ``cic="pallas"`` — the cloud-in-cell splat/gather runs as the one-hot
  matmul Pallas tile in ``repro.kernels.cic`` (MXU on TPU,
  interpret-mode on CPU) instead of the XLA scatter/gather loop.

The per-edge attraction reduction goes through the shared sorted-COO
core (:mod:`repro.core.coo`) — the same scatter-free machinery the UMAP
epoch loop uses.

Mesh-parallel sparse backend (``run_tsne(mesh=...)`` — ``None`` | device
count | 1-D ``Mesh``, plumbing in :mod:`repro.core.mesh`): the whole
optimizer loop runs inside ``shard_map``, each device owning a
contiguous row block of the state and the matching contiguous slice of
the src-sorted COO edges (``coo.ShardedEdgeLayout``, built host-side at
setup).  Attraction stays a local ``segment_reduce``; repulsion splats
per-device masses and ``psum``s the tiny (3, G, G) grid; per iteration
the only collectives are one ``all_gather`` of the block positions plus
fixed-size psums (grid, Z, KL, centering) — no cross-device scatter
(jaxpr-pinned in tests/test_mesh_embed.py).  Per-iteration quantities
match the single-device path to fp tolerance; long trajectories
decohere, as any summation-order change must under a chaotic optimizer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coo
from repro.core import mesh as mesh_mod
from repro.kernels import registry as registry_mod

BACKENDS = ("dense", "tiled", "pallas", "sparse")
CIC_PATHS = ("xla", "pallas")


@dataclasses.dataclass(frozen=True)
class TsneConfig:
    dims: int = 2
    perplexity: float = 30.0
    n_iter: int = 500
    early_exaggeration: float = 12.0
    exaggeration_iters: int = 125
    learning_rate: float = 200.0
    momentum_start: float = 0.5
    momentum_final: float = 0.8
    momentum_switch: int = 125
    min_gain: float = 0.01
    sigma_search_iters: int = 50
    backend: str = "dense"         # "dense" | "tiled" | "pallas" | "sparse"
    block: int = 512               # row-block for calibration / tiled / pallas
    knn: int = 0                   # sparse: neighbors per point (0 → 3·perp)
    grid_size: int = 128           # sparse: FFT repulsion grid, G per axis
    # adaptive grid (FIt-SNE-style): > 0 turns grid_size into the STARTING
    # G and fixes the target cell spacing in embedding units — the grid
    # doubles (up to grid_max) whenever the embedding span outgrows it,
    # re-jitting only at the doubling boundaries (staged optimizer)
    grid_interval: float = 0.0     # 0 = fixed-G; > 0 = target cell spacing
    grid_max: int = 1024           # adaptive: G cap (bounds the FFT cost)
    adaptive_interval: int = 50    # adaptive: iterations between G checks
    cic: str = "xla"               # grid splat/gather: "xla" | "pallas"
    # kNN build for the sparse backend: "exact" | "auto" | "ann" — "auto"
    # switches to the approximate engine (core.ann) above
    # AnnConfig.auto_threshold points; ``ann`` carries its knobs (an
    # ann.AnnConfig — hashable, so the config stays jit-static)
    knn_method: str = "auto"
    ann: Optional[object] = None
    # kernel dispatch mode for every Pallas call site (CIC splat/gather,
    # fused force tile, segment reduce), via kernels.registry: "auto"
    # resolves compiled → interpret → xla per backend; the other values
    # force one mode end-to-end (SnsConfig.kernel_mode threads to here)
    kernel_mode: str = "auto"


class PointStats(NamedTuple):
    """Per-point sufficient statistics for rebuilding P on the fly.

    pc(j|i) = exp(−beta_i·d²(x_i, x_j) − shift_i) / zp_i   (0 on the diag),
    P_ij    = ½ (w_i·pc(j|i) + w_j·pc(i|j)),   Σ_ij P_ij = 1.

    ``shift`` is the per-row max logit (flash-style log-domain shift) so zp
    never under/overflows regardless of the calibrated precision.
    """
    beta: jnp.ndarray    # (N,) precision 1/(2 sigma²)
    shift: jnp.ndarray   # (N,) row max of −beta_i·d², subtracted pre-exp
    zp: jnp.ndarray      # (N,) shifted row normalizer Σ_{j≠i} exp(logit−shift)
    w: jnp.ndarray       # (N,) normalized point mass, Σ w = 1


def validate_init(init, n: int, dims: int) -> Optional[jnp.ndarray]:
    """Shape/dtype-check a warm-start embedding init (shared by both
    embedders).  Accepts None (cold start) or an (N, dims) float array;
    returns it as float32 or raises with the offending shape/dtype."""
    if init is None:
        return None
    init = jnp.asarray(init)
    if init.shape != (n, dims):
        raise ValueError(
            f"init must have shape ({n}, {dims}) to seed the embedding; "
            f"got {init.shape}")
    if not jnp.issubdtype(init.dtype, jnp.floating):
        raise ValueError(f"init must be a float array; got {init.dtype}")
    return init.astype(jnp.float32)


def pairwise_sq_dists(x: jnp.ndarray, y: Optional[jnp.ndarray] = None
                      ) -> jnp.ndarray:
    """Squared Euclidean distances via the Gram-matrix identity (MXU-shaped)."""
    y = x if y is None else y
    xx = jnp.sum(x * x, axis=1)
    yy = jnp.sum(y * y, axis=1)
    d = xx[:, None] - 2.0 * (x @ y.T) + yy[None, :]
    return jnp.maximum(d, 0.0)


def _pad_rows(x: jnp.ndarray, block: int, value=0) -> jnp.ndarray:
    pad = (-x.shape[0]) % block
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=value)


def _rows_probs_entropy(neg_d: jnp.ndarray, beta: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-wise conditional P and Shannon entropy for precision beta.

    neg_d: (B, N) negative squared distances, −inf at invalid pairs.
    """
    logits = neg_d * beta[:, None]
    logits = logits - jnp.max(logits, axis=1, keepdims=True)
    p = jnp.exp(logits)
    p_sum = jnp.sum(p, axis=1, keepdims=True)
    p = p / p_sum
    logp = logits - jnp.log(p_sum)
    h = -jnp.sum(jnp.where(p > 0, p * logp, 0.0), axis=1)
    return p, h


def _beta_search(neg_d: jnp.ndarray, target_h: jnp.ndarray,
                 search_iters: int) -> jnp.ndarray:
    """Per-row binary search for beta = 1/(2σ²) matching the target entropy.

    neg_d: (B, M) negative squared distances, −inf at invalid pairs.
    Fixed iteration count → jit-compatible; identical bisection on the
    full row (dense calibration) and the kNN row (sparse calibration).
    """
    nrows = neg_d.shape[0]

    def body(_, state):
        beta, lo, hi = state
        _, h = _rows_probs_entropy(neg_d, beta)
        too_entropic = h > target_h             # entropy high -> raise beta
        lo = jnp.where(too_entropic, beta, lo)
        hi = jnp.where(too_entropic, hi, beta)
        nxt = jnp.where(jnp.isinf(hi), beta * 2.0, 0.5 * (lo + hi))
        return nxt, lo, hi

    init = (jnp.ones((nrows,)), jnp.zeros((nrows,)),
            jnp.full((nrows,), jnp.inf))
    beta, _, _ = jax.lax.fori_loop(0, search_iters, body, init)
    return beta


def calibrate_stats(x: jnp.ndarray, perplexity: float,
                    weights: Optional[jnp.ndarray] = None,
                    search_iters: int = 50, block: int = 512) -> PointStats:
    """Perplexity calibration in row blocks — peak memory O(block · N).

    Binary search on beta = 1/(2 sigma²) per row (fixed iteration count,
    jit-compatible), streamed over row chunks with ``lax.map`` so no
    (N, N) buffer ever exists.
    """
    n = x.shape[0]
    block = min(block, n) if n > 0 else block
    xp = _pad_rows(x, block)
    nb = xp.shape[0] // block
    row_ids = jnp.arange(xp.shape[0])
    col_ids = jnp.arange(n)
    target_h = jnp.log(perplexity)

    def chunk_stats(args):
        xc, idc = args                              # (B, D), (B,)
        d2 = pairwise_sq_dists(xc, x)               # (B, N) — the only big temp
        valid = idc[:, None] != col_ids[None, :]
        neg_d = jnp.where(valid, -d2, -jnp.inf)
        beta = _beta_search(neg_d, target_h, search_iters)
        logits = jnp.where(valid, -d2 * beta[:, None], -jnp.inf)
        shift = jnp.max(logits, axis=1)
        zp = jnp.sum(jnp.exp(logits - shift[:, None]), axis=1)
        return beta, shift, zp

    beta, shift, zp = jax.lax.map(
        chunk_stats, (xp.reshape(nb, block, -1), row_ids.reshape(nb, block)))
    beta = beta.reshape(-1)[:n]
    shift = shift.reshape(-1)[:n]
    zp = zp.reshape(-1)[:n]
    if weights is not None:
        w = weights / jnp.sum(weights)
    else:
        w = jnp.full((n,), 1.0 / n)
    return PointStats(beta=beta, shift=shift, zp=zp, w=w)


def p_from_stats(x: jnp.ndarray, stats: PointStats) -> jnp.ndarray:
    """Dense joint P from per-point stats (the O(N²) reconstruction)."""
    n = x.shape[0]
    d2 = pairwise_sq_dists(x)
    pc = jnp.exp(-stats.beta[:, None] * d2 - stats.shift[:, None]) \
        / stats.zp[:, None]
    pc = pc.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    wpc = stats.w[:, None] * pc
    p = 0.5 * (wpc + wpc.T)
    p = p / jnp.sum(p)
    return jnp.maximum(p, 1e-12)


def calibrate_p(x: jnp.ndarray, perplexity: float,
                weights: Optional[jnp.ndarray] = None,
                search_iters: int = 50, block: int = 512) -> jnp.ndarray:
    """Joint symmetrized P with per-point sigma matched to the perplexity.

    Convenience wrapper (dense result) over the blocked ``calibrate_stats``.
    """
    stats = calibrate_stats(x, perplexity, weights=weights,
                            search_iters=search_iters, block=block)
    return p_from_stats(x, stats)


# --------------------------------------------------------------------------
# Sparse backend: kNN-restricted attraction + FFT grid repulsion.
#
# Per-iteration cost O(N·k + G²·log G) instead of O(N²):
#   grad_i = 4 [ Σ_j P_ij·num_ij·(y_i−y_j)  −  (1/Z)·Σ_j num²_ij·(y_i−y_j) ]
# with num_ij = (1+|y_i−y_j|²)⁻¹.  The first sum runs over the symmetrized
# kNN support only (gather + sorted-row segment reduction over fixed-shape
# COO edges); the
# second is an all-pairs sum of a smooth radial kernel, evaluated by
# splatting unit masses (and y-weighted masses) onto a G×G grid,
# convolving with (1+r²)⁻² via FFT, and gathering back bilinearly.  The
# normalizer Z = Σ_{i≠j} num_ij comes from the same grid pass with the
# (1+r²)⁻¹ kernel.
# --------------------------------------------------------------------------

class SparseP(NamedTuple):
    """Symmetrized joint P on the kNN support, fixed-shape COO.

    ``val`` sums to exactly 1 by construction: each directed kNN edge
    (i→j) with conditional mass c_ij = w_i·pc(j|i) contributes c_ij/2 to
    the ordered pairs (i, j) AND (j, i), so after folding duplicates the
    entry for (i, j) holds P_ij = ½(c_ij + c_ji) — the same symmetrization
    as the dense path, restricted to the kNN union support.  Entries are
    lexsorted by (src, dst); duplicate slots carry val 0.

    ``bounds[i]:bounds[i+1]`` is row i's slice of the edge list.  The
    sorted layout is what makes the per-iteration reduction scatter-free:
    XLA's CPU scatter visits updates one by one (a segment_sum over the
    edges costs seconds at N·k ~ 10⁷), whereas cumsum + boundary-gather
    is a vectorized O(E) pass (~100 ms) — ``sparse_grad`` reduces through
    the shared :func:`repro.core.coo.segment_reduce` (the same core the
    scatter-free UMAP epoch loop uses).
    """
    src: jnp.ndarray     # (E,) int32, E = 2·N·k, sorted
    dst: jnp.ndarray     # (E,) int32
    val: jnp.ndarray     # (E,) float32, Σ val = 1
    bounds: jnp.ndarray  # (N+1,) int32: row i owns edges [bounds[i], bounds[i+1])


def calibrate_stats_knn(knn_dist: jnp.ndarray, perplexity: float,
                        weights: Optional[jnp.ndarray] = None,
                        search_iters: int = 50) -> PointStats:
    """Perplexity calibration against the kNN distances only — O(N·k).

    Same bisection as :func:`calibrate_stats`, but each row's entropy is
    computed over its k nearest neighbours instead of all N−1 points
    (the Barnes-Hut/FIt-SNE input approximation: the tail mass beyond the
    kNN radius is negligible at the calibrated sigma when k ≈ 3·perp).
    ``shift``/``zp`` normalize pc(j|i) over the kNN row.
    """
    n = knn_dist.shape[0]
    neg_d = -(knn_dist.astype(jnp.float32) ** 2)            # (N, k)
    beta = _beta_search(neg_d, jnp.log(perplexity), search_iters)
    logits = neg_d * beta[:, None]
    shift = jnp.max(logits, axis=1)
    zp = jnp.sum(jnp.exp(logits - shift[:, None]), axis=1)
    if weights is not None:
        w = weights / jnp.sum(weights)
    else:
        w = jnp.full((n,), 1.0 / n)
    return PointStats(beta=beta, shift=shift, zp=zp, w=w)


def sparse_p_from_knn(knn_idx: jnp.ndarray, knn_dist: jnp.ndarray,
                      perplexity: float,
                      weights: Optional[jnp.ndarray] = None,
                      search_iters: int = 50) -> SparseP:
    """Build the symmetrized weighted COO P from a kNN graph.

    Σ val = 1 exactly (pc rows are normalized and Σ w_i = 1), so no
    global renormalization pass is needed.
    """
    n, k = knn_idx.shape
    stats = calibrate_stats_knn(knn_dist, perplexity, weights=weights,
                                search_iters=search_iters)
    neg_d = -(knn_dist.astype(jnp.float32) ** 2)
    pc = jnp.exp(neg_d * stats.beta[:, None] - stats.shift[:, None]) \
        / stats.zp[:, None]                                  # (N, k)
    c = (stats.w[:, None] * pc).reshape(-1)                  # Σ c = 1
    rows = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    cols = knn_idx.reshape(-1).astype(jnp.int32)
    src = jnp.concatenate([rows, cols])
    dst = jnp.concatenate([cols, rows])
    val = jnp.concatenate([0.5 * c, 0.5 * c])
    src, dst, val = coo.dedupe_edges(src, dst, val)
    return SparseP(src=src, dst=dst, val=val,
                   bounds=coo.row_bounds(src, n))


def build_sparse_p(x: jnp.ndarray, perplexity: float,
                   k: Optional[int] = None,
                   weights: Optional[jnp.ndarray] = None,
                   search_iters: int = 50, block: int = 512,
                   mesh=None, method: str = "exact", ann=None) -> SparseP:
    """kNN graph + kNN calibration + symmetrized COO P — the sparse
    backend's one-time setup.  ``method``/``ann`` pick the kNN build
    (exact O(N²·D) blocked, or the sub-quadratic approximate engine —
    see ``neighbors.knn_graph``); with ``mesh`` either build row-block
    shards under ``shard_map``."""
    from repro.core import neighbors
    n = x.shape[0]
    if k is None:
        k = max(8, int(round(3.0 * perplexity)))
    k = min(k, n - 1)          # a kNN row can never exceed the other points
    idx, dist = neighbors.knn_graph(x, k, block=block, mesh=mesh,
                                    method=method, ann=ann)
    return sparse_p_from_knn(idx, dist, perplexity, weights=weights,
                             search_iters=search_iters)


def _cic_weights(y: jnp.ndarray, grid_size: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Cloud-in-cell cell indices + fractional offsets for a 2D embedding.

    The grid covers the bounding box with one spare cell of margin on
    every side and a single isotropic spacing h (the convolution kernel is
    radial, so cells must be square).  Returns (i0 (N,2) int32,
    f (N,2) fractional offsets, h scalar).
    """
    g = grid_size
    lo = jnp.min(y, axis=0)
    span = jnp.maximum(jnp.max(jnp.max(y, axis=0) - lo), 1e-9)
    h = span / (g - 3)
    u = (y - lo[None, :]) / h + 1.0                          # ∈ [1, g−2]
    i0 = jnp.clip(jnp.floor(u).astype(jnp.int32), 0, g - 2)
    f = u - i0
    return i0, f, h


def _corner_weights(f: jnp.ndarray) -> jnp.ndarray:
    """Bilinear corner weights (4, N) from fractional offsets (N, 2)."""
    fx, fy = f[:, 0], f[:, 1]
    return jnp.stack([(1 - fx) * (1 - fy), (1 - fx) * fy,
                      fx * (1 - fy), fx * fy])               # (4, N)


_CORNERS = ((0, 0), (0, 1), (1, 0), (1, 1))


def _splat_xla(i0: jnp.ndarray, f: jnp.ndarray, vals: jnp.ndarray,
               grid_size: int) -> jnp.ndarray:
    """XLA reference cloud-in-cell splat: (C, N) channel masses onto a
    (C, G, G) grid via four corner scatter-adds."""
    w = _corner_weights(f)
    grid = jnp.zeros((vals.shape[0], grid_size, grid_size), jnp.float32)
    for ci, (dx, dy) in enumerate(_CORNERS):
        grid = grid.at[:, i0[:, 0] + dx, i0[:, 1] + dy].add(
            vals * w[ci][None, :])
    return grid


def _gather_xla(field: jnp.ndarray, i0: jnp.ndarray, f: jnp.ndarray
                ) -> jnp.ndarray:
    """XLA reference cloud-in-cell gather: bilinear read of ``field``
    ((..., G, G)) at every point — returns (..., N)."""
    w = _corner_weights(f)
    acc = 0.0
    for ci, (dx, dy) in enumerate(_CORNERS):
        acc += field[..., i0[:, 0] + dx, i0[:, 1] + dy] * w[ci]
    return acc


def _grid_convolve(grid: jnp.ndarray, g: int, h: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Convolve the splatted (3, G, G) masses with the two radial tSNE
    kernels on a circulant-embedded 2G×2G domain (linear convolution).
    Returns (conv1 (3, G, G) — φ₁ * (m, m·y), conv0 (G, G) — φ₀ * m)."""
    idx = jnp.arange(2 * g)
    off = jnp.where(idx <= g, idx, idx - 2 * g).astype(jnp.float32) * h
    r2 = off[:, None] ** 2 + off[None, :] ** 2
    k0 = 1.0 / (1.0 + r2)                                    # (1+r²)⁻¹ → Z
    k1 = k0 * k0                                             # (1+r²)⁻² → force

    pad = jnp.zeros((3, 2 * g, 2 * g), jnp.float32).at[:, :g, :g].set(grid)
    mf = jnp.fft.rfft2(pad)
    conv1 = jnp.fft.irfft2(mf * jnp.fft.rfft2(k1)[None],
                           s=(2 * g, 2 * g))[:, :g, :g]      # φ₁ * (m, my)
    conv0 = jnp.fft.irfft2(mf[0] * jnp.fft.rfft2(k0),
                           s=(2 * g, 2 * g))[:g, :g]         # φ₀ * m
    return conv1, conv0


def _cfg_kernel_mode(cfg: "TsneConfig") -> Optional[str]:
    """TsneConfig.kernel_mode -> the ``mode`` argument threaded to the
    kernel call sites (None = defer to legacy interpret flag / registry)."""
    return None if cfg.kernel_mode == "auto" else cfg.kernel_mode


def fft_repulsion(y: jnp.ndarray, grid_size: int = 128, *,
                  cic: str = "xla", interpret: Optional[bool] = None,
                  mode: Optional[str] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-pairs repulsive field + Z by one particle-mesh FFT pass.

    Returns (rep (N, 2), z) with
        rep_i = Σ_j (1+|y_i−y_j|²)⁻² (y_i − y_j),
        z     = Σ_{i≠j} (1+|y_i−y_j|²)⁻¹.
    Splat the masses (1, y_x, y_y) onto a G×G grid (cloud-in-cell),
    convolve with the radial kernels on a zero-padded 2G×2G domain
    (circulant embedding → linear convolution), gather bilinearly.  The
    j = i term cancels in rep (zero displacement) and is subtracted from
    z in closed form (φ₀(0)·N).

    ``cic`` selects the splat/gather implementation: ``"xla"`` (scatter
    splat + gather loop) or ``"pallas"`` (the one-hot matmul tile in
    ``repro.kernels.cic``, dispatched through ``kernels.registry`` —
    ``mode`` forces a registry mode, legacy ``interpret`` maps to
    interpret/compiled, both-None auto-resolves per backend).  The FFT
    convolution is XLA-native either way.
    """
    if cic not in CIC_PATHS:
        raise ValueError(f"unknown cic {cic!r}; want one of {CIC_PATHS}")
    n = y.shape[0]
    g = grid_size
    y = y.astype(jnp.float32)
    i0, f, h = _cic_weights(y, g)

    if cic == "pallas":
        from repro.kernels import ops
        masses = jnp.stack([jnp.ones((n,), jnp.float32),
                            y[:, 0], y[:, 1]], axis=1)       # (N, 3)
        grid = ops.cic_splat(i0, f, masses, g, interpret=interpret,
                             mode=mode)
    else:
        vals = jnp.stack([jnp.ones((n,), jnp.float32), y[:, 0], y[:, 1]])
        grid = _splat_xla(i0, f, vals, g)

    conv1, conv0 = _grid_convolve(grid, g, h)

    if cic == "pallas":
        from repro.kernels import ops
        fields = jnp.concatenate([conv1, conv0[None]], axis=0)
        got = ops.cic_gather(fields, i0, f, interpret=interpret,
                             mode=mode)                      # (N, 4)
        s1, sy, phi0 = got[:, 0], got[:, 1:3], got[:, 3]
        z = jnp.maximum(jnp.sum(phi0) - n, 1e-12)
        return s1[:, None] * y - sy, z

    s1 = _gather_xla(conv1[0], i0, f)                        # Σ_j φ₁
    sy = _gather_xla(conv1[1:], i0, f)                       # (2, N) Σ_j φ₁·y_j
    z = jnp.maximum(jnp.sum(_gather_xla(conv0, i0, f)) - n,
                    1e-12)                                   # drop self terms
    rep = s1[:, None] * y - sy.T
    return rep, z


def sparse_grad(y: jnp.ndarray, sp: SparseP, exaggeration=1.0,
                grid_size: int = 128, *, cic: str = "xla",
                interpret: Optional[bool] = None,
                mode: Optional[str] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One sparse-backend gradient evaluation: O(N·k + G²·log G).

    Returns (grad (N, 2), KL of the exaggerated sparse P against Q) —
    the same decomposition the exact backends compute, with the P-sum
    restricted to the kNN support and the Q-sum on the FFT grid
    (``cic``/``interpret`` select its splat/gather path).
    """
    exaggeration = jnp.asarray(exaggeration, jnp.float32)
    ys, yd = y[sp.src], y[sp.dst]
    diff = ys - yd
    num = 1.0 / (1.0 + jnp.sum(diff * diff, axis=1))         # (E,)
    pe = exaggeration * sp.val
    # row-wise reduction WITHOUT scatter: edges are pre-sorted by src, so
    # Σ over row i = cumsum difference at the precomputed row bounds —
    # one vectorized O(E) pass (XLA CPU scatter walks updates serially,
    # ~100× slower at E ~ 10⁷); shared with the UMAP epoch loop
    att = coo.segment_reduce((pe * num)[:, None] * diff, sp.bounds,
                             mode=mode)
    rep, z = fft_repulsion(y, grid_size, cic=cic, interpret=interpret,
                           mode=mode)
    grad = 4.0 * (att - rep / z)
    # KL partials over the sparse support (pe = 0 elsewhere):
    #   KL = Σ pe log pe − Σ pe log num + (Σ pe)·log Z,  Σ pe = exag
    a = jnp.sum(jnp.where(pe > 0,
                          pe * jnp.log(jnp.maximum(pe, 1e-37)), 0.0))
    b = jnp.sum(pe * jnp.log(jnp.maximum(num, 1e-37)))
    kl = a - b + exaggeration * jnp.log(z)
    return grad, kl


# ------------------------------------------------------------- mesh sharding
# Row-block-sharded sparse backend: the whole iteration runs inside
# shard_map on a 1-D embed mesh (core.mesh).  Device s owns the contiguous
# row block [s·rows_per, (s+1)·rows_per) of the optimizer state AND the
# matching contiguous slice of the src-sorted COO edge list
# (coo.ShardedEdgeLayout), so the attraction reduction is the same local
# cumsum-difference segment_reduce the single-device path runs — P_ij only
# ever deposits into src rows (the symmetrized COO carries both
# directions), so tSNE needs NO dst-side exchange at all.  The repulsion
# grid is a sum of per-point splats: each device splats its own rows and
# ONE psum of the (3, G, G) grid masses replicates the total; the FFT then
# runs replicated on the tiny G×G grid and each device gathers its own
# rows back.  Collective contract per iteration (jaxpr-pinned in
# tests/test_mesh_embed.py): one all_gather (the row-block positions) +
# psums of fixed-size partials (grid, Z, KL terms, the centering mean) —
# no cross-device scatter anywhere, and the only scatter primitives of any
# kind are the same four per-device CIC corner splats the single-device
# backend runs.

class ShardedSparseP(NamedTuple):
    """``SparseP`` re-laid-out for a 1-D embed mesh: per-block contiguous
    edge slices (``coo.ShardedEdgeLayout``) + the matching (S, Ep) values
    (zeroed on padded slots).  Built host-side once at setup."""
    layout: coo.ShardedEdgeLayout
    val: jnp.ndarray         # (S, Ep) float32, padded slots carry 0


def shard_sparse_p(sp: SparseP, n: int, n_shards: int) -> ShardedSparseP:
    """Split a (src-sorted) ``SparseP`` into per-row-block edge slices —
    host-side, setup-time (per-block counts are data-dependent)."""
    layout = coo.shard_edge_layout(np.asarray(sp.src), np.asarray(sp.dst),
                                   n, n_shards)
    return ShardedSparseP(layout=layout,
                          val=coo.shard_payload(layout, sp.val))


def _fft_repulsion_shard(y_blk: jnp.ndarray, live_blk: jnp.ndarray,
                         y_full: jnp.ndarray, live_full: jnp.ndarray,
                         grid_size: int, axis: str, n: int, *,
                         cic: str = "xla", interpret: Optional[bool] = None,
                         mode: Optional[str] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-device body of :func:`fft_repulsion` on a row-block mesh.

    Grid geometry comes from the replicated ``y_full`` (live rows only, so
    padded tail rows never stretch the bounding box); each device splats
    its own block's masses, the grids ``psum``-merge, the FFT convolution
    runs replicated, and the local rows gather back.  Returns
    (rep (rows_per, 2), z) — ``z`` is replicated."""
    g = grid_size
    y_blk = y_blk.astype(jnp.float32)
    lo = jnp.min(jnp.where(live_full[:, None], y_full, jnp.inf), axis=0)
    hi = jnp.max(jnp.where(live_full[:, None], y_full, -jnp.inf), axis=0)
    span = jnp.maximum(jnp.max(hi - lo), 1e-9)
    h = span / (g - 3)
    u = (y_blk - lo[None, :]) / h + 1.0
    i0 = jnp.clip(jnp.floor(u).astype(jnp.int32), 0, g - 2)
    f = u - i0
    mass = live_blk.astype(jnp.float32)

    if cic == "pallas":
        from repro.kernels import ops
        masses = jnp.stack([mass, y_blk[:, 0] * mass,
                            y_blk[:, 1] * mass], axis=1)     # (B, 3)
        grid = ops.cic_splat(i0, f, masses, g, interpret=interpret,
                             mode=mode)
    else:
        vals = jnp.stack([mass, y_blk[:, 0] * mass, y_blk[:, 1] * mass])
        grid = _splat_xla(i0, f, vals, g)
    grid = jax.lax.psum(grid, axis)                          # THE exchange

    conv1, conv0 = _grid_convolve(grid, g, h)

    if cic == "pallas":
        from repro.kernels import ops
        fields = jnp.concatenate([conv1, conv0[None]], axis=0)
        got = ops.cic_gather(fields, i0, f, interpret=interpret,
                             mode=mode)
        s1, sy, phi0 = got[:, 0], got[:, 1:3].T, got[:, 3]
    else:
        s1 = _gather_xla(conv1[0], i0, f)
        sy = _gather_xla(conv1[1:], i0, f)                   # (2, B)
        phi0 = _gather_xla(conv0, i0, f)
    z = jnp.maximum(jax.lax.psum(jnp.sum(phi0 * mass), axis) - n, 1e-12)
    rep = s1[:, None] * y_blk - sy.T
    return rep, z


def sparse_grad_shard(y_blk: jnp.ndarray, layout: coo.ShardedEdgeLayout,
                      val: jnp.ndarray, y_full: jnp.ndarray,
                      exaggeration, grid_size: int, axis: str, n: int, *,
                      cic: str = "xla", interpret: Optional[bool] = None,
                      mode: Optional[str] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-device sparse gradient: the shard_map body mirroring
    :func:`sparse_grad`.  ``layout``/``val`` are ONE device's squeezed
    (Ep,)-slices; returns (grad (rows_per, 2), KL) with KL replicated."""
    exaggeration = jnp.asarray(exaggeration, jnp.float32)
    rows_per = layout.src_bounds.shape[0] - 1
    n_pad = layout.dst_bounds.shape[0] - 1
    ys, yd = y_full[layout.src], y_full[layout.dst]
    diff = ys - yd
    num = 1.0 / (1.0 + jnp.sum(diff * diff, axis=1))         # (Ep,)
    pe = exaggeration * val                                  # 0 on padding
    # local rows own their full edge slice (blocks split at row
    # boundaries), so the attraction reduction is entirely local
    att = coo.segment_reduce((pe * num)[:, None] * diff, layout.src_bounds,
                             mode=mode)
    live_blk = layout.row_offset + jnp.arange(rows_per) < n
    live_full = jnp.arange(n_pad) < n
    rep, z = _fft_repulsion_shard(y_blk, live_blk, y_full, live_full,
                                  grid_size, axis, n, cic=cic,
                                  interpret=interpret, mode=mode)
    grad = 4.0 * (att - rep / z)
    grad = jnp.where(live_blk[:, None], grad, 0.0)
    a = jax.lax.psum(jnp.sum(jnp.where(
        pe > 0, pe * jnp.log(jnp.maximum(pe, 1e-37)), 0.0)), axis)
    b = jax.lax.psum(jnp.sum(pe * jnp.log(jnp.maximum(num, 1e-37))), axis)
    kl = a - b + exaggeration * jnp.log(z)
    return grad, kl


def _momentum_update_shard(state: TsneState, grad: jnp.ndarray, mom,
                           cfg: TsneConfig, axis: str, live_blk: jnp.ndarray,
                           n: int) -> TsneState:
    """Row-block momentum update: identical math to
    :func:`_momentum_update` except the recentering mean is a ``psum`` of
    per-block partial sums over the live rows."""
    same_sign = jnp.sign(grad) == jnp.sign(state.velocity)
    gains = jnp.where(same_sign, state.gains * 0.8, state.gains + 0.2)
    gains = jnp.maximum(gains, cfg.min_gain)
    vel = mom * state.velocity - cfg.learning_rate * gains * grad
    y = state.y + vel
    total = jax.lax.psum(
        jnp.sum(jnp.where(live_blk[:, None], y, 0.0), axis=0), axis)
    y = y - (total / n)[None, :]
    return TsneState(y, vel, gains)


@functools.partial(jax.jit, static_argnames=("cfg", "count", "grid_size",
                                             "interpret", "mesh", "n"))
def _sparse_stage_mesh(state: TsneState, kls: jnp.ndarray,
                       ssp: ShardedSparseP, it0: jnp.ndarray, *,
                       cfg: TsneConfig, count: int, grid_size: int,
                       interpret: bool, mesh, n: int
                       ) -> Tuple[TsneState, jnp.ndarray]:
    """``count`` mesh-parallel optimizer iterations at a fixed grid size —
    the sharded twin of :func:`_sparse_stage`.  State rows and edge slices
    stay on their devices across the whole ``fori_loop``; per iteration the
    only collectives are one all_gather of the block positions and the
    fixed-size psums (grid, Z, KL, centering)."""
    axis = mesh_mod.mesh_axis(mesh)
    P = mesh_mod.P
    lay_specs = jax.tree_util.tree_map(lambda _: P(axis), ssp)
    state_specs = TsneState(P(axis), P(axis), P(axis))

    @mesh_mod.shard_map_compat(
        mesh=mesh, in_specs=(state_specs, P(), lay_specs, P()),
        out_specs=(state_specs, P()))
    def spmd(state, kls, ssp, it0):
        # (S, ...) leaves arrive as (1, ...) per device — drop the axis
        lay = jax.tree_util.tree_map(lambda a: a[0], ssp.layout)
        val = ssp.val[0]
        rows_per = lay.src_bounds.shape[0] - 1
        live_blk = lay.row_offset + jnp.arange(rows_per) < n

        def step(i, carry):
            st, kls = carry
            it = it0 + i
            exag, mom = _phase(it, cfg)
            y_full = jax.lax.all_gather(st.y, axis, axis=0, tiled=True)
            grad, kl = sparse_grad_shard(
                st.y, lay, val, y_full, exag, grid_size, axis, n,
                cic=cfg.cic, interpret=interpret,
                mode=_cfg_kernel_mode(cfg))
            st = _momentum_update_shard(st, grad, mom, cfg, axis,
                                        live_blk, n)
            return st, kls.at[it].set(kl)

        return jax.lax.fori_loop(0, count, step, (state, kls))

    return spmd(state, kls, ssp, it0)


def _run_tsne_sparse_mesh(key: jax.Array, x: jnp.ndarray, weights, init, *,
                          cfg: TsneConfig, mesh, interpret: bool
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mesh-parallel sparse optimizer (fixed or span-adaptive G).

    Setup: sharded kNN build + COO P (jitted), then the host slices the
    src-sorted edge list into per-row-block shards (shapes are
    data-dependent, so this is a one-time concrete pass).  The optimizer
    then runs in jitted mesh stages; with ``grid_interval > 0`` the host
    checks the span between stages and doubles G exactly like the
    single-device staged driver."""
    n = x.shape[0]
    n_shards = mesh_mod.axis_size(mesh, mesh_mod.mesh_axis(mesh))
    rows_per, n_pad = mesh_mod.row_block(n, n_shards)

    sp = _sparse_setup_p_mesh(x, weights, cfg=cfg, mesh=mesh)
    ssp = shard_sparse_p(sp, n, n_shards)

    # identical draws to the single-device path, then padded tail rows
    y0 = init if init is not None else \
        1e-4 * jax.random.normal(key, (n, cfg.dims))
    y0 = jnp.pad(y0, [(0, n_pad - n), (0, 0)])
    state = TsneState(y=y0, velocity=jnp.zeros_like(y0),
                      gains=jnp.ones_like(y0))
    kls = jnp.zeros((cfg.n_iter,))
    g = cfg.grid_size
    it = 0
    while it < cfg.n_iter:
        count = cfg.n_iter - it if cfg.grid_interval <= 0 else \
            min(cfg.adaptive_interval, cfg.n_iter - it)
        state, kls = _sparse_stage_mesh(
            state, kls, ssp, jnp.asarray(it, jnp.int32), cfg=cfg,
            count=count, grid_size=g, interpret=interpret, mesh=mesh, n=n)
        it += count
        if cfg.grid_interval > 0 and it < cfg.n_iter:
            y_live = state.y[:n]
            span = float(jnp.max(jnp.max(y_live, axis=0)
                                 - jnp.min(y_live, axis=0)))
            g = _grid_for_span(span, g, cfg)
    return state.y[:n], kls


@functools.partial(jax.jit, static_argnames=("cfg", "mesh"))
def _sparse_setup_p_mesh(x: jnp.ndarray, weights, *, cfg: TsneConfig,
                         mesh) -> SparseP:
    """Jitted sparse-P setup with the kNN build sharded over the mesh."""
    return build_sparse_p(x, cfg.perplexity, k=cfg.knn or None,
                          weights=weights,
                          search_iters=cfg.sigma_search_iters,
                          block=cfg.block, mesh=mesh,
                          method=cfg.knn_method, ann=cfg.ann)


def kl_divergence(p: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    n = y.shape[0]
    num = 1.0 / (1.0 + pairwise_sq_dists(y))
    num = num.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    q = jnp.maximum(num / jnp.sum(num), 1e-12)
    return jnp.sum(p * (jnp.log(p) - jnp.log(q)))


def _grad_and_kl(p: jnp.ndarray, y: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact tSNE gradient (matmul form) + current KL — dense backend."""
    n = y.shape[0]
    num = 1.0 / (1.0 + pairwise_sq_dists(y))                 # (N, N)
    num = num.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    z = jnp.sum(num)
    q = jnp.maximum(num / z, 1e-12)
    pq = (p - q) * num                                       # (N, N)
    # grad_i = 4 [ (sum_j pq_ij) y_i - sum_j pq_ij y_j ]
    grad = 4.0 * (jnp.sum(pq, axis=1, keepdims=True) * y - pq @ y)
    kl = jnp.sum(p * (jnp.log(p) - jnp.log(q)))
    return grad, kl


def _tiled_grad_kl(x: jnp.ndarray, y: jnp.ndarray, stats: PointStats,
                   exaggeration: jnp.ndarray, n_valid: int, block: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-streamed gradient + KL: peak memory O(block · N).

    All inputs padded to a multiple of ``block`` (padded rows carry w = 0
    and are masked out of every pair).  Two passes, like the Pallas
    kernel: Z is a global reduction that must precede the force weighting.
    """
    npad, dims = y.shape
    nb = npad // block
    ids = jnp.arange(npad)
    col_live = ids[None, :] < n_valid

    def pair_mask(idc):
        return (idc[:, None] != ids[None, :]) & \
            (idc[:, None] < n_valid) & col_live

    def z_chunk(args):
        yc, idc = args
        num = 1.0 / (1.0 + pairwise_sq_dists(yc, y))
        return jnp.sum(jnp.where(pair_mask(idc), num, 0.0))

    chunks_y = y.reshape(nb, block, dims)
    chunks_id = ids.reshape(nb, block)
    z = jnp.sum(jax.lax.map(z_chunk, (chunks_y, chunks_id)))

    beta, shift, zp, w = stats

    def force_chunk(args):
        xc, yc, bc, mc, zc, wc, idc = args
        mask = pair_mask(idc)
        d2x = pairwise_sq_dists(xc, x)
        pc_ij = jnp.exp(-bc[:, None] * d2x - mc[:, None]) / zc[:, None]
        pc_ji = jnp.exp(-beta[None, :] * d2x - shift[None, :]) / zp[None, :]
        p = jnp.where(mask, 0.5 * (wc[:, None] * pc_ij + w[None, :] * pc_ji),
                      0.0)
        num = 1.0 / (1.0 + pairwise_sq_dists(yc, y))
        num = jnp.where(mask, num, 0.0)
        q = num / z
        pe = exaggeration * p
        pq = (pe - q) * num
        f = 4.0 * (jnp.sum(pq, axis=1, keepdims=True) * yc - pq @ y)
        # KL partials: Σ pe log pe and Σ pe log num (q = num/Z folds in later)
        a = jnp.sum(jnp.where(pe > 0, pe * jnp.log(jnp.maximum(pe, 1e-37)),
                              0.0))
        b = jnp.sum(jnp.where(pe > 0, pe * jnp.log(jnp.maximum(num, 1e-37)),
                              0.0))
        return f, a, b

    xs = (x.reshape(nb, block, -1), chunks_y,
          beta.reshape(nb, block), shift.reshape(nb, block),
          zp.reshape(nb, block), w.reshape(nb, block), chunks_id)
    f, a, b = jax.lax.map(force_chunk, xs)
    kl = jnp.sum(a) - jnp.sum(b) + exaggeration * jnp.log(z)
    return f.reshape(npad, dims), kl


def embedding_grad(x: jnp.ndarray, y: jnp.ndarray, stats: PointStats,
                   exaggeration=1.0, *, backend: str = "tiled",
                   block: int = 512, interpret: Optional[bool] = None,
                   mode: Optional[str] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One tSNE gradient evaluation on any backend — test/bench surface.

    Returns (grad (N, dims), KL of the exaggerated P against current Q).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of {BACKENDS}")
    if backend == "sparse":
        raise ValueError(
            "the sparse backend is calibrated from the kNN graph, not "
            "PointStats — use build_sparse_p(...) once, then sparse_grad()")
    exaggeration = jnp.asarray(exaggeration, jnp.float32)
    if backend == "dense":
        return _grad_and_kl(p_from_stats(x, stats) * exaggeration, y)
    if backend == "pallas":
        from repro.kernels import ops
        return ops.tsne_step_fused(
            x, y, stats.beta, stats.zp, shift=stats.shift, weights=stats.w,
            exaggeration=exaggeration, block=min(block, x.shape[0]),
            interpret=interpret, mode=mode, return_kl=True)
    n = x.shape[0]
    block = min(block, n)
    pad = functools.partial(_pad_rows, block=block)
    spad = PointStats(beta=pad(stats.beta), shift=pad(stats.shift),
                      zp=pad(stats.zp, value=1), w=pad(stats.w))
    f, kl = _tiled_grad_kl(pad(x), pad(y), spad, exaggeration,
                           n_valid=n, block=block)
    return f[:n], kl


class TsneState(NamedTuple):
    y: jnp.ndarray
    velocity: jnp.ndarray
    gains: jnp.ndarray


def _momentum_update(state: TsneState, grad: jnp.ndarray, mom, cfg: TsneConfig
                     ) -> TsneState:
    """One momentum + per-parameter-gains optimizer update (recentered)."""
    same_sign = jnp.sign(grad) == jnp.sign(state.velocity)
    gains = jnp.where(same_sign, state.gains * 0.8, state.gains + 0.2)
    gains = jnp.maximum(gains, cfg.min_gain)
    vel = mom * state.velocity - cfg.learning_rate * gains * grad
    y = state.y + vel
    y = y - jnp.mean(y, axis=0, keepdims=True)
    return TsneState(y, vel, gains)


def _phase(i, cfg: TsneConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Staged-schedule scalars (exaggeration, momentum) at iteration i."""
    exag = jnp.where(i < cfg.exaggeration_iters,
                     cfg.early_exaggeration, 1.0)
    mom = jnp.where(i < cfg.momentum_switch,
                    cfg.momentum_start, cfg.momentum_final)
    return exag, mom


@functools.partial(jax.jit, static_argnames=("cfg", "backend", "interpret"))
def _run_tsne(key: jax.Array, x: jnp.ndarray, weights, init, *,
              cfg: TsneConfig, backend: str, interpret: bool
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n = x.shape[0]
    if backend == "sparse":
        sp = build_sparse_p(x, cfg.perplexity, k=cfg.knn or None,
                            weights=weights,
                            search_iters=cfg.sigma_search_iters,
                            block=cfg.block,
                            method=cfg.knn_method, ann=cfg.ann)

        def grad_fn(y, exag):
            return sparse_grad(y, sp, exag, grid_size=cfg.grid_size,
                               cic=cfg.cic, interpret=interpret,
                               mode=_cfg_kernel_mode(cfg))
    else:
        stats = calibrate_stats(x, cfg.perplexity, weights=weights,
                                search_iters=cfg.sigma_search_iters,
                                block=cfg.block)
        if backend == "dense":
            p = p_from_stats(x, stats)

            def grad_fn(y, exag):
                return _grad_and_kl(p * exag, y)
        else:
            def grad_fn(y, exag):
                return embedding_grad(x, y, stats, exag, backend=backend,
                                      block=cfg.block, interpret=interpret,
                                      mode=_cfg_kernel_mode(cfg))

    y0 = init if init is not None else \
        1e-4 * jax.random.normal(key, (n, cfg.dims))
    state = TsneState(y=y0, velocity=jnp.zeros_like(y0),
                      gains=jnp.ones_like(y0))

    def step(i, carry):
        state, kls = carry
        exag, mom = _phase(i, cfg)
        grad, kl = grad_fn(state.y, exag)
        return _momentum_update(state, grad, mom, cfg), kls.at[i].set(kl)

    state, kls = jax.lax.fori_loop(
        0, cfg.n_iter, step, (state, jnp.zeros((cfg.n_iter,))))
    return state.y, kls


# ------------------------------------------------------------------ adaptive G
# FIt-SNE grows the interpolation grid with the embedding span instead of
# re-spacing a fixed G×G grid: the cell spacing h stays (approximately)
# constant, so the repulsion field's resolution does not degrade as early
# exaggeration relaxes and the embedding expands 10-100×.  Shapes must be
# static under jit, so the optimizer runs in STAGES of
# ``cfg.adaptive_interval`` iterations: each stage is one jitted call with
# a static G, and between stages the host checks the span and doubles G
# when span/(G−3) outgrows ``cfg.grid_interval`` (monotone, capped at
# ``cfg.grid_max``).  G only ever takes values grid_size·2^m, so the whole
# run retraces at most log₂(grid_max/grid_size) times.

@functools.partial(jax.jit, static_argnames=("cfg",))
def _sparse_setup(key: jax.Array, x: jnp.ndarray, weights, init, *,
                  cfg: TsneConfig) -> Tuple[SparseP, TsneState]:
    """One-time sparse-backend setup: COO P + optimizer init."""
    sp = build_sparse_p(x, cfg.perplexity, k=cfg.knn or None,
                        weights=weights,
                        search_iters=cfg.sigma_search_iters,
                        block=cfg.block,
                        method=cfg.knn_method, ann=cfg.ann)
    y0 = init if init is not None else \
        1e-4 * jax.random.normal(key, (x.shape[0], cfg.dims))
    return sp, TsneState(y=y0, velocity=jnp.zeros_like(y0),
                         gains=jnp.ones_like(y0))


@functools.partial(jax.jit, static_argnames=("cfg", "count", "grid_size",
                                             "interpret"))
def _sparse_stage(state: TsneState, kls: jnp.ndarray, sp: SparseP,
                  it0: jnp.ndarray, *, cfg: TsneConfig, count: int,
                  grid_size: int, interpret: bool
                  ) -> Tuple[TsneState, jnp.ndarray]:
    """``count`` optimizer iterations at a fixed grid size.

    ``it0`` (the global iteration offset) is traced, so the stage function
    retraces only when (count, grid_size) changes — the schedule scalars
    still switch at the right global iteration.
    """
    def step(i, carry):
        state, kls = carry
        it = it0 + i
        exag, mom = _phase(it, cfg)
        grad, kl = sparse_grad(state.y, sp, exag, grid_size=grid_size,
                               cic=cfg.cic, interpret=interpret,
                               mode=_cfg_kernel_mode(cfg))
        return _momentum_update(state, grad, mom, cfg), kls.at[it].set(kl)

    return jax.lax.fori_loop(0, count, step, (state, kls))


def _grid_for_span(span: float, g: int, cfg: TsneConfig) -> int:
    """Smallest doubling of the current G that keeps the cell spacing
    h = span/(G−3) at or under the target ``cfg.grid_interval``."""
    while g < cfg.grid_max and span / (g - 3) > cfg.grid_interval:
        g *= 2
    return g


def _run_tsne_sparse_adaptive(key: jax.Array, x: jnp.ndarray, weights, init,
                              *, cfg: TsneConfig, interpret: bool
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Staged sparse optimizer with span-adaptive repulsion grid."""
    sp, state = _sparse_setup(key, x, weights, init, cfg=cfg)
    kls = jnp.zeros((cfg.n_iter,))
    g = cfg.grid_size
    it = 0
    while it < cfg.n_iter:
        count = min(cfg.adaptive_interval, cfg.n_iter - it)
        state, kls = _sparse_stage(
            state, kls, sp, jnp.asarray(it, jnp.int32), cfg=cfg,
            count=count, grid_size=g, interpret=interpret)
        it += count
        span = float(jnp.max(jnp.max(state.y, axis=0)
                             - jnp.min(state.y, axis=0)))
        g = _grid_for_span(span, g, cfg)
    return state.y, kls


def run_tsne(key: jax.Array, x: jnp.ndarray, cfg: TsneConfig,
             weights: Optional[jnp.ndarray] = None,
             backend: Optional[str] = None,
             mesh=None, init: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full tSNE: returns (embedding (N, dims), KL trace (n_iter,)).

    ``backend`` overrides ``cfg.backend``; Pallas interpret mode is
    auto-selected off-TPU.  ``mesh`` (``None`` | device count | 1-D
    ``Mesh``, see ``core.mesh``) runs the whole sparse optimizer
    row-block-sharded under ``shard_map`` — sparse backend only (the
    dense/tiled/pallas backends are O(N²) and stay single-device).

    ``init`` seeds the optimizer at the given (N, dims) float coordinates
    instead of the 1e-4·normal cold start — the warm-start hook the
    online service uses to resume from a previous embedding (callers
    normally pair it with ``exaggeration_iters=0``: early exaggeration
    would blow a converged init apart).  Works on every backend and on
    the mesh path; validated for shape/dtype up front.
    """
    backend = backend or cfg.backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of {BACKENDS}")
    if backend == "sparse" and cfg.dims != 2:
        raise ValueError(
            f"sparse backend splats onto a 2D grid; got dims={cfg.dims}")
    if cfg.cic not in CIC_PATHS:
        raise ValueError(f"unknown cic {cfg.cic!r}; want one of {CIC_PATHS}")
    if cfg.kernel_mode not in ("auto",) + registry_mod.MODES:
        raise ValueError(
            f"unknown kernel_mode {cfg.kernel_mode!r}; want one of "
            f"{('auto',) + registry_mod.MODES}")
    init = validate_init(init, x.shape[0], cfg.dims)
    if cfg.n_iter == 0:
        # degenerate but load-bearing for the warm-start contract: the
        # returned embedding IS iteration 0 (the init, bit-exact), and no
        # optimizer machinery may touch it (the fori_loop body would still
        # trace a scatter into the empty KL trace)
        y0 = init if init is not None else \
            1e-4 * jax.random.normal(key, (x.shape[0], cfg.dims))
        return y0, jnp.zeros((0,), jnp.float32)
    interpret = jax.default_backend() != "tpu"
    mesh = mesh_mod.resolve_mesh(mesh)
    if mesh is not None:
        if backend != "sparse":
            raise ValueError(
                f"mesh-parallel tSNE needs backend='sparse'; got {backend!r}")
        return _run_tsne_sparse_mesh(key, x, weights, init, cfg=cfg,
                                     mesh=mesh, interpret=interpret)
    if backend == "sparse" and cfg.grid_interval > 0:
        return _run_tsne_sparse_adaptive(key, x, weights, init, cfg=cfg,
                                         interpret=interpret)
    return _run_tsne(key, x, weights, init, cfg=cfg, backend=backend,
                     interpret=interpret)
