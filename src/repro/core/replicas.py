"""Heavy hitters → weighted representative points for tSNE/UMAP.

Paper §II-1: identical points are merged by tSNE, so each HH cell is
replicated with a small uniform jitter (¼ of the cell size).  Three
weighting schemes, all tested by the authors to give the same cluster
structure:

* ``"uniform"``  — fixed n_rep replicas per HH;
* ``"rank"``     — 1 + ⌊log₂(r_max / r)⌋ replicas for rank r;
* ``"count"``    — 1 + ⌊log₂(f / f_min)⌋ replicas for count f.

Static shapes: the output holds ``total_slots`` points; each HH fills
``replicas[i]`` of its slot budget, the rest are masked out.  Every HH gets
the same slot budget = max possible replicas, so no HH can starve.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize, u64
from repro.core.heavy_hitters import HeavyHitters
from repro.core.quantize import GridSpec


class Representatives(NamedTuple):
    points: jnp.ndarray    # (slots, D) float32 jittered cell centers
    weight: jnp.ndarray    # (slots,) float32 — HH count carried by the point
    hh_id: jnp.ndarray     # (slots,) int32 — which HH the point came from
    mask: jnp.ndarray      # (slots,) bool


def replica_counts(hh: HeavyHitters, scheme: str, max_replicas: int
                   ) -> jnp.ndarray:
    """(K,) int32 number of replicas per HH under the paper's schemes."""
    k = hh.count.shape[0]
    if scheme == "uniform":
        n = jnp.full((k,), max_replicas, jnp.int32)
    elif scheme == "rank":
        # ranks are 1-based in count-descending order; hh is already sorted
        r = jnp.arange(1, k + 1, dtype=jnp.float32)
        r_max = jnp.sum(hh.mask.astype(jnp.float32))       # rank of smallest
        n = 1 + jnp.floor(jnp.log2(jnp.maximum(r_max / r, 1.0))).astype(jnp.int32)
    elif scheme == "count":
        f = jnp.maximum(hh.count, 1e-9)
        f_min = jnp.min(jnp.where(hh.mask, f, jnp.inf))
        n = 1 + jnp.floor(jnp.log2(jnp.maximum(f / f_min, 1.0))).astype(jnp.int32)
    else:
        raise ValueError(f"unknown replica scheme {scheme!r}")
    n = jnp.clip(n, 1, max_replicas)
    return jnp.where(hh.mask, n, 0)


def make_representatives(key: jax.Array, grid: GridSpec, hh: HeavyHitters,
                         scheme: str = "count", max_replicas: int = 8,
                         jitter_frac: float = 0.25) -> Representatives:
    """HH cells → jittered weighted points, ready for tSNE/UMAP.

    Output has K·max_replicas slots; slot (i, j) is live iff j < n_i.
    """
    k = hh.key_hi.shape[0]
    coords = quantize.unpack(grid, (hh.key_hi, hh.key_lo))    # (K, D)
    centers = quantize.cell_center(grid, coords)              # (K, D)
    n = replica_counts(hh, scheme, max_replicas)              # (K,)

    cell = jnp.asarray(grid.cell_size)                        # (D,)

    # The jitter is a pure function of (cell key, slot, seed) — NOT of
    # the row index.  HH rows are sorted by count, so a position-indexed
    # draw re-rolls every cell's jitter whenever the ranking reshuffles
    # (e.g. between two extractions of a drifting stream); cell-keyed
    # draws keep each cell's representatives put, which is what lets a
    # warm-started re-embed seed matched reps at their old coordinates.
    def _cell_jitter(hi, lo):
        kc = jax.random.fold_in(jax.random.fold_in(key, hi), lo)
        return jax.random.uniform(kc, (max_replicas, grid.dims),
                                  minval=-jitter_frac, maxval=jitter_frac)

    jit = jax.vmap(_cell_jitter)(hh.key_hi, hh.key_lo)        # (K, max, D)
    pts = centers[:, None, :] + jit * cell[None, None, :]     # (K, max, D)
    slot = jnp.arange(max_replicas)[None, :]                  # (1, max)
    live = slot < n[:, None]                                  # (K, max)
    # weight: each replica carries count / n so total mass is preserved
    w = hh.count[:, None] / jnp.maximum(n[:, None].astype(jnp.float32), 1.0)
    hh_id = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[:, None],
                             (k, max_replicas))
    return Representatives(
        points=pts.reshape(k * max_replicas, grid.dims),
        weight=jnp.where(live, w, 0.0).reshape(-1),
        hh_id=hh_id.reshape(-1),
        mask=live.reshape(-1))


def compact(rep: Representatives) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side: drop masked slots -> (points, weights, hh_ids) numpy arrays."""
    m = np.asarray(rep.mask)
    return (np.asarray(rep.points)[m], np.asarray(rep.weight)[m],
            np.asarray(rep.hh_id)[m])
