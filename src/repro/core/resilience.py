"""Fault tolerance for distributed ingest: retries, straggler cutoff,
partial aggregation.

The CountSketch's linearity (``merge == add``) makes *partial
aggregation* the principled response to shard loss: merging the sketches
that DID arrive yields exactly the sketch of the surviving sub-stream,
and the damage is quantifiable — the observed-mass fraction
(``coverage``) and a widened heavy-hitter error bound (a lost shard
could have concentrated its whole mass on one cell, so every reported
count is uncertain by up to the estimated lost mass).  This module turns
that observation into machinery:

* :class:`RetryPolicy` — bounded attempts, exponential backoff with
  deterministic seed-keyed jitter, optional per-attempt timeout.
  :func:`call_with_retry` drives it; :class:`RetryError` carries the
  last failure after exhaustion.
* :func:`collect_shards` — the straggler-cutoff collector: per-shard
  jobs run concurrently, each inside its own retry loop; a global
  ``deadline`` abandons stragglers; arrived states partial-merge via
  ``stream.merge_states``; optional digest verification rejects
  corrupted deliveries (they count as failed attempts and retry).
* :class:`PartialAggregate` — merged state + ``coverage`` +
  ``hh_error_bound`` + per-shard :class:`ShardStatus` forensics.
  ``min_coverage`` is the fail-loud floor: below it the collector
  raises :class:`CoverageError` instead of degrading silently.

What is retried, what degrades, what fails loud:

* transient failures (flaky attempts, corrupted deliveries) → RETRIED,
  up to ``RetryPolicy.max_attempts`` per shard;
* permanent shard loss / deadline stragglers → DEGRADE: partial
  aggregation with ``coverage < 1`` and a widened ``hh_error_bound``
  (monotone: losing more shards never shrinks the bound — property-
  tested in tests/test_resilience.py);
* ``coverage < min_coverage`` or zero surviving shards → FAIL LOUD
  (:class:`CoverageError` listing every shard's fate).
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np


class RetryError(RuntimeError):
    """All attempts exhausted; ``__cause__`` is the last failure."""


class IntegrityError(RuntimeError):
    """A delivered payload failed its digest check (bit rot in transit)."""


class CoverageError(RuntimeError):
    """Partial aggregation fell below the configured coverage floor."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff and deterministic jitter.

    ``backoff(attempt)`` for attempt = 0, 1, ... is
    ``min(base * multiplier**attempt, max_delay)`` scaled by a jitter
    factor drawn deterministically from ``(seed, attempt)`` — chaos tests
    replay bit-for-bit.  ``attempt_timeout`` bounds one attempt's wall
    clock (the attempt's thread is abandoned, not killed — acceptable
    for the I/O-bound shard fetches this guards).

    Not every failure deserves a retry: a digest mismatch is transit
    noise worth another fetch, but a corrupted checkpoint or a config
    ``ValueError`` is deterministic — replaying it burns the whole
    attempt budget (plus backoff sleeps) to reach the same exception.
    ``retryable_exceptions`` is the allowlist; anything matching
    ``non_retryable_exceptions`` fails IMMEDIATELY even if it also
    matches the allowlist (deny wins).  ``non_retryable_exceptions=None``
    means the default deny set: ``ValueError`` and
    ``stream.CheckpointCorruptError``."""
    max_attempts: int = 3
    base_delay: float = 0.01
    multiplier: float = 2.0
    max_delay: float = 1.0
    jitter: float = 0.5                    # delay *= 1 ± U(0, jitter)
    attempt_timeout: Optional[float] = None
    retryable_exceptions: Tuple[type, ...] = (Exception,)
    non_retryable_exceptions: Optional[Tuple[type, ...]] = None

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("RetryPolicy.max_attempts must be >= 1, "
                             f"got {self.max_attempts}")
        if self.base_delay < 0 or self.max_delay < 0:
            raise ValueError("RetryPolicy delays must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError("RetryPolicy.multiplier must be >= 1")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("RetryPolicy.jitter must be in [0, 1]")
        if self.attempt_timeout is not None and self.attempt_timeout <= 0:
            raise ValueError("RetryPolicy.attempt_timeout must be > 0")
        for name in ("retryable_exceptions", "non_retryable_exceptions"):
            excs = getattr(self, name)
            if excs is None:
                continue
            if not all(isinstance(e, type) and issubclass(e, BaseException)
                       for e in excs):
                raise ValueError(
                    f"RetryPolicy.{name} must be a tuple of exception "
                    f"types, got {excs!r}")

    def is_retryable(self, exc: BaseException) -> bool:
        """Should ``exc`` consume another attempt?  Deny-list wins over
        the allow-list; the default deny set is resolved lazily so the
        stream module (which defines CheckpointCorruptError) is only
        imported when a failure actually needs classifying."""
        deny = self.non_retryable_exceptions
        if deny is None:
            from repro.core.stream import CheckpointCorruptError
            deny = (ValueError, CheckpointCorruptError)
        if isinstance(exc, deny):
            return False
        return isinstance(exc, self.retryable_exceptions)

    def backoff(self, attempt: int, seed: int = 0) -> float:
        """Sleep before retry number ``attempt+1`` (deterministic)."""
        d = min(self.base_delay * self.multiplier ** attempt,
                self.max_delay)
        if self.jitter > 0:
            u = np.random.default_rng(
                np.random.SeedSequence([seed & 0xFFFFFFFF, attempt])
            ).random()
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return d


def _timed_call(fn: Callable[[], object], timeout: Optional[float]):
    """Run ``fn`` with a wall-clock bound.  Timeouts abandon the attempt's
    thread (Python threads cannot be killed); the result, if it ever
    materializes, is discarded."""
    if timeout is None:
        return fn()
    ex = ThreadPoolExecutor(max_workers=1)
    try:
        fut = ex.submit(fn)
        return fut.result(timeout=timeout)
    except TimeoutError:
        raise TimeoutError(f"attempt exceeded {timeout}s") from None
    finally:
        ex.shutdown(wait=False, cancel_futures=True)


def call_with_retry(fn: Callable[[], object],
                    policy: Optional[RetryPolicy] = None, *,
                    seed: int = 0,
                    check: Optional[Callable[[object], None]] = None,
                    on_retry: Optional[Callable[[int, Exception], None]] = None,
                    on_attempt: Optional[
                        Callable[[int, float, Optional[Exception]], None]]
                    = None) -> Tuple[object, int]:
    """Call ``fn`` under ``policy``; returns ``(result, attempts_used)``.

    ``check(result)`` (optional) validates a delivery — raising (e.g.
    :class:`IntegrityError` on a digest mismatch) counts as a failed
    attempt, so corrupted deliveries are retried like any other fault.
    A failure the policy classifies non-retryable (``ValueError``,
    ``CheckpointCorruptError`` by default — see
    :meth:`RetryPolicy.is_retryable`) RE-RAISES immediately instead of
    burning the remaining attempt budget.  ``on_attempt(attempt,
    seconds, exc_or_None)`` (optional) observes every attempt's wall
    clock — the collector's per-shard latency forensics hang off it.
    After the final retryable failure a :class:`RetryError` chains the
    cause."""
    policy = policy or RetryPolicy()
    last: Optional[Exception] = None
    for attempt in range(policy.max_attempts):
        t_a = time.monotonic()
        try:
            out = _timed_call(fn, policy.attempt_timeout)
            if check is not None:
                check(out)
            if on_attempt is not None:
                on_attempt(attempt, time.monotonic() - t_a, None)
            return out, attempt + 1
        except Exception as e:                           # noqa: BLE001
            last = e
            if on_attempt is not None:
                on_attempt(attempt, time.monotonic() - t_a, e)
            if not policy.is_retryable(e):
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            if attempt + 1 < policy.max_attempts:
                time.sleep(policy.backoff(attempt, seed=seed))
    raise RetryError(
        f"all {policy.max_attempts} attempts failed; last: "
        f"{type(last).__name__}: {last}") from last


@dataclasses.dataclass
class ShardStatus:
    """One shard's fate through the collector."""
    shard: int
    ok: bool
    attempts: int            # attempts actually made (0 = never finished)
    seconds: float           # wall clock from submit to verdict
    error: Optional[str]     # final error ('deadline' for stragglers)
    # wall clock of each individual attempt, in order (len == attempts
    # except for deadline stragglers, whose in-flight attempt never
    # reports) — feeds the service's per-shard latency histograms
    attempt_seconds: Tuple[float, ...] = ()


# log-spaced attempt-latency buckets (seconds, upper bounds; the last
# bucket is open).  Shared by the service's per-shard histograms so
# health() payloads are comparable across deployments.
LATENCY_BUCKET_EDGES: Tuple[float, ...] = (0.001, 0.01, 0.1, 1.0, 10.0)
LATENCY_BUCKET_LABELS: Tuple[str, ...] = (
    "<=1ms", "<=10ms", "<=100ms", "<=1s", "<=10s", ">10s")


def latency_bucket(seconds: float) -> int:
    """Index into :data:`LATENCY_BUCKET_LABELS` for one attempt."""
    for i, edge in enumerate(LATENCY_BUCKET_EDGES):
        if seconds <= edge:
            return i
    return len(LATENCY_BUCKET_EDGES)


def latency_histogram(attempt_seconds: Sequence[float]) -> List[int]:
    """Bucket counts (len == len(LATENCY_BUCKET_LABELS)) for a batch of
    attempt wall-clocks."""
    counts = [0] * len(LATENCY_BUCKET_LABELS)
    for s in attempt_seconds:
        counts[latency_bucket(float(s))] += 1
    return counts


@dataclasses.dataclass
class PartialAggregate:
    """Merged survivors + the quantified damage."""
    state: object                    # merged stream.IngestState
    observed_count: float            # mass actually folded
    expected_count: float            # observed + (known or estimated) lost
    coverage: float                  # observed / expected  (1.0 = no loss)
    lost_mass: float                 # expected - observed
    hh_error_bound: float            # max survivor watermark + lost_mass
    statuses: List[ShardStatus]
    lost: Tuple[int, ...]            # shard ids that never delivered
    retries: int                     # extra attempts beyond the first, total

    @property
    def n_ok(self) -> int:
        return sum(1 for s in self.statuses if s.ok)


def widened_bound(survivor_bound: float, lost_mass: float) -> float:
    """Heavy-hitter error bound after shard loss: the survivors' own
    watermark plus the whole estimated lost mass — a lost shard could
    have put every one of its points in a single cell, so no reported
    count can be trusted closer than this.  Additive in the lost mass,
    which is what makes the bound MONOTONE under widening loss."""
    return float(survivor_bound) + float(lost_mass)


def collect_shards(jobs: Mapping[int, Callable[[], object]], *,
                   policy: Optional[RetryPolicy] = None,
                   deadline: Optional[float] = None,
                   min_coverage: float = 0.0,
                   expected_counts: Optional[Mapping[int, float]] = None,
                   verify: bool = False,
                   max_workers: Optional[int] = None) -> PartialAggregate:
    """Gather per-shard ingest states with retries and a straggler cutoff,
    then partial-aggregate whatever arrived.

    ``jobs`` maps shard id → zero-arg callable returning a
    ``stream.IngestState`` built with SHARED hash params (the paper's
    same-hash-functions contract — ``stream.merge_states`` is only linear
    under it), or, with ``verify=True``, an ``(state, digest)`` pair
    where ``digest = stream.state_digest(state)`` was computed at the
    source; a mismatch on arrival is bit rot in transit and retries.

    ``deadline`` (seconds, global): shards still outstanding when it
    expires are abandoned as stragglers and treated as lost.
    ``expected_counts`` (shard → expected mass) sharpens coverage and the
    widened bound; without it a lost shard's mass is estimated as the
    mean observed shard mass (exchangeable-shard assumption).
    ``min_coverage`` in [0, 1]: below it — including the zero-survivor
    case — a :class:`CoverageError` is raised instead of degrading."""
    from repro.core import stream as stream_mod

    if not 0.0 <= min_coverage <= 1.0:
        raise ValueError(f"min_coverage must be in [0, 1], "
                         f"got {min_coverage}")
    policy = policy or RetryPolicy()

    def checker(out):
        if not verify:
            return
        if not (isinstance(out, tuple) and len(out) == 2):
            raise IntegrityError(
                "verify=True expects jobs to return (state, digest); "
                f"got {type(out).__name__}")
        state, digest = out
        got = stream_mod.state_digest(state)
        if int(got) != int(digest):
            raise IntegrityError(
                f"state digest mismatch: got {got:#010x}, "
                f"expected {int(digest):#010x}")

    def run_one(shard: int, fn: Callable[[], object]):
        """Full retry loop for one shard — never raises; the verdict
        travels in the returned ShardStatus."""
        t0 = time.monotonic()
        laps: List[float] = []

        def lap(_attempt, secs, _exc):
            laps.append(secs)

        try:
            out, attempts = call_with_retry(fn, policy, seed=shard,
                                            check=checker, on_attempt=lap)
            state = out[0] if verify else out
            return state, ShardStatus(shard=shard, ok=True,
                                      attempts=attempts,
                                      seconds=time.monotonic() - t0,
                                      error=None,
                                      attempt_seconds=tuple(laps))
        except RetryError as e:
            return None, ShardStatus(shard=shard, ok=False,
                                     attempts=policy.max_attempts,
                                     seconds=time.monotonic() - t0,
                                     error=str(e),
                                     attempt_seconds=tuple(laps))
        except Exception as e:                           # noqa: BLE001
            # non-retryable (policy deny-list): failed on the attempt
            # that raised — record it and degrade like any lost shard
            return None, ShardStatus(shard=shard, ok=False,
                                     attempts=len(laps),
                                     seconds=time.monotonic() - t0,
                                     error=f"non-retryable "
                                           f"{type(e).__name__}: {e}",
                                     attempt_seconds=tuple(laps))

    start = time.monotonic()
    shards = list(jobs)
    ex = ThreadPoolExecutor(max_workers=max_workers
                            or min(32, max(1, len(shards))))
    futs: Dict[Future, int] = {
        ex.submit(run_one, s, jobs[s]): s for s in shards}
    try:
        remaining = None if deadline is None \
            else max(0.0, deadline - (time.monotonic() - start))
        done, pending = wait(futs, timeout=remaining)
    finally:
        # do NOT wait: abandoned straggler threads may still be sleeping
        # inside injected delays — the whole point of the cutoff
        ex.shutdown(wait=False, cancel_futures=True)

    states: Dict[int, object] = {}
    statuses: Dict[int, ShardStatus] = {}
    for fut in done:
        state, st = fut.result()
        statuses[st.shard] = st
        if st.ok:
            states[st.shard] = state
    for fut in pending:
        s = futs[fut]
        statuses[s] = ShardStatus(shard=s, ok=False, attempts=0,
                                  seconds=time.monotonic() - start,
                                  error="deadline")
    ordered = [statuses[s] for s in shards]
    lost = tuple(s for s in shards if not statuses[s].ok)
    retries = sum(max(0, st.attempts - 1) for st in ordered)

    if not states:
        raise CoverageError(
            "no shard delivered a sketch — nothing to aggregate; "
            + "; ".join(f"shard {st.shard}: {st.error}" for st in ordered))

    merged = None
    observed = 0.0
    survivor_bound = 0.0
    for s in shards:
        if s not in states:
            continue
        st = states[s]
        observed += float(st.count)
        survivor_bound = max(survivor_bound, float(st.evict_max))
        merged = st if merged is None else stream_mod.merge_states(merged, st)

    n_ok = len(states)
    if expected_counts is not None:
        lost_mass = sum(float(expected_counts[s]) for s in lost)
    else:
        lost_mass = len(lost) * (observed / n_ok)
    expected = observed + lost_mass
    coverage = observed / expected if expected > 0 else 1.0

    agg = PartialAggregate(
        state=merged, observed_count=observed, expected_count=expected,
        coverage=coverage, lost_mass=lost_mass,
        hh_error_bound=widened_bound(survivor_bound, lost_mass),
        statuses=ordered, lost=lost, retries=retries)
    if coverage < min_coverage:
        raise CoverageError(
            f"coverage {coverage:.3f} below min_coverage "
            f"{min_coverage:.3f} (lost shards: {list(lost)}; "
            + "; ".join(f"shard {st.shard}: {st.error}"
                        for st in ordered if not st.ok) + ")")
    return agg
