"""Strongly-universal hash families over 64-bit keys, uint32-limb only.

We use Thorup's *vector multiply-shift* scheme: for a 64-bit key split into
two 32-bit words (x_hi, x_lo) and independent uniform 64-bit parameters
(a1, a2, b),

    h(x) = (a1 * x_hi  +  a2 * x_lo  +  b)  >> (64 - l)      in [0, 2**l)

is strongly 2-universal.  The sign hash is the same family with l = 1,
mapped to {-1, +1}.  All arithmetic is mod 2**64 via :mod:`repro.core.u64`,
so the construction runs unchanged inside Pallas TPU kernels.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import u64


class MulShiftParams(NamedTuple):
    """Parameters for a batch of R independent vector multiply-shift hashes.

    Each field has shape (R,), dtype uint32.  (a1, a2, b) are 64-bit values
    held as hi/lo limb pairs.
    """
    a1_hi: jnp.ndarray
    a1_lo: jnp.ndarray
    a2_hi: jnp.ndarray
    a2_lo: jnp.ndarray
    b_hi: jnp.ndarray
    b_lo: jnp.ndarray

    @property
    def rows(self) -> int:
        return self.a1_hi.shape[0]


def make_params(key: jax.Array, rows: int) -> MulShiftParams:
    """Draw R independent hash functions' parameters."""
    bits = jax.random.bits(key, (6, rows), dtype=jnp.uint32)
    return MulShiftParams(*[bits[i] for i in range(6)])


def _accumulate(params: MulShiftParams, key_hi: jnp.ndarray,
                key_lo: jnp.ndarray) -> u64.U64:
    """(a1*x_hi + a2*x_lo + b) mod 2**64, broadcast (R, 1) x (items,) -> (R, items)."""
    a1 = (params.a1_hi[:, None], params.a1_lo[:, None])
    a2 = (params.a2_hi[:, None], params.a2_lo[:, None])
    b = (params.b_hi[:, None], params.b_lo[:, None])
    t1 = u64.mul_u32(a1, key_hi[None, :])
    t2 = u64.mul_u32(a2, key_lo[None, :])
    acc = u64.add(t1, t2)
    # broadcast b against acc
    acc = u64.add(acc, (jnp.broadcast_to(b[0], acc[0].shape),
                        jnp.broadcast_to(b[1], acc[1].shape)))
    return acc


def bucket_hash(params: MulShiftParams, key_hi: jnp.ndarray,
                key_lo: jnp.ndarray, log2_buckets: int) -> jnp.ndarray:
    """Hash (items,) 64-bit keys into (R, items) buckets in [0, 2**l)."""
    if not (1 <= log2_buckets <= 32):
        raise ValueError(f"log2_buckets must be in [1, 32], got {log2_buckets}")
    acc = _accumulate(params, key_hi, key_lo)
    hi, _ = acc
    return hi >> (32 - log2_buckets) if log2_buckets < 32 else hi


def sign_hash(params: MulShiftParams, key_hi: jnp.ndarray,
              key_lo: jnp.ndarray) -> jnp.ndarray:
    """Hash (items,) keys into (R, items) signs in {-1, +1} (int32)."""
    acc = _accumulate(params, key_hi, key_lo)
    bit = (acc[0] >> 31).astype(jnp.int32)
    return 1 - 2 * bit


def fold_u64_to_u32(key_hi: jnp.ndarray, key_lo: jnp.ndarray) -> jnp.ndarray:
    """Cheap 64->32 bit fold (murmur-style finalizer), for partitioning."""
    x = key_hi ^ (key_lo * np.uint32(0x9E3779B9))
    x ^= x >> 16
    x *= np.uint32(0x85EBCA6B)
    x ^= x >> 13
    x *= np.uint32(0xC2B2AE35)
    x ^= x >> 16
    return x
