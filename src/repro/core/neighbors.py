"""Shared neighbor-graph machinery for the downstream embedders.

Both embedders need the kNN graph of the (weighted) heavy-hitter
representatives — UMAP to build its fuzzy simplicial set, and the sparse
tSNE backend to restrict perplexity calibration and attraction to the
kNN support.  :func:`knn_graph` is the single entry point and picks the
build with ``method=``:

* ``"exact"`` — the O(N²·D) brute-force pass, streamed in row blocks so
  peak memory stays O(block · N);
* ``"ann"``   — the sub-quadratic approximate engine in
  :mod:`repro.core.ann` (multi-probe grid-cell bucketing + NN-descent
  refinement, recall ≥ 0.9 vs exact on blob data);
* ``"auto"``  — exact below ``AnnConfig.auto_threshold`` points, ann
  above (the default everywhere a config plumbs through).

With the ann path the embed stage has no O(N²) pass left anywhere.

Also hosts :func:`reverse_edge_values` — value of each directed edge's
reverse (0 if absent), via one sort + binary search (E log E, no (N, N)
temp).  The sorted-COO reduction machinery the sparse consumers build on
(``dedupe_edges``, ``row_bounds``, ``segment_reduce``, ``edge_layout``)
lives in :mod:`repro.core.coo`; ``dedupe_edges``/``row_bounds`` are
re-exported here for the PR-4 import surface.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import mesh as mesh_mod
from repro.core.coo import dedupe_edges, row_bounds  # noqa: F401 (re-export)
from repro.core.tsne import pairwise_sq_dists

# reverse_edge_values packs edge (i, j) into the scalar i·n + j.  The max
# key is (n−1)·n + (n−1) = n² − 1, so the packed uint32 path is valid iff
# n² ≤ 2³², i.e. n ≤ ⌊√2³²⌋ = 2¹⁶ — derived here once; the boundary is
# regression-tested at N = 2¹⁶ and 2¹⁶ + 1 (tests/test_ann.py).
PACKED_KEY_N_MAX = 1 << 16
assert PACKED_KEY_N_MAX ** 2 - 1 <= 2 ** 32 - 1
assert (PACKED_KEY_N_MAX + 1) ** 2 - 1 > 2 ** 32 - 1


def _knn_rows(x_rows: jnp.ndarray, row_ids: jnp.ndarray, x: jnp.ndarray,
              k: int, block: Optional[int]
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """kNN of ``x_rows`` (carrying global ``row_ids``) against the full
    ``x`` — the per-row-block body shared by the single-device and the
    shard_map paths.  Streams ``block``-row distance chunks so peak memory
    is O(block · N); self-pairs (row id == column id) are excluded."""
    m, n = x_rows.shape[0], x.shape[0]
    col_ids = jnp.arange(n)

    def rows(xc, idc):
        d = pairwise_sq_dists(xc, x)                       # (B, N)
        d = jnp.where(idc[:, None] == col_ids[None, :], jnp.inf, d)
        neg_top, idx = jax.lax.top_k(-d, k)
        return idx, jnp.sqrt(jnp.maximum(-neg_top, 0.0))

    if block is None or block >= m:
        return rows(x_rows, row_ids)
    pad = (-m) % block
    if pad:
        x_rows = jnp.pad(x_rows, [(0, pad), (0, 0)])
        row_ids = jnp.pad(row_ids, [(0, pad)], constant_values=-1)
    nb = x_rows.shape[0] // block
    idx, dist = jax.lax.map(
        lambda args: rows(*args),
        (x_rows.reshape(nb, block, -1), row_ids.reshape(nb, block)))
    return idx.reshape(-1, k)[:m], dist.reshape(-1, k)[:m]


def knn_graph(x: jnp.ndarray, k: int, *, block: Optional[int] = None,
              mesh=None, method: str = "exact", ann=None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """kNN graph (excluding self): returns (indices (N,k), dists (N,k)).

    ``k`` is clamped to N−1 (a point has at most N−1 neighbors).
    ``method`` picks the build:

    * ``"exact"`` (default) — brute force.  With ``block`` set (and < N)
      the distance matrix is streamed in row chunks of that size — peak
      memory O(block · N), never (N, N).
    * ``"ann"`` — the sub-quadratic approximate engine
      (:func:`repro.core.ann.ann_knn_graph`); ``ann`` is an optional
      ``AnnConfig`` with the recall/probe knobs.
    * ``"auto"`` — ``"exact"`` for N ≤ ``AnnConfig.auto_threshold``,
      ``"ann"`` above it.

    With ``mesh`` (a 1-D embed mesh, see ``core.mesh``) the build is
    row-block sharded under ``shard_map``: each device owns a contiguous
    padded row range, computes its distance blocks against the replicated
    ``x`` (embarrassingly parallel), and k-merges locally via ``top_k`` —
    the per-row results are identical to the single-device path for both
    methods (tests/test_mesh_embed.py).
    """
    n = x.shape[0]
    k = min(int(k), max(n - 1, 1))
    if method not in ("exact", "auto", "ann"):
        raise ValueError(f"unknown kNN method: {method!r}")
    if method != "exact":
        from repro.core import ann as ann_mod  # lazy: avoid import cycle
        cfg = ann if ann is not None else ann_mod.AnnConfig()
        if method == "ann" or n > cfg.auto_threshold:
            return ann_mod.ann_knn_graph(x, k, cfg, mesh=mesh)
    if mesh is None:
        if block is None or block >= n:
            d = pairwise_sq_dists(x)
            d = d.at[jnp.arange(n), jnp.arange(n)].set(jnp.inf)
            neg_top, idx = jax.lax.top_k(-d, k)
            return idx, jnp.sqrt(jnp.maximum(-neg_top, 0.0))
        return _knn_rows(x, jnp.arange(n), x, k, block)

    axis = mesh_mod.mesh_axis(mesh)
    s = mesh_mod.axis_size(mesh, axis)
    rows_per, n_pad = mesh_mod.row_block(n, s)
    xp = jnp.pad(x, [(0, n_pad - n), (0, 0)]) if n_pad > n else x
    # padded rows carry id -1: never equal to a column id, and their junk
    # kNN rows are sliced off below
    ids = jnp.where(jnp.arange(n_pad) < n, jnp.arange(n_pad), -1)
    P = mesh_mod.P

    @mesh_mod.shard_map_compat(mesh=mesh, in_specs=(P(axis), P(axis), P()),
                               out_specs=(P(axis), P(axis)))
    def spmd(x_blk, id_blk, x_full):
        b = None if block is None else min(block, rows_per)
        return _knn_rows(x_blk, id_blk, x_full, k, b)

    idx, dist = spmd(xp, ids, x)
    return idx[:n], dist[:n]


def knn_query(q: jnp.ndarray, x: jnp.ndarray, k: int, *,
              block: Optional[int] = None, method: str = "exact",
              ann=None, corpus_graph: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Asymmetric kNN: k nearest rows of the frozen corpus ``x`` (N, D)
    for each query in ``q`` (Q, D) — the out-of-sample ``transform()``
    regime.  Returns (indices (Q, k) into x, euclidean dists (Q, k)).

    Unlike :func:`knn_graph` there is NO self-exclusion: a query identical
    to a corpus row returns that row at distance 0, so ``k`` clamps to N
    (not N−1).  ``method``/``ann`` mirror :func:`knn_graph`; the exact
    path streams ``block``-query chunks through the same row machinery
    (peak O(block · N)).  ``corpus_graph`` (optional corpus kNN indices)
    feeds the ann path's expansion round for a recall lift.
    """
    n = x.shape[0]
    k = min(int(k), max(n, 1))
    if method not in ("exact", "auto", "ann"):
        raise ValueError(f"unknown kNN method: {method!r}")
    if method != "exact":
        from repro.core import ann as ann_mod  # lazy: avoid import cycle
        cfg = ann if ann is not None else ann_mod.AnnConfig()
        if method == "ann" or n > cfg.auto_threshold:
            return ann_mod.ann_knn_query(q, x, k, cfg,
                                         corpus_graph=corpus_graph)
    # query ids of -1 never equal a column id >= 0 -> no exclusion
    qids = jnp.full((q.shape[0],), -1, jnp.int32)
    return _knn_rows(q, qids, x, k, block)


def reverse_edge_values(knn_idx: jnp.ndarray, vals_nk: jnp.ndarray,
                        rows: jnp.ndarray, cols: jnp.ndarray,
                        vals: jnp.ndarray, n: int) -> jnp.ndarray:
    """Value of each directed edge's reverse (0 if absent) — sparse.

    Sort-based: pack each edge (i, j) into a scalar key, sort once, and
    binary-search every reverse key (j, i).  E log E work, O(E) memory —
    no (N, N) temp.  Keys fit uint32 iff n² ≤ 2³², i.e. N ≤
    ``PACKED_KEY_N_MAX`` (= 2¹⁶, derived at module top); beyond that we
    fall back to a gather: the reverse of (i, j) can only live in j's
    kNN row, so compare knn_idx[j] against i (E·k work, still sparse).
    """
    e = rows.shape[0]
    if n <= PACKED_KEY_N_MAX:
        n32 = jnp.uint32(n)
        fwd = rows.astype(jnp.uint32) * n32 + cols.astype(jnp.uint32)
        rev = cols.astype(jnp.uint32) * n32 + rows.astype(jnp.uint32)
        order = jnp.argsort(fwd)
        sorted_keys = fwd[order]
        sorted_vals = vals[order]
        pos = jnp.minimum(jnp.searchsorted(sorted_keys, rev), e - 1)
        hit = sorted_keys[pos] == rev
        return jnp.where(hit, sorted_vals[pos], 0.0)
    rev_rows = knn_idx[cols]                               # (E, k)
    rev_vals = vals_nk[cols]                               # (E, k)
    match = rev_rows == rows[:, None]
    return jnp.sum(jnp.where(match, rev_vals, 0.0), axis=1)


