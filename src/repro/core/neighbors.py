"""Shared neighbor-graph machinery for the downstream embedders.

Both embedders need the exact kNN graph of the (weighted) heavy-hitter
representatives — UMAP to build its fuzzy simplicial set, and the sparse
tSNE backend to restrict perplexity calibration and attraction to the
kNN support.  The graph build is the only remaining O(N²·D) pass in the
sub-quadratic embed stage, and it runs *once* at setup, streamed in row
blocks so peak memory stays O(block · N).

Also hosts :func:`reverse_edge_values` — value of each directed edge's
reverse (0 if absent), via one sort + binary search (E log E, no (N, N)
temp).  The sorted-COO reduction machinery the sparse consumers build on
(``dedupe_edges``, ``row_bounds``, ``segment_reduce``, ``edge_layout``)
lives in :mod:`repro.core.coo`; ``dedupe_edges``/``row_bounds`` are
re-exported here for the PR-4 import surface.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.coo import dedupe_edges, row_bounds  # noqa: F401 (re-export)
from repro.core.tsne import pairwise_sq_dists


def knn_graph(x: jnp.ndarray, k: int, *, block: Optional[int] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact kNN (excluding self): returns (indices (N,k), dists (N,k)).

    With ``block`` set (and < N) the distance matrix is streamed in row
    chunks of that size — peak memory O(block · N), never (N, N).
    """
    n = x.shape[0]
    if block is None or block >= n:
        d = pairwise_sq_dists(x)
        d = d.at[jnp.arange(n), jnp.arange(n)].set(jnp.inf)
        neg_top, idx = jax.lax.top_k(-d, k)
        return idx, jnp.sqrt(jnp.maximum(-neg_top, 0.0))

    pad = (-n) % block
    xp = jnp.pad(x, [(0, pad), (0, 0)]) if pad else x
    nb = xp.shape[0] // block
    row_ids = jnp.arange(xp.shape[0])
    col_ids = jnp.arange(n)

    def chunk(args):
        xc, idc = args
        d = pairwise_sq_dists(xc, x)                       # (B, N)
        d = jnp.where(idc[:, None] == col_ids[None, :], jnp.inf, d)
        neg_top, idx = jax.lax.top_k(-d, k)
        return idx, jnp.sqrt(jnp.maximum(-neg_top, 0.0))

    idx, dist = jax.lax.map(
        chunk, (xp.reshape(nb, block, -1), row_ids.reshape(nb, block)))
    return idx.reshape(-1, k)[:n], dist.reshape(-1, k)[:n]


def reverse_edge_values(knn_idx: jnp.ndarray, vals_nk: jnp.ndarray,
                        rows: jnp.ndarray, cols: jnp.ndarray,
                        vals: jnp.ndarray, n: int) -> jnp.ndarray:
    """Value of each directed edge's reverse (0 if absent) — sparse.

    Sort-based: pack each edge (i, j) into a scalar key, sort once, and
    binary-search every reverse key (j, i).  E log E work, O(E) memory —
    no (N, N) temp.  Keys fit uint32 iff N ≤ 2¹⁶; beyond that we fall back
    to a gather: the reverse of (i, j) can only live in j's kNN row, so
    compare knn_idx[j] against i (E·k work, still sparse).
    """
    e = rows.shape[0]
    if n <= (1 << 16):
        n32 = jnp.uint32(n)
        fwd = rows.astype(jnp.uint32) * n32 + cols.astype(jnp.uint32)
        rev = cols.astype(jnp.uint32) * n32 + rows.astype(jnp.uint32)
        order = jnp.argsort(fwd)
        sorted_keys = fwd[order]
        sorted_vals = vals[order]
        pos = jnp.minimum(jnp.searchsorted(sorted_keys, rev), e - 1)
        hit = sorted_keys[pos] == rev
        return jnp.where(hit, sorted_vals[pos], 0.0)
    rev_rows = knn_idx[cols]                               # (E, k)
    rev_vals = vals_nk[cols]                               # (E, k)
    match = rev_rows == rows[:, None]
    return jnp.sum(jnp.where(match, rev_vals, 0.0), axis=1)


