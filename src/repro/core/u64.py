"""64-bit unsigned arithmetic as uint32 limb pairs.

TPUs have no 64-bit integer datapath, so every 64-bit quantity in this
codebase (cell keys, hash parameters, hash accumulators) is carried as a
pair of uint32 arrays ``(hi, lo)``.  All ops are modular (mod 2**64), match
numpy uint64 semantics, and are safe inside both ``jax.jit`` and Pallas
kernel bodies (uint32 mul/add/xor/shift are native VPU ops).

A U64 value is just a ``(hi, lo)`` tuple of equal-shaped uint32 arrays.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

U64 = Tuple[jnp.ndarray, jnp.ndarray]  # (hi, lo), both uint32

_U32 = jnp.uint32
_MASK16 = np.uint32(0xFFFF)


def u64(hi, lo) -> U64:
    return jnp.asarray(hi, _U32), jnp.asarray(lo, _U32)


def from_u32(x) -> U64:
    x = jnp.asarray(x, _U32)
    return jnp.zeros_like(x), x


def from_py(value: int, shape=()) -> U64:
    """Constant U64 from a python int (host side)."""
    value = int(value) & 0xFFFFFFFFFFFFFFFF
    hi = np.full(shape, value >> 32, np.uint32)
    lo = np.full(shape, value & 0xFFFFFFFF, np.uint32)
    return jnp.asarray(hi), jnp.asarray(lo)


def to_py(x: U64) -> np.ndarray:
    """Host-side: U64 -> numpy uint64 (for tests / IO only)."""
    hi = np.asarray(x[0], np.uint64)
    lo = np.asarray(x[1], np.uint64)
    return (hi << np.uint64(32)) | lo


def add(a: U64, b: U64) -> U64:
    lo = a[1] + b[1]
    carry = (lo < a[1]).astype(_U32)
    hi = a[0] + b[0] + carry
    return hi, lo


def add_u32(a: U64, x) -> U64:
    x = jnp.asarray(x, _U32)
    lo = a[1] + x
    carry = (lo < a[1]).astype(_U32)
    return a[0] + carry, lo


def xor(a: U64, b: U64) -> U64:
    return a[0] ^ b[0], a[1] ^ b[1]


def umul32_full(x, y) -> U64:
    """Full 64-bit product of two uint32 values, via 16-bit limbs.

    Every intermediate fits in uint32:  xh*yl <= (2^16-1)^2 < 2^32, and the
    added carry is < 2^16.
    """
    x = jnp.asarray(x, _U32)
    y = jnp.asarray(y, _U32)
    xl, xh = x & _MASK16, x >> 16
    yl, yh = y & _MASK16, y >> 16
    t = xl * yl
    w0 = t & _MASK16
    k = t >> 16
    t = xh * yl + k
    w1 = t & _MASK16
    w2 = t >> 16
    t = xl * yh + w1
    k = t >> 16
    lo = (t << 16) | w0
    hi = xh * yh + w2 + k
    return hi, lo


def mul_u32(a: U64, x) -> U64:
    """(64-bit a) * (32-bit x) mod 2**64."""
    x = jnp.asarray(x, _U32)
    hi1, lo1 = umul32_full(a[1], x)   # a.lo * x  -> contributes to both limbs
    hi = hi1 + a[0] * x               # a.hi * x  -> only low 32 bits survive
    return hi, lo1


def shr(a: U64, s: int) -> U64:
    """Logical right shift by a *static* amount s in [0, 64)."""
    s = int(s)
    if s == 0:
        return a
    if s < 32:
        lo = (a[1] >> s) | (a[0] << (32 - s))
        hi = a[0] >> s
        return hi, lo
    if s == 32:
        return jnp.zeros_like(a[0]), a[0]
    return jnp.zeros_like(a[0]), a[0] >> (s - 32)


def shl(a: U64, s: int) -> U64:
    """Left shift by a *static* amount s in [0, 64)."""
    s = int(s)
    if s == 0:
        return a
    if s < 32:
        hi = (a[0] << s) | (a[1] >> (32 - s))
        lo = a[1] << s
        return hi, lo
    if s == 32:
        return a[1], jnp.zeros_like(a[1])
    return a[1] << (s - 32), jnp.zeros_like(a[1])


def bitand_u32(a: U64, mask) -> jnp.ndarray:
    """Low-word AND (for extracting packed fields that fit in 32 bits)."""
    return a[1] & jnp.asarray(mask, _U32)


def eq(a: U64, b: U64) -> jnp.ndarray:
    return (a[0] == b[0]) & (a[1] == b[1])


def less(a: U64, b: U64) -> jnp.ndarray:
    return (a[0] < b[0]) | ((a[0] == b[0]) & (a[1] < b[1]))


def searchsorted(keys: U64, queries: U64) -> jnp.ndarray:
    """Left insertion index of each query into lexicographically sorted keys.

    ``jnp.searchsorted`` needs a single comparable dtype, which two-limb
    keys do not have (and uint64 is unavailable without x64), so this is
    the bisection spelled out over ``less``: a fixed ⌈log₂ L⌉+1 iteration
    count makes it jit-compatible.  ``keys`` must be sorted ascending by
    (hi, lo); returns int32 positions in [0, L], matching
    ``np.searchsorted(side="left")`` on the packed 64-bit values.
    """
    n = int(keys[0].shape[0])
    iters = max(1, n).bit_length() + 1    # halve [0, L] to a point, +1 slack
    lo = jnp.zeros(queries[0].shape, jnp.int32)
    hi = jnp.full(queries[0].shape, n, jnp.int32)

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) >> 1
        mid_c = jnp.minimum(mid, max(n - 1, 0))
        k = (keys[0][mid_c], keys[1][mid_c])
        go_right = less(k, queries)
        return jnp.where(go_right, mid + 1, lo), jnp.where(go_right, hi, mid)

    if n == 0:
        return lo
    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo
