"""Shared device-mesh plumbing for every shard_map stage.

The ingest stage has run inside ``shard_map`` since PR 2 (``core.geo``:
per-device sketching with hierarchical ``psum`` merge); the embed stage
joined it in this PR (``core.tsne``/``core.umap``: row-block-sharded
iteration loops).  This module hoists the pieces both sides need so no
stage carries its own copy:

* :func:`shard_map_compat` — ``jax.shard_map`` across the API move
  (``check_vma`` vs the older ``jax.experimental.shard_map.check_rep``);
* :func:`make_embed_mesh` / :func:`resolve_mesh` — build or normalize the
  1-D embed mesh ``SnsConfig.embed_mesh`` names (``None`` | device count |
  a ready ``Mesh``);
* :func:`linear_index` — the traced linear shard id inside a shard_map
  body (the idiom ``geo.geo_extract_from_shards`` open-coded);
* :func:`axis_size` / :func:`row_block` — static sizing helpers for
  row-block sharding: each device owns a contiguous, equal-size (padded)
  row range, the layout every sharded embed reduction builds on.

Collective contract of the sharded embed stage (enforced by jaxpr
regressions in tests/test_mesh_embed.py): per-device bodies communicate
ONLY through ``psum`` of fixed-size partials (the CIC grid, dst-side
per-block reductions, KL terms) and ``all_gather`` of the row-block
positions — no cross-device scatter anywhere, mirroring the paper's
"only fixed-size summaries move" discipline at the embed layer.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P  # noqa: F401 (re-export)

# the 1-D mesh axis the sharded embed stage runs over
EMBED_AXIS = "embed"


def shard_map_compat(*, mesh, in_specs, out_specs):
    """Decorator: ``jax.shard_map`` with replication checks off, across the
    API move (new ``jax.shard_map(check_vma=)`` vs the older
    ``jax.experimental.shard_map.shard_map(check_rep=)``)."""
    if hasattr(jax, "shard_map"):
        return functools.partial(jax.shard_map, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return functools.partial(_sm, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)


def make_embed_mesh(n_devices: Optional[int] = None,
                    axis: str = EMBED_AXIS) -> Mesh:
    """A 1-D mesh over the first ``n_devices`` local devices (all by
    default) — the topology the row-block-sharded embed stage runs on."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"embed mesh wants {n} devices; {len(devs)} available")
    return Mesh(np.asarray(devs[:n]), (axis,))


def resolve_mesh(spec: Union[None, int, Mesh],
                 axis: str = EMBED_AXIS) -> Optional[Mesh]:
    """Normalize ``SnsConfig.embed_mesh``: ``None`` stays single-device, an
    int builds a fresh 1-D mesh of that many devices, a ``Mesh`` passes
    through as-is (its first axis is the embed axis)."""
    if spec is None:
        return None
    if isinstance(spec, Mesh):
        return spec
    if isinstance(spec, int):
        return make_embed_mesh(spec, axis=axis)
    raise TypeError(
        f"embed_mesh must be None, a device count, or a Mesh; got {spec!r}")


def mesh_axis(mesh: Mesh) -> str:
    """The (single) axis name of a 1-D embed mesh."""
    return mesh.axis_names[0]


def axis_size(mesh: Mesh, axes: Union[str, Sequence[str]]) -> int:
    """Total device count along one axis or a sequence of axes."""
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def linear_index(mesh: Mesh, axes: Union[str, Sequence[str]]) -> jnp.ndarray:
    """Traced linear shard id inside a ``shard_map`` body, row-major over
    ``axes`` (the idiom previously open-coded in
    ``geo.geo_extract_from_shards``)."""
    if isinstance(axes, str):
        axes = (axes,)
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def row_block(n: int, n_shards: int) -> Tuple[int, int]:
    """Row-block sizing for sharding ``n`` rows over ``n_shards`` devices:
    returns (rows_per_shard, n_padded) with ``n_padded = rows_per_shard ·
    n_shards ≥ n`` — device s owns global rows
    [s·rows_per_shard, (s+1)·rows_per_shard), the tail rows are padding."""
    rows_per = -(-n // n_shards)
    return rows_per, rows_per * n_shards
