"""Count Sketch (Charikar-Chen-Farach-Colton) as a JAX pytree.

The paper's four operations (§III-1): init / update / estimate / merge.
The sketch is a *linear operator* over the frequency vector — merging two
sketches built with the same hashes is element-wise addition of the tables.
That linearity is the entire geo-distributed story of the paper, and here
it is also what makes the TPU story work: ``merge == jax.lax.psum``.

Three update paths are provided:

* :func:`update` — XLA ``scatter-add`` per row (flattened to one scatter).
  Simple, always correct, and the gradient-compression path.
* :func:`update_runs` — THE bulk/streaming path: scatter of pre-deduped
  sorted key runs (``candidates.KeyRuns``).  The streaming ingest engine
  (``core.stream.ingest_step``) sorts + run-length-encodes each chunk
  exactly once via ``candidates.sorted_runs`` and feeds the same runs to
  this scatter AND to the reservoir merge — one sort per chunk total.
* :func:`update_sorted` — convenience wrapper: ``sorted_runs`` +
  ``update_runs`` for callers holding raw keys.  On TPU, ``sort`` is a
  native bitonic network and turns the random-access scatter into
  sequential memory traffic; preferred over :func:`update` when the number
  of items per call is ≫ the number of distinct cells (the paper's
  regime: 10⁸ points → 10⁵ cells) — but if a top-k/reservoir stage also
  needs the keys, build the runs once and use :func:`update_runs`.

All are exactly equivalent (tested).  The Pallas kernel in
``repro.kernels.sketch_update`` is a fused low-latency small-batch path.

Table dtype: float32 by default (exact integer counting up to 2²⁴ per
bucket per shard; shards hold ≪ 2²⁴ items per bucket in practice, and the
gradient-compression use-case needs floats).  Use int32 for exact counting
of huge single-shard streams.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, u64
from repro.core.candidates import KeyRuns, sorted_runs


class CountSketch(NamedTuple):
    """Sketch state.  A pytree: ``table`` + hash params; static geometry
    travels in the aux fields (python ints, hashable)."""
    table: jnp.ndarray                 # (R, C) float32/int32
    params: hashing.MulShiftParams     # R independent hash fns

    @property
    def rows(self) -> int:
        return self.table.shape[0]

    @property
    def cols(self) -> int:
        return self.table.shape[1]

    @property
    def log2_cols(self) -> int:
        return int(self.table.shape[1]).bit_length() - 1


def init(key: jax.Array, rows: int, log2_cols: int,
         dtype=jnp.float32) -> CountSketch:
    """``init(R, C)`` — zero table, R fresh hash functions, C = 2**log2_cols.

    Power-of-two columns so the bucket hash is a shift (no 64-bit modulo,
    which TPUs lack).  The paper's 2·10⁵ columns becomes 2¹⁸ = 262144.
    """
    if not (1 <= log2_cols <= 31):
        raise ValueError(f"log2_cols must be in [1, 31], got {log2_cols}")
    params = hashing.make_params(key, rows)
    table = jnp.zeros((rows, 1 << log2_cols), dtype)
    return CountSketch(table=table, params=params)


def _hashes(sk: CountSketch, key_hi: jnp.ndarray, key_lo: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(items,) keys -> bucket ids (R, items) uint32 and signs (R, items) int32."""
    buckets = hashing.bucket_hash(sk.params, key_hi, key_lo, sk.log2_cols)
    signs = hashing.sign_hash(sk.params, key_hi, key_lo)
    return buckets, signs


def update(sk: CountSketch, key_hi: jnp.ndarray, key_lo: jnp.ndarray,
           values: Optional[jnp.ndarray] = None,
           mask: Optional[jnp.ndarray] = None) -> CountSketch:
    """``update(s_i)`` for a batch of items: S[r, h1_r(i)] += h2_r(i)·v_i.

    ``values`` defaults to 1 (pure counting).  ``mask`` zeroes out padding
    items (static-shape streaming needs ragged tails).
    """
    items = key_hi.shape[0]
    buckets, signs = _hashes(sk, key_hi, key_lo)
    v = jnp.ones((items,), sk.table.dtype) if values is None \
        else values.astype(sk.table.dtype)
    if mask is not None:
        v = v * mask.astype(sk.table.dtype)
    upd = signs.astype(sk.table.dtype) * v[None, :]          # (R, items)
    # one scatter over the flattened (R*C) table
    flat_idx = (jnp.arange(sk.rows, dtype=jnp.uint32)[:, None]
                << np.uint32(sk.log2_cols)) | buckets
    flat = sk.table.reshape(-1).at[flat_idx.reshape(-1)].add(
        upd.reshape(-1), mode="drop")
    return sk._replace(table=flat.reshape(sk.table.shape))


def update_runs(sk: CountSketch, runs: KeyRuns) -> CountSketch:
    """Scatter pre-deduped sorted key runs into the table — the bulk path.

    ``runs`` comes from ``candidates.sorted_runs``; the caller pays that one
    sort and reuses the runs for the reservoir merge too (the fused ingest
    step).  Dead slots carry count 0, so they scatter nothing."""
    return update(sk, runs.key_hi, runs.key_lo, values=runs.count,
                  mask=runs.live)


def update_sorted(sk: CountSketch, key_hi: jnp.ndarray, key_lo: jnp.ndarray,
                  values: Optional[jnp.ndarray] = None,
                  mask: Optional[jnp.ndarray] = None) -> CountSketch:
    """Sort-based update from raw keys: aggregate duplicates, scatter once.

    ``sorted_runs`` (sort → segment boundaries → per-run summed value)
    + :func:`update_runs` (scatter of ``num_runs ≤ items`` deduped
    updates).  Equivalent to :func:`update`.
    """
    runs = sorted_runs(key_hi, key_lo, values=values, mask=mask,
                       dtype=sk.table.dtype)
    return update_runs(sk, runs)


def estimate(sk: CountSketch, key_hi: jnp.ndarray, key_lo: jnp.ndarray
             ) -> jnp.ndarray:
    """``estimate(i)``: median over rows of h2_r(i)·S[r, h1_r(i)].  (items,) float32."""
    buckets, signs = _hashes(sk, key_hi, key_lo)
    gathered = jnp.take_along_axis(
        sk.table, buckets.astype(jnp.int32), axis=1)          # (R, items)
    ests = gathered.astype(jnp.float32) * signs.astype(jnp.float32)
    return jnp.median(ests, axis=0)


def merge(a: CountSketch, b: CountSketch) -> CountSketch:
    """``merge(S1, S2) = S1 + S2``.  Hash params must match (checked by shape
    only inside jit; value equality is the caller's contract, as in the paper:
    'the hashing functions and the sketch matrix sizes must be the same')."""
    return a._replace(table=a.table + b.table)


def psum_merge(sk: CountSketch, axis_name) -> CountSketch:
    """Distributed merge across a mesh axis: the collective IS the algorithm.

    ``axis_name`` may be a single name or a tuple of names; with a tuple the
    reduction is hierarchical in the mesh ordering (ICI first, DCN second)."""
    return sk._replace(table=jax.lax.psum(sk.table, axis_name))


def l2_estimate(sk: CountSketch) -> jnp.ndarray:
    """AMS-style ℓ₂ estimate: median over rows of Σ_c S[r,c]² (paper §II-3)."""
    return jnp.sqrt(jnp.median(jnp.sum(
        sk.table.astype(jnp.float32) ** 2, axis=1)))


def tensor_sketch_update(sk: CountSketch, grad_flat: jnp.ndarray
                         ) -> CountSketch:
    """Sketch a dense vector (gradient compression): coordinate i of the
    vector is 'item i' with value grad[i].  Used by optim/sketch_compress."""
    n = grad_flat.shape[0]
    idx = jnp.arange(n, dtype=jnp.uint32)
    key_hi = jnp.zeros_like(idx)
    return update(sk, key_hi, idx, values=grad_flat)


def tensor_sketch_estimate(sk: CountSketch, n: int) -> jnp.ndarray:
    """Estimate all n coordinates of a sketched dense vector.  O(n·R) gather."""
    idx = jnp.arange(n, dtype=jnp.uint32)
    return estimate(sk, jnp.zeros_like(idx), idx)


@functools.partial(jax.jit, static_argnames=("k",))
def topk_from_candidates(sk: CountSketch, cand_hi: jnp.ndarray,
                         cand_lo: jnp.ndarray, k: int,
                         cand_mask: Optional[jnp.ndarray] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k heavy hitters among candidate keys, by sketch estimate.

    Deduplicates candidates (same key proposed by several shards), estimates
    each on the (merged) sketch, returns (hi, lo, est) of the k largest.
    Padding/invalid candidates are masked out with -inf.
    """
    m = cand_hi.shape[0]
    order = jnp.lexsort((cand_lo, cand_hi))
    shi, slo = cand_hi[order], cand_lo[order]
    is_first = jnp.concatenate([
        jnp.ones((1,), bool),
        (shi[1:] != shi[:-1]) | (slo[1:] != slo[:-1])])
    if cand_mask is not None:
        is_first &= cand_mask[order]
    est = estimate(sk, shi, slo)
    est = jnp.where(is_first, est, -jnp.inf)
    kk = min(k, m)                      # fewer candidates than k: pad
    top_est, top_idx = jax.lax.top_k(est, kk)
    hi_out, lo_out = shi[top_idx], slo[top_idx]
    if kk < k:
        pad = k - kk
        hi_out = jnp.concatenate(
            [hi_out, jnp.full((pad,), 0xFFFFFFFF, jnp.uint32)])
        lo_out = jnp.concatenate(
            [lo_out, jnp.full((pad,), 0xFFFFFFFF, jnp.uint32)])
        top_est = jnp.concatenate([top_est, jnp.full((pad,), -jnp.inf)])
    return hi_out, lo_out, top_est
