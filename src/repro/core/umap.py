"""Vanilla UMAP in pure JAX — the paper's second downstream embedder.

Faithful to McInnes-Healy-Melville 2018 (and the umap-learn reference):

* exact kNN graph (paper regime: N ≤ 2·10⁴ representatives, so brute-force
  pairwise distances on the MXU beat approximate NN),
* fuzzy simplicial set: per-point rho (distance to nearest neighbour) and
  sigma from binary search so Σ_j exp(−(d−rho)/sigma) = log₂(k),
* probabilistic t-conorm symmetrization  a ⊕ a' = a + a' − a∘a',
* (a, b) curve fit from (spread, min_dist) by least squares,
* SGD over the cross-entropy with negative sampling.

JAX adaptation: umap-learn's per-edge asynchronous SGD ("hogwild") is
host-sequential and shape-dynamic.  We instead run *epoch-batched* SGD:
each epoch applies the attractive gradient of every edge (weighted by the
fuzzy membership, equivalent in expectation to umap-learn's
sample-by-weight schedule) and `neg_rate` uniformly-sampled repulsive
pairs per edge — all static shapes, all fused by XLA.  This is the same
estimator, batched; convergence behaviour matches (tested on blobs).

The per-epoch reduction of E = N·k per-edge forces into per-point deltas
is *scatter-free*: at setup :func:`repro.core.coo.edge_layout` sorts the
edges by src (stable — the fuzzy-set edge list is already src-sorted, so
edge order and the per-edge RNG stream are unchanged), precomputes the
dst-sorted ordering plus the gather permutation between the two, and the
epoch body reduces each endpoint's contributions with one O(E) cumsum
differenced at the precomputed row bounds
(:func:`repro.core.coo.segment_reduce`) — the same machinery as the
sparse tSNE backend.  XLA's CPU scatter walks updates serially (~100×
slower at E ~ 10⁷), so replacing the two ``.at[].add`` scatters per epoch
is what lets ``embedder="umap"`` run at the same N = 10⁵–10⁶
representative counts as sparse tSNE
(benchmarks/bench_embed_throughput.py tracks epochs/sec against the
frozen scatter baseline; the epoch jaxpr is pinned scatter-free in
tests/test_umap_scatter_free.py).

Weighted extension (SnS): HH counts enter as per-point mass, scaling each
point's outgoing memberships — representatives of dense cells attract
proportionally more, mirroring the paper's replica weighting.

Mesh-parallel path (``run_umap(mesh=...)`` — ``None`` | device count |
1-D ``Mesh``, plumbing in :mod:`repro.core.mesh`): the SGD loop runs
inside ``shard_map`` with each device owning a contiguous row block of y
and the matching contiguous slice of the src-sorted edge list
(``coo.ShardedEdgeLayout``).  Per epoch: one ``all_gather`` of the block
positions, local src-side reduction, and ONE ``psum`` of the full-length
dst-side partials — zero scatter primitives of any kind (jaxpr-pinned in
tests/test_mesh_embed.py).  Negative samples are drawn as the full (E, R)
array from the replicated key and gathered per block, so the mesh run is
draw-for-draw aligned with the single-device stream.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coo
from repro.core import mesh as mesh_mod
from repro.core import neighbors
from repro.core.neighbors import knn_graph  # noqa: F401  (public re-export)


@dataclasses.dataclass(frozen=True)
class UmapConfig:
    dims: int = 2
    n_neighbors: int = 15
    min_dist: float = 0.1
    spread: float = 1.0
    n_epochs: int = 300
    learning_rate: float = 1.0
    neg_rate: int = 5
    init_scale: float = 10.0
    sigma_search_iters: int = 50
    block: int = 4096              # kNN row-block; N <= block -> dense path
    # kNN build: "exact" | "auto" | "ann" — "auto" switches to the
    # approximate engine (core.ann) above AnnConfig.auto_threshold
    # points; ``ann`` carries its knobs (an ann.AnnConfig)
    knn_method: str = "auto"
    ann: Optional[object] = None
    # kernel dispatch mode for the segment-reduce call sites (see
    # kernels.registry): "auto" keeps the cumsum path on CPU and the
    # fused kernel on accelerators; other values force one mode
    kernel_mode: str = "auto"


def _cfg_kernel_mode(cfg: UmapConfig) -> Optional[str]:
    """UmapConfig.kernel_mode -> the ``mode`` threaded to segment_reduce
    (None = defer to the registry's process-level resolution)."""
    return None if cfg.kernel_mode == "auto" else cfg.kernel_mode


@functools.lru_cache(maxsize=None)
def fit_ab(spread: float, min_dist: float) -> Tuple[float, float]:
    """Least-squares fit of 1/(1+a d^{2b}) to the target membership curve
    (host-side, same construction as umap-learn).  Cached per
    (spread, min_dist): the call happens at trace time inside the jitted
    ``optimize_embedding``, so every retrace (new static shape / cfg)
    would otherwise re-run the scipy ``curve_fit``."""
    from scipy.optimize import curve_fit
    xs = np.linspace(0, 3.0 * spread, 300)
    ys = np.where(xs < min_dist, 1.0, np.exp(-(xs - min_dist) / spread))

    def curve(x, a, b):
        return 1.0 / (1.0 + a * x ** (2 * b))

    (a, b), _ = curve_fit(curve, xs, ys, p0=(1.0, 1.0), maxfev=10_000)
    return float(a), float(b)


def fuzzy_simplicial_set(knn_idx: jnp.ndarray, knn_dist: jnp.ndarray,
                         weights: Optional[jnp.ndarray] = None,
                         search_iters: int = 50,
                         symmetrize: str = "sparse"
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Memberships on the kNN edges + symmetrized graph.

    Returns (edges (E,2) int32, membership (E,) float32) with E = N·k
    (each directed edge; symmetrization by the probabilistic t-conorm
    a ⊕ a' = a + a' − a·a').  ``symmetrize="sparse"`` (default) matches
    reverse edges by sorted-key binary search — no (N, N) temp;
    ``"dense"`` keeps the scatter-max reference path for small N."""
    n, k = knn_idx.shape
    rho = knn_dist[:, 0]
    target = jnp.log2(float(k))

    lo = jnp.full((n,), 1e-6)
    hi = jnp.full((n,), 1e6)

    def bisect(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        d = jnp.maximum(knn_dist - rho[:, None], 0.0)
        s = jnp.sum(jnp.exp(-d / mid[:, None]), axis=1)
        too_big = s > target
        return jnp.where(too_big, lo, mid), jnp.where(too_big, mid, hi)

    lo, hi = jax.lax.fori_loop(0, search_iters, bisect, (lo, hi))
    sigma = 0.5 * (lo + hi)
    memb = jnp.exp(-jnp.maximum(knn_dist - rho[:, None], 0.0)
                   / sigma[:, None])                          # (N, k)
    if weights is not None:
        w = weights / jnp.mean(weights)
        memb = jnp.minimum(memb * w[:, None], 1.0)

    rows = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    cols = knn_idx.reshape(-1).astype(jnp.int32)
    vals = memb.reshape(-1)
    if symmetrize == "sparse":
        rev = neighbors.reverse_edge_values(knn_idx, memb, rows, cols,
                                            vals, n)
        edge_vals = vals + rev - vals * rev
    elif symmetrize == "dense":
        # reference path: dense lookup of reverse membership via scatter-max
        dense = jnp.zeros((n, n)).at[rows, cols].max(vals)
        sym = dense + dense.T - dense * dense.T
        edge_vals = sym[rows, cols]
    else:
        raise ValueError(f"unknown symmetrize {symmetrize!r}")
    edges = jnp.stack([rows, cols], axis=1)
    return edges, edge_vals


class _OptState(NamedTuple):
    y: jnp.ndarray
    key: jax.Array


def epoch_delta(y: jnp.ndarray, layout: coo.EdgeLayout, memb_n: jnp.ndarray,
                kneg: jax.Array, a: float, b: float, neg_rate: int,
                mode: Optional[str] = None) -> jnp.ndarray:
    """One epoch's per-point SGD delta — the scatter-free epoch body.

    ``layout``/``memb_n`` come from the one-time setup (stable src-sort +
    dst permutation, memberships gathered into layout order).  Attraction
    and repulsion are computed per edge, then reduced into per-point
    deltas by two cumsum-difference segment reductions (src side carries
    attraction + negative samples, dst side the attraction reaction) —
    zero scatter primitives in the jaxpr.  Shared by the optimizer's
    ``fori_loop`` and the throughput bench, so what is timed is exactly
    what runs.
    """
    n = y.shape[0]
    e = layout.src.shape[0]
    src, dst = layout.src, layout.dst
    ys, yd = y[src], y[dst]
    d2 = jnp.sum((ys - yd) ** 2, axis=1)
    # attractive: dCE/dy = 2ab d^{2(b-1)} / (1 + a d^{2b}) * (ys - yd)
    grad_coef = (-2.0 * a * b * d2 ** (b - 1.0)
                 / (1.0 + a * d2 ** b))
    grad_coef = jnp.where(d2 > 0, grad_coef, 0.0)
    att = jnp.clip(grad_coef[:, None] * (ys - yd), -4.0, 4.0) \
        * memb_n[:, None]
    # repulsive: neg_rate uniform negatives per edge.  A draw can hit
    # the edge's own endpoints — repelling dst would fight the very
    # attraction this edge just applied (src is harmless: zero diff),
    # so those samples are masked out rather than resampled (keeps
    # shapes static; the tiny rate loss matches umap-learn's "skip
    # self" behaviour in expectation).
    neg = jax.random.randint(kneg, (e, neg_rate), 0, n)
    valid = (neg != src[:, None]) & (neg != dst[:, None])
    yn = y[neg]                                           # (E, R, dims)
    dn2 = jnp.sum((ys[:, None, :] - yn) ** 2, axis=2)
    rep_coef = (2.0 * b) / ((0.001 + dn2) * (1.0 + a * dn2 ** b))
    rep = jnp.clip(rep_coef[..., None] * (ys[:, None, :] - yn),
                   -4.0, 4.0) * memb_n[:, None, None]
    rep = jnp.where(valid[..., None], rep, 0.0)
    # scatter-free reduction: src side via the src-sorted bounds, dst
    # side (the attraction reaction, −att) via the precomputed gather
    # into dst-sorted order — two O(E) cumsum passes, no .at[].add
    return coo.segment_reduce(att + jnp.sum(rep, axis=1),
                              layout.src_bounds, mode=mode) \
        - coo.segment_reduce(att[layout.dst_order], layout.dst_bounds,
                             mode=mode)


@functools.partial(jax.jit, static_argnames=("cfg", "n"))
def _optimize_embedding_jit(key: jax.Array, edges: jnp.ndarray,
                            memb: jnp.ndarray, n: int, cfg: UmapConfig,
                            init: Optional[jnp.ndarray] = None
                            ) -> jnp.ndarray:
    """Single-device SGD loop (the reference path, fully jitted)."""
    a, b = fit_ab(cfg.spread, cfg.min_dist)
    kinit, kloop = jax.random.split(key)
    y0 = init if init is not None else \
        cfg.init_scale * jax.random.uniform(kinit, (n, cfg.dims)) - \
        cfg.init_scale / 2.0
    layout, order = coo.edge_layout(edges[:, 0], edges[:, 1], n)
    memb_n = (memb / jnp.maximum(jnp.max(memb), 1e-12))[order]

    def epoch(i, state):
        y, key = state
        key, kneg = jax.random.split(key)
        alpha = cfg.learning_rate * (1.0 - i / cfg.n_epochs)
        delta = epoch_delta(y, layout, memb_n, kneg, a, b, cfg.neg_rate,
                            mode=_cfg_kernel_mode(cfg))
        return _OptState(y + alpha * delta, key)

    state = jax.lax.fori_loop(0, cfg.n_epochs, epoch, _OptState(y0, kloop))
    return state.y


def epoch_delta_shard(y_blk: jnp.ndarray, y_full: jnp.ndarray,
                      lay: coo.ShardedEdgeLayout, memb_n: jnp.ndarray,
                      kneg: jax.Array, a: float, b: float, neg_rate: int,
                      n: int, e_total: int, axis: str,
                      mode: Optional[str] = None) -> jnp.ndarray:
    """One epoch's per-point delta for ONE device's row block — the
    shard_map body mirroring :func:`epoch_delta`.

    ``lay``/``memb_n`` are the device's squeezed (Ep,)-slices of the
    row-block layout (``coo.ShardedEdgeLayout``); ``y_full`` the
    all_gathered positions.  Negative samples are drawn as the FULL
    (E, neg_rate) array from the replicated ``kneg`` and gathered by
    ``lay.edge_ids`` — every edge sees bit-identical draws to the
    single-device stream, which is what makes the mesh run draw-for-draw
    reproducible (tests/test_mesh_embed.py).  The src-side reduction is
    local (blocks split at row boundaries); the dst-side attraction
    reaction reduces into a full-length per-block partial and crosses
    devices as ONE ``psum`` — no scatter anywhere.
    """
    src, dst = lay.src, lay.dst                          # global ids (Ep,)
    ys, yd = y_full[src], y_full[dst]
    d2 = jnp.sum((ys - yd) ** 2, axis=1)
    grad_coef = (-2.0 * a * b * d2 ** (b - 1.0)
                 / (1.0 + a * d2 ** b))
    grad_coef = jnp.where(d2 > 0, grad_coef, 0.0)
    att = jnp.clip(grad_coef[:, None] * (ys - yd), -4.0, 4.0) \
        * memb_n[:, None]                                # 0 on padded slots
    neg = jax.random.randint(kneg, (e_total, neg_rate), 0, n)[lay.edge_ids]
    valid = (neg != src[:, None]) & (neg != dst[:, None])
    yn = y_full[neg]                                     # (Ep, R, dims)
    dn2 = jnp.sum((ys[:, None, :] - yn) ** 2, axis=2)
    rep_coef = (2.0 * b) / ((0.001 + dn2) * (1.0 + a * dn2 ** b))
    rep = jnp.clip(rep_coef[..., None] * (ys[:, None, :] - yn),
                   -4.0, 4.0) * memb_n[:, None, None]
    rep = jnp.where(valid[..., None], rep, 0.0)
    src_red = coo.segment_reduce(att + jnp.sum(rep, axis=1),
                                 lay.src_bounds, mode=mode)  # (rows_per, dims)
    dst_part = coo.segment_reduce(att[lay.dst_order],
                                  lay.dst_bounds, mode=mode)  # (n_pad, dims)
    dst_tot = jax.lax.psum(dst_part, axis)               # THE dst exchange
    rows_per = lay.src_bounds.shape[0] - 1
    dst_blk = jax.lax.dynamic_slice_in_dim(dst_tot, lay.row_offset,
                                           rows_per, axis=0)
    return src_red - dst_blk


@functools.partial(jax.jit, static_argnames=("cfg", "n", "e_total", "mesh"))
def _optimize_embedding_mesh(key: jax.Array, slay: coo.ShardedEdgeLayout,
                             memb_s: jnp.ndarray,
                             init: Optional[jnp.ndarray], *, cfg: UmapConfig,
                             n: int, e_total: int, mesh) -> jnp.ndarray:
    """Mesh-parallel SGD loop: row blocks of y and contiguous edge slices
    stay on their devices across all epochs; per epoch one all_gather of
    the block positions + one psum of the dst-side partials."""
    a, b = fit_ab(cfg.spread, cfg.min_dist)
    axis = mesh_mod.mesh_axis(mesh)
    n_pad = slay.n_padded
    kinit, kloop = jax.random.split(key)
    if init is None:
        # identical draws to the single-device path, then padded tail rows
        y0 = cfg.init_scale * jax.random.uniform(kinit, (n, cfg.dims)) - \
            cfg.init_scale / 2.0
    else:
        y0 = init
    y0 = jnp.pad(y0, [(0, n_pad - n), (0, 0)])
    P = mesh_mod.P
    lay_specs = jax.tree_util.tree_map(lambda _: P(axis), slay)

    @mesh_mod.shard_map_compat(
        mesh=mesh, in_specs=(P(), lay_specs, P(axis), P(axis)),
        out_specs=P(axis))
    def spmd(key, slay, memb_s, y_blk):
        # (S, ...) leaves arrive as (1, ...) per device — drop the axis
        lay = jax.tree_util.tree_map(lambda x: x[0], slay)
        memb_loc = memb_s[0]

        def epoch(i, state):
            y_blk, key = state
            key, kneg = jax.random.split(key)
            alpha = cfg.learning_rate * (1.0 - i / cfg.n_epochs)
            y_full = jax.lax.all_gather(y_blk, axis, axis=0, tiled=True)
            delta = epoch_delta_shard(y_blk, y_full, lay, memb_loc, kneg,
                                      a, b, cfg.neg_rate, n, e_total, axis,
                                      mode=_cfg_kernel_mode(cfg))
            return _OptState(y_blk + alpha * delta, key)

        state = jax.lax.fori_loop(0, cfg.n_epochs, epoch,
                                  _OptState(y_blk, key))
        return state.y

    return spmd(kloop, slay, memb_s, y0)[:n]


def optimize_embedding(key: jax.Array, edges: jnp.ndarray,
                       memb: jnp.ndarray, n: int, cfg: UmapConfig,
                       init: Optional[jnp.ndarray] = None,
                       mesh=None) -> jnp.ndarray:
    """Epoch-batched SGD on the UMAP cross-entropy, scatter-free.

    Setup builds the bidirectional sorted-COO reduction plan once
    (:func:`repro.core.coo.edge_layout`); every epoch then runs
    :func:`epoch_delta` inside one jitted ``fori_loop`` with zero scatter
    primitives (jaxpr-pinned in tests/test_umap_scatter_free.py).

    With ``mesh`` (``None`` | device count | 1-D ``Mesh``, see
    ``core.mesh``) the loop runs row-block-sharded under ``shard_map``:
    the host slices the src-sorted edge list into per-block contiguous
    shards once (``coo.shard_edge_layout`` — concrete arrays, so this
    path needs ``edges``/``memb`` outside any trace), then every epoch is
    the same math with one all_gather + one psum; negative-sample draws
    stay bit-identical to the single-device stream.
    """
    mesh = mesh_mod.resolve_mesh(mesh)
    if mesh is None:
        return _optimize_embedding_jit(key, edges, memb, n, cfg, init)
    n_shards = mesh_mod.axis_size(mesh, mesh_mod.mesh_axis(mesh))
    # same stable layout order as the reference path, then host-side shard
    layout, order = coo.edge_layout(edges[:, 0], edges[:, 1], n)
    memb_n = (memb / jnp.maximum(jnp.max(memb), 1e-12))[order]
    slay = coo.shard_edge_layout(np.asarray(layout.src),
                                 np.asarray(layout.dst), n, n_shards)
    memb_s = coo.shard_payload(slay, memb_n)
    return _optimize_embedding_mesh(key, slay, memb_s, init, cfg=cfg, n=n,
                                    e_total=int(layout.src.shape[0]),
                                    mesh=mesh)


def run_umap(key: jax.Array, x: jnp.ndarray, cfg: UmapConfig,
             weights: Optional[jnp.ndarray] = None,
             mesh=None, init: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full UMAP: kNN → fuzzy set → SGD embed.  Returns (N, dims).

    Every stage is memory-bounded: kNN streams ``cfg.block`` rows at a
    time, and symmetrization is sparse — no (N, N) buffer at any N.
    ``mesh`` row-block-shards both the kNN build and the SGD loop under
    ``shard_map`` (see :func:`optimize_embedding`).

    ``init`` seeds the SGD at the given (N, dims) float coordinates
    instead of the uniform cold start — the warm-start hook the online
    service uses to resume from a previous embedding.  Validated for
    shape/dtype; works on the single-device and mesh paths alike."""
    from repro.core.tsne import validate_init
    mesh = mesh_mod.resolve_mesh(mesh)
    init = validate_init(init, x.shape[0], cfg.dims)
    idx, dist = knn_graph(x, cfg.n_neighbors, block=cfg.block, mesh=mesh,
                          method=cfg.knn_method, ann=cfg.ann)
    edges, memb = fuzzy_simplicial_set(idx, dist, weights=weights,
                                       search_iters=cfg.sigma_search_iters)
    return optimize_embedding(key, edges, memb, x.shape[0], cfg, init=init,
                              mesh=mesh)
