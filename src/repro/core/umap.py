"""Vanilla UMAP in pure JAX — the paper's second downstream embedder.

Faithful to McInnes-Healy-Melville 2018 (and the umap-learn reference):

* exact kNN graph (paper regime: N ≤ 2·10⁴ representatives, so brute-force
  pairwise distances on the MXU beat approximate NN),
* fuzzy simplicial set: per-point rho (distance to nearest neighbour) and
  sigma from binary search so Σ_j exp(−(d−rho)/sigma) = log₂(k),
* probabilistic t-conorm symmetrization  a ⊕ a' = a + a' − a∘a',
* (a, b) curve fit from (spread, min_dist) by least squares,
* SGD over the cross-entropy with negative sampling.

JAX adaptation: umap-learn's per-edge asynchronous SGD ("hogwild") is
host-sequential and shape-dynamic.  We instead run *epoch-batched* SGD:
each epoch applies the attractive gradient of every edge (weighted by the
fuzzy membership, equivalent in expectation to umap-learn's
sample-by-weight schedule) and `neg_rate` uniformly-sampled repulsive
pairs per edge — all static shapes, all fused by XLA.  This is the same
estimator, batched; convergence behaviour matches (tested on blobs).

The per-epoch reduction of E = N·k per-edge forces into per-point deltas
is *scatter-free*: at setup :func:`repro.core.coo.edge_layout` sorts the
edges by src (stable — the fuzzy-set edge list is already src-sorted, so
edge order and the per-edge RNG stream are unchanged), precomputes the
dst-sorted ordering plus the gather permutation between the two, and the
epoch body reduces each endpoint's contributions with one O(E) cumsum
differenced at the precomputed row bounds
(:func:`repro.core.coo.segment_reduce`) — the same machinery as the
sparse tSNE backend.  XLA's CPU scatter walks updates serially (~100×
slower at E ~ 10⁷), so replacing the two ``.at[].add`` scatters per epoch
is what lets ``embedder="umap"`` run at the same N = 10⁵–10⁶
representative counts as sparse tSNE
(benchmarks/bench_embed_throughput.py tracks epochs/sec against the
frozen scatter baseline; the epoch jaxpr is pinned scatter-free in
tests/test_umap_scatter_free.py).

Weighted extension (SnS): HH counts enter as per-point mass, scaling each
point's outgoing memberships — representatives of dense cells attract
proportionally more, mirroring the paper's replica weighting.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coo, neighbors
from repro.core.neighbors import knn_graph  # noqa: F401  (public re-export)


@dataclasses.dataclass(frozen=True)
class UmapConfig:
    dims: int = 2
    n_neighbors: int = 15
    min_dist: float = 0.1
    spread: float = 1.0
    n_epochs: int = 300
    learning_rate: float = 1.0
    neg_rate: int = 5
    init_scale: float = 10.0
    sigma_search_iters: int = 50
    block: int = 4096              # kNN row-block; N <= block -> dense path


@functools.lru_cache(maxsize=None)
def fit_ab(spread: float, min_dist: float) -> Tuple[float, float]:
    """Least-squares fit of 1/(1+a d^{2b}) to the target membership curve
    (host-side, same construction as umap-learn).  Cached per
    (spread, min_dist): the call happens at trace time inside the jitted
    ``optimize_embedding``, so every retrace (new static shape / cfg)
    would otherwise re-run the scipy ``curve_fit``."""
    from scipy.optimize import curve_fit
    xs = np.linspace(0, 3.0 * spread, 300)
    ys = np.where(xs < min_dist, 1.0, np.exp(-(xs - min_dist) / spread))

    def curve(x, a, b):
        return 1.0 / (1.0 + a * x ** (2 * b))

    (a, b), _ = curve_fit(curve, xs, ys, p0=(1.0, 1.0), maxfev=10_000)
    return float(a), float(b)


def fuzzy_simplicial_set(knn_idx: jnp.ndarray, knn_dist: jnp.ndarray,
                         weights: Optional[jnp.ndarray] = None,
                         search_iters: int = 50,
                         symmetrize: str = "sparse"
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Memberships on the kNN edges + symmetrized graph.

    Returns (edges (E,2) int32, membership (E,) float32) with E = N·k
    (each directed edge; symmetrization by the probabilistic t-conorm
    a ⊕ a' = a + a' − a·a').  ``symmetrize="sparse"`` (default) matches
    reverse edges by sorted-key binary search — no (N, N) temp;
    ``"dense"`` keeps the scatter-max reference path for small N."""
    n, k = knn_idx.shape
    rho = knn_dist[:, 0]
    target = jnp.log2(float(k))

    lo = jnp.full((n,), 1e-6)
    hi = jnp.full((n,), 1e6)

    def bisect(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        d = jnp.maximum(knn_dist - rho[:, None], 0.0)
        s = jnp.sum(jnp.exp(-d / mid[:, None]), axis=1)
        too_big = s > target
        return jnp.where(too_big, lo, mid), jnp.where(too_big, mid, hi)

    lo, hi = jax.lax.fori_loop(0, search_iters, bisect, (lo, hi))
    sigma = 0.5 * (lo + hi)
    memb = jnp.exp(-jnp.maximum(knn_dist - rho[:, None], 0.0)
                   / sigma[:, None])                          # (N, k)
    if weights is not None:
        w = weights / jnp.mean(weights)
        memb = jnp.minimum(memb * w[:, None], 1.0)

    rows = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    cols = knn_idx.reshape(-1).astype(jnp.int32)
    vals = memb.reshape(-1)
    if symmetrize == "sparse":
        rev = neighbors.reverse_edge_values(knn_idx, memb, rows, cols,
                                            vals, n)
        edge_vals = vals + rev - vals * rev
    elif symmetrize == "dense":
        # reference path: dense lookup of reverse membership via scatter-max
        dense = jnp.zeros((n, n)).at[rows, cols].max(vals)
        sym = dense + dense.T - dense * dense.T
        edge_vals = sym[rows, cols]
    else:
        raise ValueError(f"unknown symmetrize {symmetrize!r}")
    edges = jnp.stack([rows, cols], axis=1)
    return edges, edge_vals


class _OptState(NamedTuple):
    y: jnp.ndarray
    key: jax.Array


def epoch_delta(y: jnp.ndarray, layout: coo.EdgeLayout, memb_n: jnp.ndarray,
                kneg: jax.Array, a: float, b: float, neg_rate: int
                ) -> jnp.ndarray:
    """One epoch's per-point SGD delta — the scatter-free epoch body.

    ``layout``/``memb_n`` come from the one-time setup (stable src-sort +
    dst permutation, memberships gathered into layout order).  Attraction
    and repulsion are computed per edge, then reduced into per-point
    deltas by two cumsum-difference segment reductions (src side carries
    attraction + negative samples, dst side the attraction reaction) —
    zero scatter primitives in the jaxpr.  Shared by the optimizer's
    ``fori_loop`` and the throughput bench, so what is timed is exactly
    what runs.
    """
    n = y.shape[0]
    e = layout.src.shape[0]
    src, dst = layout.src, layout.dst
    ys, yd = y[src], y[dst]
    d2 = jnp.sum((ys - yd) ** 2, axis=1)
    # attractive: dCE/dy = 2ab d^{2(b-1)} / (1 + a d^{2b}) * (ys - yd)
    grad_coef = (-2.0 * a * b * d2 ** (b - 1.0)
                 / (1.0 + a * d2 ** b))
    grad_coef = jnp.where(d2 > 0, grad_coef, 0.0)
    att = jnp.clip(grad_coef[:, None] * (ys - yd), -4.0, 4.0) \
        * memb_n[:, None]
    # repulsive: neg_rate uniform negatives per edge.  A draw can hit
    # the edge's own endpoints — repelling dst would fight the very
    # attraction this edge just applied (src is harmless: zero diff),
    # so those samples are masked out rather than resampled (keeps
    # shapes static; the tiny rate loss matches umap-learn's "skip
    # self" behaviour in expectation).
    neg = jax.random.randint(kneg, (e, neg_rate), 0, n)
    valid = (neg != src[:, None]) & (neg != dst[:, None])
    yn = y[neg]                                           # (E, R, dims)
    dn2 = jnp.sum((ys[:, None, :] - yn) ** 2, axis=2)
    rep_coef = (2.0 * b) / ((0.001 + dn2) * (1.0 + a * dn2 ** b))
    rep = jnp.clip(rep_coef[..., None] * (ys[:, None, :] - yn),
                   -4.0, 4.0) * memb_n[:, None, None]
    rep = jnp.where(valid[..., None], rep, 0.0)
    # scatter-free reduction: src side via the src-sorted bounds, dst
    # side (the attraction reaction, −att) via the precomputed gather
    # into dst-sorted order — two O(E) cumsum passes, no .at[].add
    return coo.segment_reduce(att + jnp.sum(rep, axis=1),
                              layout.src_bounds) \
        - coo.segment_reduce(att[layout.dst_order], layout.dst_bounds)


@functools.partial(jax.jit, static_argnames=("cfg", "n"))
def optimize_embedding(key: jax.Array, edges: jnp.ndarray,
                       memb: jnp.ndarray, n: int, cfg: UmapConfig,
                       init: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Epoch-batched SGD on the UMAP cross-entropy, scatter-free.

    Setup builds the bidirectional sorted-COO reduction plan once
    (:func:`repro.core.coo.edge_layout`); every epoch then runs
    :func:`epoch_delta` inside one jitted ``fori_loop`` with zero scatter
    primitives (jaxpr-pinned in tests/test_umap_scatter_free.py)."""
    a, b = fit_ab(cfg.spread, cfg.min_dist)
    kinit, kloop = jax.random.split(key)
    y0 = init if init is not None else \
        cfg.init_scale * jax.random.uniform(kinit, (n, cfg.dims)) - \
        cfg.init_scale / 2.0
    layout, order = coo.edge_layout(edges[:, 0], edges[:, 1], n)
    memb_n = (memb / jnp.maximum(jnp.max(memb), 1e-12))[order]

    def epoch(i, state):
        y, key = state
        key, kneg = jax.random.split(key)
        alpha = cfg.learning_rate * (1.0 - i / cfg.n_epochs)
        delta = epoch_delta(y, layout, memb_n, kneg, a, b, cfg.neg_rate)
        return _OptState(y + alpha * delta, key)

    state = jax.lax.fori_loop(0, cfg.n_epochs, epoch, _OptState(y0, kloop))
    return state.y


def run_umap(key: jax.Array, x: jnp.ndarray, cfg: UmapConfig,
             weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full UMAP: kNN → fuzzy set → SGD embed.  Returns (N, dims).

    Every stage is memory-bounded: kNN streams ``cfg.block`` rows at a
    time, and symmetrization is sparse — no (N, N) buffer at any N."""
    idx, dist = knn_graph(x, cfg.n_neighbors, block=cfg.block)
    edges, memb = fuzzy_simplicial_set(idx, dist, weights=weights,
                                       search_iters=cfg.sigma_search_iters)
    return optimize_embedding(key, edges, memb, x.shape[0], cfg)
