"""Approximate kNN engine: sketch-native bucketing + NN-descent refinement.

Kills the last O(N²·D) pass in the pipeline (ROADMAP item 1): the exact
kNN build both embedders run once at setup.  Two composable stages, both
fixed-shape and fully jittable:

**Stage 1 — multi-probe grid-cell bucketing** (the candidate generator).
For each of ``probes`` random rotations: rotate, quantize the leading
``key_dims`` coordinates onto a 2^bits grid (the same floor/clip
quantization as ``core.quantize``, but with *traced* bounds — GridSpec's
corners are static), interleave the bit-planes into a Morton cell key,
and sort points by key — one lexsort per probe, the same sort-then-scan
layout ``candidates.sorted_runs`` uses for the ingest fold.  Real cell
runs have data-dependent lengths, so instead of RLE run boundaries the
scan uses the fixed-shape relaxation: consecutive **tiles of B sorted
rows**, each scored against a shared window of its own tile plus a
one-tile halo on each side (C = 3B candidates — every point within B−1
sorted positions is always in-window).  The (B, D)×(D, C) distance block
is MXU-shaped and dispatches to the Pallas tiled distance-scan kernel
(``kernels.knn_tile``, interpret-mode on CPU) or its XLA reference;
``top_k`` k-selects per row, and probes merge by per-row id-dedupe +
k-merge (``lax.top_k``), exactly the reservoir-merge discipline of the
ingest core.

**Stage 2 — NN-descent refinement** (Dong et al.; the UMAP paper §4
ships it as the standard approximate-kNN path).  A single jitted
``fori_loop`` with fixed shapes: each iteration samples, per point,
``sample`` forward neighbors and ``sample`` reverse edges (reverse lists
come from one dst-sort of the edge list + ``coo.row_bounds`` — the
repo's scatter-free sorted-COO idiom — with a random in-list window
offset), expands to the sampled neighbors' own neighbor lists, scores
candidates exactly, and k-merges into the current graph.  Early exit: a
round that changes ≤ ``delta·N·k`` entries flips a ``done`` flag and
``lax.cond`` skips the heavy work of the remaining iterations (the loop
stays a single fixed-trip-count ``fori_loop`` in the jaxpr).

No (N, N) buffer anywhere (jaxpr-regression-tested): stage 1 peaks at
O(N·D + N·k), stage 2 at O(N·k + block·C).

**Mesh path** (1-D embed mesh, ``core.mesh``): stage 1 shards the tile
scan — each device scores a contiguous slice of sorted tiles against its
own halo windows (embarrassingly parallel; sort and probe-merge are
replicated).  Stage 2 row-block-shards the refinement: each device
refines its contiguous row block; the per-iteration collectives are one
``all_gather`` of the (padded) neighbor blocks and one scalar ``psum``
of the update count.  RNG draws are keyed per *global row id*
(``fold_in``), so mesh and single-device results are bit-identical
(tests/test_mesh_embed.py).

Entry point: :func:`ann_knn_graph`, dispatched via
``neighbors.knn_graph(method="ann"|"auto")``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coo
from repro.core import mesh as mesh_mod
from repro.kernels import knn_tile

# numpy, not jnp: module import may happen lazily inside a jit trace
# (neighbors dispatch), and a jnp constant created there would be a
# tracer leaking into every later trace
_KEY_MAX = np.uint32(0xFFFFFFFF)
_TILE_CHUNK = 8          # sorted tiles scored per lax.map step (stage 1)


@dataclasses.dataclass(frozen=True)
class AnnConfig:
    """Static knobs for the approximate kNN build (hashable: jit-static).

    probes          random-rotation bucketing passes k-merged in stage 1
    bucket          sorted tile size B (window = 3B; lifted to ≥ k)
    bits            quantization bit-planes per key dim (clamped so the
                    Morton key fits 30 bits)
    key_dims        leading rotated coordinates folded into the cell key
    iters           NN-descent iteration cap (single fori_loop trip count)
    sample          per-side NN-descent sample m: m forward + m reverse
                    seeds, each expanded to m of its neighbors
                    (candidates/round = 2m² + m).  Rounds are dominated
                    by fixed per-round sort overhead on CPU, so a large
                    m with few iters beats a small m with many: the
                    defaults (m=16, 4 rounds) beat the recall ten m=8
                    rounds reached at ~half the wall-clock
    delta           early-exit threshold: stop once a round updates
                    ≤ delta·N·k graph entries
    rev_cols        reverse edges are sampled from each row's nearest
                    ``rev_cols`` neighbor slots only — the dst-sort is
                    the other per-round fixed cost and near in-edges
                    carry nearly all the signal (0 = all k slots)
    block           row block for the refinement distance pass
    tile            stage-1 distance backend: "xla" | "pallas"
    interpret       run the Pallas kernel in interpret mode (CPU)
    auto_threshold  knn_graph(method="auto") switches to ann above this N
    seed            RNG seed for rotations and descent sampling
    """
    probes: int = 4
    bucket: int = 128
    bits: int = 10
    key_dims: int = 3
    iters: int = 4
    sample: int = 16
    delta: float = 2e-3
    rev_cols: int = 32
    block: int = 4096
    tile: str = "xla"
    interpret: bool = True
    # registry dispatch mode for the stage-1 distance kernel (op
    # "knn_dist_tiles"); None defers to tile/interpret above (plus any
    # process-level SNS_KERNEL_MODE pin), a string forces one mode
    kernel_mode: Optional[str] = None
    auto_threshold: int = 1 << 16
    seed: int = 0


def _bucket_size(cfg: AnnConfig, k: int) -> int:
    # every row needs ≥ k real in-window candidates; the window always
    # holds ≥ min(n−1, B) real non-self rows, so lift B to k
    return max(cfg.bucket, k)


def _cell_keys(xr: jnp.ndarray, bits: int, key_dims: int) -> jnp.ndarray:
    """Morton cell key of the leading rotated coordinates — uint32 (N,).

    Quantizes each of m = min(D, key_dims) coordinates to 2^bits bins
    between its (traced) min/max, then interleaves the bit-planes so
    lexicographic key order is space-filling-curve order: points sorted
    by key land near their cell neighbors, which is what the fixed-tile
    halo window exploits.  bits·m is clamped to 30 so real keys stay
    below the 0xFFFFFFFF padding sentinel.
    """
    n, d = xr.shape
    m = max(1, min(d, key_dims))
    bits = max(1, min(bits, 30 // m))
    u = xr[:, :m]
    lo = jnp.min(u, axis=0)
    span = jnp.maximum(jnp.max(u, axis=0) - lo, 1e-30)
    nbins = jnp.float32(1 << bits)
    q = jnp.clip(jnp.floor((u - lo) / span * nbins),
                 0, nbins - 1).astype(jnp.uint32)
    key = jnp.zeros((n,), jnp.uint32)
    for b in range(bits):
        for j in range(m):
            key = key | (((q[:, j] >> b) & 1) << (b * m + j))
    return key


def _probe_layout(x: jnp.ndarray, k: int, key: jnp.ndarray, cfg: AnnConfig,
                  chunk_tiles: int, cand_ids: Optional[jnp.ndarray] = None):
    """One probe's sorted tile layout: rotate → cell keys → key-sort →
    fixed B-row query tiles with 3B halo candidate windows.

    Returns (qx (T,B,D), qid (T,B), cx (T,3B,D), cid (T,3B), inv) where T
    is padded to a multiple of ``chunk_tiles`` (junk tiles carry id −1)
    and ``inv`` maps original row i to its sorted position.

    ``cand_ids`` (optional, (N,) int32) decouples the *candidate* id a
    row exposes from the row's own query id: rows carrying −1 can still
    probe (they sort into the layout and get scored) but are never
    returned as neighbors — the asymmetric query-vs-corpus mode
    (:func:`ann_knn_query` appends query rows with cand id −1).  The
    default (None) keeps the symmetric self-join: cand id = row id.
    """
    n, d = x.shape
    b = _bucket_size(cfg, k)
    nb = -(-n // b)
    nbp = -(-nb // chunk_tiles) * chunk_tiles
    n_sort = nb * b
    n_lay = nbp * b

    g = jax.random.normal(key, (d, d), dtype=jnp.float32)
    rot, _ = jnp.linalg.qr(g)
    keys = _cell_keys(x.astype(jnp.float32) @ rot, cfg.bits, cfg.key_dims)
    keys_p = jnp.pad(keys, (0, n_sort - n), constant_values=_KEY_MAX)
    order = jnp.argsort(keys_p, stable=True)                 # (n_sort,)
    ids = jnp.where(jnp.arange(n_sort) < n,
                    jnp.arange(n_sort), -1).astype(jnp.int32)
    cids = ids if cand_ids is None else \
        jnp.pad(cand_ids.astype(jnp.int32), (0, n_sort - n),
                constant_values=-1)
    sx = jnp.pad(x, ((0, n_sort - n), (0, 0)))[order]
    sid = ids[order]
    scid = cids[order]
    # extend to the chunk-padded tile count, then halo-pad a tile per side
    sx = jnp.pad(sx, ((b, n_lay - n_sort + b), (0, 0)))
    sid = jnp.pad(sid, ((b, n_lay - n_sort + b),), constant_values=-1)
    scid = jnp.pad(scid, ((b, n_lay - n_sort + b),), constant_values=-1)
    qx = sx[b:b + n_lay].reshape(nbp, b, d)
    qid = sid[b:b + n_lay].reshape(nbp, b)
    cx = jnp.concatenate([sx[:n_lay].reshape(nbp, b, d), qx,
                          sx[2 * b:].reshape(nbp, b, d)], axis=1)
    cid = jnp.concatenate([scid[:n_lay].reshape(nbp, b),
                           scid[b:b + n_lay].reshape(nbp, b),
                           scid[2 * b:].reshape(nbp, b)], axis=1)
    inv = jnp.argsort(order, stable=True)
    return qx, qid, cx, cid, inv


def _tiles_topk(qx, qid, cx, cid, k: int, cfg: AnnConfig,
                chunk_tiles: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Score every tile against its window, k-select per row.  Streams
    ``chunk_tiles`` tiles per ``lax.map`` step so the distance blocks
    never materialize at once.  Returns (idx, d2) in sorted-row layout,
    d2 ascending (junk rows: idx −1, d2 +inf)."""
    nbp, b, d = qx.shape
    c = cx.shape[1]
    nch = nbp // chunk_tiles

    def step(args):
        tqx, tqid, tcx, tcid = args
        d2 = knn_tile.distance_tiles(tqx, tqid, tcx, tcid,
                                     tile=cfg.tile, interpret=cfg.interpret,
                                     mode=cfg.kernel_mode)
        neg, pos = jax.lax.top_k(-d2, k)                     # (chunk, B, k)
        idx = jnp.take_along_axis(
            jnp.broadcast_to(tcid[:, None, :], d2.shape), pos, axis=2)
        return idx.astype(jnp.int32), -neg

    idx, d2 = jax.lax.map(step, (qx.reshape(nch, chunk_tiles, b, d),
                                 qid.reshape(nch, chunk_tiles, b),
                                 cx.reshape(nch, chunk_tiles, c, d),
                                 cid.reshape(nch, chunk_tiles, c)))
    return idx.reshape(-1, k), d2.reshape(-1, k)


def _dedupe_topk(idx: jnp.ndarray, d2: jnp.ndarray, k: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row k-merge: drop duplicate ids (stable — first occurrence
    wins, so callers concat [current, new]) and invalid ids (< 0), then
    keep the k nearest.  Returns (idx (R,k), d2 (R,k)) with d2 ascending.
    """
    order = jnp.argsort(idx, axis=1, stable=True)
    idx_s = jnp.take_along_axis(idx, order, axis=1)
    d2_s = jnp.take_along_axis(d2, order, axis=1)
    dup = jnp.concatenate([jnp.zeros((idx.shape[0], 1), bool),
                           idx_s[:, 1:] == idx_s[:, :-1]], axis=1)
    d2_s = jnp.where(dup | (idx_s < 0), jnp.inf, d2_s)
    neg, pos = jax.lax.top_k(-d2_s, k)
    return jnp.take_along_axis(idx_s, pos, axis=1), -neg


def _merge_probes(probes, k: int):
    """k-merge the per-probe (idx, d2) results in ONE dedupe pass.
    k-merge is associative, so a single wide merge returns the same set
    as the pairwise chain at roughly half the (argsort-dominated) cost;
    a single probe needs no merge at all."""
    if len(probes) == 1:
        return probes[0]
    return _dedupe_topk(jnp.concatenate([p[0] for p in probes], axis=1),
                        jnp.concatenate([p[1] for p in probes], axis=1), k)


def _layout_pos(g, rows_per: int, rpp: int):
    """Layout position of global row g: devices own ``rows_per``
    consecutive global rows, padded to ``rpp`` layout slots each.  The
    single-device layout is the identity (rows_per == rpp)."""
    if rows_per == rpp:
        return g
    return (g // rows_per) * rpp + g % rows_per


def _reverse_sample(idx_full: jnp.ndarray, rid_full: jnp.ndarray,
                    key: jnp.ndarray, m: int, r: int, n: int) -> jnp.ndarray:
    """``m`` sampled reverse edges per global row: sources j that list i
    as a neighbor.  One dst-sort of the edge list + ``coo.row_bounds``
    (no scatter), then a random contiguous window per row.  Rows listed
    by fewer than m sources pad with −1.  Only the nearest ``r`` slots
    of each neighbor list feed the sort (``AnnConfig.rev_cols``): the
    dst-sort of N·r keys is the round's fixed cost, and near in-edges
    carry nearly all the reverse-neighbor signal.  Replicated and
    draw-aligned across mesh layouts: padded layout rows hold dst −1
    (sorted out by the bounds) and real edges keep (global row, slot)
    order."""
    dst = idx_full[:, :r].reshape(-1)
    e = dst.size
    order = jnp.argsort(dst, stable=True)
    bounds = coo.row_bounds(dst[order], n)
    lo, hi = bounds[:-1], bounds[1:]
    cnt = hi - lo
    off = jax.random.randint(key, (n,), 0, 1 << 30) \
        % jnp.maximum(cnt - m + 1, 1)
    j = jnp.arange(m, dtype=jnp.int32)
    pos = jnp.clip(jnp.minimum(lo[:, None] + off[:, None] + j[None, :],
                               hi[:, None] - 1), 0, e - 1)
    src = rid_full[order[pos] // r]                          # (n, m)
    return jnp.where(j[None, :] < cnt[:, None], src, -1)


def _refine_chunk(x, idx_full, rev_all, idxc, d2c, ridc, key,
                  cfg: AnnConfig, k: int, n: int, rows_per: int, rpp: int):
    """One NN-descent round for a block of rows: sample forward + reverse
    seeds, expand to their neighbor lists, score exactly, k-merge.
    Returns (idx, d2, changed) — padded rows (id −1) pass through."""
    rows = ridc.shape[0]
    m = cfg.sample
    ndraw = m + 2 * m * m
    rid_safe = jnp.maximum(ridc, 0)
    # per-global-row keys: draws are identical for any row blocking (the
    # mesh path's bit-exactness hinges on this)
    draws = jax.vmap(lambda r: jax.random.randint(
        jax.random.fold_in(key, r), (ndraw,), 0, k))(rid_safe)
    fwd = jnp.take_along_axis(idxc, draws[:, :m], axis=1)    # (rows, m)
    rev = jnp.where(ridc[:, None] >= 0, rev_all[rid_safe], -1)
    union = jnp.concatenate([fwd, rev], axis=1)              # (rows, 2m)
    upos = _layout_pos(jnp.clip(union, 0, n - 1), rows_per, rpp)
    # gather ONLY the m sampled slots of each seed's neighbor list — a
    # flat (rows, 2m, m) pick, not the (rows, 2m, k) lists (k ≫ m makes
    # the full-list gather the round's dominant memory traffic)
    ecols = draws[:, m:].reshape(rows, 2 * m, m)
    expand = idx_full.reshape(-1)[upos[:, :, None] * k + ecols]
    expand = expand.reshape(rows, 2 * m * m)
    expand = jnp.where(jnp.repeat(union >= 0, m, axis=1), expand, -1)
    cand = jnp.concatenate([rev, expand], axis=1)            # (rows, C)
    xi = x[rid_safe]
    xc = x[jnp.clip(cand, 0, n - 1)]
    d2n = jnp.sum((xi[:, None, :] - xc) ** 2, axis=2)
    d2n = jnp.where((cand < 0) | (cand == ridc[:, None]), jnp.inf, d2n)
    # Mask candidates already present in the row: they sit below τ by
    # construction (they *are* the near entries), so without this they
    # crowd out every selection slot and the descent stalls.  Bonus: at
    # the fixpoint all candidates are members, every slot selects −1,
    # and the merge returns the row bit-equal — the early-exit `changed`
    # counter hits exactly zero.
    row_sorted = jnp.sort(idxc, axis=1)
    pos = jax.vmap(jnp.searchsorted)(row_sorted, cand)
    member = jnp.take_along_axis(
        row_sorted, jnp.clip(pos, 0, k - 1), axis=1) == cand
    d2n = jnp.where(member, jnp.inf, d2n)
    # Two-stage selection: a candidate at or beyond the row's current kth
    # distance can never enter the merged top-k (τ-filter), and only a
    # handful can per round — pre-select the s best by distance with a
    # cheap partial top_k, then dedupe-merge only (k + s) wide.  The
    # stable argsort inside _dedupe_topk is the round's dominant cost on
    # CPU (~5× a top_k of the same width), so its width must not scale
    # with the candidate count C = 2m² + m.
    tau = d2c[:, k - 1:k]
    neg, cpos = jax.lax.top_k(-jnp.where(d2n >= tau, jnp.inf, d2n),
                              min(cand.shape[1], max(2 * m, 48)))
    cd = -neg
    ci = jnp.where(jnp.isinf(cd), -1,
                   jnp.take_along_axis(cand, cpos, axis=1))
    mi, md = _dedupe_topk(jnp.concatenate([idxc, ci], axis=1),
                          jnp.concatenate([d2c, cd], axis=1), k)
    live = ridc[:, None] >= 0
    mi = jnp.where(live, mi, idxc)
    md = jnp.where(live, md, d2c)
    changed = jnp.sum((mi != idxc) & live).astype(jnp.int32)
    return mi, md, changed


def _nn_descent(x, idx0, d20, row_ids, key, k: int, n: int, cfg: AnnConfig,
                bl: int, rows_per: int, rpp: int, axis: Optional[str] = None,
                rid_full: Optional[jnp.ndarray] = None):
    """The refinement loop: a single fixed-trip-count ``fori_loop``.
    Early exit via a ``done`` flag — converged iterations ``lax.cond``
    past the heavy work; the (mesh-path) collectives stay outside the
    cond so every device always executes the same collective sequence."""
    r_loc = idx0.shape[0]
    nc = r_loc // bl
    thresh = cfg.delta * n * k

    def body(it, carry):
        idx, d2, done = carry
        kit = jax.random.fold_in(key, it)
        kr, kc = jax.random.split(kit)
        if axis is None:
            idx_full, rif = idx, row_ids
        else:
            idx_full = jax.lax.all_gather(idx, axis, axis=0, tiled=True)
            rif = rid_full

        def live(args):
            idx, d2 = args
            r = min(cfg.rev_cols, k) if cfg.rev_cols else k
            rev_all = _reverse_sample(idx_full, rif, kr, cfg.sample, r, n)
            ni, nd, ch = jax.lax.map(
                lambda a: _refine_chunk(x, idx_full, rev_all, *a, kc, cfg,
                                        k, n, rows_per, rpp),
                (idx.reshape(nc, bl, k), d2.reshape(nc, bl, k),
                 row_ids.reshape(nc, bl)))
            return ni.reshape(r_loc, k), nd.reshape(r_loc, k), jnp.sum(ch)

        def skip(args):
            return args[0], args[1], jnp.zeros((), jnp.int32)

        idx, d2, changed = jax.lax.cond(done, skip, live, (idx, d2))
        if axis is not None:
            changed = jax.lax.psum(changed, axis)
        return idx, d2, done | (changed <= thresh)

    idx, d2, _ = jax.lax.fori_loop(0, cfg.iters, body,
                                   (idx0, d20, jnp.bool_(False)))
    return idx, d2


@functools.partial(jax.jit, static_argnames=("k", "cfg"))
def _ann_build(x: jnp.ndarray, k: int, cfg: AnnConfig):
    """Single-device build: multi-probe candidates → NN-descent.
    Returns (idx (N,k) int32, d2 (N,k) ascending squared distances)."""
    n = x.shape[0]
    kp, kd = jax.random.split(jax.random.PRNGKey(cfg.seed))
    probes = []
    for p in range(cfg.probes):
        lay = _probe_layout(x, k, jax.random.fold_in(kp, p), cfg,
                            _TILE_CHUNK)
        ti, td = _tiles_topk(*lay[:4], k, cfg, _TILE_CHUNK)
        probes.append((ti[lay[4][:n]], td[lay[4][:n]]))
    idx, d2 = _merge_probes(probes, k)
    bl = min(cfg.block, n)
    r_total = -(-n // bl) * bl
    rid = jnp.where(jnp.arange(r_total) < n,
                    jnp.arange(r_total), -1).astype(jnp.int32)
    idx_l = jnp.pad(idx, ((0, r_total - n), (0, 0)), constant_values=-1)
    d2_l = jnp.pad(d2, ((0, r_total - n), (0, 0)),
                   constant_values=jnp.inf)
    idx_l, d2_l = _nn_descent(x, idx_l, d2_l, rid, kd, k, n, cfg, bl,
                              r_total, r_total)
    return idx_l[:n], d2_l[:n]


def _ann_build_mesh(x: jnp.ndarray, k: int, cfg: AnnConfig, mesh):
    """Mesh build: stage 1 shards the tile scan (contiguous tile slices,
    replicated sort), stage 2 shards the refinement by row block.  Per
    descent iteration the only collectives are one all_gather of the
    neighbor blocks and one psum of the update count; results are
    bit-identical to :func:`_ann_build`."""
    axis = mesh_mod.mesh_axis(mesh)
    s = mesh_mod.axis_size(mesh, axis)
    P = mesh_mod.P
    n = x.shape[0]
    kp, kd = jax.random.split(jax.random.PRNGKey(cfg.seed))

    @mesh_mod.shard_map_compat(mesh=mesh,
                               in_specs=(P(axis), P(axis), P(axis), P(axis)),
                               out_specs=(P(axis), P(axis)))
    def tiles_spmd(qx, qid, cx, cid):
        return _tiles_topk(qx, qid, cx, cid, k, cfg, _TILE_CHUNK)

    probes = []
    for p in range(cfg.probes):
        lay = _probe_layout(x, k, jax.random.fold_in(kp, p), cfg,
                            _TILE_CHUNK * s)
        ti, td = tiles_spmd(*lay[:4])
        probes.append((ti[lay[4][:n]], td[lay[4][:n]]))
    idx, d2 = _merge_probes(probes, k)

    rows_per, _ = mesh_mod.row_block(n, s)
    bl = min(cfg.block, rows_per)
    rpp = -(-rows_per // bl) * bl
    r_total = s * rpp
    lay_j = jnp.arange(r_total) % rpp
    gid = (jnp.arange(r_total) // rpp) * rows_per + lay_j
    rid = jnp.where((lay_j < rows_per) & (gid < n), gid, -1).astype(jnp.int32)
    safe = jnp.maximum(rid, 0)
    live = rid[:, None] >= 0
    idx_l = jnp.where(live, idx[safe], -1)
    d2_l = jnp.where(live, d2[safe], jnp.inf)

    @mesh_mod.shard_map_compat(
        mesh=mesh, in_specs=(P(), P(axis), P(axis), P(axis), P(), P()),
        out_specs=(P(axis), P(axis)))
    def descent_spmd(xf, idx_b, d2_b, rid_b, rid_f, key):
        return _nn_descent(xf, idx_b, d2_b, rid_b, key, k, n, cfg, bl,
                           rows_per, rpp, axis=axis, rid_full=rid_f)

    idx_l, d2_l = descent_spmd(x, idx_l, d2_l, rid, rid, kd)
    pos = _layout_pos(jnp.arange(n), rows_per, rpp)
    return idx_l[pos], d2_l[pos]


def ann_knn_graph(x: jnp.ndarray, k: int, cfg: Optional[AnnConfig] = None,
                  *, mesh=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Approximate kNN graph (excluding self): (indices (N,k), dists
    (N,k)) — the drop-in sub-quadratic replacement for the exact
    ``neighbors.knn_graph``, same return convention (distances are
    euclidean, ascending per row).  Recall ≥ 0.9 vs exact on blob data
    at the default config (property-tested; benchmarks/bench_knn_recall
    tracks it)."""
    cfg = cfg if cfg is not None else AnnConfig()
    n = x.shape[0]
    k = min(int(k), max(n - 1, 1))
    if mesh is None:
        idx, d2 = _ann_build(x, k, cfg)
    else:
        idx, d2 = _ann_build_mesh(x, k, cfg, mesh)
    return idx, jnp.sqrt(jnp.maximum(d2, 0.0))


# ----------------------------------------------------- query-vs-corpus mode
# Asymmetric kNN: k nearest *corpus* rows for each query row, corpus
# frozen — the out-of-sample `transform()` regime (ROADMAP item 3).  The
# same two machines run unmodified: stage 1 sorts the UNION [corpus;
# queries] per probe, with the candidate-id channel carrying the corpus
# index for corpus rows and −1 for query rows (a query can probe but
# never be returned), and query rows keyed n+j so the distance tile's
# self-mask (cid == qid) never fires — an identical query keeps its
# corpus twin at distance 0.  An optional expansion round walks the
# corpus's own kNN graph from the probe candidates (one gather + exact
# rescore), the query-side half of an NN-descent iteration.

@functools.partial(jax.jit, static_argnames=("k", "cfg", "expand_k"))
def _ann_query(q: jnp.ndarray, x: jnp.ndarray, corpus_idx, k: int,
               cfg: AnnConfig, expand_k: int):
    n, d = x.shape
    m = q.shape[0]
    allx = jnp.concatenate([x.astype(jnp.float32),
                            q.astype(jnp.float32)], axis=0)
    cand_ids = jnp.concatenate([jnp.arange(n, dtype=jnp.int32),
                                jnp.full((m,), -1, jnp.int32)])
    kp = jax.random.PRNGKey(cfg.seed)
    probes = []
    for p in range(cfg.probes):
        lay = _probe_layout(allx, k, jax.random.fold_in(kp, p), cfg,
                            _TILE_CHUNK, cand_ids=cand_ids)
        ti, td = _tiles_topk(*lay[:4], k, cfg, _TILE_CHUNK)
        qpos = lay[4][n:n + m]             # sorted positions of query rows
        probes.append((ti[qpos], td[qpos]))
    idx, d2 = _merge_probes(probes, k)
    if corpus_idx is not None and expand_k > 0:
        # expansion: candidates' own neighbor lists, scored exactly —
        # peak buffer O(m · k·expand_k · D), never (m, n)
        kc = corpus_idx.shape[1]
        ecols = min(expand_k, kc)
        lists = corpus_idx[jnp.clip(idx, 0, n - 1), :ecols]  # (m, k, ecols)
        cand = jnp.where((idx >= 0)[:, :, None], lists, -1)
        cand = cand.reshape(m, k * ecols)
        xc = allx[jnp.clip(cand, 0, n - 1)]                  # (m, k·e, D)
        d2n = jnp.sum((q.astype(jnp.float32)[:, None, :] - xc) ** 2, axis=2)
        d2n = jnp.where(cand < 0, jnp.inf, d2n)
        idx, d2 = _dedupe_topk(jnp.concatenate([idx, cand], axis=1),
                               jnp.concatenate([d2, d2n], axis=1), k)
    return idx, d2


def ann_knn_query(q: jnp.ndarray, x: jnp.ndarray, k: int,
                  cfg: Optional[AnnConfig] = None, *,
                  corpus_graph: Optional[jnp.ndarray] = None,
                  expand_k: int = 16
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Approximate kNN of ``q`` (Q, D) against the frozen corpus ``x``
    (N, D): returns (indices (Q, k) into x, euclidean dists (Q, k)
    ascending).  No self-exclusion — a query identical to a corpus row
    returns that row at distance 0.

    ``corpus_graph`` (optional (N, kc) int neighbor lists, e.g. from
    :func:`ann_knn_graph`) enables one expansion round: each probe
    candidate contributes its ``expand_k`` nearest corpus neighbors,
    rescored exactly — the standard recall lift when the bucketing probes
    land near but not on the true neighbors."""
    cfg = cfg if cfg is not None else AnnConfig()
    n = x.shape[0]
    k = min(int(k), max(n, 1))
    idx, d2 = _ann_query(q, x, corpus_graph, k, cfg,
                         0 if corpus_graph is None else int(expand_k))
    return idx, jnp.sqrt(jnp.maximum(d2, 0.0))
