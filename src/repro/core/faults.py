"""Deterministic fault injection for the distributed ingest/serve path.

The paper's deployment model — edge nodes sketch locally, a master merges
the fixed-size summaries — only earns the word "distributed" once it
survives the failures such deployments actually see: shards that never
report, shards that report late, chunks delivered twice, bits flipped in
transit, checkpoints torn by a crash.  This module *manufactures* those
failures reproducibly so the resilience layer (:mod:`repro.core.
resilience`) can be tested and CI-gated instead of trusted.

Every decision is a pure function of ``(plan.seed, scope ids)`` via
``np.random.SeedSequence`` — no global RNG, no wall-clock dependence —
so a chaos test that fails on seed 3 fails on seed 3 forever.  The knobs:

* ``drop``/``drop_shards`` — a shard is *permanently* dead: every attempt
  fails (retries cannot save it; only partial aggregation can).
* ``flaky``              — an attempt fails *transiently*: the decision is
  keyed by (shard, attempt), so a bounded retry eventually gets through.
* ``delay``/``delay_seconds`` — a shard is a straggler: it sleeps before
  delivering, exercising the collector's deadline cutoff.
* ``duplicate``          — a chunk is delivered twice (at-least-once
  transport); the CountSketch is linear, so duplicates bias counts up —
  visible, not fatal.
* ``corrupt``            — one bit of a chunk (or of a returned sketch
  state) is flipped; sketch-state corruption is caught by the digest
  check in ``resilience.collect_shards(verify=True)``.

Wrappers: :func:`chaos_chunks` (a shard's chunk iterator),
:func:`chaos_make_batch` (a loader's ``make_batch``),
:func:`chaos_shard_job` (a whole shard job as submitted to the
collector), :func:`corrupt_file` (checkpoint chaos: flip / truncate).
"""
from __future__ import annotations

import dataclasses
import os
import time
import zlib
from typing import Callable, Iterable, Iterator, Optional, Sequence, Tuple

import numpy as np


class ShardFailure(RuntimeError):
    """An injected (or real) shard-level delivery failure."""


def _rng(seed: int, *scope) -> np.random.Generator:
    """Deterministic generator keyed by (seed, scope ids).  Strings enter
    via crc32 so the key is stable across processes (unlike hash())."""
    ids = [int(seed) & 0xFFFFFFFF]
    for s in scope:
        if isinstance(s, str):
            ids.append(zlib.crc32(s.encode()) & 0xFFFFFFFF)
        else:
            ids.append(int(s) & 0xFFFFFFFF)
    return np.random.default_rng(np.random.SeedSequence(ids))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Reproducible chaos recipe.  All probabilities in [0, 1]; a plan of
    all zeros injects nothing (the identity wrapper)."""
    seed: int = 0
    drop: float = 0.0                  # P(shard permanently dead)
    drop_shards: Tuple[int, ...] = ()  # explicit permanently-dead shards
    flaky: float = 0.0                 # P(one attempt fails, transient)
    delay: float = 0.0                 # P(shard is a straggler)
    delay_seconds: float = 0.05        # straggler sleep before delivery
    duplicate: float = 0.0             # P(a chunk is delivered twice)
    corrupt: float = 0.0               # P(a chunk / state gets a bit flip)

    def __post_init__(self):
        for f in ("drop", "flaky", "delay", "duplicate", "corrupt"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultPlan.{f} must be in [0, 1], "
                                 f"got {v}")
        if self.delay_seconds < 0:
            raise ValueError("FaultPlan.delay_seconds must be >= 0")

    # ------------------------------------------------- per-scope decisions
    def is_dropped(self, shard: int) -> bool:
        """Permanent death — keyed by shard only, so EVERY attempt sees
        the same verdict (retries are useless by design)."""
        if shard in self.drop_shards:
            return True
        return self.drop > 0 and \
            _rng(self.seed, "drop", shard).random() < self.drop

    def is_flaky(self, shard: int, attempt: int) -> bool:
        """Transient failure — keyed by (shard, attempt): a retried
        attempt re-rolls and can succeed."""
        return self.flaky > 0 and \
            _rng(self.seed, "flaky", shard, attempt).random() < self.flaky

    def delay_for(self, shard: int) -> float:
        """Straggler sleep for this shard (0.0 = on time)."""
        if self.delay > 0 and \
                _rng(self.seed, "delay", shard).random() < self.delay:
            return self.delay_seconds
        return 0.0

    def chunk_events(self, shard: int, chunk: int) -> Tuple[bool, bool]:
        """(duplicate?, corrupt?) for one delivered chunk."""
        dup = self.duplicate > 0 and \
            _rng(self.seed, "dup", shard, chunk).random() < self.duplicate
        cor = self.corrupt > 0 and \
            _rng(self.seed, "cor", shard, chunk).random() < self.corrupt
        return dup, cor


def flip_bit(arr: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Copy of ``arr`` with exactly one bit flipped (in-transit bit rot).
    Empty arrays pass through unchanged."""
    a = np.array(arr, copy=True)
    if a.nbytes == 0:
        return a
    raw = a.view(np.uint8).reshape(-1)
    pos = int(rng.integers(0, raw.size))
    raw[pos] ^= np.uint8(1 << int(rng.integers(0, 8)))
    return a


def corrupt_state(state, seed: int, shard: int = 0):
    """Flip one bit in a pytree of arrays (e.g. a returned
    ``stream.IngestState``) — the wire-corruption model the collector's
    digest verification exists to catch.  The first non-empty leaf is hit
    so the corruption is guaranteed to land."""
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(state)
    rng = _rng(seed, "state", shard)
    for i, leaf in enumerate(leaves):
        a = np.asarray(leaf)
        if a.nbytes:
            leaves[i] = flip_bit(a, rng)
            break
    return jax.tree_util.tree_unflatten(treedef, leaves)


def chaos_chunks(plan: FaultPlan, shard: int,
                 chunks: Iterable[np.ndarray], *,
                 attempt: int = 0) -> Iterator[np.ndarray]:
    """Wrap one shard's chunk stream with the plan's faults.

    A dropped shard raises :class:`ShardFailure` before yielding anything
    (all-or-nothing delivery — the loader/collector contract); a flaky
    attempt raises after a deterministic prefix of chunks has been
    *prepared but not delivered*; a straggler sleeps once up front;
    surviving chunks are then duplicated / bit-flipped per the plan."""
    if plan.is_dropped(shard):
        raise ShardFailure(f"shard {shard}: injected permanent drop")
    if plan.is_flaky(shard, attempt):
        raise ShardFailure(
            f"shard {shard}: injected transient failure (attempt {attempt})")
    d = plan.delay_for(shard)
    if d > 0:
        time.sleep(d)
    for i, c in enumerate(chunks):
        dup, cor = plan.chunk_events(shard, i)
        if cor:
            c = flip_bit(np.asarray(c), _rng(plan.seed, "corbits", shard, i))
        yield c
        if dup:
            yield c


def chaos_make_batch(plan: FaultPlan, make_batch: Callable
                     ) -> Callable:
    """Wrap a loader's ``make_batch(shard, batch_idx)``: dropped shards
    raise on every batch, stragglers sleep on their first batch, corrupt
    batches get one bit flipped.  (Duplicates are a *delivery* fault and
    cannot be expressed through make_batch — use :func:`chaos_chunks`.)"""
    def wrapped(shard: int, b: int):
        if plan.is_dropped(shard):
            raise ShardFailure(f"shard {shard}: injected permanent drop")
        if plan.is_flaky(shard, b):
            raise ShardFailure(
                f"shard {shard}: injected transient failure (batch {b})")
        if b == 0:
            d = plan.delay_for(shard)
            if d > 0:
                time.sleep(d)
        out = make_batch(shard, b)
        _, cor = plan.chunk_events(shard, b)
        if cor:
            out = flip_bit(np.asarray(out),
                           _rng(plan.seed, "corbits", shard, b))
        return out
    return wrapped


def chaos_shard_job(plan: FaultPlan, shard: int, fn: Callable[[], object]
                    ) -> Callable[[], object]:
    """Wrap a whole shard job (as submitted to ``resilience.
    collect_shards``).  The wrapper counts its own invocations, so the
    retry loop calling it repeatedly walks the (shard, attempt) decision
    sequence: permanent drops fail every attempt, flaky ones re-roll.

    When the job returns a ``(state, digest)`` pair and the corruption
    roll hits, the STATE is bit-flipped after the digest was computed —
    exactly the in-flight corruption the collector's ``verify=True``
    digest check is there to detect."""
    counter = [0]

    def wrapped():
        attempt = counter[0]
        counter[0] += 1
        if plan.is_dropped(shard):
            raise ShardFailure(f"shard {shard}: injected permanent drop")
        if plan.is_flaky(shard, attempt):
            raise ShardFailure(f"shard {shard}: injected transient failure "
                               f"(attempt {attempt})")
        d = plan.delay_for(shard)
        if d > 0:
            time.sleep(d)
        out = fn()
        _, cor = plan.chunk_events(shard, attempt)
        if cor and isinstance(out, tuple) and len(out) == 2:
            out = (corrupt_state(out[0], plan.seed, shard), out[1])
        return out
    return wrapped


def corrupt_file(path, seed: int = 0, mode: str = "flip",
                 truncate_frac: float = 0.5) -> None:
    """Damage a file on disk the way crashes and bit rot do — the
    checkpoint-integrity chaos primitive.

    ``mode="flip"`` flips one deterministic byte in place (silent
    corruption: the file still opens, the checksum catches it);
    ``mode="truncate"`` cuts the file to ``truncate_frac`` of its size
    (a torn write: the container itself fails to parse)."""
    path = os.fspath(path)
    size = os.path.getsize(path)
    if size == 0:
        raise ValueError(f"cannot corrupt empty file {path!r}")
    rng = _rng(seed, "file", os.path.basename(path))
    if mode == "flip":
        pos = int(rng.integers(0, size))
        with open(path, "r+b") as f:
            f.seek(pos)
            b = f.read(1)
            f.seek(pos)
            f.write(bytes([b[0] ^ (1 << int(rng.integers(0, 8)))]))
    elif mode == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(1, int(size * truncate_frac)))
    else:
        raise ValueError(f"unknown corruption mode {mode!r}; "
                         f"use 'flip' or 'truncate'")
