"""Geo-distributed sketching: the paper's topology on a JAX device mesh.

Paper §V: data at different geographic locations is sketched *in place*;
only the fixed-size sketches move; aggregation is a tree — within one data
center first, across data centers second.  On a TPU mesh that hierarchy is
exactly (ICI within a pod) × (DCN across pods):

    mesh axes ("pod", "data"):  "data" = workers inside one data center,
                                "pod"  = data centers.

``sketch_shard`` runs per device inside ``shard_map``: quantize → pack →
local Count Sketch + local exact top-L candidates.  ``psum`` over "data"
then "pod" merges the sketches (linearity!), ``all_gather`` shares the
candidate keys, and every device recovers the same global heavy hitters.

Privacy note (paper §V): only hashed, signed *sums* ever cross the pod
axis — the sketch is non-invertible; raw coordinates never leave a shard.

This module is also the template for the LM-side activation sketcher
(``repro.train.callbacks``) which reuses ``sketch_shard`` verbatim on
hidden-state projections.

The mesh/axis plumbing this stage pioneered (the ``shard_map`` compat
shim, linear shard indexing, row-block sizing) now lives in
:mod:`repro.core.mesh`, shared with the mesh-parallel EMBED stage
(``core.tsne``/``core.umap`` row-block-shard their iteration loops the
same way; ``SnsConfig.embed_mesh`` wires it through the pipeline) — the
whole ingest → HH → embed chain can run without leaving ``shard_map``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import candidates as cand_mod
from repro.core import heavy_hitters as hh_mod
from repro.core import mesh as mesh_mod
from repro.core import quantize, sketch as sketch_mod
from repro.core import stream as stream_mod
from repro.core.candidates import Candidates
from repro.core.heavy_hitters import HeavyHitters
from repro.core.mesh import shard_map_compat  # noqa: F401 (hoisted; re-export)
from repro.core.quantize import GridSpec
from repro.core.sketch import CountSketch


class GeoSketchResult(NamedTuple):
    hh: HeavyHitters            # replicated global top-K
    merged: CountSketch         # replicated merged sketch
    total_count: jnp.ndarray    # psum'd global item count (stream mass)
    # pmax'd candidate-stage watermark: the largest count any shard ever
    # withheld from the candidate set (local top-L truncation in the
    # one-shot path, reservoir eviction in the streaming path); 0 ⇒ every
    # occupied cell was proposed — the HH candidate set is complete
    evict_max: jnp.ndarray


def sketch_shard(sk: CountSketch, grid: GridSpec, points: jnp.ndarray,
                 candidate_pool: int,
                 mask: Optional[jnp.ndarray] = None,
                 ) -> Tuple[CountSketch, Candidates, jnp.ndarray]:
    """One edge node's work: quantize → pack → ONE sort+RLE feeding both
    the sketch scatter and the local top-L (the fused single-sort layout;
    the pre-fusion path sorted the same keys twice).  Also returns the
    local truncation watermark (largest count NOT proposed; 0 = none)."""
    key_hi, key_lo = quantize.points_to_keys(grid, points)
    runs = cand_mod.sorted_runs(
        key_hi, key_lo, mask=mask,
        assume_hi_zero=grid.dims * grid.bits_per_dim <= 32)
    sk = sketch_mod.update_runs(sk, runs)
    cands, dropped = cand_mod.topk_from_runs(runs, candidate_pool,
                                             return_dropped=True)
    return sk, cands, dropped


def geo_extract(mesh: Mesh, grid: GridSpec, points: jnp.ndarray,
                *, rows: int, log2_cols: int, top_k: int,
                candidate_pool: int = 0,
                data_axes: Union[str, Sequence[str]] = ("data",),
                seed: int = 0) -> GeoSketchResult:
    """End-to-end distributed heavy-hitter extraction.

    ``points``: (N, D) global array, batch dim sharded over ``data_axes``.
    Runs as a single SPMD program: every device sketches its shard, the
    sketches psum-merge hierarchically, candidates all_gather, and the
    replicated global top-K comes back.
    """
    if isinstance(data_axes, str):
        data_axes = (data_axes,)
    pool = candidate_pool or 2 * top_k
    # Hash params are drawn OUTSIDE shard_map from a shared seed — the
    # paper's requirement that every site uses identical hash functions.
    sk0 = sketch_mod.init(jax.random.key(seed), rows, log2_cols)

    pspec = P(tuple(data_axes))
    in_specs = (P(), pspec)           # sketch replicated, points sharded
    out_specs = (P(), P(), P(), P())  # everything replicated afterwards

    @shard_map_compat(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    def spmd(sk, pts):
        sk_local, cands, dropped = sketch_shard(sk, grid, pts, pool)
        hh, merged = hh_mod.distributed_extract(
            sk_local, cands, top_k, merge_axes=tuple(data_axes))
        n_local = jnp.full((), pts.shape[0], jnp.float32)
        total = jax.lax.psum(n_local, tuple(data_axes))
        evict = jax.lax.pmax(dropped, tuple(data_axes))
        return hh, merged, total, evict

    hh, merged, total, evict = spmd(sk0, points)
    return GeoSketchResult(hh=hh, merged=merged, total_count=total,
                           evict_max=evict)


def geo_extract_from_shards(mesh: Mesh, grid: GridSpec,
                            shard_fn, *, rows: int, log2_cols: int,
                            top_k: int, candidate_pool: int = 0,
                            data_axes: Union[str, Sequence[str]] = ("data",),
                            seed: int = 0, num_batches: int = 1
                            ) -> GeoSketchResult:
    """Streaming variant: each device *generates/loads* its own batches via
    ``shard_fn(device_linear_index, batch_index) -> (points, mask)`` traced
    inside the SPMD program (e.g. a synthetic generator or a sharded file
    reader).  ``batch_index`` arrives as a traced int32 scalar — index data
    with ``lax.dynamic_slice``/gather or fold it into a PRNG key.

    The batch loop is a ``lax.scan`` carrying ``stream.IngestState``
    (sketch ⊕ bounded candidate reservoir ⊕ count ⊕ eviction watermark),
    so per-device memory is O(batch + candidate_pool + sketch) regardless
    of stream length, and the trace is O(1) in ``num_batches`` — the
    paper's 'single stream I/O' regime.  (The previous implementation
    retained every batch's keys and Python-unrolled the loop, making both
    memory and trace O(stream); tests/test_stream_ingest.py pins the fixed
    behaviour via the jaxpr.)  The step is the fused single-sort fold
    (``stream.ingest_step``): one sort per batch feeds both the sketch
    scatter and the sorted-merge reservoir update —
    tests/test_fused_ingest.py pins the one-sort-per-step jaxpr.
    """
    if isinstance(data_axes, str):
        data_axes = (data_axes,)
    pool = candidate_pool or 2 * top_k
    sk0 = sketch_mod.init(jax.random.key(seed), rows, log2_cols)

    @shard_map_compat(mesh=mesh, in_specs=(P(),),
                      out_specs=(P(), P(), P(), P()))
    def spmd(sk):
        idx = mesh_mod.linear_index(mesh, data_axes)

        def step(st, b):
            pts, mask = shard_fn(idx, b)
            return stream_mod.ingest_step(st, grid, pts, mask=mask), ()

        st0 = stream_mod.from_sketch(sk, pool)
        st, _ = jax.lax.scan(step, st0,
                             jnp.arange(num_batches, dtype=jnp.int32))
        hh, merged = hh_mod.distributed_extract(
            st.sketch, st.cands, top_k, merge_axes=tuple(data_axes))
        total = jax.lax.psum(st.count, tuple(data_axes))
        evict = jax.lax.pmax(st.evict_max, tuple(data_axes))
        return hh, merged, total, evict

    hh, merged, total, evict = spmd(sk0)
    return GeoSketchResult(hh=hh, merged=merged, total_count=total,
                           evict_max=evict)
