"""Geo-distributed sketching: the paper's topology on a JAX device mesh.

Paper §V: data at different geographic locations is sketched *in place*;
only the fixed-size sketches move; aggregation is a tree — within one data
center first, across data centers second.  On a TPU mesh that hierarchy is
exactly (ICI within a pod) × (DCN across pods):

    mesh axes ("pod", "data"):  "data" = workers inside one data center,
                                "pod"  = data centers.

``sketch_shard`` runs per device inside ``shard_map``: quantize → pack →
local Count Sketch + local exact top-L candidates.  ``psum`` over "data"
then "pod" merges the sketches (linearity!), ``all_gather`` shares the
candidate keys, and every device recovers the same global heavy hitters.

Privacy note (paper §V): only hashed, signed *sums* ever cross the pod
axis — the sketch is non-invertible; raw coordinates never leave a shard.

This module is also the template for the LM-side activation sketcher
(``repro.train.callbacks``) which reuses ``sketch_shard`` verbatim on
hidden-state projections.

The mesh/axis plumbing this stage pioneered (the ``shard_map`` compat
shim, linear shard indexing, row-block sizing) now lives in
:mod:`repro.core.mesh`, shared with the mesh-parallel EMBED stage
(``core.tsne``/``core.umap`` row-block-shard their iteration loops the
same way; ``SnsConfig.embed_mesh`` wires it through the pipeline) — the
whole ingest → HH → embed chain can run without leaving ``shard_map``.

Failure semantics (two tiers, by construction):

* The SPMD paths above (``geo_extract``, ``geo_extract_from_shards``)
  run inside ONE ``shard_map`` program — XLA collectives cannot lose a
  participant, so a dead device fails the whole dispatch.  Nothing is
  retried and nothing degrades; this tier is all-or-nothing by design.
* :func:`resilient_extract` is the HOST-level topology the paper
  actually describes (edge nodes ship summaries to a master): each
  shard's fold is an independent job, transient failures are RETRIED
  under a ``resilience.RetryPolicy``, stragglers are cut off at a
  deadline, and lost shards DEGRADE into partial aggregation — the
  surviving sketches merge linearly (``stream.merge_states``), coverage
  drops below 1, and the heavy-hitter error bound widens by the
  estimated lost mass.  ``min_coverage`` is the fail-loud floor.
"""
from __future__ import annotations

from typing import (Callable, Dict, List, Mapping, NamedTuple, Optional,
                    Sequence, Tuple, Union)

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import candidates as cand_mod
from repro.core import heavy_hitters as hh_mod
from repro.core import mesh as mesh_mod
from repro.core import quantize, sketch as sketch_mod
from repro.core import stream as stream_mod
from repro.core.candidates import Candidates
from repro.core.heavy_hitters import HeavyHitters
from repro.core.mesh import shard_map_compat  # noqa: F401 (hoisted; re-export)
from repro.core.quantize import GridSpec
from repro.core.sketch import CountSketch


class GeoSketchResult(NamedTuple):
    hh: HeavyHitters            # replicated global top-K
    merged: CountSketch         # replicated merged sketch
    total_count: jnp.ndarray    # psum'd global item count (stream mass)
    # pmax'd candidate-stage watermark: the largest count any shard ever
    # withheld from the candidate set (local top-L truncation in the
    # one-shot path, reservoir eviction in the streaming path); 0 ⇒ every
    # occupied cell was proposed — the HH candidate set is complete
    evict_max: jnp.ndarray


def sketch_shard(sk: CountSketch, grid: GridSpec, points: jnp.ndarray,
                 candidate_pool: int,
                 mask: Optional[jnp.ndarray] = None,
                 ) -> Tuple[CountSketch, Candidates, jnp.ndarray]:
    """One edge node's work: quantize → pack → ONE sort+RLE feeding both
    the sketch scatter and the local top-L (the fused single-sort layout;
    the pre-fusion path sorted the same keys twice).  Also returns the
    local truncation watermark (largest count NOT proposed; 0 = none)."""
    key_hi, key_lo = quantize.points_to_keys(grid, points)
    runs = cand_mod.sorted_runs(
        key_hi, key_lo, mask=mask,
        assume_hi_zero=grid.dims * grid.bits_per_dim <= 32)
    sk = sketch_mod.update_runs(sk, runs)
    cands, dropped = cand_mod.topk_from_runs(runs, candidate_pool,
                                             return_dropped=True)
    return sk, cands, dropped


def geo_extract(mesh: Mesh, grid: GridSpec, points: jnp.ndarray,
                *, rows: int, log2_cols: int, top_k: int,
                candidate_pool: int = 0,
                data_axes: Union[str, Sequence[str]] = ("data",),
                seed: int = 0) -> GeoSketchResult:
    """End-to-end distributed heavy-hitter extraction.

    ``points``: (N, D) global array, batch dim sharded over ``data_axes``.
    Runs as a single SPMD program: every device sketches its shard, the
    sketches psum-merge hierarchically, candidates all_gather, and the
    replicated global top-K comes back.
    """
    if isinstance(data_axes, str):
        data_axes = (data_axes,)
    pool = candidate_pool or 2 * top_k
    # Hash params are drawn OUTSIDE shard_map from a shared seed — the
    # paper's requirement that every site uses identical hash functions.
    sk0 = sketch_mod.init(jax.random.key(seed), rows, log2_cols)

    pspec = P(tuple(data_axes))
    in_specs = (P(), pspec)           # sketch replicated, points sharded
    out_specs = (P(), P(), P(), P())  # everything replicated afterwards

    @shard_map_compat(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    def spmd(sk, pts):
        sk_local, cands, dropped = sketch_shard(sk, grid, pts, pool)
        hh, merged = hh_mod.distributed_extract(
            sk_local, cands, top_k, merge_axes=tuple(data_axes))
        n_local = jnp.full((), pts.shape[0], jnp.float32)
        total = jax.lax.psum(n_local, tuple(data_axes))
        evict = jax.lax.pmax(dropped, tuple(data_axes))
        return hh, merged, total, evict

    hh, merged, total, evict = spmd(sk0, points)
    return GeoSketchResult(hh=hh, merged=merged, total_count=total,
                           evict_max=evict)


def geo_extract_from_shards(mesh: Mesh, grid: GridSpec,
                            shard_fn, *, rows: int, log2_cols: int,
                            top_k: int, candidate_pool: int = 0,
                            data_axes: Union[str, Sequence[str]] = ("data",),
                            seed: int = 0, num_batches: int = 1
                            ) -> GeoSketchResult:
    """Streaming variant: each device *generates/loads* its own batches via
    ``shard_fn(device_linear_index, batch_index) -> (points, mask)`` traced
    inside the SPMD program (e.g. a synthetic generator or a sharded file
    reader).  ``batch_index`` arrives as a traced int32 scalar — index data
    with ``lax.dynamic_slice``/gather or fold it into a PRNG key.

    The batch loop is a ``lax.scan`` carrying ``stream.IngestState``
    (sketch ⊕ bounded candidate reservoir ⊕ count ⊕ eviction watermark),
    so per-device memory is O(batch + candidate_pool + sketch) regardless
    of stream length, and the trace is O(1) in ``num_batches`` — the
    paper's 'single stream I/O' regime.  (The previous implementation
    retained every batch's keys and Python-unrolled the loop, making both
    memory and trace O(stream); tests/test_stream_ingest.py pins the fixed
    behaviour via the jaxpr.)  The step is the fused single-sort fold
    (``stream.ingest_step``): one sort per batch feeds both the sketch
    scatter and the sorted-merge reservoir update —
    tests/test_fused_ingest.py pins the one-sort-per-step jaxpr.
    """
    if isinstance(data_axes, str):
        data_axes = (data_axes,)
    pool = candidate_pool or 2 * top_k
    sk0 = sketch_mod.init(jax.random.key(seed), rows, log2_cols)

    @shard_map_compat(mesh=mesh, in_specs=(P(),),
                      out_specs=(P(), P(), P(), P()))
    def spmd(sk):
        idx = mesh_mod.linear_index(mesh, data_axes)

        def step(st, b):
            pts, mask = shard_fn(idx, b)
            return stream_mod.ingest_step(st, grid, pts, mask=mask), ()

        st0 = stream_mod.from_sketch(sk, pool)
        st, _ = jax.lax.scan(step, st0,
                             jnp.arange(num_batches, dtype=jnp.int32))
        hh, merged = hh_mod.distributed_extract(
            st.sketch, st.cands, top_k, merge_axes=tuple(data_axes))
        total = jax.lax.psum(st.count, tuple(data_axes))
        evict = jax.lax.pmax(st.evict_max, tuple(data_axes))
        return hh, merged, total, evict

    hh, merged, total, evict = spmd(sk0)
    return GeoSketchResult(hh=hh, merged=merged, total_count=total,
                           evict_max=evict)


class ResilientExtractResult(NamedTuple):
    """Partial-aggregation-aware extraction result: the usual replicated
    outputs plus the quantified damage of whatever was lost."""
    hh: HeavyHitters              # top-K over the OBSERVED sub-stream
    merged: CountSketch           # merge of the shards that delivered
    observed_count: float         # mass actually folded
    coverage: float               # observed / expected   (1.0 = no loss)
    hh_error_bound: float         # survivor watermark + estimated lost mass
    lost: Tuple[int, ...]         # shard ids that never delivered
    statuses: list                # per-shard resilience.ShardStatus
    retries: int                  # extra attempts beyond the first, total


def shard_ingest_jobs(grid: GridSpec, shard_chunks: Mapping, *,
                      seed: int, rows: int, log2_cols: int, pool: int,
                      chunk_size: int, superbatch: int = 1,
                      faults=None) -> Dict[int, Callable[[], tuple]]:
    """Build the per-shard fold jobs ``resilience.collect_shards`` runs.

    ``shard_chunks`` maps shard id → a chunk source (an iterable of
    (n, D) arrays, or a zero-arg callable returning one — callables are
    re-invoked per attempt, so a retried shard re-reads its data).  All
    jobs draw hash params from the SAME seed (the paper's identical-
    hash-functions contract), so their states merge linearly.  Each job
    returns ``(state, digest)`` with the digest computed at the source —
    the collector's ``verify=True`` detects in-transit corruption.

    ``faults`` (a :class:`repro.core.faults.FaultPlan`) wraps both the
    chunk stream and the job itself — the reproducible-chaos hook the
    tests and ``bench_resilience`` drive."""
    import dataclasses as _dc

    from repro.core import faults as faults_mod

    # split the plan across its two injection points: delivery faults
    # (drop / flaky / delay) fire ONCE per attempt in chaos_shard_job —
    # whose counter ticks on every attempt — while the chunk wrapper
    # inside the job carries only the data faults (duplicate / corrupt).
    # Injecting delivery faults at both levels would double-apply them
    # with drifting attempt counters (the inner one only advances when
    # the outer roll passes), turning transient faults semi-permanent.
    chunk_faults = None if faults is None else _dc.replace(
        faults, drop=0.0, drop_shards=(), flaky=0.0, delay=0.0)

    jobs: Dict[int, Callable[[], tuple]] = {}
    for shard, source in shard_chunks.items():
        def job(shard=shard, source=source, attempt_box=[0]):
            attempt = attempt_box[0]
            attempt_box[0] += 1
            chunks = source() if callable(source) else source
            if chunk_faults is not None:
                chunks = faults_mod.chaos_chunks(chunk_faults, shard,
                                                 chunks, attempt=attempt)
            st = stream_mod.init(jax.random.key(seed), rows, log2_cols,
                                 pool)
            st = stream_mod.ingest_all(st, grid, chunks, chunk_size,
                                       superbatch=superbatch)
            st = jax.tree_util.tree_map(
                lambda x: jax.device_get(x), st)   # ship host-side bytes
            return st, stream_mod.state_digest(st)
        if faults is not None:
            # job-level faults (permanent drop / flaky / delay / state
            # corruption after the digest) stack on the chunk-level ones
            jobs[shard] = faults_mod.chaos_shard_job(faults, shard, job)
        else:
            jobs[shard] = job
    return jobs


def resilient_extract(grid: GridSpec, shard_chunks, *,
                      rows: int, log2_cols: int, top_k: int,
                      candidate_pool: int = 0, seed: int = 0,
                      chunk_size: int = 65_536, superbatch: int = 1,
                      policy=None, deadline: Optional[float] = None,
                      min_coverage: float = 0.0,
                      expected_counts: Optional[Mapping[int, float]] = None,
                      faults=None) -> ResilientExtractResult:
    """Host-level fault-tolerant heavy-hitter extraction.

    The paper's actual topology: every shard folds its own stream into a
    (sketch ⊕ reservoir) summary and ships ONLY the summary; the master
    merges.  Unlike the ``shard_map`` SPMD paths, shards here are
    independent host-side jobs, so the full failure menu applies and is
    handled (see the module docstring's failure-semantics contract):
    transient errors retry under ``policy``, stragglers are cut off at
    ``deadline``, permanent losses degrade into partial aggregation with
    ``coverage < 1`` and a widened ``hh_error_bound``, and coverage
    below ``min_coverage`` raises ``resilience.CoverageError``.

    ``shard_chunks``: mapping shard id → chunk source, or a sequence
    (ids 0..S-1).  ``grid`` must be agreed up front (the shared-
    hypercube contract — geo-distributed sites cannot take a global
    min/max pass)."""
    from repro.core import resilience

    if not isinstance(shard_chunks, Mapping):
        shard_chunks = dict(enumerate(shard_chunks))
    if not shard_chunks:
        raise ValueError("resilient_extract needs at least one shard")
    pool = candidate_pool or 2 * top_k
    jobs = shard_ingest_jobs(
        grid, shard_chunks, seed=seed, rows=rows, log2_cols=log2_cols,
        pool=pool, chunk_size=chunk_size, superbatch=superbatch,
        faults=faults)
    agg = resilience.collect_shards(
        jobs, policy=policy, deadline=deadline, min_coverage=min_coverage,
        expected_counts=expected_counts, verify=True)
    hh = hh_mod.from_candidates(agg.state.sketch, agg.state.cands, top_k)
    return ResilientExtractResult(
        hh=hh, merged=agg.state.sketch,
        observed_count=agg.observed_count, coverage=agg.coverage,
        hh_error_bound=agg.hh_error_bound, lost=agg.lost,
        statuses=agg.statuses, retries=agg.retries)
