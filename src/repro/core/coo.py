"""Shared sorted-COO segment-reduction core for the sparse embedders.

Both sub-quadratic embed engines — the sparse tSNE backend
(``tsne.SparseP``) and the scatter-free UMAP epoch loop
(``umap.optimize_embedding``) — reduce per-edge quantities into per-point
accumulators every optimizer step, over E = O(N·k) fixed-shape COO edges.
The natural primitive is a scatter-add, but XLA's CPU scatter visits
updates one at a time: at E ~ 10⁷ a single ``.at[].add`` costs seconds
where a vectorized pass costs ~100 ms (~100× — measured in
benchmarks/bench_embed_throughput.py).  This module is the scatter-free
alternative both consumers share:

* sort the edge list by the reduction key ONCE at setup (``lexsort`` /
  stable ``argsort``) and precompute the per-row slice boundaries
  (:func:`row_bounds`);
* each step, reduce with :func:`segment_reduce` — an O(E) cumulative sum
  whose per-row totals are differences at the precomputed boundaries.
  Zero scatter primitives appear in the step jaxpr (regression-pinned in
  tests/test_sparse_tsne.py and tests/test_umap_scatter_free.py).

For consumers that must reduce over BOTH endpoints of every edge (UMAP:
the attractive force moves src and dst in opposite directions),
:func:`edge_layout` additionally builds the dst-sorted ordering and the
gather permutation between the two orderings, so the second reduction is
one gather + one more cumsum — still no scatter.

Everything here is shape-static and jit-compatible; the sorts live in the
one-time setup, never inside the per-iteration jaxpr.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


def row_bounds(sorted_ids: jnp.ndarray, n: int) -> jnp.ndarray:
    """Per-row slice boundaries of a sorted id list: row i owns entries
    [bounds[i], bounds[i+1]).  The invariant every scatter-free cumsum
    reduction in this module builds on."""
    return jnp.searchsorted(sorted_ids,
                            jnp.arange(n + 1)).astype(jnp.int32)


def segment_reduce(vals: jnp.ndarray, bounds: jnp.ndarray) -> jnp.ndarray:
    """Per-row sums of row-sorted per-edge values — WITHOUT scatter.

    ``vals`` is (E,) or (E, D), ordered so that row i's entries occupy
    the contiguous slice [bounds[i], bounds[i+1]) (see :func:`row_bounds`).
    Σ over a row = cumsum difference at the row boundaries: one vectorized
    O(E) pass, versus XLA CPU scatter's serial update walk (~100× slower
    at E ~ 10⁷).  Returns (N,) or (N, D).
    """
    zero = jnp.zeros((1,) + vals.shape[1:], vals.dtype)
    cs = jnp.concatenate([zero, jnp.cumsum(vals, axis=0)])
    return cs[bounds[1:]] - cs[bounds[:-1]]


class EdgeLayout(NamedTuple):
    """Bidirectional reduction plan over a fixed-shape COO edge list.

    Built once at setup (two sorts), consumed every optimizer step with
    zero scatter primitives:

    * ``src``/``dst`` — the edge list, sorted by ``src`` (stable, so an
      already-src-sorted input keeps its edge order — this is what lets
      per-edge RNG streams line up with the pre-layout reference path);
    * ``src_bounds`` — row slices of the src-sorted order, for reducing
      per-edge values into their SOURCE points via :func:`segment_reduce`;
    * ``dst_order``/``dst_bounds`` — gather permutation into the
      dst-sorted ordering plus its row slices, for reducing the same
      per-edge values into their DESTINATION points:
      ``segment_reduce(vals[dst_order], dst_bounds)``.
    """
    src: jnp.ndarray         # (E,) int32, sorted ascending
    dst: jnp.ndarray         # (E,) int32 (src-sorted edge order)
    src_bounds: jnp.ndarray  # (N+1,) int32
    dst_order: jnp.ndarray   # (E,) int32: edge order -> dst-sorted order
    dst_bounds: jnp.ndarray  # (N+1,) int32


def edge_layout(src: jnp.ndarray, dst: jnp.ndarray, n: int
                ) -> Tuple[EdgeLayout, jnp.ndarray]:
    """Build the bidirectional reduction plan for a COO edge list.

    Returns (layout, order) where ``order`` is the stable src-sort
    permutation applied to the inputs — gather any per-edge payload with
    it once (``memb[order]``) to match the layout's edge order.
    """
    order = jnp.argsort(src, stable=True)
    s = src[order].astype(jnp.int32)
    d = dst[order].astype(jnp.int32)
    dst_order = jnp.argsort(d, stable=True).astype(jnp.int32)
    return EdgeLayout(
        src=s, dst=d,
        src_bounds=row_bounds(s, n),
        dst_order=dst_order,
        dst_bounds=row_bounds(d[dst_order], n)), order


def dedupe_edges(src: jnp.ndarray, dst: jnp.ndarray, val: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Canonical COO: sort by (src, dst), fold duplicate ordered pairs.

    Returns (src, dst, val) of the same fixed shape (E,), sorted
    lexicographically, where each distinct ordered pair carries its total
    value on the first entry of its run and 0 on the duplicates.  Total
    mass is preserved exactly; downstream segment-sums are unaffected by
    the zeroed duplicate slots, while per-pair quantities (Σ p log p, the
    symmetry check) become well defined.

    Setup-time only (the run-head fold is a segment_sum scatter); the
    per-iteration reductions go through :func:`segment_reduce`.
    """
    e = src.shape[0]
    order = jnp.lexsort((dst, src))
    s, d, v = src[order], dst[order], val[order]
    new_run = jnp.concatenate([
        jnp.ones((1,), bool), (s[1:] != s[:-1]) | (d[1:] != d[:-1])])
    run_id = jnp.cumsum(new_run) - 1
    run_sum = jax.ops.segment_sum(v, run_id, num_segments=e)
    v_out = jnp.where(new_run, run_sum[run_id], 0.0)
    return s, d, v_out
