"""Shared sorted-COO segment-reduction core for the sparse embedders.

Both sub-quadratic embed engines — the sparse tSNE backend
(``tsne.SparseP``) and the scatter-free UMAP epoch loop
(``umap.optimize_embedding``) — reduce per-edge quantities into per-point
accumulators every optimizer step, over E = O(N·k) fixed-shape COO edges.
The natural primitive is a scatter-add, but XLA's CPU scatter visits
updates one at a time: at E ~ 10⁷ a single ``.at[].add`` costs seconds
where a vectorized pass costs ~100 ms (~100× — measured in
benchmarks/bench_embed_throughput.py).  This module is the scatter-free
alternative both consumers share:

* sort the edge list by the reduction key ONCE at setup (``lexsort`` /
  stable ``argsort``) and precompute the per-row slice boundaries
  (:func:`row_bounds`);
* each step, reduce with :func:`segment_reduce` — an O(E) cumulative sum
  whose per-row totals are differences at the precomputed boundaries.
  Zero scatter primitives appear in the step jaxpr (regression-pinned in
  tests/test_sparse_tsne.py and tests/test_umap_scatter_free.py).

For consumers that must reduce over BOTH endpoints of every edge (UMAP:
the attractive force moves src and dst in opposite directions),
:func:`edge_layout` additionally builds the dst-sorted ordering and the
gather permutation between the two orderings, so the second reduction is
one gather + one more cumsum — still no scatter.

For the mesh-parallel embed stage, :class:`ShardedEdgeLayout` row-block
shards the same machinery: each device owns a contiguous src-row range
(and, because the edge list is src-sorted, a CONTIGUOUS padded slice of
the edge array), runs the identical local cumsum-difference reduction
over its slice, and cross-block dst contributions travel as one ``psum``
of per-block full-length partials — still zero scatter primitives,
per-device (tests/test_mesh_embed.py pins the sharded jaxpr).

Everything here is shape-static and jit-compatible; the sorts live in the
one-time setup, never inside the per-iteration jaxpr.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def row_bounds(sorted_ids: jnp.ndarray, n: int) -> jnp.ndarray:
    """Per-row slice boundaries of a sorted id list: row i owns entries
    [bounds[i], bounds[i+1]).  The invariant every scatter-free cumsum
    reduction in this module builds on."""
    return jnp.searchsorted(sorted_ids,
                            jnp.arange(n + 1)).astype(jnp.int32)


def segment_reduce(vals: jnp.ndarray, bounds: jnp.ndarray,
                   mode: Optional[str] = None) -> jnp.ndarray:
    """Per-row sums of row-sorted per-edge values — WITHOUT scatter.

    ``vals`` is (E,) or (E, D), ordered so that row i's entries occupy
    the contiguous slice [bounds[i], bounds[i+1]) (see :func:`row_bounds`).
    Σ over a row = cumsum difference at the row boundaries: one vectorized
    O(E) pass, versus XLA CPU scatter's serial update walk (~100× slower
    at E ~ 10⁷).  Returns (N,) or (N, D).

    ``mode`` routes through the kernel registry (op ``segment_reduce``):
    None or "xla" keeps the cumsum-difference path below (the CPU
    default, unless the process pins another mode via SNS_KERNEL_MODE /
    a registry override); "interpret"/"compiled" run the fused Pallas
    kernel (``kernels.segment_reduce``), "auto" resolves per backend —
    on accelerators that picks the fused kernel, on CPU it falls back
    to the cumsum path.
    """
    if mode is None or mode == "auto":
        from repro.kernels import registry
        pinned = registry.resolve_mode(None, "segment_reduce")
        mode = pinned if pinned != "auto" else mode
    if mode not in (None, "xla"):
        from repro.kernels import registry
        impl = registry.resolve("segment_reduce", mode=mode,
                                shape=vals.shape, dtype=vals.dtype)
        if impl.mode != "xla":
            return impl.fn(vals, bounds,
                           **registry.tile_params("segment_reduce",
                                                  shape=vals.shape))
    zero = jnp.zeros((1,) + vals.shape[1:], vals.dtype)
    cs = jnp.concatenate([zero, jnp.cumsum(vals, axis=0)])
    return cs[bounds[1:]] - cs[bounds[:-1]]


class EdgeLayout(NamedTuple):
    """Bidirectional reduction plan over a fixed-shape COO edge list.

    Built once at setup (two sorts), consumed every optimizer step with
    zero scatter primitives:

    * ``src``/``dst`` — the edge list, sorted by ``src`` (stable, so an
      already-src-sorted input keeps its edge order — this is what lets
      per-edge RNG streams line up with the pre-layout reference path);
    * ``src_bounds`` — row slices of the src-sorted order, for reducing
      per-edge values into their SOURCE points via :func:`segment_reduce`;
    * ``dst_order``/``dst_bounds`` — gather permutation into the
      dst-sorted ordering plus its row slices, for reducing the same
      per-edge values into their DESTINATION points:
      ``segment_reduce(vals[dst_order], dst_bounds)``.
    """
    src: jnp.ndarray         # (E,) int32, sorted ascending
    dst: jnp.ndarray         # (E,) int32 (src-sorted edge order)
    src_bounds: jnp.ndarray  # (N+1,) int32
    dst_order: jnp.ndarray   # (E,) int32: edge order -> dst-sorted order
    dst_bounds: jnp.ndarray  # (N+1,) int32


def edge_layout(src: jnp.ndarray, dst: jnp.ndarray, n: int
                ) -> Tuple[EdgeLayout, jnp.ndarray]:
    """Build the bidirectional reduction plan for a COO edge list.

    Returns (layout, order) where ``order`` is the stable src-sort
    permutation applied to the inputs — gather any per-edge payload with
    it once (``memb[order]``) to match the layout's edge order.
    """
    order = jnp.argsort(src, stable=True)
    s = src[order].astype(jnp.int32)
    d = dst[order].astype(jnp.int32)
    dst_order = jnp.argsort(d, stable=True).astype(jnp.int32)
    return EdgeLayout(
        src=s, dst=d,
        src_bounds=row_bounds(s, n),
        dst_order=dst_order,
        dst_bounds=row_bounds(d[dst_order], n)), order


class ShardedEdgeLayout(NamedTuple):
    """Row-block-sharded reduction plan over a src-sorted COO edge list.

    Device s owns the contiguous global row range
    [s·rows_per, (s+1)·rows_per) (``rows_per`` = ``src_bounds.shape[1]−1``;
    the last block may contain padded rows beyond N).  Because the input
    edge list is sorted by src, each block's edges are a CONTIGUOUS slice
    of the global array; slices are padded to the max per-block edge count
    Ep so every leading-axis entry has the same shape and the whole layout
    enters ``shard_map`` with ``P(axis)`` in-specs (device s sees its own
    (Ep,)-rows after squeezing).

    Per-device reduction contract (all scatter-free, see
    tests/test_mesh_embed.py for the jaxpr pin):

    * src side — ``segment_reduce(vals, src_bounds[s])`` over LOCAL row
      ids (``src − s·rows_per``) gives the block's (rows_per, ...) sums;
    * dst side — ``segment_reduce(vals[dst_order[s]], dst_bounds[s])``
      gives a FULL-LENGTH (n_padded, ...) per-block partial over GLOBAL
      dst rows; one ``psum`` over the mesh axis totals the cross-block
      contributions (no cross-device scatter anywhere);
    * padded edge slots repeat the block's last real edge with
      ``edge_mask`` False — gather any payload through
      :func:`shard_payload`, which zeroes them, so they vanish from every
      linear reduction;
    * ``edge_ids`` maps each slot back to its global edge index — the
      hook that keeps per-edge RNG streams draw-for-draw aligned with the
      single-device path (draw globally, gather by ``edge_ids``).
    """
    src: jnp.ndarray         # (S, Ep) int32 global src ids, sorted per block
    dst: jnp.ndarray         # (S, Ep) int32 global dst ids
    edge_ids: jnp.ndarray    # (S, Ep) int32 global edge index of each slot
    edge_mask: jnp.ndarray   # (S, Ep) bool, False on padded slots
    src_bounds: jnp.ndarray  # (S, rows_per+1) int32, LOCAL-row slices
    dst_order: jnp.ndarray   # (S, Ep) int32: block order -> dst-sorted order
    dst_bounds: jnp.ndarray  # (S, n_padded+1) int32, GLOBAL-row slices
    row_offset: jnp.ndarray  # (S,) int32 first global row of each block

    @property
    def n_shards(self) -> int:
        return self.src.shape[0]

    @property
    def rows_per_shard(self) -> int:
        return self.src_bounds.shape[1] - 1

    @property
    def n_padded(self) -> int:
        return self.dst_bounds.shape[1] - 1


def shard_edge_layout(src, dst, n: int, n_shards: int) -> ShardedEdgeLayout:
    """Build the row-block-sharded reduction plan — host-side, setup-time.

    ``src``/``dst`` are the (E,) global edge list, ``src`` sorted
    ascending (the invariant :func:`edge_layout` and ``tsne.SparseP``
    already maintain).  Runs in numpy on concrete arrays: the per-block
    edge counts are data-dependent, so the padded width Ep must be known
    before anything is traced.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    e = src.shape[0]
    if e and np.any(src[1:] < src[:-1]):
        raise ValueError("shard_edge_layout needs a src-sorted edge list")
    rows_per = -(-n // n_shards)
    n_pad = rows_per * n_shards
    starts = np.searchsorted(src, np.arange(n_shards) * rows_per)
    ends = np.append(starts[1:], e)
    ep = max(1, int(np.max(ends - starts)))

    ids = np.empty((n_shards, ep), np.int64)
    mask = np.empty((n_shards, ep), bool)
    src_b = np.empty((n_shards, rows_per + 1), np.int64)
    dst_b = np.empty((n_shards, n_pad + 1), np.int64)
    dst_o = np.empty((n_shards, ep), np.int64)
    for s in range(n_shards):
        cnt = ends[s] - starts[s]
        # padded slots repeat the block's last real edge (or edge 0 for an
        # empty block): their src stays inside the block, keeping the
        # per-block src-sorted invariant, and shard_payload zeroes them
        last = max(starts[s], ends[s] - 1) if cnt else 0
        row = np.minimum(starts[s] + np.arange(ep), last)
        ids[s] = row
        mask[s] = np.arange(ep) < cnt
        local = src[row] - s * rows_per
        src_b[s] = np.searchsorted(local, np.arange(rows_per + 1))
        order = np.argsort(dst[row], kind="stable")
        dst_o[s] = order
        dst_b[s] = np.searchsorted(dst[row][order], np.arange(n_pad + 1))

    return ShardedEdgeLayout(
        src=jnp.asarray(src[ids], jnp.int32),
        dst=jnp.asarray(dst[ids], jnp.int32),
        edge_ids=jnp.asarray(ids, jnp.int32),
        edge_mask=jnp.asarray(mask),
        src_bounds=jnp.asarray(src_b, jnp.int32),
        dst_order=jnp.asarray(dst_o, jnp.int32),
        dst_bounds=jnp.asarray(dst_b, jnp.int32),
        row_offset=jnp.asarray(np.arange(n_shards) * rows_per, jnp.int32))


def shard_payload(layout: ShardedEdgeLayout, vals: jnp.ndarray
                  ) -> jnp.ndarray:
    """Gather a (E, ...) per-edge payload into the sharded layout's
    (S, Ep, ...) slot order, zeroed on padded slots — padded edges then
    contribute exactly nothing to any linear reduction."""
    out = jnp.asarray(vals)[layout.edge_ids]
    m = layout.edge_mask
    return jnp.where(m.reshape(m.shape + (1,) * (out.ndim - 2)), out, 0)


def dedupe_edges(src: jnp.ndarray, dst: jnp.ndarray, val: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Canonical COO: sort by (src, dst), fold duplicate ordered pairs.

    Returns (src, dst, val) of the same fixed shape (E,), sorted
    lexicographically, where each distinct ordered pair carries its total
    value on the first entry of its run and 0 on the duplicates.  Total
    mass is preserved exactly; downstream segment-sums are unaffected by
    the zeroed duplicate slots, while per-pair quantities (Σ p log p, the
    symmetry check) become well defined.

    Setup-time only (the run-head fold is a segment_sum scatter); the
    per-iteration reductions go through :func:`segment_reduce`.
    """
    e = src.shape[0]
    order = jnp.lexsort((dst, src))
    s, d, v = src[order], dst[order], val[order]
    new_run = jnp.concatenate([
        jnp.ones((1,), bool), (s[1:] != s[:-1]) | (d[1:] != d[:-1])])
    run_id = jnp.cumsum(new_run) - 1
    run_sum = jax.ops.segment_sum(v, run_id, num_segments=e)
    v_out = jnp.where(new_run, run_sum[run_id], 0.0)
    return s, d, v_out
