"""Sketch-and-Scale end-to-end pipeline (paper Fig. 1).

    1. set a regular grid            → core.quantize.fit_grid[_streaming]
    2. count points, find heavy bins → core.sketch/stream + heavy_hitters
    3. representatives per heavy bin → core.replicas
    4. feed into tSNE / UMAP         → core.tsne / core.umap

Single-host and mesh-distributed front-ends share all stages; only stage 2
differs (local sketch vs. shard_map + psum via core.geo).

Two ingest regimes for stage 1-2:

* one-shot — ``run(cfg, points)`` with the full (N, D) array resident;
* streaming — ``run_streaming(cfg, chunks)`` folds a chunk iterator
  through ``core.stream.IngestState`` (bounded memory, two passes: chunked
  min/max for the grid, then sketch+reservoir).  ``chunks_from_loader``
  adapts a ``data.loader.ShardedLoader`` plan into the re-iterable chunk
  stream this needs.

The two regimes are *equivalent*: on the same data (and a candidate pool
that covers the distinct occupied cells) they produce bit-identical heavy
hitters — tests/test_stream_ingest.py property-tests the contract.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import candidates as cand_mod
from repro.core import geo, heavy_hitters as hh_mod, quantize, replicas
from repro.core import mesh as mesh_mod
from repro.core import sketch as sketch_mod
from repro.core import stream as stream_mod
from repro.core import tsne as tsne_mod
from repro.core import u64
from repro.core import umap as umap_mod
from repro.core.heavy_hitters import HeavyHitters
from repro.core.quantize import GridSpec
from repro.core.replicas import Representatives


@dataclasses.dataclass(frozen=True)
class SnsConfig:
    """Paper-parameterized pipeline config (defaults = cancer experiment)."""
    bins: int = 25                 # M, linear bins per axis
    rows: int = 16                 # R, sketch rows
    log2_cols: int = 18            # C = 2^18 ≈ the paper's 2·10^5
    top_k: int = 20_000            # heavy hitters to extract
    candidate_pool: int = 0        # 0 -> 2*top_k (reservoir size L too)
    ingest_chunk: int = 65_536     # streaming ingest: points per chunk step
    ingest_superbatch: int = 8     # chunks folded per dispatch (1 = off)
    replica_scheme: str = "count"  # "uniform" | "rank" | "count"
    max_replicas: int = 8
    jitter_frac: float = 0.25
    embedder: str = "umap"         # "umap" | "tsne"
    embed_dims: int = 2
    # tSNE gradient: "dense"|"tiled"|"pallas" (exact, O(N²) per iter) or
    # "sparse" (kNN attraction + FFT grid repulsion, O(N·k + G²logG) —
    # the N = 10⁵-10⁶ representative regime)
    embed_backend: str = "dense"
    embed_block: int = 512         # row-block for tiled/pallas tSNE + UMAP kNN
    embed_knn: int = 0             # sparse tSNE: kNN fan-out (0 → 3·perp)
    embed_grid: int = 128          # sparse tSNE: FFT repulsion grid G
    # sparse tSNE adaptive grid: > 0 = target cell spacing (embed_grid
    # becomes the starting G and doubles with the embedding span up to
    # embed_grid_max — FIt-SNE-style, see tsne.TsneConfig.grid_interval)
    embed_grid_interval: float = 0.0
    embed_grid_max: int = 1024
    embed_cic: str = "xla"         # grid splat/gather: "xla" | "pallas"
    # kNN build for BOTH embedders: "exact" (brute force, O(N²·D)),
    # "ann" (sub-quadratic sketch-bucketing + NN-descent, core.ann), or
    # "auto" (exact below ann.AnnConfig.auto_threshold points, ann
    # above — the safe default).  embed_ann optionally carries the
    # recall/probe knobs as an ann.AnnConfig (None = defaults)
    embed_knn_method: str = "auto"
    embed_ann: object = None       # None | ann.AnnConfig
    # mesh-parallel embed stage: None = single device; an int builds a 1-D
    # mesh of that many local devices; a ready jax Mesh passes through.
    # Row-block-shards the kNN build + the whole optimizer loop of BOTH
    # embedders under shard_map (sparse tSNE only — see tsne.run_tsne);
    # collective contract in core.mesh
    embed_mesh: object = None      # None | int | jax.sharding.Mesh
    # Pallas kernel dispatch tier for the embed stage (kernels.registry):
    # "auto" = compiled → interpret → xla for the current backend;
    # "compiled"|"interpret"|"xla" force one tier for every registry op
    # (cic splat/gather, tSNE force tile, kNN distance scan, the fused
    # segment-reduce).  CPU CI pins interpret/xla; accelerators keep auto.
    kernel_mode: str = "auto"
    seed: int = 0

    def __post_init__(self):
        """Fail-loud validation: nonsensical values are caught HERE with
        a message naming the knob, instead of surfacing as shape errors
        deep inside a jitted trace."""
        checks = [
            (self.bins >= 2, f"bins (grid M) must be >= 2, got {self.bins}"),
            (self.rows >= 1,
             f"rows (sketch R) must be >= 1 — a zero-row sketch estimates "
             f"nothing; got {self.rows}"),
            (1 <= self.log2_cols <= 31,
             f"log2_cols must be in [1, 31], got {self.log2_cols}"),
            (self.top_k >= 1, f"top_k must be >= 1, got {self.top_k}"),
            (self.candidate_pool >= 0,
             f"candidate_pool must be >= 0 (0 = 2*top_k), "
             f"got {self.candidate_pool}"),
            (self.ingest_chunk >= 1,
             f"ingest_chunk must be >= 1, got {self.ingest_chunk}"),
            (self.ingest_superbatch >= 1,
             f"ingest_superbatch must be >= 1 (1 = off), "
             f"got {self.ingest_superbatch}"),
            (self.replica_scheme in ("uniform", "rank", "count"),
             f"replica_scheme must be 'uniform'|'rank'|'count', "
             f"got {self.replica_scheme!r}"),
            (self.max_replicas >= 1,
             f"max_replicas must be >= 1, got {self.max_replicas}"),
            (0.0 <= self.jitter_frac <= 1.0,
             f"jitter_frac must be in [0, 1] (fraction of a cell), "
             f"got {self.jitter_frac}"),
            (self.embedder in ("umap", "tsne"),
             f"embedder must be 'umap'|'tsne', got {self.embedder!r}"),
            (self.embed_dims >= 1,
             f"embed_dims must be >= 1, got {self.embed_dims}"),
            (self.embed_backend in ("dense", "tiled", "pallas", "sparse"),
             f"embed_backend must be 'dense'|'tiled'|'pallas'|'sparse', "
             f"got {self.embed_backend!r}"),
            (self.embed_block >= 1,
             f"embed_block must be >= 1, got {self.embed_block}"),
            (self.embed_knn >= 0,
             f"embed_knn must be >= 0 (0 = 3*perplexity), "
             f"got {self.embed_knn}"),
            (self.embed_grid >= 2,
             f"embed_grid must be >= 2, got {self.embed_grid}"),
            (self.embed_grid_interval >= 0.0,
             f"embed_grid_interval must be >= 0 (0 = fixed grid), "
             f"got {self.embed_grid_interval}"),
            (self.embed_grid_max >= self.embed_grid,
             f"embed_grid_max ({self.embed_grid_max}) must be >= "
             f"embed_grid ({self.embed_grid})"),
            (self.embed_cic in ("xla", "pallas"),
             f"embed_cic must be 'xla'|'pallas', got {self.embed_cic!r}"),
            (self.embed_knn_method in ("exact", "auto", "ann"),
             f"embed_knn_method must be 'exact'|'auto'|'ann', "
             f"got {self.embed_knn_method!r}"),
            (self.kernel_mode in ("auto", "compiled", "interpret", "xla"),
             f"kernel_mode must be 'auto'|'compiled'|'interpret'|'xla', "
             f"got {self.kernel_mode!r}"),
        ]
        bad = [msg for ok, msg in checks if not ok]
        if bad:
            raise ValueError("invalid SnsConfig: " + "; ".join(bad))


@dataclasses.dataclass
class SnsResult:
    grid: GridSpec
    hh: HeavyHitters
    reps: Representatives
    embedding: jnp.ndarray         # (live_reps, embed_dims)
    rep_weight: np.ndarray         # weights of live reps
    rep_hh_id: np.ndarray          # HH index of each live rep
    coverage: float                # fraction of stream mass in the HHs
    # candidate-stage recall diagnostic, measured on every path: the
    # largest exact count ever withheld from the candidate set (reservoir
    # eviction when streaming; local top-L truncation one-shot; pmax over
    # shards on a mesh).  On the resilient path this is WIDENED by the
    # estimated mass of lost shards (resilience.widened_bound).  0.0 =
    # the candidate set provably contains every occupied cell, so the
    # heavy hitters are exact up to the pool size
    hh_error_bound: float = 0.0
    # fraction of the expected stream mass actually observed by ingest:
    # 1.0 everywhere except the resilient path after shard loss, where
    # partial aggregation folds only the shards that delivered
    # (distinct from `coverage`, the HH-mass fraction OF the observed)
    ingest_coverage: float = 1.0
    # shard ids the resilient path lost (empty on every other path)
    lost_shards: Tuple[int, ...] = ()


def _chunk_stream(chunks) -> Iterable:
    """One pass over a chunk source: a callable factory or an iterable."""
    return chunks() if callable(chunks) else iter(chunks)


def _is_points_array(points) -> bool:
    return isinstance(points, (jnp.ndarray, np.ndarray)) or \
        hasattr(points, "shape")


def sketch_stage(cfg: SnsConfig, points,
                 grid: Optional[GridSpec] = None,
                 mesh=None, data_axes=("data",)
                 ) -> Tuple[GridSpec, HeavyHitters]:
    """Stages 1-2: grid + heavy hitters (local or mesh-distributed).

    ``points`` may be a resident (N, D) array (one-shot path) or a chunk
    iterator / factory (single-host streaming path; delegates to
    :func:`sketch_stage_streaming`)."""
    grid, hh, _ = _sketch_stage_impl(cfg, points, grid=grid, mesh=mesh,
                                     data_axes=data_axes)
    return grid, hh


def _sketch_stage_impl(cfg: SnsConfig, points, grid, mesh, data_axes
                       ) -> Tuple[GridSpec, HeavyHitters, float]:
    """Stages 1-2 plus the candidate-stage recall watermark (the third
    return: largest count withheld from the candidate set; 0 = complete)."""
    if not _is_points_array(points):
        if mesh is not None:
            raise ValueError(
                "chunk-iterator input is single-host only; use "
                "geo.geo_extract_from_shards for the mesh streaming path")
        grid, state = _ingest_stream(cfg, points, grid)
        hh = hh_mod.from_candidates(state.sketch, state.cands, cfg.top_k)
        return grid, hh, float(stream_mod.space_saving_bound(state))
    if grid is None:
        grid = quantize.fit_grid(points, cfg.bins)
    if mesh is not None:
        res = geo.geo_extract(
            mesh, grid, points, rows=cfg.rows, log2_cols=cfg.log2_cols,
            top_k=cfg.top_k, candidate_pool=cfg.candidate_pool,
            data_axes=data_axes, seed=cfg.seed)
        return grid, res.hh, float(res.evict_max)
    # fused single-sort path: one sort+RLE feeds the sketch scatter and
    # the candidate top-k alike (same math as update_sorted + extract)
    key_hi, key_lo = quantize.points_to_keys(grid, points)
    sk = sketch_mod.init(jax.random.key(cfg.seed), cfg.rows, cfg.log2_cols)
    runs = cand_mod.sorted_runs(
        key_hi, key_lo,
        assume_hi_zero=grid.dims * grid.bits_per_dim <= 32)
    sk = sketch_mod.update_runs(sk, runs)
    pool = cfg.candidate_pool or min(2 * cfg.top_k, key_hi.shape[0])
    cands, dropped = cand_mod.topk_from_runs(runs, pool,
                                             return_dropped=True)
    hh = hh_mod.from_candidates(sk, cands, cfg.top_k)
    return grid, hh, float(dropped)


def sketch_stage_streaming(cfg: SnsConfig, chunks,
                           grid: Optional[GridSpec] = None,
                           ) -> Tuple[GridSpec, HeavyHitters, float]:
    """Stages 1-2 over a chunk stream, bounded memory.

    ``chunks``: an iterable of (n_i, D) arrays, or a zero-arg callable
    returning one.  When ``grid`` is None two passes are made (chunked
    min/max, then sketch), so the source must be re-iterable — pass a
    callable or a sequence, or supply the grid up front.

    Returns (grid, heavy hitters, total ingested count) — the count comes
    from the ingest state, not from re-materializing the stream."""
    grid, state = _ingest_stream(cfg, chunks, grid)
    hh = hh_mod.from_candidates(state.sketch, state.cands, cfg.top_k)
    return grid, hh, float(state.count)


def _ingest_stream(cfg: SnsConfig, chunks, grid: Optional[GridSpec]
                   ) -> Tuple[GridSpec, stream_mod.IngestState]:
    """Shared ingest fold: grid fit (pass 1 if needed) + fused superbatched
    ingest (pass 2).  Returns the final :class:`stream.IngestState` so
    callers can surface its diagnostics (count, eviction watermark)."""
    if grid is None:
        if not callable(chunks) and iter(chunks) is chunks:
            raise ValueError(
                "grid=None needs two passes over the stream, but `chunks` "
                "is a one-shot iterator; pass a callable / sequence, or "
                "fit the grid up front (quantize.fit_grid_streaming)")
        grid = quantize.fit_grid_streaming(_chunk_stream(chunks), cfg.bins)
    pool = cfg.candidate_pool or 2 * cfg.top_k
    state = stream_mod.init(jax.random.key(cfg.seed), cfg.rows,
                            cfg.log2_cols, pool)
    state = stream_mod.ingest_all(state, grid, _chunk_stream(chunks),
                                  cfg.ingest_chunk,
                                  superbatch=cfg.ingest_superbatch)
    if float(state.count) == 0.0:
        # a factory returning the SAME exhausted iterator passes the
        # re-iterable guard above but yields nothing on the ingest pass —
        # fail loudly instead of returning empty heavy hitters
        raise ValueError(
            "ingest pass saw no data; if `chunks` is a callable it must "
            "return a FRESH iterator on every call")
    return grid, state


def resolve_embed_cfg(cfg: SnsConfig, tsne_cfg=None, umap_cfg=None):
    """Embedder config with SnsConfig's backend/block/kNN knobs applied.

    SnsConfig is authoritative for the embedding backend/block — the
    tsne/umap cfgs carry algorithm hyper-parameters only."""
    # a forced kernel tier also pins the ANN stage-1 distance kernel
    # (AnnConfig.kernel_mode None = defer to its tile/interpret knobs)
    ann_cfg = cfg.embed_ann
    if cfg.kernel_mode != "auto":
        from repro.core import ann as ann_mod
        ann_cfg = dataclasses.replace(ann_cfg or ann_mod.AnnConfig(),
                                      kernel_mode=cfg.kernel_mode)
    if cfg.embedder == "tsne":
        tc = tsne_cfg or tsne_mod.TsneConfig(dims=cfg.embed_dims)
        return dataclasses.replace(tc, backend=cfg.embed_backend,
                                   block=cfg.embed_block, knn=cfg.embed_knn,
                                   grid_size=cfg.embed_grid,
                                   grid_interval=cfg.embed_grid_interval,
                                   grid_max=cfg.embed_grid_max,
                                   cic=cfg.embed_cic,
                                   knn_method=cfg.embed_knn_method,
                                   ann=ann_cfg,
                                   kernel_mode=cfg.kernel_mode)
    if cfg.embedder == "umap":
        # embed_block bounds the kNN row-block on the UMAP side too
        # (tests/test_umap_scatter_free.py pins the propagation)
        uc = umap_cfg or umap_mod.UmapConfig(dims=cfg.embed_dims)
        return dataclasses.replace(uc, block=cfg.embed_block,
                                   knn_method=cfg.embed_knn_method,
                                   ann=ann_cfg,
                                   kernel_mode=cfg.kernel_mode)
    raise ValueError(f"unknown embedder {cfg.embedder!r}")


def embed_points(cfg: SnsConfig, key, x: jnp.ndarray, weights: jnp.ndarray,
                 ecfg=None, *, init: Optional[jnp.ndarray] = None,
                 tsne_cfg=None, umap_cfg=None
                 ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run the configured embedder on already-built representatives.

    Returns ``(embedding, kl_trace)`` — the trace is the tSNE per-iteration
    KL history, or None for UMAP.  ``init`` (optional (N, dims)) warm-starts
    the optimizer; ``ecfg`` short-circuits :func:`resolve_embed_cfg` for
    callers that pre-resolved the embedder config (the service keeps one
    resolved cold config and a warm variant)."""
    embed_mesh = mesh_mod.resolve_mesh(cfg.embed_mesh)
    if ecfg is None:
        ecfg = resolve_embed_cfg(cfg, tsne_cfg=tsne_cfg, umap_cfg=umap_cfg)
    # only forward init= when set: run_tsne/run_umap stand-ins predating
    # the warm-start hook stay call-compatible
    kw = {} if init is None else {"init": init}
    if cfg.embedder == "tsne":
        emb, kl = tsne_mod.run_tsne(key, x, ecfg, weights=weights,
                                    mesh=embed_mesh, **kw)
        return emb, kl
    emb = umap_mod.run_umap(key, x, ecfg, weights=weights, mesh=embed_mesh,
                            **kw)
    return emb, None


def embed_stage(cfg: SnsConfig, grid: GridSpec, hh: HeavyHitters,
                tsne_cfg: Optional[tsne_mod.TsneConfig] = None,
                umap_cfg: Optional[umap_mod.UmapConfig] = None,
                ) -> Tuple[Representatives, jnp.ndarray, np.ndarray, np.ndarray]:
    """Stages 3-4: replicas + tSNE/UMAP on the live representatives.

    With ``cfg.embed_mesh`` set the embedder runs row-block-sharded under
    ``shard_map`` (see ``core.mesh``); results stay fp-equivalent to the
    single-device path, and UMAP's negative-sample draws stay
    draw-for-draw aligned (tests/test_mesh_embed.py)."""
    key = jax.random.key(cfg.seed + 1)
    krep, kembed = jax.random.split(key)
    reps = replicas.make_representatives(
        krep, grid, hh, scheme=cfg.replica_scheme,
        max_replicas=cfg.max_replicas, jitter_frac=cfg.jitter_frac)
    pts, w, ids = replicas.compact(reps)
    emb, _ = embed_points(cfg, kembed, jnp.asarray(pts), jnp.asarray(w),
                          tsne_cfg=tsne_cfg, umap_cfg=umap_cfg)
    return reps, emb, w, ids


def run(cfg: SnsConfig, points, grid: Optional[GridSpec] = None,
        mesh=None, data_axes=("data",),
        tsne_cfg=None, umap_cfg=None) -> SnsResult:
    """Full SnS: points → embedding of weighted heavy-hitter representatives.

    A chunk iterator / factory instead of an array delegates to
    :func:`run_streaming` (single-host only).  ``mesh`` shards the
    *sketch* stage; ``cfg.embed_mesh`` independently shards the *embed*
    stage (see :func:`embed_stage`) — set both to run the whole pipeline
    under ``shard_map``, as examples/geo_distributed.py does."""
    if not _is_points_array(points):
        if mesh is not None:
            raise ValueError(
                "chunk-iterator input is single-host only; use "
                "run_streaming(mesh=..., shard_fn=...) for the mesh path")
        return run_streaming(cfg, points, grid=grid, tsne_cfg=tsne_cfg,
                             umap_cfg=umap_cfg)
    grid, hh, bound = _sketch_stage_impl(cfg, points, grid=grid, mesh=mesh,
                                         data_axes=data_axes)
    reps, emb, w, ids = embed_stage(cfg, grid, hh, tsne_cfg=tsne_cfg,
                                    umap_cfg=umap_cfg)
    n_total = int(np.prod(points.shape[:-1]))  # all leading dims are batch
    coverage = float(jnp.sum(hh.count) / max(n_total, 1))
    return SnsResult(grid=grid, hh=hh, reps=reps, embedding=emb,
                     rep_weight=w, rep_hh_id=ids, coverage=coverage,
                     hh_error_bound=bound)


def run_streaming(cfg: SnsConfig, chunks=None,
                  grid: Optional[GridSpec] = None,
                  mesh=None, data_axes=("data",),
                  shard_fn=None, num_batches: int = 1,
                  tsne_cfg=None, umap_cfg=None) -> SnsResult:
    """Full SnS over a stream — no stage materializes all N points.

    Single-host: ``chunks`` is an iterable of (n_i, D) arrays or a callable
    factory (re-iterable; needed when ``grid`` is None for the min/max
    pass).  Mesh: pass ``mesh`` + ``shard_fn(idx, batch) -> (points, mask)``
    + ``num_batches`` (see ``geo.geo_extract_from_shards``); ``grid`` is
    then required, since geo-distributed sites must agree on the hypercube
    without a global data pass.

    ``coverage`` is HH mass over the ingest-state's running count — the
    stream length is never re-derived from a resident array.  After
    ingest, ``cfg.embed_mesh`` applies to the embed stage exactly as in
    :func:`run` (the two meshes are independent: a geo ingest mesh can
    hand off to a local embed mesh, or re-use the same devices)."""
    if mesh is not None:
        if shard_fn is None:
            raise ValueError("mesh streaming needs shard_fn + num_batches")
        if grid is None:
            raise ValueError(
                "mesh streaming needs an agreed grid up front (the paper's "
                "shared-hypercube contract); supply grid=")
        res = geo.geo_extract_from_shards(
            mesh, grid, shard_fn, rows=cfg.rows, log2_cols=cfg.log2_cols,
            top_k=cfg.top_k, candidate_pool=cfg.candidate_pool,
            data_axes=data_axes, seed=cfg.seed, num_batches=num_batches)
        hh, total = res.hh, float(res.total_count)
        bound = float(res.evict_max)   # pmax'd per-shard watermark
    else:
        if chunks is None:
            raise ValueError("single-host streaming needs a chunk source")
        grid, state = _ingest_stream(cfg, chunks, grid)
        hh = hh_mod.from_candidates(state.sketch, state.cands, cfg.top_k)
        total = float(state.count)
        bound = float(stream_mod.space_saving_bound(state))
    reps, emb, w, ids = embed_stage(cfg, grid, hh, tsne_cfg=tsne_cfg,
                                    umap_cfg=umap_cfg)
    coverage = float(jnp.sum(hh.count)) / max(total, 1.0)
    return SnsResult(grid=grid, hh=hh, reps=reps, embedding=emb,
                     rep_weight=w, rep_hh_id=ids, coverage=coverage,
                     hh_error_bound=bound)


def run_resilient(cfg: SnsConfig, shard_chunks, grid: GridSpec, *,
                  policy=None, deadline: Optional[float] = None,
                  min_coverage: float = 0.0, expected_counts=None,
                  faults=None, tsne_cfg=None, umap_cfg=None) -> SnsResult:
    """Full SnS over independent per-shard chunk sources with failure
    handling — the fault-tolerant front-end of :func:`run_streaming`.

    Each shard folds its own stream into a summary (host-level jobs, not
    one SPMD program), so shards can fail without failing the run:
    transient errors RETRY under ``policy`` (``resilience.RetryPolicy``),
    stragglers are cut off at ``deadline`` seconds, permanent losses
    DEGRADE into partial aggregation — the result carries
    ``ingest_coverage < 1``, the lost shard ids, and an
    ``hh_error_bound`` widened by the estimated lost mass — and coverage
    below ``min_coverage`` FAILS LOUD (``resilience.CoverageError``).
    See ``geo.resilient_extract`` for the collection machinery and
    ``core.faults`` for the reproducible-chaos hook (``faults=``).

    ``grid`` is required up front (the shared-hypercube contract: sites
    that may be lost cannot be part of a global min/max pass)."""
    res = geo.resilient_extract(
        grid, shard_chunks, rows=cfg.rows, log2_cols=cfg.log2_cols,
        top_k=cfg.top_k, candidate_pool=cfg.candidate_pool, seed=cfg.seed,
        chunk_size=cfg.ingest_chunk, superbatch=cfg.ingest_superbatch,
        policy=policy, deadline=deadline, min_coverage=min_coverage,
        expected_counts=expected_counts, faults=faults)
    reps, emb, w, ids = embed_stage(cfg, grid, res.hh, tsne_cfg=tsne_cfg,
                                    umap_cfg=umap_cfg)
    coverage = float(jnp.sum(res.hh.count)) / max(res.observed_count, 1.0)
    return SnsResult(grid=grid, hh=res.hh, reps=reps, embedding=emb,
                     rep_weight=w, rep_hh_id=ids, coverage=coverage,
                     hh_error_bound=res.hh_error_bound,
                     ingest_coverage=res.coverage, lost_shards=res.lost)


def chunks_from_loader(plan, host: int,
                       make_batch: Callable[[int, int], np.ndarray],
                       batches_per_shard: int = 1,
                       steal: bool = False,
                       globally_completed=None,
                       on_shard_done: Optional[Callable[[int], None]] = None,
                       faults=None,
                       on_shard_error: Optional[
                           Callable[[int, Exception], bool]] = None
                       ) -> Callable:
    """Adapt a ``data.loader.ShardPlan`` into the re-iterable chunk factory
    ``run_streaming`` consumes.  Each pass builds a fresh ``ShardedLoader``
    (its ``completed`` set is mutated by iteration, so a loader instance is
    single-use) and yields the raw batch arrays in plan order.

    ``steal=True`` turns on the plan's straggler mitigation: after this
    host drains its primary slice, it calls ``ShardedLoader.steal`` with
    the shards other hosts have already finished (``globally_completed`` —
    a zero-arg callable re-read at steal time, or a static sequence) and
    ingests the leftovers in the plan's deterministic steal order.
    ``on_shard_done(shard)`` fires once per shard AFTER its last batch is
    yielded — the hook a multi-host driver uses to publish completions to
    whatever shared board backs ``globally_completed``.  Hosts that share
    one board process every shard exactly once between them
    (tests/test_loader.py::test_chunks_from_loader_steals_exactly_once).

    Fault tolerance: ``faults`` (a ``core.faults.FaultPlan``) wraps
    ``make_batch`` with reproducible chaos, and ``on_shard_error(shard,
    exc) -> bool`` decides a failing shard's fate — return True to skip
    it (the loader records it in ``ShardedLoader.failed``, its batches
    are withheld all-or-nothing, and ingest degrades to the surviving
    shards), False/None to re-raise (fail loud).  Skipped shards are NOT
    marked completed, so a shared board leaves them for another host's
    steal pass to rescue.

    Caveat: with ``grid=None`` the pipeline iterates the factory twice
    (min/max pass, then ingest) while the board keeps moving — supply the
    grid up front so only the single ingest pass claims shards.
    """
    from repro.data.loader import ShardedLoader

    if faults is not None:
        from repro.core import faults as faults_mod
        make_batch = faults_mod.chaos_make_batch(faults, make_batch)

    def factory():
        loader = ShardedLoader(plan, host, make_batch,
                               batches_per_shard=batches_per_shard,
                               on_error=on_shard_error)

        def drain(pairs):
            prev = None
            for shard, batch in pairs:
                if prev is not None and shard != prev \
                        and on_shard_done is not None:
                    on_shard_done(prev)
                prev = shard
                yield batch
            if prev is not None and on_shard_done is not None:
                on_shard_done(prev)

        yield from drain(iter(loader))
        if steal:
            done = globally_completed() if callable(globally_completed) \
                else (globally_completed or ())
            yield from drain(loader.steal(done))
    return factory


@functools.partial(jax.jit, static_argnames=("grid", "chunk"))
def _assign_chunks(pts: jnp.ndarray, shi: jnp.ndarray, slo: jnp.ndarray,
                   sids: jnp.ndarray, grid: GridSpec, chunk: int
                   ) -> jnp.ndarray:
    """Quantize + binary-search ``pts`` (padded to a chunk multiple)
    against the sorted HH key table, one ``lax.map`` chunk at a time —
    peak memory O(chunk), one compile per (grid, chunk, shapes)."""
    nk = shi.shape[0]

    def one(p):
        khi, klo = quantize.points_to_keys(grid, p)
        pos = u64.searchsorted((shi, slo), (khi, klo))
        pos_c = jnp.minimum(pos, nk - 1)
        hit = (shi[pos_c] == khi) & (slo[pos_c] == klo)
        return jnp.where(hit, sids[pos_c], -1)

    nb = pts.shape[0] // chunk
    return jax.lax.map(one, pts.reshape(nb, chunk, -1)).reshape(-1)


def assign_points_to_hh(grid: GridSpec, hh: HeavyHitters,
                        points: jnp.ndarray, chunk: int = 65536
                        ) -> np.ndarray:
    """Label raw points by nearest HH cell key (-1 if not an HH cell).

    Used to project HH-level cluster labels back to the raw data, as the
    paper does for the contingency table (§IV-1), and by the service's
    drift accounting.  The whole per-chunk body (quantize + two-limb
    binary search, :func:`repro.core.u64.searchsorted`) is one jitted
    ``lax.map`` — no per-chunk host round-trip, so large query batches
    stream at device speed."""
    n = points.shape[0]
    hh_hi = np.asarray(hh.key_hi, np.uint64)
    hh_lo = np.asarray(hh.key_lo, np.uint64)
    live = np.asarray(hh.mask).astype(bool)
    packed = (hh_hi << np.uint64(32)) | hh_lo
    order = np.argsort(packed[live], kind="stable")
    sorted_keys = packed[live][order]
    sorted_ids = np.flatnonzero(live)[order]
    if sorted_keys.size == 0 or n == 0:
        return np.full((n,), -1, np.int64)
    shi = jnp.asarray((sorted_keys >> np.uint64(32)).astype(np.uint32))
    slo = jnp.asarray(sorted_keys.astype(np.uint32))
    sids = jnp.asarray(sorted_ids.astype(np.int32))
    chunk = max(1, min(int(chunk), n))
    pts = np.asarray(points, np.float32)
    pad = (-n) % chunk
    if pad:
        pts = np.concatenate([pts, np.zeros((pad, pts.shape[1]),
                                            np.float32)])
    out = _assign_chunks(jnp.asarray(pts), shi, slo, sids, grid, chunk)
    return np.asarray(out[:n]).astype(np.int64)
