"""Sketch-and-Scale end-to-end pipeline (paper Fig. 1).

    1. set a regular grid            → core.quantize.fit_grid
    2. count points, find heavy bins → core.sketch + core.heavy_hitters
    3. representatives per heavy bin → core.replicas
    4. feed into tSNE / UMAP         → core.tsne / core.umap

Single-host and mesh-distributed front-ends share all stages; only stage 2
differs (local sketch vs. shard_map + psum via core.geo).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import geo, heavy_hitters as hh_mod, quantize, replicas
from repro.core import sketch as sketch_mod
from repro.core import tsne as tsne_mod
from repro.core import umap as umap_mod
from repro.core.heavy_hitters import HeavyHitters
from repro.core.quantize import GridSpec
from repro.core.replicas import Representatives


@dataclasses.dataclass(frozen=True)
class SnsConfig:
    """Paper-parameterized pipeline config (defaults = cancer experiment)."""
    bins: int = 25                 # M, linear bins per axis
    rows: int = 16                 # R, sketch rows
    log2_cols: int = 18            # C = 2^18 ≈ the paper's 2·10^5
    top_k: int = 20_000            # heavy hitters to extract
    candidate_pool: int = 0        # 0 -> 2*top_k
    replica_scheme: str = "count"  # "uniform" | "rank" | "count"
    max_replicas: int = 8
    jitter_frac: float = 0.25
    embedder: str = "umap"         # "umap" | "tsne"
    embed_dims: int = 2
    embed_backend: str = "dense"   # tSNE gradient: "dense"|"tiled"|"pallas"
    embed_block: int = 512         # row-block for tiled/pallas tSNE + kNN
    seed: int = 0


@dataclasses.dataclass
class SnsResult:
    grid: GridSpec
    hh: HeavyHitters
    reps: Representatives
    embedding: jnp.ndarray         # (live_reps, embed_dims)
    rep_weight: np.ndarray         # weights of live reps
    rep_hh_id: np.ndarray          # HH index of each live rep
    coverage: float                # fraction of stream mass in the HHs


def sketch_stage(cfg: SnsConfig, points: jnp.ndarray,
                 grid: Optional[GridSpec] = None,
                 mesh=None, data_axes=("data",)
                 ) -> Tuple[GridSpec, HeavyHitters]:
    """Stages 1-2: grid + heavy hitters (local or mesh-distributed)."""
    if grid is None:
        grid = quantize.fit_grid(points, cfg.bins)
    if mesh is not None:
        res = geo.geo_extract(
            mesh, grid, points, rows=cfg.rows, log2_cols=cfg.log2_cols,
            top_k=cfg.top_k, candidate_pool=cfg.candidate_pool,
            data_axes=data_axes, seed=cfg.seed)
        return grid, res.hh
    key_hi, key_lo = quantize.points_to_keys(grid, points)
    sk = sketch_mod.init(jax.random.key(cfg.seed), cfg.rows, cfg.log2_cols)
    sk = sketch_mod.update_sorted(sk, key_hi, key_lo)
    hh = hh_mod.extract(sk, key_hi, key_lo, k=cfg.top_k,
                        candidate_pool=cfg.candidate_pool or None)
    return grid, hh


def embed_stage(cfg: SnsConfig, grid: GridSpec, hh: HeavyHitters,
                tsne_cfg: Optional[tsne_mod.TsneConfig] = None,
                umap_cfg: Optional[umap_mod.UmapConfig] = None,
                ) -> Tuple[Representatives, jnp.ndarray, np.ndarray, np.ndarray]:
    """Stages 3-4: replicas + tSNE/UMAP on the live representatives."""
    key = jax.random.key(cfg.seed + 1)
    krep, kembed = jax.random.split(key)
    reps = replicas.make_representatives(
        krep, grid, hh, scheme=cfg.replica_scheme,
        max_replicas=cfg.max_replicas, jitter_frac=cfg.jitter_frac)
    pts, w, ids = replicas.compact(reps)
    x = jnp.asarray(pts)
    wj = jnp.asarray(w)
    # SnsConfig is authoritative for the embedding backend/block — the
    # tsne/umap cfgs carry algorithm hyper-parameters only.
    if cfg.embedder == "tsne":
        tc = tsne_cfg or tsne_mod.TsneConfig(dims=cfg.embed_dims)
        tc = dataclasses.replace(tc, backend=cfg.embed_backend,
                                 block=cfg.embed_block)
        emb, _ = tsne_mod.run_tsne(kembed, x, tc, weights=wj)
    elif cfg.embedder == "umap":
        uc = umap_cfg or umap_mod.UmapConfig(dims=cfg.embed_dims)
        uc = dataclasses.replace(uc, block=cfg.embed_block)
        emb = umap_mod.run_umap(kembed, x, uc, weights=wj)
    else:
        raise ValueError(f"unknown embedder {cfg.embedder!r}")
    return reps, emb, w, ids


def run(cfg: SnsConfig, points: jnp.ndarray,
        grid: Optional[GridSpec] = None, mesh=None, data_axes=("data",),
        tsne_cfg=None, umap_cfg=None) -> SnsResult:
    """Full SnS: points → embedding of weighted heavy-hitter representatives."""
    grid, hh = sketch_stage(cfg, points, grid=grid, mesh=mesh,
                            data_axes=data_axes)
    reps, emb, w, ids = embed_stage(cfg, grid, hh, tsne_cfg=tsne_cfg,
                                    umap_cfg=umap_cfg)
    n_total = int(np.prod(points.shape[:-1]))  # all leading dims are batch
    coverage = float(jnp.sum(hh.count) / max(n_total, 1))
    return SnsResult(grid=grid, hh=hh, reps=reps, embedding=emb,
                     rep_weight=w, rep_hh_id=ids, coverage=coverage)


def assign_points_to_hh(grid: GridSpec, hh: HeavyHitters,
                        points: jnp.ndarray, chunk: int = 65536
                        ) -> np.ndarray:
    """Label raw points by nearest HH cell key (-1 if not an HH cell).

    Used to project HH-level cluster labels back to the raw data, as the
    paper does for the contingency table (§IV-1).  Chunked exact match on
    packed keys."""
    n = points.shape[0]
    hh_hi = np.asarray(hh.key_hi, np.uint64)
    hh_lo = np.asarray(hh.key_lo, np.uint64)
    live = np.asarray(hh.mask).astype(bool)
    packed = (hh_hi << np.uint64(32)) | hh_lo
    order = np.argsort(packed[live], kind="stable")
    sorted_keys = packed[live][order]
    sorted_ids = np.flatnonzero(live)[order]
    out = np.full((n,), -1, np.int64)
    if sorted_keys.size == 0:
        return out
    for s in range(0, n, chunk):
        pts = jnp.asarray(points[s:s + chunk])
        khi, klo = quantize.points_to_keys(grid, pts)
        keys = (np.asarray(khi, np.uint64) << np.uint64(32)) | \
            np.asarray(klo, np.uint64)
        pos = np.minimum(np.searchsorted(sorted_keys, keys),
                         sorted_keys.size - 1)
        hit = sorted_keys[pos] == keys
        out[s:s + chunk] = np.where(hit, sorted_ids[pos], -1)
    return out
