"""Streaming ingest engine: bounded-memory sketch stage over a chunked stream.

The paper's headline resource claim (§II) is *logarithmic memory* and
*single-stream I/O* on the edge nodes.  The sketch itself is trivially
bounded — a fixed (R, C) table — but candidate tracking is not: the exact
local top-L needs the whole key stream unless it is folded incrementally.
This module provides that fold as a pytree + step function:

    ``IngestState``  = CountSketch  ⊕  Candidates reservoir (L)  ⊕  count
    ``ingest_step``  : state × (chunk, mask) → state          (traceable)
    ``ingest_chunk`` : jitted, donated wrapper — per-call memory is
                       O(chunk + L + R·C) no matter how long the stream is.

The reservoir fold is ``candidates.merge_topk`` (concat → dedupe → top-L):
a key held by the reservoir accumulates its *exact* count, so while the
number of distinct keys seen stays ≤ L the reservoir is bit-identical to
the one-shot exact top-L of the concatenated stream — the equivalence
contract tested in tests/test_stream_ingest.py.  Beyond L distinct keys it
degrades gracefully to a space-saving-style approximation whose recall on
(ε,ℓ₂)-heavy keys is what the paper's averaging argument needs.

Host-side helpers: ``rechunk`` re-packs a ragged chunk iterator into
fixed-shape padded (points, mask) blocks so the jitted step traces once.

Used by the single-host streaming pipeline (``pipeline.run_streaming``)
and, via ``ingest_step`` inside ``lax.scan``, by the mesh streaming path
(``geo.geo_extract_from_shards``).
"""
from __future__ import annotations

import functools
from typing import Iterable, Iterator, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import candidates as cand_mod
from repro.core import quantize, sketch as sketch_mod
from repro.core.candidates import Candidates
from repro.core.quantize import GridSpec
from repro.core.sketch import CountSketch


class IngestState(NamedTuple):
    """Everything the sketch stage carries between chunks.  A pytree, so it
    scans, donates, and psums like any other JAX state."""
    sketch: CountSketch     # (R, C) table + hash params
    cands: Candidates       # (L,) bounded candidate reservoir
    count: jnp.ndarray      # () float32 — items ingested so far


def init(key: jax.Array, rows: int, log2_cols: int, pool: int,
         dtype=jnp.float32) -> IngestState:
    """Fresh state: zero sketch, empty reservoir of capacity ``pool``."""
    return IngestState(
        sketch=sketch_mod.init(key, rows, log2_cols, dtype=dtype),
        cands=cand_mod.empty(pool),
        count=jnp.zeros((), jnp.float32))


def from_sketch(sk: CountSketch, pool: int) -> IngestState:
    """Wrap an existing (e.g. replicated-into-shard_map) sketch."""
    return IngestState(sketch=sk, cands=cand_mod.empty(pool),
                       count=jnp.zeros((), jnp.float32))


def ingest_step(state: IngestState, grid: GridSpec, points: jnp.ndarray,
                mask: Optional[jnp.ndarray] = None) -> IngestState:
    """Traceable fold of one chunk: quantize → pack → sketch update +
    reservoir merge.  Call inside ``lax.scan`` / ``shard_map`` / jit.

    The raw chunk keys enter the reservoir merge directly as count-1
    candidates — one sort over (pool + chunk) instead of a chunk-local
    top-L followed by a second sort, and no per-chunk truncation (a chunk
    with more than ``pool`` distinct keys loses nothing here; eviction
    happens only at the reservoir boundary)."""
    pool = state.cands.capacity
    n = points.shape[0]
    key_hi, key_lo = quantize.points_to_keys(grid, points)
    sk = sketch_mod.update_sorted(state.sketch, key_hi, key_lo, mask=mask)
    chunk_cands = Candidates(
        key_hi=key_hi, key_lo=key_lo,
        count=jnp.ones((n,), jnp.float32),
        mask=jnp.ones((n,), bool) if mask is None else mask)
    cands = state.cands.merge_topk(chunk_cands, pool)
    if mask is None:
        inc = jnp.full((), n, jnp.float32)
    else:
        inc = jnp.sum(mask.astype(jnp.float32))
    return IngestState(sketch=sk, cands=cands, count=state.count + inc)


@functools.partial(jax.jit, static_argnames=("grid",), donate_argnums=(0,))
def ingest_chunk(state: IngestState, points: jnp.ndarray,
                 mask: jnp.ndarray, *, grid: GridSpec) -> IngestState:
    """Jitted single-trace ingest step.  ``state`` is donated: the sketch
    table and reservoir are updated in place, so steady-state device memory
    is one state + one chunk.  Feed fixed-shape (points, mask) blocks —
    :func:`rechunk` produces them from any ragged iterator."""
    return ingest_step(state, grid, points, mask=mask)


Chunk = Union[np.ndarray, jnp.ndarray]


def rechunk(chunks: Iterable[Chunk], size: int,
            ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Repack a ragged stream of (n_i, D) arrays into fixed (size, D)
    blocks + boolean masks (padding rows are zeros, mask=False).  Order
    preserving; host-side; O(size) working memory."""
    buf: list = []
    have = 0
    dims = None
    for c in chunks:
        c = np.asarray(c, np.float32)
        if c.ndim != 2:
            c = c.reshape(-1, c.shape[-1])
        if dims is None:
            dims = c.shape[1]
        while c.shape[0] > 0:
            take = min(size - have, c.shape[0])
            buf.append(c[:take])
            have += take
            c = c[take:]
            if have == size:
                yield (np.concatenate(buf, axis=0),
                       np.ones((size,), bool))
                buf, have = [], 0
    if have > 0:
        pts = np.concatenate(buf, axis=0)
        pad = size - have
        pts = np.concatenate(
            [pts, np.zeros((pad, dims), np.float32)], axis=0)
        mask = np.arange(size) < have
        yield pts, mask


def ingest_all(state: IngestState, grid: GridSpec,
               chunks: Iterable[Chunk], chunk_size: int) -> IngestState:
    """Drive the jitted step over a whole (host-side) chunk stream."""
    for pts, mask in rechunk(chunks, chunk_size):
        state = ingest_chunk(state, jnp.asarray(pts), jnp.asarray(mask),
                             grid=grid)
    return state
