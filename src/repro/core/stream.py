"""Streaming ingest engine: bounded-memory, single-sort, superbatched.

The paper's headline resource claim (§II) is *logarithmic memory* and
*single-stream I/O* on the edge nodes; its headline time claim is *linear*
at 10⁸⁺ points — which makes the ingest front-end a points/sec throughput
engine, not just a memory bound.  This module provides the fold:

    ``IngestState``      = CountSketch ⊕ key-sorted Candidates reservoir
                           ⊕ count ⊕ eviction watermark
    ``ingest_step``      : state × (chunk, mask) → state      (traceable)
    ``ingest_chunk``     : jitted, donated single-chunk wrapper
    ``ingest_superbatch``: jitted, donated ``lax.scan`` over B stacked
                           chunks — one dispatch amortizes B steps
    ``ingest_all``       : host driver — rechunk → superbatch → double-
                           buffered async prefetch (device_put of batch
                           b+1 overlaps the compute of batch b)

Hot-path structure (the fused single-sort fold): ``ingest_step`` sorts and
run-length-encodes the chunk's keys ONCE (``candidates.sorted_runs``) and
feeds the same deduped runs to both consumers — the sketch scatter
(``sketch.update_runs``) and the reservoir merge
(``candidates.merge_runs``, a binary-search sorted merge against the
key-sorted reservoir; no second sort).  Exactly one sort primitive per
chunk step, jaxpr-regression-tested in tests/test_fused_ingest.py.

The reservoir invariant: a key held by the reservoir accumulates its
*exact* count, so while the number of distinct keys seen stays ≤ L the
reservoir is bit-identical to the one-shot exact top-L of the concatenated
stream — the equivalence contract tested in tests/test_stream_ingest.py.
Beyond L distinct keys it degrades to a space-saving-style approximation;
``state.evict_max`` tracks the running maximum count ever evicted, the
space-saving error diagnostic surfaced by the pipeline (a key whose true
count exceeds every eviction it suffered survives; see
:func:`space_saving_bound`).

``save_state`` / ``load_state`` checkpoint the fold mid-stream (resumable
ingest): the state is a flat pytree of arrays, round-tripped through one
``.npz`` — resuming reproduces bit-identical heavy hitters.  Writes are
ATOMIC (temp file + ``os.replace``: a crash mid-save can never leave a
torn file at the target path) and CHECKSUMMED (a crc32 digest rides in
the payload; ``load_state`` recomputes it and raises
:class:`CheckpointCorruptError` on silent bit rot, optionally falling
back to the previous good generation written by ``keep_backup=True``).

``merge_states`` is the host-level mergeability primitive: two folds
built with identical hash params combine linearly (sketch tables add,
reservoirs sorted-merge, counts add, eviction watermarks max) — the
partial-aggregation backbone of ``core.resilience``.

Used by the single-host streaming pipeline (``pipeline.run_streaming``)
and, via ``ingest_step`` inside ``lax.scan``, by the mesh streaming path
(``geo.geo_extract_from_shards``).
"""
from __future__ import annotations

import functools
import os
import zlib
from typing import Iterable, Iterator, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import candidates as cand_mod
from repro.core import hashing, quantize, sketch as sketch_mod
from repro.core.candidates import Candidates
from repro.core.quantize import GridSpec
from repro.core.sketch import CountSketch


class IngestState(NamedTuple):
    """Everything the sketch stage carries between chunks.  A pytree, so it
    scans, donates, and psums like any other JAX state.

    ``cands`` is maintained KEY-SORTED (live keys ascending, padding last)
    — the invariant that lets ``candidates.merge_runs`` merge without
    sorting.  ``evict_max`` is the space-saving diagnostic: the largest
    exact count ever evicted from the reservoir (0 while the distinct-key
    universe fits in the pool, i.e. while the reservoir is exact)."""
    sketch: CountSketch     # (R, C) table + hash params
    cands: Candidates       # (L,) bounded candidate reservoir, key-sorted
    count: jnp.ndarray      # () float32 — items ingested so far
    evict_max: jnp.ndarray  # () float32 — running max evicted count


def init(key: jax.Array, rows: int, log2_cols: int, pool: int,
         dtype=jnp.float32) -> IngestState:
    """Fresh state: zero sketch, empty reservoir of capacity ``pool``."""
    return IngestState(
        sketch=sketch_mod.init(key, rows, log2_cols, dtype=dtype),
        cands=cand_mod.empty(pool),
        count=jnp.zeros((), jnp.float32),
        evict_max=jnp.zeros((), jnp.float32))


def from_sketch(sk: CountSketch, pool: int) -> IngestState:
    """Wrap an existing (e.g. replicated-into-shard_map) sketch."""
    return IngestState(sketch=sk, cands=cand_mod.empty(pool),
                       count=jnp.zeros((), jnp.float32),
                       evict_max=jnp.zeros((), jnp.float32))


def space_saving_bound(state: IngestState) -> jnp.ndarray:
    """Error bound on heavy-hitter *recall* from the reservoir: any key
    whose exact stream count exceeds ``evict_max`` at every eviction it
    suffered is still in the reservoir; 0 means the reservoir is exact
    (no eviction ever happened).  Reported counts themselves come from the
    sketch estimate and are not affected."""
    return state.evict_max


def ingest_step(state: IngestState, grid: GridSpec, points: jnp.ndarray,
                mask: Optional[jnp.ndarray] = None) -> IngestState:
    """Traceable fused fold of one chunk: quantize → pack → ONE sort+RLE →
    {sketch scatter, sorted-merge reservoir update}.  Call inside
    ``lax.scan`` / ``shard_map`` / jit.

    The chunk's deduped runs enter the reservoir merge directly — no
    per-chunk top-L truncation (a chunk with more than ``pool`` distinct
    keys loses nothing here; eviction happens only at the reservoir
    boundary, where it raises the ``evict_max`` watermark)."""
    pool = state.cands.capacity
    n = points.shape[0]
    key_hi, key_lo = quantize.points_to_keys(grid, points)
    # grids packing ≤ 32 bits leave key_hi ≡ 0 — sort one limb (static)
    hi_zero = grid.dims * grid.bits_per_dim <= 32
    runs = cand_mod.sorted_runs(key_hi, key_lo, mask=mask,
                                assume_hi_zero=hi_zero)       # THE sort
    sk = sketch_mod.update_runs(state.sketch, runs)
    cands, evicted = cand_mod.merge_runs(state.cands, runs, pool)
    if mask is None:
        inc = jnp.full((), n, jnp.float32)
    else:
        inc = jnp.sum(mask.astype(jnp.float32))
    return IngestState(sketch=sk, cands=cands, count=state.count + inc,
                       evict_max=jnp.maximum(state.evict_max, evicted))


@functools.partial(jax.jit, static_argnames=("grid",), donate_argnums=(0,))
def ingest_chunk(state: IngestState, points: jnp.ndarray,
                 mask: jnp.ndarray, *, grid: GridSpec) -> IngestState:
    """Jitted single-trace ingest step.  ``state`` is donated: the sketch
    table and reservoir are updated in place, so steady-state device memory
    is one state + one chunk.  Feed fixed-shape (points, mask) blocks —
    :func:`rechunk` produces them from any ragged iterator."""
    return ingest_step(state, grid, points, mask=mask)


@functools.partial(jax.jit, static_argnames=("grid",), donate_argnums=(0,))
def ingest_superbatch(state: IngestState, points: jnp.ndarray,
                      mask: jnp.ndarray, *, grid: GridSpec) -> IngestState:
    """Jitted fold of B stacked chunks in ONE dispatch: ``points`` is
    (B, chunk, D), ``mask`` (B, chunk).  A ``lax.scan`` over the leading
    axis carries the donated state, so trace size and per-call memory are
    O(1) in B while the Python-loop/dispatch overhead is paid once per
    superbatch instead of once per chunk.  Fully-masked chunks are
    no-ops — the host driver pads the ragged tail superbatch with them."""
    def step(st, batch):
        pts, m = batch
        return ingest_step(st, grid, pts, mask=m), ()

    state, _ = jax.lax.scan(step, state, (points, mask))
    return state


Chunk = Union[np.ndarray, jnp.ndarray]


def rechunk(chunks: Iterable[Chunk], size: int,
            ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Repack a ragged stream of (n_i, D) arrays into fixed (size, D)
    blocks + boolean masks (padding rows are zeros, mask=False).  Order
    preserving; host-side; O(size) working memory."""
    buf: list = []
    have = 0
    dims = None
    for c in chunks:
        c = np.asarray(c, np.float32)
        if c.ndim != 2:
            c = c.reshape(-1, c.shape[-1])
        if dims is None:
            dims = c.shape[1]
        while c.shape[0] > 0:
            take = min(size - have, c.shape[0])
            buf.append(c[:take])
            have += take
            c = c[take:]
            if have == size:
                yield (np.concatenate(buf, axis=0),
                       np.ones((size,), bool))
                buf, have = [], 0
    if have > 0:
        pts = np.concatenate(buf, axis=0)
        pad = size - have
        pts = np.concatenate(
            [pts, np.zeros((pad, dims), np.float32)], axis=0)
        mask = np.arange(size) < have
        yield pts, mask


def _superbatches(blocks: Iterator[Tuple[np.ndarray, np.ndarray]],
                  b: int) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Stack b fixed-shape (chunk, D) blocks into (b, chunk, D) + (b, chunk)
    superbatches; the ragged tail is padded with fully-masked chunks so
    every superbatch has the same shape (exactly one trace)."""
    buf_p, buf_m = [], []
    for pts, mask in blocks:
        buf_p.append(pts)
        buf_m.append(mask)
        if len(buf_p) == b:
            yield np.stack(buf_p), np.stack(buf_m)
            buf_p, buf_m = [], []
    if buf_p:
        pad_p = np.zeros_like(buf_p[0])
        pad_m = np.zeros_like(buf_m[0])
        while len(buf_p) < b:
            buf_p.append(pad_p)
            buf_m.append(pad_m)
        yield np.stack(buf_p), np.stack(buf_m)


def ingest_all(state: IngestState, grid: GridSpec,
               chunks: Iterable[Chunk], chunk_size: int,
               superbatch: int = 1) -> IngestState:
    """Drive the jitted fold over a whole (host-side) chunk stream.

    ``superbatch`` > 1 enables the throughput path: B rechunked blocks are
    stacked per dispatch (:func:`ingest_superbatch`) and the host→device
    transfer of superbatch b+1 is enqueued while b computes — JAX dispatch
    is asynchronous, so ``device_put`` of the next batch overlaps the
    running scan (double buffering).  ``superbatch=1`` is the per-chunk
    low-latency path."""
    if superbatch <= 1:
        for pts, mask in rechunk(chunks, chunk_size):
            state = ingest_chunk(state, jnp.asarray(pts), jnp.asarray(mask),
                                 grid=grid)
        return state

    def _put(batch):
        if batch is None:
            return None
        return jax.device_put(batch[0]), jax.device_put(batch[1])

    batches = _superbatches(rechunk(chunks, chunk_size), superbatch)
    nxt = _put(next(batches, None))
    while nxt is not None:
        cur, nxt = nxt, None
        state = ingest_superbatch(state, cur[0], cur[1], grid=grid)
        # state is dispatched asynchronously — assembling + transferring
        # the next superbatch here overlaps the device-side compute
        nxt = _put(next(batches, None))
    return state


def merge_states(a: IngestState, b: IngestState) -> IngestState:
    """Linear merge of two ingest folds built with IDENTICAL hash params
    (the paper's same-hash-functions contract — checked by table shape;
    value equality is the caller's responsibility, exactly as in
    ``sketch.merge``): sketch tables add, candidate reservoirs combine
    through the sort-free sorted merge (``b``'s reservoir re-keyed as
    runs via ``candidates.runs_from_candidates``), counts add, and the
    eviction watermarks max — including anything evicted by THIS merge,
    so the space-saving diagnostic stays a true upper bound.

    This is the host-level aggregation primitive: what ``psum`` does
    inside ``shard_map``, done between independently-built shard states —
    the backbone of partial aggregation (``resilience.collect_shards``),
    where exactly the shards that delivered are merged and the rest are
    accounted as lost mass."""
    if a.sketch.table.shape != b.sketch.table.shape:
        raise ValueError(
            f"cannot merge sketches of different geometry: "
            f"{a.sketch.table.shape} vs {b.sketch.table.shape}")
    # merge_runs' clamped gathers assume jnp semantics — host-side states
    # (device_get'd shard results, loaded checkpoints) arrive as numpy,
    # where an out-of-range index raises instead of clamping
    a = jax.tree_util.tree_map(jnp.asarray, a)
    b = jax.tree_util.tree_map(jnp.asarray, b)
    runs = cand_mod.runs_from_candidates(b.cands)
    cands, evicted = cand_mod.merge_runs(a.cands, runs, a.cands.capacity)
    return IngestState(
        sketch=sketch_mod.merge(a.sketch, b.sketch),
        cands=cands,
        count=a.count + b.count,
        evict_max=jnp.maximum(jnp.maximum(a.evict_max, b.evict_max),
                              evicted))


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed to parse or failed its checksum."""


def _npz_path(path) -> str:
    """np.savez appends '.npz' to suffix-less paths but np.load does not —
    normalize so save/load accept the same path string."""
    path = os.fspath(path)
    return path if path.endswith(".npz") else path + ".npz"


def backup_path(path) -> str:
    """The previous-good-generation file ``save_state(keep_backup=True)``
    rotates to (``<path>.npz.bak``)."""
    return _npz_path(path) + ".bak"


def _payload_crc(payload: dict) -> int:
    """crc32 over (name, bytes) of every array, in sorted-name order —
    the integrity digest stored inside the checkpoint itself."""
    crc = 0
    for k in sorted(payload):
        if k == "checksum_crc32":
            continue
        crc = zlib.crc32(k.encode(), crc)
        crc = zlib.crc32(np.ascontiguousarray(payload[k]).tobytes(), crc)
    return crc & 0xFFFFFFFF


def state_digest(state: IngestState) -> int:
    """crc32 fingerprint of a fold's arrays — computed at the source,
    verified on arrival (``resilience.collect_shards(verify=True)``), so
    a bit flipped in transit is detected instead of silently merged."""
    crc = 0
    for leaf in jax.tree_util.tree_leaves(state):
        crc = zlib.crc32(
            np.ascontiguousarray(np.asarray(leaf)).tobytes(), crc)
    return crc & 0xFFFFFFFF


def save_state(state: IngestState, path, extra=None,
               keep_backup: bool = False) -> None:
    """Checkpoint the ingest fold mid-stream to one ``.npz`` (resumable
    ingest; a missing ``.npz`` suffix is added).  Everything the fold
    carries — sketch table, hash params, reservoir, count, eviction
    watermark — round-trips exactly, so resuming reproduces bit-identical
    heavy hitters.

    Crash safety: the payload is written to a temp file in the target
    directory and moved into place with ``os.replace`` — readers see the
    old complete file or the new complete file, never a torn one.  A
    crc32 over every array travels inside the payload; ``load_state``
    verifies it.  ``keep_backup=True`` first rotates an existing
    checkpoint to :func:`backup_path` — the previous good generation
    ``load_state(fallback=True)`` falls back to.

    ``extra`` (optional str → array mapping) rides along under
    ``extra_``-prefixed keys — how the service persists its embed cache
    next to the fold without a second file."""
    payload = dict(
        table=np.asarray(state.sketch.table),
        hash_params=np.stack([np.asarray(p) for p in state.sketch.params]),
        cand_key_hi=np.asarray(state.cands.key_hi),
        cand_key_lo=np.asarray(state.cands.key_lo),
        cand_count=np.asarray(state.cands.count),
        cand_mask=np.asarray(state.cands.mask),
        count=np.asarray(state.count),
        evict_max=np.asarray(state.evict_max))
    for k, v in (extra or {}).items():
        if not k or not isinstance(k, str):
            raise ValueError(f"extra keys must be non-empty strings; "
                             f"got {k!r}")
        payload["extra_" + k] = np.asarray(v)
    payload["checksum_crc32"] = np.uint32(_payload_crc(payload))
    target = _npz_path(path)
    tmp = target + f".tmp.{os.getpid()}"
    try:
        # savez on an OPEN FILE OBJECT never appends a suffix, so the
        # temp name is exactly what os.replace moves
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
        if keep_backup and os.path.exists(target):
            os.replace(target, backup_path(path))
        os.replace(tmp, target)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def _load_npz(p: str, with_extra: bool):
    """One checkpoint file → state (+extras), verifying the checksum.
    Raises :class:`CheckpointCorruptError` on ANY parse or digest
    failure — a torn zip, a missing field, a flipped bit."""
    try:
        with np.load(p) as z:
            arrays = {k: z[k] for k in z.files}
    except Exception as e:                               # noqa: BLE001
        raise CheckpointCorruptError(
            f"checkpoint {p!r} unreadable: {type(e).__name__}: {e}") from e
    stored = arrays.pop("checksum_crc32", None)
    if stored is not None and int(stored) != _payload_crc(arrays):
        raise CheckpointCorruptError(
            f"checkpoint {p!r} failed its crc32 check (bit rot or a "
            f"partial overwrite)")
    try:
        params = hashing.MulShiftParams(
            *(jnp.asarray(arrays["hash_params"][i]) for i in range(6)))
        state = IngestState(
            sketch=CountSketch(table=jnp.asarray(arrays["table"]),
                               params=params),
            cands=Candidates(
                key_hi=jnp.asarray(arrays["cand_key_hi"]),
                key_lo=jnp.asarray(arrays["cand_key_lo"]),
                count=jnp.asarray(arrays["cand_count"]),
                mask=jnp.asarray(arrays["cand_mask"])),
            count=jnp.asarray(arrays["count"]),
            evict_max=jnp.asarray(arrays["evict_max"]))
    except (KeyError, IndexError, ValueError) as e:
        raise CheckpointCorruptError(
            f"checkpoint {p!r} missing/malformed fields: {e}") from e
    if not with_extra:
        return state
    extras = {k[len("extra_"):]: arrays[k] for k in arrays
              if k.startswith("extra_")}
    return state, extras


def load_state(path, with_extra: bool = False, fallback: bool = False):
    """Inverse of :func:`save_state`.  With ``with_extra=True`` returns
    ``(state, extras)`` where extras maps the un-prefixed ``extra=`` keys
    saved alongside (empty dict if none).

    Integrity: the stored crc32 is recomputed over every array —
    mismatch, torn file, or missing fields raise
    :class:`CheckpointCorruptError` (checkpoints predating the checksum
    load without verification).  ``fallback=True`` then tries the
    previous good generation at :func:`backup_path` before giving up —
    the crash-safe pairing of ``save_state(keep_backup=True)``."""
    tried = [_npz_path(path)]
    if fallback:
        tried.append(backup_path(path))
    errors = []
    for p in tried:
        if not os.path.exists(p):
            errors.append(f"{p!r}: not found")
            continue
        try:
            return _load_npz(p, with_extra)
        except CheckpointCorruptError as e:
            errors.append(str(e))
    raise CheckpointCorruptError(
        "no loadable checkpoint: " + "; ".join(errors))
