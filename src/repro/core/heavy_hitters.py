"""Global heavy-hitter recovery: candidates × merged sketch → top-K cells.

Single-shard ("exact") and distributed (SPMD) variants.  The distributed
variant is the paper's geo-distributed topology mapped onto a device mesh:

    per-device:  quantize → pack → local sketch update + local top-L
    data axis :  psum(sketch)           [paper: merge within a data center]
    pod axis  :  psum(sketch)           [paper: merge across data centers]
    everywhere:  all_gather(candidates) → dedupe → estimate on merged
                 sketch → global top-K   [paper: master-node HH extraction]

Every device finishes with the same top-K list (replicated), which is
*stronger* than the paper's single-master output and removes the
aggregation-site straggler.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import candidates as cand_mod
from repro.core import sketch as sketch_mod
from repro.core.candidates import Candidates
from repro.core.sketch import CountSketch


class HeavyHitters(NamedTuple):
    """Top-K cells: packed keys, estimated counts, validity mask."""
    key_hi: jnp.ndarray   # (K,) uint32
    key_lo: jnp.ndarray   # (K,) uint32
    count: jnp.ndarray    # (K,) float32 — sketch-estimated frequency
    mask: jnp.ndarray     # (K,) bool


def from_candidates(sk: CountSketch, cands: Candidates, k: int
                    ) -> HeavyHitters:
    """Dedupe candidate keys, estimate on the sketch, keep the top-k."""
    hi, lo, est = sketch_mod.topk_from_candidates(
        sk, cands.key_hi, cands.key_lo, k, cand_mask=cands.mask)
    mask = jnp.isfinite(est) & (est > 0)
    return HeavyHitters(key_hi=hi, key_lo=lo,
                        count=jnp.where(mask, est, 0.0), mask=mask)


def extract(sk: CountSketch, key_hi: jnp.ndarray, key_lo: jnp.ndarray,
            k: int, candidate_pool: Optional[int] = None,
            values: Optional[jnp.ndarray] = None,
            mask: Optional[jnp.ndarray] = None) -> HeavyHitters:
    """Single-shard convenience: exact local top-pool candidates, then
    sketch-estimated top-k (pool ≥ k; default 2k for head-room)."""
    pool = candidate_pool or min(2 * k, key_hi.shape[0])
    cands = cand_mod.local_topk(key_hi, key_lo, pool,
                                values=values, mask=mask)
    return from_candidates(sk, cands, k)


def distributed_extract(
        sk_local: CountSketch, cands_local: Candidates, k: int,
        merge_axes: Union[str, Sequence[str]],
) -> Tuple[HeavyHitters, CountSketch]:
    """SPMD global HH extraction (call inside shard_map / jit-with-mesh).

    ``merge_axes``: mesh axis name(s) the data is sharded over, innermost
    (fast interconnect) first, e.g. ``("data",)`` or ``("data", "pod")``.
    Returns (replicated HH list, merged sketch).
    """
    if isinstance(merge_axes, str):
        merge_axes = (merge_axes,)
    merged = sk_local
    for ax in merge_axes:           # hierarchical: ICI first, DCN second
        merged = sketch_mod.psum_merge(merged, ax)
    gathered = cands_local
    for ax in merge_axes:
        gathered = cand_mod.all_gather(gathered, ax)
    return from_candidates(merged, gathered, k), merged


def exact_counts(key_hi: jnp.ndarray, key_lo: jnp.ndarray,
                 query_hi: jnp.ndarray, query_lo: jnp.ndarray
                 ) -> jnp.ndarray:
    """Ground-truth frequency of each query key in the stream (test oracle).
    O(items × queries) — test-scale only."""
    eq = (key_hi[None, :] == query_hi[:, None]) & \
         (key_lo[None, :] == query_lo[:, None])
    return jnp.sum(eq.astype(jnp.float32), axis=1)
