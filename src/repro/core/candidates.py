"""Candidate tracking: sorted key runs, exact local top-k, reservoir merges.

The Count Sketch table estimates *frequencies* but does not store key
*identities*.  The classic stream solution keeps a heap of candidates next
to the sketch; a heap is hostile to SPMD TPU execution, so we use the
averaging argument instead: any globally (ε,ℓ₂)-heavy key is locally heavy
on at least one shard.  Each shard therefore extracts its own exact top-L
keys, and the global stage (:mod:`repro.core.heavy_hitters`) all-gathers
the candidate keys and re-estimates them on the merged sketch.

The throughput currency of the ingest hot path is :class:`KeyRuns` — the
output of ONE lexsort + run-length-encode over a chunk's keys
(:func:`sorted_runs`).  The same runs feed both sides of the streaming
fold with no further sorting:

* ``sketch.update_runs``      — the deduped scatter into the sketch table;
* :func:`merge_runs`          — the bounded reservoir merge, a *sorted
  merge* (binary-search ranking, no lexsort) against a reservoir kept
  key-sorted as a carried invariant;
* :func:`topk_from_runs`      — exact local top-k (one-shot shard path).

The legacy entry points (:func:`local_topk`, :func:`merge_topk`) are thin
compositions of the runs machinery and remain the reference semantics:
``merge_runs`` holds exactly the same live (key → count) set, bit-identical
counts included, as ``merge_topk`` over the raw keys — property-tested in
tests/test_fused_ingest.py.  Only the storage ORDER differs: ``merge_topk``
returns count-descending, ``merge_runs`` key-ascending (the invariant that
makes the next merge sort-free).  Heavy-hitter extraction canonicalizes by
key, so the two orders produce bit-identical heavy hitters.

Everything is static-shape: L is fixed, shards with fewer than L distinct
keys pad with an invalid key + mask.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


INVALID_KEY = 0xFFFFFFFF


class Candidates(NamedTuple):
    """Top-L locally-frequent keys of one shard (padded, mask-carrying).

    Two storage orders occur, by provenance:

    * :func:`local_topk` / :func:`topk_from_runs` / :func:`merge_topk`
      return count-descending order (``lax.top_k`` output order);
    * :func:`merge_runs` (and therefore the streaming reservoir) returns
      live keys ascending with all padding at the end — the key-sorted
      invariant the sort-free merge relies on.  :func:`empty` satisfies it
      trivially.

    Both orders carry identical (key, count, mask) *sets*; every consumer
    (``heavy_hitters.from_candidates``, ``all_gather`` + dedupe) is
    order-insensitive.
    """
    key_hi: jnp.ndarray    # (L,) uint32
    key_lo: jnp.ndarray    # (L,) uint32
    count: jnp.ndarray     # (L,) float32 — exact local count
    mask: jnp.ndarray      # (L,) bool — False for padding

    @property
    def capacity(self) -> int:
        """Reservoir size L (static)."""
        return self.key_hi.shape[0]

    def merge_topk(self, other: "Candidates", k: int) -> "Candidates":
        """Reservoir merge: see :func:`merge_topk`."""
        return merge_topk(self, other, k=k)


class KeyRuns(NamedTuple):
    """Run-length-encoded sorted keys of one chunk — the single-sort
    currency of the ingest hot path (see :func:`sorted_runs`).

    ``key_hi/key_lo[j]`` for j < num_runs is the j-th distinct key in
    ascending (hi, lo) order; ``count[j]`` its masked value sum; positions
    j ≥ num_runs repeat the largest sorted key with count 0 (so the arrays
    stay globally non-decreasing — required by the sort-free merge).
    """
    key_hi: jnp.ndarray    # (n,) uint32 — run keys, ascending, compacted
    key_lo: jnp.ndarray    # (n,) uint32
    count: jnp.ndarray     # (n,) summed value per run (0 past num_runs)
    live: jnp.ndarray      # (n,) bool — position < num_runs

    @property
    def size(self) -> int:
        return self.key_hi.shape[0]


def empty(k: int) -> Candidates:
    """An all-padding candidate reservoir of capacity k (merge identity;
    key-sorted trivially)."""
    return Candidates(
        key_hi=jnp.full((k,), INVALID_KEY, jnp.uint32),
        key_lo=jnp.full((k,), INVALID_KEY, jnp.uint32),
        count=jnp.zeros((k,), jnp.float32),
        mask=jnp.zeros((k,), bool))


def sorted_runs(key_hi: jnp.ndarray, key_lo: jnp.ndarray,
                values: Optional[jnp.ndarray] = None,
                mask: Optional[jnp.ndarray] = None,
                dtype=jnp.float32, assume_hi_zero: bool = False) -> KeyRuns:
    """THE sort of the ingest hot path: lexsort (hi, lo) → run-length
    segments → per-run value sum.  One TPU-native bitonic sort per chunk;
    everything downstream (sketch scatter, reservoir merge, local top-k)
    consumes the runs without re-sorting.

    ``values`` defaults to 1 (counting); ``mask`` zeroes padding rows —
    masked rows still occupy sort slots, so a run whose occurrences are all
    masked survives with count 0 (dropped later by liveness filters).

    ``assume_hi_zero`` is a STATIC fast path for keys known to fit the low
    limb (grids packing ≤ 32 bits, i.e. ``dims·bits_per_dim ≤ 32`` — the
    caller's contract): the sort compares one u32 key instead of two,
    which is the dominant cost of the whole fold.  With ``key_hi ≡ 0``
    both paths are the identical stable permutation, so results are
    bit-identical.
    """
    n = key_hi.shape[0]
    v = jnp.ones((n,), dtype) if values is None else values.astype(dtype)
    if mask is not None:
        v = v * mask.astype(dtype)
    order = jnp.lexsort((key_lo,) if assume_hi_zero else (key_lo, key_hi))
    shi, slo, sv = key_hi[order], key_lo[order], v[order]
    if assume_hi_zero:
        new_run = jnp.concatenate([
            jnp.ones((1,), bool), slo[1:] != slo[:-1]])
    else:
        new_run = jnp.concatenate([
            jnp.ones((1,), bool),
            (shi[1:] != shi[:-1]) | (slo[1:] != slo[:-1])])
    run_id = jnp.cumsum(new_run) - 1
    run_sum = jax.ops.segment_sum(sv, run_id, num_segments=n)
    # representative key of each run = first occurrence (run_id is sorted,
    # so a searchsorted replaces the costlier nonzero-with-size); dead
    # slots clip to n-1, repeating the largest sorted key so the arrays
    # stay globally non-decreasing
    first_idx = jnp.clip(
        jnp.searchsorted(run_id, jnp.arange(n), side="left"), 0, n - 1)
    return KeyRuns(key_hi=shi[first_idx], key_lo=slo[first_idx],
                   count=run_sum,
                   live=jnp.arange(n) < (run_id[-1] + 1))


def topk_from_runs(runs: KeyRuns, k: int, return_dropped: bool = False):
    """Exact top-k runs by count (count-descending order, like
    :func:`local_topk`).  ``k`` may exceed the number of slots: output is
    padded to k with invalid keys + mask=False.

    ``return_dropped=True`` additionally returns the largest live count
    NOT selected (the (k+1)-th largest; 0.0 when nothing is truncated) —
    the one-shot analog of the reservoir eviction watermark: any key with
    a larger local count is guaranteed to be among the candidates."""
    n = runs.size
    live = runs.live & (runs.count > 0)
    score = jnp.where(live, runs.count.astype(jnp.float32), -jnp.inf)
    kk = min(k, n)                      # top_k(score, k) requires k <= n
    kk2 = min(k + 1, n)                 # one extra for the drop watermark
    top_score, top_idx = jax.lax.top_k(score, kk2)
    dropped = jnp.maximum(top_score[kk2 - 1], 0.0) if kk2 > kk \
        else jnp.zeros(())              # kk == n: nothing can be dropped
    top_score, top_idx = top_score[:kk], top_idx[:kk]
    cmask = jnp.isfinite(top_score)
    out = Candidates(
        key_hi=jnp.where(cmask, runs.key_hi[top_idx],
                         jnp.uint32(INVALID_KEY)),
        key_lo=jnp.where(cmask, runs.key_lo[top_idx],
                         jnp.uint32(INVALID_KEY)),
        count=jnp.where(cmask, top_score, 0.0),
        mask=cmask)
    if kk < k:                          # fewer items than the pool: pad
        out = concat(out, empty(k - kk))
    if return_dropped:
        return out, dropped
    return out


def local_topk(key_hi: jnp.ndarray, key_lo: jnp.ndarray, k: int,
               values: Optional[jnp.ndarray] = None,
               mask: Optional[jnp.ndarray] = None) -> Candidates:
    """Exact top-k distinct keys of this shard by total count/value:
    :func:`sorted_runs` + :func:`topk_from_runs`.  O(n log n) work, fully
    vectorized, static shapes."""
    return topk_from_runs(
        sorted_runs(key_hi, key_lo, values=values, mask=mask), k)


def concat(*cands: Candidates) -> Candidates:
    """Concatenate candidate sets (e.g. after all_gather over shards)."""
    return Candidates(
        key_hi=jnp.concatenate([c.key_hi for c in cands]),
        key_lo=jnp.concatenate([c.key_lo for c in cands]),
        count=jnp.concatenate([c.count for c in cands]),
        mask=jnp.concatenate([c.mask for c in cands]))


def runs_from_candidates(c: Candidates) -> KeyRuns:
    """View a candidate set (DISTINCT keys — a reservoir or a top-k, any
    storage order) as :class:`KeyRuns`, the currency :func:`merge_runs`
    consumes: one lexsort puts live keys ascending (INVALID padding sorts
    last, count forced to 0), satisfying the globally-non-decreasing
    contract.  This is what lets two *reservoirs* merge through the same
    sort-free path as the streaming fold — the host-level mergeability
    behind ``stream.merge_states`` / partial aggregation."""
    order = jnp.lexsort((c.key_lo, c.key_hi))
    return KeyRuns(
        key_hi=c.key_hi[order],
        key_lo=c.key_lo[order],
        count=jnp.where(c.mask, c.count, 0.0)[order].astype(jnp.float32),
        live=c.mask[order])


def merge_topk(a: Candidates, b: Candidates, k: int) -> Candidates:
    """Unordered reservoir merge: concat → lexsort → dedupe (sum counts of
    equal keys) → exact top-k.  Works for ANY input order (the all-gather
    merge path); the streaming fold uses the sort-free :func:`merge_runs`
    instead, which holds the identical live set.  A key held by either side
    keeps its full accumulated count, so as long as the number of distinct
    keys ever seen stays ≤ k the reservoir equals the exact top-k of the
    whole stream."""
    c = concat(a, b)
    return local_topk(c.key_hi, c.key_lo, k, values=c.count, mask=c.mask)


def _searchsorted_pair(b_hi: jnp.ndarray, b_lo: jnp.ndarray,
                       q_hi: jnp.ndarray, q_lo: jnp.ndarray,
                       side: str) -> jnp.ndarray:
    """searchsorted over lexicographically sorted (hi, lo) uint32 pairs.

    64-bit keys live as uint32 limb pairs (TPUs lack 64-bit ints), so
    ``jnp.searchsorted`` cannot see them as one value; this is the standard
    vectorized binary search with a two-limb comparator — ⌈log₂(n+1)⌉
    statically-unrolled gather rounds, no sort anywhere.
    """
    n = b_hi.shape[0]
    lo = jnp.zeros(q_hi.shape, jnp.int32)
    hi = jnp.full(q_hi.shape, n, jnp.int32)
    for _ in range(max(1, math.ceil(math.log2(n + 1)))):
        done = lo >= hi
        mid = (lo + hi) >> 1
        mhi, mlo = b_hi[mid], b_lo[mid]
        if side == "left":              # count of b strictly < q
            go_right = (mhi < q_hi) | ((mhi == q_hi) & (mlo < q_lo))
        else:                           # count of b <= q
            go_right = (mhi < q_hi) | ((mhi == q_hi) & (mlo <= q_lo))
        lo = jnp.where(done, lo, jnp.where(go_right, mid + 1, lo))
        hi = jnp.where(done, hi, jnp.where(go_right, hi, mid))
    return lo


def merge_runs(pool: Candidates, runs: KeyRuns, k: int
               ) -> Tuple[Candidates, jnp.ndarray]:
    """Sort-free bounded reservoir merge: the streaming-fold hot path.

    ``pool`` MUST be key-sorted (live keys ascending, padding at the end —
    the invariant :func:`empty` starts and this function maintains); the
    chunk side arrives pre-deduped and sorted as :class:`KeyRuns`.  The
    merge is then a *sorted merge*, built entirely from gathers, cumsums
    and reductions — XLA-CPU/TPU-hostile primitives (sort, scatter,
    nonzero, top_k) are deliberately absent from the whole function:

    1. cross binary search ranks each side's slot in the combined order
       (pool wins ties) — no sort;
    2. the merged sorted view is materialized by GATHER from the monotone
       rank arrays (``searchsorted`` of the positions) — no scatter;
    3. duplicate keys sum by a shifted pair-add: the pool holds distinct
       keys and the runs are deduped, so every merged key has ≤ 2 nonzero
       occurrences, adjacent, pool first — no segment_sum;
    4. the k-th largest count comes from a bitwise bisection on the
       (monotone for finite non-negatives) float32 bit pattern, counting
       survivors per trial bit — no top_k;
    5. selected entries compact to the front via ``searchsorted`` over the
       selection cumsum — order, and therefore the key-sorted invariant,
       is preserved.

    Bit-identity with :func:`merge_topk`: identical live keys and exactly
    equal counts (all adds are exact small integers in f32; the selection
    reproduces ``lax.top_k``'s break-ties-by-lower-index rule, which in
    both paths means ascending key order).

    Returns ``(merged, evicted_max)`` where ``evicted_max`` is the largest
    count evicted in THIS merge (0.0 if nothing was evicted) — the
    space-saving diagnostic accumulated by ``stream.IngestState``.
    """
    pool_n, n = pool.capacity, runs.size
    tot = pool_n + n
    p_cnt = pool.count * pool.mask.astype(pool.count.dtype)
    r_cnt = runs.count.astype(jnp.float32)

    # 1. combined sorted order via cross binary search (stable, pool first)
    pos_p = jnp.arange(pool_n, dtype=jnp.int32) + _searchsorted_pair(
        runs.key_hi, runs.key_lo, pool.key_hi, pool.key_lo, "left")
    pos_r = jnp.arange(n, dtype=jnp.int32) + _searchsorted_pair(
        pool.key_hi, pool.key_lo, runs.key_hi, runs.key_lo, "right")

    # 2. merged view by gather: every slot is pool's or runs'; counting
    # run slots ≤ p in the SMALL (n-entry, cache-resident) rank array
    # gives both the discriminator and both gather indices — pidx =
    # p - (#run slots ≤ p), so no search over the pool_n-entry side
    p_all = jnp.arange(tot, dtype=jnp.int32)
    r_le = jnp.searchsorted(pos_r, p_all, side="left").astype(jnp.int32)
    is_run = (r_le < n) & (pos_r[jnp.clip(r_le, 0, n - 1)] == p_all)
    from_pool = ~is_run
    pidx = p_all - r_le - is_run.astype(jnp.int32)
    pidx_c = jnp.clip(pidx, 0, pool_n - 1)
    ridx = jnp.clip(p_all - pidx - 1, 0, n - 1)
    m_hi = jnp.where(from_pool, pool.key_hi[pidx_c], runs.key_hi[ridx])
    m_lo = jnp.where(from_pool, pool.key_lo[pidx_c], runs.key_lo[ridx])
    m_cnt = jnp.where(from_pool, p_cnt[pidx_c], r_cnt[ridx])

    # 3. pair-add dedupe: each key occurs ≤ 2× with nonzero count (pool
    # distinct ∧ runs deduped), adjacent, pool first — the sum of a run
    # is its head count plus its immediate same-key successor's
    new_run = jnp.concatenate([
        jnp.ones((1,), bool),
        (m_hi[1:] != m_hi[:-1]) | (m_lo[1:] != m_lo[:-1])])
    nxt_cnt = jnp.concatenate([m_cnt[1:], jnp.zeros((1,), jnp.float32)])
    csum = m_cnt + jnp.where(jnp.concatenate([~new_run[1:],
                                              jnp.zeros((1,), bool)]),
                             nxt_cnt, 0.0)
    live = new_run & (csum > 0)       # value valid at run heads only

    # 4. k-th largest live count: bitwise-greedy max threshold t with
    # |{live : count ≥ t}| ≥ k, on the f32 bit pattern (monotone for
    # finite non-negative floats); t = 0 when fewer than k live
    cbits = jax.lax.bitcast_convert_type(csum, jnp.uint32)
    thresh = jnp.zeros((), jnp.uint32)
    for b in range(30, -1, -1):       # counts are finite positives: ≤ 2³¹
        cand = thresh | jnp.uint32(1 << b)
        cnt = jnp.sum(live & (cbits >= cand))
        thresh = jnp.where(cnt >= k, cand, thresh)
    gt = live & (cbits > thresh)
    n_gt = jnp.sum(gt.astype(jnp.int32))
    eq = live & (cbits == thresh) & (csum > 0)
    eq_rank = jnp.cumsum(eq) - 1
    sel = gt | (eq & (eq_rank < (k - n_gt)))

    evicted = jnp.where(live & ~sel, csum, 0.0)
    evicted_max = jnp.max(evicted, initial=0.0)

    # 5. gather-compact the selected run heads to the front: the q-th
    # output is the merged position where the selection cumsum first
    # reaches q+1 (ascending → key-sorted invariant preserved)
    csel = jnp.cumsum(sel)
    src = jnp.clip(jnp.searchsorted(csel, jnp.arange(1, k + 1),
                                    side="left"), 0, tot - 1)
    valid = jnp.arange(k) < csel[-1]
    out = Candidates(
        key_hi=jnp.where(valid, m_hi[src], jnp.uint32(INVALID_KEY)),
        key_lo=jnp.where(valid, m_lo[src], jnp.uint32(INVALID_KEY)),
        count=jnp.where(valid, csum[src], 0.0),
        mask=valid)
    return out, evicted_max


def all_gather(cands: Candidates, axis_name) -> Candidates:
    """Gather every shard's candidates along a mesh axis -> (shards*L,) sets."""
    gathered = jax.lax.all_gather(cands, axis_name, tiled=True)
    return gathered
