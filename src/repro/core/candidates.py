"""Exact local top-k candidate extraction over one data shard.

The Count Sketch table estimates *frequencies* but does not store key
*identities*.  The classic stream solution keeps a heap of candidates next
to the sketch; a heap is hostile to SPMD TPU execution, so we use the
averaging argument instead: any globally (ε,ℓ₂)-heavy key is locally heavy
on at least one shard.  Each shard therefore extracts its own exact top-L
keys (sort → run-length-encode → top-k), and the global stage
(:mod:`repro.core.heavy_hitters`) all-gathers the candidate keys and
re-estimates them on the merged sketch.

Everything is static-shape: L is fixed, shards with fewer than L distinct
keys pad with an invalid key + mask.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


INVALID_KEY = 0xFFFFFFFF


class Candidates(NamedTuple):
    """Top-L locally-frequent keys of one shard (padded, mask-carrying)."""
    key_hi: jnp.ndarray    # (L,) uint32
    key_lo: jnp.ndarray    # (L,) uint32
    count: jnp.ndarray     # (L,) float32 — exact local count
    mask: jnp.ndarray      # (L,) bool — False for padding

    @property
    def capacity(self) -> int:
        """Reservoir size L (static)."""
        return self.key_hi.shape[0]

    def merge_topk(self, other: "Candidates", k: int) -> "Candidates":
        """Reservoir merge: see :func:`merge_topk`."""
        return merge_topk(self, other, k=k)


def empty(k: int) -> Candidates:
    """An all-padding candidate reservoir of capacity k (merge identity)."""
    return Candidates(
        key_hi=jnp.full((k,), INVALID_KEY, jnp.uint32),
        key_lo=jnp.full((k,), INVALID_KEY, jnp.uint32),
        count=jnp.zeros((k,), jnp.float32),
        mask=jnp.zeros((k,), bool))


def local_topk(key_hi: jnp.ndarray, key_lo: jnp.ndarray, k: int,
               values: Optional[jnp.ndarray] = None,
               mask: Optional[jnp.ndarray] = None) -> Candidates:
    """Exact top-k distinct keys of this shard by total count/value.

    sort (TPU-native bitonic) → run-length segments → segment_sum →
    top_k.  O(n log n) work, fully vectorized, static shapes.

    ``k`` may exceed the number of items n (e.g. a small chunk against a
    large candidate pool): the selection is clamped to n and the output is
    padded to k with invalid keys + mask=False.
    """
    n = key_hi.shape[0]
    v = jnp.ones((n,), jnp.float32) if values is None \
        else values.astype(jnp.float32)
    if mask is not None:
        v = v * mask.astype(jnp.float32)
    order = jnp.lexsort((key_lo, key_hi))
    shi, slo, sv = key_hi[order], key_lo[order], v[order]
    new_run = jnp.concatenate([
        jnp.ones((1,), bool),
        (shi[1:] != shi[:-1]) | (slo[1:] != slo[:-1])])
    run_id = jnp.cumsum(new_run) - 1
    run_sum = jax.ops.segment_sum(sv, run_id, num_segments=n)   # (n,) padded
    first_idx = jnp.where(new_run, size=n, fill_value=n - 1)[0]
    rhi, rlo = shi[first_idx], slo[first_idx]
    num_runs = run_id[-1] + 1
    live = jnp.arange(n) < num_runs
    # masked-out inputs can form runs with sum 0 — drop them too
    live &= run_sum > 0
    score = jnp.where(live, run_sum, -jnp.inf)
    kk = min(k, n)                      # top_k(score, k) requires k <= n
    top_score, top_idx = jax.lax.top_k(score, kk)
    cmask = jnp.isfinite(top_score)
    out = Candidates(
        key_hi=jnp.where(cmask, rhi[top_idx], jnp.uint32(INVALID_KEY)),
        key_lo=jnp.where(cmask, rlo[top_idx], jnp.uint32(INVALID_KEY)),
        count=jnp.where(cmask, top_score, 0.0),
        mask=cmask)
    if kk < k:                          # fewer items than the pool: pad
        pad = empty(k - kk)
        out = concat(out, pad)
    return out


def concat(*cands: Candidates) -> Candidates:
    """Concatenate candidate sets (e.g. after all_gather over shards)."""
    return Candidates(
        key_hi=jnp.concatenate([c.key_hi for c in cands]),
        key_lo=jnp.concatenate([c.key_lo for c in cands]),
        count=jnp.concatenate([c.count for c in cands]),
        mask=jnp.concatenate([c.mask for c in cands]))


def merge_topk(a: Candidates, b: Candidates, k: int) -> Candidates:
    """Bounded reservoir merge: concat → dedupe (sum counts of equal keys) →
    exact top-k.  The streaming ingest invariant: a key held by either side
    keeps its full accumulated count, so as long as the number of distinct
    keys ever seen stays ≤ k the reservoir equals the exact top-k of the
    whole stream.  Reuses the sort/RLE machinery of :func:`local_topk`
    (counts ride in as ``values``); padding entries carry count 0 and are
    dropped by the run-sum liveness filter."""
    c = concat(a, b)
    return local_topk(c.key_hi, c.key_lo, k, values=c.count, mask=c.mask)


def all_gather(cands: Candidates, axis_name) -> Candidates:
    """Gather every shard's candidates along a mesh axis -> (shards*L,) sets."""
    gathered = jax.lax.all_gather(cands, axis_name, tiled=True)
    return gathered
