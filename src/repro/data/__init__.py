from repro.data.synthetic import (gaussian_mixture, zipf_token_stream,
                                  clustered_points_sharded)
from repro.data.loader import ShardedLoader, ShardPlan
