"""Sharded input pipeline with over-decomposition (straggler mitigation).

Work is split into many more logical shards than hosts (default 16×).
Each host owns a deterministic *primary* slice; leftover shards from a
slow/failed host re-queue onto finishers — because assignment is a pure
function of (epoch, shard count, host count), every host computes the
same plan with zero coordination.  Resuming after a crash replays the
plan from the recorded (epoch, cursor).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    num_shards: int              # logical shards (≫ hosts)
    num_hosts: int
    epoch: int = 0

    def shards_for(self, host: int) -> List[int]:
        """Deterministic primary assignment: strided round-robin, rotated
        per epoch so hot shards move between hosts."""
        rot = (self.epoch * 7919) % self.num_shards
        return [(s + rot) % self.num_shards
                for s in range(host, self.num_shards, self.num_hosts)]

    def steal_order(self, host: int) -> List[int]:
        """Order in which a finished host picks up other hosts' leftovers
        (reverse order of the victim's own list — steal from the tail)."""
        order = []
        for other in range(1, self.num_hosts):
            victim = (host + other) % self.num_hosts
            order.extend(reversed(self.shards_for(victim)))
        return order


class ShardedLoader:
    """Iterates (shard_id, batch) pairs for one host.

    ``make_batch(shard_id, batch_idx)`` generates data purely from ids —
    works for synthetic generators and for file-backed shards alike.

    Fault handling: ``on_error(shard, exc) -> bool`` (optional) is
    consulted when ``make_batch`` raises.  Returning True SKIPS the shard
    — it is recorded in ``self.failed``, left out of ``completed`` (so a
    shared completion board lets another host's steal pass rescue it),
    and NONE of its batches are delivered: with a handler installed each
    shard's batches are buffered and yielded only once the whole shard
    materialized, so a mid-shard failure can never half-deliver (the
    streaming fold downstream cannot un-ingest).  Returning False/None
    re-raises (fail loud).  Without a handler, behavior is unchanged:
    batches stream unbuffered and errors propagate.
    """

    def __init__(self, plan: ShardPlan, host: int,
                 make_batch: Callable[[int, int], dict],
                 batches_per_shard: int = 1,
                 completed: Optional[Sequence[int]] = None,
                 on_error: Optional[Callable[[int, Exception], bool]] = None):
        self.plan = plan
        self.host = host
        self.make_batch = make_batch
        self.batches_per_shard = batches_per_shard
        self.completed = set(completed or ())
        self.on_error = on_error
        self.failed: set = set()

    def _shard_batches(self, shard: int) -> Iterator[tuple]:
        """All-or-nothing delivery of one shard (see class docstring).
        Yields nothing if the shard failed and the handler swallowed."""
        if self.on_error is None:
            for b in range(self.batches_per_shard):
                yield shard, self.make_batch(shard, b)
            return
        try:
            batches = [self.make_batch(shard, b)
                       for b in range(self.batches_per_shard)]
        except Exception as e:                           # noqa: BLE001
            if self.on_error(shard, e):
                self.failed.add(shard)
                return
            raise
        for batch in batches:
            yield shard, batch

    def __iter__(self) -> Iterator[tuple]:
        for shard in self.plan.shards_for(self.host):
            if shard in self.completed:
                continue
            delivered = False
            for pair in self._shard_batches(shard):
                delivered = True
                yield pair
            if delivered or shard not in self.failed:
                self.completed.add(shard)

    def steal(self, globally_completed: Sequence[int]) -> Iterator[tuple]:
        """After finishing the primary slice: process other hosts' leftovers
        that nobody has completed yet (straggler pickup).  Failed shards
        are skipped here too (and stay failed — this host's view of the
        shard is broken; a DIFFERENT host's steal pass may still get it)."""
        done = set(globally_completed) | self.completed | self.failed
        for shard in self.plan.steal_order(self.host):
            if shard in done:
                continue
            delivered = False
            for pair in self._shard_batches(shard):
                delivered = True
                yield pair
            done.add(shard)
            if delivered or shard not in self.failed:
                self.completed.add(shard)
