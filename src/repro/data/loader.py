"""Sharded input pipeline with over-decomposition (straggler mitigation).

Work is split into many more logical shards than hosts (default 16×).
Each host owns a deterministic *primary* slice; leftover shards from a
slow/failed host re-queue onto finishers — because assignment is a pure
function of (epoch, shard count, host count), every host computes the
same plan with zero coordination.  Resuming after a crash replays the
plan from the recorded (epoch, cursor).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    num_shards: int              # logical shards (≫ hosts)
    num_hosts: int
    epoch: int = 0

    def shards_for(self, host: int) -> List[int]:
        """Deterministic primary assignment: strided round-robin, rotated
        per epoch so hot shards move between hosts."""
        rot = (self.epoch * 7919) % self.num_shards
        return [(s + rot) % self.num_shards
                for s in range(host, self.num_shards, self.num_hosts)]

    def steal_order(self, host: int) -> List[int]:
        """Order in which a finished host picks up other hosts' leftovers
        (reverse order of the victim's own list — steal from the tail)."""
        order = []
        for other in range(1, self.num_hosts):
            victim = (host + other) % self.num_hosts
            order.extend(reversed(self.shards_for(victim)))
        return order


class ShardedLoader:
    """Iterates (shard_id, batch) pairs for one host.

    ``make_batch(shard_id, batch_idx)`` generates data purely from ids —
    works for synthetic generators and for file-backed shards alike.
    """

    def __init__(self, plan: ShardPlan, host: int,
                 make_batch: Callable[[int, int], dict],
                 batches_per_shard: int = 1,
                 completed: Optional[Sequence[int]] = None):
        self.plan = plan
        self.host = host
        self.make_batch = make_batch
        self.batches_per_shard = batches_per_shard
        self.completed = set(completed or ())

    def __iter__(self) -> Iterator[tuple]:
        for shard in self.plan.shards_for(self.host):
            if shard in self.completed:
                continue
            for b in range(self.batches_per_shard):
                yield shard, self.make_batch(shard, b)
            self.completed.add(shard)

    def steal(self, globally_completed: Sequence[int]) -> Iterator[tuple]:
        """After finishing the primary slice: process other hosts' leftovers
        that nobody has completed yet (straggler pickup)."""
        done = set(globally_completed) | self.completed
        for shard in self.plan.steal_order(self.host):
            if shard in done:
                continue
            for b in range(self.batches_per_shard):
                yield shard, self.make_batch(shard, b)
            done.add(shard)
            self.completed.add(shard)
