"""Synthetic data generators.

* ``gaussian_mixture`` — clustered point clouds matching the paper's data
  statistics (dense clusters + uniform background, high density contrast):
  the stand-in for the cancer-pixel and SDSS-star sets, with ground-truth
  labels the real data lacks.
* ``zipf_token_stream`` — LM token batches with zipfian unigram statistics
  (so losses move meaningfully during example training runs).
* ``clustered_points_sharded`` — deterministic per-shard generation: shard
  w of W generates its own slice from fold_in(seed, w); no host ever
  materializes the global array (the paper's geo-distributed setting).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MixtureSpec:
    dims: int = 8
    n_clusters: int = 10
    cluster_std: float = 0.02
    background_frac: float = 0.3
    box_lo: float = 0.0
    box_hi: float = 1.0

    def centers(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        return rng.uniform(self.box_lo + 0.1, self.box_hi - 0.1,
                           size=(self.n_clusters, self.dims))


def gaussian_mixture(n: int, spec: MixtureSpec = MixtureSpec(),
                     seed: int = 0, shuffle: bool = True
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (points (N, D) f32 in the box, labels (N,) int: -1=background)."""
    rng = np.random.default_rng(seed + 1)
    centers = spec.centers(seed)
    n_bg = int(n * spec.background_frac)
    n_cl = n - n_bg
    per = n_cl // spec.n_clusters
    pts = [rng.uniform(spec.box_lo, spec.box_hi, size=(n_bg, spec.dims))]
    labels = [np.full((n_bg,), -1, np.int32)]
    for i, c in enumerate(centers):
        m = per if i < spec.n_clusters - 1 else n_cl - per * (spec.n_clusters - 1)
        pts.append(c + spec.cluster_std * rng.normal(size=(m, spec.dims)))
        labels.append(np.full((m,), i, np.int32))
    pts = np.clip(np.concatenate(pts), spec.box_lo, spec.box_hi)
    labels = np.concatenate(labels)
    if shuffle:
        perm = rng.permutation(n)
        pts, labels = pts[perm], labels[perm]
    return pts.astype(np.float32), labels


def clustered_points_sharded(shard: int, n_per_shard: int,
                             spec: MixtureSpec = MixtureSpec(),
                             seed: int = 0) -> np.ndarray:
    """Shard-local generation — same mixture, disjoint randomness.  Every
    site draws from the identical cluster model (the paper's assumption:
    one underlying distribution, geographically split)."""
    pts, _ = gaussian_mixture(n_per_shard, spec,
                              seed=seed * 100_003 + shard * 7 + 13)
    return pts


def zipf_token_stream(key: jax.Array, batch: int, seq: int, vocab: int,
                      alpha: float = 1.2) -> dict:
    """LM batch with zipfian tokens + shifted labels."""
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    probs = 1.0 / ranks ** alpha
    probs = probs / jnp.sum(probs)
    toks = jax.random.choice(key, vocab, shape=(batch, seq + 1), p=probs)
    return {
        "tokens": toks[:, :-1].astype(jnp.int32),
        "labels": toks[:, 1:].astype(jnp.int32),
        "loss_mask": jnp.ones((batch, seq), jnp.float32),
    }
