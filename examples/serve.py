"""Batched serving: prefill a batch of prompts, then decode with KV caches.

    PYTHONPATH=src python examples/serve.py [--arch tinyllama-1.1b] \
        [--batch 4] [--prompt-len 32] [--gen 16]

Uses the smoke-size variant of any assigned arch (the full configs need a
pod).  Demonstrates the serve_step path the decode_32k / long_500k
dry-run cells lower: prefill -> argmax decode loop against the cache
(incl. SSM-state decode for mamba/jamba).

NOTE: this (and launch/serve.py) serves the LM stack.  The Sketch-and-
Scale serving counterpart — incremental ingest + warm re-embed +
out-of-sample transform() — is examples/sns_service.py on top of
core.service.SnsService.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402

from repro.configs import ARCH_IDS, get_config                 # noqa: E402
from repro.models import model as model_mod                    # noqa: E402
from repro.train.steps import (make_prefill_step,              # noqa: E402
                               make_decode_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    print(f"[model] {args.arch} (smoke config: {cfg.num_layers}L "
          f"d{cfg.d_model}, vocab {cfg.vocab_size})")
    params = model_mod.init_params(jax.random.key(0), cfg)

    cache_len = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    decode = jax.jit(make_decode_step(cfg))

    key = jax.random.key(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.num_prefix, cfg.d_model), cfg.pdtype)
    if cfg.encoder_layers:
        batch["src_embeds"] = 0.02 * jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), cfg.pdtype)

    t0 = time.perf_counter()
    logits, state = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"[prefill] batch={args.batch} len={args.prompt_len} "
          f"-> {t_prefill * 1e3:.1f} ms")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, state = decode(params, tok, state)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print(f"[decode] {args.gen - 1} steps -> "
          f"{t_decode * 1e3 / max(args.gen - 1, 1):.1f} ms/token "
          f"({args.batch * (args.gen - 1) / t_decode:.0f} tok/s batch)")
    print(f"[sample] first sequence token ids: {gen[0].tolist()}")


if __name__ == "__main__":
    main()
