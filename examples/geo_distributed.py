"""Geo-distributed Sketch-and-Scale: the full pipeline on a device mesh.

    PYTHONPATH=src python examples/geo_distributed.py

Simulates 2 "data centers" x 4 edge workers (8 host devices).  The whole
paper pipeline runs without leaving ``shard_map``:

  ingest — each worker sketches ONLY its local shard on the ("pod",
    "data") mesh; raw points never cross the pod axis, the fixed-size
    sketches merge hierarchically (psum over "data" = intra-DC ICI, then
    "pod" = inter-DC WAN) and every site recovers the identical global
    heavy-hitter list (``core.geo``);
  embed — the weighted heavy-hitter representatives are embedded with the
    optimizer row-block-sharded over a 1-D embed mesh of the same 8
    devices (``SnsConfig.embed_mesh`` → ``core.tsne``/``core.umap`` under
    ``shard_map``): per iteration one all_gather of the block positions +
    psums of fixed-size partials, no cross-device scatter.

Mesh/axis plumbing both stages share lives in ``core.mesh``.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys                                                     # noqa: E402
sys.path.insert(0, "src")

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402

from repro.core import geo, pipeline, quantize                 # noqa: E402
from repro.core import mesh as mesh_mod                        # noqa: E402
from repro.data.synthetic import (MixtureSpec,                 # noqa: E402
                                  clustered_points_sharded)


def main():
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    print(f"[mesh] {dict(mesh.shape)} — pod=data centers, data=edge workers")

    spec = MixtureSpec(dims=6, n_clusters=10, cluster_std=0.015,
                       background_frac=0.3)
    n_per = 50_000
    shards = [clustered_points_sharded(w, n_per, spec, seed=1)
              for w in range(8)]
    pts = jnp.asarray(np.concatenate(shards))
    print(f"[data] 8 x {n_per} points, one shard per worker "
          f"(same underlying mixture, disjoint draws)")

    # ---- ingest → HH: every site must agree on the grid (fixed box, no
    # data pass); sketching + hierarchical merge run inside shard_map
    grid = quantize.GridSpec(dims=spec.dims, bins=16,
                             lo=tuple([0.0] * spec.dims),
                             hi=tuple([1.0] * spec.dims))
    res = geo.geo_extract(mesh, grid, pts, rows=8, log2_cols=14,
                          top_k=256, data_axes=("data", "pod"), seed=0)
    live = int(np.asarray(res.hh.mask).sum())
    cov = float(np.asarray(res.hh.count).sum()) / (8 * n_per)
    print(f"[merge] sketch bytes per site = "
          f"{res.merged.table.size * 4 / 2**20:.1f} MiB "
          f"(vs {8 * n_per * spec.dims * 4 / 2**20:.0f} MiB raw)")
    print(f"[hh] {live} global heavy hitters, coverage {cov:.1%}; "
          f"identical list on every device (replicated output)")

    # show the top-5 cells in data space
    coords = quantize.unpack(grid, (res.hh.key_hi, res.hh.key_lo))
    centers = np.asarray(quantize.cell_center(grid, coords))[:5]
    counts = np.asarray(res.hh.count)[:5]
    for c, n in zip(centers, counts):
        print(f"   cell@{np.round(c, 2).tolist()}  count={n:.0f}")

    # ---- embed: the same 8 devices re-form as a 1-D embed mesh and the
    # UMAP epoch loop runs row-block-sharded under shard_map
    embed_mesh = mesh_mod.make_embed_mesh(8)
    cfg = pipeline.SnsConfig(top_k=256, embedder="umap", embed_block=512,
                             max_replicas=1, embed_mesh=embed_mesh)
    reps, emb, w, _ = pipeline.embed_stage(cfg, grid, res.hh)
    print(f"[embed] {emb.shape[0]} weighted representatives → "
          f"{emb.shape[1]}D, optimizer row-block-sharded over "
          f"{mesh_mod.axis_size(embed_mesh, mesh_mod.EMBED_AXIS)} devices "
          f"('{mesh_mod.EMBED_AXIS}' axis)")
    print(f"[embed] span x={float(emb[:, 0].min()):+.2f}"
          f"..{float(emb[:, 0].max()):+.2f} "
          f"y={float(emb[:, 1].min()):+.2f}..{float(emb[:, 1].max()):+.2f}, "
          f"total weight {float(np.sum(w)):.0f}")


if __name__ == "__main__":
    main()
