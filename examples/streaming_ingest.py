"""Streaming ingest: bounded-memory Sketch-and-Scale over a sharded stream.

    PYTHONPATH=src python examples/streaming_ingest.py [--n 400000]

The paper's 'single stream I/O' regime on one host: data arrives as
shard-plan batches from a ShardedLoader (over-decomposed, deterministic,
resumable) and is folded chunk-by-chunk through core.stream.IngestState —
a Count Sketch plus a bounded candidate reservoir.  No stage ever holds
the full (N, D) array: the grid comes from a chunked min/max pass, the
sketch stage's working set is O(ingest_chunk + candidate_pool), and only
the heavy-hitter representatives reach the embedder.
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core import pipeline                               # noqa: E402
from repro.core.umap import UmapConfig                        # noqa: E402
from repro.data.loader import ShardPlan                       # noqa: E402
from repro.data.synthetic import (MixtureSpec,                # noqa: E402
                                  clustered_points_sharded)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=400_000)
    ap.add_argument("--shards", type=int, default=32)
    ap.add_argument("--top-k", type=int, default=512)
    ap.add_argument("--chunk", type=int, default=16_384)
    ap.add_argument("--superbatch", type=int, default=8,
                    help="chunks folded per dispatch (1 = per-chunk)")
    args = ap.parse_args()

    spec = MixtureSpec(dims=6, n_clusters=8, cluster_std=0.015,
                       background_frac=0.3)
    per_shard = args.n // args.shards
    plan = ShardPlan(num_shards=args.shards, num_hosts=1)
    chunks = pipeline.chunks_from_loader(
        plan, host=0,
        make_batch=lambda shard, b: clustered_points_sharded(
            shard, per_shard, spec, seed=7))
    print(f"[stream] {args.shards} shards x {per_shard} points; no host "
          f"ever holds the {args.n}x{spec.dims} array")

    cfg = pipeline.SnsConfig(bins=16, rows=8, log2_cols=14,
                             top_k=args.top_k, candidate_pool=4 * args.top_k,
                             ingest_chunk=args.chunk,
                             ingest_superbatch=args.superbatch,
                             max_replicas=4)
    res = pipeline.run_streaming(
        cfg, chunks, umap_cfg=UmapConfig(n_neighbors=10, n_epochs=200))
    if res.hh_error_bound == 0.0:
        print("[hh] reservoir never evicted — heavy hitters exact "
              "up to the pool size")
    else:
        print(f"[hh] space-saving watermark {res.hh_error_bound:.0f} "
              f"(largest count ever evicted from the reservoir)")

    live = int(np.asarray(res.hh.mask).sum())
    state_bytes = (cfg.rows * (1 << cfg.log2_cols) * 4          # table
                   + (cfg.candidate_pool or 2 * cfg.top_k) * 13  # reservoir
                   + cfg.ingest_chunk * spec.dims * 4)           # chunk
    print(f"[ingest] working set ≈ {state_bytes / 2**20:.1f} MiB "
          f"(vs {args.n * spec.dims * 4 / 2**20:.0f} MiB resident)")
    print(f"[hh] {live} heavy hitters, coverage {res.coverage:.1%} "
          f"of the {args.n}-point stream")
    print(f"[embed] {res.embedding.shape[0]} representatives -> "
          f"{res.embedding.shape[1]}-D via {cfg.embedder}")

    out = np.concatenate([np.asarray(res.embedding),
                          res.rep_weight[:, None]], axis=1)
    np.savetxt("/tmp/sns_streaming_embedding.csv", out, delimiter=",",
               header="x,y,weight")
    print("[out] /tmp/sns_streaming_embedding.csv")


if __name__ == "__main__":
    main()
