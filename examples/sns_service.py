"""Online SnS service: ingest → serve → drift → warm refresh → transform.

    PYTHONPATH=src python examples/sns_service.py [--n 100000] [--tsne]

The SnS counterpart of the LM-stack servers (`examples/serve.py` /
`launch/serve.py` serve language models; THIS is the paper's pipeline as
a service, ROADMAP item 3).  One episode of the serving loop:

  1. `update(chunks)`   — fold a stream into the live ingest state
                          (linear sketch: no history re-read);
  2. `refresh()`        — heavy hitters → representatives → embedding
                          (cold the first time);
  3. more `update()`    — absorb a drift batch; `needs_refresh()` trips
                          once pending mass crosses the drift gate;
  4. `refresh()` again  — warm: returning cells seeded at their old
                          coordinates, ~10× fewer optimizer iterations;
  5. `transform(q)`     — out-of-sample queries placed against the
                          frozen embedding, no optimizer, batched.

Prints the absorption rate, warm-start match statistics, and transform
throughput; writes the served embedding to /tmp/sns_service_embedding.csv.
"""
import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import pipeline, quantize                     # noqa: E402
from repro.core.service import ServiceConfig, SnsService      # noqa: E402
from repro.core.tsne import TsneConfig                        # noqa: E402
from repro.core.umap import UmapConfig                        # noqa: E402
from repro.data import gaussian_mixture                       # noqa: E402
from repro.data.synthetic import MixtureSpec                  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--drift-frac", type=float, default=0.08)
    ap.add_argument("--dims", type=int, default=4)
    ap.add_argument("--top-k", type=int, default=512)
    ap.add_argument("--tsne", action="store_true")
    ap.add_argument("--queries", type=int, default=50_000)
    args = ap.parse_args()

    spec = MixtureSpec(dims=args.dims, n_clusters=8, cluster_std=0.05,
                       background_frac=0.1)
    base, _ = gaussian_mixture(args.n, spec, seed=0)
    drift, _ = gaussian_mixture(int(args.n * args.drift_frac), spec, seed=1)
    base, drift = np.asarray(base, np.float32), np.asarray(drift, np.float32)

    cfg = pipeline.SnsConfig(
        bins=16, rows=8, log2_cols=14, top_k=args.top_k,
        embedder="tsne" if args.tsne else "umap",
        embed_backend="dense", max_replicas=4)
    # the grid is the service's fixed frame of reference (cell keys must
    # be comparable across refreshes) — fit it on what we expect to see
    grid = quantize.fit_grid(np.concatenate([base, drift]), cfg.bins)
    svc = SnsService(cfg, grid,
                     tsne_cfg=TsneConfig(dims=2, n_iter=400),
                     umap_cfg=UmapConfig(dims=2, n_epochs=200),
                     service_cfg=ServiceConfig())

    stats = svc.update(np.array_split(base, 8))
    print(f"[update]  absorbed {stats['points']:.0f} points at "
          f"{stats['points_per_sec']:,.0f} pts/s")

    t0 = time.perf_counter()
    cold = svc.refresh()
    print(f"[refresh] cold: {cold.embedding.shape[0]} reps embedded in "
          f"{cold.n_iters} iters ({time.perf_counter() - t0:.1f}s)")

    stats = svc.update(drift)
    print(f"[update]  drift {stats['points']:.0f} points -> pending "
          f"{stats['pending_fraction']:.1%}, "
          f"needs_refresh={stats['needs_refresh']}")

    t0 = time.perf_counter()
    warm = svc.refresh()
    print(f"[refresh] warm: matched {warm.n_matched}, new {warm.n_new}, "
          f"{warm.n_iters} iters ({time.perf_counter() - t0:.1f}s)")

    q, _ = gaussian_mixture(args.queries, spec, seed=2)
    q = np.asarray(q, np.float32)
    svc.transform(q[:1024])                       # compile
    t0 = time.perf_counter()
    y = svc.transform(q)
    dt = time.perf_counter() - t0
    print(f"[transform] {len(q):,} queries in {dt * 1e3:.1f} ms "
          f"({len(q) / dt:,.0f} q/s)")

    out = "/tmp/sns_service_embedding.csv"
    np.savetxt(out, np.column_stack([np.asarray(warm.embedding),
                                     warm.weights]),
               delimiter=",", header="y0,y1,weight", comments="")
    print(f"wrote served embedding -> {out}")


if __name__ == "__main__":
    main()
