"""Quickstart: Sketch-and-Scale on a synthetic clustered point cloud.

    PYTHONPATH=src python examples/quickstart.py [--n 200000] [--tsne]

Runs the paper's full Fig.-1 pipeline on one host: quantize → Count
Sketch → heavy hitters → weighted jittered representatives → UMAP (or
tSNE) → cluster summary.  Prints coverage and HH statistics, and writes
the 2-D embedding to /tmp/sns_embedding.csv.

Kernel tiers: every Pallas call site dispatches through
`repro.kernels.registry`, picking the best tier the current backend
supports (`SnsConfig.kernel_mode="auto"`, overridable per run or via
the `SNS_KERNEL_MODE` env var):

    tier       | what runs                        | where
    -----------+----------------------------------+--------------------
    compiled   | Mosaic/Triton-compiled Pallas    | TPU/GPU only
    interpret  | Python-level Pallas execution    | any backend
    xla        | pure-jnp reference               | any backend

Auto-resolution walks compiled → interpret → xla; the sorted-COO
segment-reduce prefers its XLA cumsum on CPU (nothing beats it there)
while the fused kernel takes over on accelerators.  Force a tier with
e.g. ``SNS_KERNEL_MODE=xla python examples/quickstart.py``.

This is the one-shot front-end.  For data that keeps arriving, the
long-lived service API (`core.service.SnsService`) wraps the same
stages behind `update(chunks)` (incremental ingest), `refresh()`
(warm-start re-embed from the previous coordinates), and
`transform(queries)` (batched out-of-sample placement, no optimizer) —
see examples/sns_service.py.  (examples/serve.py and launch/serve.py
are the LM-stack servers, unrelated to the SnS pipeline.)
"""
import argparse
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import pipeline                               # noqa: E402
from repro.core.tsne import TsneConfig                        # noqa: E402
from repro.core.umap import UmapConfig                        # noqa: E402
from repro.data import gaussian_mixture                       # noqa: E402
from repro.data.synthetic import MixtureSpec                  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--clusters", type=int, default=8)
    ap.add_argument("--tsne", action="store_true")
    ap.add_argument("--embed-backend", default="dense",
                    choices=("dense", "tiled", "pallas", "sparse"),
                    help="tSNE gradient backend; 'sparse' (kNN attraction "
                         "+ FFT grid repulsion) is the 10^5+ reps regime")
    ap.add_argument("--knn-method", default="auto",
                    choices=("auto", "exact", "ann"),
                    help="kNN build for the embed stage: 'ann' is the "
                         "sub-quadratic approximate engine (sketch-native "
                         "bucketing + NN-descent, recall >= 0.9); 'auto' "
                         "switches to it past ~65k representatives")
    ap.add_argument("--top-k", type=int, default=512)
    args = ap.parse_args()

    spec = MixtureSpec(dims=6, n_clusters=args.clusters,
                       cluster_std=0.015, background_frac=0.3)
    pts, labels = gaussian_mixture(args.n, spec, seed=0)
    print(f"[data] {args.n} points, {args.clusters} clusters + 30% "
          f"uniform background, D={spec.dims}")

    cfg = pipeline.SnsConfig(
        bins=16, rows=8, log2_cols=14, top_k=args.top_k,
        embedder="tsne" if args.tsne else "umap", max_replicas=4,
        embed_backend=args.embed_backend,
        embed_knn_method=args.knn_method)
    res = pipeline.run(
        cfg, jnp.asarray(pts),
        tsne_cfg=TsneConfig(n_iter=250),
        umap_cfg=UmapConfig(n_neighbors=10, n_epochs=200))

    live = int(np.asarray(res.hh.mask).sum())
    top = float(np.asarray(res.hh.count)[0])
    print(f"[sketch] {cfg.rows}x{1 << cfg.log2_cols} Count Sketch")
    print(f"[hh] {live} heavy hitters; top cell holds {top:.0f} points; "
          f"coverage of stream = {res.coverage:.1%}")
    print(f"[embed] {res.embedding.shape[0]} representatives -> "
          f"{res.embedding.shape[1]}-D via {cfg.embedder}")

    out = np.concatenate([np.asarray(res.embedding),
                          res.rep_weight[:, None]], axis=1)
    np.savetxt("/tmp/sns_embedding.csv", out, delimiter=",",
               header="x,y,weight")
    print("[out] /tmp/sns_embedding.csv")


if __name__ == "__main__":
    main()
