"""End-to-end driver: train a small LM with the full production stack —
checkpointed Trainer, cosine schedule, Count-Sketch gradient compression
(FetchSGD-style, the paper's data structure as a distributed-training
optimization), and the SnS activation monitor.

    PYTHONPATH=src python examples/train_lm.py                # quick (~2 min)
    PYTHONPATH=src python examples/train_lm.py --steps 300 --d-model 512 \
        --layers 12     # ~100M-class run (CPU: slow but it is the real loop)
    PYTHONPATH=src python examples/train_lm.py --sketch-grads  # compressed
"""
import argparse
import sys

sys.path.insert(0, "src")

import dataclasses                                             # noqa: E402
import jax                                                     # noqa: E402
import numpy as np                                             # noqa: E402

from repro.data import zipf_token_stream                       # noqa: E402
from repro.models.config import ModelConfig                    # noqa: E402
from repro.optim import (SketchCompressConfig,                 # noqa: E402
                         sketch_compress_init, compress_and_reduce)
from repro.train.steps import TrainStepConfig                  # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig         # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--sketch-grads", action="store_true",
                    help="Count-Sketch gradient compression demo")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = ModelConfig(
        arch_id="example-lm", family="dense",
        num_layers=args.layers, d_model=args.d_model,
        num_heads=max(args.d_model // 32, 2), num_kv_heads=2,
        d_ff=args.d_model * 3, vocab_size=args.vocab, head_dim=32)
    n_params = cfg.param_count()
    print(f"[model] {n_params / 1e6:.1f}M params, {args.layers}L "
          f"d{args.d_model}")

    tcfg = TrainStepConfig(peak_lr=args.lr, warmup_steps=10,
                           total_steps=args.steps, q_chunk=64)
    rc = TrainerConfig(total_steps=args.steps, ckpt_every=20,
                       ckpt_dir=args.ckpt_dir, log_every=10,
                       monitor_activations=True)

    def batch_fn(step):
        return zipf_token_stream(jax.random.key(step), args.batch,
                                 args.seq, args.vocab)

    if args.sketch_grads:
        print("[optim] Count-Sketch compressed gradients "
              "(sketch all-reduced instead of the dense gradient)")
        _demo_sketch_grads(cfg, tcfg, args, batch_fn)
        return

    tr = Trainer(cfg, tcfg, rc, batch_fn)
    if tr.start_step:
        print(f"[resume] from checkpoint step {tr.start_step}")
    out = tr.run()
    first = out["metrics"][0]["loss"] if out["metrics"] else float("nan")
    last = out["metrics"][-1]["loss"] if out["metrics"] else float("nan")
    print(f"[train] steps={out['final_step']} wall={out['wall_s']:.1f}s "
          f"loss {first:.3f} -> {last:.3f}")
    rep = out.get("activation_report", {})
    print(f"[sns-monitor] representation-space HHs={rep.get('hh_count')} "
          f"top1_frac={rep.get('hh_top1_frac', 0):.3f} "
          f"tokens_seen={rep.get('tokens_seen')}")


def _demo_sketch_grads(cfg, tcfg, args, batch_fn):
    """Manual loop: grads -> sketch -> (psum in multi-host) -> top-k apply."""
    from repro.models import model as model_mod
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    params = model_mod.init_params(jax.random.key(0), cfg)
    ccfg = SketchCompressConfig(rows=8, log2_cols=16, top_k=50_000)
    cstate = sketch_compress_init(params, ccfg)
    ocfg = AdamWConfig(lr=args.lr)
    ostate = adamw_init(params)
    n = cfg.param_count()
    wire_dense = 2 * n
    wire_sketch = 4 * ccfg.rows * (1 << ccfg.log2_cols)
    print(f"[wire] dense grad all-reduce: {wire_dense / 2**20:.1f} MiB/step"
          f"  sketch: {wire_sketch / 2**20:.1f} MiB/step "
          f"({wire_dense / wire_sketch:.0f}x less)")

    @jax.jit
    def grad_fn(p, batch):
        def loss(p):
            return model_mod.forward_train(cfg, p, batch, q_chunk=64)
        return jax.value_and_grad(loss, has_aux=True)(p)

    for step in range(args.steps):
        batch = batch_fn(step)
        (loss, _), grads = grad_fn(params, batch)
        upd, cstate, density = compress_and_reduce(grads, cstate, ccfg)
        params, ostate, _ = adamw_update(upd, ostate, params, ocfg)
        if step % 10 == 0:
            print(f"  step {step:4d} loss {float(loss):.3f} "
                  f"density {float(density):.4f}")


if __name__ == "__main__":
    main()
