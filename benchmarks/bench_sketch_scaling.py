"""Paper Fig. 6: sketch build time vs stream size — asymptotically linear.

The paper streams up to 10⁹ points through a 10×20,000 sketch on a V100
and reports linear scaling.  We sweep the stream length over two orders
of magnitude on CPU and fit the log-log slope: linear scaling ⇒ slope ≈ 1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_fn
from repro.core import sketch


def run() -> str:
    csv = Csv(["n_points", "seconds", "points_per_sec"])
    sk0 = sketch.init(jax.random.key(0), rows=10, log2_cols=15)
    sizes = [1 << 14, 1 << 16, 1 << 18, 1 << 20]
    secs = []
    upd = jax.jit(sketch.update_sorted)
    for n in sizes:
        keys = jax.random.bits(jax.random.key(n), (2, n), dtype=jnp.uint32)
        t = time_fn(upd, sk0, keys[0], keys[1])
        secs.append(t)
        csv.add(n, f"{t:.5f}", f"{n / t:.3e}")
    slope = np.polyfit(np.log(sizes), np.log(secs), 1)[0]
    csv.add("loglog_slope", f"{slope:.3f}", "target~1.0(linear)")
    return csv.dump("sketch_scaling (paper Fig 6: linear in stream size)")
