"""Paper §IV-1 contingency table analog: end-to-end SnS clustering quality.

The paper labels pixels Tumor/Other via the HH clusters and reports false
positive rates 3.7% / 5.9% against the pathologist segmentation.  Our
synthetic mixture has exact ground truth: we run the full pipeline
(sketch → HH → replicas → UMAP → k-means on the embedding), project the
HH cluster labels back to the raw points, and report the contingency
table between true mixture components and predicted groups.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.core import pipeline
from repro.core.umap import UmapConfig
from repro.data import gaussian_mixture
from repro.data.synthetic import MixtureSpec


def _kmeans(x: np.ndarray, k: int, iters: int = 50, seed: int = 0,
            restarts: int = 8) -> np.ndarray:
    """k-means with restarts (best inertia wins) — a single seed can merge
    adjacent embedding clusters."""
    best, best_inertia = None, np.inf
    for r in range(restarts):
        rng = np.random.default_rng(seed + r)
        centers = x[rng.choice(len(x), k, replace=False)]
        for _ in range(iters):
            d = ((x[:, None, :] - centers[None]) ** 2).sum(-1)
            assign = d.argmin(1)
            for j in range(k):
                sel = x[assign == j]
                if len(sel):
                    centers[j] = sel.mean(0)
        inertia = float(((x - centers[assign]) ** 2).sum())
        if inertia < best_inertia:
            best, best_inertia = assign, inertia
    return best


def run(n_points: int = 300_000) -> str:
    csv = Csv(["metric", "value", "paper_analog"])
    spec = MixtureSpec(dims=6, n_clusters=5, cluster_std=0.015,
                       background_frac=0.3)
    pts, labels = gaussian_mixture(n_points, spec, seed=11)
    cfg = pipeline.SnsConfig(bins=16, rows=8, log2_cols=14, top_k=512,
                             max_replicas=4, embedder="umap")
    res = pipeline.run(cfg, jnp.asarray(pts),
                       umap_cfg=UmapConfig(n_neighbors=10, n_epochs=150))

    # cluster the embedding into n_clusters groups; map HH -> group
    emb = np.asarray(res.embedding)
    groups = _kmeans(emb, spec.n_clusters, seed=1)
    hh_group = np.full(cfg.top_k, -1)
    for rep_idx, hh_idx in enumerate(res.rep_hh_id):
        hh_group[hh_idx] = groups[rep_idx]

    # project back to raw points
    assign = pipeline.assign_points_to_hh(res.grid, res.hh, pts)
    in_hh = assign >= 0
    pred = np.where(in_hh, hh_group[np.clip(assign, 0, None)], -1)

    # purity among cluster points captured by HH cells
    mask = (labels >= 0) & in_hh
    purity = 0.0
    if mask.sum():
        for g in range(spec.n_clusters):
            sel = labels[mask & (pred == g)]
            if len(sel):
                purity += np.bincount(sel).max()
        purity /= mask.sum()
    # false-positive analog: background points landing in HH cells
    bg_fp = float(in_hh[labels < 0].mean())
    cl_capture = float(in_hh[labels >= 0].mean())
    csv.add("cluster_point_capture", f"{cl_capture:.3f}", "HH coverage 84-99%")
    csv.add("cluster_purity_in_hh", f"{purity:.3f}",
            "paper FP 3.7%/5.9% => purity ~0.95")
    csv.add("background_in_hh", f"{bg_fp:.3f}", "low")
    csv.add("sns_coverage", f"{res.coverage:.3f}", "84.11% (cancer)")
    return csv.dump("pipeline_quality (paper §IV-1 contingency analog)")
