"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Emits CSV blocks (name, value, paper reference) for:
  * sketch_scaling       — paper Fig. 6 (linear time in stream size)
  * error_vs_rank        — paper §III-2 (CS estimate error by HH rank)
  * hh_vs_sampling       — paper §II-2 (HH beats random subsampling)
  * hh_coverage          — paper §IV (cumulative HH mass)
  * collision_model      — paper §III-2 (grid-resolution guidance)
  * pipeline_quality     — paper §IV-1 (contingency-table analog)
  * kernel_paths         — update/estimate implementation comparison
  * embed_scaling        — dense vs tiled vs sparse embedding memory/time vs N
  * embed_throughput     — tSNE gradient iters/sec (dense/tiled/sparse) +
                           UMAP epochs/sec (scatter baseline vs scatter-free)
  * ingest_scaling       — streaming vs one-shot sketch-stage memory vs N
  * ingest_throughput    — points/sec: two-sort vs fused vs fused+superbatch
  * embed_mesh           — sharded embed stage iters/sec vs device count
                           (one subprocess per D, virtual CPU devices)
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_sketch_scaling, bench_error_vs_rank,
                            bench_hh_vs_sampling, bench_coverage,
                            bench_collision_model, bench_pipeline_quality,
                            bench_kernels, bench_embed_scaling,
                            bench_embed_throughput, bench_embed_mesh,
                            bench_ingest_scaling, bench_ingest_throughput)
    n_scale = 200_000 if args.fast else 2_000_000
    n_mid = 100_000 if args.fast else 1_000_000
    n_small = 60_000 if args.fast else 300_000
    jobs = [
        ("sketch_scaling", lambda: bench_sketch_scaling.run()),
        ("error_vs_rank", lambda: bench_error_vs_rank.run(n_scale)),
        ("hh_vs_sampling", lambda: bench_hh_vs_sampling.run(n_mid)),
        ("hh_coverage", lambda: bench_coverage.run(n_scale)),
        ("collision_model", lambda: bench_collision_model.run()),
        ("pipeline_quality", lambda: bench_pipeline_quality.run(n_small)),
        ("kernel_paths", lambda: bench_kernels.run()),
        ("embed_scaling", lambda: bench_embed_scaling.run(
            sizes=(4096, 8192) if args.fast
            else (8192, 16384, 32768, 65536),
            dense_max=8192 if args.fast else 16384,
            iters=1 if args.fast else 2,
            # fast mode must not clobber the tracked full-size baseline
            json_out=None if args.fast else bench_embed_scaling.DEFAULT_JSON)),
        ("embed_throughput", lambda: bench_embed_throughput.run(
            sizes=(4096, 8192) if args.fast
            else (16384, 65536, 262144),
            knn=16 if args.fast else 90,
            grid=64 if args.fast else 128,
            dense_max=4096 if args.fast else 16384,
            tiled_max=8192 if args.fast else 65536,
            iters=2 if args.fast else 3,
            # k=15 is the UMAP acceptance geometry (paper n_neighbors)
            umap_knn=15, neg_rate=5,
            json_out=None if args.fast
            else bench_embed_throughput.DEFAULT_JSON)),
        ("ingest_scaling", lambda: bench_ingest_scaling.run(
            sizes=(8192, 32768) if args.fast
            else (8192, 65536, 262144, 1048576),
            chunk=4096 if args.fast else 8192,
            oneshot_time_max=32768 if args.fast else 262144)),
        ("embed_mesh", lambda: bench_embed_mesh.run(
            devices=(1, 2) if args.fast else (1, 2, 4, 8),
            n=4096 if args.fast else 20_000,
            knn=16 if args.fast else 32,
            grid=64 if args.fast else 128,
            tsne_iters=5 if args.fast else 20,
            umap_epochs=5 if args.fast else 20,
            # fast mode must not clobber the tracked full-size baseline
            json_out=None if args.fast else "__default__")),
        ("ingest_throughput", lambda: bench_ingest_throughput.run(
            sizes=(16384, 65536) if args.fast
            else (65536, 262144, 1048576),
            chunk=2048 if args.fast else 4096,
            top_k=2048 if args.fast else 20480,
            # fast mode must not clobber the tracked full-size baseline
            json_out=None if args.fast
            else bench_ingest_throughput.DEFAULT_JSON)),
    ]
    for name, fn in jobs:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            print(fn())
            print(f"# [{name} done in {time.time() - t0:.1f}s]\n",
                  flush=True)
        except Exception as e:                               # noqa: BLE001
            print(f"# [{name} FAILED: {type(e).__name__}: {e}]\n",
                  file=sys.stderr, flush=True)
            raise


if __name__ == "__main__":
    main()
