"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Emits CSV blocks (name, value, paper reference) for:
  * sketch_scaling       — paper Fig. 6 (linear time in stream size)
  * error_vs_rank        — paper §III-2 (CS estimate error by HH rank)
  * hh_vs_sampling       — paper §II-2 (HH beats random subsampling)
  * hh_coverage          — paper §IV (cumulative HH mass)
  * collision_model      — paper §III-2 (grid-resolution guidance)
  * pipeline_quality     — paper §IV-1 (contingency-table analog)
  * kernel_paths         — per-op kernel-tier microbench: every registry
                           op timed compiled vs interpret vs XLA ref
                           (--fast runs the numeric smoke gate)
  * embed_scaling        — dense vs tiled vs sparse embedding memory/time vs N
  * embed_throughput     — tSNE gradient iters/sec (dense/tiled/sparse) +
                           UMAP epochs/sec (scatter baseline vs scatter-free)
  * ingest_scaling       — streaming vs one-shot sketch-stage memory vs N
  * ingest_throughput    — points/sec: two-sort vs fused vs fused+superbatch
  * embed_mesh           — sharded embed stage iters/sec vs device count
                           (one subprocess per D, virtual CPU devices)
  * knn_recall           — approximate (sketch bucketing + NN-descent) vs
                           exact kNN build: recall + wall-clock
  * service              — online service: ingest absorption points/sec,
                           warm vs cold refresh iterations-to-target,
                           out-of-sample transform queries/sec
  * resilience           — quality under shard loss (coverage, widened
                           error bound, HH recall, KL vs no-loss), retry
                           rescue of transient faults, straggler cutoff

Every bench is registered by module name and imported via importlib at
dispatch time — a registered module that fails to import aborts the run
with the import error (no silent skips), and an unknown ``--only`` name
is an error listing the registry.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time


def _load(module: str):
    """Import a registered bench module, failing LOUDLY if it is absent
    or broken — a bench silently dropping out of the suite is how
    regressions hide."""
    try:
        return importlib.import_module(f"benchmarks.{module}")
    except ImportError as e:
        raise RuntimeError(
            f"registered bench module benchmarks.{module} failed to "
            f"import: {e}") from e


def build_jobs(fast: bool):
    """The registry: (name, module, runner(mod)) per bench."""
    n_scale = 200_000 if fast else 2_000_000
    n_mid = 100_000 if fast else 1_000_000
    n_small = 60_000 if fast else 300_000
    return [
        ("sketch_scaling", "bench_sketch_scaling", lambda m: m.run()),
        ("error_vs_rank", "bench_error_vs_rank", lambda m: m.run(n_scale)),
        ("hh_vs_sampling", "bench_hh_vs_sampling", lambda m: m.run(n_mid)),
        ("hh_coverage", "bench_coverage", lambda m: m.run(n_scale)),
        ("collision_model", "bench_collision_model", lambda m: m.run()),
        ("pipeline_quality", "bench_pipeline_quality",
         lambda m: m.run(n_small)),
        ("kernel_paths", "bench_kernels", lambda m: (
            m.run(smoke=True, json_out="BENCH_kernels_ci.json") if fast
            else m.run(json_out=m.DEFAULT_JSON))),
        ("embed_scaling", "bench_embed_scaling", lambda m: m.run(
            sizes=(4096, 8192) if fast else (8192, 16384, 32768, 65536),
            dense_max=8192 if fast else 16384,
            iters=1 if fast else 2,
            # fast mode must not clobber the tracked full-size baseline
            json_out=None if fast else m.DEFAULT_JSON)),
        ("embed_throughput", "bench_embed_throughput", lambda m: m.run(
            sizes=(4096, 8192) if fast else (16384, 65536, 262144),
            knn=16 if fast else 90,
            grid=64 if fast else 128,
            dense_max=4096 if fast else 16384,
            tiled_max=8192 if fast else 65536,
            iters=2 if fast else 3,
            # k=15 is the UMAP acceptance geometry (paper n_neighbors)
            umap_knn=15, neg_rate=5,
            json_out=None if fast else m.DEFAULT_JSON)),
        ("ingest_scaling", "bench_ingest_scaling", lambda m: m.run(
            sizes=(8192, 32768) if fast
            else (8192, 65536, 262144, 1048576),
            chunk=4096 if fast else 8192,
            oneshot_time_max=32768 if fast else 262144)),
        ("embed_mesh", "bench_embed_mesh", lambda m: m.run(
            devices=(1, 2) if fast else (1, 2, 4, 8),
            n=4096 if fast else 20_000,
            knn=16 if fast else 32,
            grid=64 if fast else 128,
            tsne_iters=5 if fast else 20,
            umap_epochs=5 if fast else 20,
            # fast mode must not clobber the tracked full-size baseline
            json_out=None if fast else "__default__")),
        ("ingest_throughput", "bench_ingest_throughput", lambda m: m.run(
            sizes=(16384, 65536) if fast else (65536, 262144, 1048576),
            chunk=2048 if fast else 4096,
            top_k=2048 if fast else 20480,
            # fast mode must not clobber the tracked full-size baseline
            json_out=None if fast else m.DEFAULT_JSON)),
        ("knn_recall", "bench_knn_recall", lambda m: (
            m.run_smoke(json_out="BENCH_knn_recall_ci.json") if fast
            else m.run(json_out=m.DEFAULT_JSON))),
        ("service", "bench_service", lambda m: (
            m.run_smoke(json_out="BENCH_service_ci.json") if fast
            else m.run(json_out=m.DEFAULT_JSON))),
        ("resilience", "bench_resilience", lambda m: (
            m.run_smoke(json_out="BENCH_resilience_ci.json") if fast
            else m.run(json_out=m.DEFAULT_JSON))),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of each bench's "
                         "timed region into DIR/<bench> (opt-in; "
                         "profiling overhead perturbs timings, so never "
                         "set this for baseline runs)")
    args = ap.parse_args()

    from benchmarks.common import maybe_trace

    jobs = build_jobs(args.fast)
    names = [name for name, _, _ in jobs]
    if args.only is not None and args.only not in names:
        raise SystemExit(
            f"--only {args.only!r} matches no registered bench; "
            f"choose from: {', '.join(names)}")
    for name, module, runner in jobs:
        if args.only and args.only != name:
            continue
        mod = _load(module)
        t0 = time.time()
        try:
            with maybe_trace(args.trace, name):
                print(runner(mod))
            print(f"# [{name} done in {time.time() - t0:.1f}s]\n",
                  flush=True)
        except Exception as e:                               # noqa: BLE001
            print(f"# [{name} FAILED: {type(e).__name__}: {e}]\n",
                  file=sys.stderr, flush=True)
            raise


if __name__ == "__main__":
    main()
