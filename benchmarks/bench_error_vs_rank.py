"""Paper §III-2: CS frequency-estimate relative error vs heavy-hitter rank.

Paper setup: cancer sample, 22 bins/axis, 16×200k sketch, top-20k HHs.
Reported rms relative errors: ~0.001 (r<3k), ~0.003 (3k<r<10k),
~0.01 (10k<r<20k).  We reproduce on the matched-statistics synthetic
mixture at reduced-but-faithful scale (16×2¹⁸ sketch, top-20k query).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.core import quantize, sketch
from repro.data import gaussian_mixture
from repro.data.synthetic import MixtureSpec


def _core_halo_mixture(n: int, n_clusters: int = 30, dims: int = 8,
                       seed: int = 3) -> np.ndarray:
    """Clusters with dense cores + extended halos — the fat-tailed cell
    count profile of the paper's cancer data (top cell 204,901 pts,
    rank-20k cell 180 pts).  A single-scale Gaussian in 8-D dilutes its
    mass exponentially across cells and has no fat tail."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.15, 0.85, size=(n_clusters, dims))
    n_bg = int(n * 0.15)
    per = (n - n_bg) // n_clusters
    pts = [rng.uniform(0, 1, size=(n_bg, dims))]
    for c in centers:
        ns = [int(per * 0.5), int(per * 0.35),
              per - int(per * 0.5) - int(per * 0.35)]
        for m, s in zip(ns, (0.008, 0.025, 0.07)):
            pts.append(c + s * rng.normal(size=(m, dims)))
    return np.clip(np.concatenate(pts), 0, 1).astype(np.float32)


def run(n_points: int = 2_000_000) -> str:
    csv = Csv(["rank_band", "rms_rel_error", "abs_err_counts",
               "paper_rel (26M pts)"])
    pts = _core_halo_mixture(n_points)
    grid = quantize.fit_grid(jnp.asarray(pts), bins=22)
    khi, klo = quantize.points_to_keys(grid, jnp.asarray(pts))

    # exact counts of every distinct cell (host side)
    keys = (np.asarray(khi, np.uint64) << np.uint64(32)) | \
        np.asarray(klo, np.uint64)
    uniq, counts = np.unique(keys, return_counts=True)
    order = np.argsort(counts)[::-1][:20_000]
    top_keys, top_counts = uniq[order], counts[order]

    sk = sketch.init(jax.random.key(0), rows=16, log2_cols=18)
    sk = sketch.update_sorted(sk, khi, klo)
    qhi = jnp.asarray((top_keys >> np.uint64(32)).astype(np.uint32))
    qlo = jnp.asarray((top_keys & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    est = np.asarray(sketch.estimate(sk, qhi, qlo))
    rel = np.abs(est - top_counts) / np.maximum(top_counts, 1)

    abse = np.abs(est - top_counts)
    bands = [("r<3000", slice(0, 3000), 0.001),
             ("3000<r<10000", slice(3000, 10_000), 0.003),
             ("10000<r<20000", slice(10_000, 20_000), 0.01)]
    for name, sl, paper in bands:
        seg = rel[sl]
        if seg.size:
            rms = float(np.sqrt(np.mean(seg ** 2)))
            rms_abs = float(np.sqrt(np.mean(abse[sl] ** 2)))
            csv.add(name, f"{rms:.5f}", f"{rms_abs:.2f}", paper)
    # the CS noise floor is ADDITIVE (~eps*||f||_2): relative bands depend
    # on the count scale; the paper's abs floor is ~2 counts at 26M pts.
    return csv.dump("error_vs_rank (paper §III-2; additive noise floor — "
                    "compare abs_err_counts across scales)")
