"""Paper §III-2: grid-resolution collision model vs Monte-Carlo.

The paper's guidance for choosing M: with K HHs on an M^D grid, the
expected number of HHs with another HH in their 3^D contact
neighbourhood is C = K·P(N≥2).  Paper values: K=10⁴, D=10: M=8 → 1057,
M=16 → 0.00144.  We reproduce the closed form AND validate it with a
Monte-Carlo placement at feasible (D, M).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv
from repro.core.quantize import collision_rate


def _monte_carlo(volume_side: int, dims: int, k: int, trials: int = 30
                 ) -> float:
    rng = np.random.default_rng(0)
    total = 0
    for _ in range(trials):
        cells = rng.integers(0, volume_side, size=(k, dims))
        # count HHs with a neighbour within chebyshev distance 1
        from scipy.spatial import cKDTree
        tree = cKDTree(cells)
        pairs = tree.query_pairs(r=1.0, p=np.inf)
        collided = set()
        for a, b in pairs:
            collided.add(a)
            collided.add(b)
        total += len(collided)
    return total / trials


def run() -> str:
    csv = Csv(["K", "D", "M", "C_paper_numbers", "C_paper_text",
               "reference"])
    from repro.core.quantize import collision_rate_text
    # the paper's own numbers (closed form): match P(N>=2), NOT the text
    for m, paper in ((8, 1057.0), (16, 0.00144)):
        _, c = collision_rate(float(m) ** 10, 10_000, 10)
        _, ct = collision_rate_text(float(m) ** 10, 10_000, 10)
        csv.add(10_000, 10, m, f"{c:.5g}", f"{ct:.5g}", f"paper={paper}")
    # Monte-Carlo validation at tractable scale: supports the TEXT formula
    # (per-HH collision = >=1 other in the contact neighbourhood)
    for d, m, k in ((4, 16, 200), (5, 12, 300)):
        _, c_model = collision_rate(float(m) ** d, k, d)
        _, c_text = collision_rate_text(float(m) ** d, k, d)
        c_mc = _monte_carlo(m, d, k)
        csv.add(k, d, m, f"{c_model:.2f}", f"{c_text:.2f}",
                f"monte_carlo={c_mc:.2f}")
    return csv.dump("collision_model (paper §III-2; text vs numbers "
                    "discrepancy documented in EXPERIMENTS.md)")
