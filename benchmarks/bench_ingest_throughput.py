"""Ingest throughput: points/sec across the three chunk-fold generations.

The PR-3 tentpole claim, measured end to end on a synthetic clustered
stream (same generator as bench_ingest_scaling):

* ``twosort``  — the PR-2 per-chunk fold, reconstructed: ``update_sorted``
  sorts the chunk for the sketch, then ``merge_topk`` re-sorts pool ∪
  raw-chunk for the reservoir — every chunk pays two lexsorts over
  overlapping key material, one of them over the whole L-entry pool.
* ``fused``    — one ``sorted_runs`` per chunk feeds both the sketch
  scatter and the sort-free ``merge_runs`` (binary-search sorted merge
  against the key-sorted reservoir); still one dispatch per chunk.
* ``fused_superbatch`` — the fused fold inside ``ingest_superbatch``'s
  donated ``lax.scan`` (B chunks per dispatch) driven by the
  double-buffered ``ingest_all`` (device_put of batch b+1 overlaps the
  compute of batch b).

All three produce bit-identical heavy hitters (tests/test_fused_ingest.py);
only the points/sec differ.  Default geometry is the paper-scale heavy-
hitter extraction (top_k 20480) with the deep churn-regime reservoir
(pool = 4·top_k, the setting examples/streaming_ingest.py recommends when
the distinct-key universe exceeds the pool) and a small low-latency chunk
— the regime where the legacy path's per-chunk pool re-sort dominates and
the fused merge pays off hardest.  The three variants are timed in
interleaved rounds (median per variant) so machine drift cannot bias the
ratios.

    PYTHONPATH=src python -m benchmarks.bench_ingest_throughput \
        --sizes 65536,262144,1048576 --json-out BENCH_ingest_throughput.json

Emits a JSON trajectory (default path: BENCH_ingest_throughput.json at the
repo root — the repo's tracked points/sec baseline); ``run()`` returns it
as a string for benchmarks/run.py.
"""
from __future__ import annotations

import argparse
import functools
import json
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (emit_json, interleaved_medians,
                               repo_root_json)
from repro.core import quantize, sketch as sketch_mod, stream
from repro.core.candidates import Candidates
from repro.data.synthetic import MixtureSpec, gaussian_mixture

DIMS = 6
SPEC = MixtureSpec(dims=DIMS, n_clusters=8, cluster_std=0.02,
                   background_frac=0.3)
DEFAULT_JSON = repo_root_json("BENCH_ingest_throughput.json")


def _grid(bins: int) -> quantize.GridSpec:
    return quantize.GridSpec(dims=DIMS, bins=bins,
                             lo=tuple([0.0] * DIMS), hi=tuple([1.0] * DIMS))


# --------------------------------------------------------------------------
# The PR-2 two-sort chunk fold, frozen VERBATIM (modulo imports) so the
# baseline stays what it actually was: `update_sorted` re-sorting the chunk
# (lexsort + nonzero-RLE + deduped scatter) and `merge_topk` re-sorting
# pool ∪ raw-chunk (concat + lexsort + nonzero-RLE + top_k).  The live
# library versions of these helpers have since been rebuilt on the fused
# runs machinery, so reconstructing the old fold from them would silently
# flatter the baseline.
# --------------------------------------------------------------------------

def _pr2_update_sorted(sk, key_hi, key_lo, mask=None):
    items = key_hi.shape[0]
    v = jnp.ones((items,), sk.table.dtype)
    if mask is not None:
        v = v * mask.astype(sk.table.dtype)
    order = jnp.lexsort((key_lo, key_hi))
    shi, slo, sv = key_hi[order], key_lo[order], v[order]
    new_run = jnp.concatenate([
        jnp.ones((1,), bool),
        (shi[1:] != shi[:-1]) | (slo[1:] != slo[:-1])])
    run_id = jnp.cumsum(new_run) - 1
    run_sum = jax.ops.segment_sum(sv, run_id, num_segments=items)
    first_idx = jnp.where(new_run, size=items, fill_value=items - 1)[0]
    rhi, rlo = shi[first_idx], slo[first_idx]
    live = jnp.arange(items) < (run_id[-1] + 1)
    return sketch_mod.update(sk, rhi, rlo, values=run_sum, mask=live)


def _pr2_local_topk(key_hi, key_lo, k, values=None, mask=None):
    from repro.core.candidates import INVALID_KEY, concat, empty
    n = key_hi.shape[0]
    v = jnp.ones((n,), jnp.float32) if values is None \
        else values.astype(jnp.float32)
    if mask is not None:
        v = v * mask.astype(jnp.float32)
    order = jnp.lexsort((key_lo, key_hi))
    shi, slo, sv = key_hi[order], key_lo[order], v[order]
    new_run = jnp.concatenate([
        jnp.ones((1,), bool),
        (shi[1:] != shi[:-1]) | (slo[1:] != slo[:-1])])
    run_id = jnp.cumsum(new_run) - 1
    run_sum = jax.ops.segment_sum(sv, run_id, num_segments=n)
    first_idx = jnp.where(new_run, size=n, fill_value=n - 1)[0]
    rhi, rlo = shi[first_idx], slo[first_idx]
    num_runs = run_id[-1] + 1
    live = jnp.arange(n) < num_runs
    live &= run_sum > 0
    score = jnp.where(live, run_sum, -jnp.inf)
    kk = min(k, n)
    top_score, top_idx = jax.lax.top_k(score, kk)
    cmask = jnp.isfinite(top_score)
    out = Candidates(
        key_hi=jnp.where(cmask, rhi[top_idx], jnp.uint32(INVALID_KEY)),
        key_lo=jnp.where(cmask, rlo[top_idx], jnp.uint32(INVALID_KEY)),
        count=jnp.where(cmask, top_score, 0.0),
        mask=cmask)
    if kk < k:
        out = concat(out, empty(k - kk))
    return out


def _legacy_step(state: stream.IngestState, points, mask, *, grid):
    """The PR-2 two-sort chunk fold (what stream.ingest_step used to be)."""
    from repro.core.candidates import concat
    pool = state.cands.capacity
    n = points.shape[0]
    key_hi, key_lo = quantize.points_to_keys(grid, points)
    sk = _pr2_update_sorted(state.sketch, key_hi, key_lo, mask=mask)
    chunk_cands = Candidates(
        key_hi=key_hi, key_lo=key_lo,
        count=jnp.ones((n,), jnp.float32), mask=mask)
    both = concat(state.cands, chunk_cands)
    cands = _pr2_local_topk(both.key_hi, both.key_lo, pool,
                            values=both.count, mask=both.mask)
    inc = jnp.sum(mask.astype(jnp.float32))
    return stream.IngestState(sketch=sk, cands=cands,
                              count=state.count + inc,
                              evict_max=state.evict_max)


def _chunk_driver(step_fn, init_fn, pts, chunk: int):
    """A zero-arg callable folding the whole array chunk by chunk (a
    ragged tail is zero-padded and masked, like stream.rechunk)."""
    n, d = pts.shape

    def once():
        st = init_fn()
        for s in range(0, n, chunk):
            blk = pts[s:s + chunk]
            take = blk.shape[0]
            if take < chunk:
                blk = np.concatenate(
                    [blk, np.zeros((chunk - take, d), np.float32)])
            st = step_fn(st, jnp.asarray(blk),
                         jnp.asarray(np.arange(chunk) < take))
        jax.block_until_ready(st.sketch.table)

    return once


def run(sizes: Sequence[int] = (65536, 262144, 1048576),
        chunk: int = 4096, superbatch: int = 16, bins: int = 16,
        rows: int = 8, log2_cols: int = 16, top_k: int = 20480,
        pool: int = 0, json_out: Optional[str] = DEFAULT_JSON) -> str:
    pool = pool or 4 * top_k
    grid = _grid(bins)
    legacy_jit = jax.jit(functools.partial(_legacy_step, grid=grid),
                         donate_argnums=(0,))
    records = []
    for n in sizes:
        c = min(chunk, n)
        pts, _ = gaussian_mixture(n, SPEC, seed=0)

        def fresh():
            return stream.init(jax.random.key(0), rows, log2_cols, pool)

        def super_once():
            st = stream.ingest_all(fresh(), grid, [pts], c,
                                   superbatch=superbatch)
            jax.block_until_ready(st.sketch.table)

        times = interleaved_medians({
            "twosort": _chunk_driver(legacy_jit, fresh, pts, c),
            "fused": _chunk_driver(
                functools.partial(stream.ingest_chunk, grid=grid),
                fresh, pts, c),
            "super": super_once})
        t_two, t_fused, t_super = (times["twosort"], times["fused"],
                                   times["super"])

        rec = {"bench": "ingest_throughput", "n": n, "chunk": c,
               "superbatch": superbatch, "pool": pool, "rows": rows,
               "log2_cols": log2_cols,
               "twosort_pps": n / t_two,
               "fused_pps": n / t_fused,
               "fused_superbatch_pps": n / t_super,
               "speedup_fused": t_two / t_fused,
               "speedup_fused_superbatch": t_two / t_super}
        records.append(rec)
        print(f"# ingest_throughput N={n:8d} chunk={c:5d} "
              f"twosort={rec['twosort_pps'] / 1e6:6.3f} "
              f"fused={rec['fused_pps'] / 1e6:6.3f} "
              f"fused+superbatch={rec['fused_superbatch_pps'] / 1e6:6.3f} "
              f"Mpts/s  speedup={rec['speedup_fused_superbatch']:.2f}x",
              flush=True)

    return emit_json({"bench": "ingest_throughput",
                      "speedup_at_max_n":
                          records[-1]["speedup_fused_superbatch"],
                      "records": records}, json_out)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="65536,262144,1048576")
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--superbatch", type=int, default=16)
    ap.add_argument("--bins", type=int, default=16)
    ap.add_argument("--rows", type=int, default=8)
    ap.add_argument("--log2-cols", type=int, default=16)
    ap.add_argument("--top-k", type=int, default=20480)
    ap.add_argument("--pool", type=int, default=0,
                    help="candidate reservoir size L (0 -> 4*top_k, the "
                         "deep churn-regime setting)")
    ap.add_argument("--json-out", default=DEFAULT_JSON)
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    print(run(sizes=sizes, chunk=args.chunk, superbatch=args.superbatch,
              bins=args.bins, rows=args.rows, log2_cols=args.log2_cols,
              top_k=args.top_k, pool=args.pool, json_out=args.json_out))


if __name__ == "__main__":
    main()
