"""Online SnS service: freshness, warm-vs-cold refresh, transform qps.

    PYTHONPATH=src python -m benchmarks.bench_service \
        --json-out BENCH_service.json

Three serving levers, one scenario (gaussian-mixture stream + a
same-distribution drift batch):

  * freshness  — points/sec ``service.update()`` absorbs into the live
    ingest fold (steady-state, compile excluded), i.e. how fast the
    service tracks a moving stream;
  * warm vs cold — iterations-to-target: run the post-drift re-embed
    once cold and once warm-started from the cached embedding (both with
    the FULL iteration budget), find the first iteration whose KL enters
    the quality band (within ``slack`` = 5% of the cold run's final KL —
    gradient-descent tSNE keeps shaving the fourth decimal for hundreds
    of tail iterations, so a tighter band measures tail-chasing, not
    embedding quality), and report the ratio — the acceptance bar is
    warm ≤ 1/5 of cold;
  * transform  — out-of-sample queries/sec through the batched
    barycentric path at several batch sizes.

``--smoke`` runs a reduced config and **asserts** warm beats cold (the
CI gate; writes BENCH_service_ci.json so the tracked full-size baseline
is never clobbered by a CI box).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence

import numpy as np

from benchmarks.common import Csv, emit_json, repo_root_json
from repro.core import pipeline, quantize
from repro.core.service import ServiceConfig, SnsService
from repro.core.tsne import TsneConfig
from repro.data.synthetic import MixtureSpec, gaussian_mixture

DEFAULT_JSON = repo_root_json("BENCH_service.json")
WARM_RATIO_CEIL = 0.2          # acceptance: warm ≤ 1/5 of cold iterations


def _blobs(n: int, dims: int, seed: int):
    spec = MixtureSpec(dims=dims, n_clusters=8, cluster_std=0.05,
                       background_frac=0.1)
    pts, _ = gaussian_mixture(n, spec, seed=seed)
    return np.asarray(pts, np.float32)


def _iters_to_target(trace: np.ndarray, target: float) -> int:
    """First iteration whose KL is ≤ target (1-based count of optimizer
    steps spent).  The trace must reach it — callers derive the target
    from a run that did."""
    hit = np.flatnonzero(trace <= target)
    assert hit.size, f"trace never reached target {target}"
    return int(hit[0]) + 1


def run(n: int = 400_000, drift_frac: float = 0.05, dims: int = 4,
        top_k: int = 1024, n_iter: int = 500, slack: float = 0.05,
        batch_sizes: Sequence[int] = (1024, 16384, 131072),
        transform_iters: int = 3, seed: int = 0,
        json_out: Optional[str] = DEFAULT_JSON,
        assert_ratio: bool = True) -> str:
    base = _blobs(n, dims, seed)
    drift = _blobs(max(1, int(n * drift_frac)), dims, seed + 1)
    cfg = pipeline.SnsConfig(bins=16, rows=8, log2_cols=14, top_k=top_k,
                             ingest_chunk=65_536, embedder="tsne",
                             embed_backend="dense", max_replicas=4,
                             seed=seed)
    tc = TsneConfig(dims=2, n_iter=n_iter, perplexity=30.0)
    # warm_iters = the FULL budget: the warm run must be measured on the
    # same trace length as cold so iterations-to-target is comparable
    scfg = ServiceConfig(warm_iters=n_iter, transform_chunk=4096,
                         transform_k=8)
    grid = quantize.fit_grid(np.concatenate([base, drift]), cfg.bins)
    svc = SnsService(cfg, grid, tsne_cfg=tc, service_cfg=scfg)

    # ---- freshness: first update compiles, second is steady state
    half = n // 2
    first = svc.update(base[:half])
    steady = svc.update(base[half:])

    # ---- serve once (cold), absorb drift, then re-embed both ways on
    # the SAME post-drift heavy-hitter set (refresh() re-extracts
    # deterministically from the state, which it never mutates)
    svc.refresh(mode="cold")
    svc.update(drift)
    warm = svc.refresh(mode="warm")
    cold = svc.refresh(mode="cold")
    cold_trace = np.asarray(cold.kl_trace)
    warm_trace = np.asarray(warm.kl_trace)
    target = float(cold_trace[-1]) * (1.0 + slack)
    cold_iters = _iters_to_target(cold_trace, target)
    warm_iters = _iters_to_target(warm_trace, target)
    ratio = warm_iters / cold_iters

    # ---- transform throughput vs batch size
    n_reps = int(svc._cache.rep_x.shape[0])
    rng = np.random.default_rng(seed + 2)
    transforms = []
    for q in batch_sizes:
        queries = _blobs(int(q), dims, seed + 3)[rng.permutation(int(q))]
        svc.transform(queries[: min(int(q), 4096)])      # compile
        times = []
        for _ in range(transform_iters):
            t0 = time.perf_counter()
            y = svc.transform(queries)                   # returns synced np
            times.append(time.perf_counter() - t0)
        assert np.isfinite(y).all()
        sec = float(np.median(times))
        transforms.append({"batch": int(q), "seconds": sec,
                           "qps": int(q) / sec})

    csv = Csv(["metric", "value", "note"])
    csv.add("ingest_points_per_sec", f"{steady['points_per_sec']:.0f}",
            "steady-state update() absorption")
    csv.add("cold_iters_to_target", cold_iters,
            f"target KL {target:.4f} (cold final +{slack:.0%})")
    csv.add("warm_iters_to_target", warm_iters,
            f"matched {warm.n_matched} reps, {warm.n_new} new")
    csv.add("warm_over_cold", f"{ratio:.3f}",
            f"acceptance ceiling {WARM_RATIO_CEIL}")
    for t in transforms:
        csv.add(f"transform_qps_b{t['batch']}", f"{t['qps']:.0f}",
                f"{t['seconds'] * 1e3:.1f} ms/batch, {n_reps} reps")

    emit_json({"n": n, "drift_frac": drift_frac, "dims": dims,
               "top_k": top_k, "n_reps": n_reps, "n_iter": n_iter,
               "ingest": {"first_points_per_sec": first["points_per_sec"],
                          "steady_points_per_sec":
                              steady["points_per_sec"]},
               "warm_vs_cold": {"target_kl": target, "slack": slack,
                                "cold_iters_to_target": cold_iters,
                                "warm_iters_to_target": warm_iters,
                                "ratio": ratio,
                                "n_matched": warm.n_matched,
                                "n_new": warm.n_new},
               "transform": transforms}, json_out)
    if assert_ratio:
        assert ratio <= WARM_RATIO_CEIL, (
            f"warm refresh took {warm_iters}/{cold_iters} = {ratio:.3f} "
            f"of cold iterations-to-target (> {WARM_RATIO_CEIL})")
    return csv.dump("service — incremental ingest, warm re-embed, "
                    "out-of-sample transform")


def run_smoke(json_out: Optional[str] = "BENCH_service_ci.json") -> str:
    """CI gate: reduced sizes; hard-asserts warm beats cold and that
    transform qps was recorded at ≥ 2 batch sizes."""
    out = run(n=20_000, drift_frac=0.05, dims=3, top_k=128, n_iter=150,
              slack=0.05, batch_sizes=(256, 4096), transform_iters=2,
              json_out=json_out, assert_ratio=False)
    import json as json_mod
    with open(json_out) as f:
        rec = json_mod.load(f)
    wc = rec["warm_vs_cold"]
    assert wc["warm_iters_to_target"] < wc["cold_iters_to_target"], (
        f"warm refresh ({wc['warm_iters_to_target']} iters) did not beat "
        f"cold ({wc['cold_iters_to_target']})")
    assert len(rec["transform"]) >= 2
    assert all(t["qps"] > 0 for t in rec["transform"])
    print(f"# smoke OK: warm {wc['warm_iters_to_target']} < cold "
          f"{wc['cold_iters_to_target']} iters; "
          f"qps {[int(t['qps']) for t in rec['transform']]}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=400_000)
    ap.add_argument("--drift-frac", type=float, default=0.05)
    ap.add_argument("--dims", type=int, default=4)
    ap.add_argument("--top-k", type=int, default=1024)
    ap.add_argument("--n-iter", type=int, default=500)
    ap.add_argument("--batch-sizes", default="1024,16384,131072")
    ap.add_argument("--json-out", default=DEFAULT_JSON)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes + hard warm-beats-cold assert (CI)")
    args = ap.parse_args()
    if args.smoke:
        out = args.json_out if args.json_out != DEFAULT_JSON \
            else "BENCH_service_ci.json"
        print(run_smoke(json_out=out))
        return
    sizes = tuple(int(s) for s in args.batch_sizes.split(","))
    print(run(n=args.n, drift_frac=args.drift_frac, dims=args.dims,
              top_k=args.top_k, n_iter=args.n_iter, batch_sizes=sizes,
              json_out=args.json_out))


if __name__ == "__main__":
    main()
