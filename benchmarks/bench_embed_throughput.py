"""Embed throughput: tSNE gradient iterations/sec across backends.

The PR-4 tentpole claim, measured on the steady-state iteration the
optimizer's ``fori_loop`` actually runs:

* ``dense``  — the classic O(N²)-memory matmul gradient (only timed while
  its (N, N) buffers fit, ``--dense-max``).
* ``tiled``  — the pure-XLA block-streamed exact gradient: O(block·N)
  memory but still O(N²) work per iteration.
* ``sparse`` — kNN-restricted attraction (fixed-shape COO, scatter-free
  sorted-row reduction) + FFT grid repulsion: O(N·k + G²·log G) per
  iteration.  This is what turns N = 10⁵–10⁶ representative embeddings
  from hours into minutes on CPU.

Setup costs (perplexity calibration, the one-off O(N²·D) kNN build) are
excluded: they are paid once, not per iteration, and the exact backends
get synthetic calibration stats for the same reason.  The sparse COO is
drawn with a uniformly random topology — iteration cost depends only on
the edge COUNT (E = 2·N·k), so this times the same work as a real graph
while letting the bench scale past the point where the kNN build
dominates wall-clock.  Backends are timed in interleaved rounds
(median-of-3 per variant) so machine drift cannot bias the ratios.

    PYTHONPATH=src python -m benchmarks.bench_embed_throughput \
        --sizes 16384,65536,262144 --json-out BENCH_embed_throughput.json

Emits a JSON trajectory (default path: BENCH_embed_throughput.json at the
repo root — the repo's tracked iterations/sec baseline); ``run()``
returns it as a string for benchmarks/run.py.
"""
from __future__ import annotations

import argparse
import json
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import interleaved_medians, repo_root_json
from repro.core import neighbors, tsne
from repro.core.tsne import PointStats, SparseP

DEFAULT_JSON = repo_root_json("BENCH_embed_throughput.json")


def synthetic_stats(n: int, rng) -> PointStats:
    """Plausible calibration stats without the calibration pass."""
    beta = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
    shift = jnp.zeros((n,), jnp.float32)
    zp = jnp.asarray(rng.uniform(5.0, 50.0, n).astype(np.float32))
    w = jnp.full((n,), 1.0 / n, jnp.float32)
    return PointStats(beta=beta, shift=shift, zp=zp, w=w)


def synthetic_sparse_p(n: int, k: int, rng) -> SparseP:
    """Random-topology COO with the real layout (symmetric closure of a
    k-out graph, deduped + sorted + row bounds): E = 2·N·k edges."""
    srcf = np.repeat(np.arange(n, dtype=np.int32), k)
    dstf = rng.integers(0, n, size=n * k).astype(np.int32)
    src = jnp.asarray(np.concatenate([srcf, dstf]))
    dst = jnp.asarray(np.concatenate([dstf, srcf]))
    val = jnp.full((2 * n * k,), 0.5 / (n * k), jnp.float32)
    s, d, v = neighbors.dedupe_edges(src, dst, val)
    return SparseP(src=s, dst=d, val=v, bounds=neighbors.row_bounds(s, n))


def run(sizes: Sequence[int] = (16384, 65536, 262144), block: int = 512,
        knn: int = 90, grid: int = 128, dense_max: int = 16384,
        tiled_max: int = 65536, iters: int = 3,
        json_out: Optional[str] = DEFAULT_JSON) -> str:
    rng = np.random.default_rng(0)
    records = []
    for n in sizes:
        x = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
        stats = synthetic_stats(n, rng)
        sp = synthetic_sparse_p(n, knn, rng)

        sparse_step = jax.jit(
            lambda y_: tsne.sparse_grad(y_, sp, 1.0, grid_size=grid)[0])
        drivers = {
            "sparse": lambda: jax.block_until_ready(sparse_step(y))}
        skipped = {}
        for backend, cap in (("tiled", tiled_max), ("dense", dense_max)):
            if n > cap:
                skipped[backend] = (f"O(N²) per-iteration cost at N={n} — "
                                    f"over --{backend}-max={cap}")
                continue
            step = jax.jit(lambda y_, _b=backend: tsne.embedding_grad(
                x, y_, stats, 1.0, backend=_b, block=block)[0])
            drivers[backend] = \
                lambda _s=step: jax.block_until_ready(_s(y))

        times = interleaved_medians(drivers, iters=iters)
        rec = {"bench": "embed_throughput", "n": n, "knn": knn,
               "grid": grid, "block": block,
               "edges": int(sp.src.shape[0])}
        for backend in ("dense", "tiled", "sparse"):
            ips = 1.0 / times[backend] if backend in times else None
            rec[f"{backend}_ips"] = ips
            if backend in skipped:
                rec[f"{backend}_skipped"] = skipped[backend]
        if rec["tiled_ips"]:
            rec["speedup_sparse_vs_tiled"] = \
                rec["sparse_ips"] / rec["tiled_ips"]
        records.append(rec)
        fmt = lambda v: f"{v:8.3f}" if v else "       -"
        print(f"# embed_throughput N={n:7d} k={knn} G={grid} "
              f"dense={fmt(rec['dense_ips'])} tiled={fmt(rec['tiled_ips'])} "
              f"sparse={fmt(rec['sparse_ips'])} iters/s  "
              f"sparse/tiled={rec.get('speedup_sparse_vs_tiled', '-')}",
              flush=True)

    common = [r for r in records if r.get("speedup_sparse_vs_tiled")]
    out = json.dumps({
        "bench": "embed_throughput",
        "speedup_sparse_vs_tiled_at_max_common_n":
            common[-1]["speedup_sparse_vs_tiled"] if common else None,
        "records": records}, indent=2)
    if json_out:
        with open(json_out, "w") as f:
            f.write(out + "\n")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="16384,65536,262144")
    ap.add_argument("--block", type=int, default=512)
    ap.add_argument("--knn", type=int, default=90,
                    help="sparse fan-out k (default 3·perplexity at the "
                         "paper's perplexity 30)")
    ap.add_argument("--grid", type=int, default=128)
    ap.add_argument("--dense-max", type=int, default=16384,
                    help="largest N at which the dense backend is timed")
    ap.add_argument("--tiled-max", type=int, default=65536,
                    help="largest N at which the tiled backend is timed")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--json-out", default=DEFAULT_JSON)
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    print(run(sizes=sizes, block=args.block, knn=args.knn, grid=args.grid,
              dense_max=args.dense_max, tiled_max=args.tiled_max,
              iters=args.iters, json_out=args.json_out))


if __name__ == "__main__":
    main()
