"""Embed throughput: tSNE gradient iterations/sec + UMAP epochs/sec.

The PR-4 tentpole claim, measured on the steady-state iteration the
optimizer's ``fori_loop`` actually runs:

* ``dense``  — the classic O(N²)-memory matmul gradient (only timed while
  its (N, N) buffers fit, ``--dense-max``).
* ``tiled``  — the pure-XLA block-streamed exact gradient: O(block·N)
  memory but still O(N²) work per iteration.
* ``sparse`` — kNN-restricted attraction (fixed-shape COO, scatter-free
  sorted-row reduction) + FFT grid repulsion: O(N·k + G²·log G) per
  iteration.  This is what turns N = 10⁵–10⁶ representative embeddings
  from hours into minutes on CPU.

And the PR-5 claim, measured the same way on the UMAP epoch:

* ``umap_scatter``     — the PR-4 epoch-batched SGD epoch, frozen
  VERBATIM below: per-edge forces reduced into per-point deltas by two
  ``.at[].add`` scatters over E = N·k edges (XLA CPU scatter walks
  updates serially).
* ``umap_scatterfree`` — the live ``umap.epoch_delta``: identical per-
  edge math, reduction via the shared sorted-COO cumsum core
  (``repro.core.coo``), zero scatter primitives.  The bidirectional edge
  layout is built once at setup, outside the timed region, exactly as
  ``optimize_embedding`` builds it outside its ``fori_loop``.

Setup costs (perplexity calibration, the one-off O(N²·D) kNN build, the
edge-layout sorts) are excluded: they are paid once, not per iteration,
and the exact backends get synthetic calibration stats for the same
reason.  The sparse COO / UMAP edge set is drawn with a uniformly random
topology — iteration cost depends only on the edge COUNT, so this times
the same work as a real graph while letting the bench scale past the
point where the kNN build dominates wall-clock.  Variants are timed in
interleaved rounds (median-of-3 per variant) so machine drift cannot
bias the ratios.

    PYTHONPATH=src python -m benchmarks.bench_embed_throughput \
        --sizes 16384,65536,262144 --json-out BENCH_embed_throughput.json

Emits a JSON trajectory (default path: BENCH_embed_throughput.json at the
repo root — the repo's tracked iterations/sec baseline); ``run()``
returns it as a string for benchmarks/run.py.
"""
from __future__ import annotations

import argparse
import json
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (emit_json, interleaved_medians,
                               repo_root_json)
from repro.core import coo, neighbors, tsne, umap
from repro.core.tsne import PointStats, SparseP

DEFAULT_JSON = repo_root_json("BENCH_embed_throughput.json")


def synthetic_stats(n: int, rng) -> PointStats:
    """Plausible calibration stats without the calibration pass."""
    beta = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
    shift = jnp.zeros((n,), jnp.float32)
    zp = jnp.asarray(rng.uniform(5.0, 50.0, n).astype(np.float32))
    w = jnp.full((n,), 1.0 / n, jnp.float32)
    return PointStats(beta=beta, shift=shift, zp=zp, w=w)


def synthetic_sparse_p(n: int, k: int, rng) -> SparseP:
    """Random-topology COO with the real layout (symmetric closure of a
    k-out graph, deduped + sorted + row bounds): E = 2·N·k edges."""
    srcf = np.repeat(np.arange(n, dtype=np.int32), k)
    dstf = rng.integers(0, n, size=n * k).astype(np.int32)
    src = jnp.asarray(np.concatenate([srcf, dstf]))
    dst = jnp.asarray(np.concatenate([dstf, srcf]))
    val = jnp.full((2 * n * k,), 0.5 / (n * k), jnp.float32)
    s, d, v = neighbors.dedupe_edges(src, dst, val)
    return SparseP(src=s, dst=d, val=v, bounds=neighbors.row_bounds(s, n))


def synthetic_umap_edges(n: int, k: int, rng
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Random-topology UMAP edge set with the real layout: the fuzzy-set
    edge list is (rows repeated k times, kNN columns) — src-sorted by
    construction — with memberships in (0, 1]."""
    src = np.repeat(np.arange(n, dtype=np.int32), k)
    dst = rng.integers(0, n, size=n * k).astype(np.int32)
    memb = rng.uniform(0.05, 1.0, size=n * k).astype(np.float32)
    return jnp.asarray(np.stack([src, dst], axis=1)), jnp.asarray(memb)


# --------------------------------------------------------------------------
# The PR-4 UMAP epoch reduction, frozen VERBATIM (modulo function
# packaging): per-edge attraction/repulsion reduced into per-point deltas
# by two `.at[].add` scatters.  The live `umap.epoch_delta` has since been
# rebuilt on the sorted-COO cumsum core, so reconstructing the old epoch
# from it would silently flatter the baseline.  Given the same `kneg` and
# an src-sorted edge list this computes the same delta as the live epoch
# up to summation order (tests/test_umap_scatter_free.py pins the
# trajectory equivalence).
# --------------------------------------------------------------------------

def umap_scatter_epoch_delta(y, kneg, src, dst, memb_n, a, b, neg_rate):
    e = src.shape[0]
    n = y.shape[0]
    ys, yd = y[src], y[dst]
    d2 = jnp.sum((ys - yd) ** 2, axis=1)
    grad_coef = (-2.0 * a * b * d2 ** (b - 1.0)
                 / (1.0 + a * d2 ** b))
    grad_coef = jnp.where(d2 > 0, grad_coef, 0.0)
    att = jnp.clip(grad_coef[:, None] * (ys - yd), -4.0, 4.0) \
        * memb_n[:, None]
    neg = jax.random.randint(kneg, (e, neg_rate), 0, n)
    valid = (neg != src[:, None]) & (neg != dst[:, None])
    yn = y[neg]
    dn2 = jnp.sum((ys[:, None, :] - yn) ** 2, axis=2)
    rep_coef = (2.0 * b) / ((0.001 + dn2) * (1.0 + a * dn2 ** b))
    rep = jnp.clip(rep_coef[..., None] * (ys[:, None, :] - yn),
                   -4.0, 4.0) * memb_n[:, None, None]
    rep = jnp.where(valid[..., None], rep, 0.0)
    delta = jnp.zeros_like(y)
    delta = delta.at[src].add(att + jnp.sum(rep, axis=1))
    delta = delta.at[dst].add(-att)
    return delta


def run(sizes: Sequence[int] = (16384, 65536, 262144), block: int = 512,
        knn: int = 90, grid: int = 128, dense_max: int = 16384,
        tiled_max: int = 65536, iters: int = 3, umap_knn: int = 15,
        neg_rate: int = 5,
        json_out: Optional[str] = DEFAULT_JSON) -> str:
    rng = np.random.default_rng(0)
    records = []
    for n in sizes:
        x = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
        stats = synthetic_stats(n, rng)
        sp = synthetic_sparse_p(n, knn, rng)

        sparse_step = jax.jit(
            lambda y_: tsne.sparse_grad(y_, sp, 1.0, grid_size=grid)[0])
        drivers = {
            "sparse": lambda: jax.block_until_ready(sparse_step(y))}
        skipped = {}
        for backend, cap in (("tiled", tiled_max), ("dense", dense_max)):
            if n > cap:
                skipped[backend] = (f"O(N²) per-iteration cost at N={n} — "
                                    f"over --{backend}-max={cap}")
                continue
            step = jax.jit(lambda y_, _b=backend: tsne.embedding_grad(
                x, y_, stats, 1.0, backend=_b, block=block)[0])
            drivers[backend] = \
                lambda _s=step: jax.block_until_ready(_s(y))

        # UMAP epoch: frozen scatter baseline vs live scatter-free epoch,
        # same per-edge math and the same negative-sample key, timed on
        # the steady-state epoch (edge layout built outside, like the
        # optimizer's own setup)
        edges, memb = synthetic_umap_edges(n, umap_knn, rng)
        a, b = umap.fit_ab(1.0, 0.1)
        memb_n = memb / jnp.maximum(jnp.max(memb), 1e-12)
        layout, order = coo.edge_layout(edges[:, 0], edges[:, 1], n)
        memb_s = memb_n[order]
        usrc, udst = edges[:, 0], edges[:, 1]
        kneg = jax.random.key(1)
        scatter_step = jax.jit(lambda y_, k_: y_ + umap_scatter_epoch_delta(
            y_, k_, usrc, udst, memb_n, a, b, neg_rate))
        free_step = jax.jit(lambda y_, k_: y_ + umap.epoch_delta(
            y_, layout, memb_s, k_, a, b, neg_rate))
        drivers["umap_scatter"] = \
            lambda: jax.block_until_ready(scatter_step(y, kneg))
        drivers["umap_scatterfree"] = \
            lambda: jax.block_until_ready(free_step(y, kneg))

        times = interleaved_medians(drivers, iters=iters)
        rec = {"bench": "embed_throughput", "n": n, "knn": knn,
               "grid": grid, "block": block,
               "edges": int(sp.src.shape[0]),
               "umap_knn": umap_knn, "neg_rate": neg_rate,
               "umap_edges": int(usrc.shape[0])}
        for backend in ("dense", "tiled", "sparse"):
            ips = 1.0 / times[backend] if backend in times else None
            rec[f"{backend}_ips"] = ips
            if backend in skipped:
                rec[f"{backend}_skipped"] = skipped[backend]
        if rec["tiled_ips"]:
            rec["speedup_sparse_vs_tiled"] = \
                rec["sparse_ips"] / rec["tiled_ips"]
        rec["umap_scatter_eps"] = 1.0 / times["umap_scatter"]
        rec["umap_scatterfree_eps"] = 1.0 / times["umap_scatterfree"]
        rec["speedup_umap_scatterfree_vs_scatter"] = \
            rec["umap_scatterfree_eps"] / rec["umap_scatter_eps"]
        records.append(rec)
        fmt = lambda v: f"{v:8.3f}" if v else "       -"
        print(f"# embed_throughput N={n:7d} k={knn} G={grid} "
              f"dense={fmt(rec['dense_ips'])} tiled={fmt(rec['tiled_ips'])} "
              f"sparse={fmt(rec['sparse_ips'])} iters/s  "
              f"sparse/tiled={rec.get('speedup_sparse_vs_tiled', '-')}",
              flush=True)
        print(f"#                  N={n:7d} k={umap_knn} R={neg_rate} "
              f"umap_scatter={fmt(rec['umap_scatter_eps'])} "
              f"umap_scatterfree={fmt(rec['umap_scatterfree_eps'])} "
              f"epochs/s  scatterfree/scatter="
              f"{rec['speedup_umap_scatterfree_vs_scatter']:.1f}",
              flush=True)

    common = [r for r in records if r.get("speedup_sparse_vs_tiled")]
    return emit_json({
        "bench": "embed_throughput",
        "speedup_sparse_vs_tiled_at_max_common_n":
            common[-1]["speedup_sparse_vs_tiled"] if common else None,
        "speedup_umap_scatterfree_vs_scatter_at_max_n":
            records[-1]["speedup_umap_scatterfree_vs_scatter"]
            if records else None,
        "records": records}, json_out)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="16384,65536,262144")
    ap.add_argument("--block", type=int, default=512)
    ap.add_argument("--knn", type=int, default=90,
                    help="sparse fan-out k (default 3·perplexity at the "
                         "paper's perplexity 30)")
    ap.add_argument("--grid", type=int, default=128)
    ap.add_argument("--dense-max", type=int, default=16384,
                    help="largest N at which the dense backend is timed")
    ap.add_argument("--tiled-max", type=int, default=65536,
                    help="largest N at which the tiled backend is timed")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--umap-knn", type=int, default=15,
                    help="UMAP edge fan-out k (E = N·k edges per epoch)")
    ap.add_argument("--neg-rate", type=int, default=5,
                    help="UMAP negative samples per edge")
    ap.add_argument("--json-out", default=DEFAULT_JSON)
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    print(run(sizes=sizes, block=args.block, knn=args.knn, grid=args.grid,
              dense_max=args.dense_max, tiled_max=args.tiled_max,
              iters=args.iters, umap_knn=args.umap_knn,
              neg_rate=args.neg_rate, json_out=args.json_out))


if __name__ == "__main__":
    main()
