"""Approximate vs exact kNN build: recall and wall-clock (ROADMAP item 1).

    PYTHONPATH=src python -m benchmarks.bench_knn_recall \
        --sizes 16384,65536,262144 --json-out BENCH_knn_recall.json

Per N (gaussian-mixture blobs, the pipeline's representative geometry):
build the exact graph (``neighbors.knn_graph(method="exact")``, blocked)
and the approximate one (``method="ann"``: multi-probe sketch bucketing +
NN-descent, ``core.ann``), then report

  * recall — fraction of true kNN edges the ann graph recovers,
  * build wall-clock for both and the ann speedup.

The tracked baseline (BENCH_knn_recall.json at the repo root, the
BENCH_*.json convention) is the contract behind switching ``"auto"`` to
the ann path above ``AnnConfig.auto_threshold``: recall ≥ 0.9 with the
build no longer the wall at representative counts.

``--smoke`` runs one small size and **asserts** recall ≥ 0.9 — the CI
recall gate (writes BENCH_knn_recall_ci.json so the tracked full-size
baseline is never clobbered by a CI box).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence

import jax
import numpy as np

from benchmarks.common import Csv, emit_json, repo_root_json
from repro.core import neighbors
from repro.core.ann import AnnConfig
from repro.data.synthetic import MixtureSpec, gaussian_mixture

DEFAULT_JSON = repo_root_json("BENCH_knn_recall.json")
SMOKE_RECALL_FLOOR = 0.9


def _blobs(n: int, dims: int, seed: int = 0):
    spec = MixtureSpec(dims=dims, n_clusters=10, cluster_std=0.05,
                       background_frac=0.2)
    pts, _ = gaussian_mixture(n, spec, seed=seed)
    return jax.numpy.asarray(pts)


def recall_vs_exact(ann_idx: np.ndarray, exact_idx: np.ndarray) -> float:
    """Fraction of true kNN edges present in the ann graph (order-free)."""
    n, k = exact_idx.shape
    rows = np.arange(n, dtype=np.int64)[:, None]
    exact_keys = exact_idx.astype(np.int64) + rows * n
    ann_keys = ann_idx.astype(np.int64) + rows * n
    return float(np.isin(ann_keys, exact_keys).mean())


def _timed_build(x, k: int, reps: int, **kw):
    """Median build seconds over ``reps`` post-compile runs + the result
    of the first (compile excluded: one warmup build)."""
    idx, dist = jax.block_until_ready(
        neighbors.knn_graph(x, k, **kw))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(neighbors.knn_graph(x, k, **kw))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2], np.asarray(idx)


def run(sizes: Sequence[int] = (16384, 65536, 262144), k: int = 90,
        dims: int = 8, block: int = 512, ann: Optional[AnnConfig] = None,
        exact_max: int = 262144,
        json_out: Optional[str] = DEFAULT_JSON) -> str:
    """Recall + build-time trajectory; returns the CSV block."""
    cfg = ann if ann is not None else AnnConfig()
    records = []
    csv = Csv(["n", "k", "recall", "t_exact_s", "t_ann_s", "speedup"])
    for n in sizes:
        x = _blobs(n, dims)
        reps = 1 if n >= 131072 else 3
        t_ann, ann_idx = _timed_build(x, k, reps, method="ann", ann=cfg)
        rec = {"n": n, "k": min(k, n - 1), "dims": dims,
               "t_ann_build_s": t_ann,
               "ann": {"probes": cfg.probes, "bucket": cfg.bucket,
                       "iters": cfg.iters, "sample": cfg.sample,
                       "delta": cfg.delta, "tile": cfg.tile}}
        if n <= exact_max:
            t_exact, exact_idx = _timed_build(x, k, reps, method="exact",
                                              block=block)
            rec["t_exact_build_s"] = t_exact
            rec["recall"] = recall_vs_exact(ann_idx, exact_idx)
            rec["speedup_ann_vs_exact"] = t_exact / t_ann
            csv.add(n, rec["k"], f"{rec['recall']:.4f}", f"{t_exact:.2f}",
                    f"{t_ann:.2f}", f"{rec['speedup_ann_vs_exact']:.1f}")
        else:
            csv.add(n, rec["k"], "-", "-", f"{t_ann:.2f}", "-")
        records.append(rec)
        print(f"# knn_recall N={n:7d} k={rec['k']} "
              f"ann={t_ann:.2f}s "
              + (f"exact={rec['t_exact_build_s']:.2f}s "
                 f"recall={rec['recall']:.4f} "
                 f"speedup={rec['speedup_ann_vs_exact']:.1f}x"
                 if "recall" in rec else "(exact skipped)"), flush=True)

    gated = [r for r in records if "recall" in r]
    emit_json({"bench": "knn_recall",
               "recall_at_max_gated_n": gated[-1]["recall"] if gated else
               None,
               "speedup_at_max_gated_n":
                   gated[-1]["speedup_ann_vs_exact"] if gated else None,
               "records": records}, json_out)
    return csv.dump("knn_recall — approximate (sketch bucketing + "
                    "NN-descent) vs exact kNN build")


def run_smoke(n: int = 4096, k: int = 15, dims: int = 8,
              json_out: Optional[str] = "BENCH_knn_recall_ci.json") -> str:
    """CI gate: one small blob set, hard recall assert."""
    out = run(sizes=(n,), k=k, dims=dims, exact_max=n, json_out=json_out)
    import json as json_mod
    with open(json_out) as f:
        rec = json_mod.load(f)["records"][0]
    assert rec["recall"] >= SMOKE_RECALL_FLOOR, (
        f"ann recall {rec['recall']:.4f} < {SMOKE_RECALL_FLOOR} "
        f"at N={n}, k={k}")
    print(f"# smoke OK: recall {rec['recall']:.4f} >= {SMOKE_RECALL_FLOOR}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="16384,65536,262144")
    ap.add_argument("--k", type=int, default=90)
    ap.add_argument("--dims", type=int, default=8)
    ap.add_argument("--block", type=int, default=512)
    ap.add_argument("--exact-max", type=int, default=262144)
    ap.add_argument("--json-out", default=DEFAULT_JSON)
    ap.add_argument("--smoke", action="store_true",
                    help="small blob set + hard recall >= 0.9 assert (CI)")
    args = ap.parse_args()
    if args.smoke:
        out = args.json_out if args.json_out != DEFAULT_JSON \
            else "BENCH_knn_recall_ci.json"
        print(run_smoke(json_out=out))
        return
    sizes = tuple(int(s) for s in args.sizes.split(","))
    print(run(sizes=sizes, k=args.k, dims=args.dims, block=args.block,
              exact_max=args.exact_max, json_out=args.json_out))


if __name__ == "__main__":
    main()
