"""Mesh-parallel embed stage: device-count scaling for both embedders.

The PR-6 tentpole claim: the whole sparse embed iteration runs inside
``shard_map`` on a 1-D embed mesh (row-block state + contiguous edge
slices, one all_gather + fixed-size psums per step, no cross-device
scatter), so adding devices divides the per-device edge/row work.  This
bench measures it directly:

* ``tsne``  — optimizer iters/sec of the jitted sharded stage
  (``tsne._sparse_stage_mesh``: kNN attraction + psum'd CIC/FFT repulsion
  + sharded momentum update), setup excluded;
* ``umap``  — epochs/sec of the jitted sharded SGD loop
  (``umap._optimize_embedding_mesh``), setup excluded;
* at 1 device the plain single-device drivers (``_sparse_stage`` /
  ``_optimize_embedding_jit``) run too, so the shard_map overhead at
  D=1 is visible next to the true baseline.

Each device count runs in its OWN subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=D`` (the flag must be
set before jax initializes, and the parent process keeps its 1-device
view).  Virtual host devices share the machine's cores, so CPU numbers
show overhead trends and collective counts more than true speedup — on a
real multi-chip mesh the same jaxpr is what runs.

    PYTHONPATH=src python -m benchmarks.bench_embed_mesh \
        --devices 1,2,4,8 --n 20000 --json-out BENCH_embed_mesh.json

Emits a JSON trajectory (default: BENCH_embed_mesh.json at the repo
root, the tracked baseline); ``run()`` returns it as a string for
benchmarks/run.py.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Optional, Sequence

MARKER = "@@EMBED_MESH@@ "
DIMS = 8


def _worker(devices: int, n: int, knn: int, grid: int, tsne_iters: int,
            umap_epochs: int) -> None:
    """Runs inside the subprocess that actually sees ``devices`` devices."""
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import time_fn
    from repro.core import coo, tsne, umap
    from repro.core import mesh as mesh_mod

    assert jax.device_count() >= devices, \
        f"{jax.device_count()} devices visible, wanted {devices}"
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.concatenate([
        rng.normal(0, 1, (n // 2, DIMS)),
        rng.normal(6, 1, (n - n // 2, DIMS))]).astype(np.float32))
    mesh = mesh_mod.make_embed_mesh(devices)
    rec = {"devices": devices, "n": n, "knn": knn, "grid": grid}

    # ---- tSNE: time the jitted sharded stage, setup excluded
    tc = tsne.TsneConfig(backend="sparse", knn=knn, grid_size=grid,
                         n_iter=tsne_iters)
    sp = tsne._sparse_setup_p_mesh(x, None, cfg=tc, mesh=mesh)
    ssp = tsne.shard_sparse_p(sp, n, devices)
    _, n_pad = mesh_mod.row_block(n, devices)
    y0 = 1e-4 * jax.random.normal(jax.random.key(0), (n_pad, 2))
    state = tsne.TsneState(y0, jnp.zeros_like(y0), jnp.ones_like(y0))
    kls = jnp.zeros((tsne_iters,))
    it0 = jnp.asarray(0, jnp.int32)
    stage = functools.partial(tsne._sparse_stage_mesh, cfg=tc,
                              count=tsne_iters, grid_size=grid,
                              interpret=True, mesh=mesh, n=n)
    rec["tsne_iters_per_sec"] = tsne_iters / time_fn(stage, state, kls,
                                                     ssp, it0)

    # ---- UMAP: time the jitted sharded epoch loop, setup excluded
    uc = umap.UmapConfig(n_epochs=umap_epochs, n_neighbors=min(knn, 15),
                         block=4096)
    idx, dist = umap.knn_graph(x, uc.n_neighbors, block=uc.block, mesh=mesh)
    edges, memb = umap.fuzzy_simplicial_set(idx, dist)
    layout, order = coo.edge_layout(edges[:, 0], edges[:, 1], n)
    memb_n = (memb / jnp.maximum(jnp.max(memb), 1e-12))[order]
    slay = coo.shard_edge_layout(np.asarray(layout.src),
                                 np.asarray(layout.dst), n, devices)
    memb_s = coo.shard_payload(slay, memb_n)
    opt = functools.partial(umap._optimize_embedding_mesh, cfg=uc, n=n,
                            e_total=int(layout.src.shape[0]), mesh=mesh)
    rec["umap_epochs_per_sec"] = umap_epochs / time_fn(
        opt, jax.random.key(1), slay, memb_s, None)

    if devices == 1:
        # the true single-device baselines, same sizes
        sstage = functools.partial(tsne._sparse_stage, cfg=tc,
                                   count=tsne_iters, grid_size=grid,
                                   interpret=True)
        s0 = tsne.TsneState(y0[:n], jnp.zeros((n, 2)), jnp.ones((n, 2)))
        rec["tsne_single_iters_per_sec"] = tsne_iters / time_fn(
            sstage, s0, kls, sp, it0)
        rec["umap_single_epochs_per_sec"] = umap_epochs / time_fn(
            functools.partial(umap._optimize_embedding_jit, n=n, cfg=uc),
            jax.random.key(1), edges, memb)

    print(MARKER + json.dumps(rec), flush=True)


DEFAULT_JSON = None  # resolved lazily: benchmarks.common imports jax


def run(devices: Sequence[int] = (1, 2, 4, 8), n: int = 20_000,
        knn: int = 32, grid: int = 128, tsne_iters: int = 20,
        umap_epochs: int = 20,
        json_out: Optional[str] = "__default__") -> str:
    from benchmarks.common import Csv, repo_root_json
    if json_out == "__default__":
        json_out = repo_root_json("BENCH_embed_mesh.json")
    records = []
    for d in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={d} "
            + env.get("XLA_FLAGS", "")).strip()
        cmd = [sys.executable, "-m", "benchmarks.bench_embed_mesh",
               "--worker", str(d), "--n", str(n), "--knn", str(knn),
               "--grid", str(grid), "--tsne-iters", str(tsne_iters),
               "--umap-epochs", str(umap_epochs)]
        out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             timeout=3600)
        if out.returncode != 0:
            raise RuntimeError(
                f"embed_mesh worker (D={d}) failed:\n{out.stdout}\n"
                f"{out.stderr}")
        line = [ln for ln in out.stdout.splitlines()
                if ln.startswith(MARKER)][-1]
        rec = json.loads(line[len(MARKER):])
        records.append(rec)
        print(f"# embed_mesh D={d} "
              f"tsne={rec['tsne_iters_per_sec']:7.2f} it/s "
              f"umap={rec['umap_epochs_per_sec']:7.2f} ep/s", flush=True)

    csv = Csv(["devices", "tsne_iters_per_sec", "umap_epochs_per_sec"])
    for rec in records:
        csv.add(rec["devices"], f"{rec['tsne_iters_per_sec']:.3f}",
                f"{rec['umap_epochs_per_sec']:.3f}")
    base = records[0]
    summary = {
        "bench": "embed_mesh", "n": n, "knn": knn, "grid": grid,
        "tsne_speedup_at_max_d":
            records[-1]["tsne_iters_per_sec"] / base["tsne_iters_per_sec"],
        "umap_speedup_at_max_d":
            records[-1]["umap_epochs_per_sec"] / base["umap_epochs_per_sec"],
        "records": records}
    from benchmarks.common import emit_json
    emit_json(summary, json_out)
    return csv.dump("embed_mesh — sharded embed stage, device-count scaling "
                    "(virtual CPU devices share cores; see module docstring)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--worker", type=int, default=0,
                    help="internal: run the measurement in THIS process "
                         "for the given device count")
    ap.add_argument("--devices", default="1,2,4,8")
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--knn", type=int, default=32)
    ap.add_argument("--grid", type=int, default=128)
    ap.add_argument("--tsne-iters", type=int, default=20)
    ap.add_argument("--umap-epochs", type=int, default=20)
    ap.add_argument("--json-out", default="__default__")
    args = ap.parse_args()
    if args.worker:
        _worker(args.worker, args.n, args.knn, args.grid, args.tsne_iters,
                args.umap_epochs)
        return
    devices = tuple(int(s) for s in args.devices.split(","))
    print(run(devices=devices, n=args.n, knn=args.knn, grid=args.grid,
              tsne_iters=args.tsne_iters, umap_epochs=args.umap_epochs,
              json_out=args.json_out))


if __name__ == "__main__":
    main()
