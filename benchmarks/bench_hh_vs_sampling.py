"""Paper §II-2: heavy hitters vs random subsampling at equal budget.

The Poisson argument: at sampling rate p → 0 the fat tail of the cell
count distribution collapses; a 10⁷-point cluster sampled at 10⁻⁷ yields
K=1 point — indistinguishable from background.  HH extraction keeps it.
We measure cluster *detection rate* (a cluster is detected if ≥ X of its
representative cells appear in the budget-limited summary) for both
methods at the same output budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.core import quantize, sketch, heavy_hitters
from repro.data import gaussian_mixture
from repro.data.synthetic import MixtureSpec


def run(n_points: int = 1_000_000, budget: int = 100) -> str:
    csv = Csv(["method", "clusters_detected", "of", "bg_fraction_of_summary"])
    # paper regime: clusters hold a SMALL fraction of the stream, so a
    # budget-limited random sample is dominated by background (Poisson
    # argument); HHs ignore the diffuse background entirely.
    spec = MixtureSpec(dims=6, n_clusters=30, cluster_std=0.01,
                       background_frac=0.9)
    pts, labels = gaussian_mixture(n_points, spec, seed=5)
    centers = spec.centers(5)
    grid = quantize.fit_grid(jnp.asarray(pts), bins=16)
    cell = grid.cell_size

    def detected(summary_pts):
        """clusters with a summary point within 1.5 cells of the center."""
        det = 0
        for c in centers:
            d = np.abs(summary_pts - c).max(axis=1)
            if (d < 1.5 * cell.max()).any():
                det += 1
        return det

    def bg_frac(summary_pts):
        d = np.stack([np.abs(summary_pts - c).max(axis=1)
                      for c in centers]).min(axis=0)
        return float((d > 3 * cell.max()).mean())

    # --- random subsampling at the same budget ---
    rng = np.random.default_rng(0)
    sub = pts[rng.choice(n_points, budget, replace=False)]
    csv.add("random_subsample", detected(sub), len(centers),
            f"{bg_frac(sub):.2f}")

    # --- heavy hitters ---
    khi, klo = quantize.points_to_keys(grid, jnp.asarray(pts))
    sk = sketch.init(jax.random.key(0), rows=8, log2_cols=14)
    sk = sketch.update_sorted(sk, khi, klo)
    hh = heavy_hitters.extract(sk, khi, klo, k=budget)
    coords = quantize.unpack(grid, (hh.key_hi, hh.key_lo))
    hh_pts = np.asarray(quantize.cell_center(grid, coords))[
        np.asarray(hh.mask)]
    csv.add("heavy_hitters", detected(hh_pts), len(centers),
            f"{bg_frac(hh_pts):.2f}")
    return csv.dump("hh_vs_sampling (paper §II-2 Poisson argument)")
