"""Shared benchmark utilities: timing, CSV emission."""
from __future__ import annotations

import time
from typing import Callable, List

import jax


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


class Csv:
    def __init__(self, header: List[str]):
        self.header = header
        self.rows: List[List] = []

    def add(self, *row):
        self.rows.append(list(row))

    def dump(self, title: str) -> str:
        out = [f"# {title}", ",".join(self.header)]
        for r in self.rows:
            out.append(",".join(str(x) for x in r))
        return "\n".join(out)
