"""Shared benchmark utilities: timing, CSV emission, JSON baselines with
a backend stamp, static jaxpr peak-buffer measurement (used by the
scaling benches to report memory trajectories past the point where
allocation would OOM)."""
from __future__ import annotations

import contextlib
import json
import os
import time
from typing import Callable, List, Optional

import jax
import numpy as np


@contextlib.contextmanager
def maybe_trace(trace_dir: Optional[str], label: str = "bench"):
    """Opt-in profiler capture: with ``trace_dir`` set, the wrapped
    region runs under ``jax.profiler.trace`` and the TensorBoard/Perfetto
    artifacts land in ``trace_dir/label`` (one subdirectory per bench so
    a multi-bench run keeps captures separate).  ``trace_dir=None`` is a
    no-op with zero overhead — the default for every CI and baseline
    run, since profiling perturbs the timings it wraps."""
    if not trace_dir:
        yield
        return
    out = os.path.join(trace_dir, label)
    os.makedirs(out, exist_ok=True)
    with jax.profiler.trace(out):
        yield


def repo_root_json(name: str) -> str:
    """Absolute path of a tracked ``BENCH_*.json`` baseline at the repo
    root — the convention for benchmark trajectories kept under git."""
    return os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), name)


def emit_json(payload: dict, json_out: Optional[str]) -> str:
    """Serialize a bench summary and optionally write it to ``json_out``.

    Stamps a ``backend`` column (``jax.default_backend()``) right after
    the bench name so every ``BENCH_*.json`` records where it ran — the
    tracked baselines are only comparable within a backend (ROADMAP
    item 4's CPU-vs-accelerator trajectory).  Returns the JSON string.
    """
    stamped = {"bench": payload.get("bench"),
               "backend": jax.default_backend()}
    stamped.update({k: v for k, v in payload.items() if k != "bench"})
    out = json.dumps(stamped, indent=2)
    if json_out:
        with open(json_out, "w") as f:
            f.write(out + "\n")
    return out


def iter_jaxpr_avals(jaxpr):
    """Yield every intermediate abstract value in a jaxpr, recursively."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v, "aval"):
                yield v.aval
        for p in eqn.params.values():
            for sub in _sub_jaxprs(p):
                yield from iter_jaxpr_avals(sub)


def _sub_jaxprs(param):
    vals = param if isinstance(param, (list, tuple)) else [param]
    for v in vals:
        if hasattr(v, "jaxpr"):          # ClosedJaxpr
            yield v.jaxpr
        elif hasattr(v, "eqns"):         # raw Jaxpr
            yield v


def count_primitive(jaxpr, name: str) -> int:
    """Occurrences of a primitive (e.g. "sort") in a jaxpr, recursively
    (scan/pjit bodies are traced once, so a scanned step's primitives are
    counted once regardless of trip count)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for p in eqn.params.values():
            for sub in _sub_jaxprs(p):
                n += count_primitive(sub, name)
    return n


def count_eqns(jaxpr) -> int:
    """Total equation count of a jaxpr, recursively — a trace-size proxy."""
    n = 0
    for eqn in jaxpr.eqns:
        n += 1
        for p in eqn.params.values():
            for sub in _sub_jaxprs(p):
                n += count_eqns(sub)
    return n


def peak_buffer_bytes(fn, *args) -> int:
    """Largest single intermediate of fn(*args), from the jaxpr (static)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    best = 0
    for aval in iter_jaxpr_avals(jaxpr.jaxpr):
        if hasattr(aval, "shape") and hasattr(aval, "dtype"):
            best = max(best, int(np.prod(aval.shape, dtype=np.int64))
                       * aval.dtype.itemsize)
    return best


def interleaved_medians(drivers: dict, iters: int = 3) -> dict:
    """Time each zero-arg driver `iters` times in interleaved rounds (all
    are warmed first); median wall seconds per driver.  Interleaving keeps
    slow machine drift out of the variant RATIOS — shared by the
    throughput benches."""
    for once in drivers.values():
        once()                                 # warm the trace
    ts: dict = {k: [] for k in drivers}
    for _ in range(iters):
        for k, once in drivers.items():
            t0 = time.perf_counter()
            once()
            ts[k].append(time.perf_counter() - t0)
    return {k: sorted(v)[len(v) // 2] for k, v in ts.items()}


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


class Csv:
    def __init__(self, header: List[str]):
        self.header = header
        self.rows: List[List] = []

    def add(self, *row):
        self.rows.append(list(row))

    def dump(self, title: str) -> str:
        out = [f"# {title}", ",".join(self.header)]
        for r in self.rows:
            out.append(",".join(str(x) for x in r))
        return "\n".join(out)
