"""Paper §IV coverage numbers: cumulative mass captured by the top-K HHs.

Cancer: top-20k HHs hold 84.11% of 26M pixels (top-1 = 204,901 pts,
rank-20k = 180).  SDSS: top-2,609 HHs hold 99.0% of 30M stars.  We
reproduce the *shape* of those curves on matched-statistics mixtures:
strongly clustered data concentrates the mass in few cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.core import quantize, sketch, heavy_hitters
from repro.data import gaussian_mixture
from repro.data.synthetic import MixtureSpec


def run(n_points: int = 2_000_000) -> str:
    csv = Csv(["dataset_analog", "top_k", "coverage_frac", "paper_analog"])
    cases = [
        ("cancer-like", MixtureSpec(dims=8, n_clusters=40,
                                    cluster_std=0.015,
                                    background_frac=0.16),
         22, 20_000, "84.11% of 26M (top-20k)"),
        ("sdss-like", MixtureSpec(dims=6, n_clusters=12, cluster_std=0.008,
                                  background_frac=0.01),
         22, 2_609, "99.0% of 30M (top-2609)"),
    ]
    for name, spec, bins, k, paper in cases:
        pts, _ = gaussian_mixture(n_points, spec, seed=7)
        grid = quantize.fit_grid(jnp.asarray(pts), bins=bins)
        khi, klo = quantize.points_to_keys(grid, jnp.asarray(pts))
        sk = sketch.init(jax.random.key(0), rows=16, log2_cols=18)
        sk = sketch.update_sorted(sk, khi, klo)
        hh = heavy_hitters.extract(sk, khi, klo, k=min(k, n_points // 10),
                                   candidate_pool=2 * k)
        cov = float(np.asarray(hh.count)[np.asarray(hh.mask)].sum()
                    / n_points)
        csv.add(name, k, f"{cov:.4f}", paper)
    return csv.dump("hh_coverage (paper §IV cumulative fractions)")
