"""Embed-stage scaling: dense vs tiled tSNE gradient, time + memory vs N.

Demonstrates the tentpole claim: the dense backend's per-iteration peak
buffer grows as 3·N²·4 bytes (the cliff that pinned the paper at
N ≈ 2·10⁴ representatives), while the tiled backend's peak temp stays at
block·N — a flat line in N for fixed work per row.

Peak buffer sizes are measured *statically* by walking the jaxpr of one
gradient step and taking the largest intermediate — no allocation needed,
so the dense trajectory can be reported past the point where it would
OOM.  Iteration times are wall-clock (dense only attempted while its
buffers fit, ``--dense-max``).

    PYTHONPATH=src python -m benchmarks.bench_embed_scaling \
        --sizes 8192,16384,32768,65536 --json-out BENCH_embed_scaling.json

Also times the chunked UMAP kNN stage at each N (the other former O(N²)
buffer).  Emits a JSON trajectory (default path: BENCH_embed_scaling.json
at the repo root, the tracked BENCH_*.json convention); ``run()`` returns
it as a string for benchmarks/run.py.
"""
from __future__ import annotations

import argparse
import json
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# peak_buffer_bytes / iter_jaxpr_avals moved to benchmarks.common (shared
# with bench_ingest_scaling); re-exported here for callers of this module.
from benchmarks.common import (emit_json, iter_jaxpr_avals,  # noqa: F401
                               peak_buffer_bytes, repo_root_json, time_fn)
from benchmarks.bench_embed_throughput import (synthetic_sparse_p,
                                               synthetic_stats)
from repro.core import tsne, umap
from repro.core.tsne import PointStats  # noqa: F401  (re-export)

DEFAULT_JSON = repo_root_json("BENCH_embed_scaling.json")


def run(sizes: Sequence[int] = (8192, 16384, 32768, 65536),
        dense_max: int = 16384, block: int = 512, dims_hi: int = 8,
        iters: int = 2, umap_k: int = 15, sparse_k: int = 32,
        sparse_grid: int = 128,
        json_out: Optional[str] = DEFAULT_JSON) -> str:
    rng = np.random.default_rng(0)
    records = []
    for n in sizes:
        x = jnp.asarray(rng.normal(size=(n, dims_hi)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
        stats = synthetic_stats(n, rng)
        sp = synthetic_sparse_p(n, sparse_k, rng)
        for backend in ("dense", "tiled", "sparse"):
            if backend == "sparse":
                def grad(y_):
                    return tsne.sparse_grad(y_, sp, 1.0,
                                            grid_size=sparse_grid)[0]
            else:
                def grad(y_, _backend=backend):
                    return tsne.embedding_grad(x, y_, stats, 1.0,
                                               backend=_backend,
                                               block=block)[0]

            rec = {"stage": "tsne_grad", "backend": backend, "n": n,
                   "block": block,
                   "peak_buffer_bytes": peak_buffer_bytes(grad, y)}
            if backend == "dense" and n > dense_max:
                rec["iter_time_s"] = None
                rec["skipped"] = (f"dense O(N²) buffers at N={n} "
                                  f"(~{rec['peak_buffer_bytes'] / 1e9:.1f} GB)"
                                  " — over --dense-max")
            else:
                jitted = jax.jit(grad)
                rec["iter_time_s"] = time_fn(jitted, y, warmup=1, iters=iters)
            records.append(rec)
            print(f"# tsne_grad {backend:6s} N={n:6d} "
                  f"peak={rec['peak_buffer_bytes'] / 1e6:10.1f} MB "
                  f"t={rec['iter_time_s']}", flush=True)

        def knn(x_):
            return umap.knn_graph(x_, umap_k, block=block)

        rec = {"stage": "umap_knn", "backend": "tiled", "n": n,
               "block": block, "peak_buffer_bytes": peak_buffer_bytes(knn, x),
               "iter_time_s": time_fn(jax.jit(knn), x, warmup=1, iters=1)}
        records.append(rec)
        print(f"# umap_knn  tiled  N={n:6d} "
              f"peak={rec['peak_buffer_bytes'] / 1e6:10.1f} MB "
              f"t={rec['iter_time_s']:.3f}", flush=True)

    return emit_json({"bench": "embed_scaling", "records": records},
                     json_out)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="8192,16384,32768,65536")
    ap.add_argument("--dense-max", type=int, default=16384,
                    help="largest N at which the dense backend is timed")
    ap.add_argument("--block", type=int, default=512)
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--json-out", default=DEFAULT_JSON)
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    print(run(sizes=sizes, dense_max=args.dense_max, block=args.block,
              iters=args.iters, json_out=args.json_out))


if __name__ == "__main__":
    main()
