"""Per-op kernel-tier microbenchmarks over the dispatch registry.

Every op registered in ``repro.kernels.registry`` (cic splat/gather, the
kNN distance scan, the fused tSNE force tile, the fused segment reduce)
is timed under every mode it supports on this backend — compiled vs
interpret vs the pure-XLA reference — median-of-3 via
``common.time_fn``.  Modes a backend cannot run (compiled on CPU) are
reported as skipped, never silently dropped: the row is the evidence
that the tier was considered.

On CPU the interpret timings are NOT accelerator predictions — the value
is (a) correctness at benchmark scale (``--smoke`` turns the
auto-vs-XLA comparison into a hard CI gate) and (b) the tracked
per-mode trajectory in ``BENCH_kernels.json`` (backend-stamped by
``common.emit_json``, so baselines are only compared within a backend).

``--autotune`` sweeps tile-size candidates for each tunable op and
persists winners to the registry's autotune cache (keyed by
``backend/op/shape-bucket``) — a one-off pass on real hardware that
keeps paying off across processes.
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, emit_json, repo_root_json, time_fn
from repro.core import coo
from repro.kernels import knn_tile, ops, registry

DEFAULT_JSON = repo_root_json("BENCH_kernels.json")

# auto-vs-XLA gate: interpret and compiled reassociate fp sums, so the
# tolerance is fp32-accumulation-loose, not bitwise (the bitwise claims
# live in tests/test_kernel_registry.py on exact-integer payloads)
_RTOL, _ATOL = 1e-4, 1e-5


def _inputs(n: int) -> Dict[str, jnp.ndarray]:
    """Deterministic shared inputs for every op at problem size n."""
    kk = jax.random.split(jax.random.key(0), 9)
    g = 64
    pts = jax.random.uniform(kk[0], (n, 2), jnp.float32, 0.0, g - 1.001)
    m = min(n, 2048)                     # tsne tile is O(m²): keep modest
    t, b, d = max(1, n // 4096), 128, 8  # knn tiles: B queries, 3B window
    rows, fan = max(8, n // 8), 8        # sorted-COO, uniform fan-out
    return {
        "grid_size": g,
        "i0": jnp.floor(pts).astype(jnp.int32),
        "frac": pts - jnp.floor(pts),
        "masses": jax.random.normal(kk[1], (n, 2), jnp.float32),
        "fields": jax.random.normal(kk[2], (2, g, g), jnp.float32),
        "x": jax.random.normal(kk[3], (m, 8), jnp.float32),
        "y": jax.random.normal(kk[4], (m, 2), jnp.float32),
        "beta": jnp.ones((m,), jnp.float32),
        "zp": jnp.full((m,), float(m), jnp.float32),
        "qx": jax.random.normal(kk[5], (t, b, d), jnp.float32),
        "qid": jnp.arange(t * b, dtype=jnp.int32).reshape(t, b),
        "cx": jax.random.normal(kk[6], (t, 3 * b, d), jnp.float32),
        "cid": jax.random.randint(kk[7], (t, 3 * b), -1, t * b,
                                  dtype=jnp.int32),
        "vals": jax.random.normal(kk[8], (rows * fan, 2), jnp.float32),
        "bounds": jnp.arange(rows + 1, dtype=jnp.int32) * fan,
    }


def _cases(v: Dict[str, jnp.ndarray]) -> List[Tuple[str, object]]:
    """One entry per registered op: ``(op, make)`` where ``make(mode)``
    is a zero-arg driver returning the op's output array."""
    return [
        ("cic_splat", lambda mode: (
            lambda: ops.cic_splat(v["i0"], v["frac"], v["masses"],
                                  v["grid_size"], mode=mode))),
        ("cic_gather", lambda mode: (
            lambda: ops.cic_gather(v["fields"], v["i0"], v["frac"],
                                   mode=mode))),
        ("knn_dist_tiles", lambda mode: (
            lambda: knn_tile.distance_tiles(v["qx"], v["qid"], v["cx"],
                                            v["cid"], mode=mode))),
        ("tsne_step", lambda mode: (
            lambda: ops.tsne_step_fused(v["x"], v["y"], v["beta"],
                                        v["zp"], mode=mode))),
        ("segment_reduce", lambda mode: (
            lambda: coo.segment_reduce(v["vals"], v["bounds"],
                                       mode=mode))),
    ]


def _maxdiff(a: np.ndarray, b: np.ndarray) -> float:
    """Max |a−b| over finite entries (+inf == +inf counts as equal)."""
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    both_inf = np.isinf(a) & np.isinf(b) & (np.sign(a) == np.sign(b))
    # zero matched infinities BEFORE subtracting (inf - inf is nan)
    d = np.abs(np.where(both_inf, 0.0, a) - np.where(both_inf, 0.0, b))
    return float(np.max(d)) if d.size else 0.0


def run(n: int = 1 << 16, *, smoke: bool = False,
        json_out: Optional[str] = None, autotune: bool = False) -> str:
    """Bench (or, with ``smoke=True``, gate) every registered op.

    ``smoke`` shrinks the problem, keeps median-of-3 timing, and turns
    the auto-resolution-vs-XLA comparison into an ``AssertionError`` —
    the CI contract that whatever impl auto picks on this backend agrees
    numerically with the ground-truth reference.
    """
    if smoke:
        n = min(n, 4096)
    backend = jax.default_backend()
    v = _inputs(n)
    csv = Csv(["op", "mode", "backend", "seconds", "max_abs_diff_vs_xla",
               "notes"])
    ops_json: Dict[str, dict] = {}
    failures: List[str] = []

    for op, make in _cases(v):
        results: Dict[str, np.ndarray] = {}
        entry: Dict[str, dict] = {}
        auto_mode = registry.resolve(op).mode
        for mode in registry.modes_of(op):
            driver = make(mode)
            try:
                out = np.asarray(jax.block_until_ready(driver()))
            except registry.KernelUnavailableError as e:
                csv.add(op, mode, backend, "skipped", "",
                        f"unsupported: {e}")
                entry[mode] = {"skipped": str(e)}
                continue
            results[mode] = out
            secs = time_fn(driver)
            entry[mode] = {"seconds": round(secs, 6)}
            note = "auto pick" if mode == auto_mode else ""
            csv.add(op, mode, backend, f"{secs:.5f}", "", note)
        # per-mode deviation from the XLA reference
        ref = results.get("xla")
        for mode, out in results.items():
            if ref is None:
                break
            diff = _maxdiff(out, ref)
            entry[mode]["max_abs_diff_vs_xla"] = diff
            for row in csv.rows:
                if row[0] == op and row[1] == mode:
                    row[4] = f"{diff:.2e}"
        ops_json[op] = {"auto_mode": auto_mode, "modes": entry}
        if smoke:
            if ref is None or auto_mode not in results:
                failures.append(f"{op}: auto mode {auto_mode!r} or xla "
                                f"reference did not produce a result")
            elif not np.allclose(results[auto_mode], ref,
                                 rtol=_RTOL, atol=_ATOL):
                failures.append(
                    f"{op}: auto-resolved mode {auto_mode!r} deviates "
                    f"from xla reference by "
                    f"{_maxdiff(results[auto_mode], ref):.3e} "
                    f"(rtol={_RTOL}, atol={_ATOL})")

    if autotune:
        for row in _run_autotune(n, v):
            csv.add(*row)

    payload = {"bench": "kernels", "n": n, "smoke": smoke, "ops": ops_json}
    emit_json(payload, json_out)
    if failures:
        raise AssertionError(
            "bench_kernels --smoke gate failed:\n  " + "\n  ".join(failures))
    title = f"kernel_tiers (per-op compiled/interpret/xla, backend={backend}"
    title += ", SMOKE GATE PASSED)" if smoke else ")"
    return csv.dump(title)


def _run_autotune(n: int, v: Dict[str, jnp.ndarray]):
    """Sweep tile candidates through the PUBLIC wrappers (so padding
    logic sees each candidate) and persist winners to the registry
    autotune cache.  Yields CSV rows describing each winner."""
    backend = jax.default_backend()
    # the best pallas tier this backend actually runs; nothing to tune
    # when auto already lands on the pure-XLA path everywhere
    mode = "compiled" if backend in registry.ACCELERATOR_BACKENDS \
        else "interpret"
    seg_impl = registry.get("segment_reduce", mode)
    sweeps = {
        "cic_splat": (
            [{"block_items": s} for s in (256, 512, 1024, 2048)],
            lambda p: time_fn(lambda: ops.cic_splat(
                v["i0"], v["frac"], v["masses"], v["grid_size"],
                mode=mode, **p))),
        "cic_gather": (
            [{"block_items": s} for s in (256, 512, 1024, 2048)],
            lambda p: time_fn(lambda: ops.cic_gather(
                v["fields"], v["i0"], v["frac"], mode=mode, **p))),
        "tsne_step": (
            [{"block": s} for s in (128, 256, 512)],
            lambda p: time_fn(lambda: ops.tsne_step_fused(
                v["x"], v["y"], v["beta"], v["zp"], mode=mode, **p))),
        "segment_reduce": (
            [{"rows_per_block": r, "edge_chunk": c}
             for r in (64, 128, 256) for c in (256, 512)],
            lambda p: time_fn(lambda: seg_impl.fn(
                v["vals"], v["bounds"], **p))),
    }
    for op, (candidates, measure) in sweeps.items():
        try:
            best = registry.autotune_op(
                op, candidates, measure,
                bucket=registry.shape_bucket((n,)))
        except registry.KernelUnavailableError as e:
            yield (op, mode, backend, "skipped", "", f"autotune: {e}")
            continue
        yield (op, mode, backend, "", "", f"autotuned winner: {best}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1 << 16)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: assert auto resolution matches the "
                         "XLA reference per op (small n)")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep tile-size candidates and persist winners "
                         "to the registry autotune cache")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_kernels-style JSON here "
                         "(default: no file)")
    args = ap.parse_args()
    print(run(args.n, smoke=args.smoke, json_out=args.json,
              autotune=args.autotune))


if __name__ == "__main__":
    main()
