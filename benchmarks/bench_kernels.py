"""Kernel-path benchmarks: Pallas (interpret) vs pure-jnp reference, plus
the sort-based vs scatter-based sketch update paths.

On CPU the interpret-mode timings are NOT TPU predictions — the value is
(a) correctness at benchmark scale and (b) the op-count/roofline numbers
recorded in EXPERIMENTS.md §Perf.  The flop/byte model for the MXU
estimate path is printed alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_fn
from repro.core import sketch
from repro.kernels import ops


def run(n: int = 1 << 16) -> str:
    csv = Csv(["path", "seconds", "notes"])
    keys = jax.random.bits(jax.random.key(0), (2, n), dtype=jnp.uint32)
    sk0 = sketch.init(jax.random.key(1), rows=8, log2_cols=14)

    upd_scatter = jax.jit(sketch.update)
    upd_sorted = jax.jit(sketch.update_sorted)
    csv.add("xla_scatter_update", f"{time_fn(upd_scatter, sk0, keys[0], keys[1]):.5f}",
            f"n={n}")
    csv.add("xla_sort_update", f"{time_fn(upd_sorted, sk0, keys[0], keys[1]):.5f}",
            "production bulk path")

    # estimate: gather vs MXU one-hot (flop model: R*Q*C MAC)
    skf = sketch.update(sk0, keys[0], keys[1])
    q = 1 << 12
    est_ref = jax.jit(sketch.estimate)
    csv.add("xla_gather_estimate",
            f"{time_fn(est_ref, skf, keys[0][:q], keys[1][:q]):.5f}",
            f"q={q}")
    mac = 8 * q * (1 << 14)
    csv.add("mxu_estimate_model", f"{2 * mac / 197e12:.2e}",
            "TPU-v5e seconds at MXU rate (model)")
    return csv.dump("kernel_paths (update/estimate path comparison)")
