"""Quality under shard loss: what partial aggregation actually costs.

    PYTHONPATH=src python -m benchmarks.bench_resilience \
        --json-out BENCH_resilience.json

The paper's deployment model ships per-site summaries to a master; the
resilience layer (PR 9) lets the master proceed when sites are lost.
This bench quantifies the degradation curve on one scenario
(gaussian-mixture stream split over S equal shards, tSNE embed):

  * lose 0 / 1 / 2 of S shards (deterministic chaos via
    ``faults.FaultPlan``) and record, per loss level: coverage, the
    widened heavy-hitter error bound, mass-weighted HH recall against
    the no-loss run, and the final tSNE KL;
  * a flaky-transport run (every shard fails transiently with p = 0.3)
    showing bounded retries recover FULL coverage — resilience is free
    when faults are transient;
  * straggler cutoff wall-clock: a shard sleeping past the deadline
    must not stall the collection.

``--smoke`` reduces sizes and hard-asserts the CI gate: at 1-of-8
shards lost, coverage == 7/8 exactly, HH recall stays above
``RECALL_FLOOR``, and the final KL is within ``KL_RATIO_CEIL`` of the
no-loss run (writes BENCH_resilience_ci.json so the tracked full-size
baseline is never clobbered by a CI box).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, emit_json, repo_root_json
from repro.core import geo, pipeline, quantize, replicas
from repro.core.faults import FaultPlan
from repro.core.resilience import RetryPolicy
from repro.core.tsne import TsneConfig
from repro.data.synthetic import MixtureSpec, gaussian_mixture

DEFAULT_JSON = repo_root_json("BENCH_resilience.json")
KL_RATIO_CEIL = 1.5     # 1-of-8 lost: final KL within 50% of no-loss
RECALL_FLOOR = 0.70     # ...and ≥ 70% of the no-loss HH mass retained


def _shards(n: int, n_shards: int, dims: int, seed: int):
    spec = MixtureSpec(dims=dims, n_clusters=8, cluster_std=0.05,
                       background_frac=0.1)
    pts, _ = gaussian_mixture(n, spec, seed=seed)
    pts = np.asarray(pts, np.float32)
    per = n // n_shards
    return {s: [pts[s * per:(s + 1) * per]] for s in range(n_shards)}, per


def _hh_mass(hh):
    """{packed key: count} over the live heavy hitters."""
    m = np.asarray(hh.mask).astype(bool)
    keys = (np.asarray(hh.key_hi, np.uint64)[m] << np.uint64(32)) \
        | np.asarray(hh.key_lo, np.uint64)[m]
    return dict(zip(keys.tolist(), np.asarray(hh.count)[m].tolist()))


def _embed_kl(cfg, grid, hh, tc):
    """Reps → tSNE embed → final KL (the quality scalar the loss levels
    are compared on; same key discipline as pipeline.embed_stage)."""
    krep, kembed = jax.random.split(jax.random.key(cfg.seed + 1))
    reps = replicas.make_representatives(
        krep, grid, hh, scheme=cfg.replica_scheme,
        max_replicas=cfg.max_replicas, jitter_frac=cfg.jitter_frac)
    pts, w, _ = replicas.compact(reps)
    ecfg = pipeline.resolve_embed_cfg(cfg, tsne_cfg=tc)
    emb, trace = pipeline.embed_points(cfg, kembed, jnp.asarray(pts),
                                       jnp.asarray(w), ecfg)
    assert np.isfinite(np.asarray(emb)).all()
    return float(np.asarray(trace)[-1]), int(pts.shape[0])


def run(n: int = 200_000, n_shards: int = 8, dims: int = 4,
        top_k: int = 512, n_iter: int = 300,
        drops: Sequence[int] = (0, 1, 2), seed: int = 0,
        json_out: Optional[str] = DEFAULT_JSON) -> str:
    data, per = _shards(n, n_shards, dims, seed)
    cfg = pipeline.SnsConfig(bins=12, rows=8, log2_cols=13, top_k=top_k,
                             candidate_pool=2 * top_k,
                             ingest_chunk=16_384, embedder="tsne",
                             embed_backend="dense", max_replicas=4,
                             seed=seed)
    tc = TsneConfig(dims=2, n_iter=n_iter, perplexity=20.0)
    grid = quantize.fit_grid(
        np.concatenate([c for v in data.values() for c in v]), cfg.bins)
    expected = {s: float(per) for s in range(n_shards)}
    policy = RetryPolicy(max_attempts=3, base_delay=0.01)

    def extract(faults=None, deadline=None, pol=policy):
        return geo.resilient_extract(
            grid, data, rows=cfg.rows, log2_cols=cfg.log2_cols,
            top_k=cfg.top_k, candidate_pool=cfg.candidate_pool,
            seed=cfg.seed, chunk_size=cfg.ingest_chunk, policy=pol,
            expected_counts=expected, faults=faults, deadline=deadline)

    # ---- degradation curve: lose 0, 1, 2, ... shards
    base_mass = None
    levels = []
    for k in sorted(drops):
        mask = tuple(range(1, 1 + k))        # deterministic victim set
        res = extract(faults=FaultPlan(seed=seed, drop_shards=mask)
                      if mask else None)
        kl, n_reps = _embed_kl(cfg, grid, res.hh, tc)
        mass = _hh_mass(res.hh)
        if base_mass is None:
            base_mass = mass
        total = sum(base_mass.values())
        recall = sum(c for key, c in base_mass.items()
                     if key in mass) / total
        levels.append({"lost_shards": k, "coverage": res.coverage,
                       "hh_error_bound": res.hh_error_bound,
                       "hh_recall_mass": recall, "final_kl": kl,
                       "n_reps": n_reps})

    # ---- transient faults: retries buy back full coverage
    flaky = extract(faults=FaultPlan(seed=seed, flaky=0.3),
                    pol=RetryPolicy(max_attempts=6, base_delay=0.01))
    assert flaky.coverage == 1.0, \
        f"retries failed to rescue flaky shards: {flaky.coverage}"

    # ---- straggler cutoff: a sleeping shard must not stall the merge
    slow = dict(data)

    def sleeper(chunks=data[0]):
        time.sleep(8.0)
        return list(chunks)

    slow[0] = sleeper
    t0 = time.perf_counter()
    strag = geo.resilient_extract(
        grid, slow, rows=cfg.rows, log2_cols=cfg.log2_cols,
        top_k=cfg.top_k, candidate_pool=cfg.candidate_pool,
        seed=cfg.seed, chunk_size=cfg.ingest_chunk,
        policy=RetryPolicy(max_attempts=1), expected_counts=expected,
        deadline=3.0)
    cutoff_s = time.perf_counter() - t0
    assert 0 in strag.lost and cutoff_s < 8.0

    kl0 = levels[0]["final_kl"]
    csv = Csv(["metric", "value", "note"])
    for lv in levels:
        k = lv["lost_shards"]
        csv.add(f"coverage_lost{k}", f"{lv['coverage']:.4f}",
                f"{k}/{n_shards} shards dropped")
        csv.add(f"hh_error_bound_lost{k}", f"{lv['hh_error_bound']:.0f}",
                "survivor watermark + lost mass")
        csv.add(f"hh_recall_lost{k}", f"{lv['hh_recall_mass']:.4f}",
                "mass-weighted vs no-loss HH set")
        csv.add(f"kl_ratio_lost{k}", f"{lv['final_kl'] / kl0:.4f}",
                f"final KL {lv['final_kl']:.4f} vs {kl0:.4f}")
    csv.add("flaky_retries", flaky.retries,
            "p=0.3 transient/attempt, full coverage recovered")
    csv.add("straggler_cutoff_sec", f"{cutoff_s:.2f}",
            "8s sleeper, 3s deadline: merge not stalled")

    emit_json({"bench": "resilience", "n": n, "n_shards": n_shards,
               "per_shard": per, "top_k": top_k, "n_iter": n_iter,
               "levels": levels,
               "flaky": {"p": 0.3, "retries": flaky.retries,
                         "coverage": flaky.coverage},
               "straggler": {"deadline": 3.0,
                             "cutoff_seconds": cutoff_s}}, json_out)
    return csv.dump("resilience — quality under shard loss, retry "
                    "rescue, straggler cutoff")


def run_smoke(json_out: Optional[str] = "BENCH_resilience_ci.json") -> str:
    """CI gate: 1-of-8 shards lost must degrade, not collapse."""
    out = run(n=24_000, n_shards=8, dims=3, top_k=128, n_iter=120,
              drops=(0, 1), json_out=json_out)
    import json as json_mod
    with open(json_out) as f:
        rec = json_mod.load(f)
    by_k = {lv["lost_shards"]: lv for lv in rec["levels"]}
    l0, l1 = by_k[0], by_k[1]
    assert l0["coverage"] == 1.0
    assert abs(l1["coverage"] - 7 / 8) < 1e-9, l1["coverage"]
    assert l1["hh_error_bound"] > l0["hh_error_bound"]
    assert l1["hh_recall_mass"] >= RECALL_FLOOR, l1["hh_recall_mass"]
    ratio = l1["final_kl"] / l0["final_kl"]
    assert ratio <= KL_RATIO_CEIL, (
        f"1-of-8 shard loss blew up the embedding: final KL ratio "
        f"{ratio:.3f} > {KL_RATIO_CEIL}")
    print(f"# smoke OK: coverage {l1['coverage']:.4f}, recall "
          f"{l1['hh_recall_mass']:.3f}, KL ratio {ratio:.3f}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--n-shards", type=int, default=8)
    ap.add_argument("--dims", type=int, default=4)
    ap.add_argument("--top-k", type=int, default=512)
    ap.add_argument("--n-iter", type=int, default=300)
    ap.add_argument("--drops", default="0,1,2")
    ap.add_argument("--json-out", default=DEFAULT_JSON)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes + hard degradation asserts (CI)")
    args = ap.parse_args()
    if args.smoke:
        out = args.json_out if args.json_out != DEFAULT_JSON \
            else "BENCH_resilience_ci.json"
        print(run_smoke(json_out=out))
        return
    drops = tuple(int(s) for s in args.drops.split(","))
    print(run(n=args.n, n_shards=args.n_shards, dims=args.dims,
              top_k=args.top_k, n_iter=args.n_iter, drops=drops,
              json_out=args.json_out))


if __name__ == "__main__":
    main()
